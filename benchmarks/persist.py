"""Durability benchmarks: recovery time vs. WAL length, WAL write overhead,
and churn-drift before/after sketch compaction.

All functions run in-process on the single-device index (no forced device
counts), sized so the whole module stays CI-friendly.  Rows follow run.py's
``(name, value, derived)`` convention.
"""

from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np


def _corpus(n_docs, seed=0):
    from repro.data import synth
    ds = synth.SparseDatasetSpec("persist", n=2000, psi_doc=32, psi_query=12,
                                 value_dist="gaussian")
    idx, val = synth.make_corpus(seed, ds, n_docs, pad=48)
    return ds, idx, val


def _spec(capacity):
    from repro.core.engine import EngineSpec
    return EngineSpec(n=2000, m=16, capacity=capacity, max_nnz=48, h=1,
                      value_dtype="float32")


def persist_recovery():
    """Recovery wall-time vs. WAL tail length (snapshot fixed at op 0)."""
    from repro.persist.durable import DurableSinnamonIndex

    rows = []
    for n_ops in (256, 1024):
        d = tempfile.mkdtemp(prefix="bench_persist_")
        try:
            ds, idx, val = _corpus(n_ops)
            index = DurableSinnamonIndex.open(
                _spec(((n_ops + 31) // 32) * 32),
                wal_dir=os.path.join(d, "wal"),
                snapshot_dir=os.path.join(d, "snap"))
            index.snapshot()                      # empty base snapshot
            bs = 64
            for lo in range(0, n_ops, bs):
                hi = min(lo + bs, n_ops)
                index.insert_many(list(range(lo, hi)), idx[lo:hi],
                                  val[lo:hi])
            t0 = time.perf_counter()
            rec = DurableSinnamonIndex.open(
                index.spec, wal_dir=os.path.join(d, "wal"),
                snapshot_dir=os.path.join(d, "snap"))
            dt = (time.perf_counter() - t0) * 1e3
            assert rec.size == n_ops
            rows.append((f"persist/recovery_ms_wal{n_ops}", f"{dt:.1f}",
                         f"{n_ops / max(dt, 1e-9) * 1e3:.0f} docs/s"))
        finally:
            shutil.rmtree(d, ignore_errors=True)
    return rows


def persist_overhead():
    """Insert throughput with the WAL off / on (fsync off) / on (fsync)."""
    from repro.core.engine import SinnamonIndex
    from repro.persist.durable import DurableSinnamonIndex

    n_docs, bs = 1024, 64
    ds, idx, val = _corpus(n_docs)
    spec = _spec(((n_docs + 31) // 32) * 32)

    def run(build):
        d = tempfile.mkdtemp(prefix="bench_persist_")
        try:
            index = build(d)
            t0 = time.perf_counter()
            for lo in range(0, n_docs, bs):
                hi = min(lo + bs, n_docs)
                index.insert_many(list(range(lo, hi)), idx[lo:hi],
                                  val[lo:hi])
            import jax
            jax.block_until_ready(index.state.u)
            return n_docs / (time.perf_counter() - t0)
        finally:
            shutil.rmtree(d, ignore_errors=True)

    run(lambda d: SinnamonIndex(spec))       # jit-compile warmup, unmeasured
    base = run(lambda d: SinnamonIndex(spec))
    nosync = run(lambda d: DurableSinnamonIndex(
        spec, wal_dir=os.path.join(d, "wal"), fsync=False))
    sync = run(lambda d: DurableSinnamonIndex(
        spec, wal_dir=os.path.join(d, "wal"), fsync=True))
    return [
        ("persist/insert_tput_wal_off", f"{base:.1f}", "docs/s"),
        ("persist/insert_tput_wal_nosync", f"{nosync:.1f}",
         f"{nosync / base:.2f}x of off"),
        ("persist/insert_tput_wal_fsync", f"{sync:.1f}",
         f"{sync / base:.2f}x of off"),
    ]


def persist_drift():
    """Churn drift: max/mean sketch overestimate after delete/re-insert
    cycles, and the same after compaction (should collapse to ~0)."""
    from repro.core.engine import SinnamonIndex
    from repro.persist import compact

    n_docs = 512
    ds, idx, val = _corpus(n_docs)
    index = SinnamonIndex(_spec(n_docs))
    index.insert_many(list(range(n_docs)), idx, val)
    gen = np.random.Generator(np.random.Philox(key=7))
    next_id = n_docs
    for _ in range(4):                       # churn: delete + recycle waves
        victims = gen.choice(index.doc_ids(), size=n_docs // 4,
                             replace=False)
        for v in victims:
            index.delete(int(v))
        fresh_i, fresh_v = _corpus(len(victims), seed=next_id)[1:]
        index.insert_many(list(range(next_id, next_id + len(victims))),
                          fresh_i, fresh_v)
        next_id += len(victims)
    before = compact.drift_metrics(index)
    t0 = time.perf_counter()
    rebuilt = index.compact()
    dt = (time.perf_counter() - t0) * 1e3
    after = compact.drift_metrics(index)
    return [
        ("persist/drift_max_before", f"{before['max_overestimate']:.4f}",
         f"{before['dirty_active']} recycled slots"),
        ("persist/drift_mean_before", f"{before['mean_overestimate']:.4f}",
         ""),
        ("persist/drift_max_after", f"{after['max_overestimate']:.4f}",
         f"compacted {rebuilt} cols in {dt:.0f}ms"),
    ]


def persist_smoke():
    """CI-sized durability round trip: snapshot → more ops → truncate the
    WAL mid-record → recover → compare queries against the surviving-ops
    reference.  Exercises the whole persist stack in a few seconds."""
    from repro.persist import wal
    from repro.persist.durable import DurableSinnamonIndex

    d = tempfile.mkdtemp(prefix="bench_persist_")
    try:
        from repro.data import synth
        n_docs = 256
        ds, idx, val = _corpus(n_docs)
        spec = _spec(n_docs)
        index = DurableSinnamonIndex.open(
            spec, wal_dir=os.path.join(d, "wal"),
            snapshot_dir=os.path.join(d, "snap"))
        index.insert_many(list(range(128)), idx[:128], val[:128])
        index.snapshot()
        for e in range(0, 16):
            index.delete(e)
        index.insert_many(list(range(128, n_docs)), idx[128:], val[128:])
        # tear the last record mid-payload, as a crash would
        part = os.path.join(d, "wal", wal.partition_name(0))
        seg = os.path.join(part, sorted(os.listdir(part))[-1])
        with open(seg, "r+b") as f:
            f.truncate(os.path.getsize(seg) - 11)
        t0 = time.perf_counter()
        rec = DurableSinnamonIndex.open(
            spec, wal_dir=os.path.join(d, "wal"),
            snapshot_dir=os.path.join(d, "snap"))
        dt = (time.perf_counter() - t0) * 1e3
        # the torn record is the last insert batch: 128 snapshot docs
        # minus 16 deletes must have survived
        ok = rec.size == 128 - 16
        qi, qv = synth.make_queries(3, ds, 4, pad=24)
        ids, _ = rec.search(qi[0], qv[0], k=10, kprime=64)
        ok &= not (set(range(16)) & set(ids.tolist()))
        if not ok:      # raise so run.py emits an ERROR row and CI fails
            raise RuntimeError(
                f"persist smoke failed: recovered {rec.size} docs, "
                f"top ids {ids.tolist()}")
        return [
            ("persist/smoke_recovered_docs", str(rec.size),
             "after mid-record WAL truncation"),
            ("persist/smoke_recovery_ms", f"{dt:.1f}", ""),
            ("persist/smoke_ok", str(int(ok)), "1 = queries consistent"),
        ]
    finally:
        shutil.rmtree(d, ignore_errors=True)


ALL = [persist_smoke, persist_recovery, persist_overhead, persist_drift]
