"""Serving front-door benchmarks (the ISSUE 7 acceptance gate).

Two entries, both emitted as ``run.py`` rows (``--json`` writes
BENCH_serving.json — schema documented in docs/serving.md):

* ``serving_sweep`` — offered-load sweep of the front door in two
  configurations over the SAME index and query stream:

  - ``batch1``  — ``max_batch=1, batch_window_ms=0``: every request is its
    own device dispatch (the no-coalescing baseline);
  - ``batched`` — ``max_batch=16, batch_window_ms=2``: deadline-aware
    dynamic batching into fused ``query_many`` dispatches.

  Each operating point reports goodput (OK-within-deadline per second),
  p50/p99/p999 latency, and rejected/expired counts.  The gate compares
  the best goodput each configuration achieves while holding the same p99
  SLO (adaptively set from the warm single-query latency, so the gate
  tracks the machine): **batched must deliver >= 2x the goodput of batch1
  at equal p99** — the whole reason the front door exists, since fused
  ``query_many`` amortizes dispatch overhead across the coalesced batch.

* ``serving_smoke`` — boots the HTTP front door, drives it with concurrent
  closed-loop clients inside the capacity envelope, and asserts every
  request succeeded (zero dropped-for-the-wrong-reason) with answers
  identical to direct ``QueryServer.query`` calls.
"""

from __future__ import annotations

import numpy as np

_SLO_MULT = 16.0       # SLO = _SLO_MULT x warm single-query p50
_GATE_RATIO = 2.0
_MAX_BATCH = 16
_WINDOW_MS = 2.0
_POINT_SECONDS = 2.0
_CLIENTS = 32


def _frontend(server, *, max_batch, window_ms, registry=None):
    from repro.obs import NULL_REGISTRY
    from repro.serving.frontend import ServingFrontend

    return ServingFrontend(
        server, max_batch=max_batch, batch_window_ms=window_ms,
        queue_depth=4 * _CLIENTS,
        registry=NULL_REGISTRY if registry is None else registry)


def _warm_p50_ms(server, qi, qv, reps=20):
    """Warm per-request latency of the uncoalesced path (compile excluded)."""
    import time

    from repro.serving.frontend import ServingFrontend

    fe = ServingFrontend(server, max_batch=1, batch_window_ms=0.0,
                         queue_depth=8)
    try:
        for b in range(4):                            # compile warmup
            fe.query(qi[b % len(qi)], qv[b % len(qv)])
        lat = []
        for r in range(reps):
            t0 = time.perf_counter()
            fe.query(qi[r % len(qi)], qv[r % len(qv)])
            lat.append((time.perf_counter() - t0) * 1e3)
        return float(np.median(lat))
    finally:
        fe.close()


def _sweep_config(server, queries, *, max_batch, window_ms, offered, slo_ms):
    from repro.serving import loadgen

    fe = _frontend(server, max_batch=max_batch, window_ms=window_ms)
    try:
        # warm every dispatch shape this config will see
        for _ in range(2):
            fs = [fe.submit(qi, qv) for qi, qv in queries[:max_batch]]
            for f in fs:
                f.result()
        points = []
        for qps in offered:
            points.append(loadgen.run_point(
                loadgen.frontend_client(fe, deadline_ms=slo_ms),
                queries, qps, clients=_CLIENTS,
                duration_s=_POINT_SECONDS))
        return points
    finally:
        fe.close()


def _slo_goodput(point, slo_ms):
    """Responses served WITHIN the SLO per second — late answers don't
    count, so both configurations are compared at the same latency bound
    (the "equal p99" condition of the gate, enforced per response)."""
    within = sum(1 for lat in point.latencies_ms if lat <= slo_ms)
    return within / point.duration_s


def _best_point(points, slo_ms):
    """(slo_goodput, point) of the best operating point for a config."""
    best = max(points, key=lambda p: _slo_goodput(p, slo_ms))
    return _slo_goodput(best, slo_ms), best


def serving_sweep():
    """Offered-load sweep: batched vs batch=1 goodput at equal p99 SLO."""
    from benchmarks.query_path import _build
    from repro.serving.serve import QueryServer

    index, _, _, qi, qv = _build(2048)
    server = QueryServer(index, k=10, kprime=100)
    queries = [(qi[b], qv[b]) for b in range(qi.shape[0])]

    t1_ms = _warm_p50_ms(server, qi, qv)
    slo_ms = _SLO_MULT * t1_ms
    base_qps = 1e3 / t1_ms
    offered = [base_qps * mult for mult in (0.5, 1.0, 2.0, 4.0, 8.0)]

    rows = [("serving/warm_single_p50_ms", f"{t1_ms:.3f}",
             f"SLO <= {slo_ms:.1f}ms ({_SLO_MULT:g}x warm p50)")]
    sweeps = {}
    for name, mb, win in (("batch1", 1, 0.0),
                          ("batched", _MAX_BATCH, _WINDOW_MS)):
        points = _sweep_config(server, queries, max_batch=mb,
                               window_ms=win, offered=offered,
                               slo_ms=slo_ms)
        sweeps[name] = points
        for p in points:
            r = p.to_row()
            tag = f"serving/{name}/offered{r['offered_qps']:.0f}"
            rows += [
                (f"{tag}/goodput_qps", f"{r['goodput_qps']:.1f}",
                 f"achieved {r['achieved_qps']:.1f} qps"),
                (f"{tag}/p50_ms", f"{r['p50_ms']:.3f}", ""),
                (f"{tag}/p99_ms", f"{r['p99_ms']:.3f}", ""),
                (f"{tag}/p999_ms", f"{r['p999_ms']:.3f}", ""),
                (f"{tag}/rejected", str(r["rejected"]),
                 "backpressure (queue_full/throttled)"),
                (f"{tag}/expired", str(r["expired"]),
                 "deadline elapsed in queue"),
            ]
            if r["errors"]:
                raise RuntimeError(
                    f"{tag}: {r['errors']} requests failed outright "
                    f"(neither served, rejected, nor expired)")

    g1, pt1 = _best_point(sweeps["batch1"], slo_ms)
    gb, ptb = _best_point(sweeps["batched"], slo_ms)
    ratio = gb / max(g1, 1e-9)
    rows += [
        ("serving/batch1/goodput_at_slo_qps", f"{g1:.1f}",
         f"within-SLO responses/s at offered {pt1.offered_qps:.0f} "
         f"(p99 {pt1.p99_ms:.1f}ms)"),
        ("serving/batched/goodput_at_slo_qps", f"{gb:.1f}",
         f"within-SLO responses/s at offered {ptb.offered_qps:.0f} "
         f"(p99 {ptb.p99_ms:.1f}ms)"),
        ("serving/goodput_ratio", f"{ratio:.2f}",
         f"batched/batch1 within SLO {slo_ms:.1f}ms "
         f"(gate >= {_GATE_RATIO:g})"),
    ]
    if g1 <= 0:
        raise RuntimeError(
            f"batch1 never served a response within the {slo_ms:.1f}ms "
            f"SLO — sweep misconfigured for this machine, cannot "
            f"evaluate the gate")
    if ratio < _GATE_RATIO:
        raise RuntimeError(
            f"dynamic batching goodput ratio {ratio:.2f} < "
            f"{_GATE_RATIO:g} gate at equal p99 "
            f"(batch1 {g1:.1f} qps vs batched {gb:.1f} qps, "
            f"SLO {slo_ms:.1f}ms)")
    rows.append(("serving/gate", "PASS",
                 f"batched >= {_GATE_RATIO:g}x batch1 goodput at equal p99"))
    return rows


def serving_smoke():
    """HTTP front door under concurrent clients: zero wrong-reason drops."""
    import json
    import threading
    import urllib.request

    from benchmarks.query_path import _build
    from repro.obs import MetricsRegistry, parse_exposition
    from repro.serving.frontend import FrontendServer, ServingFrontend
    from repro.serving.serve import QueryServer

    n_clients, per_client = 4, 16
    index, _, _, qi, qv = _build(1024)
    registry = MetricsRegistry()
    server = QueryServer(index, k=10, kprime=100, registry=registry)
    expect = [server.query(qi[b], qv[b]) for b in range(n_clients)]
    fe = ServingFrontend(server, max_batch=8, batch_window_ms=2.0,
                         queue_depth=256, default_deadline_ms=30_000.0,
                         registry=registry)
    outcomes = {"ok": 0, "mismatch": 0, "error": 0}
    lock = threading.Lock()
    with FrontendServer(fe, port=0, registry=registry) as door:
        url = door.url + "/v1/query"

        def client(c):
            body = json.dumps({"indices": qi[c].tolist(),
                               "values": qv[c].tolist(),
                               "tenant": f"smoke-{c}"}).encode()
            want = [int(i) for i in np.asarray(expect[c].ids)]
            for _ in range(per_client):
                try:
                    req = urllib.request.Request(url, data=body,
                                                 method="POST")
                    doc = json.loads(urllib.request.urlopen(
                        req, timeout=60).read())
                    good = doc["ids"] == want
                except Exception:                       # noqa: BLE001
                    with lock:
                        outcomes["error"] += 1
                    continue
                with lock:
                    outcomes["ok" if good else "mismatch"] += 1

        threads = [threading.Thread(target=client, args=(c,))
                   for c in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        scrape = urllib.request.urlopen(door.url + "/metrics",
                                        timeout=10).read().decode()
    fe.close()
    families = {name.split("_bucket")[0].split("_sum")[0]
                    .split("_count")[0]
                for (name, _labels) in parse_exposition(scrape)}
    for fam in ("repro_frontend_requests_total",
                "repro_frontend_batch_size",
                "repro_frontend_queue_depth"):
        if fam not in families:
            raise RuntimeError(f"{fam} missing from /metrics scrape")
    total = n_clients * per_client
    if outcomes["ok"] != total:
        raise RuntimeError(
            f"smoke dropped requests for the wrong reason: {outcomes} "
            f"(expected {total} ok — the load is inside the capacity "
            f"envelope, nothing should be rejected, expired, or wrong)")
    return [
        ("serving_smoke/requests", str(total),
         f"{n_clients} concurrent HTTP clients"),
        ("serving_smoke/ok", str(outcomes["ok"]),
         "answers identical to direct QueryServer.query"),
        ("serving_smoke/gate", "PASS", "zero wrong-reason drops"),
    ]


ALL = [serving_sweep, serving_smoke]


if __name__ == "__main__":
    # Standalone entry: `python benchmarks/serving.py [--json PATH]`.
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks import run as _run

    sys.argv = [sys.argv[0], "serving"] + sys.argv[1:]
    _run.main()
