"""Benchmark harnesses — one per paper table/figure (DESIGN.md §7).

Each function returns a list of CSV rows (name, value, derived); run.py
prints them.  Sizes are scaled down to run on a 1-CPU container in minutes;
the *structure* of each experiment matches its paper counterpart exactly.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as eng
from repro.core import sketch, theory
from repro.core.engine import EngineSpec, SinnamonIndex
from repro.core.linscan import LinScanIndex, brute_force_topk
from repro.core.wand import WandIndex
from repro.data import synth


def _recall(ids, ids0):
    return len(set(np.asarray(ids).tolist())
               & set(np.asarray(ids0).tolist())) / len(ids0)


# ---------------------------------------------------------------------------
# Table 1 / Table 2 — probability & expectation of sketching error
# ---------------------------------------------------------------------------

def table1_error_prob():
    rows = []
    psi = 120
    dists = [("uniform", theory.uniform_dist(-1, 1)),
             ("gaussian_1", theory.gaussian_dist(0, 1)),
             ("zeta_2.5", theory.zeta_dist(2.5))]
    for name, (pdf, cdf, grid) in dists:
        for m in (60, 120, 240):
            for h in (1, 2, 3):
                p = theory.prob_overestimate(pdf, cdf, grid, psi, m, h)
                rows.append((f"table1/{name}/m{m}/h{h}", round(p, 4), ""))
    return rows


def table2_expected_error():
    rows = []
    psi = 120
    dists = [("uniform", theory.uniform_dist(-1, 1)),
             ("gaussian_0.1", theory.gaussian_dist(0, 0.1))]
    for name, (pdf, cdf, grid) in dists:
        for m in (60, 120, 240):
            e = theory.expected_error(pdf, cdf, grid, psi, m, 1)
            rows.append((f"table2/{name}/m{m}/h1", round(e, 4), ""))
    return rows


# ---------------------------------------------------------------------------
# Fig. 4 / Fig. 7a — CDF of the sketching error: theory vs Monte-Carlo
# ---------------------------------------------------------------------------

def fig4_error_cdf():
    gen = np.random.default_rng(0)
    n, psi, m, h = 600, 120, 120, 1
    mp = jnp.asarray(sketch.make_mappings(7, n, m, h))
    errs = []
    for _ in range(40):
        active = gen.random(n) < psi / n
        k = int(active.sum())
        idx = np.full(n, -1, np.int32)
        val = np.zeros(n, np.float32)
        idx[:k] = np.where(active)[0]
        val[:k] = gen.normal(0, 1, k)
        u, l = sketch.encode(mp, m, jnp.asarray(idx), jnp.asarray(val),
                             dtype="float32")
        ub, _ = sketch.decode_vector(mp, u, l, jnp.asarray(idx))
        errs.append(np.asarray(ub)[:k] - val[:k])
    errs = np.concatenate(errs)
    pdf, cdf, grid = theory.gaussian_dist(0, 1.0)
    rows = []
    for delta in (0.1, 0.25, 0.5, 1.0, 2.0):
        emp = float((errs <= delta).mean())
        pred = float(theory.error_cdf(delta, pdf, cdf, grid, psi, m, h))
        rows.append((f"fig4/cdf@{delta}/empirical", round(emp, 4), ""))
        rows.append((f"fig4/cdf@{delta}/theory", round(pred, 4),
                     f"abs_err={abs(emp - pred):.3f}"))
    return rows


# ---------------------------------------------------------------------------
# Fig. 5 — normality of the standardised inner-product error Z
# ---------------------------------------------------------------------------

def fig5_z_normality():
    gen = np.random.default_rng(1)
    n, psi_d, m, psi_q = 600, 120, 60, 16
    p = psi_d / n
    pdf, cdf, grid = theory.gaussian_dist(0, 1.0)
    mu = theory.expected_error(pdf, cdf, grid, psi_d, m, 1)
    deltas = np.linspace(0, 8, 300)
    tail = 1.0 - np.asarray(theory.error_cdf(deltas, pdf, cdf, grid,
                                             psi_d, m, 1))
    e2 = float(np.trapezoid(2 * deltas * tail, deltas))
    _, var_u = theory.unconditional_moments(p, mu, e2 - mu ** 2)
    # Monte-Carlo pool of per-coordinate errors
    mp = jnp.asarray(sketch.make_mappings(3, n, m, 1))
    pool = []
    for _ in range(60):
        active = gen.random(n) < p
        k = int(active.sum())
        idx = np.full(n, -1, np.int32); val = np.zeros(n, np.float32)
        idx[:k] = np.where(active)[0]; val[:k] = gen.normal(0, 1, k)
        u, l = sketch.encode(mp, m, jnp.asarray(idx), jnp.asarray(val),
                             dtype="float32")
        ub, _ = sketch.decode_vector(mp, u, l, jnp.asarray(idx))
        pool.append(np.asarray(ub)[:k] - val[:k])
    pool = np.concatenate(pool)
    zs = []
    for _ in range(500):
        qv = np.abs(gen.normal(0, 1, psi_q))
        ei = np.where(gen.random(psi_q) < p, gen.choice(pool, psi_q), 0.0)
        zs.append(theory.z_statistic(np.array([np.sum(qv * ei)]), qv, p,
                                     mu, var_u)[0])
    zs = np.asarray(zs)
    return [("fig5/z_mean", round(float(zs.mean()), 3), "expect ~0"),
            ("fig5/z_std", round(float(zs.std()), 3), "expect ~1"),
            ("fig5/z_skew", round(float(
                ((zs - zs.mean()) ** 3).mean() / zs.std() ** 3), 3), "")]


# ---------------------------------------------------------------------------
# Table 4 — G100/G200-style: index size / latency / recall per algorithm
# ---------------------------------------------------------------------------

def _bench_search(fn, queries, warmup=2):
    for q in queries[:warmup]:
        fn(*q)
    t0 = time.perf_counter()
    for q in queries:
        fn(*q)
    return (time.perf_counter() - t0) / len(queries) * 1e3


def table4_retrieval(n_docs=20_000, n_queries=20):
    ds = synth.SparseDatasetSpec("g100s", n=10_000, psi_doc=100,
                                 psi_query=100, value_dist="gaussian")
    idx, val = synth.make_corpus(0, ds, n_docs, pad=160)
    qi, qv = synth.make_queries(1, ds, n_queries, pad=160)
    k = 100
    truth = [brute_force_topk(idx, val, qi[b], qv[b], ds.n, k)[0]
             for b in range(n_queries)]
    rows = []

    w = WandIndex(ds.n)
    w.build(range(n_docs), idx, val)
    lat = _bench_search(lambda a, b: w.search(a, b, k),
                        [(qi[b], qv[b]) for b in range(n_queries)])
    rec = np.mean([_recall(w.search(qi[b], qv[b], k)[0], truth[b])
                   for b in range(n_queries)])
    rows.append(("table4/wand/latency_ms", round(lat, 2),
                 f"recall={rec:.3f} size={w.memory_bytes()/2**20:.1f}MiB"))

    ls = LinScanIndex(ds.n)
    ls.insert_many(range(n_docs), idx, val)
    lat = _bench_search(lambda a, b: ls.search(a, b, k),
                        [(qi[b], qv[b]) for b in range(n_queries)])
    rec = np.mean([_recall(ls.search(qi[b], qv[b], k)[0], truth[b])
                   for b in range(n_queries)])
    rows.append(("table4/linscan/latency_ms", round(lat, 2),
                 f"recall={rec:.3f} size={ls.memory_bytes()/2**20:.1f}MiB"))

    for m_frac, budget in ((0.37, None), (0.37, 50)):
        m = int(100 * m_frac)
        spec = EngineSpec(n=ds.n, m=m, capacity=((n_docs + 31) // 32) * 32,
                          max_nnz=160, h=1)
        index = SinnamonIndex(spec)
        index.insert_many(list(range(n_docs)), idx, val)
        fn = lambda a, b: index.search(a, b, k=k, kprime=max(4 * k, 400),
                                       budget=budget)
        lat = _bench_search(fn, [(qi[b], qv[b]) for b in range(n_queries)])
        rec = np.mean([_recall(fn(qi[b], qv[b])[0], truth[b])
                       for b in range(n_queries)])
        mem = index.memory_bytes()
        tag = f"T{budget or 'inf'}"
        rows.append((f"table4/sinnamon_2m{2*m}_{tag}/latency_ms",
                     round(lat, 2),
                     f"recall={rec:.3f} "
                     f"index={mem['index_total']/2**20:.1f}MiB"))
    return rows


# ---------------------------------------------------------------------------
# Fig. 8/11 — latency–memory–accuracy Pareto over (m, budget)
# ---------------------------------------------------------------------------

def fig8_tradeoffs(n_docs=8_000, n_queries=12):
    ds = synth.SPLADE_LIKE
    idx, val = synth.make_corpus(2, ds, n_docs, pad=256)
    qi, qv = synth.make_queries(3, ds, n_queries, pad=96)
    k = 100
    truth = [brute_force_topk(idx, val, qi[b], qv[b], ds.n, k)[0]
             for b in range(n_queries)]
    rows = []
    for m in (30, 60, 90):
        spec = EngineSpec(n=ds.n, m=m, capacity=((n_docs + 31) // 32) * 32,
                          max_nnz=256, h=1, positive_only=True)
        index = SinnamonIndex(spec)
        index.insert_many(list(range(n_docs)), idx, val)
        for budget in (8, 16, None):
            fn = lambda a, b: index.search(a, b, k=k, kprime=400,
                                           budget=budget)
            lat = _bench_search(fn, [(qi[b], qv[b])
                                     for b in range(n_queries)])
            rec = np.mean([_recall(fn(qi[b], qv[b])[0], truth[b])
                           for b in range(n_queries)])
            mem = index.memory_bytes()["index_total"] / 2 ** 20
            rows.append((f"fig8/m{m}/T{budget or 'inf'}",
                         round(lat, 2),
                         f"recall={rec:.3f} index_MiB={mem:.1f}"))
    return rows


# ---------------------------------------------------------------------------
# Fig. 10 — recall vs k'
# ---------------------------------------------------------------------------

def fig10_kprime(n_docs=8_000, n_queries=12):
    ds = synth.SPLADE_LIKE
    idx, val = synth.make_corpus(4, ds, n_docs, pad=256)
    qi, qv = synth.make_queries(5, ds, n_queries, pad=96)
    k = 100
    truth = [brute_force_topk(idx, val, qi[b], qv[b], ds.n, k)[0]
             for b in range(n_queries)]
    spec = EngineSpec(n=ds.n, m=30, capacity=((n_docs + 31) // 32) * 32,
                      max_nnz=256, h=1, positive_only=True)
    index = SinnamonIndex(spec)
    index.insert_many(list(range(n_docs)), idx, val)
    rows = []
    for kprime in (100, 200, 400, 800, 1600):
        rec = np.mean([_recall(index.search(qi[b], qv[b], k=k,
                                            kprime=kprime)[0], truth[b])
                       for b in range(n_queries)])
        rows.append((f"fig10/kprime{kprime}/recall", round(float(rec), 4),
                     ""))
    return rows


# ---------------------------------------------------------------------------
# Fig. 12 — insertion throughput / deletion latency over index life
# ---------------------------------------------------------------------------

def fig12_updates(n_docs=4_096):
    ds = synth.SparseDatasetSpec("t", n=5_000, psi_doc=60, psi_query=20)
    idx, val = synth.make_corpus(6, ds, n_docs, pad=96)
    spec = EngineSpec(n=ds.n, m=30, capacity=n_docs, max_nnz=96, h=1)
    index = SinnamonIndex(spec)
    rows = []
    bs = 256
    for lo in range(0, n_docs, bs):
        t0 = time.perf_counter()
        index.insert_many(list(range(lo, lo + bs)), idx[lo:lo + bs],
                          val[lo:lo + bs])
        jax.block_until_ready(index.state.u)
        dt = time.perf_counter() - t0
        if lo in (0, n_docs // 2, n_docs - bs):
            rows.append((f"fig12/insert_tput@{lo + bs}",
                         round(bs / dt, 1), "docs/s"))
    gen = np.random.default_rng(0)
    victims = gen.choice(n_docs, 64, replace=False)
    t0 = time.perf_counter()
    for v in victims:
        index.delete(int(v))
    jax.block_until_ready(index.state.bits)
    rows.append(("fig12/delete_ms", round(
        (time.perf_counter() - t0) / 64 * 1e3, 2), "ms/doc"))
    return rows


# ---------------------------------------------------------------------------
# Table 5 — parallel scaling (shard-count structural scaling on CPU)
# ---------------------------------------------------------------------------

def table5_parallelism(n_docs=8_192, n_queries=8):
    """Per-shard work scales ~1/S (the SPMD equivalent of thread speed-up).

    On this 1-core container wall-clock can't show parallel speed-up, so we
    report per-shard scoring work (C_local · ψ_q reads) and measured
    single-shard latency at each shard count — the structural analogue of
    the paper's Table 5.
    """
    ds = synth.G100
    idx, val = synth.make_corpus(7, ds, n_docs, pad=160)
    qi, qv = synth.make_queries(8, ds, n_queries, pad=160)
    rows = []
    for shards in (1, 2, 4, 8):
        c_local = n_docs // shards
        spec = EngineSpec(n=ds.n, m=37, capacity=c_local, max_nnz=160, h=1)
        index = SinnamonIndex(spec)
        index.insert_many(list(range(c_local)), idx[:c_local],
                          val[:c_local])
        fn = lambda a, b: index.search(a, b, k=10, kprime=100)
        lat = _bench_search(fn, [(qi[b], qv[b]) for b in range(n_queries)])
        rows.append((f"table5/shards{shards}/local_latency_ms",
                     round(lat, 2), f"C_local={c_local}"))
    return rows


ALL = [table1_error_prob, table2_expected_error, fig4_error_cdf,
       fig5_z_normality, table4_retrieval, fig8_tradeoffs, fig10_kprime,
       fig12_updates, table5_parallelism]
