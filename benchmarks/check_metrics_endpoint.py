"""CI check: launch the demo server with --metrics-port and validate /metrics.

Boots ``repro.launch.serve`` as a subprocess with a metrics endpoint, an
event log and tracing enabled, then:

1. polls ``/metrics`` until the per-stage and latency histogram families
   appear (i.e. the server actually served traced queries),
2. parses the full Prometheus exposition with
   ``repro.obs.metrics.parse_exposition`` (malformed lines raise),
3. asserts the required metric families from the ISSUE acceptance list are
   present (per-stage latency, WAL-independent engine health, byte gauges),
4. fetches ``/metrics.json`` and checks it is valid JSON with the same
   metric names,
5. checks the event log contains parseable ``query`` events with spans,
6. hits the ISSUE 8 surfaces on the same port — ``/readyz`` (must be 200
   with per-check detail once the engine is built), ``/debug/requests``
   (flight-recorder ring + stats schema), and ``/debug/slo`` (declared
   objectives + per-window burn rates) — validating each JSON schema.

Exit 0 on success; raises (non-zero) on any failure.  Run as
``python benchmarks/check_metrics_endpoint.py`` from the repo root.
"""

from __future__ import annotations

import json
import os
import socket
import subprocess
import sys
import tempfile
import time
import urllib.request

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

# Families the endpoint must expose once a traced query has been served.
REQUIRED = (
    "repro_query_latency_ms_count",
    "repro_query_stage_ms_count",
    "repro_queries_total",
    "repro_engine_live_docs",
    "repro_engine_bytes",
    "repro_engine_ops_total",
)
_READY_MARKERS = ("repro_query_stage_ms", "repro_query_latency_ms_count")
_TIMEOUT_S = 240.0


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _fetch(url: str) -> str:
    with urllib.request.urlopen(url, timeout=10) as resp:
        return resp.read().decode("utf-8")


def main() -> None:
    from repro.obs.metrics import parse_exposition

    port = _free_port()
    event_log = os.path.join(tempfile.mkdtemp(prefix="obs_check_"),
                             "events.jsonl")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_ROOT, "src"), env.get("PYTHONPATH", "")])
    cmd = [sys.executable, "-m", "repro.launch.serve",
           "--docs", "512", "--queries", "16", "--query-batch", "8",
           "--kprime", "64", "--metrics-port", str(port),
           "--event-log", event_log, "--trace-every", "2",
           "--hold-seconds", "600"]
    print(f"+ {' '.join(cmd)}")
    proc = subprocess.Popen(cmd, env=env, cwd=_ROOT,
                            stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True)
    try:
        base = f"http://127.0.0.1:{port}"
        deadline = time.time() + _TIMEOUT_S
        text = ""
        while time.time() < deadline:
            if proc.poll() is not None:
                out = proc.stdout.read() if proc.stdout else ""
                raise RuntimeError(
                    f"server exited early (rc={proc.returncode}):\n{out}")
            try:
                text = _fetch(base + "/metrics")
            except OSError:
                time.sleep(0.5)
                continue
            if all(m in text for m in _READY_MARKERS):
                break
            time.sleep(0.5)
        else:
            raise RuntimeError(
                f"timed out after {_TIMEOUT_S}s waiting for "
                f"{_READY_MARKERS} in /metrics; last scrape:\n{text[:2000]}")

        flat = parse_exposition(text)   # raises on malformed exposition
        names = {name for name, _ in flat}
        missing = [m for m in REQUIRED if m not in names]
        if missing:
            raise RuntimeError(f"missing metric families: {missing}")
        stages = sorted({dict(labels).get("stage")
                         for name, labels in flat
                         if name == "repro_query_stage_ms_count"})
        print(f"/metrics OK: {len(flat)} series, stages={stages}")

        doc = json.loads(_fetch(base + "/metrics.json"))
        missing = [m for m in ("repro_query_latency_ms",
                               "repro_engine_live_docs") if m not in doc]
        if missing:
            raise RuntimeError(f"/metrics.json missing: {missing}")
        if doc["repro_query_latency_ms"]["type"] != "histogram":
            raise RuntimeError("repro_query_latency_ms is not a histogram")
        print(f"/metrics.json OK: {len(doc)} metric names")

        with open(event_log) as f:
            events = [json.loads(line) for line in f if line.strip()]
        traced = [e for e in events
                  if e["event"] == "query" and e.get("spans")]
        if not traced:
            raise RuntimeError(f"no traced query events in {event_log}; "
                               f"saw {[e['event'] for e in events][:20]}")
        print(f"event log OK: {len(events)} events, {len(traced)} traced; "
              f"sample spans={[s['stage'] for s in traced[0]['spans']]}")

        ready = json.loads(_fetch(base + "/readyz"))
        if ready.get("ready") is not True:
            raise RuntimeError(f"/readyz not ready after build: {ready}")
        engine = ready.get("checks", {}).get("engine")
        if not (isinstance(engine, dict) and engine.get("ok") is True):
            raise RuntimeError(f"/readyz missing engine check: {ready}")
        print(f"/readyz OK: checks={sorted(ready['checks'])}")

        dbg = json.loads(_fetch(base + "/debug/requests?limit=10"))
        for key in ("requests", "count", "recorder"):
            if key not in dbg:
                raise RuntimeError(f"/debug/requests missing {key!r}: "
                                   f"{sorted(dbg)}")
        stats = dbg["recorder"]
        if stats.get("seen", 0) < 1 or "capacity" not in stats:
            raise RuntimeError(f"/debug/requests recorder stats wrong: "
                               f"{stats}")
        for rec in dbg["requests"]:
            for key in ("trace_id", "outcome", "stages", "retained"):
                if key not in rec:
                    raise RuntimeError(
                        f"/debug/requests record missing {key!r}: {rec}")
        print(f"/debug/requests OK: {dbg['count']} retained of "
              f"{stats['seen']} seen")

        slo = json.loads(_fetch(base + "/debug/slo"))
        for key in ("objectives", "windows", "slos"):
            if key not in slo:
                raise RuntimeError(f"/debug/slo missing {key!r}: "
                                   f"{sorted(slo)}")
        for name in ("latency", "availability"):
            wins = slo["slos"][name]["windows"]
            for w in ("fast", "slow"):
                for key in ("burn_rate", "compliance", "good", "total"):
                    if key not in wins[w]:
                        raise RuntimeError(
                            f"/debug/slo {name}/{w} missing {key!r}: "
                            f"{wins[w]}")
        print(f"/debug/slo OK: objectives={slo['objectives']}")
        print("check_metrics_endpoint: PASS")
    finally:
        proc.terminate()
        try:
            proc.wait(timeout=20)
        except subprocess.TimeoutExpired:
            proc.kill()


if __name__ == "__main__":
    main()
