"""Sharded streaming benchmarks: insert throughput and query latency vs
shard count on a host-local mesh (the ISSUE 2 tentpole's perf entry point).

Runs in a subprocess so the forced host-device count never leaks into the
parent's jax runtime (same pattern as tests/test_distributed.py).  Rows come
back over stdout as ``ROW,name,value,derived`` lines.
"""

from __future__ import annotations

import os
import subprocess
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SUBPROC = r'''
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={max_shards}"
sys.path.insert(0, "src")
import time
import jax
import numpy as np
from repro.core.engine import EngineSpec
from repro.data import synth
from repro.distributed import mesh as meshlib
from repro.serving.serve import QueryServer
from repro.serving.sharded import ShardedSinnamonIndex

docs, queries, batch = {docs}, {queries}, {batch}
ds = synth.SparseDatasetSpec("stream", n=2000, psi_doc=40, psi_query=16)
idx, val = synth.make_corpus(0, ds, docs, pad=64)
qi, qv = synth.make_queries(1, ds, queries, pad=32)
for shards in {shard_counts}:
    mesh = meshlib.make_mesh((1, shards), ("data", "model"))
    cap_local = (((docs + shards - 1) // shards + 31) // 32) * 32
    spec = EngineSpec(n=ds.n, m=20, capacity=cap_local, max_nnz=64, h=1)
    index = ShardedSinnamonIndex(spec, mesh)
    bs = 256
    t0 = time.perf_counter()
    for lo in range(0, docs, bs):
        hi = min(lo + bs, docs)
        index.insert_many(list(range(lo, hi)), idx[lo:hi], val[lo:hi])
    jax.block_until_ready(index.state.u)
    tput = docs / (time.perf_counter() - t0)
    server = QueryServer(index, k=10, kprime=50)
    server.query_many(qi[:batch], qv[:batch])        # compile warmup
    server.reset_stats()
    for lo in range(0, queries, batch):
        server.query_many(qi[lo:lo + batch], qv[lo:lo + batch])
    lat = server.latency_percentiles()
    print(f"ROW,streaming/shards{{shards}}/insert_tput,{{tput:.1f}},docs/s")
    print(f"ROW,streaming/shards{{shards}}/query_p50_ms,{{lat['p50']:.2f}},")
    print(f"ROW,streaming/shards{{shards}}/query_p99_ms,{{lat['p99']:.2f}},")
'''


def _run(max_shards, shard_counts, docs, queries, batch, timeout):
    code = SUBPROC.format(max_shards=max_shards, shard_counts=shard_counts,
                          docs=docs, queries=queries, batch=batch)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, cwd=_ROOT)
    if out.returncode != 0:
        raise RuntimeError(f"streaming subprocess failed:\n{out.stderr[-2000:]}")
    rows = []
    for line in out.stdout.splitlines():
        if line.startswith("ROW,"):
            _, name, value, derived = line.split(",", 3)
            rows.append((name, value, derived))
    if not rows:
        raise RuntimeError(f"no rows from streaming subprocess:\n{out.stdout}")
    return rows


def streaming_smoke():
    """CI-sized: 2 shards, small corpus — exercises the full sharded
    insert → batched-serve path in under a couple of minutes on CPU."""
    return _run(max_shards=2, shard_counts=[2], docs=512, queries=16,
                batch=8, timeout=600)


def streaming_sharded():
    """Insert throughput and query p50/p99 vs shard count (1, 2, 4)."""
    return _run(max_shards=4, shard_counts=[1, 2, 4], docs=4096, queries=32,
                batch=16, timeout=1800)


ALL = [streaming_smoke, streaming_sharded]
