"""Query & update hot-path benchmarks (the ISSUE 4 perf tentpole).

Two trajectories, both emitted as ``run.py`` rows (and BENCH_query_path.json
via ``--json`` / ``python benchmarks/query_path.py --json PATH``):

* ``query_path_backends`` — serving latency of each scoring backend
  (``reference | grouped | pallas``) through the batched ``QueryServer``
  path, swept over batch size and anytime budget, plus live device bytes
  (``jax.live_arrays``) sampled after each backend's run.  Derived
  ``speedup/...`` rows divide reference p50 by fused p50 — the acceptance
  gate is >= 2x at batch >= 8.
* ``query_path_inserts`` — write-side throughput of the vectorized
  single-dispatch ``insert_batch`` vs the sequential ``lax.scan`` oracle at
  batch 256 (same documents, same slots); gate is >= 5x.

Engine config: m=64, h=2 sketch (two random mappings — the multi-mapping
configuration the paper's §5 analysis favors for accuracy; it is also where
one-sided decode matters most, since reference decode cost scales with
2·h sides), n=4096, psi_doc=48, psi_query=24 gaussian-valued vectors.

CPU timing note: the ``pallas`` backend times the fused tile program's XLA
twin (identical math to the kernel, asserted bit-identical in tests);
interpret-mode pallas_call timing would measure the Pallas *interpreter*,
not the fused schedule.
"""

from __future__ import annotations

import time

import numpy as np

_DOCS = 8192
_M, _H = 64, 2
_K, _KPRIME = 10, 100
_QUERIES = 32


def _build(docs=_DOCS, capacity=None):
    from repro.core.engine import EngineSpec, SinnamonIndex
    from repro.data import synth

    ds = synth.SparseDatasetSpec("query_path", n=4096, psi_doc=48,
                                 psi_query=24, value_dist="gaussian")
    idx, val = synth.make_corpus(0, ds, docs, pad=64)
    qi, qv = synth.make_queries(1, ds, _QUERIES, pad=32)
    spec = EngineSpec(n=ds.n, m=_M, capacity=capacity or docs, max_nnz=64,
                      h=_H)
    index = SinnamonIndex(spec)
    for lo in range(0, docs, 1024):
        index.insert_many(list(range(lo, min(lo + 1024, docs))),
                          idx[lo:lo + 1024], val[lo:lo + 1024])
    return index, idx, val, qi, qv


def _live_mb():
    import jax
    return sum(a.nbytes for a in jax.live_arrays()) / 1e6


def _bench_backends(docs, batches, budgets, reps):
    from repro.serving.serve import QueryServer

    index, _, _, qi, qv = _build(docs)
    rows = []
    p50 = {}
    for backend in ("reference", "grouped", "pallas"):
        server = QueryServer(index, k=_K, kprime=_KPRIME,
                             score_backend=backend)
        for budget in budgets:
            server.budget = budget
            tag = f"query_path/{backend}" + (
                "" if budget is None else f"/budget{budget}")
            for bs in batches:
                server.query_many(qi[:bs], qv[:bs])       # compile warmup
                server.reset_stats()
                for _ in range(reps):
                    for lo in range(0, _QUERIES, bs):
                        server.query_many(qi[lo:lo + bs], qv[lo:lo + bs])
                lat = server.latency_percentiles()
                p50[(backend, budget, bs)] = lat["p50"]
                rows.append((f"{tag}/b{bs}/p50_ms", f"{lat['p50']:.3f}", ""))
                rows.append((f"{tag}/b{bs}/p99_ms", f"{lat['p99']:.3f}", ""))
        rows.append((f"query_path/{backend}/live_mb", f"{_live_mb():.1f}",
                     "jax.live_arrays after serving"))
    for budget in budgets:
        btag = "" if budget is None else f"/budget{budget}"
        for bs in batches:
            if bs < 8:
                continue
            ratio = (p50[("reference", budget, bs)]
                     / max(p50[("pallas", budget, bs)], 1e-9))
            derived = "x (p50, gate >= 2)" if budget is None else "x (p50)"
            rows.append((f"query_path/speedup{btag}/b{bs}"
                         "_pallas_vs_reference",
                         f"{ratio:.2f}", derived))
    return rows


def _bench_inserts(batch, reps):
    import jax
    import jax.numpy as jnp

    from repro.core import engine as eng

    # Half-full index built through the functional API: docs 0..1023 occupy
    # slots 0..1023, so the benchmarked batch lands on genuinely free slots
    # (1024..1024+batch) exactly as the host allocator would hand them out.
    from repro.data import synth

    ds = synth.SparseDatasetSpec("query_path", n=4096, psi_doc=48,
                                 psi_query=24, value_dist="gaussian")
    idx, val = synth.make_corpus(0, ds, 1024 + batch, pad=64)
    spec = eng.EngineSpec(n=ds.n, m=_M, capacity=2048, max_nnz=64, h=_H)
    state = eng.insert_batch(
        eng.init(spec), spec, jnp.arange(1024, dtype=jnp.int32),
        jnp.asarray(eng.pack_ids64(np.arange(1024, dtype=np.int64))),
        jnp.asarray(idx[:1024]), jnp.asarray(val[:1024]))
    slots = jnp.arange(1024, 1024 + batch, dtype=jnp.int32)
    rng = np.random.default_rng(3)
    eids = jnp.asarray(eng.pack_ids64(
        rng.integers(2**33, 2**40, batch).astype(np.int64)))
    docs_i = jnp.asarray(np.asarray(idx[1024:1024 + batch]))
    docs_v = jnp.asarray(np.asarray(val[1024:1024 + batch]))

    vec = jax.jit(eng.insert_batch, static_argnums=(1,))
    scan = jax.jit(eng.insert_batch_scan, static_argnums=(1,))
    out = {}
    for name, fn in (("vectorized", vec), ("scan", scan)):
        jax.block_until_ready(fn(state, spec, slots, eids, docs_i, docs_v))
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn(state, spec, slots, eids, docs_i,
                                     docs_v))
        dt = (time.perf_counter() - t0) / reps
        out[name] = batch / dt
    rows = [(f"query_path/insert/b{batch}/{name}_tput", f"{tput:.0f}",
             "docs/s") for name, tput in out.items()]
    derived = "x (gate >= 5)" if batch >= 256 else "x"
    rows.append((f"query_path/insert/b{batch}/speedup_vectorized_vs_scan",
                 f"{out['vectorized'] / out['scan']:.2f}",
                 derived))
    return rows


def query_path_backends():
    """Backend x batch x budget latency sweep + live-bytes accounting."""
    return _bench_backends(docs=_DOCS, batches=(1, 8, 32),
                           budgets=(None, 8), reps=3)


def query_path_inserts():
    """Vectorized single-dispatch batch insert vs the lax.scan oracle."""
    return _bench_inserts(batch=256, reps=5)


def query_path_smoke():
    """CI-sized subset: one budget, one batch size, small insert batch.

    Rows are renamed under ``query_path_smoke/`` so a combined
    ``run.py query_path --json`` run never overwrites the full-sweep rows
    (run.py keys its JSON by row name).
    """
    rows = _bench_backends(docs=2048, batches=(8,), budgets=(None,), reps=2)
    rows += _bench_inserts(batch=64, reps=2)
    return [(name.replace("query_path/", "query_path_smoke/", 1), v, d)
            for name, v, d in rows]


ALL = [query_path_backends, query_path_inserts, query_path_smoke]


if __name__ == "__main__":
    # Standalone entry: `python benchmarks/query_path.py [--json PATH]`
    # (same rows/JSON schema as benchmarks/run.py).
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks import run as _run

    sys.argv = [sys.argv[0], "query_path"] + sys.argv[1:]
    _run.main()
