"""Roofline report (deliverable g): renders results/dryrun_all.json into the
EXPERIMENTS.md §Roofline table.

Terms per (arch × shape × mesh), all per-chip:
    compute    = HLO_FLOPs / peak_FLOP/s        (197 TF/s bf16, v5e-class)
    memory     = HLO_bytes / HBM_bw             (819 GB/s)
    collective = collective_bytes / link_bw     (~50 GB/s/link ICI)

Caveats recorded in EXPERIMENTS.md §Roofline:
  * XLA-CPU cost_analysis counts a while-loop body ONCE, so scanned-layer
    models under-report compute/bytes by ~n_layers.  The compute term is
    therefore max(HLO, analytic·(1+remat)) with analytic = 6·N·D (train) or
    2·N·D (serve), and the bytes term for decode cells is cross-checked
    against the analytic working set (params + KV cache).
  * roofline% = useful / binding-resource time:
      - compute-bound kinds: t_model / max(term)      (MFU-like)
      - lm_decode kinds:     analytic_bytes / HLO_bytes (MBU-like)
"""

from __future__ import annotations

import json
import sys

PEAK = 197e12
HBM = 819e9
ICI = 50e9


def _decode_bytes(arch: str, shape_name: str) -> float:
    """Analytic minimum HBM traffic of one decode step: params + KV cache."""
    from repro.configs import registry
    mod = registry.get(arch)
    if mod.FAMILY != "lm":
        return 0.0
    cfg = mod.full_config()
    shape = mod.SHAPES[shape_name]
    B, S = shape["batch"], shape["seq"]
    cache = 2 * cfg.n_layers * B * cfg.n_kv_heads * S * cfg.head_dim * 2
    return cfg.param_count() * 2 + cache


def corrected_compute(r) -> float:
    meta = r.get("meta", {})
    mf = meta.get("model_flops") or 0
    kind = meta.get("arch_kind", "")
    mult = 8.0 / 6.0 if "train" in kind else 1.0   # remat recompute
    analytic = mf * mult / r["n_chips"]
    return max(r["hlo_flops_per_device"], analytic)


def render(results, mesh="16x16"):
    lines = []
    hdr = (f"| {'arch':22s} | {'shape':14s} | {'GiB/dev':>7s} | "
           f"{'t_comp(s)':>9s} | {'t_mem(s)':>9s} | {'t_coll(s)':>9s} | "
           f"{'bound':>10s} | {'roofline%':>9s} |")
    lines.append(hdr)
    lines.append("|" + "|".join("-" * (len(c))
                                for c in hdr.split("|")[1:-1]) + "|")
    for r in results:
        if r.get("mesh") != mesh or not r.get("ok"):
            continue
        kind = r.get("meta", {}).get("arch_kind", "")
        tc = corrected_compute(r) / PEAK
        tm, tl = r["t_memory"], r["t_collective"]
        binding = max(tc, tm, tl)
        dom = {tc: "compute", tm: "memory", tl: "collective"}[binding]
        mf = r.get("meta", {}).get("model_flops")
        if kind == "lm_decode":
            ab = _decode_bytes(r["arch"], r["shape"]) / r["n_chips"]
            frac = ab / max(r["hlo_bytes_per_device"], 1)
            # memory term may also undercount scans; use analytic if larger
            tm = max(tm, ab / HBM)
            binding = max(tc, tm, tl)
            dom = {tc: "compute", tm: "memory", tl: "collective"}[binding]
        elif mf:
            t_model = mf / (r["n_chips"] * PEAK)
            frac = t_model / binding
        else:
            frac = float("nan")
        lines.append(
            f"| {r['arch']:22s} | {r['shape']:14s} | "
            f"{r['bytes_per_device']/2**30:7.2f} | {tc:9.3e} | {tm:9.3e} | "
            f"{tl:9.3e} | {dom:>10s} | {100*min(frac,1):8.1f}% |")
    return "\n".join(lines)


def main(path="results/dryrun_all.json"):
    results = json.load(open(path))
    for mesh in ("16x16", "2x16x16"):
        print(f"\n### Roofline — mesh {mesh} "
              f"({256 if mesh=='16x16' else 512} chips)\n")
        print(render(results, mesh))


if __name__ == "__main__":
    main(*sys.argv[1:])
