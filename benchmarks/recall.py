"""Recall/memory/latency frontier benchmark (the ISSUE 5 accuracy tentpole).

Sweeps the paper's accuracy levers — sketch half-size m, sketch_kind
full|lite (§3.3), quantized cell dtype bf16|f8, rerank k' and the anytime
query cutoff — over two synthetic corpora and emits one (memory, p99,
recall@10) frontier point per configuration as ``run.py`` rows (and
``BENCH_recall.json`` via ``--json``):

* ``gauss`` — signed Gaussian values, uniform activation (the paper's G-style
  collections).  Here the lite sketch genuinely *loses* recall (negative
  query coordinates give up their lower bound), so the frontier shows the
  real trade-off; the §5 theory check uses the Gaussian closed forms.
* ``text`` — non-negative lognormal values with Zipf activation (the
  SPLADE/BM25-shaped collections the paper targets).  Queries carry no
  negative coordinates, so lite matches full's recall while halving sketch
  bytes — the §3.3 claim, gated below.

Two hard gates (a violation raises, which run.py turns into an ERROR row and
a non-zero exit, failing CI):

* ``lite`` halves sketch bytes and stays within 5 recall points of ``full``
  on the text corpus;
* every swept point's measured per-coordinate overestimate respects the
  Eq. (13) tail bound from ``repro.core.theory`` (via repro.eval.bounds,
  with the quantization margin for narrow cell dtypes).

``recall_churn`` additionally reports the §4.3 drift trajectory
(clean → churned → compacted) that ``eval.bounds.churn_overestimate``
measures.
"""

from __future__ import annotations

_DOCS, _QUERIES, _K = 4096, 32, 10


def _dataset(name):
    from repro.data import synth

    if name == "gauss":
        return synth.SparseDatasetSpec("recall_gauss", n=4096, psi_doc=48,
                                       psi_query=24, value_dist="gaussian",
                                       value_param=1.0)
    return synth.SparseDatasetSpec("recall_text", n=8192, psi_doc=64,
                                   psi_query=24, value_dist="lognormal",
                                   value_param=0.6, nonneg=True,
                                   activation="zipf")


def _corpus(name, docs=_DOCS, queries=_QUERIES):
    from repro.data import synth

    ds = _dataset(name)
    idx, val = synth.make_corpus(0, ds, docs, pad=96)
    qi, qv = synth.make_queries(1, ds, queries, pad=32)
    return ds, idx, val, qi, qv


def _value_dist(name):
    from repro.core import theory

    if name == "gauss":
        return theory.gaussian_dist(0.0, 1.0)
    return theory.lognormal_dist(sigma=0.6)


def _tag(corpus, pt):
    tag = (f"recall/{corpus}/m{pt['m']}/{pt['sketch_kind']}"
           f"/{pt['cell_dtype']}/kp{pt['kprime']}")
    if pt["budget"] is not None:
        tag += f"/budget{pt['budget']}"
    return tag


def _point_rows(corpus, pt):
    tag = _tag(corpus, pt)
    rows = [
        (f"{tag}/recall_at_{pt['k']}", f"{pt['recall_at_k']:.3f}",
         "vs exact oracle"),
        (f"{tag}/mrr", f"{pt['mrr']:.3f}", ""),
        (f"{tag}/p99_ms", f"{pt['p99_ms']:.3f}", "batched QueryServer path"),
        (f"{tag}/sketch_kb", f"{pt['sketch_bytes'] / 1024:.1f}", ""),
        (f"{tag}/index_kb", f"{pt['index_bytes'] / 1024:.1f}",
         "sketch + inverted index"),
    ]
    b = pt.get("bounds")
    if b is not None:
        worst = max((c["empirical"] - c["bound"] for c in b["checks"]))
        rows.append((f"{tag}/bound_ok", str(b["ok"]).lower(),
                     f"worst tail excess {worst:+.3f} (gate <= slack)"))
    return rows


def _sweep(corpus, points, docs=_DOCS, queries=_QUERIES, reps=2):
    from repro.eval import recall as harness

    ds, idx, val, qi, qv = _corpus(corpus, docs, queries)
    pts = harness.frontier(
        idx, val, qi, qv, ds.n, points, k=_K, reps=reps,
        bounds_params=dict(value_dist=_value_dist(corpus)))
    for pt in pts:
        pt["corpus"] = corpus
    return pts


def _gate_bounds(pts):
    bad = [pt for pt in pts if not pt["bounds"]["ok"]]
    if bad:
        worst = bad[0]
        raise ValueError(
            f"measured overestimate exceeds the theory bound at "
            f"{_tag(worst['corpus'], worst)}: {worst['bounds']['checks']}")


def _gate_lite(pts, corpus, max_gap=0.05):
    def find(kind):
        for pt in pts:
            if (pt["corpus"] == corpus and pt["sketch_kind"] == kind
                    and pt["cell_dtype"] == "bf16" and pt["budget"] is None):
                return pt
        raise ValueError(f"no {kind} baseline point on {corpus}")

    full, lite = find("full"), find("lite")
    if lite["sketch_bytes"] * 2 != full["sketch_bytes"]:
        raise ValueError(f"lite sketch bytes {lite['sketch_bytes']} are not "
                         f"half of full's {full['sketch_bytes']}")
    gap = full["recall_at_k"] - lite["recall_at_k"]
    if gap > max_gap:
        raise ValueError(f"lite recall gap {gap:.3f} on {corpus} exceeds "
                         f"{max_gap} (full {full['recall_at_k']:.3f}, "
                         f"lite {lite['recall_at_k']:.3f})")
    return [
        (f"recall/gate/{corpus}/lite_vs_full_gap", f"{gap:.3f}",
         f"recall@{_K} points, gate <= {max_gap}"),
        (f"recall/gate/{corpus}/lite_sketch_ratio",
         f"{lite['sketch_bytes'] / full['sketch_bytes']:.2f}",
         "gate == 0.50"),
    ]


def recall_frontier():
    """Full lever sweep over both corpora + the two acceptance gates."""
    gauss_points = [
        dict(m=32, sketch_kind="full"), dict(m=32, sketch_kind="lite"),
        dict(m=64, sketch_kind="full"), dict(m=64, sketch_kind="lite"),
        dict(m=64, sketch_kind="full", cell_dtype="f8"),
        dict(m=64, sketch_kind="full", budget=8),
        dict(m=64, sketch_kind="full", kprime=40),
    ]
    text_points = [
        dict(m=64, sketch_kind="full"), dict(m=64, sketch_kind="lite"),
        dict(m=64, sketch_kind="full", cell_dtype="f8"),
    ]
    pts = _sweep("gauss", gauss_points) + _sweep("text", text_points)
    rows = []
    for pt in pts:
        rows += _point_rows(pt["corpus"], pt)
    _gate_bounds(pts)
    rows += _gate_lite(pts, "text")
    return rows


def recall_churn():
    """§4.3 churn drift trajectory: clean -> churned -> compacted."""
    from repro.eval import bounds as blib
    from repro.eval import recall as harness

    ds, idx, val, _, _ = _corpus("gauss", docs=1024, queries=1)
    spec = harness.lever_spec(ds.n, 1024, idx.shape[1], m=64)
    out = blib.churn_overestimate(spec, idx, val, rounds=2, frac=0.25)
    rows = []
    for stage in ("clean", "churned", "compacted"):
        rows.append((f"recall/churn/{stage}/err_mean",
                     f"{out[stage]['err_mean']:.4f}",
                     "per-coordinate overestimate"))
        rows.append((f"recall/churn/{stage}/drift_max",
                     f"{out[stage]['drift_max']:.4f}",
                     "engine slot_drift"))
    rows.append(("recall/churn/columns_rebuilt",
                 str(out["columns_rebuilt"]), ""))
    if out["compacted"]["drift_max"] != 0.0:
        raise ValueError("compaction left residual sketch drift: "
                         f"{out['compacted']['drift_max']}")
    return rows


def recall_smoke():
    """CI-sized subset: one corpus, the lite/full pair, 1k docs.

    Rows are renamed under ``recall_smoke/`` so a combined
    ``run.py recall --json`` run never overwrites the full-sweep rows.
    """
    pts = _sweep("text", [dict(m=48, sketch_kind="full"),
                          dict(m=48, sketch_kind="lite")],
                 docs=1024, queries=16, reps=1)
    rows = []
    for pt in pts:
        rows += _point_rows(pt["corpus"], pt)
    _gate_bounds(pts)
    rows += _gate_lite(pts, "text")
    return [(name.replace("recall/", "recall_smoke/", 1), v, d)
            for name, v, d in rows]


ALL = [recall_frontier, recall_churn, recall_smoke]


if __name__ == "__main__":
    # Standalone entry: `python benchmarks/recall.py [--json PATH]`
    # (same rows/JSON schema as benchmarks/run.py).
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks import run as _run

    sys.argv = [sys.argv[0], "recall"] + sys.argv[1:]
    _run.main()
