"""Chaos benchmarks (the ISSUE 9 acceptance gates).

Two entries, both emitted as ``run.py`` rows (``--json`` writes
BENCH_chaos.json; CI's chaos-smoke job archives it):

* ``chaos_smoke`` — 100 seeded crash/recover schedules against the durable
  index with probabilistic failpoints armed (torn WAL writes, fsync ENOSPC,
  snapshot write/rename faults).  An op that raised was never acked; after
  each schedule "crashes", recovery must reproduce the acked-only live
  index **byte-for-byte**.  Gate: zero acked-write loss, byte-identical
  recovery, across every schedule.

* ``chaos_availability`` — a 250 ms stall injected at ``device.rerank``
  (a stuck accelerator) under closed-loop load with a 300 ms deadline.
  With the degradation ladder OFF almost nothing meets the deadline; with
  the ladder ON, queue pressure escalates to L2 (sketch-only answers,
  stamped ``degraded``) which sidesteps the rerank entirely.  Gate:
  ladder-on availability >= 5x ladder-off.

Everything is seeded — the same machine replays the same fault schedules.
"""

from __future__ import annotations

import os
import random
import shutil
import tempfile
import threading
import time

import numpy as np

_SCHEDULES = 100
_OPS_PER_SCHEDULE = 12
# Distinct sites so every hazard is armed at once; the seeds make each
# schedule's fault sequence deterministic.
_CHAOS_SPEC = ("wal.write=torn:0.35:0.25,wal.fsync=enospc:0.1,"
               "snapshot.write=error:0.5,snapshot.rename=error:0.5")

_STALL_MS = 250.0
_DEADLINE_MS = 300.0
_AVAIL_CLIENTS = 16
_AVAIL_DURATION_S = 4.0
_AVAIL_MAX_BATCH = 4
_AVAIL_GATE = 5.0


def _spec():
    from repro.core.engine import EngineSpec
    return EngineSpec(n=300, m=12, capacity=96, max_nnz=32, h=2, seed=3,
                      value_dtype="float32")


def _corpus(seed=0):
    from repro.data import synth
    ds = synth.SparseDatasetSpec("chaos", n=300, psi_doc=16, psi_query=8,
                                 value_dist="gaussian")
    return synth.make_corpus(seed, ds, 200, pad=32)


def _states_equal(a, b) -> bool:
    import jax
    ok = True

    def cmp(x, y):
        nonlocal ok
        ok = ok and np.array_equal(np.asarray(x), np.asarray(y))

    jax.tree.map(cmp, a, b)
    return ok


def chaos_smoke():
    """Seeded crash/recover schedules: zero acked-write loss."""
    from repro.fault import failpoints as fp
    from repro.obs import MetricsRegistry
    from repro.persist.durable import DurableSinnamonIndex

    idx, val = _corpus()
    total_faults = 0
    total_verified = 0
    for seed in range(_SCHEDULES):
        rng = random.Random(seed)
        d = tempfile.mkdtemp(prefix="bench_chaos_")
        try:
            wd, sd = os.path.join(d, "wal"), os.path.join(d, "snap")
            live = DurableSinnamonIndex.open(_spec(), wal_dir=wd,
                                             snapshot_dir=sd)
            acked = set()
            next_id = 0
            reg = fp.FailpointRegistry(
                seed=seed, registry=MetricsRegistry()).configure(_CHAOS_SPEC)
            prev = fp.set_failpoints(reg)
            try:
                for _ in range(_OPS_PER_SCHEDULE):
                    roll = rng.random()
                    try:
                        if roll < 0.55 or not acked:
                            k = rng.randint(1, 4)
                            ids = list(range(next_id, next_id + k))
                            rows = [i % 200 for i in ids]
                            live.insert_many(ids, idx[rows], val[rows])
                            acked.update(ids)
                            next_id += k
                        elif roll < 0.80:
                            e = rng.choice(sorted(acked))
                            live.delete(e)
                            acked.discard(e)
                        elif roll < 0.92:
                            live.snapshot()
                        else:
                            live.compact()
                    except OSError as e:
                        if not isinstance(e, fp.InjectedFault):
                            raise       # a REAL fault — fail the benchmark
                        total_faults += 1   # op raised -> never acked
            finally:
                fp.set_failpoints(prev)
            # "crash" (abandon live without closing), then recover.
            rec = DurableSinnamonIndex.open(_spec(), wal_dir=wd,
                                            snapshot_dir=sd)
            if set(rec._id2slot) != acked:
                lost = acked - set(rec._id2slot)
                raise RuntimeError(
                    f"chaos seed {seed}: ACKED-WRITE LOSS — ids {sorted(lost)[:5]} "
                    f"were acknowledged but did not survive recovery")
            if (rec._id2slot != live._id2slot or rec._free != live._free
                    or not _states_equal(rec.state, live.state)):
                raise RuntimeError(
                    f"chaos seed {seed}: recovery is not byte-identical "
                    f"to the live (acked-only) index")
            total_verified += len(acked)
        finally:
            shutil.rmtree(d, ignore_errors=True)
    if total_faults == 0:
        raise RuntimeError(
            "chaos schedules injected zero faults — failpoint wiring broken")
    return [
        ("chaos/schedules", str(_SCHEDULES),
         f"{_OPS_PER_SCHEDULE} seeded ops each; "
         f"spec {_CHAOS_SPEC.replace(',', ' + ')}"),
        ("chaos/faults_injected", str(total_faults),
         "ops failed by armed failpoints (never acked)"),
        ("chaos/acked_docs_verified", str(total_verified),
         "recovered byte-identically across all schedules"),
        ("chaos/smoke_gate", "PASS",
         "zero acked-write loss + byte-identical recovery"),
    ]


def _closed_loop(fe, queries, duration_s):
    """Drive ``fe`` with closed-loop clients; count request outcomes.

    ``ok`` = answered within the deadline; everything else (late answers,
    in-queue expiry, shed/throttled rejections) is unavailability.
    """
    from repro.serving.frontend import (DeadlineExceeded, DeviceStuck,
                                        Rejected)

    counts = {"ok": 0, "late": 0, "expired": 0, "rejected": 0,
              "degraded": 0}
    lock = threading.Lock()
    stop_at = time.monotonic() + duration_s

    def client(c):
        i = c
        while time.monotonic() < stop_at:
            qi, qv = queries[i % len(queries)]
            i += 1
            t0 = time.monotonic()
            try:
                res = fe.query(qi, qv, deadline_ms=_DEADLINE_MS)
                lat_ms = (time.monotonic() - t0) * 1e3
                key = "ok" if lat_ms <= _DEADLINE_MS else "late"
                degraded = bool(getattr(res, "degraded", False))
            except Rejected:
                key, degraded = "rejected", False
                time.sleep(0.01)        # back off as a real client would
            except (DeadlineExceeded, DeviceStuck):
                key, degraded = "expired", False
            with lock:
                counts[key] += 1
                if key == "ok" and degraded:
                    counts["degraded"] += 1

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(_AVAIL_CLIENTS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return counts


def _availability(counts) -> float:
    total = sum(v for k, v in counts.items() if k != "degraded")
    return counts["ok"] / max(total, 1)


def chaos_availability():
    """250 ms injected rerank stall: ladder-on vs ladder-off availability."""
    from benchmarks.query_path import _build
    from repro.fault import failpoints as fp
    from repro.fault.degrade import DegradeConfig
    from repro.obs import NULL_REGISTRY
    from repro.serving.frontend import ServingFrontend
    from repro.serving.serve import QueryServer

    index, _, _, qi, qv = _build(1024)
    server = QueryServer(index, k=10, kprime=100)
    queries = [(qi[b], qv[b]) for b in range(qi.shape[0])]

    # Warm every program the run will need — the fixed (max_batch, bucket)
    # dispatch rectangle at each degrade level — so compile time never
    # masquerades as unavailability.
    bucket = -(-qi.shape[1] // 32) * 32
    wi = np.full((_AVAIL_MAX_BATCH, bucket), -1, np.int32)
    wv = np.zeros((_AVAIL_MAX_BATCH, bucket), np.float32)
    wi[0, :qi.shape[1]], wv[0, :qi.shape[1]] = qi[0], qv[0]
    for level in (0, 1, 2):
        server.query_many(wi, wv, degrade=level)

    def run(degrade_cfg):
        fe = ServingFrontend(
            server, max_batch=_AVAIL_MAX_BATCH, batch_window_ms=1.0,
            queue_depth=32, default_deadline_ms=_DEADLINE_MS,
            degrade=degrade_cfg, degrade_tick_s=0.05,
            registry=NULL_REGISTRY)
        reg = fp.FailpointRegistry(seed=0)
        reg.configure(f"device.rerank=stall:{_STALL_MS:g}ms")
        prev = fp.set_failpoints(reg)
        try:
            return _closed_loop(fe, queries, _AVAIL_DURATION_S)
        finally:
            fp.set_failpoints(prev)
            fe.close()

    off = run(None)
    # Queue pressure alone drives the ladder (no SLO monitor needed):
    # enter at 12% queue occupancy, huge dwell so a 4 s run never
    # de-escalates back into the stall, cap at L2 (no shedding — every
    # tenant is equal here, availability should come from degraded
    # answers, not 429s).
    on = run(DegradeConfig(enabled=True, enter_queue_frac=0.12,
                           exit_queue_frac=0.01, dwell_ticks=100_000,
                           max_level=2))

    a_off, a_on = _availability(off), _availability(on)
    ratio = a_on / max(a_off, 1e-3)     # floor: off can legitimately be ~0
    rows = [
        ("chaos/avail_ladder_off", f"{a_off:.3f}",
         f"{off['ok']} ok / {off['late']} late / {off['expired']} expired "
         f"/ {off['rejected']} rejected under {_STALL_MS:g}ms rerank stall"),
        ("chaos/avail_ladder_on", f"{a_on:.3f}",
         f"{on['ok']} ok ({on['degraded']} degraded) / {on['late']} late "
         f"/ {on['expired']} expired / {on['rejected']} rejected"),
        ("chaos/avail_ratio", f"{ratio:.1f}",
         f"ladder-on / ladder-off (gate >= {_AVAIL_GATE:g}x)"),
    ]
    if a_on <= 0.5:
        raise RuntimeError(
            f"ladder-on availability {a_on:.3f} <= 0.5 — degradation is "
            f"not actually serving under the stall")
    if ratio < _AVAIL_GATE:
        raise RuntimeError(
            f"availability ratio {ratio:.1f} < {_AVAIL_GATE:g} gate "
            f"(off {a_off:.3f}, on {a_on:.3f})")
    rows.append(("chaos/availability_gate", "PASS",
                 f"ladder-on >= {_AVAIL_GATE:g}x ladder-off under stall"))
    return rows


ALL = [chaos_smoke, chaos_availability]


if __name__ == "__main__":
    # Standalone entry: `python benchmarks/chaos.py [--json PATH]`.
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks import run as _run

    sys.argv = [sys.argv[0], "chaos"] + sys.argv[1:]
    _run.main()
