# One function per paper table. Prints ``name,value,derived`` CSV; with
# ``--json PATH`` also writes a machine-readable {name: {value, derived}}
# map so CI can archive the perf trajectory as BENCH_<n>.json artifacts.
# Exits non-zero if any table function errors, so CI smoke jobs fail loudly.
import argparse
import datetime
import json
import os
import subprocess
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("only", nargs="?", default=None,
                    help="run only benchmark functions matching this substring")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as JSON (BENCH_<n>.json)")
    return ap.parse_args(argv)


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "-C", _ROOT, "rev-parse", "HEAD"], capture_output=True,
            text=True, timeout=10, check=True).stdout.strip()
    except Exception:                               # noqa: BLE001
        return "unknown"


def main() -> None:
    from benchmarks import (chaos, obs_overhead, paper, persist, query_path,
                            recall, serving, streaming, tiering)

    args = parse_args()
    fns = [fn for fn in paper.ALL + streaming.ALL + persist.ALL
           + query_path.ALL + recall.ALL + obs_overhead.ALL + serving.ALL
           + chaos.ALL + tiering.ALL
           if not args.only or args.only in fn.__name__]
    if not fns:
        print(f"no benchmark matches {args.only!r}", file=sys.stderr)
        sys.exit(2)
    failed = False
    results = {}
    print("name,value,derived")
    for fn in fns:
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:                      # noqa: BLE001
            print(f"{fn.__name__},ERROR,{type(e).__name__}: {e}")
            results[fn.__name__] = {"value": "ERROR",
                                    "derived": f"{type(e).__name__}: {e}"}
            failed = True
            continue
        for name, value, derived in rows:
            print(f"{name},{value},{derived}")
            results[name] = {"value": value, "derived": derived}
        print(f"# {fn.__name__} took {time.time() - t0:.1f}s",
              file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench_schema_version": 2, "git_sha": _git_sha(),
                       "generated_utc": datetime.datetime.now(
                           datetime.timezone.utc).isoformat(
                               timespec="seconds"),
                       "rows": results, "failed": failed}, f, indent=1)
        print(f"# wrote {args.json}", file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == '__main__':
    main()
