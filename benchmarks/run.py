# One function per paper table. Prints ``name,value,derived`` CSV.
import sys
import time


def main() -> None:
    from benchmarks import paper

    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,value,derived")
    for fn in paper.ALL:
        if only and only not in fn.__name__:
            continue
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:                      # noqa: BLE001
            print(f"{fn.__name__},ERROR,{type(e).__name__}: {e}")
            continue
        for name, value, derived in rows:
            print(f"{name},{value},{derived}")
        print(f"# {fn.__name__} took {time.time() - t0:.1f}s",
              file=sys.stderr)


if __name__ == '__main__':
    main()
