# One function per paper table. Prints ``name,value,derived`` CSV.
# Exits non-zero if any table function errors, so CI smoke jobs fail loudly.
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)


def main() -> None:
    from benchmarks import paper, streaming

    only = sys.argv[1] if len(sys.argv) > 1 else None
    fns = [fn for fn in paper.ALL + streaming.ALL
           if not only or only in fn.__name__]
    if not fns:
        print(f"no benchmark matches {only!r}", file=sys.stderr)
        sys.exit(2)
    failed = False
    print("name,value,derived")
    for fn in fns:
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:                      # noqa: BLE001
            print(f"{fn.__name__},ERROR,{type(e).__name__}: {e}")
            failed = True
            continue
        for name, value, derived in rows:
            print(f"{name},{value},{derived}")
        print(f"# {fn.__name__} took {time.time() - t0:.1f}s",
              file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == '__main__':
    main()
