"""Observability overhead benchmark (the ISSUE 6 acceptance gate).

Measures the serving-path cost of the metrics registry by timing the SAME
query stream through three QueryServer configurations over one shared index:

* ``off``    — ``NULL_REGISTRY`` injected, no recorder: every metric call
  is a no-op attribute chain, the zero-instrumentation baseline.
* ``on``     — a real ``MetricsRegistry`` PLUS the full ISSUE 8 stack:
  per-query latency histograms and counters, a per-batch `TraceContext`,
  a tail-sampled `FlightRecorder`, and a ticking `SLOMonitor` (the
  always-on production path; ``trace_every=0`` so no staged dispatches).
* ``traced`` — the ``on`` stack plus ``trace_every=8``: every 8th batch
  runs the staged per-stage path with device syncs between spans
  (reported for context; sampling keeps it off the common case so it is
  NOT gated).

Rounds alternate off/on/traced so drift (thermal, allocator state) hits all
three equally, and p50s come from external ``perf_counter`` timing around
``query_many`` — the registry never times itself.

Gate: ``on`` p50 at batch 8 must be within 5% of ``off`` p50
(``obs_overhead/gate``); the row errors the run (and CI) when exceeded.
"""

from __future__ import annotations

import time

import numpy as np

_BATCH = 8
_ROUNDS = 40
_GATE_PCT = 5.0


def _bench(docs=2048, batch=_BATCH, rounds=_ROUNDS):
    from benchmarks.query_path import _QUERIES, _build
    from repro.obs import FlightRecorder, NULL_REGISTRY, MetricsRegistry
    from repro.obs.slo import SLOMonitor, SLOSpec
    from repro.serving.serve import QueryServer

    index, _, _, qi, qv = _build(docs)

    def full_stack(trace_every=0):
        # the production configuration the gate must hold with: registry +
        # flight recorder + ticking SLO monitor (ISSUE 8 acceptance)
        reg = MetricsRegistry()
        rec = FlightRecorder(capacity=512, sample_rate=0.05, registry=reg,
                             spill=False)
        slo = SLOMonitor(SLOSpec(), reg).start(interval_s=0.25)
        srv = QueryServer(index, k=10, kprime=100, registry=reg,
                          recorder=rec, trace_every=trace_every)
        return srv, slo

    on_srv, on_slo = full_stack()
    traced_srv, traced_slo = full_stack(trace_every=8)
    servers = {
        "off": QueryServer(index, k=10, kprime=100, registry=NULL_REGISTRY),
        "on": on_srv,
        "traced": traced_srv,
    }
    for srv in servers.values():                     # compile warmup
        for _ in range(8):                           # incl. staged path jits
            srv.query_many(qi[:batch], qv[:batch])

    samples = {name: [] for name in servers}
    for _ in range(rounds):
        # interleave so machine drift is shared, not attributed to one mode
        for name, srv in servers.items():
            t0 = time.perf_counter()
            for lo in range(0, _QUERIES, batch):
                srv.query_many(qi[lo:lo + batch], qv[lo:lo + batch])
            samples[name].append((time.perf_counter() - t0) * 1e3
                                 / _QUERIES)
    on_slo.stop()
    traced_slo.stop()
    return ({name: float(np.median(v)) for name, v in samples.items()},
            {name: float(np.percentile(v, 99)) for name, v in samples.items()})


def obs_overhead():
    """Registry on/off/traced p50/p99 per-query latency + the <=5% gate."""
    p50, p99 = _bench()
    overhead_pct = (p50["on"] / max(p50["off"], 1e-9) - 1.0) * 100.0
    traced_pct = (p50["traced"] / max(p50["off"], 1e-9) - 1.0) * 100.0
    rows = [
        (f"obs_overhead/b{_BATCH}/off_p50_ms", f"{p50['off']:.4f}",
         "NULL_REGISTRY baseline"),
        (f"obs_overhead/b{_BATCH}/on_p50_ms", f"{p50['on']:.4f}",
         "metrics + flight recorder + SLO monitor on"),
        (f"obs_overhead/b{_BATCH}/traced_p50_ms", f"{p50['traced']:.4f}",
         "full stack + trace_every=8 (not gated)"),
        (f"obs_overhead/b{_BATCH}/off_p99_ms", f"{p99['off']:.4f}", ""),
        (f"obs_overhead/b{_BATCH}/on_p99_ms", f"{p99['on']:.4f}", ""),
        (f"obs_overhead/b{_BATCH}/traced_p99_ms", f"{p99['traced']:.4f}",
         ""),
        (f"obs_overhead/b{_BATCH}/overhead_pct", f"{overhead_pct:.2f}",
         f"% (gate <= {_GATE_PCT})"),
        (f"obs_overhead/b{_BATCH}/traced_overhead_pct",
         f"{traced_pct:.2f}", "%"),
    ]
    if overhead_pct > _GATE_PCT:
        raise RuntimeError(
            f"metrics overhead {overhead_pct:.2f}% > {_GATE_PCT}% gate "
            f"(off p50 {p50['off']:.4f}ms vs on p50 {p50['on']:.4f}ms)")
    rows.append((f"obs_overhead/b{_BATCH}/gate", "PASS",
                 f"on within {_GATE_PCT}% of off"))
    return rows


ALL = [obs_overhead]


if __name__ == "__main__":
    # Standalone entry: `python benchmarks/obs_overhead.py [--json PATH]`.
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks import run as _run

    sys.argv = [sys.argv[0], "obs_overhead"] + sys.argv[1:]
    _run.main()
