"""Hot/cold tiered-store benchmark (the ISSUE 10 acceptance gate).

One entry, emitted as ``run.py`` rows (``--json`` writes BENCH_tiering.json):

* ``tiering_serving`` — serves a clustered corpus whose raw rows are >= 4x
  the device budget from a :class:`TieredSinnamonIndex` and from the
  resident baseline, driving both with the SAME zipf-hot query stream
  (queries concentrate on a few hot chunks, the realistic regime tiering
  is built for).  Reports and gates:

  - **bit identity** — tiered ids AND scores match the resident index
    exactly on spot-check batches (tiering must be invisible);
  - **hit rate** — unique-chunk cache hit rate over the measured stream
    must be >= 0.80 (the LFU-with-aging cache keeps the zipf head
    resident);
  - **latency** — tiered p99 batch latency must be <= 3x resident p99
    (the price of the host gather + promotion on the miss tail);
  - **promotion/demotion throughput** — chunks/s and MB/s through a
    deliberately thrashing cache (every access promotes + evicts).
"""

from __future__ import annotations

import numpy as np

_CHUNK_SLOTS = 64
_CHUNKS = 64                    # corpus = 4096 slots
_N = 2048
_MAX_NNZ = 48
_DOC_NNZ = 24
_M = 128
_ZIPF_A = 1.6
_K, _KPRIME = 10, 32
_BATCH = 8
_WARM, _MEASURE = 8, 48
_BUDGET_FRACTION = 4            # corpus raw bytes >= 4x device budget
_HIT_RATE_GATE = 0.80
_P99_GATE = 3.0


def _clustered_corpus(rng):
    """Padded-CSR corpus where each chunk owns a disjoint coordinate band,
    so a query about one cluster finds its candidates in one chunk —
    document locality is what makes a corpus *tierable* in practice."""
    cap = _CHUNK_SLOTS * _CHUNKS
    band = _N // _CHUNKS
    idx = np.full((cap, _MAX_NNZ), -1, np.int32)
    val = np.zeros((cap, _MAX_NNZ), np.float32)
    for c in range(_CHUNKS):
        base = c * band
        for s in range(_CHUNK_SLOTS):
            r = c * _CHUNK_SLOTS + s
            idx[r, :_DOC_NNZ] = rng.choice(band, _DOC_NNZ,
                                           replace=False) + base
            val[r, :_DOC_NNZ] = np.abs(rng.standard_normal(_DOC_NNZ)) + 0.1
    return idx, val


def _zipf_queries(rng, batches, idx, val):
    """[batches][B, P] query stream: each query re-asks about a document
    sampled zipf-hot over chunks (hot chunks scattered over slot space so
    residency comes from the cache policy, not slot order)."""
    ranks = np.arange(1, _CHUNKS + 1, dtype=np.float64)
    p = ranks ** -_ZIPF_A
    p /= p.sum()
    perm = rng.permutation(_CHUNKS)
    out = []
    for _ in range(batches):
        chunks = perm[rng.choice(_CHUNKS, size=_BATCH, p=p)]
        rows = chunks * _CHUNK_SLOTS + rng.integers(0, _CHUNK_SLOTS, _BATCH)
        out.append((idx[rows].copy(), val[rows].copy()))
    return out


def tiering_serving():
    import time

    import repro.core.engine as eng
    from repro.storage.tiered import TieredVecStore, chunk_bytes

    rng = np.random.default_rng(0)
    cap = _CHUNK_SLOTS * _CHUNKS
    spec = eng.EngineSpec(capacity=cap, n=_N, m=_M, max_nnz=_MAX_NNZ)
    idx, val = _clustered_corpus(rng)

    host_bytes = cap * _MAX_NNZ * (4 + 2)          # int32 idx + bf16 val
    budget = host_bytes // _BUDGET_FRACTION
    resident = eng.SinnamonIndex(spec)
    tiered = eng.TieredSinnamonIndex(spec, tier_chunk_slots=_CHUNK_SLOTS,
                                     device_budget_bytes=budget)
    assert tiered.tiered.host_bytes() >= _BUDGET_FRACTION * budget
    ids = list(range(cap))
    for lo in range(0, cap, 512):
        resident.insert_many(ids[lo:lo + 512], idx[lo:lo + 512],
                             val[lo:lo + 512])
        tiered.insert_many(ids[lo:lo + 512], idx[lo:lo + 512],
                           val[lo:lo + 512])

    stream = _zipf_queries(rng, _WARM + _MEASURE, idx, val)

    # -- bit-identity spot check (tiering must be invisible) -------------------
    for qi, qv in stream[:4]:
        ri, rs = resident.search_many(qi, qv, _K, kprime=_KPRIME)
        ti, ts = tiered.search_many(qi, qv, _K, kprime=_KPRIME)
        if not (np.array_equal(ri, ti) and np.array_equal(rs, ts)):
            raise AssertionError("tiered results diverge from resident "
                                 "baseline (ids or scores)")

    # -- latency + hit rate over the zipf stream ------------------------------
    for qi, qv in stream[:_WARM]:                  # compile + cache warmup
        resident.search_many(qi, qv, _K, kprime=_KPRIME)
        tiered.search_many(qi, qv, _K, kprime=_KPRIME)
    before = tiered.tiered.stats()
    lat_r, lat_t = [], []
    for qi, qv in stream[_WARM:]:
        t0 = time.perf_counter()
        resident.search_many(qi, qv, _K, kprime=_KPRIME)
        lat_r.append((time.perf_counter() - t0) * 1e3)
        t0 = time.perf_counter()
        tiered.search_many(qi, qv, _K, kprime=_KPRIME)
        lat_t.append((time.perf_counter() - t0) * 1e3)
    after = tiered.tiered.stats()
    hits = after["hits"] - before["hits"]
    misses = after["misses"] - before["misses"]
    hit_rate = hits / max(1, hits + misses)
    p50_r, p99_r = np.percentile(lat_r, [50, 99])
    p50_t, p99_t = np.percentile(lat_t, [50, 99])

    # -- promotion/demotion throughput (forced thrash) ------------------------
    store = TieredVecStore(cap, _MAX_NNZ, chunk_slots=_CHUNK_SLOTS,
                           cache_chunks=2)
    store.load_rows(idx, val.astype(np.float32))
    t0 = time.perf_counter()
    for c in range(_CHUNKS):
        r = store.gather_rows(np.arange(c * _CHUNK_SLOTS,
                                        c * _CHUNK_SLOTS + 4))
        r[0].block_until_ready()
    dt = time.perf_counter() - t0
    st = store.stats()
    promo_per_s = st["promotions"] / dt
    promo_mb_s = promo_per_s * chunk_bytes(_CHUNK_SLOTS, _MAX_NNZ,
                                           "bfloat16") / 2**20

    rows = [
        ("tiering_corpus_over_budget",
         round(tiered.tiered.host_bytes() / budget, 2),
         f"raw rows {tiered.tiered.host_bytes()}B vs device budget "
         f"{budget}B (gate >= {_BUDGET_FRACTION})"),
        ("tiering_bit_identity", 1,
         "tiered ids+scores == resident on spot-check batches"),
        ("tiering_hit_rate", round(hit_rate, 4),
         f"{hits} hits / {misses} misses on the zipf stream "
         f"(gate >= {_HIT_RATE_GATE})"),
        ("tiering_p50_ms", round(p50_t, 3),
         f"resident p50 {p50_r:.3f} ms"),
        ("tiering_p99_ms", round(p99_t, 3),
         f"resident p99 {p99_r:.3f} ms (gate <= {_P99_GATE}x)"),
        ("tiering_p99_vs_resident", round(p99_t / max(p99_r, 1e-9), 2),
         "tiered p99 / resident p99"),
        ("tiering_promotions_per_s", round(promo_per_s, 1),
         f"{promo_mb_s:.1f} MB/s host->device through a thrashing "
         f"2-chunk cache ({st['evictions']} demotions)"),
        ("tiering_resident_chunks", after["resident_chunks"],
         f"of {after['cache_chunks']} cache / {after['num_chunks']} total"),
    ]
    if hit_rate < _HIT_RATE_GATE:
        raise AssertionError(
            f"tiering gate: hit rate {hit_rate:.3f} < {_HIT_RATE_GATE} on "
            f"the zipf stream")
    if p99_t > _P99_GATE * p99_r:
        raise AssertionError(
            f"tiering gate: tiered p99 {p99_t:.2f} ms > {_P99_GATE}x "
            f"resident {p99_r:.2f} ms")
    return rows


ALL = [tiering_serving]
