"""Train a small LM for a few hundred steps with the full substrate stack
(data pipeline → model → AdamW → checkpointing with auto-resume).

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--resume]
"""

import argparse
import os

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.data import loaders
from repro.models import transformer as tr
from repro.optim import adamw
from repro.train import loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = tr.LMConfig("demo-lm", n_layers=4, d_model=128, n_heads=8,
                      n_kv_heads=4, d_ff=384, vocab=2_048, head_dim=16,
                      attn_chunk=64, attn_q_chunk=64)
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=20,
                                decay_steps=args.steps)

    def loss_fn(params, batch):
        return tr.lm_loss(params, batch[0], batch[1], cfg)

    step_fn = jax.jit(loop.make_train_step(loss_fn, opt_cfg))

    state = loop.init_state(tr.init_params(jax.random.PRNGKey(0), cfg))
    start = 0
    if args.resume and ckpt.latest_step(args.ckpt_dir) is not None:
        state, start, _ = ckpt.restore(args.ckpt_dir, state)
        print(f"resumed from step {start}")

    for step in range(start, args.steps):
        toks, labels = loaders.lm_batch(0, step, batch=8, seq=128,
                                        vocab=cfg.vocab)
        state, metrics = step_fn(state, (jnp.asarray(toks),
                                         jnp.asarray(labels)))
        if (step + 1) % 25 == 0:
            print(f"step {step+1:4d}  loss={float(metrics['loss']):.4f}  "
                  f"|g|={float(metrics['grad_norm']):.3f}  "
                  f"lr={float(metrics['lr']):.2e}")
        if (step + 1) % 100 == 0:
            path = ckpt.save(args.ckpt_dir, step + 1, state)
            print(f"checkpointed -> {path}")


if __name__ == "__main__":
    main()
