"""End-to-end serving driver (the paper's kind of workload): index a
SPLADE-like corpus through the ``repro.api`` facade, serve batched queries
through the QueryServer with the anytime budget as the latency lever, then
put the async front door in front of it and show dynamic batching turning
concurrent clients into fused dispatches.

    PYTHONPATH=src python examples/serve_sparse_corpus.py [--docs 20000]
"""

import argparse
import threading
import time

import numpy as np

from repro.api import IndexConfig, open_index
from repro.core.linscan import brute_force_topk
from repro.data import synth
from repro.obs import MetricsRegistry
from repro.serving import QueryServer, ServingFrontend


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=20_000)
    ap.add_argument("--queries", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    ds = synth.SPLADE_LIKE
    print(f"building corpus: {args.docs} docs, n={ds.n}, ψ_d≈{ds.psi_doc}")
    idx, val = synth.make_corpus(0, ds, args.docs, pad=256)
    qi, qv = synth.make_queries(1, ds, args.queries, pad=96)

    index = open_index(IndexConfig(n=ds.n, m=60, capacity=args.docs,
                                   max_nnz=256, h=1, positive_only=True))
    bs = 2_048
    for lo in range(0, args.docs, bs):
        index.insert_many(list(range(lo, min(lo + bs, args.docs))),
                          idx[lo:lo + bs], val[lo:lo + bs])
    print(f"index bytes: {index.memory_bytes()}")

    truth = [brute_force_topk(idx, val, qi[b], qv[b], ds.n, args.k)[0]
             for b in range(args.queries)]

    for budget in (None, 16, 8):
        server = QueryServer(index, k=args.k, kprime=800, budget=budget,
                             registry=MetricsRegistry())
        recalls = []
        for b in range(args.queries):
            result = server.query(qi[b], qv[b])      # -> QueryResult
            recalls.append(len(set(result.ids.tolist())
                               & set(truth[b].tolist())) / args.k)
        lat = server.latency_percentiles()
        print(f"budget={str(budget):>4s}: recall@{args.k}="
              f"{np.mean(recalls):.3f}  latency p50={lat['p50']:.1f}ms "
              f"p99={lat['p99']:.1f}ms")

    # --- the async front door: concurrent clients coalesce into fused
    # query_many dispatches (docs/serving.md); answers stay bit-identical
    # to the per-query path.
    server = QueryServer(index, k=args.k, kprime=800, budget=16,
                         registry=MetricsRegistry())
    with ServingFrontend(server, max_batch=16, batch_window_ms=2.0,
                         queue_depth=256) as frontend:
        frontend.query(qi[0], qv[0])                 # compile warmup
        t0 = time.perf_counter()
        lats = []
        lock = threading.Lock()

        def client(b):
            for _ in range(8):
                t = time.perf_counter()
                frontend.query(qi[b], qv[b])
                with lock:
                    lats.append((time.perf_counter() - t) * 1e3)

        threads = [threading.Thread(target=client, args=(b,))
                   for b in range(min(args.queries, 16))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        print(f"front door: {len(lats)} concurrent queries in {wall:.2f}s "
              f"({len(lats) / wall:.0f} qps) — p50="
              f"{np.percentile(lats, 50):.1f}ms "
              f"p99={np.percentile(lats, 99):.1f}ms")


if __name__ == "__main__":
    main()
