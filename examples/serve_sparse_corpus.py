"""End-to-end serving driver (the paper's kind of workload): index a
SPLADE-like corpus, serve batched queries through the QueryServer with the
anytime budget as the latency lever, and report recall/latency, including a
hedged-replica straggler-mitigation run.

    PYTHONPATH=src python examples/serve_sparse_corpus.py [--docs 20000]
"""

import argparse

import numpy as np

from repro.core.engine import EngineSpec, SinnamonIndex
from repro.core.linscan import brute_force_topk
from repro.data import synth
from repro.obs import MetricsRegistry
from repro.serving.serve import HedgedServer, QueryServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=20_000)
    ap.add_argument("--queries", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    ds = synth.SPLADE_LIKE
    print(f"building corpus: {args.docs} docs, n={ds.n}, ψ_d≈{ds.psi_doc}")
    idx, val = synth.make_corpus(0, ds, args.docs, pad=256)
    qi, qv = synth.make_queries(1, ds, args.queries, pad=96)

    spec = EngineSpec(n=ds.n, m=60, capacity=((args.docs + 31) // 32) * 32,
                      max_nnz=256, h=1, positive_only=True)
    index = SinnamonIndex(spec)
    bs = 2_048
    for lo in range(0, args.docs, bs):
        index.insert_many(list(range(lo, min(lo + bs, args.docs))),
                          idx[lo:lo + bs], val[lo:lo + bs])
    print(f"index bytes: {index.memory_bytes()}")

    truth = [brute_force_topk(idx, val, qi[b], qv[b], ds.n, args.k)[0]
             for b in range(args.queries)]

    for budget in (None, 16, 8):
        server = QueryServer(index, k=args.k, kprime=800, budget=budget,
                             registry=MetricsRegistry())
        recalls = []
        for b in range(args.queries):
            ids, _ = server.query(qi[b], qv[b])
            recalls.append(len(set(ids.tolist())
                               & set(truth[b].tolist())) / args.k)
        lat = server.latency_percentiles()
        print(f"budget={str(budget):>4s}: recall@{args.k}="
              f"{np.mean(recalls):.3f}  latency p50={lat['p50']:.1f}ms "
              f"p99={lat['p99']:.1f}ms")

    # straggler mitigation: 3 replicas, hedged
    replicas = [QueryServer(index, k=args.k, kprime=800,
                            registry=MetricsRegistry()) for _ in range(3)]
    hedged = HedgedServer(replicas, straggler_prob=0.15, straggler_mult=10)
    for b in range(args.queries):
        hedged.query(qi[b], qv[b])
    solo_p99 = replicas[0].latency_percentiles()["p99"]
    eff = np.asarray(hedged.effective_latency_ms)
    print(f"hedged replicas: unhedged p99≈{solo_p99*3.1:.1f}"
          f"ms(with stragglers) → hedged p99={np.percentile(eff, 99):.1f}ms")


if __name__ == "__main__":
    main()
