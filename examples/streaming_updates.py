"""Streaming behaviour demo (paper §6.4): interleaved inserts/deletes from a
rolling feed; the index stays consistent and search quality is stable over
the index's life.

    PYTHONPATH=src python examples/streaming_updates.py
"""

import numpy as np

from repro.core.engine import EngineSpec, SinnamonIndex
from repro.core.linscan import brute_force_topk
from repro.data import synth


def main():
    ds = synth.SparseDatasetSpec("stream", n=4_000, psi_doc=40,
                                 psi_query=16, value_dist="gaussian")
    spec = EngineSpec(n=ds.n, m=20, capacity=1_024, max_nnz=64, h=1)
    index = SinnamonIndex(spec)
    feed = synth.StreamingFeed(seed=0, spec=ds, pad=64, delete_ratio=0.25)

    live_idx, live_val, live_ids = {}, {}, []
    qi, qv = synth.make_queries(9, ds, 4, pad=32)

    for step, (op, doc, didx, dval) in enumerate(feed.events(1_500)):
        if op == "insert":
            index.insert(doc, didx[didx >= 0], dval[didx >= 0])
            live_idx[doc], live_val[doc] = didx, dval
        else:
            index.delete(doc)
            live_idx.pop(doc), live_val.pop(doc)
        if (step + 1) % 500 == 0:
            ids_list = sorted(live_idx)
            arr_i = np.stack([live_idx[d] for d in ids_list])
            arr_v = np.stack([live_val[d] for d in ids_list])
            recs = []
            for b in range(4):
                pos, _ = brute_force_topk(arr_i, arr_v, qi[b], qv[b],
                                          ds.n, 10)
                truth = {ids_list[p] for p in pos}
                got, _ = index.search(qi[b], qv[b], k=10, kprime=100)
                recs.append(len(set(got.tolist()) & truth) / 10)
            print(f"step {step+1}: live={len(live_idx)} "
                  f"capacity={index.spec.capacity} "
                  f"recall@10={np.mean(recs):.3f}")


if __name__ == "__main__":
    main()
