"""Streaming behaviour demo (paper §6.4): interleaved inserts/deletes from a
rolling feed; the index stays consistent and search quality is stable over
the index's life — AND survives a process restart.

The stream runs against a durable index (WAL + snapshots).  Halfway through
we simulate a crash: drop the index object, tear the last WAL record the way
a power cut would, then recover from snapshot + WAL tail and keep streaming.
At the end, churn drift is measured and compacted away.

    PYTHONPATH=src python examples/streaming_updates.py
"""

import os
import tempfile

import numpy as np

from repro.api import DurabilityConfig, IndexConfig, open_index
from repro.core.linscan import brute_force_topk
from repro.data import synth
from repro.persist import wal
from repro.persist.compact import drift_metrics


def report(step, index, live_idx, live_val, qi, qv, ds):
    ids_list = sorted(live_idx)
    arr_i = np.stack([live_idx[d] for d in ids_list])
    arr_v = np.stack([live_val[d] for d in ids_list])
    recs = []
    for b in range(4):
        pos, _ = brute_force_topk(arr_i, arr_v, qi[b], qv[b], ds.n, 10)
        truth = {ids_list[p] for p in pos}
        got, _ = index.search(qi[b], qv[b], k=10, kprime=100)
        recs.append(len(set(got.tolist()) & truth) / 10)
    print(f"step {step}: live={len(live_idx)} "
          f"capacity={index.spec.capacity} "
          f"recall@10={np.mean(recs):.3f}")


def main():
    ds = synth.SparseDatasetSpec("stream", n=4_000, psi_doc=40,
                                 psi_query=16, value_dist="gaussian")
    root = tempfile.mkdtemp(prefix="streaming_updates_")
    wal_dir, snap_dir = os.path.join(root, "wal"), os.path.join(root, "snap")
    config = IndexConfig(n=ds.n, m=20, capacity=1_024, max_nnz=64, h=1,
                         durability=DurabilityConfig(wal_dir=wal_dir,
                                                     snapshot_dir=snap_dir))

    index = open_index(config)
    feed = synth.StreamingFeed(seed=0, spec=ds, pad=64, delete_ratio=0.25)

    live_idx, live_val = {}, {}
    qi, qv = synth.make_queries(9, ds, 4, pad=32)

    def apply(op, doc, didx, dval):
        if op == "insert":
            index.insert(doc, didx[didx >= 0], dval[didx >= 0])
            live_idx[doc], live_val[doc] = didx, dval
        else:
            index.delete(doc)
            live_idx.pop(doc), live_val.pop(doc)

    events = feed.events(1_500)
    for step, ev in enumerate(events):
        apply(*ev)
        if (step + 1) % 250 == 0:
            report(step + 1, index, live_idx, live_val, qi, qv, ds)
        if step + 1 == 500:
            index.snapshot()
        if step + 1 == 750:
            break

    # ---- simulated crash: lose the process, tear the WAL tail ------------
    print(f"crash at step 751 (snapshot at 500, {index.size} docs live)")
    del index
    part = os.path.join(wal_dir, wal.partition_name(0))
    seg = os.path.join(part, sorted(os.listdir(part))[-1])
    with open(seg, "r+b") as f:
        f.truncate(os.path.getsize(seg) - 9)     # mid-record, like a power cut

    # ---- restart-and-resume: snapshot + WAL tail replay ------------------
    # same config, same dirs -> open_index recovers instead of starting empty
    index = open_index(config)
    # The torn record is the last, unacknowledged op.  Like a real client,
    # the application re-applies whatever the recovered index is missing
    # relative to its own mirror (a lost insert or a lost delete).
    lost = [d for d in live_idx if d not in index]
    gone = [d for d in index.doc_ids() if d not in live_idx]
    for d in gone:
        index.delete(d)
    for d in lost:
        didx, dval = live_idx[d], live_val[d]
        index.insert(d, didx[didx >= 0], dval[didx >= 0])
    print(f"recovered {index.size} docs "
          f"(re-applied {len(lost) + len(gone)} unacknowledged torn-tail "
          f"op(s))")

    for step, ev in enumerate(feed.events(750), start=751):
        apply(*ev)
        if step % 250 == 0:
            report(step, index, live_idx, live_val, qi, qv, ds)

    # ---- churn drift + compaction ----------------------------------------
    before = drift_metrics(index)
    rebuilt = index.compact()
    after = drift_metrics(index)
    print(f"drift: max={before['max_overestimate']:.3f} over "
          f"{before['dirty_active']} recycled slots -> "
          f"{after['max_overestimate']:.3f} after compacting {rebuilt} cols")


if __name__ == "__main__":
    main()
