"""Quickstart: build a Sinnamon index, stream inserts/deletes, search, and
compare against the exact LinScan baseline.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.api import IndexConfig, open_index
from repro.core.linscan import LinScanIndex
from repro.data import synth


def main():
    ds = synth.SparseDatasetSpec("demo", n=5_000, psi_doc=60, psi_query=24,
                                 value_dist="gaussian")
    n_docs = 2_000
    idx, val = synth.make_corpus(seed=0, spec=ds, n_docs=n_docs, pad=96)
    qi, qv = synth.make_queries(seed=1, spec=ds, n_queries=5, pad=48)

    # --- Sinnamon: sketch size 2m = ψ_d (the paper's mid setting), h=1
    index = open_index(IndexConfig(n=ds.n, m=30, capacity=2_048,
                                   max_nnz=96, h=1))
    index.insert_many(list(range(n_docs)), idx, val)
    print(f"indexed {index.size} docs; "
          f"index bytes: {index.memory_bytes()}")

    # --- exact baseline
    exact = LinScanIndex(ds.n)
    exact.insert_many(range(n_docs), idx, val)

    for b in range(5):
        ids, scores = index.search(qi[b], qv[b], k=10, kprime=100)
        ids0, scores0 = exact.search(qi[b], qv[b], k=10)
        recall = len(set(ids.tolist()) & set(ids0.tolist())) / 10
        print(f"query {b}: recall@10={recall:.2f}  "
              f"top1 sinnamon={ids[0]}({scores[0]:.3f}) "
              f"exact={ids0[0]}({scores0[0]:.3f})")

    # --- streaming: delete the current top-1, insert a replacement
    victim = int(ids[0])
    index.delete(victim)
    ids2, _ = index.search(qi[4], qv[4], k=10, kprime=100)
    print(f"after delete({victim}): still returned? {victim in ids2}")

    new_idx = np.arange(0, 96, 2, dtype=np.int32)
    new_val = np.ones(48, np.float32)
    index.insert(999_999, new_idx, new_val)
    print(f"inserted doc 999999; index size = {index.size}")


if __name__ == "__main__":
    main()
