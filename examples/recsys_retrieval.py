"""RecSys retrieval_cand: dense batched-dot MIPS vs the paper's Sinnamon
engine over sparsified item vectors — the integration point between the
assigned recsys architectures and the paper's technique.

The item catalog is sparsified (top-t magnitude coordinates per item — a
standard sparse-retrieval trick) and served by Sinnamon; recall is measured
against the exact dense scores.

    PYTHONPATH=src python examples/recsys_retrieval.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import EngineSpec, SinnamonIndex
from repro.data import loaders
from repro.models import recsys as rs


def main():
    cfg = rs.RecsysConfig(name="sasrec-demo", model="sasrec", embed_dim=50,
                          n_blocks=2, n_heads=1, seq_len=50, n_items=20_000)
    params = rs.init_params(jax.random.PRNGKey(0), cfg)
    batch = jax.tree.map(jnp.asarray, loaders.recsys_batch(0, 0, 8, cfg))

    # dense path: exact batched-dot MIPS
    t0 = time.perf_counter()
    scores = rs.retrieval_scores(params, batch, cfg)
    top_dense = jax.lax.top_k(scores, 10)[1]
    jax.block_until_ready(top_dense)
    t_dense = time.perf_counter() - t0
    print(f"dense MIPS over {cfg.n_items} items: {t_dense*1e3:.1f}ms")

    # Sinnamon path over sparsified items: keep top-t coords per item
    items = np.asarray(rs.item_embeddings(params, cfg))     # [V, D]
    t = 16
    order = np.argsort(-np.abs(items), axis=1)[:, :t]
    spec = EngineSpec(n=cfg.embed_dim, m=8,
                      capacity=((cfg.n_items + 31) // 32) * 32,
                      max_nnz=t, h=1, value_dtype="float32")
    index = SinnamonIndex(spec)
    idx_b = np.sort(order, axis=1).astype(np.int32)
    val_b = np.take_along_axis(items, idx_b, axis=1).astype(np.float32)
    for lo in range(0, cfg.n_items, 4096):
        hi = min(lo + 4096, cfg.n_items)
        index.insert_many(list(range(lo, hi)), idx_b[lo:hi], val_b[lo:hi])

    users = np.asarray(rs.user_repr(params, batch, cfg))     # [B, D]
    recalls = []
    for b in range(users.shape[0]):
        qidx = np.arange(cfg.embed_dim, dtype=np.int32)
        ids, _ = index.search(qidx, users[b], k=10, kprime=200)
        truth = set(np.asarray(top_dense[b]).tolist())
        recalls.append(len(set(ids.tolist()) & truth) / 10)
    print(f"sinnamon over top-{t} sparsified items: "
          f"recall@10 vs dense = {np.mean(recalls):.2f} "
          f"(sparsification keeps {t}/{cfg.embed_dim} coords — the recall "
          f"gap is the sparsification cost, not the sketch's)")


if __name__ == "__main__":
    main()
