"""repro — a production-grade JAX framework reproducing and extending

  "An Approximate Algorithm for Maximum Inner Product Search over Streaming
   Sparse Vectors" (Bruch, Nardini, Ingber, Liberty — 2023, cs.IR).

Public surface (see docs/architecture.md for the data-flow map):
    repro.api         — the facade: IndexConfig + open_index over every
                        deployment shape; typed QueryResult
    repro.core        — Sinnamon sketch / bit-packed index / engines
                        (Sinnamon, LinScan, WAND) + the §5 error theory
    repro.kernels     — Pallas TPU kernels, XLA twins, scoring-backend dispatch
    repro.storage     — raw padded-CSR vector store (exact rerank source)
    repro.serving     — QueryServer, the async front door (admission,
                        per-tenant quotas, deadline-aware dynamic batching,
                        HTTP/JSON door) + loadgen, the mesh-sharded SPMD index
    repro.distributed — mesh helpers, hierarchical top-k candidate merge
    repro.persist     — WAL, snapshots, crash recovery, sketch compaction
    repro.eval        — recall harness, empirical-vs-theory bounds, auto-tuner
    repro.data        — synthetic sparse corpora (paper Table 3 shapes)
    repro.launch      — serving/train launchers, mesh dry-run
    repro.checkpoint  — atomic-rename checkpointing (snapshot substrate)

Dormant seed scaffolding (excluded from the docs site; see
configs/README.md): repro.configs, repro.models, repro.optim, repro.train.
"""

__version__ = "1.0.0"
