"""repro — a production-grade JAX framework reproducing and extending

  "An Approximate Algorithm for Maximum Inner Product Search over Streaming
   Sparse Vectors" (Bruch, Nardini, Ingber, Liberty — 2023, cs.IR).

Public surface:
    repro.core      — Sinnamon sketch / bit-packed index / engines (Sinnamon, LinScan, WAND)
    repro.kernels   — Pallas TPU kernels (+ pure-jnp oracles)
    repro.models    — assigned architectures (LM / MoE / GNN / recsys)
    repro.distributed, repro.train, repro.serving, repro.checkpoint
    repro.configs   — one module per assigned architecture
    repro.launch    — production mesh, multi-pod dry-run, train/serve drivers
"""

__version__ = "1.0.0"
