"""Explicitly-sharded EquiformerV2 message passing (shard_map).

The GSPMD-automatic path (models/gnn.py) is correct but replicates node
features around the arbitrary-index gather — at ogb_products scale (2.45M
nodes × 49 coef × 128 ch) that is ~62 GB per device.  This module is the
beyond-baseline schedule (EXPERIMENTS.md §Perf, cell equiformer-v2 ×
ogb_products):

  * node tensors: REPLICATED over 'data', channel-sharded over 'model'
    → per-device f is [N, K, C/16] (~240 MB bf16 / 3.8 GB f32 at ogb scale);
  * edges: sharded over 'data'; gathers and scatters are fully shard-local;
  * SO(2) conv: weights row-sharded over 'model', partial matmul + psum;
  * per-shard streaming segment-softmax states merged across 'data' with the
    associative (max, denom, numerator) combine — one pmax + two psums per
    layer instead of per-chunk collectives;
  * the per-degree output mixing (w_out) is folded into the *edge* path
    (linear ops commute with the attention-weighted sum and with rotations),
    so node-level updates never need full-C matmuls;
  * node updates (LN + gating) are computed on each device's node range and
    all-gathered over 'data'.

Numerics match models/gnn.py exactly (tests/test_gnn_sharded.py).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import gnn, sh
from repro.models.gnn import NEG, GNNConfig, GraphBatch, _m_indices, _rbf

Array = jax.Array


def _axis_size(ax):
    return jax.lax.psum(1, ax)


def _axis_linear_index(axes):
    """Linear device index over a tuple of mesh axes (major-to-minor)."""
    idx = jax.lax.axis_index(axes[0])
    for a in axes[1:]:
        idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
    return idx


def so2_conv_sharded(fr: Array, lp_so2, cfg: GNNConfig,
                     model_axis) -> Array:
    """SO(2) conv with C sharded: fr [e, K, Cl]; weights row-sharded.

    Weight rows use the (channel-major, degree-minor) layout of
    gnn._flat_cmajor, so this device's contiguous row shard is exactly its
    channel slice × all degrees.  Output is full-C (partial matmuls psum'ed
    over the model axis) in the same flattened layout.
    """
    e, K, Cl = fr.shape
    lm = cfg.l_max
    C = cfg.c

    def mix(flat_local, w_local):
        # flat_local [e, n_rows*Cl]; w_local [(n_rows*Cl), n_rows*C]
        return jax.lax.psum(flat_local @ w_local, model_axis)

    out = jnp.zeros((e, K, C), fr.dtype)
    i0 = jnp.asarray(_m_indices(lm, 0))
    o0 = mix(gnn._flat_cmajor(fr[:, i0, :]), lp_so2["w0"])
    out = out.at[:, i0, :].set(gnn._unflat_cmajor(o0, lm + 1))
    for m in range(1, cfg.m_max + 1):
        ip = jnp.asarray(_m_indices(lm, m))
        im = jnp.asarray(_m_indices(lm, -m))
        nm = lm + 1 - m
        cm = gnn._flat_cmajor(fr[:, ip, :])
        sm = gnn._flat_cmajor(fr[:, im, :])
        cp = mix(cm, lp_so2[f"w{m}r"]) - mix(sm, lp_so2[f"w{m}i"])
        sp = mix(cm, lp_so2[f"w{m}i"]) + mix(sm, lp_so2[f"w{m}r"])
        out = out.at[:, ip, :].set(gnn._unflat_cmajor(cp, nm))
        out = out.at[:, im, :].set(gnn._unflat_cmajor(sp, nm))
    return out


def _per_l_linear_full(x: Array, w: Array, cfg: GNNConfig) -> Array:
    outs = [x[:, sh.l_slice(l), :] @ w[l].astype(x.dtype)
            for l in range(cfg.l_max + 1)]
    return jnp.concatenate(outs, axis=1)


def mp_layer_local(lp, f_slice: Array, src, dst, vec, cfg: GNNConfig,
                   *, data_axis, model_axis: str, N: int) -> Array:
    """Per-device body of one message-passing layer.

    f_slice: [N/nd, K, Cl] — this device's NODE range × channel slice.  The
    layer-boundary representation is doubly sharded so the remat'ed layer
    scan only snapshots N/nd-sized carries; the full node table is a
    per-layer transient (all-gathered here, recomputed in the backward).
    src/dst/vec: this data-shard's edge slice.
    Returns the updated f_slice (same layout).
    """
    K = cfg.k
    C = cfg.c
    H = cfg.n_heads
    Cl = f_slice.shape[-1]
    f_local = jax.lax.all_gather(f_slice, data_axis, axis=0, tiled=True)
    midx = jax.lax.axis_index(model_axis)
    c_lo = midx * Cl
    E_local = src.shape[0]
    chunk = min(cfg.edge_chunk, E_local)
    while E_local % chunk != 0:
        chunk -= 1
    nch = E_local // chunk
    resh = lambda x: x.reshape((nch, chunk) + x.shape[1:])
    xs = (resh(src), resh(dst), resh(vec))

    def edge_math(src_c, dst_c, vec_c):
        valid = src_c >= 0
        s_src = jnp.where(valid, src_c, 0)
        s_dst = jnp.where(valid, dst_c, 0)
        fs = f_local[s_src]                              # [e, K, Cl] local
        blocks = sh.wigner_blocks(cfg.l_max, vec_c)
        fr = sh.apply_blocks(blocks, fs)
        conv = so2_conv_sharded(fr, lp["so2"], cfg, model_axis)
        r = jnp.linalg.norm(vec_c, axis=-1)
        gate = jax.nn.silu(_rbf(r, cfg) @ lp["rad1"]) @ lp["rad2"]
        conv = conv * gnn._per_l_expand(gate, cfg.l_max)[..., None]
        inv = conv[:, 0, :]                              # full-C (post-psum)
        logits = jax.nn.silu(inv @ lp["wa1"]) @ lp["wa2"]
        logits = jnp.where(valid[:, None], logits, NEG)
        return valid, s_dst, blocks, conv, logits

    # ---- pass 1 (no gradients): global per-dst max of attention logits.
    # The max shift cancels between numerator and denominator, so its
    # gradient is exactly zero — a stop_gradient pass is exact and keeps the
    # backward free of per-chunk carry residuals.
    def max_fn(M, inp):
        valid, s_dst, _, _, logits = edge_math(*inp)
        return jnp.maximum(M, jax.ops.segment_max(logits, s_dst,
                                                  num_segments=N)), None

    M0 = jnp.full((N, H), NEG, jnp.float32)
    M, _ = jax.lax.scan(jax.checkpoint(max_fn), M0,
                        jax.lax.stop_gradient(xs))
    # M still carries a tangent via the f_local closure — sever it before
    # the collective (pmax has no differentiation rule; the shift's true
    # gradient is zero anyway).
    M_g = jax.lax.pmax(jax.lax.stop_gradient(M), data_axis)

    # ---- pass 2 (with gradients): accumulate the softmax numerator and
    # denominator.  A plain remat'ed scan would still snapshot its (num, Z)
    # carry every chunk (~4 GB × n_chunks), so the accumulation is a
    # custom_vjp whose backward re-walks the chunks, pulling the (d_num, d_Z)
    # cotangents through a per-chunk jax.vjp and summing into a single
    # [N, K, Cl]-sized d_f accumulator — the flash-attention backward
    # structure.  d_M_g is returned as zeros: M_g is a softmax shift whose
    # true gradient through the num/Z *ratio* is identically zero (and it is
    # produced under stop_gradient anyway).
    lp_edge = {k: lp[k] for k in
               ("so2", "rad1", "rad2", "wa1", "wa2", "w_out")}

    def chunk_contrib(f_loc, lpe, M_shift, c_lo_f, inp):
        c_lo_i = c_lo_f.astype(jnp.int32)
        src_c, dst_c, vec_c = inp
        valid = src_c >= 0
        s_src = jnp.where(valid, src_c, 0)
        s_dst = jnp.where(valid, dst_c, 0)
        fs = f_loc[s_src]
        blocks = sh.wigner_blocks(cfg.l_max, vec_c)
        fr = sh.apply_blocks(blocks, fs)
        conv = so2_conv_sharded(fr, lpe["so2"], cfg, model_axis)
        r = jnp.linalg.norm(vec_c, axis=-1)
        gate = jax.nn.silu(_rbf(r, cfg) @ lpe["rad1"]) @ lpe["rad2"]
        conv = conv * gnn._per_l_expand(gate, cfg.l_max)[..., None]
        logits = jax.nn.silu(conv[:, 0, :] @ lpe["wa1"]) @ lpe["wa2"]
        logits = jnp.where(valid[:, None], logits, NEG)
        mixed = _per_l_linear_full(conv, lpe["w_out"], cfg)
        msg = sh.apply_blocks(blocks, mixed, transpose=True)
        msg = jax.lax.dynamic_slice_in_dim(msg, c_lo_i, Cl, axis=2)
        msg = msg.reshape(-1, K, H, Cl // H)
        p = jnp.where(valid[:, None], jnp.exp(logits - M_shift[s_dst]), 0.0)
        num_c = jax.ops.segment_sum(
            (msg * p[:, None, :, None]).astype(jnp.float32), s_dst,
            num_segments=N)
        Z_c = jax.ops.segment_sum(p, s_dst, num_segments=N)
        return num_c, Z_c

    def _agg_fwd_scan(f_loc, lpe, M_shift, c_lo_f, xs):
        def step(carry, inp):
            num, Z = carry
            nc, zc = chunk_contrib(f_loc, lpe, M_shift, c_lo_f, inp)
            return (num + nc, Z + zc), None

        num0 = jnp.zeros((N, K, H, Cl // H), jnp.float32)
        Z0 = jnp.zeros((N, H), jnp.float32)
        (num, Z), _ = jax.lax.scan(step, (num0, Z0), xs)
        return num, Z

    @jax.custom_vjp
    def aggregate(f_loc, lpe, M_shift, c_lo_f, xs):
        return _agg_fwd_scan(f_loc, lpe, M_shift, c_lo_f, xs)

    def agg_fwd(f_loc, lpe, M_shift, c_lo_f, xs):
        return (_agg_fwd_scan(f_loc, lpe, M_shift, c_lo_f, xs),
                (f_loc, lpe, M_shift, c_lo_f, xs))

    def agg_bwd(res, cots):
        f_loc, lpe, M_shift, c_lo_f, xs_r = res

        def step(carry, inp):
            d_f, d_lpe = carry
            _, vjp_fn = jax.vjp(
                lambda ff, ll: chunk_contrib(ff, ll, M_shift, c_lo_f, inp),
                f_loc, lpe)
            df_c, dl_c = vjp_fn(cots)
            return (d_f + df_c,
                    jax.tree.map(jnp.add, d_lpe, dl_c)), None

        d_f0 = jnp.zeros_like(f_loc)
        d_lp0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                             lpe)
        (d_f, d_lpe), _ = jax.lax.scan(step, (d_f0, d_lp0), xs_r)
        d_xs = jax.tree.map(jnp.zeros_like, xs_r)   # positions are data
        return (d_f, d_lpe, jnp.zeros_like(M_shift), jnp.zeros_like(c_lo_f),
                d_xs)

    aggregate.defvjp(agg_fwd, agg_bwd)

    num, Z = aggregate(f_local, lp_edge, M_g,
                       (c_lo * 1.0).astype(jnp.float32), xs)
    Z_g = jax.lax.psum(Z, data_axis)
    num_g = jax.lax.psum(num, data_axis)
    out = (num_g / jnp.maximum(Z_g, 1e-30)[:, None, :, None]
           ).reshape(N, K, Cl).astype(f_local.dtype)

    f_new = f_local + out          # w_out already applied on the edge path

    # node update on this device's node range only (slice = layer carry)
    didx = _axis_linear_index(data_axis if isinstance(data_axis, tuple)
                              else (data_axis,))
    nd = _axis_size(data_axis)
    Nl = N // nd
    fr_ = jax.lax.dynamic_slice_in_dim(f_new, didx * Nl, Nl, axis=0)

    # equivariant LN: per-degree RMS over (m, FULL C) — partial + psum
    outs = []
    for l in range(cfg.l_max + 1):
        blk = fr_[:, sh.l_slice(l), :]
        ss = jnp.sum(blk.astype(jnp.float32) ** 2, axis=(1, 2))
        ss = jax.lax.psum(ss, model_axis)
        rms = jnp.sqrt(ss / ((2 * l + 1) * C) + 1e-6)
        scale_l = jax.lax.dynamic_slice_in_dim(lp["ln"][l], c_lo, Cl, axis=0)
        outs.append((blk / rms[:, None, None].astype(blk.dtype))
                    * scale_l.astype(blk.dtype))
    fr_ = jnp.concatenate(outs, axis=1)

    # gated nonlinearity: gates need full-C f0 — partial matmul + psum
    f0 = fr_[:, 0, :]
    w_gate = jax.lax.dynamic_slice_in_dim(lp["gate"], c_lo, Cl, axis=0)
    gates_full = jax.lax.psum(f0 @ w_gate, model_axis)   # [Nl, lm*C]
    gates = jax.nn.sigmoid(gates_full).reshape(Nl, cfg.l_max, C)
    gates = jax.lax.dynamic_slice_in_dim(gates, c_lo, Cl, axis=2)
    scal = jax.nn.silu(f0)
    rest = fr_[:, 1:, :] * gnn._per_l_expand_high(gates, cfg.l_max)
    return jnp.concatenate([scal[:, None, :], rest],
                           axis=1).astype(f_slice.dtype)


def forward_sharded(params, g: GraphBatch, cfg: GNNConfig, mesh: Mesh):
    """shard_map forward returning node features [N, K, C] (C sharded)."""
    data_ax = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    model_ax = "model"
    N = g.node_feat.shape[0]

    def body(params, node_feat, src, dst, vec):
        Cl = cfg.c // mesh.shape[model_ax]
        nd = _axis_size(data_ax)
        didx = _axis_linear_index(data_ax)
        midx = jax.lax.axis_index(model_ax)
        Nl = N // nd
        feat_slice = jax.lax.dynamic_slice_in_dim(node_feat, didx * Nl, Nl,
                                                  axis=0)
        emb = feat_slice.astype(jnp.float32) @ params["embed_in"]  # [Nl, C]
        emb = jax.lax.dynamic_slice_in_dim(emb, midx * Cl, Cl, axis=1)
        f = jnp.zeros((Nl, cfg.k, Cl), jnp.dtype(cfg.dtype))
        f = f.at[:, 0, :].set(emb.astype(f.dtype))

        def layer_fn(f, lp):
            return mp_layer_local(lp, f, src[0], dst[0], vec[0], cfg,
                                  data_axis=data_ax, model_axis=model_ax,
                                  N=N), None

        lf = jax.checkpoint(layer_fn) if cfg.remat else layer_fn
        f, _ = jax.lax.scan(lf, f, params["layers"])
        return f[None]

    pspecs = _param_pspecs(cfg)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(pspecs, P(), P(None, data_ax), P(None, data_ax),
                  P(None, data_ax, None)),
        out_specs=P(None, data_ax if isinstance(data_ax, str) else data_ax,
                    None, model_ax),
        check_rep=False)
    # edges get a leading singleton axis so shard_map splits dim 1 (= edges)
    f = fn(params, g.node_feat, g.edge_src[None], g.edge_dst[None],
           g.edge_vec[None])
    return f[0]


def _param_pspecs(cfg: GNNConfig):
    so2 = {"w0": P(None, "model", None)}
    for m in range(1, cfg.m_max + 1):
        so2[f"w{m}r"] = P(None, "model", None)
        so2[f"w{m}i"] = P(None, "model", None)
    layers = {"so2": so2, "rad1": P(), "rad2": P(), "wa1": P(), "wa2": P(),
              "w_out": P(), "gate": P(), "ln": P()}
    return {"embed_in": P(), "layers": layers, "ro1": P(), "ro2": P(),
            "force_w": P()}


def param_shardings(cfg: GNNConfig, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        _param_pspecs(cfg), is_leaf=lambda x: isinstance(x, P))


def loss_fn_sharded(params, g: GraphBatch, cfg: GNNConfig, mesh: Mesh):
    f = forward_sharded(params, g, cfg, mesh)
    inv = f[:, 0, :].astype(jnp.float32)          # [N, C] (C sharded)
    h = jax.nn.silu(inv @ params["ro1"])
    out = h @ params["ro2"]
    if cfg.task == "energy_force":
        energy = jax.ops.segment_sum(out[:, 0], g.graph_id,
                                     num_segments=g.n_graphs)
        forces = (f[:, 1:4, :].astype(jnp.float32)
                  @ params["force_w"])[..., 0]
        le = jnp.mean((energy - g.labels.astype(jnp.float32)) ** 2)
        lf = jnp.mean((forces - g.forces) ** 2)
        return le + 10.0 * lf, {"energy_mse": le}
    valid = g.labels >= 0
    labels = jnp.where(valid, g.labels, 0)
    lse = jax.nn.logsumexp(out, axis=-1)
    gold = jnp.take_along_axis(out, labels[:, None], axis=-1)[:, 0]
    xent = jnp.sum(jnp.where(valid, lse - gold, 0.0)) / jnp.maximum(
        valid.sum(), 1)
    return xent, {"xent": xent}
