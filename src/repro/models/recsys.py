"""RecSys architectures: dlrm-rm2, din, sasrec, mind.

Shared substrate: stacked hashed embedding tables with an EmbeddingBag built
from ``jnp.take`` + masked reduction (JAX has no native EmbeddingBag — the
Pallas variant lives in repro.kernels.embed_bag; this jnp path is the
differentiable reference the tables train through).

Every model exposes:
    init_params / abstract_params / logical_axes
    loss(params, batch)                      — training objective
    score(params, batch)                     — pointwise serving (CTR / next-item)
    user_repr(params, batch) / item_embeddings(params)
                                             — the MIPS retrieval factorisation
The ``retrieval_cand`` shape is exactly the paper's MIPS problem: a batched
dot of user_repr against the candidate table (dense path), or the Sinnamon
engine over sparsified item vectors (see examples/recsys_retrieval.py).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import rules as R
from repro.distributed.rules import L

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    model: str                    # dlrm | din | sasrec | mind
    embed_dim: int = 64
    n_items: int = 1_000_000      # item vocabulary (retrieval candidates)
    # dlrm
    n_dense: int = 13
    n_sparse: int = 26
    vocab_per_field: int = 1_000_000
    multi_hot: int = 4            # lookups per sparse field (embedding bag)
    bot_mlp: tuple = (512, 256, 64)
    top_mlp: tuple = (512, 512, 256, 1)
    # din
    seq_len: int = 100
    attn_mlp: tuple = (80, 40)
    mlp: tuple = (200, 80)
    # sasrec
    n_blocks: int = 2
    n_heads: int = 1
    # mind
    n_interests: int = 4
    capsule_iters: int = 3
    dtype: str = "float32"


class RecsysBatch(NamedTuple):
    dense: Array      # f32[B, n_dense]            (dlrm; zeros otherwise)
    sparse: Array     # int32[B, n_sparse, hot]    (dlrm; pad = -1)
    hist: Array       # int32[B, seq_len]          (din/sasrec/mind; pad = -1)
    target: Array     # int32[B]                   target item
    labels: Array     # f32[B]                     click labels


def batch_logical_axes() -> RecsysBatch:
    return RecsysBatch(dense=L("batch", None), sparse=L("batch", None, None),
                       hist=L("batch", None), target=L("batch"),
                       labels=L("batch"))


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------

def _mlp_params(key, dims, dtype):
    ks = jax.random.split(key, len(dims) - 1)
    out = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        out[f"w{i}"] = (jax.random.normal(ks[i], (a, b), jnp.float32)
                        / math.sqrt(a)).astype(dtype)
        out[f"b{i}"] = jnp.zeros((b,), dtype)
    return out


def _mlp_axes(dims):
    out = {}
    for i in range(len(dims) - 1):
        out[f"w{i}"] = L(None, None)
        out[f"b{i}"] = L(None)
    return out


def _mlp(p, x, n, act=jax.nn.relu, final_act=False):
    for i in range(n):
        x = x @ p[f"w{i}"].astype(x.dtype) + p[f"b{i}"].astype(x.dtype)
        if i < n - 1 or final_act:
            x = act(x)
    return x


def embedding_bag(table: Array, idx: Array, mode: str = "sum") -> Array:
    """[..., hot] indices (pad=-1) into [V, D] table → [..., D]."""
    valid = idx >= 0
    rows = jnp.take(table, jnp.where(valid, idx, 0), axis=0)
    rows = jnp.where(valid[..., None], rows, 0)
    out = rows.sum(axis=-2)
    if mode == "mean":
        out = out / jnp.maximum(valid.sum(-1, keepdims=True), 1)
    return out


def _bce(logit: Array, label: Array) -> Array:
    return jnp.mean(jnp.maximum(logit, 0) - logit * label
                    + jnp.log1p(jnp.exp(-jnp.abs(logit))))


# ---------------------------------------------------------------------------
# DLRM (arXiv:1906.00091) — rm2 config
# ---------------------------------------------------------------------------

def _dlrm_init(key, cfg, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    D = cfg.embed_dim
    tables = (jax.random.normal(
        k1, (cfg.n_sparse, cfg.vocab_per_field, D), jnp.float32)
        / math.sqrt(D)).astype(dtype)
    bot_dims = (cfg.n_dense,) + cfg.bot_mlp
    n_f = cfg.n_sparse + 1
    top_in = cfg.bot_mlp[-1] + n_f * (n_f - 1) // 2
    top_dims = (top_in,) + cfg.top_mlp
    return {"tables": tables,
            "bot": _mlp_params(k2, bot_dims, dtype),
            "top": _mlp_params(k3, top_dims, dtype)}


def _dlrm_axes(cfg):
    return {"tables": L("fields", "table_rows", None),
            "bot": _mlp_axes((cfg.n_dense,) + cfg.bot_mlp),
            "top": _mlp_axes((0,) + cfg.top_mlp)}


def _dlrm_features(p, batch, cfg, mesh=None, rules=None):
    B = batch.dense.shape[0]
    x0 = _mlp(p["bot"], batch.dense.astype(p["tables"].dtype),
              len(cfg.bot_mlp), final_act=True)                 # [B, D]
    lookup = jax.vmap(embedding_bag, in_axes=(0, 1), out_axes=1)
    emb = lookup(p["tables"], batch.sparse)                     # [B, F, D]
    if mesh is not None:
        emb = R.constrain(emb, mesh, ("batch", None, None), rules)
    return x0, emb


def _dlrm_score(p, batch, cfg, mesh=None, rules=None):
    x0, emb = _dlrm_features(p, batch, cfg, mesh, rules)
    vecs = jnp.concatenate([x0[:, None, :], emb], axis=1)       # [B, F+1, D]
    gram = jnp.einsum("bfd,bgd->bfg", vecs, vecs)
    iu, ju = np.triu_indices(vecs.shape[1], k=1)
    inter = gram[:, jnp.asarray(iu), jnp.asarray(ju)]           # [B, F(F+1)/2]
    top_in = jnp.concatenate([x0, inter], axis=-1)
    return _mlp(p["top"], top_in, len(cfg.top_mlp))[:, 0]


# ---------------------------------------------------------------------------
# DIN (arXiv:1706.06978)
# ---------------------------------------------------------------------------

def _din_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    D = cfg.embed_dim
    table = (jax.random.normal(k1, (cfg.n_items, D), jnp.float32)
             / math.sqrt(D)).astype(dtype)
    attn_dims = (4 * D,) + cfg.attn_mlp + (1,)
    mlp_dims = (2 * D,) + cfg.mlp + (1,)
    return {"table": table,
            "attn": _mlp_params(k2, attn_dims, dtype),
            "mlp": _mlp_params(k3, mlp_dims, dtype)}


def _din_axes(cfg):
    return {"table": L("table_rows", None),
            "attn": _mlp_axes((0,) + cfg.attn_mlp + (1,)),
            "mlp": _mlp_axes((0,) + cfg.mlp + (1,))}


def _din_user(p, batch, cfg):
    """Target-attention pooled user interest vector."""
    valid = batch.hist >= 0
    eh = jnp.take(p["table"], jnp.where(valid, batch.hist, 0), axis=0)
    eh = jnp.where(valid[..., None], eh, 0)                     # [B, S, D]
    et = jnp.take(p["table"], batch.target, axis=0)             # [B, D]
    etb = jnp.broadcast_to(et[:, None, :], eh.shape)
    a_in = jnp.concatenate([eh, etb, eh * etb, eh - etb], axis=-1)
    logits = _mlp(p["attn"], a_in, len(cfg.attn_mlp) + 1)[..., 0]
    logits = jnp.where(valid, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bs,bsd->bd", w, eh), et


def _din_score(p, batch, cfg, mesh=None, rules=None):
    u, et = _din_user(p, batch, cfg)
    x = jnp.concatenate([u, et], axis=-1)
    return _mlp(p["mlp"], x, len(cfg.mlp) + 1)[:, 0]


# ---------------------------------------------------------------------------
# SASRec (arXiv:1808.09781)
# ---------------------------------------------------------------------------

def _sasrec_init(key, cfg, dtype):
    ks = jax.random.split(key, 4 + cfg.n_blocks)
    D = cfg.embed_dim
    table = (jax.random.normal(ks[0], (cfg.n_items, D), jnp.float32)
             / math.sqrt(D)).astype(dtype)
    pos = (jax.random.normal(ks[1], (cfg.seq_len, D), jnp.float32)
           * 0.02).astype(dtype)
    blocks = []
    for b in range(cfg.n_blocks):
        kb = jax.random.split(ks[2 + b], 6)
        s = 1 / math.sqrt(D)
        blocks.append({
            "wq": (jax.random.normal(kb[0], (D, D)) * s).astype(dtype),
            "wk": (jax.random.normal(kb[1], (D, D)) * s).astype(dtype),
            "wv": (jax.random.normal(kb[2], (D, D)) * s).astype(dtype),
            "ln1": jnp.ones((D,), dtype), "ln2": jnp.ones((D,), dtype),
            "f1": (jax.random.normal(kb[3], (D, D)) * s).astype(dtype),
            "f2": (jax.random.normal(kb[4], (D, D)) * s).astype(dtype),
        })
    blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    return {"table": table, "pos": pos, "blocks": blocks,
            "ln_f": jnp.ones((D,), dtype)}


def _sasrec_axes(cfg):
    blk = {"wq": L(None, None, None), "wk": L(None, None, None),
           "wv": L(None, None, None), "ln1": L(None, None),
           "ln2": L(None, None), "f1": L(None, None, None),
           "f2": L(None, None, None)}
    return {"table": L("table_rows", None), "pos": L(None, None),
            "blocks": blk, "ln_f": L(None)}


def _ln(x, s, eps=1e-6):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * s


def _sasrec_hidden(p, hist, cfg):
    valid = hist >= 0
    x = jnp.take(p["table"], jnp.where(valid, hist, 0), axis=0)
    x = jnp.where(valid[..., None], x, 0) + p["pos"][None]
    S = hist.shape[1]
    causal = jnp.tril(jnp.ones((S, S), bool))

    def block(x, bp):
        h = _ln(x, bp["ln1"])
        q, k, v = h @ bp["wq"], h @ bp["wk"], h @ bp["wv"]
        s = jnp.einsum("bqd,bkd->bqk", q, k) / math.sqrt(q.shape[-1])
        s = jnp.where(causal[None] & valid[:, None, :], s, -1e30)
        x = x + jnp.einsum("bqk,bkd->bqd", jax.nn.softmax(s, axis=-1), v)
        h = _ln(x, bp["ln2"])
        x = x + jax.nn.relu(h @ bp["f1"]) @ bp["f2"]
        return x, None

    x, _ = jax.lax.scan(block, x, p["blocks"])
    return _ln(x, p["ln_f"]) * valid[..., None]


def _sasrec_user(p, batch, cfg):
    return _sasrec_hidden(p, batch.hist, cfg)[:, -1, :]


def _sasrec_loss(p, batch, cfg, key=None, mesh=None, rules=None):
    """Next-item BCE with one uniform negative per position (the paper's)."""
    hist = batch.hist
    # positions 0..S-2 predict items at 1..S-1 (teacher forcing)
    h = _sasrec_hidden(p, hist, cfg)[:, :-1, :]
    pos_items = hist[:, 1:]
    valid = pos_items >= 0
    pe = jnp.take(p["table"], jnp.where(valid, pos_items, 0), axis=0)
    neg_items = ((pos_items.astype(jnp.uint32) * jnp.uint32(2654435761)
                  + jnp.uint32(12345)) % jnp.uint32(cfg.n_items)
                 ).astype(jnp.int32)
    ne = jnp.take(p["table"], neg_items, axis=0)
    lp = jnp.einsum("bsd,bsd->bs", h, pe)
    ln_ = jnp.einsum("bsd,bsd->bs", h, ne)
    per = (jnp.log1p(jnp.exp(-lp)) + jnp.log1p(jnp.exp(ln_)))
    return jnp.sum(jnp.where(valid, per, 0)) / jnp.maximum(valid.sum(), 1)


# ---------------------------------------------------------------------------
# MIND (arXiv:1904.08030) — multi-interest dynamic routing
# ---------------------------------------------------------------------------

def _mind_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    D = cfg.embed_dim
    table = (jax.random.normal(k1, (cfg.n_items, D), jnp.float32)
             / math.sqrt(D)).astype(dtype)
    bilinear = (jax.random.normal(k2, (D, D), jnp.float32)
                / math.sqrt(D)).astype(dtype)
    binit = (jax.random.normal(k3, (cfg.n_interests, cfg.seq_len),
                               jnp.float32)).astype(dtype)
    return {"table": table, "bilinear": bilinear, "b_init": binit}


def _mind_axes(cfg):
    return {"table": L("table_rows", None), "bilinear": L(None, None),
            "b_init": L(None, None)}


def _squash(x, axis=-1):
    n2 = jnp.sum(x * x, axis=axis, keepdims=True)
    return (n2 / (1 + n2)) * x / jnp.sqrt(n2 + 1e-9)


def _mind_interests(p, hist, cfg):
    """B2I dynamic routing → [B, K, D] interest capsules."""
    valid = hist >= 0
    e = jnp.take(p["table"], jnp.where(valid, hist, 0), axis=0)
    e = jnp.where(valid[..., None], e, 0)                    # [B, S, D]
    el = e @ p["bilinear"]                                   # shared S matrix
    b = jnp.broadcast_to(p["b_init"][None], (e.shape[0],) + p["b_init"].shape)
    caps = None
    for _ in range(cfg.capsule_iters):
        w = jax.nn.softmax(b, axis=1)                        # over K interests
        w = jnp.where(valid[:, None, :], w, 0)
        caps = _squash(jnp.einsum("bks,bsd->bkd", w, el))
        b = b + jnp.einsum("bkd,bsd->bks", caps, el)
    return caps                                               # [B, K, D]


def _mind_loss(p, batch, cfg, key=None, mesh=None, rules=None):
    """Label-aware attention + sampled softmax against uniform negatives."""
    caps = _mind_interests(p, batch.hist, cfg)               # [B, K, D]
    et = jnp.take(p["table"], batch.target, axis=0)          # [B, D]
    att = jax.nn.softmax(jnp.einsum("bkd,bd->bk", caps, et) * 2.0, axis=-1)
    u = jnp.einsum("bk,bkd->bd", att, caps)
    n_neg = 64
    neg = (((batch.target[:, None].astype(jnp.uint32) + jnp.uint32(1))
            * jnp.arange(1, n_neg + 1, dtype=jnp.uint32)
            * jnp.uint32(2654435761)) % jnp.uint32(cfg.n_items)
           ).astype(jnp.int32)                               # [B, n_neg]
    en = jnp.take(p["table"], neg, axis=0)                   # [B, n_neg, D]
    lp = jnp.einsum("bd,bd->b", u, et)
    ln_ = jnp.einsum("bd,bnd->bn", u, en)
    logits = jnp.concatenate([lp[:, None], ln_], axis=1)
    return jnp.mean(jax.nn.logsumexp(logits, -1) - lp)


def _mind_user(p, batch, cfg):
    """Serving: strongest interest per user (retrieval uses max over K)."""
    caps = _mind_interests(p, batch.hist, cfg)
    norms = jnp.linalg.norm(caps, axis=-1)
    best = jnp.argmax(norms, axis=-1)
    return jnp.take_along_axis(caps, best[:, None, None], axis=1)[:, 0]


# ---------------------------------------------------------------------------
# Dispatch table
# ---------------------------------------------------------------------------

def init_params(key, cfg: RecsysConfig, dtype=None):
    dtype = jnp.dtype(dtype or cfg.dtype)
    return {"dlrm": _dlrm_init, "din": _din_init,
            "sasrec": _sasrec_init, "mind": _mind_init}[cfg.model](
        key, cfg, dtype)


def abstract_params(cfg: RecsysConfig, dtype=None):
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg,
                                              dtype))


def logical_axes(cfg: RecsysConfig):
    return {"dlrm": _dlrm_axes, "din": _din_axes,
            "sasrec": _sasrec_axes, "mind": _mind_axes}[cfg.model](cfg)


def score(params, batch: RecsysBatch, cfg: RecsysConfig, mesh=None,
          rules=None) -> Array:
    """Pointwise serving logit [B] (CTR for dlrm/din; u·target for seq models)."""
    if cfg.model == "dlrm":
        return _dlrm_score(params, batch, cfg, mesh, rules)
    if cfg.model == "din":
        return _din_score(params, batch, cfg, mesh, rules)
    u = user_repr(params, batch, cfg)
    et = jnp.take(item_embeddings(params, cfg), batch.target, axis=0)
    return jnp.einsum("bd,bd->b", u, et)


def loss(params, batch: RecsysBatch, cfg: RecsysConfig, mesh=None,
         rules=None) -> Array:
    if cfg.model in ("dlrm", "din"):
        return _bce(score(params, batch, cfg, mesh, rules), batch.labels)
    if cfg.model == "sasrec":
        return _sasrec_loss(params, batch, cfg, mesh=mesh, rules=rules)
    return _mind_loss(params, batch, cfg, mesh=mesh, rules=rules)


def user_repr(params, batch: RecsysBatch, cfg: RecsysConfig) -> Array:
    """[B, D] MIPS query vector for retrieval."""
    if cfg.model == "dlrm":
        x0, emb = _dlrm_features(params, batch, cfg)
        return x0 + emb.mean(axis=1)          # two-tower factorisation
    if cfg.model == "din":
        valid = batch.hist >= 0
        eh = jnp.take(params["table"], jnp.where(valid, batch.hist, 0), axis=0)
        return jnp.where(valid[..., None], eh, 0).sum(1) / jnp.maximum(
            valid.sum(-1, keepdims=True), 1)
    if cfg.model == "sasrec":
        return _sasrec_user(params, batch, cfg)
    return _mind_user(params, batch, cfg)


def item_embeddings(params, cfg: RecsysConfig) -> Array:
    """[n_items, D] retrieval candidate matrix."""
    if cfg.model == "dlrm":
        return params["tables"][0, : cfg.n_items]
    return params["table"][: cfg.n_items]


def retrieval_scores(params, batch: RecsysBatch, cfg: RecsysConfig,
                     mesh=None, rules=None) -> Array:
    """retrieval_cand shape: score users against the full candidate set.

    Batched dot — the dense-MIPS path ([B, D] @ [D, n_items]); the sparse
    Sinnamon path lives in examples/recsys_retrieval.py.
    """
    u = user_repr(params, batch, cfg)
    items = item_embeddings(params, cfg)
    if mesh is not None:
        items = R.constrain(items, mesh, ("candidates", None), rules)
    s = jnp.einsum("bd,nd->bn", u, items)
    if mesh is not None:
        s = R.constrain(s, mesh, ("batch", "candidates"), rules)
    return s
