"""EquiformerV2 (arXiv:2306.12059): equivariant graph attention via eSCN
SO(2) convolutions, adapted TPU-native.

Key adaptations (DESIGN.md §2/§5):
  * Message passing is **edge-chunked** (lax.scan over fixed-size edge blocks)
    with a **streaming segment-softmax** — flash-attention-style running
    (max, denom, numerator) per destination node — so the 61.8M-edge
    ogb_products cell never materialises per-edge features for the whole
    graph at once.
  * Per-edge Wigner matrices come from the closed-form z-y-z factorisation in
    `repro.models.sh` (two small dense matmuls per degree — O(L³), the eSCN
    speedup — instead of O(L⁶) Clebsch-Gordan contractions).
  * Scatter/gather is `jax.ops.segment_*` over edge index lists (JAX-native
    message passing; no sparse formats needed).

Feature layout: [N, (l_max+1)², C] real spherical-harmonic coefficients,
degree-l block at rows l²..(l+1)², orders m = −l..l.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import rules as R
from repro.distributed.rules import L
from repro.models import sh

Array = jax.Array
NEG = -2.0 ** 30


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str = "equiformer-v2"
    n_layers: int = 12
    c: int = 128                 # hidden channels (d_hidden)
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    n_rbf: int = 32
    cutoff: float = 5.0
    f_in: int = 100              # invariant input features
    n_out: int = 1               # classes (task=node_class) or 1 (energy)
    task: str = "node_class"     # node_class | energy_force
    edge_chunk: int = 8192
    dtype: str = "float32"
    remat: bool = True

    @property
    def k(self) -> int:
        return sh.num_coef(self.l_max)


class GraphBatch(NamedTuple):
    node_feat: Array     # f32[N, F]
    edge_src: Array      # int32[E]  (pad = -1)
    edge_dst: Array      # int32[E]  (pad = -1)
    edge_vec: Array      # f32[E, 3] relative position of src w.r.t. dst
    labels: Array        # int32[N] (node_class) / f32[G] energies
    forces: Array        # f32[N, 3] (energy_force) or zeros
    graph_id: Array      # int32[N]  molecule id for batched small graphs
    n_graphs: int = 1


def graph_logical_axes() -> GraphBatch:
    return GraphBatch(
        node_feat=L("nodes", None),      # "nodes" rule = replicated
        edge_src=L("edges"), edge_dst=L("edges"),
        edge_vec=L("edges", None),
        labels=L("nodes"), forces=L("nodes", None),
        graph_id=L("nodes"), n_graphs=None,
    )


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def _m_indices(l_max: int, m: int) -> np.ndarray:
    """Coefficient rows of order +m (or −m if m<0) for degrees l ≥ |m|."""
    return np.array([l * l + l + m for l in range(abs(m), l_max + 1)], np.int32)


def init_params(key: Array, cfg: GNNConfig, dtype=jnp.float32) -> Dict[str, Any]:
    ks = jax.random.split(key, 16)
    Lr, C, lm = cfg.n_layers, cfg.c, cfg.l_max
    n0 = lm + 1

    def nrm(k, shape, fan_in):
        return (jax.random.normal(k, shape, jnp.float32)
                / math.sqrt(fan_in)).astype(dtype)

    so2 = {"w0": nrm(ks[0], (Lr, n0 * C, n0 * C), n0 * C)}
    for m in range(1, cfg.m_max + 1):
        nm = lm + 1 - m
        so2[f"w{m}r"] = nrm(ks[m], (Lr, nm * C, nm * C), nm * C)
        so2[f"w{m}i"] = nrm(ks[m + 4], (Lr, nm * C, nm * C), nm * C)
    layers = {
        "so2": so2,
        "rad1": nrm(ks[8], (Lr, cfg.n_rbf, C), cfg.n_rbf),
        "rad2": nrm(ks[9], (Lr, C, n0), C),
        "wa1": nrm(ks[10], (Lr, C, C), C),
        "wa2": nrm(ks[11], (Lr, C, cfg.n_heads), C),
        "w_out": nrm(ks[12], (Lr, n0, C, C), C),
        "gate": nrm(ks[13], (Lr, C, (lm) * C), C),
        "ln": jnp.ones((Lr, n0, C), dtype),
    }
    return {
        "embed_in": nrm(ks[14], (cfg.f_in, C), cfg.f_in),
        "layers": layers,
        "ro1": nrm(ks[15], (C, C), C),
        "ro2": nrm(ks[7], (C, cfg.n_out), C),
        "force_w": nrm(ks[6], (C, 1), C),
    }


def abstract_params(cfg: GNNConfig, dtype=jnp.float32):
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg, dtype))


def logical_axes(cfg: GNNConfig) -> Dict[str, Any]:
    so2 = {"w0": L(None, None, "mlp")}
    for m in range(1, cfg.m_max + 1):
        so2[f"w{m}r"] = L(None, None, "mlp")
        so2[f"w{m}i"] = L(None, None, "mlp")
    layers = {
        "so2": so2,
        "rad1": L(None, None, None), "rad2": L(None, None, None),
        "wa1": L(None, None, None), "wa2": L(None, None, None),
        "w_out": L(None, None, None, "mlp"),
        "gate": L(None, None, "mlp"),
        "ln": L(None, None, None),
    }
    return {"embed_in": L(None, None), "layers": layers,
            "ro1": L(None, None), "ro2": L(None, None),
            "force_w": L(None, None)}


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _flat_cmajor(x: Array) -> Array:
    """[e, n_l, C] -> [e, C*n_l] with (channel-major, degree-minor) rows.

    This layout makes a contiguous shard of the flattened axis equal a
    channel slice × all degrees — which is exactly what the row-sharded
    weights of the explicit-shard_map path need (models/gnn_sharded.py).
    """
    e, nl, C = x.shape
    return jnp.swapaxes(x, 1, 2).reshape(e, C * nl)


def _unflat_cmajor(x: Array, nl: int) -> Array:
    e = x.shape[0]
    return jnp.swapaxes(x.reshape(e, -1, nl), 1, 2)    # [e, nl, C]


def so2_conv(fr: Array, lp_so2: Dict[str, Array], cfg: GNNConfig) -> Array:
    """eSCN SO(2) linear layer in the edge-aligned frame.  fr: [e, K, C]."""
    e, K, C = fr.shape
    lm = cfg.l_max
    out = jnp.zeros_like(fr)
    # m = 0
    i0 = jnp.asarray(_m_indices(lm, 0))
    f0 = _flat_cmajor(fr[:, i0, :])
    o0 = _unflat_cmajor(f0 @ lp_so2["w0"].astype(fr.dtype), lm + 1)
    out = out.at[:, i0, :].set(o0)
    # m = 1..m_max: rotation-equivariant 2×2 complex-style mixing
    for m in range(1, cfg.m_max + 1):
        ip = jnp.asarray(_m_indices(lm, m))
        im = jnp.asarray(_m_indices(lm, -m))
        cm = _flat_cmajor(fr[:, ip, :])
        sm = _flat_cmajor(fr[:, im, :])
        wr = lp_so2[f"w{m}r"].astype(fr.dtype)
        wi = lp_so2[f"w{m}i"].astype(fr.dtype)
        nm = lm + 1 - m
        out = out.at[:, ip, :].set(_unflat_cmajor(cm @ wr - sm @ wi, nm))
        out = out.at[:, im, :].set(_unflat_cmajor(cm @ wi + sm @ wr, nm))
    # orders |m| > m_max stay zero (eSCN truncation)
    return out


def _rbf(r: Array, cfg: GNNConfig) -> Array:
    mu = jnp.linspace(0.0, cfg.cutoff, cfg.n_rbf)
    sig = cfg.cutoff / cfg.n_rbf
    return jnp.exp(-((r[..., None] - mu) / sig) ** 2)


def _per_l_expand(per_l: Array, l_max: int) -> Array:
    """[..., l_max+1] per-degree values → [..., (l_max+1)²] per-coefficient."""
    reps = np.repeat(np.arange(l_max + 1), [2 * l + 1 for l in range(l_max + 1)])
    return per_l[..., jnp.asarray(reps)]


def mp_layer(lp, f: Array, g: GraphBatch, cfg: GNNConfig,
             mesh=None, rules=None) -> Array:
    """One message-passing block with streaming segment softmax."""
    N, K, C = f.shape
    H = cfg.n_heads
    Ch = C // H
    E = g.edge_src.shape[0]
    chunk = min(cfg.edge_chunk, E)
    while E % chunk != 0:
        chunk -= 1
    nch = E // chunk

    resh = lambda x: x.reshape((nch, chunk) + x.shape[1:])
    xs = (resh(g.edge_src), resh(g.edge_dst), resh(g.edge_vec))

    def chunk_fn(carry, inp):
        M, Z, acc = carry
        src, dst, vec = inp
        valid = src >= 0
        s_src = jnp.where(valid, src, 0)
        s_dst = jnp.where(valid, dst, 0)
        fs = f[s_src]                                         # [e, K, C]
        if mesh is not None:
            fs = R.constrain(fs, mesh, ("edges", None, "gnn_c"), rules)
        blocks = sh.wigner_blocks(cfg.l_max, vec)
        fr = sh.apply_blocks(blocks, fs)
        conv = so2_conv(fr, lp["so2"], cfg)                   # [e, K, C]
        r = jnp.linalg.norm(vec, axis=-1)
        gate = jax.nn.silu(_rbf(r, cfg) @ lp["rad1"]) @ lp["rad2"]  # [e, l+1]
        conv = conv * _per_l_expand(gate, cfg.l_max)[..., None]
        inv = conv[:, 0, :]                                   # [e, C] (l=0)
        logits = jax.nn.silu(inv @ lp["wa1"]) @ lp["wa2"]     # [e, H]
        logits = jnp.where(valid[:, None], logits, NEG)
        msg = sh.apply_blocks(blocks, conv, transpose=True)   # back to global
        msg = msg.reshape(-1, K, H, Ch)

        mloc = jax.ops.segment_max(logits, s_dst, num_segments=N)
        M_new = jnp.maximum(M, mloc)
        scale = jnp.exp(jnp.minimum(M - M_new, 0.0))
        p = jnp.where(valid[:, None],
                      jnp.exp(logits - M_new[s_dst]), 0.0)    # [e, H]
        Z = Z * scale + jax.ops.segment_sum(p, s_dst, num_segments=N)
        acc = (acc * scale[:, None, :, None]
               + jax.ops.segment_sum(msg * p[:, None, :, None], s_dst,
                                     num_segments=N))
        if mesh is not None:
            # node accumulators: node axis replicated, channels model-sharded
            acc = R.constrain(acc, mesh, (None, None, None, "gnn_c"), rules)
        return (M_new, Z, acc), None

    M0 = jnp.full((N, H), NEG, jnp.float32)
    Z0 = jnp.zeros((N, H), jnp.float32)
    A0 = jnp.zeros((N, K, H, Ch), f.dtype)
    body = jax.checkpoint(chunk_fn) if cfg.remat else chunk_fn
    (M, Z, acc), _ = jax.lax.scan(body, (M0, Z0, A0), xs)
    out = (acc / jnp.maximum(Z, 1e-30)[:, None, :, None]).reshape(N, K, C)

    # per-degree output mixing + residual
    f = f + _per_l_linear(out, lp["w_out"], cfg)

    # equivariant layer norm (per-degree RMS) + gated nonlinearity
    f = _equivariant_ln(f, lp["ln"], cfg)
    gates = jax.nn.sigmoid(f[:, 0, :] @ lp["gate"])           # [N, lm*C]
    gates = gates.reshape(N, cfg.l_max, C)
    scal = jax.nn.silu(f[:, 0, :])
    rest = f[:, 1:, :] * _per_l_expand_high(gates, cfg.l_max)
    f = jnp.concatenate([scal[:, None, :], rest], axis=1)
    if mesh is not None:
        f = R.constrain(f, mesh, (None, None, "gnn_c"), rules)
    return f


def _per_l_linear(x: Array, w: Array, cfg: GNNConfig) -> Array:
    outs = [x[:, sh.l_slice(l), :] @ w[l].astype(x.dtype)
            for l in range(cfg.l_max + 1)]
    return jnp.concatenate(outs, axis=1)


def _per_l_expand_high(gates: Array, l_max: int) -> Array:
    """[N, l_max, C] per-degree gates → [N, (l_max+1)²−1, C] (degrees ≥ 1)."""
    reps = np.repeat(np.arange(l_max), [2 * (l + 1) + 1 for l in range(l_max)])
    return gates[:, jnp.asarray(reps), :]


def _equivariant_ln(f: Array, scales: Array, cfg: GNNConfig) -> Array:
    outs = []
    for l in range(cfg.l_max + 1):
        blk = f[:, sh.l_slice(l), :]
        rms = jnp.sqrt(jnp.mean(blk.astype(jnp.float32) ** 2,
                                axis=(1, 2), keepdims=True) + 1e-6)
        outs.append((blk / rms.astype(blk.dtype))
                    * scales[l].astype(blk.dtype))
    return jnp.concatenate(outs, axis=1)


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

def forward(params, g: GraphBatch, cfg: GNNConfig, mesh=None, rules=None):
    N = g.node_feat.shape[0]
    f = jnp.zeros((N, cfg.k, cfg.c), jnp.dtype(cfg.dtype))
    f = f.at[:, 0, :].set(
        (g.node_feat.astype(jnp.float32) @ params["embed_in"]
         ).astype(f.dtype))
    if mesh is not None:
        f = R.constrain(f, mesh, (None, None, "gnn_c"), rules)

    def layer_fn(f, lp):
        return mp_layer(lp, f, g, cfg, mesh, rules), None

    body = jax.checkpoint(layer_fn) if cfg.remat else layer_fn
    f, _ = jax.lax.scan(body, f, params["layers"])
    return f


def predict(params, g: GraphBatch, cfg: GNNConfig, mesh=None, rules=None):
    f = forward(params, g, cfg, mesh, rules)
    inv = f[:, 0, :].astype(jnp.float32)
    h = jax.nn.silu(inv @ params["ro1"])
    out = h @ params["ro2"]                                    # [N, n_out]
    if cfg.task == "energy_force":
        energy = jax.ops.segment_sum(out[:, 0], g.graph_id,
                                     num_segments=g.n_graphs)
        forces = (f[:, 1:4, :].astype(jnp.float32)
                  @ params["force_w"])[..., 0]                 # [N, 3]
        return energy, forces
    return out                                                 # node logits


def loss_fn(params, g: GraphBatch, cfg: GNNConfig, mesh=None, rules=None):
    if cfg.task == "energy_force":
        energy, forces = predict(params, g, cfg, mesh, rules)
        le = jnp.mean((energy - g.labels.astype(jnp.float32)) ** 2)
        lf = jnp.mean((forces - g.forces) ** 2)
        return le + 10.0 * lf, {"energy_mse": le, "force_mse": lf}
    logits = predict(params, g, cfg, mesh, rules)
    valid = g.labels >= 0
    labels = jnp.where(valid, g.labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    xent = jnp.sum(jnp.where(valid, lse - gold, 0.0)) / jnp.maximum(
        valid.sum(), 1)
    return xent, {"xent": xent}
