"""Shared transformer layers: RMSNorm, RoPE, blockwise (flash-style)
attention with GQA + sliding-window support, SwiGLU MLP, and a GShard-style
top-k MoE layer with capacity-based dispatch (EP-shardable).

Everything is pure-functional jnp over explicit parameter pytrees; sharding
is expressed through `repro.distributed.rules.constrain` calls that no-op on
single-device meshes.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed import rules as R

Array = jax.Array

NEG_BIG = -2.0 ** 30  # finite mask sentinel (NaN-safe running-max math)


# ---------------------------------------------------------------------------
# Norms & positional encoding
# ---------------------------------------------------------------------------

def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def rope(x: Array, positions: Array, theta: float = 10_000.0) -> Array:
    """Rotary embedding.  x: [..., S, H, D] (D even), positions: [..., S]."""
    d = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, d // 2, dtype=jnp.float32) / (d // 2))
    ang = positions[..., :, None].astype(jnp.float32) * freqs     # [..., S, D/2]
    cos = jnp.cos(ang)[..., :, None, :]                           # [..., S, 1, D/2]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise attention (jnp flash-attention: O(q_chunk·kv_chunk) score memory)
# ---------------------------------------------------------------------------

def _repeat_kv(x: Array, H: int) -> Array:
    """[B, S, KV, D] -> [B, S, H, D] by broadcasting each KV head G times.

    Keeping the head axis *flat* (H = KV·G) lets GSPMD shard it over 'model'
    even when KV alone doesn't divide the axis size — the broadcast is free
    under sharding (per-chip bytes equal the unrepeated-replicated layout).
    """
    B, S, KV, D = x.shape
    G = H // KV
    return jnp.broadcast_to(x[:, :, :, None, :], (B, S, KV, G, D)
                            ).reshape(B, S, H, D)


def _mask(q_pos, k_pos, causal, window, kv_len):
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        w = jnp.asarray(window)
        m &= jnp.where(w > 0, k_pos[None, :] > q_pos[:, None] - w, True)
    if kv_len is not None:
        m &= (k_pos < kv_len)[None, :]
    return m


def blockwise_attention(
    q: Array,                  # [B, Sq, H, D]
    k: Array,                  # [B, Sk, KV, D]
    v: Array,                  # [B, Sk, KV, D]
    *,
    causal: bool = True,
    window: Optional[Array] = None,   # tokens of lookback (None/0 = unlimited)
    q_offset=0,                # absolute position of q[0]
    kv_len: Optional[Array] = None,   # valid cache length (decode), else Sk
    chunk: int = 512,
    q_chunk: int = 1024,
    mesh=None, rules=None,
) -> Array:
    """Numerically-stable doubly-chunked attention with GQA.

    Outer scan over query chunks, inner scan over KV chunks with running
    (max, denom, acc).  KV heads are broadcast to the flat H axis *per KV
    chunk* (never materialising the repeated cache), so peak score memory is
    [B, q_chunk, H, chunk] — head-shardable over 'model' because H is flat.
    Returns [B, Sq, H, D].
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    chunk = min(chunk, Sk)
    while Sk % chunk != 0:   # static shapes: largest divisor ≤ chunk
        chunk -= 1
    q_chunk = min(q_chunk, Sq)
    while Sq % q_chunk != 0:
        q_chunk -= 1
    scale = 1.0 / math.sqrt(D)

    kc = jnp.moveaxis(k.reshape(B, Sk // chunk, chunk, k.shape[2], D), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, Sk // chunk, chunk, v.shape[2], D), 1, 0)
    k_starts = jnp.arange(Sk // chunk) * chunk
    qc = jnp.moveaxis(
        (q.astype(jnp.float32) * scale).reshape(B, Sq // q_chunk, q_chunk,
                                                H, D), 1, 0)
    q_starts = q_offset + jnp.arange(Sq // q_chunk) * q_chunk

    @jax.checkpoint
    def q_step(_, q_in):
        qb, q0 = q_in
        q_pos = q0 + jnp.arange(q_chunk)

        def kv_step(carry, kv_in):
            m, l, acc = carry
            kb, vb, k0 = kv_in
            kbf = _repeat_kv(kb, H).astype(jnp.float32)   # per-chunk only
            vbf = _repeat_kv(vb, H).astype(jnp.float32)
            s = jnp.einsum("bqhd,bchd->bqhc", qb, kbf)    # [B, qc, H, chunk]
            if mesh is not None:
                s = R.constrain(s, mesh, ("batch", None, "heads", None),
                                rules)
            msk = _mask(q_pos, k0 + jnp.arange(chunk), causal, window,
                        kv_len)[None, :, None, :]
            s = jnp.where(msk, s, NEG_BIG)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.where(msk, jnp.exp(s - m_new[..., None]), 0.0)
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bqhc,bchd->bqhd", p, vbf)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, q_chunk, H), NEG_BIG, jnp.float32)
        l0 = jnp.zeros((B, q_chunk, H), jnp.float32)
        a0 = jnp.zeros((B, q_chunk, H, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (kc, vc, k_starts))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out

    _, outs = jax.lax.scan(q_step, None, (qc, q_starts))   # [nq, B, qc, H, D]
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, D)
    if mesh is not None:
        out = R.constrain(out, mesh, ("batch", None, "heads", None), rules)
    return out.astype(q.dtype)


def decode_attention(
    q: Array,                  # [B, 1, H, D]
    k: Array,                  # [B, KV, Sk, D]  (cache layout: heads major)
    v: Array,
    *,
    window: Optional[Array] = None,
    kv_len: Optional[Array] = None,   # valid cache entries (≤ Sk)
    q_offset=0,                       # position of the query token
    mesh=None, rules=None,
) -> Array:
    """Single-position attention against a (possibly sharded) KV cache.

    Grouped einsum — the KV cache is never repeated/materialised; scores are
    [B, Sq, KV, G, Sk] and a softmax over a seq-sharded cache axis lowers to
    a pair of small all-reduces under GSPMD.
    """
    B, Sq, H, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    qg = (q.astype(jnp.float32) * scale).reshape(B, Sq, KV, G, D)
    s = jnp.einsum("bqkgd,bksd->bqkgs", qg, k,
                   preferred_element_type=jnp.float32)
    q_pos = q_offset + jnp.arange(Sq)
    msk = _mask(q_pos, jnp.arange(Sk), True, window,
                kv_len)[None, :, None, None, :]
    s = jnp.where(msk, s, NEG_BIG)
    p = jnp.where(msk, jax.nn.softmax(s, axis=-1), 0.0)
    out = jnp.einsum("bqkgs,bksd->bqkgd", p, v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu_mlp(x: Array, wi: Array, wg: Array, wo: Array,
               mesh=None, rules=None) -> Array:
    h = jnp.einsum("...d,df->...f", x, wi.astype(x.dtype))
    g = jnp.einsum("...d,df->...f", x, wg.astype(x.dtype))
    h = jax.nn.silu(g) * h
    if mesh is not None:
        # NB: constraint dims marked None are REPLICATED — batch must be named
        h = R.constrain(h, mesh,
                        ("batch",) + (None,) * (h.ndim - 2) + ("mlp",), rules)
    out = jnp.einsum("...f,fd->...d", h, wo.astype(x.dtype))
    if mesh is not None:
        # Megatron-SP: block outputs are seq-FULL here (the layer-end
        # constraint reduce-scatters back to act_seq).  Pinning this keeps
        # the wo weight-grad contraction token-local + psum(data) instead of
        # an fp32 batch-axis all-gather of the cotangent (see DESIGN.md §4).
        out = R.constrain(out, mesh,
                          ("batch",) + (None,) * (out.ndim - 1), rules)
    return out


# ---------------------------------------------------------------------------
# GShard-style top-k MoE with capacity dispatch (expert-parallel shardable)
# ---------------------------------------------------------------------------

def moe_layer(
    x: Array,                  # [B, S, d]
    router: Array,             # [d, E]
    wi: Array, wg: Array,      # [E, d, f]
    wo: Array,                 # [E, f, d]
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    group_size: int = 4096,
    mesh=None, rules=None,
):
    """Returns (y [B, S, d], aux_loss scalar)."""
    B, S, d = x.shape
    E = router.shape[-1]
    T = B * S
    g = min(group_size, T)
    assert T % g == 0, (T, g)
    G = T // g
    xt = x.reshape(G, g, d)
    if mesh is not None:
        xt = R.constrain(xt, mesh, ("group", "act_seq", None), rules)

    logits = jnp.einsum("Gtd,de->Gte", xt.astype(jnp.float32),
                        router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # [G, g, E]
    top_p, top_e = jax.lax.top_k(probs, top_k)                 # [G, g, k]
    gates = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    cap = int(math.ceil(g * top_k / E * capacity_factor / 4.0) * 4)
    cap = min(cap, g)

    count = jnp.zeros((G, 1, E), jnp.float32)
    dispatch = jnp.zeros((G, g, E, cap), x.dtype)
    combine = jnp.zeros((G, g, E, cap), jnp.float32)
    for r in range(top_k):
        oh = jax.nn.one_hot(top_e[..., r], E, dtype=jnp.float32)   # [G, g, E]
        pos = jnp.cumsum(oh, axis=1) - oh + count                  # [G, g, E]
        pos_t = (pos * oh).sum(-1)                                 # [G, g]
        count = count + oh.sum(axis=1, keepdims=True)
        keep = pos_t < cap
        slot = jax.nn.one_hot(pos_t, cap, dtype=jnp.float32)       # [G, g, cap]
        d_r = (oh[..., None] * slot[..., None, :]
               * keep[..., None, None])                            # [G,g,E,cap]
        dispatch = dispatch + d_r.astype(x.dtype)
        combine = combine + d_r * gates[..., r][..., None, None]

    disp_x = jnp.einsum("gtec,gtd->gecd", dispatch, xt)            # [G,E,cap,d]
    if mesh is not None:
        disp_x = R.constrain(disp_x, mesh, ("group", "expert", None, None),
                             rules)
    h = jnp.einsum("gecd,edf->gecf", disp_x, wi.astype(x.dtype))
    u = jnp.einsum("gecd,edf->gecf", disp_x, wg.astype(x.dtype))
    h = jax.nn.silu(u) * h
    eo = jnp.einsum("gecf,efd->gecd", h, wo.astype(x.dtype))
    if mesh is not None:
        eo = R.constrain(eo, mesh, ("group", "expert", None, None), rules)
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), eo)

    # Switch-style load-balance auxiliary loss.
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_e[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    mean_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * mean_probs)
    return y.reshape(B, S, d), aux
