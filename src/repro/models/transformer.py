"""Decoder-only LM family: dense (deepseek-67b, stablelm-12b, gemma3-27b with
5:1 local:global attention) and MoE (llama4-scout 16e top-1, moonshot 64e
top-6), with GQA, RoPE, scanned+remat'ed layers, chunked cross-entropy, and a
KV-cache decode path.

Parameters are plain nested dicts; `logical_axes` returns a matching tree of
`repro.distributed.rules.L` annotations that drives all sharding (DP/FSDP over
(pod, data), TP/EP over model; decode caches fall back from kv_heads→model to
kv_seq→model when head counts don't divide — see rules.py).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import rules as R
from repro.distributed.rules import L
from repro.models import layers

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    rope_theta: float = 500_000.0
    # MoE
    moe: bool = False
    n_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    group_size: int = 4096
    # local:global interleave (gemma3): ratio local layers per global layer
    local_window: int = 0
    local_global_ratio: int = 0
    # numerics / scheduling
    dtype: str = "bfloat16"
    attn_chunk: int = 512
    attn_q_chunk: int = 512
    remat: bool = True

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def param_count(self) -> int:
        """Total parameters N (for MODEL_FLOPS = 6·N·D accounting)."""
        c = self
        attn = c.d_model * c.head_dim * (c.n_heads * 2 + c.n_kv_heads * 2)
        if c.moe:
            mlp = 3 * c.d_model * c.d_ff * c.n_experts + c.d_model * c.n_experts
        else:
            mlp = 3 * c.d_model * c.d_ff
        per_layer = attn + mlp + 2 * c.d_model
        return (c.n_layers * per_layer + 2 * c.vocab * c.d_model + c.d_model)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed experts count)."""
        if not self.moe:
            return self.param_count()
        c = self
        attn = c.d_model * c.head_dim * (c.n_heads * 2 + c.n_kv_heads * 2)
        mlp = 3 * c.d_model * c.d_ff * c.moe_top_k + c.d_model * c.n_experts
        per_layer = attn + mlp + 2 * c.d_model
        return (c.n_layers * per_layer + 2 * c.vocab * c.d_model + c.d_model)


def layer_is_global(cfg: LMConfig) -> np.ndarray:
    """bool[n_layers]; gemma3 pattern = ratio local layers then one global."""
    if cfg.local_global_ratio <= 0:
        return np.ones(cfg.n_layers, bool)
    period = cfg.local_global_ratio + 1
    return np.array([(i % period) == cfg.local_global_ratio
                     for i in range(cfg.n_layers)])


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_params(key: Array, cfg: LMConfig, dtype=jnp.float32) -> Dict[str, Any]:
    """Materialised init (small/smoke configs). Use jax.eval_shape for dry-runs."""
    k = jax.random.split(key, 12)
    d, hd, H, KV, V, Lr = (cfg.d_model, cfg.head_dim, cfg.n_heads,
                           cfg.n_kv_heads, cfg.vocab, cfg.n_layers)
    s = 1.0 / math.sqrt(d)

    def nrm(key, shape, scale):
        return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)

    lp = {
        "ln1": jnp.ones((Lr, d), dtype),
        "ln2": jnp.ones((Lr, d), dtype),
        "wq": nrm(k[0], (Lr, d, H, hd), s),
        "wk": nrm(k[1], (Lr, d, KV, hd), s),
        "wv": nrm(k[2], (Lr, d, KV, hd), s),
        "wo": nrm(k[3], (Lr, H, hd, d), s / math.sqrt(2 * Lr)),
    }
    if cfg.moe:
        E, f = cfg.n_experts, cfg.d_ff
        lp.update({
            "router": nrm(k[4], (Lr, d, E), s),
            "wi": nrm(k[5], (Lr, E, d, f), s),
            "wg": nrm(k[6], (Lr, E, d, f), s),
            "wo_mlp": nrm(k[7], (Lr, E, f, d), 1 / math.sqrt(cfg.d_ff)),
        })
    else:
        f = cfg.d_ff
        lp.update({
            "wi": nrm(k[5], (Lr, d, f), s),
            "wg": nrm(k[6], (Lr, d, f), s),
            "wo_mlp": nrm(k[7], (Lr, f, d), 1 / math.sqrt(f)),
        })
    return {
        "embed": nrm(k[8], (V, d), 1.0),
        "layers": lp,
        "ln_f": jnp.ones((d,), dtype),
        "unembed": nrm(k[9], (d, V), s),
    }


def abstract_params(cfg: LMConfig, dtype=jnp.float32):
    """ShapeDtypeStruct tree without allocation (dry-run path)."""
    return jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg, dtype))


def logical_axes(cfg: LMConfig) -> Dict[str, Any]:
    lp = {
        "ln1": L(None, "embed"),
        "ln2": L(None, "embed"),
        "wq": L(None, "fsdp", "heads", None),
        "wk": L(None, "fsdp", "kv_heads", None),
        "wv": L(None, "fsdp", "kv_heads", None),
        "wo": L(None, "heads", None, "fsdp"),
    }
    if cfg.moe:
        lp.update({
            "router": L(None, "fsdp", None),
            "wi": L(None, "expert", "fsdp", "mlp"),
            "wg": L(None, "expert", "fsdp", "mlp"),
            "wo_mlp": L(None, "expert", "mlp", "fsdp"),
        })
    else:
        lp.update({
            "wi": L(None, "fsdp", "mlp"),
            "wg": L(None, "fsdp", "mlp"),
            "wo_mlp": L(None, "mlp", "fsdp"),
        })
    return {
        "embed": L("vocab", "fsdp"),
        "layers": lp,
        "ln_f": L("embed"),
        "unembed": L("fsdp", "vocab"),
    }


# ---------------------------------------------------------------------------
# Forward (training)
# ---------------------------------------------------------------------------

def _attention_block(lp, x, positions, *, cfg, window, mesh, rules):
    h = layers.rms_norm(x, lp["ln1"])
    q = jnp.einsum("bsd,dhe->bshe", h, lp["wq"].astype(h.dtype))
    knew = jnp.einsum("bsd,dke->bske", h, lp["wk"].astype(h.dtype))
    vnew = jnp.einsum("bsd,dke->bske", h, lp["wv"].astype(h.dtype))
    q = layers.rope(q, positions, cfg.rope_theta)
    knew = layers.rope(knew, positions, cfg.rope_theta)
    if mesh is not None:
        q = R.constrain(q, mesh, ("batch", None, "heads", None), rules)
    out = layers.blockwise_attention(
        q, knew, vnew, causal=True, window=window,
        chunk=cfg.attn_chunk, q_chunk=cfg.attn_q_chunk, mesh=mesh,
        rules=rules)
    out = jnp.einsum("bshe,hed->bsd", out, lp["wo"].astype(h.dtype))
    if mesh is not None:  # seq-full at the block edge (Megatron-SP; see mlp)
        out = R.constrain(out, mesh, ("batch", None, "embed"), rules)
    return out, (knew, vnew)


def _mlp_block(lp, x, *, cfg, mesh, rules):
    h = layers.rms_norm(x, lp["ln2"])
    if cfg.moe:
        y, aux = layers.moe_layer(
            h, lp["router"], lp["wi"], lp["wg"], lp["wo_mlp"],
            top_k=cfg.moe_top_k, capacity_factor=cfg.capacity_factor,
            group_size=cfg.group_size, mesh=mesh, rules=rules)
        return y, aux
    return layers.swiglu_mlp(h, lp["wi"], lp["wg"], lp["wo_mlp"],
                             mesh=mesh, rules=rules), 0.0


def forward(params, tokens: Array, cfg: LMConfig, mesh=None,
            rules=None, collect_kv: bool = False):
    """tokens [B, S] -> (final hidden [B, S, d], aux_loss[, kv cache]).

    collect_kv=True additionally returns the per-layer K/V tensors stacked as
    a decode-ready cache (the prefill serving path).
    """
    B, S = tokens.shape
    dt = cfg.jdtype
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    if mesh is not None:
        x = R.constrain(x, mesh, ("batch", "act_seq", "embed"), rules)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    is_global = jnp.asarray(layer_is_global(cfg))

    def layer_fn(carry, inputs):
        x, aux = carry
        lp, flag_global = inputs
        window = jnp.where(flag_global, 0, cfg.local_window)
        attn, kv = _attention_block(lp, x, positions, cfg=cfg,
                                    window=window, mesh=mesh, rules=rules)
        x = x + attn
        mlp, a = _mlp_block(lp, x, cfg=cfg, mesh=mesh, rules=rules)
        x = x + mlp
        if mesh is not None:
            x = R.constrain(x, mesh, ("batch", "act_seq", "embed"), rules)
        ys = kv if collect_kv else None
        return (x, aux + a), ys

    body = jax.checkpoint(layer_fn) if cfg.remat else layer_fn
    (x, aux), kvs = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                 (params["layers"], is_global))
    x = layers.rms_norm(x, params["ln_f"])
    if collect_kv:
        # [L, B, S, KV, hd] -> heads-major [L, B, KV, S, hd] (cache layout:
        # kv_heads precede kv_seq so head-sharding is preferred when it
        # divides, with seq-sharding as the fallback — rules.py).
        cache = {"k": jnp.moveaxis(kvs[0], 3, 2),
                 "v": jnp.moveaxis(kvs[1], 3, 2)}
        if mesh is not None:
            cache = jax.tree.map(lambda c: R.constrain(
                c, mesh, (None, "batch", "kv_heads", "kv_seq", None), rules),
                cache)
        return x, aux / cfg.n_layers, cache
    return x, aux / cfg.n_layers


def lm_loss(params, tokens: Array, labels: Array, cfg: LMConfig, mesh=None,
            rules=None) -> Tuple[Array, Dict]:
    """Softmax cross-entropy.

    Logits stay (batch, act_seq)-sharded — with sequence parallelism over
    'model' the full [B, S, V] bf16 logits are only ~V·(S/16)·(B/16) per
    device, which beats chunked recomputation on both memory and HBM traffic.
    """
    hidden, aux = forward(params, tokens, cfg, mesh, rules)
    if mesh is not None:
        # Megatron vocab-parallel xent: logits sharded over vocab ('model'),
        # per-token max/sum/gold reduced with tiny all-reduces.
        hidden = R.constrain(hidden, mesh, ("batch", None, "embed"), rules)
    logits = jnp.einsum("bsd,dv->bsv", hidden,
                        params["unembed"].astype(hidden.dtype))
    if mesh is not None:
        logits = R.constrain(logits, mesh, ("batch", None, "vocab"), rules)
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0].astype(jnp.float32)
    total = jnp.sum(lse - gold)
    xent = total / labels.size
    loss = xent + 0.01 * aux
    return loss, {"xent": xent, "aux": aux}


def prefill(params, tokens: Array, cfg: LMConfig, mesh=None, rules=None):
    """Inference prefill: next-token logits for the last position + KV cache."""
    hidden, _, cache = forward(params, tokens, cfg, mesh, rules,
                               collect_kv=True)
    logits = jnp.einsum("bd,dv->bv", hidden[:, -1].astype(jnp.float32),
                        params["unembed"].astype(jnp.float32))
    if mesh is not None:
        logits = R.constrain(logits, mesh, ("batch", "vocab"), rules)
    return logits, cache


# ---------------------------------------------------------------------------
# Decode (serving) path
# ---------------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, max_seq: int, dtype=None):
    """KV cache, heads-major: [L, B, KV, S, hd] (see cache_logical_axes)."""
    dtype = dtype or cfg.jdtype
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, max_seq, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def abstract_cache(cfg: LMConfig, batch: int, max_seq: int, dtype=None):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_seq, dtype))


def cache_logical_axes():
    ax = L(None, "batch", "kv_heads", "kv_seq", None)
    return {"k": ax, "v": ax}


def decode_step(params, cache, tokens: Array, pos: Array, cfg: LMConfig,
                mesh=None, rules=None):
    """One decoding step.

    tokens: [B, 1] current token; pos: scalar int32 — its position (the cache
    holds `pos` valid entries; the new KV is written at index pos).
    Returns (logits [B, V], new cache).
    """
    B = tokens.shape[0]
    dt = cfg.jdtype
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)    # [B, 1, d]
    positions = jnp.full((B, 1), pos, jnp.int32)
    is_global = jnp.asarray(layer_is_global(cfg))

    cax = ("batch", "kv_heads", "kv_seq", None)

    def layer_fn(carry, inputs):
        # The cache rides in the scan CARRY (not xs/ys) and is updated with
        # dynamic_update_index_in_dim — XLA keeps carry buffers in place, so
        # the multi-hundred-GB cache is never double-buffered.
        x, kall, vall = carry
        lp, flag_global, li = inputs
        window = jnp.where(flag_global, 0, cfg.local_window)
        kc = jax.lax.dynamic_index_in_dim(kall, li, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(vall, li, 0, keepdims=False)

        h = layers.rms_norm(x, lp["ln1"])
        q = jnp.einsum("bsd,dhe->bshe", h, lp["wq"].astype(h.dtype))
        knew = jnp.einsum("bsd,dke->bske", h, lp["wk"].astype(h.dtype))
        vnew = jnp.einsum("bsd,dke->bske", h, lp["wv"].astype(h.dtype))
        q = layers.rope(q, positions, cfg.rope_theta)
        knew = layers.rope(knew, positions, cfg.rope_theta)
        # [B, 1, KV, hd] -> heads-major cache slot [B, KV, 1, hd]
        k2 = jax.lax.dynamic_update_slice(
            kc, jnp.moveaxis(knew, 1, 2).astype(kc.dtype), (0, 0, pos, 0))
        v2 = jax.lax.dynamic_update_slice(
            vc, jnp.moveaxis(vnew, 1, 2).astype(vc.dtype), (0, 0, pos, 0))
        if mesh is not None:
            k2 = R.constrain(k2, mesh, cax, rules)
            v2 = R.constrain(v2, mesh, cax, rules)
        attn = layers.decode_attention(
            q, k2, v2, window=window, q_offset=pos, kv_len=pos + 1,
            mesh=mesh, rules=rules)
        attn = jnp.einsum("bshe,hed->bsd", attn, lp["wo"].astype(h.dtype))
        x = x + attn
        mlp, _ = _mlp_block(lp, x, cfg=cfg, mesh=mesh, rules=rules)
        x = x + mlp
        kall = jax.lax.dynamic_update_index_in_dim(kall, k2, li, 0)
        vall = jax.lax.dynamic_update_index_in_dim(vall, v2, li, 0)
        return (x, kall, vall), None

    (x, kall, vall), _ = jax.lax.scan(
        layer_fn, (x, cache["k"], cache["v"]),
        (params["layers"], is_global, jnp.arange(cfg.n_layers)))
    x = layers.rms_norm(x, params["ln_f"])
    logits = jnp.einsum("bsd,dv->bsv", x.astype(jnp.float32),
                        params["unembed"].astype(jnp.float32))[:, 0]
    return logits, {"k": kall, "v": vall}
