"""Real spherical harmonics and Wigner-D rotations for the eSCN-style
SO(2) convolution (EquiformerV2, arXiv:2306.12059).

eSCN's trick needs, per edge, the Wigner matrix D_l(R_e) of the rotation
aligning the edge direction with the canonical axis.  We factorise it as

    R_e = R_y(-θ) · R_z(-φ)          (θ, φ) = polar/azimuth of the edge
    D_l(R_e) = Jᵀ_l · Dz_l(-θ) · J_l · Dz_l(-φ)

where ``Dz_l`` (rotation about z) is closed-form — cos/sin mixing of the
(m, −m) component pairs — and ``J_l = D_l(R_x(π/2))`` is a *constant* matrix
computed once at import time by least squares on sampled spherical-harmonic
evaluations (exact to machine precision; the linear system is square+
overdetermined and Y_l spans degree-l harmonics).  This avoids per-edge
Clebsch-Gordan machinery entirely: per edge we do two small dense matmuls per
degree — the O(L³) cost profile that makes eSCN practical.

Conventions: components of degree l ordered m = −l..l; Condon–Shortley-free
real basis; ``D(R) Y(x) = Y(R x)`` (verified by tests/test_gnn.py).
"""

from __future__ import annotations

import functools
import math
from typing import List

import jax
import jax.numpy as jnp
import numpy as np


def num_coef(l_max: int) -> int:
    return (l_max + 1) ** 2


def l_slice(l: int) -> slice:
    return slice(l * l, (l + 1) * (l + 1))


# ---------------------------------------------------------------------------
# Real spherical harmonics (NumPy — used for J fitting and tests)
# ---------------------------------------------------------------------------

def real_sh_numpy(l_max: int, xyz: np.ndarray) -> np.ndarray:
    """Y[l² + l + m] for unit vectors xyz [N, 3] → [N, (l_max+1)²]."""
    xyz = np.asarray(xyz, np.float64)
    r = np.linalg.norm(xyz, axis=-1, keepdims=True)
    x, y, z = (xyz / np.maximum(r, 1e-30)).T
    ct = np.clip(z, -1.0, 1.0)
    st = np.sqrt(np.maximum(0.0, 1.0 - ct * ct))
    phi = np.arctan2(y, x)

    # associated Legendre P_l^m(ct) without Condon–Shortley phase
    P = {}
    P[(0, 0)] = np.ones_like(ct)
    for m in range(1, l_max + 1):
        P[(m, m)] = (2 * m - 1) * st * P[(m - 1, m - 1)]
    for m in range(0, l_max):
        P[(m + 1, m)] = (2 * m + 1) * ct * P[(m, m)]
    for m in range(0, l_max + 1):
        for l in range(m + 2, l_max + 1):
            P[(l, m)] = (((2 * l - 1) * ct * P[(l - 1, m)]
                          - (l + m - 1) * P[(l - 2, m)]) / (l - m))

    out = np.zeros((xyz.shape[0], num_coef(l_max)))
    for l in range(l_max + 1):
        for m in range(-l, l + 1):
            am = abs(m)
            k = math.sqrt((2 * l + 1) / (4 * math.pi)
                          * math.factorial(l - am) / math.factorial(l + am))
            if m == 0:
                v = k * P[(l, 0)]
            elif m > 0:
                v = math.sqrt(2) * k * np.cos(m * phi) * P[(l, m)]
            else:
                v = math.sqrt(2) * k * np.sin(am * phi) * P[(l, am)]
            out[:, l * l + l + m] = v
    return out


def fit_wigner_numpy(l: int, R: np.ndarray) -> np.ndarray:
    """D_l(R) by least squares from Y(Rx) = D Y(x) on sampled points."""
    rng = np.random.Generator(np.random.Philox(key=1234 + l))
    pts = rng.normal(size=(8 * (2 * l + 1) + 16, 3))
    pts /= np.linalg.norm(pts, axis=-1, keepdims=True)
    Yx = real_sh_numpy(l, pts)[:, l_slice(l)]
    YRx = real_sh_numpy(l, pts @ R.T)[:, l_slice(l)]
    D, *_ = np.linalg.lstsq(Yx, YRx, rcond=None)
    return D.T   # rows: Y(Rx)_i = Σ_j D[i, j] Y(x)_j


@functools.lru_cache(maxsize=None)
def j_matrices(l_max: int) -> tuple:
    """Constant J_l = D_l(R_x(π/2)) for l = 0..l_max."""
    Rc = np.array([[1.0, 0.0, 0.0],
                   [0.0, 0.0, -1.0],
                   [0.0, 1.0, 0.0]])   # rotation by +π/2 about x: y→z
    return tuple(fit_wigner_numpy(l, Rc) for l in range(l_max + 1))


# ---------------------------------------------------------------------------
# Closed-form z-rotation blocks + per-edge Wigner matrices (JAX)
# ---------------------------------------------------------------------------

def _dz_masks(l: int):
    """Constant masks: Dz(γ)[i,j] = diag_ij·cos(|m_i|γ) + anti_ij·sin(|m_i|γ)."""
    dim = 2 * l + 1
    ms = np.arange(-l, l + 1)
    diag = np.eye(dim)
    anti = np.zeros((dim, dim))
    for i, m in enumerate(ms):
        if m == 0:
            continue
        j = l - m   # index of −m
        anti[i, j] = -1.0 if m > 0 else 1.0
    return diag, anti, np.abs(ms).astype(np.float64)


@functools.lru_cache(maxsize=None)
def _dz_consts(l: int):
    # cache NumPy constants only (jnp conversion must happen inside the trace)
    diag, anti, absm = _dz_masks(l)
    return (np.asarray(diag, np.float32), np.asarray(anti, np.float32),
            np.asarray(absm, np.float32))


def dz_block(l: int, gamma: jax.Array) -> jax.Array:
    """Dz_l(γ) for a batch of angles γ [...]:  [..., 2l+1, 2l+1]."""
    diag, anti, absm = _dz_consts(l)
    c = jnp.cos(gamma[..., None] * jnp.asarray(absm))      # [..., 2l+1]
    s = jnp.sin(gamma[..., None] * jnp.asarray(absm))
    return (jnp.asarray(diag) * c[..., None, :]
            + jnp.asarray(anti) * s[..., None, :])


def wigner_blocks(l_max: int, edge_vec: jax.Array) -> List[jax.Array]:
    """Per-edge D_l(R_e), R_e aligning edge_vec [..., 3] with +z.

    Returns a list (l = 0..l_max) of [..., 2l+1, 2l+1] matrices.
    """
    v = edge_vec
    r = jnp.linalg.norm(v, axis=-1, keepdims=True)
    u = v / jnp.maximum(r, 1e-12)
    theta = jnp.arccos(jnp.clip(u[..., 2], -1.0, 1.0))
    phi = jnp.arctan2(u[..., 1], u[..., 0])
    Js = j_matrices(l_max)
    out = []
    for l in range(l_max + 1):
        J = jnp.asarray(Js[l])
        dz_t = dz_block(l, -theta)
        dz_p = dz_block(l, -phi)
        D = jnp.einsum("ij,...jk,kl,...lm->...im", J.T, dz_t, J, dz_p)
        out.append(D)
    return out


def apply_blocks(blocks: List[jax.Array], feats: jax.Array,
                 transpose: bool = False) -> jax.Array:
    """Apply per-degree rotation blocks to features [..., (L+1)², C]."""
    outs = []
    for l, D in enumerate(blocks):
        f = feats[..., l_slice(l), :]
        eq = "...ji,...jc->...ic" if transpose else "...ij,...jc->...ic"
        outs.append(jnp.einsum(eq, D, f))
    return jnp.concatenate(outs, axis=-2)
