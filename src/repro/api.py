"""``repro.api`` — the one front door to the engine.

One config, one factory::

    from repro.api import IndexConfig, open_index

    index = open_index(IndexConfig(n=30_000, capacity=65_536))
    index.insert_many(ids, idx, val)
    server = QueryServer(index, k=10)
    result = server.query(q_idx, q_val)        # -> QueryResult

:func:`open_index` replaces the four constructor permutations the system
grew (``SinnamonIndex``, ``ShardedSinnamonIndex``, ``DurableSinnamonIndex``,
``DurableShardedSinnamonIndex``) with a single declarative
:class:`IndexConfig`:

* ``shards`` picks single-device vs mesh-sharded SPMD serving (capacity is
  always the GLOBAL slot count; per-shard sizing is derived),
* ``durability`` (a :class:`DurabilityConfig` block) turns on the
  WAL + snapshot + recovery machinery — ``open_index`` then *recovers*
  existing state instead of starting empty,
* ``backend`` pins the scoring backend for every search on the returned
  index, subsuming the ``REPRO_SCORE_BACKEND`` env var (which remains the
  process-wide default when ``backend`` is None).

The legacy constructors keep working — they are exactly what the factory
routes to — and ``tests/test_api_facade.py`` asserts each one produces the
same state as its :func:`open_index` spelling.  New code (the launcher, the
examples, the async front door in ``repro.serving.frontend``) goes through
the facade.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import engine as eng
from repro.serving.results import QueryResult, new_trace_id

__all__ = [
    "DurabilityConfig",
    "IndexConfig",
    "QueryResult",
    "new_trace_id",
    "open_index",
]


@dataclasses.dataclass(frozen=True)
class DurabilityConfig:
    """WAL + snapshot policy block of an :class:`IndexConfig`.

    Presence of this block makes :func:`open_index` return a durable index
    (``repro.persist``): every mutation is logged before it is applied and
    opening again on the same directories recovers snapshot + WAL tail.
    """

    wal_dir: str
    snapshot_dir: Optional[str] = None
    snapshot_every: Optional[int] = None   # snapshot after N logged ops
    compact_threshold: Optional[float] = None  # compact when drift exceeds
    compact_check_every: int = 64
    fsync: bool = True
    segment_bytes: int = 4 << 20
    snapshot_keep: int = 3

    def __post_init__(self):
        if self.snapshot_every is not None and self.snapshot_dir is None:
            raise ValueError("snapshot_every requires snapshot_dir "
                             "(periodic snapshots need somewhere to go)")

    def kwargs(self) -> dict:
        """Keyword arguments for the Durable* constructors."""
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class IndexConfig:
    """Declarative index configuration; the input to :func:`open_index`.

    Engine geometry (the paper's levers — see docs/levers.md):

    * ``n`` — ambient dimensionality; ``capacity`` — GLOBAL document slots;
      ``max_nnz`` — padded CSR width (max ψ_d); ``m``/``h`` — sketch size /
      hash count.
    * ``sketch_kind`` — ``full | lite`` (§3.3 half sketch);
      ``cell_dtype`` — sketch cell storage (``f32 | bf16 | f8``);
      ``store_dtype`` — raw VecStore width the exact rerank reads.
    * ``positive_only`` (Sinnamon+), ``index_buckets`` (§4.1.2 hashed
      inverted index), ``seed``.

    Deployment shape:

    * ``backend`` — scoring backend for every search on this index
      (``reference | grouped | pallas``; None → the process default, i.e.
      ``REPRO_SCORE_BACKEND`` or pallas).
    * ``shards`` — >1 serves the mesh-sharded SPMD index over a host-local
      mesh (pass an explicit ``mesh`` to :func:`open_index` for real
      topologies).
    * ``durability`` — optional :class:`DurabilityConfig` block.
    * ``device_budget_mb`` — cap on the PER-DEVICE bytes of raw vector
      rows; setting it serves the hot/cold tiered index (sketches stay
      fully resident, raw CSR rows page between a device chunk cache and
      host RAM — see docs/tiering.md).  Results are bit-identical to the
      resident index.  ``tier_chunk_slots`` is the paging granularity in
      slots per chunk.
    """

    n: int
    capacity: int
    m: int = 60
    h: int = 1
    max_nnz: int = 256
    positive_only: bool = False
    index_buckets: Optional[int] = None
    sketch_kind: str = "full"
    cell_dtype: str = "bf16"
    store_dtype: str = "bfloat16"
    seed: int = 0
    backend: Optional[str] = None
    shards: int = 1
    update_block: int = 32
    durability: Optional[DurabilityConfig] = None
    device_budget_mb: Optional[float] = None   # per-device raw-store budget
    tier_chunk_slots: int = 256                # slots per tiering chunk

    def __post_init__(self):
        if self.shards < 1:
            raise ValueError(f"shards must be >= 1, got {self.shards}")
        if self.capacity < 1:
            raise ValueError(f"capacity must be positive, got {self.capacity}")
        if self.backend is not None:
            from repro.kernels import ops as _ops
            _ops.resolve_backend(self.backend)     # validate eagerly
        if self.device_budget_mb is not None and self.device_budget_mb <= 0:
            raise ValueError(f"device_budget_mb must be positive, "
                             f"got {self.device_budget_mb}")
        if self.tier_chunk_slots < 1:
            raise ValueError(f"tier_chunk_slots must be >= 1, "
                             f"got {self.tier_chunk_slots}")

    @property
    def local_capacity(self) -> int:
        """Per-shard slot count: ceil(capacity / shards), rounded up to 32."""
        per = -(-self.capacity // self.shards)
        return ((per + 31) // 32) * 32

    def engine_spec(self) -> eng.EngineSpec:
        """The per-shard :class:`EngineSpec` this config describes.

        For ``shards == 1`` this is also the global spec (capacity rounded
        up to the engine's multiple-of-32 requirement).
        """
        return eng.EngineSpec(
            n=self.n, m=self.m, h=self.h, capacity=self.local_capacity,
            max_nnz=self.max_nnz, positive_only=self.positive_only,
            index_buckets=self.index_buckets, sketch_kind=self.sketch_kind,
            dtype=self.cell_dtype, value_dtype=self.store_dtype,
            seed=self.seed)


def _host_mesh(shards: int):
    import jax

    from repro.distributed import mesh as meshlib
    if shards == 1:
        return meshlib.single_device_mesh(("data", "model"))
    n_dev = len(jax.devices())
    if n_dev < shards:
        raise RuntimeError(
            f"IndexConfig.shards={shards} but only {n_dev} device(s) are "
            f"visible; on CPU force host devices BEFORE importing jax, e.g. "
            f'os.environ["XLA_FLAGS"] = '
            f'"--xla_force_host_platform_device_count={shards}", or pass an '
            f"explicit mesh to open_index")
    return meshlib.make_mesh((1, shards), ("data", "model"))


def open_index(config: IndexConfig, *, mesh=None):
    """Open (or recover) the index a config describes.

    Routing:

    ========== ============ ==========================================
    durability shards/mesh  returns
    ========== ============ ==========================================
    None       1, no mesh   ``SinnamonIndex``
    None       >1 or mesh   ``ShardedSinnamonIndex``
    set        1, no mesh   ``DurableSinnamonIndex.open`` (recovers)
    set        >1 or mesh   ``DurableShardedSinnamonIndex.open``
    ========== ============ ==========================================

    With ``device_budget_mb`` set, each row routes to its Tiered* twin
    (``TieredSinnamonIndex`` / ``TieredShardedSinnamonIndex`` /
    ``DurableTieredSinnamonIndex``); durable + sharded + tiered is not
    implemented yet and raises ``NotImplementedError``.

    ``mesh`` overrides the host-local mesh that ``shards > 1`` would build
    (and forces the sharded path even for one shard — the 1×1 mesh runs the
    same shard_map program as production).  The returned index carries
    ``config`` on ``.config`` and ``config.backend`` as its default scoring
    backend, so callers never touch ``REPRO_SCORE_BACKEND``.
    """
    spec = config.engine_spec()
    sharded = mesh is not None or config.shards > 1
    tiered = config.device_budget_mb is not None
    if sharded and tiered and config.durability is not None:
        raise NotImplementedError(
            "durability + shards + device_budget_mb is not supported yet: "
            "drop one of the three (tiered sharded serving is available "
            "without durability)")
    if sharded and mesh is None:
        mesh = _host_mesh(config.shards)
    tkw = dict(tier_chunk_slots=config.tier_chunk_slots,
               device_budget_bytes=int(config.device_budget_mb * (1 << 20))
               ) if tiered else {}

    if config.durability is None:
        if sharded and tiered:
            from repro.serving.sharded import TieredShardedSinnamonIndex
            index = TieredShardedSinnamonIndex(
                spec, mesh, update_block=config.update_block, **tkw)
        elif sharded:
            from repro.serving.sharded import ShardedSinnamonIndex
            index = ShardedSinnamonIndex(spec, mesh,
                                         update_block=config.update_block)
        elif tiered:
            index = eng.TieredSinnamonIndex(spec, **tkw)
        else:
            index = eng.SinnamonIndex(spec)
    else:
        dkw = config.durability.kwargs()
        if sharded:
            from repro.persist import DurableShardedSinnamonIndex
            index = DurableShardedSinnamonIndex.open(
                spec, mesh, update_block=config.update_block, **dkw)
        elif tiered:
            from repro.persist.durable import DurableTieredSinnamonIndex
            index = DurableTieredSinnamonIndex.open(spec, **dkw, **tkw)
        else:
            from repro.persist import DurableSinnamonIndex
            index = DurableSinnamonIndex.open(spec, **dkw)

    index.default_backend = config.backend
    index.config = config
    return index
