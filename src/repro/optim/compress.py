"""Gradient compression for cross-pod data parallelism.

int8 quantisation with error feedback (1-bit-Adam-family trick): each worker
keeps a residual; quantise (g + residual) per-tensor to int8 with a shared
scale, all-reduce the int8 payload (4× fewer wire bytes than f32 / 2× vs
bf16 on the pod-interconnect — the slowest link in a multi-pod mesh), keep
the quantisation error as the next residual.  Convergence parity is checked
in tests/test_optim.py on a quadratic model.

``compressed_psum`` is designed for use inside shard_map over the 'pod' axis;
outside shard_map (single-pod) it degrades to an exact psum.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads, residual):
    """(grads, residual) -> (int8 tree, scales tree, new residual tree)."""

    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s = quantize_int8(x)
        deq = dequantize(q, s)
        return q, s, x - deq

    out = jax.tree.map(one, grads, residual)
    leaf = lambda t: isinstance(t, tuple)
    q = jax.tree.map(lambda t: t[0], out, is_leaf=leaf)
    s = jax.tree.map(lambda t: t[1], out, is_leaf=leaf)
    res = jax.tree.map(lambda t: t[2], out, is_leaf=leaf)
    return q, s, res


def compressed_psum(grads, residual, axis_name: str):
    """Error-feedback int8 all-reduce of a gradient tree over ``axis_name``.

    Returns (mean_grads_f32, new_residual).  Scales are max-reduced first so
    every worker dequantises identically.
    """
    def one(g, r):
        x = g.astype(jnp.float32) + r
        amax = jax.lax.pmax(jnp.max(jnp.abs(x)).astype(jnp.float32), axis_name)
        scale = jnp.maximum(amax / 127.0, 1e-12)
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        new_r = x - q.astype(jnp.float32) * scale
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return (total.astype(jnp.float32) * scale) / n, new_r

    out = jax.tree.map(one, grads, residual)
    leaf = lambda t: isinstance(t, tuple)
    return (jax.tree.map(lambda t: t[0], out, is_leaf=leaf),
            jax.tree.map(lambda t: t[1], out, is_leaf=leaf))
