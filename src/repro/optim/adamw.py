"""AdamW + cosine schedule + global-norm clipping, implemented from scratch
(no optax in this environment).  fp32 moments regardless of param dtype;
optimizer state inherits the parameters' sharding (ZeRO-style: the rules in
distributed/rules.py shard fan-in dims over the data axes, so m/v shard too).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jax.Array


def init(params) -> OptState:
    z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(m=z, v=jax.tree.map(jnp.copy, z),
                    step=jnp.zeros((), jnp.int32))


def schedule(cfg: AdamWConfig, step) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(math.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    sq = jax.tree.reduce(
        lambda a, g: a + jnp.sum(g.astype(jnp.float32) ** 2), tree, 0.0)
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def update(grads, opt: OptState, params, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.clip_norm > 0:
        grads, gn = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gn = global_norm(grads)
    step = opt.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m2 / b1c
        vh = v2 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, opt.m, opt.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, OptState(new_m, new_v, step), {
        "grad_norm": gn, "lr": lr}
