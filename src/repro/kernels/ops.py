"""jit'd public wrappers around the Pallas kernels + the scoring-backend
dispatch point.

Handles operand preparation (query sorting/budgeting, membership-row
gathering, tile padding) and implementation selection: compiled Pallas on
TPU; elsewhere the dense kernels run in interpret mode (the mandated
validation path) while the fused serving path runs its XLA twin — the same
tile program without the per-grid-step interpreter overhead (interpret-mode
execution of the fused kernel remains available via ``use_kernel=True`` and
is what the equivalence tests exercise).

Scoring-backend dispatch
------------------------
Every query hot path (``engine.search``/``search_batch``, both serving
layers, the launcher) routes candidate generation through ONE selector:

* ``pallas``    — the fused tiled kernel (``sinnamon_score_topk`` + log-tree
  merge): never materializes the ``[B, C]`` score matrix.  The production
  default.
* ``grouped``   — ``engine.score_grouped`` (one fused [L, C] pass) + dense
  ``lax.top_k``.
* ``reference`` — paper-faithful coordinate-at-a-time ``engine.score`` +
  dense ``lax.top_k``; the correctness oracle.

Select per call (``backend=...``), per server (``--score-backend``), or
process-wide via the ``REPRO_SCORE_BACKEND`` environment variable.

The §3.3 *lite* sketch variant (``EngineSpec.sketch_kind="lite"``) rides the
existing one-sided machinery for free: with no ``l`` leaf the fused path
gathers only ``U`` rows and zeroes negative-coordinate contributions —
exactly the Sinnamon+ code path, now reachable on signed collections as a
memory/recall lever.  Quantized cells (bf16/f8) flow through every gather
unchanged and are upcast to f32 inside the tile (see
repro.kernels.sinnamon_score).
"""

from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import csr_score as _csr
from repro.kernels import embed_bag as _bag
from repro.kernels import sinnamon_score as _sinn

SCORE_BACKENDS = ("reference", "grouped", "pallas")
SCORE_BACKEND_ENV = "REPRO_SCORE_BACKEND"
DEFAULT_SCORE_BACKEND = "pallas"


def resolve_backend(backend: Optional[str] = None) -> str:
    """Validate an explicit backend choice or fall back to the env default."""
    if backend is None:
        backend = os.environ.get(SCORE_BACKEND_ENV, DEFAULT_SCORE_BACKEND)
    if backend not in SCORE_BACKENDS:
        raise ValueError(f"unknown score backend {backend!r}; "
                         f"expected one of {SCORE_BACKENDS}")
    return backend


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not on_tpu()


def pad_axis(x: jax.Array, axis: int, multiple: int, fill=0):
    size = x.shape[axis]
    target = ((size + multiple - 1) // multiple) * multiple
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=fill)


def prepare_query_operands(state, q_idx: jax.Array, q_val: jax.Array,
                           budget: Optional[int] = None, spec=None):
    """Engine state + padded sparse query -> (qv, rows, qbits) kernel operands.

    Sorts coordinates by |q[j]| descending (Algorithm 6 line 2), truncates to
    the anytime budget, gathers the h sketch-row ids and the membership words
    per kept coordinate.  Padded / out-of-budget coordinates get qv = 0.
    """
    L = q_idx.shape[-1] if budget is None else min(budget, q_idx.shape[-1])
    key = jnp.where(q_idx >= 0, jnp.abs(q_val.astype(jnp.float32)), -1.0)
    order = jnp.argsort(-key, axis=-1)[..., :L]
    idx_s = jnp.take_along_axis(q_idx, order, axis=-1)
    val_s = jnp.take_along_axis(q_val, order, axis=-1).astype(jnp.float32)
    valid = idx_s >= 0
    safe = jnp.where(valid, idx_s, 0)
    qv = jnp.where(valid, val_s, 0.0)
    rows = jnp.moveaxis(state.mappings[:, safe], 0, -1)       # [..., L, h]
    from repro.core import engine as _eng
    bit_rows = jnp.maximum(_eng.coord_rows(spec, idx_s), 0) if spec \
        is not None else safe
    qbits = state.bits[bit_rows]                               # [..., L, W]
    qbits = jnp.where(valid[..., None], qbits, jnp.uint32(0))
    return qv, rows, qbits


def sinnamon_score_batch(state, qv, rows, qbits, *, tile_c=None,
                         interpret=None):
    """Kernel-backed Algorithm 6 over a query batch. f32[B, C]."""
    C = state.u.shape[1]
    tile_c = tile_c or min(_sinn.DEFAULT_TILE_C, C)
    interpret = _interpret() if interpret is None else interpret
    u = pad_axis(state.u, 1, tile_c)
    l = None if state.l is None else pad_axis(state.l, 1, tile_c)
    qbits_p = pad_axis(qbits, -1, tile_c // 32)
    out = _sinn.sinnamon_score(qv, rows, qbits_p, u, l,
                               tile_c=tile_c, interpret=interpret)
    return out[:, :C]


def prepare_fused_operands(state, q_idx, q_val, budget=None, spec=None):
    """Query + state -> (qv, pos, rows, qbits, skmat, one_sided) for the
    fused kernel / XLA twin.

    On top of :func:`prepare_query_operands`: splits coordinate signs, stacks
    ``[U; L]`` into one gather matrix and pre-offsets negative coordinates'
    sketch rows by +m, so the fused path reads each sketch cell ONE-SIDED —
    half the decode work of the reference scorer.
    """
    qv, rows, qbits = prepare_query_operands(state, q_idx, q_val, budget,
                                             spec=spec)
    pos = qv > 0
    if state.l is None:
        return qv, pos, rows, qbits, state.u, False
    m = state.u.shape[0]
    skmat = jnp.concatenate([state.u, state.l], axis=0)       # [2m, C]
    rows = jnp.where(pos[..., None], rows, rows + m)
    return qv, pos, rows, qbits, skmat, True


def sinnamon_tile_topk(state, spec, q_idx, q_val, kprime, *, budget=None,
                       ok=None, tile_c=None, query_block=2,
                       use_kernel=None, interpret=None):
    """Sketch-scan stage of the fused path: per-tile candidates, pre-merge.

    Prepares sign-split operands, pads the slot axis to a tile multiple
    (padded slots are gated to -inf so they can never become candidates —
    works at any post-``grow()`` capacity) and runs the fused
    score→top-kp tile program.  Returns ``(vals f32[B, T, kp],
    slots int32[B, T, kp])`` still per-tile; feed through
    :func:`repro.kernels.sinnamon_score.merge_tile_topk` (or call
    :func:`sinnamon_topk_batch` which does both).  Split out so the staged
    query tracer can time sketch scan and top-k merge separately.
    """
    C = state.u.shape[1]
    if kprime > C:
        raise ValueError(f"kprime={kprime} > capacity {C}")
    use_kernel = on_tpu() if use_kernel is None else use_kernel
    if tile_c is None:
        full = _sinn.DEFAULT_TILE_C if use_kernel else _sinn.DEFAULT_TILE_C_XLA
        tile_c = min(full, ((C + 255) // 256) * 256)   # whole (padded) C if small
    qv, pos, rows, qbits, skmat, one_sided = prepare_fused_operands(
        state, q_idx, q_val, budget, spec=spec)
    skmat = pad_axis(skmat, 1, tile_c)
    qbits_p = pad_axis(qbits, -1, tile_c // 32)
    keep = jnp.ones((C,), jnp.bool_) if ok is None else ok
    gate = jnp.where(keep, 0.0, -jnp.inf).astype(jnp.float32)[None]
    gate = pad_axis(gate, -1, tile_c, fill=-jnp.inf)
    kp = min(kprime, tile_c)
    if use_kernel:
        interpret = _interpret() if interpret is None else interpret
        return _sinn.sinnamon_score_topk(
            qv, pos, rows, qbits_p, gate, skmat, kp=kp, tile_c=tile_c,
            one_sided=one_sided, interpret=interpret)
    return _sinn.fused_topk_xla(
        qv, pos, rows, qbits_p, gate, skmat, kp=kp, tile_c=tile_c,
        one_sided=one_sided, query_block=query_block)


def sinnamon_topk_batch(state, spec, q_idx, q_val, kprime, *, budget=None,
                        ok=None, tile_c=None, query_block=2,
                        use_kernel=None, interpret=None):
    """Fused candidate generation: (vals f32[B, kprime], slots int32[B, kprime]).

    The full search front half in one pipeline: the per-tile scan
    (:func:`sinnamon_tile_topk`) followed by the log-tree merge.

    Implementation selection: the Pallas kernel where it compiles (TPU), the
    XLA twin of the same tile program elsewhere (CPU serving); pass
    ``use_kernel=True`` to force the kernel (interpret-mode validation).

    ``ok``: optional bool[C] keep-mask (active & filter); ordering of the
    result is (upper-bound desc, slot asc) — lax.top_k order over the gated
    fused scores.
    """
    vals, slots = sinnamon_tile_topk(
        state, spec, q_idx, q_val, kprime, budget=budget, ok=ok,
        tile_c=tile_c, query_block=query_block, use_kernel=use_kernel,
        interpret=interpret)
    return _sinn.merge_tile_topk(vals, slots, kprime)


def make_engine_score_fn(tile_c=None, interpret=None):
    """A drop-in ``score_fn`` for `repro.core.engine.search` (single query)."""

    def score_fn(state, spec, q_idx, q_val, budget=None):
        qv, rows, qbits = prepare_query_operands(
            state, q_idx[None], q_val[None], budget, spec=spec)
        return sinnamon_score_batch(state, qv, rows, qbits, tile_c=tile_c,
                                    interpret=interpret)[0]

    return score_fn


def exact_scores_all(store, q_dense, *, tile_c=None, interpret=None):
    """Kernel-backed exact document-ordered scan (TPU-native LinScan)."""
    C = store.indices.shape[0]
    tile_c = tile_c or min(_csr.DEFAULT_TILE_C, C)
    interpret = _interpret() if interpret is None else interpret
    idx = pad_axis(store.indices, 0, tile_c, fill=-1)
    val = pad_axis(store.values, 0, tile_c)
    qd = pad_axis(q_dense, 0, 128)
    return _csr.csr_score(qd, idx, val, tile_c=tile_c,
                          interpret=interpret)[:C]


def embed_bag(table, indices, weights=None, *, mode="sum", interpret=None):
    """EmbeddingBag(sum|mean) built on the Pallas gather kernel."""
    interpret = _interpret() if interpret is None else interpret
    B, F = indices.shape
    if weights is None:
        weights = jnp.ones((B, F), jnp.float32)
    if mode == "mean":
        counts = jnp.maximum((indices >= 0).sum(-1, keepdims=True), 1)
        weights = weights / counts
    elif mode != "sum":
        raise ValueError(mode)
    return _bag.embed_bag(table, indices, weights, interpret=interpret)
