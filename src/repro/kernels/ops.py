"""jit'd public wrappers around the Pallas kernels.

Handles operand preparation (query sorting/budgeting, membership-row
gathering, tile padding) and backend selection: compiled Pallas on TPU,
interpret mode elsewhere (this container is CPU-only; interpret mode executes
the kernel body in Python and is the mandated validation path).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import csr_score as _csr
from repro.kernels import embed_bag as _bag
from repro.kernels import sinnamon_score as _sinn


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not on_tpu()


def pad_axis(x: jax.Array, axis: int, multiple: int, fill=0):
    size = x.shape[axis]
    target = ((size + multiple - 1) // multiple) * multiple
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=fill)


def prepare_query_operands(state, q_idx: jax.Array, q_val: jax.Array,
                           budget: Optional[int] = None, spec=None):
    """Engine state + padded sparse query -> (qv, rows, qbits) kernel operands.

    Sorts coordinates by |q[j]| descending (Algorithm 6 line 2), truncates to
    the anytime budget, gathers the h sketch-row ids and the membership words
    per kept coordinate.  Padded / out-of-budget coordinates get qv = 0.
    """
    L = q_idx.shape[-1] if budget is None else min(budget, q_idx.shape[-1])
    key = jnp.where(q_idx >= 0, jnp.abs(q_val.astype(jnp.float32)), -1.0)
    order = jnp.argsort(-key, axis=-1)[..., :L]
    idx_s = jnp.take_along_axis(q_idx, order, axis=-1)
    val_s = jnp.take_along_axis(q_val, order, axis=-1).astype(jnp.float32)
    valid = idx_s >= 0
    safe = jnp.where(valid, idx_s, 0)
    qv = jnp.where(valid, val_s, 0.0)
    rows = jnp.moveaxis(state.mappings[:, safe], 0, -1)       # [..., L, h]
    from repro.core import engine as _eng
    bit_rows = jnp.maximum(_eng.coord_rows(spec, idx_s), 0) if spec \
        is not None else safe
    qbits = state.bits[bit_rows]                               # [..., L, W]
    qbits = jnp.where(valid[..., None], qbits, jnp.uint32(0))
    return qv, rows, qbits


def sinnamon_score_batch(state, qv, rows, qbits, *, tile_c=None,
                         interpret=None):
    """Kernel-backed Algorithm 6 over a query batch. f32[B, C]."""
    C = state.u.shape[1]
    tile_c = tile_c or min(_sinn.DEFAULT_TILE_C, C)
    interpret = _interpret() if interpret is None else interpret
    u = pad_axis(state.u, 1, tile_c)
    l = None if state.l is None else pad_axis(state.l, 1, tile_c)
    qbits_p = pad_axis(qbits, -1, tile_c // 32)
    out = _sinn.sinnamon_score(qv, rows, qbits_p, u, l,
                               tile_c=tile_c, interpret=interpret)
    return out[:, :C]


def make_engine_score_fn(tile_c=None, interpret=None):
    """A drop-in ``score_fn`` for `repro.core.engine.search` (single query)."""

    def score_fn(state, spec, q_idx, q_val, budget=None):
        qv, rows, qbits = prepare_query_operands(
            state, q_idx[None], q_val[None], budget, spec=spec)
        return sinnamon_score_batch(state, qv, rows, qbits, tile_c=tile_c,
                                    interpret=interpret)[0]

    return score_fn


def exact_scores_all(store, q_dense, *, tile_c=None, interpret=None):
    """Kernel-backed exact document-ordered scan (TPU-native LinScan)."""
    C = store.indices.shape[0]
    tile_c = tile_c or min(_csr.DEFAULT_TILE_C, C)
    interpret = _interpret() if interpret is None else interpret
    idx = pad_axis(store.indices, 0, tile_c, fill=-1)
    val = pad_axis(store.values, 0, tile_c)
    qd = pad_axis(q_dense, 0, 128)
    return _csr.csr_score(qd, idx, val, tile_c=tile_c,
                          interpret=interpret)[:C]


def embed_bag(table, indices, weights=None, *, mode="sum", interpret=None):
    """EmbeddingBag(sum|mean) built on the Pallas gather kernel."""
    interpret = _interpret() if interpret is None else interpret
    B, F = indices.shape
    if weights is None:
        weights = jnp.ones((B, F), jnp.float32)
    if mode == "mean":
        counts = jnp.maximum((indices >= 0).sum(-1, keepdims=True), 1)
        weights = weights / counts
    elif mode != "sum":
        raise ValueError(mode)
    return _bag.embed_bag(table, indices, weights, interpret=interpret)
