"""Pallas TPU kernels for the paper's compute hot-spots.

  sinnamon_score — Algorithm 6 scoring (tile-resident sketch + bitmask)
  csr_score      — exact padded-CSR scan (LinScan / Algorithm 7 rerank)
  embed_bag      — EmbeddingBag gather-reduce (recsys substrate)

Each kernel has a pure-jnp oracle in ref.py and a jit'd wrapper in ops.py.
Validated in interpret mode on CPU; compiled pl.pallas_call on TPU.
"""
