"""Pallas TPU kernel for exact padded-CSR scoring.

Used twice in the system:
  * TPU-native exact LinScan (document-ordered scan of the whole store);
  * Algorithm 7's exact rerank (same kernel over the gathered k' rows).

The dense query vector (n up to a few hundred thousand → ≤1 MiB fp32) stays
resident in VMEM across all document tiles; each grid step streams a
``(TC, P)`` block of indices/values, gathers ``q[idx]`` and reduces the
masked products along P.  Arithmetic intensity is ~1 FLOP per 6 bytes — this
kernel is memory-bound by design, and its roofline term is the exact-scan
baseline Sinnamon's sketch is compared against in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_C = 1024


def _kernel(q_ref, idx_ref, val_ref, out_ref):
    qd = q_ref[...]                         # [n] resident
    idx = idx_ref[...]                      # [TC, P]
    val = val_ref[...].astype(jnp.float32)  # [TC, P]
    valid = idx >= 0
    safe = jnp.where(valid, idx, 0)
    gathered = jnp.take(qd, safe, axis=0)   # [TC, P]
    out_ref[...] = jnp.sum(jnp.where(valid, gathered * val, 0.0), axis=-1)


@functools.partial(jax.jit, static_argnames=("tile_c", "interpret"))
def csr_score(
    q_dense: jax.Array,          # f32[n]
    indices: jax.Array,          # int32[C, P]
    values: jax.Array,           # [C, P]
    *,
    tile_c: int = DEFAULT_TILE_C,
    interpret: bool = True,
) -> jax.Array:
    """Exact scores f32[C] for one query."""
    C, P = indices.shape
    n = q_dense.shape[0]
    if C % tile_c != 0:
        raise ValueError(f"C={C} must be a multiple of tile_c={tile_c}")
    return pl.pallas_call(
        _kernel,
        grid=(C // tile_c,),
        in_specs=[
            pl.BlockSpec((n,), lambda c: (0,)),
            pl.BlockSpec((tile_c, P), lambda c: (c, 0)),
            pl.BlockSpec((tile_c, P), lambda c: (c, 0)),
        ],
        out_specs=pl.BlockSpec((tile_c,), lambda c: (c,)),
        out_shape=jax.ShapeDtypeStruct((C,), jnp.float32),
        interpret=interpret,
    )(q_dense, indices, values)
