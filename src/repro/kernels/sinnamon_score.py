"""Pallas TPU kernel for Sinnamon scoring (paper Algorithm 6).

This is the paper's hot spot: for each query coordinate, read h sketch rows,
take the elementwise min (max for the lower sketch), mask by the bit-packed
inverted index, scale by q[j] and accumulate.

TPU schedule (the *beyond-paper* tile-resident formulation — see DESIGN.md §2):
the grid walks document tiles of size ``TC`` along the slot axis; the full
sketch block ``[m, TC]`` is resident in VMEM while **all** budgeted query
coordinates stream over it, so each sketch tile is fetched from HBM exactly
once per query (the faithful coordinate-at-a-time order would fetch ``h``
rows per coordinate — same arithmetic, ψ_q·h/m× the HBM traffic when
ψ_q·h > m).  Membership words are pre-gathered per query coordinate
(``uint32[L, TC/32]`` per tile) and unpacked lane-wise in-kernel.

Block shapes: sketches ``(m, TC)``, membership ``(1, L, TW)``, scores
``(1, TC)`` with ``TC`` a multiple of 128 lanes (f32 tile 8×128; the m axis is
the sublane axis).  VMEM footprint ≈ 2·m·TC·2B + L·TC/8 + TC·4B — e.g.
m=128, TC=2048, L=64: 1.1 MiB, comfortably inside the ~16 MiB VMEM budget.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_C = 2048


def _kernel(qv_ref, rows_ref, qbits_ref, u_ref, l_ref, out_ref, *,
            budget: int, h: int, tile_c: int):
    U = u_ref[...].astype(jnp.float32)                    # [m, TC]
    L = None if l_ref is None else l_ref[...].astype(jnp.float32)
    qv = qv_ref[0]                                        # [Lq]
    rows = rows_ref[0]                                    # [Lq, h]
    words = qbits_ref[0]                                  # [Lq, TW]
    shifts = jnp.arange(32, dtype=jnp.uint32)

    def body(t, acc):
        r = rows[t]
        ub = jax.lax.dynamic_index_in_dim(U, r[0], 0, keepdims=False)
        for o in range(1, h):
            ub = jnp.minimum(
                ub, jax.lax.dynamic_index_in_dim(U, r[o], 0, keepdims=False))
        if L is None:
            lb = jnp.zeros_like(ub)
        else:
            lb = jax.lax.dynamic_index_in_dim(L, r[0], 0, keepdims=False)
            for o in range(1, h):
                lb = jnp.maximum(
                    lb, jax.lax.dynamic_index_in_dim(L, r[o], 0, keepdims=False))
        v = qv[t]
        contrib = jnp.where(v > 0, v * ub, v * lb)
        w = words[t]                                      # [TW] uint32
        mask = ((w[:, None] >> shifts) & 1).reshape(tile_c) != 0
        return acc + jnp.where(mask, contrib, 0.0)

    acc = jax.lax.fori_loop(0, budget, body,
                            jnp.zeros((tile_c,), jnp.float32))
    out_ref[0, :] = acc


@functools.partial(jax.jit, static_argnames=("tile_c", "interpret"))
def sinnamon_score(
    qv: jax.Array,               # f32[B, L]
    rows: jax.Array,             # int32[B, L, h]
    qbits: jax.Array,            # uint32[B, L, W]  (W = C/32)
    u: jax.Array,                # [m, C]
    l: Optional[jax.Array] = None,
    *,
    tile_c: int = DEFAULT_TILE_C,
    interpret: bool = True,
) -> jax.Array:
    """Upper-bound scores f32[B, C].  Grid = (B, C / tile_c)."""
    B, Lq = qv.shape
    h = rows.shape[-1]
    m, C = u.shape
    if C % tile_c != 0:
        raise ValueError(f"C={C} must be a multiple of tile_c={tile_c}")
    tw = tile_c // 32
    grid = (B, C // tile_c)

    in_specs = [
        pl.BlockSpec((1, Lq), lambda b, c: (b, 0)),            # qv
        pl.BlockSpec((1, Lq, h), lambda b, c: (b, 0, 0)),      # rows
        pl.BlockSpec((1, Lq, tw), lambda b, c: (b, 0, c)),     # qbits
        pl.BlockSpec((m, tile_c), lambda b, c: (0, c)),        # u
    ]
    args = [qv, rows, qbits, u]
    if l is not None:
        in_specs.append(pl.BlockSpec((m, tile_c), lambda b, c: (0, c)))
        args.append(l)
        kern = functools.partial(_kernel, budget=Lq, h=h, tile_c=tile_c)
    else:
        kern = functools.partial(
            lambda qv_ref, rows_ref, qbits_ref, u_ref, out_ref, **kw:
            _kernel(qv_ref, rows_ref, qbits_ref, u_ref, None, out_ref, **kw),
            budget=Lq, h=h, tile_c=tile_c)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, tile_c), lambda b, c: (b, c)),
        out_shape=jax.ShapeDtypeStruct((B, C), jnp.float32),
        interpret=interpret,
    )(*args)
