"""Pallas TPU kernel for Sinnamon scoring (paper Algorithm 6).

This is the paper's hot spot: for each query coordinate, read h sketch rows,
take the elementwise min (max for the lower sketch), mask by the bit-packed
inverted index, scale by q[j] and accumulate.

TPU schedule (the *beyond-paper* tile-resident formulation — see DESIGN.md §2):
the grid walks document tiles of size ``TC`` along the slot axis; the full
sketch block ``[m, TC]`` is resident in VMEM while **all** budgeted query
coordinates stream over it, so each sketch tile is fetched from HBM exactly
once per query (the faithful coordinate-at-a-time order would fetch ``h``
rows per coordinate — same arithmetic, ψ_q·h/m× the HBM traffic when
ψ_q·h > m).  Membership words are pre-gathered per query coordinate
(``uint32[L, TC/32]`` per tile) and unpacked lane-wise in-kernel.

Block shapes: sketches ``(m, TC)``, membership ``(1, L, TW)``, scores
``(1, TC)`` with ``TC`` a multiple of 128 lanes (f32 tile 8×128; the m axis is
the sublane axis).  VMEM footprint ≈ 2·m·TC·2B + L·TC/8 + TC·4B — e.g.
m=128, TC=2048, L=64: 1.1 MiB, comfortably inside the ~16 MiB VMEM budget.

Two entry points share the schedule:

* :func:`sinnamon_score` — the original dense variant, returns ``f32[B, C]``.
* :func:`sinnamon_score_topk` — the FUSED serving variant: each grid tile
  reduces its ``TC`` upper-bound scores to a ``kp``-candidate buffer
  (scores + global slot ids) **in-kernel**, so the full ``[B, C]`` score
  matrix never exists.  Tile buffers are then combined by
  :func:`merge_tile_topk`, a log-tree merge that sorts on the explicit key
  (score desc, slot asc) — the exact tie order of ``lax.top_k`` over a
  dense score vector.

The fused variant also changes the decode schedule (the perf tentpole):

* ONE-SIDED gathers: Algorithm 6 needs ``u``-cells only where ``q[j] > 0``
  and ``l``-cells only where ``q[j] < 0``, so the wrapper concatenates
  ``[U; L]`` into one ``[2m, C]`` matrix and pre-offsets each coordinate's
  sketch rows by the query sign — HALF the gather + reduce work of the
  reference decode, which always reads both sides.
* VECTORIZED coordinates: all budgeted coordinates form one ``[L, TC]``
  contribution block reduced in a single pass, instead of ψ_q sequential
  read-modify-write sweeps of the accumulator.  (Summation association
  differs from the sequential reference in the last ulp; candidate slots —
  and therefore the exact-reranked ids — are asserted identical in tests.)

Quantized sketch cells (``EngineSpec.dtype`` = f32 | bf16 | f8) are decoded
*inside* the tile loop: every entry point gathers the narrow cells and
upcasts with ``.astype(f32)`` after the gather, so the HBM-resident sketch —
and the VMEM block the grid streams — stays at the narrow storage width and
the f32 math is confined to the tile registers.

:func:`fused_topk_xla` is the same tile program expressed as a lax.scan for
backends without a compiled Pallas lowering (CPU serving): identical math,
identical tile shapes, no per-grid-step interpreter overhead.  Interpret-mode
``pallas_call`` remains the kernel-validation path (tests assert kernel ==
twin == dense oracle on the same operands).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_C = 2048
# CPU/XLA-twin tile: big tiles amortize per-tile top_k and scan overhead on
# CPU (no VMEM ceiling); the TPU kernel keeps the VMEM-sized DEFAULT_TILE_C.
DEFAULT_TILE_C_XLA = 8192
_SLOT_SENTINEL = jnp.iinfo(jnp.int32).max


def _accumulate(qv_ref, rows_ref, qbits_ref, u_ref, l_ref, *,
                budget: int, h: int, tile_c: int):
    """Shared Algorithm 6 inner loop: upper-bound scores f32[TC] of one tile.

    Accumulates coordinate contributions SEQUENTIALLY (fori_loop) in the
    sorted-|q[j]| order, i.e. the exact same f32 add sequence per slot as the
    reference ``engine.score`` loop — the scores (and therefore any top-k cut
    over them) come out bit-identical to the reference backend.
    """
    U = u_ref[...].astype(jnp.float32)                    # [m, TC]
    L = None if l_ref is None else l_ref[...].astype(jnp.float32)
    qv = qv_ref[0]                                        # [Lq]
    rows = rows_ref[0]                                    # [Lq, h]
    words = qbits_ref[0]                                  # [Lq, TW]
    shifts = jnp.arange(32, dtype=jnp.uint32)

    def body(t, acc):
        r = rows[t]
        ub = jax.lax.dynamic_index_in_dim(U, r[0], 0, keepdims=False)
        for o in range(1, h):
            ub = jnp.minimum(
                ub, jax.lax.dynamic_index_in_dim(U, r[o], 0, keepdims=False))
        if L is None:
            lb = jnp.zeros_like(ub)
        else:
            lb = jax.lax.dynamic_index_in_dim(L, r[0], 0, keepdims=False)
            for o in range(1, h):
                lb = jnp.maximum(
                    lb, jax.lax.dynamic_index_in_dim(L, r[o], 0, keepdims=False))
        v = qv[t]
        contrib = jnp.where(v > 0, v * ub, v * lb)
        w = words[t]                                      # [TW] uint32
        mask = ((w[:, None] >> shifts) & 1).reshape(tile_c) != 0
        return acc + jnp.where(mask, contrib, 0.0)

    return jax.lax.fori_loop(0, budget, body,
                             jnp.zeros((tile_c,), jnp.float32))


def _kernel(qv_ref, rows_ref, qbits_ref, u_ref, l_ref, out_ref, *,
            budget: int, h: int, tile_c: int):
    out_ref[0, :] = _accumulate(qv_ref, rows_ref, qbits_ref, u_ref, l_ref,
                                budget=budget, h=h, tile_c=tile_c)


def _fused_tile_scores(qv, pos, rows, words, gate, skmat, *, h: int,
                       one_sided: bool, tile_c: int):
    """Gated upper-bound scores of one tile block — the SHARED fused math.

    Both the Pallas kernel body and the XLA twin call exactly this function
    on identically-shaped operands, so the two lower to the same per-slot
    float program (tests assert bitwise equality).

    qv/pos:  f32/bool[..., L]    query values and their signs
    rows:    int32[..., L, h]    sketch rows, PRE-OFFSET by +m for negative
                                 coordinates when one_sided (see the wrapper)
    words:   uint32[..., L, TW]  membership words of this tile
    gate:    f32[TC]             0 keep / -inf excluded
    skmat:   f32-castable[R, TC] [U; L] rows of this tile (R = 2m, or m when
                                 the engine runs positive-only)
    """
    sk = skmat.astype(jnp.float32)
    x = sk[rows[..., 0]]                                   # [..., L, TC]
    for o in range(1, h):
        y = sk[rows[..., o]]
        if one_sided:
            # positive coords decode U (least upper bound -> min); negative
            # coords decode L (greatest lower bound -> max).
            x = jnp.where(pos[..., None], jnp.minimum(x, y),
                          jnp.maximum(x, y))
        else:
            x = jnp.minimum(x, y)
    if not one_sided:
        # positive-only engine: l == 0 exactly, so q<0 contributes q*0.
        x = jnp.where(pos[..., None], x, 0.0)
    contrib = qv[..., None] * x
    shifts = jnp.arange(32, dtype=jnp.uint32)
    mask = ((words[..., :, None] >> shifts) & 1).reshape(
        *words.shape[:-1], tile_c) != 0
    s = jnp.sum(jnp.where(mask, contrib, 0.0), axis=-2)    # [..., TC]
    return jnp.where(gate == 0.0, s, -jnp.inf)


def _topk_kernel(qv_ref, pos_ref, rows_ref, qbits_ref, gate_ref, sk_ref,
                 val_ref, slot_ref, *, h: int, tile_c: int, kp: int,
                 one_sided: bool):
    """Fused tile: score, gate (active/filter/pad -> -inf), reduce to top-kp.

    In-tile selection is ``lax.top_k``, whose tie order (lower index first)
    is (score desc, slot asc) — the same key the tree merge sorts on.
    """
    s = _fused_tile_scores(qv_ref[0], pos_ref[0], rows_ref[0], qbits_ref[0],
                           gate_ref[0], sk_ref[...], h=h,
                           one_sided=one_sided, tile_c=tile_c)
    v, i = jax.lax.top_k(s, kp)
    base = pl.program_id(1) * tile_c
    val_ref[0, 0, :] = v
    slot_ref[0, 0, :] = (base + i).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("tile_c", "interpret"))
def sinnamon_score(
    qv: jax.Array,               # f32[B, L]
    rows: jax.Array,             # int32[B, L, h]
    qbits: jax.Array,            # uint32[B, L, W]  (W = C/32)
    u: jax.Array,                # [m, C]
    l: Optional[jax.Array] = None,
    *,
    tile_c: int = DEFAULT_TILE_C,
    interpret: bool = True,
) -> jax.Array:
    """Upper-bound scores f32[B, C].  Grid = (B, C / tile_c)."""
    B, Lq = qv.shape
    h = rows.shape[-1]
    m, C = u.shape
    if C % tile_c != 0:
        raise ValueError(f"C={C} must be a multiple of tile_c={tile_c}")
    tw = tile_c // 32
    grid = (B, C // tile_c)

    in_specs = [
        pl.BlockSpec((1, Lq), lambda b, c: (b, 0)),            # qv
        pl.BlockSpec((1, Lq, h), lambda b, c: (b, 0, 0)),      # rows
        pl.BlockSpec((1, Lq, tw), lambda b, c: (b, 0, c)),     # qbits
        pl.BlockSpec((m, tile_c), lambda b, c: (0, c)),        # u
    ]
    args = [qv, rows, qbits, u]
    if l is not None:
        in_specs.append(pl.BlockSpec((m, tile_c), lambda b, c: (0, c)))
        args.append(l)
        kern = functools.partial(_kernel, budget=Lq, h=h, tile_c=tile_c)
    else:
        kern = functools.partial(
            lambda qv_ref, rows_ref, qbits_ref, u_ref, out_ref, **kw:
            _kernel(qv_ref, rows_ref, qbits_ref, u_ref, None, out_ref, **kw),
            budget=Lq, h=h, tile_c=tile_c)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, tile_c), lambda b, c: (b, c)),
        out_shape=jax.ShapeDtypeStruct((B, C), jnp.float32),
        interpret=interpret,
    )(*args)


@functools.partial(jax.jit,
                   static_argnames=("kp", "tile_c", "one_sided", "interpret"))
def sinnamon_score_topk(
    qv: jax.Array,               # f32[B, L]
    pos: jax.Array,              # bool[B, L]   q[j] > 0
    rows: jax.Array,             # int32[B, L, h]  (pre-offset when one_sided)
    qbits: jax.Array,            # uint32[B, L, W]  (W = C/32)
    gate: jax.Array,             # f32[1, C]: 0 keep / -inf excluded (or pad)
    skmat: jax.Array,            # [R, C]  [U; L] stacked (R = 2m, or m)
    *,
    kp: int,
    tile_c: int = DEFAULT_TILE_C,
    one_sided: bool = True,
    interpret: bool = True,
) -> tuple:
    """Fused scoring + per-tile top-kp.  Returns (vals f32[B, T, kp],
    slots int32[B, T, kp]) with T = C / tile_c; feed to merge_tile_topk.

    Operand preparation (sign split, row offsetting, [U; L] stacking, tile
    padding) lives in repro.kernels.ops.sinnamon_topk_batch.
    """
    B, Lq = qv.shape
    h = rows.shape[-1]
    R, C = skmat.shape
    if C % tile_c != 0:
        raise ValueError(f"C={C} must be a multiple of tile_c={tile_c}")
    if kp > tile_c:
        raise ValueError(f"kp={kp} cannot exceed tile_c={tile_c}")
    tw = tile_c // 32
    T = C // tile_c
    grid = (B, T)

    in_specs = [
        pl.BlockSpec((1, Lq), lambda b, c: (b, 0)),            # qv
        pl.BlockSpec((1, Lq), lambda b, c: (b, 0)),            # pos
        pl.BlockSpec((1, Lq, h), lambda b, c: (b, 0, 0)),      # rows
        pl.BlockSpec((1, Lq, tw), lambda b, c: (b, 0, c)),     # qbits
        pl.BlockSpec((1, tile_c), lambda b, c: (0, c)),        # gate
        pl.BlockSpec((R, tile_c), lambda b, c: (0, c)),        # [U; L]
    ]
    kern = functools.partial(_topk_kernel, h=h, tile_c=tile_c, kp=kp,
                             one_sided=one_sided)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=(pl.BlockSpec((1, 1, kp), lambda b, c: (b, c, 0)),
                   pl.BlockSpec((1, 1, kp), lambda b, c: (b, c, 0))),
        out_shape=(jax.ShapeDtypeStruct((B, T, kp), jnp.float32),
                   jax.ShapeDtypeStruct((B, T, kp), jnp.int32)),
        interpret=interpret,
    )(qv, pos, rows, qbits, gate, skmat)


@functools.partial(jax.jit,
                   static_argnames=("kp", "tile_c", "one_sided",
                                    "query_block"))
def fused_topk_xla(
    qv: jax.Array,               # f32[B, L]
    pos: jax.Array,              # bool[B, L]
    rows: jax.Array,             # int32[B, L, h]  (pre-offset when one_sided)
    qbits: jax.Array,            # uint32[B, L, W]
    gate: jax.Array,             # f32[1, C]
    skmat: jax.Array,            # [R, C]
    *,
    kp: int,
    tile_c: int = DEFAULT_TILE_C_XLA,
    one_sided: bool = True,
    query_block: int = 2,
) -> tuple:
    """XLA twin of :func:`sinnamon_score_topk`: same operands, same per-tile
    math (:func:`_fused_tile_scores`), same (vals, slots)[B, T, kp] output.

    The grid becomes lax.map over query blocks × lax.scan over slot tiles,
    which is how the tile program runs fast on backends where Pallas only has
    the (per-grid-step interpreted) validation lowering.  Query blocks bound
    the [QB, L, TC] working set exactly like the kernel's VMEM block does.
    """
    B, Lq = qv.shape
    h = rows.shape[-1]
    R, C = skmat.shape
    if C % tile_c != 0:
        raise ValueError(f"C={C} must be a multiple of tile_c={tile_c}")
    if kp > tile_c:
        raise ValueError(f"kp={kp} cannot exceed tile_c={tile_c}")
    tw = tile_c // 32
    T = C // tile_c
    qb = min(query_block, B)
    nb = (B + qb - 1) // qb
    pad_b = nb * qb - B

    def pad(x):
        return jnp.pad(x, [(0, pad_b)] + [(0, 0)] * (x.ndim - 1))

    qv_b = pad(qv).reshape(nb, qb, Lq)
    pos_b = pad(pos).reshape(nb, qb, Lq)
    rows_b = pad(rows).reshape(nb, qb, Lq, h)
    qbits_b = pad(qbits).reshape(nb, qb, Lq, T, tw)
    sk_t = jnp.moveaxis(skmat.reshape(R, T, tile_c), 1, 0)   # [T, R, TC]
    gate_t = gate.reshape(T, tile_c)

    def one_block(args):
        bqv, bpos, brows, bqbits = args                      # [qb, ...]

        def tile_step(carry, xs):
            sk_tile, g_tile, words, base = xs
            s = _fused_tile_scores(bqv, bpos, brows, words, g_tile, sk_tile,
                                   h=h, one_sided=one_sided, tile_c=tile_c)
            v, i = jax.lax.top_k(s, kp)                      # [qb, kp]
            return carry, (v, (base * tile_c + i).astype(jnp.int32))

        xs = (sk_t, gate_t, jnp.moveaxis(bqbits, 2, 0), jnp.arange(T))
        _, (vs, ss) = jax.lax.scan(tile_step, 0, xs)         # [T, qb, kp]
        return jnp.moveaxis(vs, 0, 1), jnp.moveaxis(ss, 0, 1)

    vals, slots = jax.lax.map(one_block, (qv_b, pos_b, rows_b, qbits_b))
    vals = vals.reshape(nb * qb, T, kp)[:B]
    slots = slots.reshape(nb * qb, T, kp)[:B]
    return vals, slots


def _sorted_merge(neg: jax.Array, slots: jax.Array, width: int) -> tuple:
    """Sort candidate rows by (neg score asc, slot asc) and keep ``width``."""
    neg, slots = jax.lax.sort((neg, slots), dimension=-1, num_keys=2)
    return neg[..., :width], slots[..., :width]


def merge_tile_topk(vals: jax.Array, slots: jax.Array, kprime: int) -> tuple:
    """Log-tree merge of per-tile candidate buffers -> global top-kprime.

    vals/slots: [B, T, kp] per-tile candidates, each tile already ordered by
    (score desc, slot asc).  Adjacent tiles are merged pairwise with a
    two-key sort on (-score, slot), so the final [B, kprime] list carries the
    exact (score desc, slot asc) order of ``lax.top_k`` over the dense score
    vector — including the all--inf tail when fewer than kprime slots
    survive the gate.  Requires T * kp >= kprime (guaranteed by the wrapper:
    kp = min(kprime, tile_c) and T * tile_c >= C >= kprime).
    """
    B, T, kp = vals.shape
    neg = -vals
    while T > 1:
        if T % 2:
            # Odd tile count: add a dummy tile that sorts after everything
            # (score -inf AND the max slot key), so it can never displace a
            # real candidate nor perturb the -inf tie order.
            neg = jnp.concatenate(
                [neg, jnp.full((B, 1, kp), jnp.inf, neg.dtype)], axis=1)
            slots = jnp.concatenate(
                [slots, jnp.full((B, 1, kp), _SLOT_SENTINEL, slots.dtype)],
                axis=1)
            T += 1
        width = min(kprime, 2 * kp)
        neg = neg.reshape(B, T // 2, 2 * kp)
        slots = slots.reshape(B, T // 2, 2 * kp)
        neg, slots = _sorted_merge(neg, slots, width)
        T //= 2
        kp = width
    return -neg[:, 0, :kprime], slots[:, 0, :kprime]
