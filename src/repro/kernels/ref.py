"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` takes exactly the same (already prepared/padded) operands as
its kernel and is the correctness contract: tests sweep shapes/dtypes and
assert allclose between kernel (interpret mode on CPU; compiled on TPU) and
these references.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def sinnamon_score_ref(
    qv: jax.Array,        # f32[B, L]      query values (sorted, 0-padded)
    rows: jax.Array,      # int32[B, L, h] sketch rows per coordinate (π_o(j))
    qbits: jax.Array,     # uint32[B, L, W] membership words per coordinate
    u: jax.Array,         # [m, C]         upper-bound sketch
    l: Optional[jax.Array],  # [m, C] or None (Sinnamon+)
) -> jax.Array:
    """Upper-bound scores f32[B, C] — dense Algorithm 6."""
    C = u.shape[1]
    uf = u.astype(jnp.float32)
    lf = None if l is None else l.astype(jnp.float32)

    def one_query(qv1, rows1, qbits1):
        def body(t, acc):
            r = rows1[t]                                   # [h]
            ub = jnp.min(uf[r], axis=0)                    # [C]
            lb = jnp.zeros_like(ub) if lf is None else jnp.max(lf[r], axis=0)
            v = qv1[t]
            contrib = jnp.where(v > 0, v * ub, v * lb)
            words = qbits1[t]                              # [W]
            shifts = jnp.arange(32, dtype=jnp.uint32)
            mask = ((words[:, None] >> shifts) & 1).reshape(C).astype(jnp.bool_)
            return acc + jnp.where(mask, contrib, 0.0)

        return jax.lax.fori_loop(0, qv1.shape[0], body,
                                 jnp.zeros((C,), jnp.float32))

    return jax.vmap(one_query)(qv, rows, qbits)


def sinnamon_topk_ref(
    qv: jax.Array,        # f32[B, L]
    rows: jax.Array,      # int32[B, L, h]  (UN-offset: always indexes [0, m))
    qbits: jax.Array,     # uint32[B, L, W]
    gate: jax.Array,      # f32[1, C]: 0 keep / -inf excluded
    u: jax.Array,         # [m, C]
    l: Optional[jax.Array],
    kprime: int,
):
    """Dense oracle for the fused path: score, gate, global lax.top_k.

    Independent formulation: decodes BOTH sketch sides per coordinate and
    where-selects by query sign (the fused path gathers one-sided — the two
    are elementwise identical), sums all coordinate contributions in one
    dense [B, L, C] pass, then takes a global top-k.  Returns
    (vals f32[B, kprime], slots int32[B, kprime]) in lax.top_k order
    (score desc, ties by slot asc) — the contract sinnamon_score_topk +
    merge_tile_topk (and the XLA twin) must reproduce bit-for-bit.
    """
    B, Lq = qv.shape
    C = u.shape[1]
    uf = u.astype(jnp.float32)
    ub = jnp.min(uf[rows], axis=-2)                         # [B, L, C]
    if l is None:
        lb = jnp.zeros_like(ub)
    else:
        lb = jnp.max(l.astype(jnp.float32)[rows], axis=-2)
    contrib = jnp.where(qv[..., None] > 0, qv[..., None] * ub,
                        qv[..., None] * lb)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    mask = ((qbits[..., :, None] >> shifts) & 1).reshape(B, Lq, C) != 0
    s = jnp.sum(jnp.where(mask, contrib, 0.0), axis=1)      # [B, C]
    s = jnp.where(gate == 0.0, s, -jnp.inf)
    vals, slots = jax.lax.top_k(s, kprime)
    return vals, slots.astype(jnp.int32)


def csr_score_ref(
    q_dense: jax.Array,   # f32[n]
    indices: jax.Array,   # int32[C, P], pad = -1
    values: jax.Array,    # [C, P]
) -> jax.Array:
    """Exact scores f32[C] of one dense query against padded-CSR documents."""
    valid = indices >= 0
    safe = jnp.where(valid, indices, 0)
    qv = q_dense[safe]
    return jnp.sum(jnp.where(valid, qv * values.astype(jnp.float32), 0.0),
                   axis=-1)


def embed_bag_ref(
    table: jax.Array,     # [V, D]
    indices: jax.Array,   # int32[B, F], pad = -1
    weights: jax.Array,   # f32[B, F]  (0 at padded positions; mean folded in)
) -> jax.Array:
    """Weighted-sum embedding bag f32[B, D]."""
    valid = indices >= 0
    safe = jnp.where(valid, indices, 0)
    rows = table[safe].astype(jnp.float32)                  # [B, F, D]
    w = jnp.where(valid, weights, 0.0)
    return jnp.einsum("bfd,bf->bd", rows, w)
