"""Pallas TPU embedding-bag kernel (gather + weighted segment reduce).

JAX has no native ``nn.EmbeddingBag``; the recsys substrate builds it here.
The table lives in HBM and is far too large for VMEM, so the kernel uses the
canonical Pallas-TPU gather idiom: the grid walks the flattened (bag, feature)
space and the *table's BlockSpec index_map reads the feature id from a
scalar-prefetch operand*, so each grid step DMAs exactly one embedding row
``(1, D)`` into VMEM.  The output block revisits the same bag row for F
consecutive steps, initialising on the first and accumulating in place.

Padded feature slots carry weight 0 and a clamped index of 0 — they fetch row
0 and add nothing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, table_ref, w_ref, out_ref, *, F: int):
    i = pl.program_id(0)
    f = i % F

    @pl.when(f == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w = w_ref[0, f]
    out_ref[0, :] += table_ref[0, :].astype(jnp.float32) * w


@functools.partial(jax.jit, static_argnames=("interpret",))
def embed_bag(
    table: jax.Array,        # [V, D]
    indices: jax.Array,      # int32[B, F]  (pad = -1)
    weights: jax.Array,      # f32[B, F]    (0 at padded slots)
    *,
    interpret: bool = True,
) -> jax.Array:
    """Weighted-sum bags f32[B, D]."""
    B, F = indices.shape
    V, D = table.shape
    safe = jnp.where(indices >= 0, indices, 0).reshape(-1)       # [B*F]
    w = jnp.where(indices >= 0, weights, 0.0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * F,),
        in_specs=[
            pl.BlockSpec((1, D), lambda i, idx: (idx[i], 0)),     # table row
            pl.BlockSpec((1, F), lambda i, idx: (i // F, 0)),     # weights row
        ],
        out_specs=pl.BlockSpec((1, D), lambda i, idx: (i // F, 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, F=F),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.float32),
        interpret=interpret,
    )(safe, table, w)
