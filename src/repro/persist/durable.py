"""WAL-on-write wrappers around the streaming indexes.

``DurableSinnamonIndex`` / ``DurableShardedSinnamonIndex`` subclass the
in-memory indexes and log every public mutation to the write-ahead log
*before* applying it, so recovery = latest snapshot + replay of the WAL tail
through the exact same host code paths.  Replay therefore reproduces slot
allocation, free-list order, capacity growth, recycled-column merges and
compaction points bit-for-bit: a recovered index returns byte-identical
search results to the never-restarted one.

Determinism notes:

* Auto-grow (free-list exhaustion inside an insert) is NOT logged — it is a
  deterministic function of the op stream and replays identically.  Explicit
  ``grow()`` calls are logged.
* ``compact()`` IS logged (KIND_COMPACT): compaction changes upper-bound
  scores, so replay must rebuild the dirty columns at the same op position
  to keep candidate generation identical.
* Serving never blocks: searches read ``self.state`` (an immutable pytree
  ref) without taking the op lock, so snapshots and background compaction
  can run while a ``QueryServer`` keeps answering queries.
"""

from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import engine as eng
from repro.fault import failpoints as _fp
from repro.obs import events as obs_events
from repro.obs import metrics as obs_metrics
from repro.persist import snapshot as snaplib
from repro.persist import wal
from repro.serving.sharded import ShardedSinnamonIndex, make_compact_step


class _DurableOps:
    """Logging, policy and recovery machinery shared by both wrappers."""

    def _init_durable(self, *, wal_dir: str, snapshot_dir: Optional[str],
                      fsync: bool, segment_bytes: int,
                      snapshot_every: Optional[int],
                      compact_threshold: Optional[float],
                      compact_check_every: int,
                      snapshot_keep: int):
        self.wal_dir = wal_dir
        self.snapshot_dir = snapshot_dir
        self.fsync = fsync
        self.segment_bytes = segment_bytes
        self.snapshot_every = snapshot_every
        self.compact_threshold = compact_threshold
        self.compact_check_every = compact_check_every
        self.snapshot_keep = snapshot_keep
        self._lock = threading.RLock()
        self._suspend = 0            # >0: inside a replay or an internal call
        self._writers: dict[int, wal.WalWriter] = {}
        self._next_lsn = 0
        self._last_lsn = -1
        self._ops_since_snapshot = 0
        self._ops_since_compact_check = 0
        self._last_snapshot_ts: Optional[float] = None
        self._replayed_ops = 0

    @contextmanager
    def _nolog(self):
        self._suspend += 1
        try:
            yield
        finally:
            self._suspend -= 1

    @property
    def _logging(self) -> bool:
        return self._suspend == 0

    def _writer(self, shard: int) -> wal.WalWriter:
        if shard not in self._writers:
            self._writers[shard] = wal.writer_for(
                self.wal_dir, shard, fsync=self.fsync,
                segment_bytes=self.segment_bytes)
        return self._writers[shard]

    def _append(self, shard: int, kind: int, arrays: dict) -> int:
        lsn = self._writer(shard).append(kind, arrays, lsn=self._next_lsn)
        self._next_lsn = lsn + 1
        self._last_lsn = lsn
        return lsn

    # -- policy ---------------------------------------------------------------
    def _after_ops(self, n: int) -> None:
        if not self._logging:
            return
        self._ops_since_snapshot += n
        self._ops_since_compact_check += n
        # The drift metric re-encodes the whole store (O(corpus)), so it is
        # only recomputed every compact_check_every ops — and only when a
        # recycled (dirty+active) slot exists, the sole place drift can live.
        if (self.compact_threshold is not None
                and self._ops_since_compact_check >= self.compact_check_every):
            self._ops_since_compact_check = 0
            st = self.state
            if bool(np.asarray(jnp.any(st.dirty & st.active))):
                drift = self.slot_drift()
                if float(drift.max()) > self.compact_threshold:
                    self.compact()
        if (self.snapshot_every is not None and self.snapshot_dir
                and self._ops_since_snapshot >= self.snapshot_every):
            self.snapshot()

    # -- snapshot / compaction ------------------------------------------------
    def snapshot(self) -> str:
        """Write a full snapshot and prune WAL segments it covers.

        Safe to call while a ``QueryServer`` is serving: searches never take
        the op lock; the lock only orders the snapshot against concurrent
        mutations so (state, id↔slot map, free lists, LSN) stay consistent.
        """
        if not self.snapshot_dir:
            raise ValueError("index was opened without a snapshot_dir")
        t0 = time.perf_counter()
        with self._lock:
            ms = snaplib.latest_manifest(self.snapshot_dir)
            extra = None if ms is None else ms[0]["extra"]
            skipped = (extra is not None and snaplib.matches_layout(extra, self)
                       and int(extra["wal_lsn"]) == self._last_lsn)
            if skipped:
                # State at a given LSN is deterministic, so the on-disk
                # snapshot is already current: rewriting it would briefly
                # unpublish the only recovery base for zero gain.
                path = snaplib.step_path(self.snapshot_dir, ms[1])
            else:
                path = snaplib.save(self.snapshot_dir, self, self._last_lsn,
                                    keep=self.snapshot_keep)
            self._ops_since_snapshot = 0
            pruned = wal.prune(self.wal_dir, self._last_lsn)
            # The prune may unlink a writer's open segment; close so the next
            # append rotates to a fresh file instead of a dead inode.
            for w in self._writers.values():
                w.close()
            lsn = self._last_lsn
        dt_ms = (time.perf_counter() - t0) * 1e3
        self._last_snapshot_ts = time.time()
        reg = obs_metrics.get_registry()
        reg.counter("repro_snapshots_total",
                    "Snapshot calls by outcome (written | skipped_current).",
                    labels={"outcome": "skipped_current" if skipped
                            else "written"}).inc()
        reg.histogram("repro_snapshot_ms",
                      "Wall time of snapshot() incl. WAL prune.").observe(dt_ms)
        obs_events.emit("snapshot", path=path, lsn=lsn, ms=round(dt_ms, 3),
                        skipped=skipped, pruned_segments=pruned)
        return path

    def compact(self) -> int:
        """Logged compaction: rebuild dirty sketch columns (see superclass)."""
        with self._lock:
            if not int(np.asarray(jnp.sum(self.state.dirty))):
                return 0
            if self._logging:
                self._append(0, wal.KIND_COMPACT, {})
            with self._nolog():
                return super().compact()

    def try_compact_async(self) -> Optional[int]:
        """Optimistic compaction for a background thread.

        Computes the compacted state from a snapshot of ``self.state``
        WITHOUT holding the op lock, then swaps it in only if no mutation
        raced us (otherwise returns None and the caller retries later).  The
        KIND_COMPACT record is appended at the swap point, so replay rebuilds
        at the same position in the op stream.
        """
        st = self.state
        n_dirty = int(np.asarray(jnp.sum(st.dirty)))
        if not n_dirty:
            return 0
        new_state = self._compacted_state(st)
        # Failpoint: stall widens the optimistic-race window (a mutation
        # lands first and the swap is skipped); error models the rebuild
        # itself failing and takes the compactor's error path.
        _fp.fire("compact.swap")
        with self._lock:
            if self.state is not st:
                return None
            if self._logging:
                self._append(0, wal.KIND_COMPACT, {})
            self.state = new_state
        return n_dirty

    # -- recovery -------------------------------------------------------------
    def _recover(self, restore_fn) -> None:
        """Shared open flow: latest snapshot (if any) + WAL tail replay.

        ``restore_fn(state, extra) -> (wal_lsn, rebased)`` fills the index
        from the restored snapshot parts; ``rebased`` means the restore was
        elastic (cross-layout / different shard count), in which case a fresh
        snapshot is written so later recoveries skip the rebuild.
        """
        t0 = time.perf_counter()
        snap_lsn = -1
        rebased = False
        ms = None
        if self.snapshot_dir:
            # Recovery owns the dir at this point (nothing serves yet), so
            # crash-stranded resaves can safely be promoted back.
            snaplib.adopt_strays(self.snapshot_dir)
            ms = snaplib.latest_manifest(self.snapshot_dir)
        if ms is not None:
            if snaplib.matches_layout(ms[0]["extra"], self):
                # A same-layout restore replaces the state wholesale: free
                # the constructor's fresh arrays BEFORE materialising the
                # snapshot so recovery never holds two full copies.  (An
                # elastic restore re-inserts into the fresh state, so it
                # must stay.)
                self.state = None
            state, extra = snaplib.restore_parts(self.snapshot_dir, ms)
            with self._nolog():     # elastic re-inserts must not re-log
                snap_lsn, rebased = restore_fn(state, extra)
        horizon = self._replay(snap_lsn)
        dt_ms = (time.perf_counter() - t0) * 1e3
        reg = obs_metrics.get_registry()
        reg.counter("repro_recoveries_total", "Open-with-recovery calls.").inc()
        reg.gauge("repro_recovery_replay_ms",
                  "Wall time of the last recovery (restore + replay).",
                  ).set(dt_ms)
        reg.gauge("repro_recovery_replayed_ops",
                  "WAL records replayed by the last recovery.",
                  ).set(self._replayed_ops)
        obs_events.emit("recovery", snapshot_lsn=snap_lsn, horizon=horizon,
                        replayed=self._replayed_ops, rebased=rebased,
                        ms=round(dt_ms, 3))
        if rebased:
            self.snapshot()

    def _replay(self, after_lsn: int) -> int:
        """Apply the WAL tail (> after_lsn); returns the replay horizon.

        One scan serves replay, the orphan check and the repair decision;
        repair itself (which must re-read files to rewrite them) only runs
        when there is actually a torn tail or an orphan to drop.
        """
        merged, torn = wal.scan_all(self.wal_dir)
        ops = wal.gap_free_ops(merged, after_lsn)
        horizon = after_lsn
        self._replayed_ops = len(ops)
        with self._nolog():
            for lsn, kind, arrays in ops:
                self._apply_op(kind, arrays)
                horizon = lsn
        # Records beyond the horizon that repair would drop: a torn final
        # batch reaches at most one-batch past the horizon (one record per
        # shard).  Anything further means the replay base itself is wrong —
        # typically a WAL pruned against a snapshot this open() wasn't given —
        # and "repairing" would silently destroy acknowledged data.
        orphans = [lsn for lsn, _, _ in merged if lsn > horizon]
        max_batch = max(len(wal.partitions(self.wal_dir)),
                        getattr(self, "n_shards", 1))
        if orphans and orphans[-1] > horizon + max_batch:
            raise RuntimeError(
                f"{self.wal_dir}: WAL records at LSNs {orphans[:3]}"
                f"{'...' if len(orphans) > 3 else ''} are unreachable from "
                f"recovery base LSN {after_lsn} — this is not a torn batch "
                f"tail (wrong or missing snapshot_dir?); refusing to repair")
        if torn or orphans:
            wal.repair(self.wal_dir, horizon)
        self._next_lsn = horizon + 1
        self._last_lsn = horizon
        return horizon

    def _apply_op(self, kind: int, arrays: dict) -> None:
        if kind == wal.KIND_INSERT:
            self.insert_many([int(e) for e in arrays["ext_ids"]],
                             arrays["idx"], arrays["val"])
        elif kind == wal.KIND_INSERT_ONE:
            self.insert(int(arrays["ext_ids"][0]), arrays["idx"][0],
                        arrays["val"][0])
        elif kind == wal.KIND_DELETE:
            self._apply_delete([int(e) for e in arrays["ext_ids"]])
        elif kind == wal.KIND_GROW:
            try:
                self.grow(int(arrays["capacity"]))
            except ValueError:
                # Cross-layout elastic replay: the logged capacity was for a
                # different layout (e.g. per-shard local).  Skipping is safe:
                # grow never changes content, and auto-grow covers need.
                pass
        elif kind == wal.KIND_COMPACT:
            self.compact()
        else:
            raise ValueError(f"unknown WAL record kind {kind}")


class DurableSinnamonIndex(_DurableOps, eng.SinnamonIndex):
    """Single-device streaming index with WAL + snapshot durability.

    Same surface as :class:`repro.core.engine.SinnamonIndex`; every mutation
    is validated, logged (fsync'd) and only then applied, so
    :meth:`open`-after-crash reproduces the pre-crash state byte-for-byte.
    See docs/operations.md for the runbook and the on-disk layout.
    """

    def __init__(self, spec: eng.EngineSpec, *, wal_dir: str,
                 snapshot_dir: Optional[str] = None, fsync: bool = True,
                 segment_bytes: int = 4 << 20,
                 snapshot_every: Optional[int] = None,
                 compact_threshold: Optional[float] = None,
                 compact_check_every: int = 64,
                 snapshot_keep: int = 3):
        eng.SinnamonIndex.__init__(self, spec)
        self._init_durable(wal_dir=wal_dir, snapshot_dir=snapshot_dir,
                           fsync=fsync, segment_bytes=segment_bytes,
                           snapshot_every=snapshot_every,
                           compact_threshold=compact_threshold,
                           compact_check_every=compact_check_every,
                           snapshot_keep=snapshot_keep)

    @classmethod
    def open(cls, spec: eng.EngineSpec, *, wal_dir: str,
             snapshot_dir: Optional[str] = None,
             **kw) -> "DurableSinnamonIndex":
        """Open-or-recover: fresh if no durable data exists, otherwise
        latest snapshot + WAL tail replay (torn tails repaired)."""
        index = cls(spec, wal_dir=wal_dir, snapshot_dir=snapshot_dir, **kw)
        index._recover(lambda state, extra: (
            snaplib.apply_single(index, state, extra),
            extra["kind"] != "single"))             # cross-layout elastic
        return index

    def _compacted_state(self, state):
        return self._compact(state, self.spec)

    # -- logged mutations -----------------------------------------------------
    # Every op validates BEFORE appending to the WAL: a record is only
    # written for an op that will succeed, so a caller-handled error (bad id,
    # bad capacity, wrong width) can never leave a poison record that breaks
    # every future replay.

    def insert(self, ext_id: int, idx, val) -> None:
        with self._lock:
            if self._logging:
                pi, pv = eng.pad_sparse(idx, val, self.spec.max_nnz)
                self._append(0, wal.KIND_INSERT_ONE, {
                    "ext_ids": np.asarray([ext_id], np.int64),
                    "idx": np.asarray(pi)[None],
                    "val": np.asarray(pv)[None]})
            with self._nolog():
                super().insert(ext_id, idx, val)
            self._after_ops(1)

    def insert_many(self, ext_ids, idx_batch, val_batch) -> None:
        with self._lock:
            idx_batch = np.asarray(idx_batch, np.int32)
            val_batch = np.asarray(val_batch, np.float32)
            if idx_batch.shape[1] != self.spec.max_nnz:
                raise ValueError(f"batch nnz width {idx_batch.shape[1]} != "
                                 f"max_nnz {self.spec.max_nnz}")
            if not (len(ext_ids) == idx_batch.shape[0] == val_batch.shape[0]):
                raise ValueError(
                    f"batch length mismatch: {len(ext_ids)} ids vs "
                    f"{idx_batch.shape[0]} idx rows / "
                    f"{val_batch.shape[0]} val rows")
            if self._logging:
                self._append(0, wal.KIND_INSERT, {
                    "ext_ids": np.asarray(ext_ids, np.int64),
                    "idx": idx_batch, "val": val_batch})
            with self._nolog():
                super().insert_many(ext_ids, idx_batch, val_batch)
            self._after_ops(len(ext_ids))

    def delete(self, ext_id: int) -> None:
        with self._lock:
            if ext_id not in self._id2slot:
                raise KeyError(f"unknown document id: {ext_id}")
            if self._logging:
                self._append(0, wal.KIND_DELETE, {
                    "ext_ids": np.asarray([ext_id], np.int64)})
            with self._nolog():
                super().delete(ext_id)
            self._after_ops(1)

    def _apply_delete(self, ext_ids) -> None:
        for e in ext_ids:
            self.delete(e)

    def grow(self, new_capacity: int) -> None:
        with self._lock:
            if new_capacity <= self.spec.capacity or new_capacity % 32 != 0:
                raise ValueError("new capacity must be a larger multiple of 32")
            if self._logging:
                self._append(0, wal.KIND_GROW, {
                    "capacity": np.asarray(new_capacity, np.int64)})
            super().grow(new_capacity)


class DurableShardedSinnamonIndex(_DurableOps, ShardedSinnamonIndex):
    """Mesh-sharded streaming index with per-shard WAL partitions.

    Each operation batch is routed exactly as the in-memory index routes it
    and logged to the owning shard's partition (control records — grow,
    compact — go to partition 0).  LSNs come from one global counter, so the
    merged log totally orders the stream and elastic recovery onto a
    *different* shard count can replay it through the new routing.
    """

    def __init__(self, spec: eng.EngineSpec, mesh, *,
                 wal_dir: str, snapshot_dir: Optional[str] = None,
                 update_block: int = 32, fsync: bool = True,
                 segment_bytes: int = 4 << 20,
                 snapshot_every: Optional[int] = None,
                 compact_threshold: Optional[float] = None,
                 compact_check_every: int = 64,
                 snapshot_keep: int = 3):
        ShardedSinnamonIndex.__init__(self, spec, mesh,
                                      update_block=update_block)
        self._init_durable(wal_dir=wal_dir, snapshot_dir=snapshot_dir,
                           fsync=fsync, segment_bytes=segment_bytes,
                           snapshot_every=snapshot_every,
                           compact_threshold=compact_threshold,
                           compact_check_every=compact_check_every,
                           snapshot_keep=snapshot_keep)

    @classmethod
    def open(cls, spec: eng.EngineSpec, mesh, *, wal_dir: str,
             snapshot_dir: Optional[str] = None,
             **kw) -> "DurableShardedSinnamonIndex":
        """Open-or-recover onto ``mesh``.

        If the snapshot was taken with a different shard count the restore is
        elastic (re-route + re-insert from raw vectors, which freshens the
        sketch) and a new snapshot is written immediately so later recoveries
        don't repeat the rebuild.
        """
        index = cls(spec, mesh, wal_dir=wal_dir, snapshot_dir=snapshot_dir,
                    **kw)
        index._recover(lambda state, extra: (
            snaplib.apply_sharded(index, state, extra, mesh),
            extra["kind"] != "sharded"              # cross-layout elastic
            or int(extra["n_shards"]) != index.n_shards))
        return index

    def _compacted_state(self, state):
        step = self._step("compact", lambda: make_compact_step(self.mesh,
                                                               self.spec))
        return step(state)

    # -- logged mutations (validate BEFORE logging; see single-device note) ---
    def insert_many(self, ext_ids, idx_batch, val_batch) -> None:
        with self._lock:
            idx_batch = np.asarray(idx_batch)
            val_batch = np.asarray(val_batch)
            if idx_batch.shape[1] > self.spec.max_nnz:
                raise ValueError(
                    f"document nnz {idx_batch.shape[1]} > "
                    f"max_nnz {self.spec.max_nnz}")
            if not (len(ext_ids) == idx_batch.shape[0] == val_batch.shape[0]):
                raise ValueError(
                    f"batch length mismatch: {len(ext_ids)} ids vs "
                    f"{idx_batch.shape[0]} idx rows / "
                    f"{val_batch.shape[0]} val rows")
            if self._logging:
                self._log_routed(wal.KIND_INSERT, ext_ids, idx_batch,
                                 val_batch)
            with self._nolog():
                super().insert_many(ext_ids, idx_batch, val_batch)
            self._after_ops(len(ext_ids))

    def delete_many(self, ext_ids) -> None:
        with self._lock:
            # Dedup BEFORE logging: a duplicated id would pass the missing
            # check, get logged, then fail on apply — a poison record.
            ext_ids = list(dict.fromkeys(int(e) for e in ext_ids))
            missing = [e for e in ext_ids if e not in self._id2slot]
            if missing:
                raise KeyError(f"unknown document ids: {missing[:5]}")
            if self._logging:
                self._log_routed(wal.KIND_DELETE, ext_ids, None, None)
            with self._nolog():
                super().delete_many(ext_ids)
            self._after_ops(len(ext_ids))

    def _apply_delete(self, ext_ids) -> None:
        self.delete_many(ext_ids)

    def _log_routed(self, kind: int, ext_ids, idx_batch, val_batch) -> None:
        """One record per owning shard partition.

        Per-shard sub-batches replay identically to the combined batch:
        state touched by different shards is disjoint, and within a shard the
        original batch order is preserved.  Insert payloads are padded to
        ``max_nnz`` so a cross-layout replay (whose width check is strict)
        accepts them.

        The batch's LSNs are assigned in shard order but the records are
        APPENDED in descending-LSN order: if the process dies between
        appends, the durable subset is missing the batch's first LSN, so the
        gap rule discards the whole batch on replay — a multi-shard batch is
        recovered all-or-nothing, never partially.
        """
        ext_ids = [int(e) for e in ext_ids]
        per_shard: dict[int, list[int]] = {}
        for pos, e in enumerate(ext_ids):
            per_shard.setdefault(self.route(e), []).append(pos)
        if kind == wal.KIND_INSERT:
            idx_batch = self._pad(np.asarray(idx_batch, np.int32), -1)
            val_batch = self._pad(np.asarray(val_batch, np.float32), 0)
        records = []
        lsn = self._next_lsn
        for s in sorted(per_shard):
            take = per_shard[s]
            arrays = {"ext_ids": np.asarray([ext_ids[p] for p in take],
                                            np.int64)}
            if kind == wal.KIND_INSERT:
                arrays["idx"] = idx_batch[take]
                arrays["val"] = val_batch[take]
            records.append((s, arrays, lsn))
            lsn += 1
        appended = []
        try:
            for s, arrays, rec_lsn in reversed(records):
                self._writer(s).append(kind, arrays, lsn=rec_lsn)
                appended.append(s)
        except OSError:
            # Keep the batch all-or-nothing ON DISK too: the already-durable
            # higher-LSN records would otherwise pin LSNs that the next op
            # (which reuses this batch's numbers) collides with.
            for s in reversed(appended):
                self._writers[s].unappend()
            raise
        self._next_lsn = lsn
        self._last_lsn = lsn - 1

    def grow(self, new_local_capacity: Optional[int] = None) -> None:
        with self._lock:
            new_c = new_local_capacity or self.spec.capacity * 2
            if new_c <= self.spec.capacity or new_c % 32 != 0:
                raise ValueError("new capacity must be a larger multiple of 32")
            if self._logging:
                self._append(0, wal.KIND_GROW, {
                    "capacity": np.asarray(new_c, np.int64)})
            super().grow(new_c)


class DurableTieredSinnamonIndex(DurableSinnamonIndex,
                                 eng.TieredSinnamonIndex):
    """WAL + snapshot durability over the tiered single-device index.

    The WAL logs *logical* operations only, so the log is byte-identical to
    the resident index's: tiering is invisible to the durability layer.
    Snapshots go through ``logical_state()`` (the full raw store spliced
    back in) and restores through ``adopt_logical_state()`` (rows to the
    host backing, chunk-cache heat reset to access-free defaults) — both
    directions interchange freely with resident-index snapshots.
    """

    def __init__(self, spec: eng.EngineSpec, *, wal_dir: str,
                 snapshot_dir: Optional[str] = None,
                 tier_chunk_slots: int = 256,
                 device_budget_bytes: Optional[int] = None,
                 cache_chunks: Optional[int] = None,
                 fsync: bool = True, segment_bytes: int = 4 << 20,
                 snapshot_every: Optional[int] = None,
                 compact_threshold: Optional[float] = None,
                 compact_check_every: int = 64,
                 snapshot_keep: int = 3):
        eng.TieredSinnamonIndex.__init__(
            self, spec, tier_chunk_slots=tier_chunk_slots,
            device_budget_bytes=device_budget_bytes,
            cache_chunks=cache_chunks)
        self._init_durable(wal_dir=wal_dir, snapshot_dir=snapshot_dir,
                           fsync=fsync, segment_bytes=segment_bytes,
                           snapshot_every=snapshot_every,
                           compact_threshold=compact_threshold,
                           compact_check_every=compact_check_every,
                           snapshot_keep=snapshot_keep)

    def _compacted_state(self, state):
        """Rows-based twin of the resident optimistic compaction: rebuild
        ``state``'s dirty columns from the host backing WITHOUT touching
        ``self.state`` (try_compact_async swaps the result in only if no
        mutation raced the rebuild)."""
        dirty = np.flatnonzero(np.asarray(state.dirty))
        B = self._MAINT_BLOCK
        for i in range(0, dirty.size, B):
            blk = dirty[i:i + B]
            slots = np.zeros((B,), np.int32)
            mask = np.zeros((B,), bool)
            slots[:blk.size] = blk
            mask[:blk.size] = True
            ridx, rval = self.tiered.read_rows(slots)
            state = self._compact_rows(state, self.spec, jnp.asarray(slots),
                                       jnp.asarray(ridx), jnp.asarray(rval),
                                       jnp.asarray(mask))
        return state
