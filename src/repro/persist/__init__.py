"""Durability layer for the streaming retrieval engine.

* ``wal``      — append-only write-ahead log of insert/delete/grow/compact ops
                 (numpy record batches, fsync'd segments, CRC-checked replay).
* ``snapshot`` — full-state snapshots built on checkpoint/ckpt.py's atomic
                 rename layout; always stored *unsharded* so a sharded index
                 can be restored elastically onto a different shard count.
* ``compact``  — drift metrics + compaction policy (including a background
                 compactor thread) for §4.3 recycled-slot sketch residue.
* ``durable``  — ``DurableSinnamonIndex`` / ``DurableShardedSinnamonIndex``:
                 WAL-on-write wrappers with recovery = snapshot + WAL tail.
"""

from repro.persist.durable import (  # noqa: F401
    DurableShardedSinnamonIndex,
    DurableSinnamonIndex,
)
