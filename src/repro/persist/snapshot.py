"""Full-state snapshots of the streaming index.

Built on ``repro.checkpoint.ckpt``'s atomic-rename layout (a preempted save
never corrupts the latest snapshot).  The SinnamonState pytree — including
the ``Optional[l]`` leaf and the VecStore NamedTuple — flattens natively;
the host-side reconstruction recipe (engine spec, id↔slot map, free lists,
WAL position, shard count) rides in the manifest's ``extra`` blob.

Arrays are always stored UNSHARDED (gathered global state), so a sharded
index restores onto **any** shard count: same count → direct device placement
(byte-identical state); different count → documents are re-routed and
re-inserted from the raw VecStore rows (which implicitly compacts the
sketch — rebuilt columns are exact).
"""

from __future__ import annotations

import dataclasses
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.core import engine as eng
from repro.fault import failpoints as _fp
from repro.serving.sharded import ShardedSinnamonIndex, shard_state

# Format history (older formats are refused with an explicit error in
# restore_parts — restore them with the version that wrote them, or re-index):
#   v1: int32[C] ids leaf (pre packed-int64 ids).
#   v2: ids became packed uint32[C, 2] lo/hi words.
#   v3: spec grew the accuracy levers `sketch_kind` (lite = no `l` leaf on
#       signed collections) and quantized cell dtypes (f8 sketch cells are
#       stored as raw uint8 views).  A v2 recipe never recorded those
#       fields, so restoring one means *assuming* defaults for levers that
#       shape the state template; the policy here (as everywhere in
#       recovery) is an explicit refusal over a silent assumption — v2
#       writers only ever produced default-lever states, but the reader
#       cannot verify that from the recipe alone.
FORMAT = "sinnamon-snapshot-v3"


def _spec_dict(spec: eng.EngineSpec) -> dict:
    return dataclasses.asdict(spec)


def _spec_from(d: dict) -> eng.EngineSpec:
    return eng.EngineSpec(**d)


def save(snap_dir: str, index, wal_lsn: int, keep: int = 3) -> str:
    """Snapshot a SinnamonIndex or ShardedSinnamonIndex (durable or not).

    ``wal_lsn`` is the LSN of the last operation reflected in the state;
    recovery replays the WAL strictly after it.  The ckpt step number is the
    snapshot's WAL position + 1 so newer snapshots always sort later (and a
    zero-op snapshot is still representable).
    """
    sharded = isinstance(index, ShardedSinnamonIndex)
    # Tiered indexes keep the raw store host-side behind a zero-row
    # placeholder; logical_state() splices the full store back in, so every
    # snapshot is one interchangeable format regardless of tiering.
    state = (index.logical_state() if hasattr(index, "logical_state")
             else index.state)
    state = jax.device_get(state)             # gathers the global arrays
    extra = {
        "format": FORMAT,
        "kind": "sharded" if sharded else "single",
        "spec": _spec_dict(index.spec),       # per-shard spec when sharded
        "wal_lsn": int(wal_lsn),
    }
    if sharded:
        extra["n_shards"] = index.n_shards
        extra["update_block"] = index.update_block
        extra["free"] = [list(map(int, f)) for f in index._free]
        extra["id2slot"] = {str(k): [int(v[0]), int(v[1])]
                            for k, v in index._id2slot.items()}
    else:
        extra["free"] = list(map(int, index._free))
        extra["id2slot"] = {str(k): int(v)
                            for k, v in index._id2slot.items()}
    return ckpt.save(snap_dir, int(wal_lsn) + 1, state, keep=keep,
                     extra=extra)


def latest_manifest(snap_dir: str) -> Optional[Tuple[dict, int]]:
    """(manifest, step) of the newest snapshot, or None if there is none.

    Recovery paths should call this ONCE and thread the pair through
    (``matches_layout`` on its extra, :func:`restore_parts`, ``step_path``)
    instead of re-reading the manifest per question.
    """
    if ckpt.latest_step(snap_dir) is None:
        return None
    return ckpt.read_manifest(snap_dir)


def latest_extra(snap_dir: str) -> Optional[dict]:
    """The newest snapshot's ``extra`` blob (spec, maps, wal_lsn, shard
    count), or None if no snapshot exists."""
    ms = latest_manifest(snap_dir)
    return None if ms is None else ms[0]["extra"]


def latest_wal_lsn(snap_dir: str) -> Optional[int]:
    """WAL position of the newest snapshot, or None if there is none."""
    extra = latest_extra(snap_dir)
    return None if extra is None else int(extra["wal_lsn"])


def step_path(snap_dir: str, step: int) -> str:
    """Directory of the snapshot published at ``step``."""
    return os.path.join(snap_dir, f"step_{step:010d}")


def adopt_strays(snap_dir: str) -> None:
    """Writer-side crash repair of the snapshot dir (see ckpt.adopt_strays)."""
    ckpt.adopt_strays(snap_dir)


def matches_layout(extra: dict, index) -> bool:
    """Does a snapshot recipe describe ``index``'s layout (kind + shards)?"""
    sharded = isinstance(index, ShardedSinnamonIndex)
    if extra.get("kind") != ("sharded" if sharded else "single"):
        return False
    return not sharded or int(extra["n_shards"]) == index.n_shards


def restore_parts(snap_dir: str,
                  manifest_step: Optional[Tuple[dict, int]] = None
                  ) -> Tuple[eng.SinnamonState, dict]:
    """Load (host state arrays, extra recipe) from the newest snapshot.

    Pass a ``latest_manifest`` result as ``manifest_step`` to avoid
    re-reading the manifest.  The restore template comes from
    ``jax.eval_shape`` — no device state is allocated just to describe the
    tree, so recovery materialises the index exactly once.
    """
    manifest, step = manifest_step or ckpt.read_manifest(snap_dir)
    extra = manifest["extra"]
    if extra.get("format") != FORMAT:
        raise ValueError(
            f"{snap_dir}: snapshot format {extra.get('format')!r} is "
            f"incompatible with {FORMAT} (the state layout changed); "
            f"restore it with the version that wrote it, or re-index")
    spec = _spec_from(extra["spec"])
    if extra["kind"] == "sharded":
        spec = dataclasses.replace(
            spec, capacity=spec.capacity * int(extra["n_shards"]))
    template = jax.eval_shape(lambda: eng.init(spec))
    state, _, _ = ckpt.restore(snap_dir, template, step=step)
    return state, extra


def _live_rows(extra) -> dict:
    """ext_id → global VecStore row of every live doc in a snapshot."""
    if extra["kind"] == "sharded":
        local_cap = int(extra["spec"]["capacity"])
        return {int(k): int(v[0]) * local_cap + int(v[1])
                for k, v in extra["id2slot"].items()}
    return {int(k): int(v) for k, v in extra["id2slot"].items()}


def _reinsert_live(index, state, extra) -> int:
    """Elastic restore: re-insert every live doc from its raw VecStore row
    (deterministic ascending-id order; sketch columns come out fresh).
    Works across layouts — sharded↔sharded with a different shard count,
    and sharded↔single.  Returns wal_lsn.
    """
    rows_of = _live_rows(extra)
    # Failpoint: a bad read of the raw VecStore rows during elastic
    # restore — recovery must surface it, never silently re-insert junk.
    _fp.fire("vecstore.read")
    indices = np.asarray(state.store.indices)
    values = np.asarray(state.store.values, np.float32)
    width = index.spec.max_nnz
    if indices.shape[1] > width:
        raise ValueError(f"snapshot max_nnz {indices.shape[1]} > target "
                         f"index max_nnz {width}: would drop coordinates")
    if indices.shape[1] < width:
        pad_i = np.full((indices.shape[0], width), -1, indices.dtype)
        pad_i[:, :indices.shape[1]] = indices
        pad_v = np.zeros((values.shape[0], width), values.dtype)
        pad_v[:, :values.shape[1]] = values
        indices, values = pad_i, pad_v
    ext_ids = sorted(rows_of)
    for lo in range(0, len(ext_ids), 512):
        chunk = ext_ids[lo:lo + 512]
        rows = [rows_of[e] for e in chunk]
        index.insert_many(chunk, indices[rows], values[rows])
    return int(extra["wal_lsn"])


def apply_single(index: eng.SinnamonIndex, state, extra) -> int:
    """Fill an existing SinnamonIndex from restored parts.  Returns wal_lsn.

    A single-kind snapshot restores byte-identically (arrays, slot map,
    free-list order); a sharded-kind snapshot restores elastically by
    re-inserting the live docs from the raw store.
    """
    if extra["kind"] != "single":
        return _reinsert_live(index, state, extra)
    index.spec = _spec_from(extra["spec"])
    if hasattr(index, "adopt_logical_state"):
        index.adopt_logical_state(state)      # tiered: store → host backing
    else:
        index.state = jax.tree.map(jnp.asarray, state)
    index._id2slot = {int(k): int(v) for k, v in extra["id2slot"].items()}
    index._free = [int(s) for s in extra["free"]]
    return int(extra["wal_lsn"])


def apply_sharded(index: ShardedSinnamonIndex, state, extra, mesh) -> int:
    """Fill an existing ShardedSinnamonIndex from restored parts.

    Sharded snapshot with the same shard count → direct placement
    (byte-identical state + bookkeeping).  Different shard count or a
    single-kind snapshot → elastic restore via :func:`_reinsert_live`.
    Returns wal_lsn.
    """
    if (extra["kind"] != "sharded"
            or index.n_shards != int(extra["n_shards"])):
        return _reinsert_live(index, state, extra)
    index.spec = _spec_from(extra["spec"])
    if hasattr(index, "adopt_logical_state"):
        index.adopt_logical_state(state)      # tiered: store → host backing
    else:
        index.state = shard_state(jax.tree.map(jnp.asarray, state), mesh)
    index._free = [[int(s) for s in f] for f in extra["free"]]
    index._id2slot = {int(k): (int(v[0]), int(v[1]))
                      for k, v in extra["id2slot"].items()}
    index._steps.clear()
    return int(extra["wal_lsn"])


def load_single(snap_dir: str) -> Tuple[eng.SinnamonIndex, int]:
    """Rebuild a SinnamonIndex from the newest snapshot.  (index, wal_lsn)."""
    state, extra = restore_parts(snap_dir)
    index = eng.SinnamonIndex(_spec_from(extra["spec"]))
    return index, apply_single(index, state, extra)


def load_sharded(snap_dir: str, mesh) -> Tuple[ShardedSinnamonIndex, int]:
    """Rebuild a ShardedSinnamonIndex from the newest snapshot onto ``mesh``.
    (index, wal_lsn); see :func:`apply_sharded` for elastic semantics.

    A single-kind snapshot (no ``update_block``/``n_shards`` in the recipe)
    restores elastically; its spec describes the whole corpus, so it is used
    as the per-shard local spec unchanged (capacity to spare on every shard).
    """
    state, extra = restore_parts(snap_dir)
    spec = _spec_from(extra["spec"])
    index = ShardedSinnamonIndex(spec, mesh,
                                 update_block=int(extra.get("update_block",
                                                            32)))
    return index, apply_sharded(index, state, extra, mesh)
