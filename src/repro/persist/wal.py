"""Append-only write-ahead log of streaming index operations.

Layout: ``<wal_dir>/<partition>/wal-<first_lsn:016d>.seg`` — one partition per
shard (``shard-000`` … ; a single-device index uses just ``shard-000``), each
a sequence of fixed-header records:

    magic u32 | lsn u64 | kind u8 | pad x3 | payload_len u32 | crc u32
    payload   (np.savez bytes: the op's numpy record batch)

The CRC covers the header fields (magic, lsn, kind, payload_len) AND the
payload, so a flipped bit anywhere in a record — including its lsn or kind —
makes the record undecodable instead of replaying garbage.

LSNs are assigned from ONE global counter across partitions, so the merged
log totally orders every operation.  Each append is flushed (and fsync'd by
default) before the in-memory index mutates — a crash can lose at most the
torn tail of the record being written.

Replay semantics (``read_ops``): scan every partition (a torn/corrupt record
hides only the rest of its own segment; later segments stay visible), merge
by LSN, and apply only the gap-free prefix — with per-record fsync a torn
record is necessarily the globally last write, so the prefix is exactly
"everything that was acknowledged".  Records that survive past a gap are
*orphans*: the replay layer refuses to proceed unless they fit a torn final
batch (see ``durable._replay``).  ``repair`` truncates torn tails and drops
beyond-horizon segments so the writer can resume cleanly.
"""

from __future__ import annotations

import errno
import io
import os
import struct
import time
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.fault import failpoints as _fp
from repro.fault.retry import RetryPolicy, call_with_retry, fsync_transient
from repro.obs import metrics as obs_metrics

MAGIC = 0x57414C31                       # "WAL1"
_HEADER = struct.Struct("<IQB3xII")      # magic, lsn, kind, pad, len, crc
_CRC_OFF = _HEADER.size - 4              # crc is the header's last field

KIND_INSERT = 1        # batch insert        (ext_ids, idx, val)
KIND_INSERT_ONE = 2    # single-doc insert   (ext_ids[1], idx[1], val[1])
KIND_DELETE = 3        # batch delete        (ext_ids)
KIND_GROW = 4          # explicit capacity growth (capacity; per-shard local)
KIND_COMPACT = 5       # sketch compaction point (empty payload)

KIND_NAMES = {KIND_INSERT: "insert", KIND_INSERT_ONE: "insert_one",
              KIND_DELETE: "delete", KIND_GROW: "grow",
              KIND_COMPACT: "compact"}


def partition_name(shard: int) -> str:
    return f"shard-{shard:03d}"


def _fsync_dir(path: str) -> None:
    """Durably persist directory entries (new/renamed/removed files)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _encode_payload(arrays: Dict[str, np.ndarray]) -> bytes:
    bio = io.BytesIO()
    np.savez(bio, **arrays)
    return bio.getvalue()


def _decode_payload(payload: bytes) -> Dict[str, np.ndarray]:
    if not payload:
        return {}
    with np.load(io.BytesIO(payload)) as z:
        return {k: z[k] for k in z.files}


def _pack_record(lsn: int, kind: int, payload: bytes) -> bytes:
    hdr = _HEADER.pack(MAGIC, lsn, kind, len(payload), 0)[:_CRC_OFF]
    crc = zlib.crc32(payload, zlib.crc32(hdr)) & 0xFFFFFFFF
    return hdr + struct.pack("<I", crc) + payload


#: Default fsync retry budget: a couple of quick backoffs for pure
#: interruptions (EINTR/EAGAIN — see ``fsync_transient``), bounded well
#: under a request deadline.  EIO and ENOSPC are never retried at the
#: durability barrier: after a failed fsync the kernel may have marked
#: the dirty pages clean (fsyncgate), so a retried "success" proves
#: nothing about the bytes on disk — the append unwinds instead.
FSYNC_RETRY = RetryPolicy(attempts=3, base_delay_s=0.005,
                          max_delay_s=0.05, deadline_s=0.25)


class WalWriter:
    """Appends records to one partition directory (one shard's log).

    Failpoint sites (docs/robustness.md): ``wal.write`` fires before the
    record bytes are written (``torn`` mode writes a prefix of the record
    then raises EIO — the torn-tail crash); ``wal.fsync`` fires inside
    the fsync, which is retried per ``fsync_retry`` for interruptions
    (EINTR/EAGAIN) only — an fsync EIO/ENOSPC is fatal: the append
    unwinds and the segment is abandoned (fsyncgate: a post-failure
    fsync on the same fd can report durability that never happened).
    """

    def __init__(self, part_dir: str, *, fsync: bool = True,
                 segment_bytes: int = 4 << 20, next_lsn: int = 0,
                 fsync_retry: Optional[RetryPolicy] = None):
        self.part_dir = part_dir
        self.fsync = fsync
        self.segment_bytes = segment_bytes
        self.next_lsn = next_lsn          # used when the caller doesn't pass one
        self.fsync_retry = fsync_retry or FSYNC_RETRY
        os.makedirs(part_dir, exist_ok=True)
        if fsync:
            _fsync_dir(os.path.dirname(part_dir.rstrip(os.sep)) or ".")
        self._f = None
        self._last_append: Optional[int] = None
        self._obs_registry = None

    def _obs(self):
        """WAL metric handles, bound lazily against the current global
        registry (revalidated so `set_registry` in tests takes effect)."""
        reg = obs_metrics.get_registry()
        if reg is not self._obs_registry:
            self._obs_append_ms = reg.histogram(
                "repro_wal_append_ms", "One WAL record append, fsync included.")
            self._obs_fsync_ms = reg.histogram(
                "repro_wal_fsync_ms", "fsync portion of a WAL append.")
            self._obs_bytes = reg.counter(
                "repro_wal_appended_bytes_total", "Record bytes appended.")
            self._obs_records = {
                name: reg.counter("repro_wal_records_total",
                                  "WAL records appended by kind.",
                                  labels={"kind": name})
                for name in KIND_NAMES.values()}
            self._obs_rotations = reg.counter(
                "repro_wal_segment_rotations_total", "Segment files opened.")
            self._obs_errors = reg.counter(
                "repro_wal_append_errors_total", "Failed (unwound) appends.")
            self._obs_registry = reg
        return self

    def _rotate(self, first_lsn: int) -> None:
        self._last_append = None
        if self._f is not None:
            self._f.close()
        path = os.path.join(self.part_dir, f"wal-{first_lsn:016d}.seg")
        self._f = open(path, "ab")
        self._obs()._obs_rotations.inc()
        if self.fsync:
            # Persist the directory entry too: an fsync'd record in a file
            # whose entry was lost to a power cut is a lost record.
            _fsync_dir(self.part_dir)

    def append(self, kind: int, arrays: Dict[str, np.ndarray],
               lsn: Optional[int] = None) -> int:
        t0 = time.perf_counter()
        lsn = self.next_lsn if lsn is None else lsn
        payload = _encode_payload(arrays) if arrays else b""
        if self._f is None or self._f.tell() >= self.segment_bytes:
            self._rotate(lsn)
        start = self._f.tell()
        record = _pack_record(lsn, kind, payload)
        obs = self._obs()
        try:
            act = _fp.fire("wal.write")
            if act is not None and act.mode == "torn":
                # Model a mid-write crash: a prefix of the record reaches
                # the file, then the write "fails".  The unwind below must
                # erase it; replay must never decode it.
                self._f.write(record[:max(1, int(len(record) * act.arg))])
                self._f.flush()
                raise _fp.InjectedError(
                    errno.EIO, "injected torn write at wal.write")
            self._f.write(record)
            self._f.flush()
        except OSError:
            # Roll the partial bytes back: garbage mid-segment would hide
            # every later acknowledged record in this segment from replay.
            obs._obs_errors.inc()
            self._unwind(start)
            raise
        if self.fsync:
            t_sync = time.perf_counter()
            try:
                call_with_retry(self._do_fsync, policy=self.fsync_retry,
                                should_retry=fsync_transient,
                                op="wal.fsync")
            except OSError:
                # The durability barrier itself failed.  fsyncgate: the
                # kernel may now consider the dirty pages clean, so neither
                # a retried fsync nor any later one on this fd can be
                # trusted to have persisted the record.  Unwind the bytes
                # and abandon the segment — the next append lands on a
                # fresh file whose first fsync tells the truth.
                obs._obs_errors.inc()
                self._unwind(start)
                if self._f is not None:
                    try:
                        self._f.close()
                    except OSError:
                        pass
                    self._f = None
                raise
            obs._obs_fsync_ms.observe((time.perf_counter() - t_sync) * 1e3)
        self.next_lsn = lsn + 1
        self._last_append = start
        obs._obs_append_ms.observe((time.perf_counter() - t0) * 1e3)
        obs._obs_bytes.inc(len(record))
        counter = obs._obs_records.get(KIND_NAMES.get(kind, ""))
        if counter is not None:
            counter.inc()
        return lsn

    def _do_fsync(self) -> None:
        _fp.fire("wal.fsync")
        os.fsync(self._f.fileno())

    def _unwind(self, start: int) -> None:
        try:
            self._f.truncate(start)
            # truncate() does not move the stream position, and the file is
            # in append mode so writes still land correctly — but tell()
            # (used for the NEXT append's unwind start and the rotation
            # check) would stay past the new end, making a later unwind
            # truncate short and strand garbage.  Re-sync it.
            self._f.seek(start)
            self._f.flush()
            if self.fsync:
                os.fsync(self._f.fileno())
        except OSError:
            # Disk too broken even to truncate: abandon the segment so the
            # next append (if any succeeds) lands in a fresh file AFTER the
            # garbage, where the scanner can still reach it.
            self._f.close()
            self._f = None

    def unappend(self) -> None:
        """Roll back the most recent successful append (best effort).

        Used to keep a multi-record batch all-or-nothing ON DISK when a
        later record of the same batch fails to append: the durable subset
        would otherwise pin stale LSNs that collide with the next op.
        """
        if self._f is not None and self._last_append is not None:
            self._unwind(self._last_append)
            self._last_append = None

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


# ---------------------------------------------------------------------------
# Reading / replay
# ---------------------------------------------------------------------------

def _segments(part_dir: str) -> List[str]:
    if not os.path.isdir(part_dir):
        return []
    return sorted(n for n in os.listdir(part_dir)
                  if n.startswith("wal-") and n.endswith(".seg"))


def _scan_segment(path: str) -> Tuple[List[Tuple[int, int, bytes]], int, bool]:
    """Parse one segment file.

    Returns (records [(lsn, kind, payload)], clean_byte_len, torn) where
    ``torn`` means trailing bytes past ``clean_byte_len`` failed the
    magic/length/CRC check (truncated or corrupt tail).
    """
    with open(path, "rb") as f:
        buf = f.read()
    records, off = [], 0
    while off + _HEADER.size <= len(buf):
        magic, lsn, kind, plen, crc = _HEADER.unpack_from(buf, off)
        end = off + _HEADER.size + plen
        if magic != MAGIC or end > len(buf):
            break
        payload = buf[off + _HEADER.size:end]
        hdr_crc = zlib.crc32(buf[off:off + _CRC_OFF])
        if zlib.crc32(payload, hdr_crc) & 0xFFFFFFFF != crc:
            break
        records.append((lsn, kind, bytes(payload)))
        off = end
    return records, off, off < len(buf)


def scan_partition(part_dir: str) -> Tuple[List[Tuple[int, int, bytes]], bool]:
    """All decodable records of one partition.

    A torn/corrupt record hides the rest of ITS segment (there is no way to
    find the next record boundary in the same file), but later segments
    start at a known boundary and ARE still scanned: their records must stay
    visible so the replay orphan guard can refuse to repair over acknowledged
    data (a mid-stream corruption must never silently delete the segments
    after it).  The LSN gap rule keeps any post-corruption record out of the
    replayed stream regardless.
    """
    records: List[Tuple[int, int, bytes]] = []
    torn_any = False
    for name in _segments(part_dir):
        recs, _, torn = _scan_segment(os.path.join(part_dir, name))
        records.extend(recs)
        torn_any = torn_any or torn
    return records, torn_any


def scan_all(wal_dir: str) -> Tuple[List[Tuple[int, int, bytes]], bool]:
    """One pass over every partition: (merged decodable records sorted by
    LSN, whether any partition has a torn tail).  The raw-record form lets a
    caller derive the gap-free stream AND the orphan set from a single scan
    (see :func:`gap_free_ops` / ``durable._replay``)."""
    merged: List[Tuple[int, int, bytes]] = []
    torn_any = False
    for part in partitions(wal_dir):
        recs, torn = scan_partition(os.path.join(wal_dir, part))
        merged.extend(recs)
        torn_any = torn_any or torn
    merged.sort(key=lambda r: r[0])
    return merged, torn_any


def gap_free_ops(merged: List[Tuple[int, int, bytes]], after_lsn: int = -1
                 ) -> List[Tuple[int, int, Dict[str, np.ndarray]]]:
    """Decode the gap-free op stream out of :func:`scan_all`'s records.

    Keeps only records with ``lsn > after_lsn`` and stops at the first
    missing LSN — a gap means a mid-stream record was lost (torn tail), so
    later records (which the live process applied *after* the lost one) are
    discarded for consistency.
    """
    out = []
    # A snapshot at L means ops <= L were applied, so the tail must start at
    # exactly L+1.  With no snapshot the stream must start at LSN 0: pruning
    # only ever runs after a snapshot, so a WAL whose head is missing is
    # unrecoverable without that snapshot — and a multi-shard batch whose
    # lowest-LSN record was lost to a torn tail (records are appended in
    # descending-LSN order) must be discarded whole, never applied partially.
    expect = after_lsn + 1
    for lsn, kind, payload in merged:
        if lsn <= after_lsn:
            continue
        if lsn != expect:
            break
        expect = lsn + 1
        out.append((lsn, kind, _decode_payload(payload)))
    return out


def read_ops(wal_dir: str, after_lsn: int = -1
             ) -> List[Tuple[int, int, Dict[str, np.ndarray]]]:
    """Merged, gap-free op stream across all partitions (single scan)."""
    merged, _ = scan_all(wal_dir)
    return gap_free_ops(merged, after_lsn)


def partitions(wal_dir: str) -> List[str]:
    if not os.path.isdir(wal_dir):
        return []
    return sorted(n for n in os.listdir(wal_dir)
                  if n.startswith("shard-")
                  and os.path.isdir(os.path.join(wal_dir, n)))


def last_lsn(wal_dir: str) -> int:
    """Highest LSN in the gap-free merged stream (-1 if empty)."""
    ops = read_ops(wal_dir)
    return ops[-1][0] if ops else -1


def orphan_lsns(wal_dir: str, horizon_lsn: int) -> List[int]:
    """LSNs of decodable records beyond a replay horizon, sorted.

    Non-empty means the gap-free stream could not reach these records.  The
    only legitimate cause is a torn multi-shard batch (at most one record per
    shard, LSNs within one batch of the horizon); anything further out means
    the replay base is wrong — e.g. the WAL was pruned against a snapshot the
    caller no longer has — and repair would destroy acknowledged data.
    """
    merged, _ = scan_all(wal_dir)
    return [lsn for lsn, _, _ in merged if lsn > horizon_lsn]


def repair(wal_dir: str, horizon_lsn: int) -> None:
    """Make the on-disk log consistent with a replay horizon.

    Truncates torn segment tails and removes any record/segment beyond
    ``horizon_lsn`` so a resuming writer (next_lsn = horizon+1) never
    collides with stale bytes.
    """
    for part in partitions(wal_dir):
        pdir = os.path.join(wal_dir, part)
        changed = False
        for name in _segments(pdir):
            path = os.path.join(pdir, name)
            recs, clean_len, torn = _scan_segment(path)
            keep = [r for r in recs if r[0] <= horizon_lsn]
            if len(keep) == len(recs):
                if torn:
                    with open(path, "r+b") as f:
                        f.truncate(clean_len)
                        f.flush()
                        os.fsync(f.fileno())
                continue
            if not keep:
                os.remove(path)
                changed = True
                continue
            # Rewrite via temp + atomic rename: a crash mid-repair must not
            # destroy records that were acknowledged in the original run.
            buf = b"".join(_pack_record(lsn, kind, payload)
                           for lsn, kind, payload in keep)
            tmp = path + ".tmp"
            with open(tmp, "wb") as f:
                f.write(buf)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
            changed = True
        if changed:
            _fsync_dir(pdir)


def prune(wal_dir: str, upto_lsn: int) -> int:
    """Drop whole segments whose every record is covered by a snapshot at
    ``upto_lsn``.  Returns the number of segments removed."""
    removed = 0
    for part in partitions(wal_dir):
        pdir = os.path.join(wal_dir, part)
        n = 0
        for name in _segments(pdir):
            path = os.path.join(pdir, name)
            recs, _, torn = _scan_segment(path)
            if not torn and recs and recs[-1][0] <= upto_lsn:
                os.remove(path)
                n += 1
        if n:
            _fsync_dir(pdir)
        removed += n
    return removed


def writer_for(wal_dir: str, shard: int, *, fsync: bool = True,
               segment_bytes: int = 4 << 20, next_lsn: int = 0) -> WalWriter:
    return WalWriter(os.path.join(wal_dir, partition_name(shard)),
                     fsync=fsync, segment_bytes=segment_bytes,
                     next_lsn=next_lsn)
