"""Sketch-drift metrics and compaction policy.

The paper's §4.3 deletion leaves recycled sketch columns carrying stale
maxima (see repro.core.engine: merge-on-recycle insert), so the Theorem 5.1
upper bound stays *valid* but grows *loose* under churn — candidate
generation quality silently degrades.  This module measures that drift
against a freshly encoded sketch and decides when to pay for a rebuild:

* :func:`drift_metrics`  — mean/max per-slot overestimate + dirty counts,
  for any index flavour (single-device or mesh-sharded, durable or not).
* :func:`maybe_compact`  — threshold policy: compact iff max drift exceeds.
* :class:`BackgroundCompactor` — a daemon thread that polls drift and
  compacts optimistically (state-identity CAS swap via
  ``DurableIndex.try_compact_async``), so serving never blocks.
"""

from __future__ import annotations

import threading
from typing import Optional

import numpy as np


def drift_metrics(index) -> dict:
    """Drift of the live sketch vs. a fresh one.  All values are host floats.

    mean/max are over ACTIVE slots (inactive columns never contribute to a
    search).  ``dirty_active`` counts recycled columns — the only place
    drift can live; ``dirty_total`` additionally counts deleted-not-yet-
    recycled columns (zeroed by the next compaction).
    """
    # A concurrent grow() can swap state between reads; retry until the
    # drift vector and the state snapshot agree on capacity.
    for _ in range(5):
        per_slot = index.slot_drift()                    # f32[C]
        state = index.state
        if per_slot.shape[0] == state.active.shape[0]:
            break
    else:
        raise RuntimeError("index capacity kept changing during drift scan")
    active = np.asarray(state.active)
    dirty = np.asarray(state.dirty)
    act = per_slot[active] if active.any() else np.zeros((0,), np.float32)
    return {
        "mean_overestimate": float(act.mean()) if act.size else 0.0,
        "max_overestimate": float(act.max()) if act.size else 0.0,
        "dirty_active": int((dirty & active).sum()),
        "dirty_total": int(dirty.sum()),
        "active": int(active.sum()),
    }


def maybe_compact(index, threshold: float) -> Optional[dict]:
    """Compact iff the max per-slot overestimate exceeds ``threshold``.

    Returns the pre-compaction metrics dict when compaction ran, else None.
    """
    metrics = drift_metrics(index)
    if metrics["max_overestimate"] > threshold:
        index.compact()
        return metrics
    return None


class BackgroundCompactor:
    """Daemon thread: poll drift every ``interval_s``, compact when above
    ``threshold``.  Requires a durable index (``try_compact_async``) so the
    rebuild happens off the serving path and the WAL stays consistent."""

    def __init__(self, index, threshold: float, interval_s: float = 1.0):
        self.index = index
        self.threshold = threshold
        self.interval_s = interval_s
        self.compactions = 0
        self.skipped_races = 0
        self.errors = 0
        self.last_error: Optional[BaseException] = None
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)

    def start(self) -> "BackgroundCompactor":
        self._thread.start()
        return self

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            # The daemon must survive transient races (e.g. a grow swapping
            # state mid-scan): record the error and retry next tick rather
            # than silently dying and letting drift grow unbounded.
            try:
                self._tick()
            except Exception as e:                      # noqa: BLE001
                self.errors += 1
                self.last_error = e

    def _tick(self) -> None:
        metrics = drift_metrics(self.index)
        if metrics["max_overestimate"] <= self.threshold:
            return
        n = self.index.try_compact_async()
        if n is None:
            self.skipped_races += 1     # a mutation raced us; retry next tick
        elif n:
            self.compactions += 1

    def stop(self) -> None:
        self._stop.set()
        self._thread.join(timeout=30)
