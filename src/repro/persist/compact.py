"""Sketch-drift metrics and compaction policy.

The paper's §4.3 deletion leaves recycled sketch columns carrying stale
maxima (see repro.core.engine: merge-on-recycle insert), so the Theorem 5.1
upper bound stays *valid* but grows *loose* under churn — candidate
generation quality silently degrades.  This module measures that drift
against a freshly encoded sketch and decides when to pay for a rebuild:

* :func:`drift_metrics`  — mean/max per-slot overestimate + dirty counts,
  for any index flavour (single-device or mesh-sharded, durable or not).
  Every call also publishes the values as ``repro_sketch_drift_*`` gauges.
* :func:`maybe_compact`  — threshold policy: compact iff max drift exceeds.
* :class:`BackgroundCompactor` — a daemon thread that polls drift and
  compacts optimistically (state-identity CAS swap via
  ``DurableIndex.try_compact_async``), so serving never blocks.  Outcomes
  (compactions / skipped races / errors) are published as counters, and a
  nonzero post-compaction drift raises a WARN event.
"""

from __future__ import annotations

import threading
import time
from typing import Optional

import numpy as np

from repro.fault.retry import CircuitBreaker
from repro.obs import events as obs_events
from repro.obs import metrics as obs_metrics


def drift_metrics(index, registry=None) -> dict:
    """Drift of the live sketch vs. a fresh one.  All values are host floats.

    mean/max are over ACTIVE slots (inactive columns never contribute to a
    search).  ``dirty_active`` counts recycled columns — the only place
    drift can live; ``dirty_total`` additionally counts deleted-not-yet-
    recycled columns (zeroed by the next compaction).

    The values are also published to ``registry`` (default: the
    process-global one) as gauges, so a scrape always reflects the most
    recent drift scan.
    """
    # A concurrent grow() can swap state between reads; retry until the
    # drift vector and the state snapshot agree on capacity.
    for _ in range(5):
        per_slot = index.slot_drift()                    # f32[C]
        state = index.state
        if per_slot.shape[0] == state.active.shape[0]:
            break
    else:
        raise RuntimeError("index capacity kept changing during drift scan")
    active = np.asarray(state.active)
    dirty = np.asarray(state.dirty)
    act = per_slot[active] if active.any() else np.zeros((0,), np.float32)
    out = {
        "mean_overestimate": float(act.mean()) if act.size else 0.0,
        "max_overestimate": float(act.max()) if act.size else 0.0,
        "dirty_active": int((dirty & active).sum()),
        "dirty_total": int(dirty.sum()),
        "active": int(active.sum()),
    }
    reg = registry if registry is not None else obs_metrics.get_registry()
    reg.gauge("repro_sketch_drift_mean",
              "Mean per-slot sketch overestimate vs. fresh (active slots)."
              ).set(out["mean_overestimate"])
    reg.gauge("repro_sketch_drift_max",
              "Max per-slot sketch overestimate vs. fresh (active slots)."
              ).set(out["max_overestimate"])
    reg.gauge("repro_sketch_dirty_active_slots",
              "Recycled (dirty & active) columns — where drift lives."
              ).set(out["dirty_active"])
    reg.gauge("repro_sketch_dirty_total_slots",
              "All dirty columns, incl. deleted-not-yet-recycled."
              ).set(out["dirty_total"])
    return out


def _publish_compaction(registry, before: dict, after: dict,
                        dt_ms: float, source: str) -> None:
    """Before/after drift gauges + WARN when residual drift survives."""
    registry.gauge("repro_compaction_drift_before",
                   "Max overestimate just before the last compaction."
                   ).set(before["max_overestimate"])
    registry.gauge("repro_compaction_drift_after",
                   "Max overestimate just after the last compaction."
                   ).set(after["max_overestimate"])
    registry.histogram("repro_compaction_ms",
                       "Wall time of one sketch compaction.").observe(dt_ms)
    if after["max_overestimate"] > 0:
        # Zero is the invariant a quiesced compaction restores; residue
        # means mutations raced the rebuild (benign churn) or the rebuild
        # itself is wrong — either way worth surfacing.
        registry.counter("repro_compaction_residual_drift_total",
                         "Compactions that left nonzero drift behind.").inc()
        obs_events.emit("compaction_residual_drift", level="WARN",
                        source=source,
                        drift_before=round(before["max_overestimate"], 6),
                        drift_after=round(after["max_overestimate"], 6))
    obs_events.emit("compaction", source=source, ms=round(dt_ms, 3),
                    drift_before=round(before["max_overestimate"], 6),
                    drift_after=round(after["max_overestimate"], 6),
                    dirty_active=before["dirty_active"])


def maybe_compact(index, threshold: float, registry=None) -> Optional[dict]:
    """Compact iff the max per-slot overestimate exceeds ``threshold``.

    Returns the pre-compaction metrics dict when compaction ran, else None.
    """
    reg = registry if registry is not None else obs_metrics.get_registry()
    metrics = drift_metrics(index, reg)
    if metrics["max_overestimate"] > threshold:
        t0 = time.perf_counter()
        index.compact()
        dt_ms = (time.perf_counter() - t0) * 1e3
        after = drift_metrics(index, reg)
        _publish_compaction(reg, metrics, after, dt_ms, source="maybe_compact")
        return metrics
    return None


class BackgroundCompactor:
    """Daemon thread: poll drift every ``interval_s``, compact when above
    ``threshold``.  Requires a durable index (``try_compact_async``) so the
    rebuild happens off the serving path and the WAL stays consistent.

    Persistent failures trip a circuit breaker (``breaker_failures``
    consecutive errors → skip ticks for ``breaker_reset_s``, then probe
    once) so a wedged rebuild path degrades to periodic probes instead of
    hot-looping error spam while drift monitoring keeps running."""

    def __init__(self, index, threshold: float, interval_s: float = 1.0,
                 registry=None, breaker_failures: int = 5,
                 breaker_reset_s: float = 30.0):
        self.index = index
        self.threshold = threshold
        self.interval_s = interval_s
        self.registry = (registry if registry is not None
                         else obs_metrics.get_registry())
        self.compactions = 0
        self.skipped_races = 0
        self.errors = 0
        self.last_error: Optional[BaseException] = None
        self.breaker = CircuitBreaker(failure_threshold=breaker_failures,
                                      reset_timeout_s=breaker_reset_s,
                                      name="compactor",
                                      registry=self.registry)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)

    def _outcome(self, outcome: str):
        return self.registry.counter(
            "repro_compactor_outcomes_total",
            "Background compactor ticks by outcome "
            "(compacted | skipped_race | error | breaker_open).",
            labels={"outcome": outcome})

    def start(self) -> "BackgroundCompactor":
        self._thread.start()
        return self

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            if not self.breaker.allow():
                self._outcome("breaker_open").inc()
                continue
            # The daemon must survive transient races (e.g. a grow swapping
            # state mid-scan): record the error and retry next tick rather
            # than silently dying and letting drift grow unbounded.
            try:
                self._tick()
                self.breaker.record_success()
            except Exception as e:                      # noqa: BLE001
                self.errors += 1
                self.last_error = e
                self.breaker.record_failure()
                self._outcome("error").inc()
                obs_events.emit("compactor_error", level="WARN",
                                error=repr(e))

    def _tick(self) -> None:
        metrics = drift_metrics(self.index, self.registry)
        if metrics["max_overestimate"] <= self.threshold:
            return
        t0 = time.perf_counter()
        n = self.index.try_compact_async()
        if n is None:
            self.skipped_races += 1     # a mutation raced us; retry next tick
            self._outcome("skipped_race").inc()
        elif n:
            self.compactions += 1
            self._outcome("compacted").inc()
            after = drift_metrics(self.index, self.registry)
            _publish_compaction(self.registry, metrics, after,
                                (time.perf_counter() - t0) * 1e3,
                                source="background_compactor")

    def stop(self) -> None:
        self._stop.set()
        self._thread.join(timeout=30)
