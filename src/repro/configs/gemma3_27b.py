"""gemma3-27b [dense] — 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt; unverified]."""
from repro.configs.common import LM_SHAPES as SHAPES  # noqa: F401
from repro.models.transformer import LMConfig

ARCH = "gemma3-27b"
FAMILY = "lm"


def full_config() -> LMConfig:
    return LMConfig(
        name=ARCH, n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16,
        d_ff=21504, vocab=262144, head_dim=128, rope_theta=1_000_000.0,
        local_window=1024, local_global_ratio=5)


def smoke_config() -> LMConfig:
    return LMConfig(
        name=ARCH + "-smoke", n_layers=6, d_model=128, n_heads=8,
        n_kv_heads=4, d_ff=384, vocab=512, head_dim=16,
        local_window=16, local_global_ratio=5, attn_chunk=32)
