"""stablelm-12b [dense] — [hf:stabilityai/stablelm-2-1_6b; hf]."""
from repro.configs.common import LM_SHAPES as SHAPES  # noqa: F401
from repro.models.transformer import LMConfig

ARCH = "stablelm-12b"
FAMILY = "lm"


def full_config() -> LMConfig:
    return LMConfig(
        name=ARCH, n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
        d_ff=13824, vocab=100352, head_dim=160, rope_theta=10_000.0)


def smoke_config() -> LMConfig:
    return LMConfig(
        name=ARCH + "-smoke", n_layers=3, d_model=96, n_heads=6,
        n_kv_heads=2, d_ff=256, vocab=384, head_dim=16, attn_chunk=64)
