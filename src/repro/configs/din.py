"""din [recsys] — target attention over user history [arXiv:1706.06978]."""
from repro.configs.common import RECSYS_SHAPES as SHAPES  # noqa: F401
from repro.models.recsys import RecsysConfig

ARCH = "din"
FAMILY = "recsys"


def full_config() -> RecsysConfig:
    return RecsysConfig(
        name=ARCH, model="din", embed_dim=18, seq_len=100,
        attn_mlp=(80, 40), mlp=(200, 80), n_items=1_000_000)


def smoke_config() -> RecsysConfig:
    return RecsysConfig(
        name=ARCH + "-smoke", model="din", embed_dim=8, seq_len=12,
        attn_mlp=(16, 8), mlp=(24, 8), n_items=500)
