"""sasrec [recsys] — self-attentive sequential recommendation
[arXiv:1808.09781; paper]."""
from repro.configs.common import RECSYS_SHAPES as SHAPES  # noqa: F401
from repro.models.recsys import RecsysConfig

ARCH = "sasrec"
FAMILY = "recsys"


def full_config() -> RecsysConfig:
    return RecsysConfig(
        name=ARCH, model="sasrec", embed_dim=50, n_blocks=2, n_heads=1,
        seq_len=50, n_items=1_000_000)


def smoke_config() -> RecsysConfig:
    return RecsysConfig(
        name=ARCH + "-smoke", model="sasrec", embed_dim=16, n_blocks=2,
        n_heads=1, seq_len=12, n_items=500)
