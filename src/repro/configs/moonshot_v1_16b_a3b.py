"""moonshot-v1-16b-a3b [moe] — kimi/moonlight, 64 fine-grained experts top-6
[hf:moonshotai/Moonlight-16B-A3B; hf]."""
from repro.configs.common import LM_SHAPES as SHAPES  # noqa: F401
from repro.models.transformer import LMConfig

ARCH = "moonshot-v1-16b-a3b"
FAMILY = "lm"


def full_config() -> LMConfig:
    return LMConfig(
        name=ARCH, n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab=163840, head_dim=128, rope_theta=50_000.0,
        moe=True, n_experts=64, moe_top_k=6, group_size=4096)


def smoke_config() -> LMConfig:
    return LMConfig(
        name=ARCH + "-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=48, vocab=384, head_dim=16,
        moe=True, n_experts=8, moe_top_k=3, group_size=32, attn_chunk=32)
