"""mind [recsys] — multi-interest capsule routing [arXiv:1904.08030]."""
from repro.configs.common import RECSYS_SHAPES as SHAPES  # noqa: F401
from repro.models.recsys import RecsysConfig

ARCH = "mind"
FAMILY = "recsys"


def full_config() -> RecsysConfig:
    return RecsysConfig(
        name=ARCH, model="mind", embed_dim=64, n_interests=4,
        capsule_iters=3, seq_len=50, n_items=1_000_000)


def smoke_config() -> RecsysConfig:
    return RecsysConfig(
        name=ARCH + "-smoke", model="mind", embed_dim=16, n_interests=3,
        capsule_iters=2, seq_len=12, n_items=500)
