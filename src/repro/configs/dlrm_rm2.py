"""dlrm-rm2 [recsys] — [arXiv:1906.00091; paper]."""
from repro.configs.common import RECSYS_SHAPES as SHAPES  # noqa: F401
from repro.models.recsys import RecsysConfig

ARCH = "dlrm-rm2"
FAMILY = "recsys"


def full_config() -> RecsysConfig:
    return RecsysConfig(
        name=ARCH, model="dlrm", embed_dim=64, n_dense=13, n_sparse=26,
        vocab_per_field=1_000_000, multi_hot=1, n_items=1_000_000,
        bot_mlp=(512, 256, 64), top_mlp=(512, 512, 256, 1))


def smoke_config() -> RecsysConfig:
    return RecsysConfig(
        name=ARCH + "-smoke", model="dlrm", embed_dim=16, n_dense=13,
        n_sparse=6, vocab_per_field=1000, multi_hot=1, n_items=1000,
        bot_mlp=(32, 16), top_mlp=(32, 1))
