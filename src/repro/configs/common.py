"""Shared shape tables for the assigned architecture × input-shape cells."""

# LM-family transformers: seq_len × global_batch per the assignment block.
LM_SHAPES = {
    "train_4k":    {"kind": "lm_train",   "seq": 4096,    "batch": 256},
    "prefill_32k": {"kind": "lm_prefill", "seq": 32768,   "batch": 32},
    "decode_32k":  {"kind": "lm_decode",  "seq": 32768,   "batch": 128},
    # long_500k is a DECODE shape: one new token against a 524,288-entry KV
    # cache — linear per-token cost, so full-attention archs run it too
    # (DESIGN.md §5); the cache seq axis shards over (data, model).
    "long_500k":   {"kind": "lm_decode",  "seq": 524288,  "batch": 1},
}

# GNN shapes.  Node/edge counts padded to 512-divisible (mesh-shardable)
# sizes with edge pads chosen divisible by the edge-chunk (DESIGN.md §5).
GNN_SHAPES = {
    "full_graph_sm": {"kind": "gnn_train", "n_nodes": 2708, "n_edges": 10556,
                      "d_feat": 1433, "n_classes": 7,
                      "pad_nodes": 3072, "pad_edges": 12288,
                      "edge_chunk": 4096, "task": "node_class"},
    "minibatch_lg": {"kind": "gnn_train", "n_nodes": 169984,
                     "n_edges": 168960, "d_feat": 602, "n_classes": 41,
                     "pad_nodes": 169984, "pad_edges": 172032,
                     "edge_chunk": 8192, "task": "node_class",
                     "sampled": True, "batch_nodes": 1024,
                     "fanout": (15, 10), "full_nodes": 232965,
                     "full_edges": 114615892},
    "ogb_products": {"kind": "gnn_train", "n_nodes": 2449029,
                     "n_edges": 61859140, "d_feat": 100, "n_classes": 47,
                     "pad_nodes": 2449408, "pad_edges": 61865984,
                     "edge_chunk": 65536, "task": "node_class"},
    "molecule": {"kind": "gnn_train", "n_nodes": 3840, "n_edges": 8192,
                 "d_feat": 16, "n_classes": 1,
                 "pad_nodes": 4096, "pad_edges": 8192,
                 "edge_chunk": 8192, "task": "energy_force",
                 "batch_graphs": 128, "nodes_per": 30, "edges_per": 64},
}

# RecSys shapes.
RECSYS_SHAPES = {
    "train_batch":    {"kind": "recsys_train", "batch": 65536},
    "serve_p99":      {"kind": "recsys_serve", "batch": 512},
    "serve_bulk":     {"kind": "recsys_serve", "batch": 262144},
    "retrieval_cand": {"kind": "recsys_retrieval", "batch": 1,
                       "n_candidates": 1_000_000, "k": 100},
}
