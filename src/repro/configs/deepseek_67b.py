"""deepseek-67b [dense] — llama-arch [arXiv:2401.02954; hf]."""
from repro.configs.common import LM_SHAPES as SHAPES  # noqa: F401
from repro.models.transformer import LMConfig

ARCH = "deepseek-67b"
FAMILY = "lm"


def full_config() -> LMConfig:
    return LMConfig(
        name=ARCH, n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=22016, vocab=102400, head_dim=128, rope_theta=10_000.0)


def smoke_config() -> LMConfig:
    # same family traits: GQA (kv < heads), llama MLP, deep-ish stack
    return LMConfig(
        name=ARCH + "-smoke", n_layers=4, d_model=128, n_heads=8,
        n_kv_heads=2, d_ff=352, vocab=512, head_dim=16, attn_chunk=64)
