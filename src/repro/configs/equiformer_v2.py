"""equiformer-v2 [gnn] — equivariant graph attention via eSCN SO(2)
convolutions [arXiv:2306.12059; unverified]."""
import dataclasses

from repro.configs.common import GNN_SHAPES as SHAPES  # noqa: F401
from repro.models.gnn import GNNConfig

ARCH = "equiformer-v2"
FAMILY = "gnn"


def full_config(shape: dict | None = None) -> GNNConfig:
    cfg = GNNConfig(
        name=ARCH, n_layers=12, c=128, l_max=6, m_max=2, n_heads=8,
        n_rbf=32, f_in=100, n_out=47, task="node_class", edge_chunk=65536)
    if shape:
        cfg = dataclasses.replace(
            cfg, f_in=shape["d_feat"],
            n_out=shape["n_classes"] if shape["task"] == "node_class" else 1,
            task=shape["task"], edge_chunk=shape["edge_chunk"])
    return cfg


def smoke_config() -> GNNConfig:
    return GNNConfig(
        name=ARCH + "-smoke", n_layers=2, c=16, l_max=3, m_max=2, n_heads=4,
        n_rbf=8, f_in=12, n_out=5, task="node_class", edge_chunk=64)
