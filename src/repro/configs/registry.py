"""Architecture registry: --arch <id> resolution for launch scripts."""
import importlib

ARCHS = {
    "deepseek-67b": "deepseek_67b",
    "stablelm-12b": "stablelm_12b",
    "gemma3-27b": "gemma3_27b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "equiformer-v2": "equiformer_v2",
    "sasrec": "sasrec",
    "mind": "mind",
    "din": "din",
    "dlrm-rm2": "dlrm_rm2",
    # extra: the paper's own workload (not part of the 40 assigned cells)
    "sinnamon-engine": "sinnamon_engine",
}

ASSIGNED = [a for a in ARCHS if a != "sinnamon-engine"]


def get(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {list(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[arch]}")


def all_cells(include_extra: bool = False):
    names = list(ARCHS) if include_extra else ASSIGNED
    for a in names:
        mod = get(a)
        for shape in mod.SHAPES:
            yield a, shape
