"""The paper's own workload as an extra dry-run arch: a sharded Sinnamon
index at MS-MARCO scale (8.8M docs, SPLADE-like stats) serving batched
queries.  Not one of the 40 assigned cells — it is the paper-representative
cell used in EXPERIMENTS.md §Perf."""
from repro.core.engine import EngineSpec

ARCH = "sinnamon-engine"
FAMILY = "retrieval"

SHAPES = {
    "serve_msmarco": {"kind": "retrieval_serve", "corpus": 8_912_896,
                      "batch": 256, "n": 30_000, "m": 64, "max_nnz": 128,
                      "kprime_local": 64, "k": 10, "psi_q": 64},
    # billion-scale needs the §4.1.2 approximate (hashed-bucket) inverted
    # index: the exact n×C bitmap would be ~4 PB; 4096 buckets bring it to
    # C/8·4096 bytes ≈ 0.5 TB across the fleet with a quantified recall cost.
    "serve_billion": {"kind": "retrieval_serve", "corpus": 1_073_741_824,
                      "batch": 256, "n": 30_000, "m": 64, "max_nnz": 128,
                      "kprime_local": 64, "k": 10, "psi_q": 64,
                      "index_buckets": 4096},
}


def full_config(shape: dict, n_corpus_shards: int) -> EngineSpec:
    return EngineSpec(
        n=shape["n"], m=shape["m"],
        capacity=shape["corpus"] // n_corpus_shards,
        max_nnz=shape["max_nnz"], h=1, positive_only=False,
        index_buckets=shape.get("index_buckets"))


def smoke_config() -> EngineSpec:
    return EngineSpec(n=512, m=16, capacity=1024, max_nnz=48, h=2)
