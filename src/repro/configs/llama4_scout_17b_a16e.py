"""llama4-scout-17b-a16e [moe] — MoE 16 experts top-1
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]."""
from repro.configs.common import LM_SHAPES as SHAPES  # noqa: F401
from repro.models.transformer import LMConfig

ARCH = "llama4-scout-17b-a16e"
FAMILY = "lm"


def full_config() -> LMConfig:
    return LMConfig(
        name=ARCH, n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=8192, vocab=202048, head_dim=128, rope_theta=500_000.0,
        moe=True, n_experts=16, moe_top_k=1, group_size=4096,
        attn_q_chunk=256)


def smoke_config() -> LMConfig:
    return LMConfig(
        name=ARCH + "-smoke", n_layers=3, d_model=96, n_heads=8,
        n_kv_heads=2, d_ff=128, vocab=384, head_dim=16,
        moe=True, n_experts=4, moe_top_k=1, group_size=32, attn_chunk=32)
