"""Recall harness: quality of the approximate engine vs its exact oracles.

Implements the paper's §6.2/§6.5 evaluation protocol offline: build an index
at a given lever configuration, serve the query set through the production
``QueryServer`` path, and score the returned ids against the exact top-k from
:func:`repro.core.linscan.brute_force_topk` (the dense oracle; identical
result set to ``LinScanIndex`` without the postings machinery).  The sweep
driver :func:`frontier` emits one (memory, latency, recall) point per lever
configuration — the shape of the paper's Figure 8/9 trade-off curves.

Harness conventions (deliberate, see ``lever_spec``):

* documents are inserted with ``ext_id = corpus row``, so oracle ids and
  returned ids share a namespace;
* the raw VecStore keeps float32 values so the Algorithm 7 rerank is exact
  against the oracle — the *sketch* quantization under test is isolated from
  incidental storage rounding;
* ``positive_only`` stays False so ``sketch_kind="full"`` always stores both
  U and L — the paper's full-vs-lite memory comparison (§3.3) is 2m rows vs
  m rows even on non-negative collections.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core import engine as eng
from repro.serving.serve import QueryServer


def recall_at_k(pred_ids, true_ids) -> float:
    """|pred ∩ truth| / |truth| for one query (order-insensitive)."""
    truth = [int(t) for t in np.asarray(true_ids).ravel()]
    hit = set(int(p) for p in np.asarray(pred_ids).ravel())
    return sum(t in hit for t in truth) / max(len(truth), 1)


def reciprocal_rank(pred_ids, top1: int) -> float:
    """1/rank of the exact best document in the returned list (0 if absent)."""
    for rank, p in enumerate(np.asarray(pred_ids).ravel(), start=1):
        if int(p) == int(top1):
            return 1.0 / rank
    return 0.0


def exact_topk_ids(doc_idx, doc_val, q_idx, q_val, n: int, k: int,
                   chunk: int = 1024) -> np.ndarray:
    """Exact oracle ids int64[B, k] (corpus-row ids, score-descending).

    Same result set as :func:`repro.core.linscan.brute_force_topk`, computed
    as a chunked dense gather over the whole query batch at once (the
    per-doc Python loop of the single-query oracle would dominate a sweep).
    Exact-score ties break toward the lower row id *deterministically* (full
    stable descending sort — unlike argpartition-based selection, whose
    boundary membership is arbitrary under ties).
    """
    doc_idx = np.asarray(doc_idx)
    doc_val = np.asarray(doc_val, np.float32)
    q_idx = np.asarray(q_idx)
    q_val = np.asarray(q_val, np.float32)
    B, D = len(q_idx), len(doc_idx)
    qd = np.zeros((B, n), np.float32)
    for b in range(B):
        keep = q_idx[b] >= 0
        np.add.at(qd[b], q_idx[b][keep], q_val[b][keep])
    scores = np.zeros((B, D), np.float32)
    for lo in range(0, D, chunk):
        hi = min(lo + chunk, D)
        idx = doc_idx[lo:hi]
        valid = idx >= 0
        gathered = qd[:, np.where(valid, idx, 0)] * valid[None]   # [B, C, P]
        scores[:, lo:hi] = np.einsum("bcp,cp->bc", gathered, doc_val[lo:hi])
    return np.argsort(-scores, axis=1, kind="stable")[:, :k].astype(np.int64)


def pad_capacity(docs: int) -> int:
    """Smallest valid engine capacity (multiple of 32) holding ``docs``."""
    return ((docs + 31) // 32) * 32


def lever_spec(n: int, docs: int, max_nnz: int, *, m: int = 64, h: int = 1,
               sketch_kind: str = "full", cell_dtype: str = "bf16",
               index_buckets: Optional[int] = None,
               seed: int = 0) -> eng.EngineSpec:
    """An :class:`~repro.core.engine.EngineSpec` at one lever configuration.

    ``cell_dtype`` takes the lever aliases ``f32 | bf16 | f8``
    (:func:`repro.core.sketch.resolve_cell_dtype`).
    """
    return eng.EngineSpec(
        n=n, m=m, capacity=pad_capacity(docs), max_nnz=max_nnz, h=h,
        positive_only=False, index_buckets=index_buckets,
        sketch_kind=sketch_kind, dtype=cell_dtype, value_dtype="float32",
        seed=seed)


def build_index(spec: eng.EngineSpec, doc_idx, doc_val,
                batch: int = 2048) -> eng.SinnamonIndex:
    """Index a padded (idx, val) corpus with ``ext_id = row`` in batches."""
    index = eng.SinnamonIndex(spec)
    for lo in range(0, len(doc_idx), batch):
        hi = min(lo + batch, len(doc_idx))
        index.insert_many(list(range(lo, hi)), doc_idx[lo:hi],
                          doc_val[lo:hi])
    return index


def evaluate_index(index: eng.SinnamonIndex, q_idx, q_val,
                   truth: np.ndarray, *, k: int = 10,
                   kprime: Optional[int] = None,
                   budget: Optional[int] = None,
                   backend: Optional[str] = None, reps: int = 2) -> dict:
    """Serve the query batch and score it against the exact oracle ids.

    Returns ``{"recall_at_k", "mrr", "p50_ms", "p99_ms"}``.  Queries go
    through the batched ``QueryServer.query_many`` production path; the
    first call is compile warm-up and excluded from the latency window.
    """
    server = QueryServer(index, k=k, kprime=kprime or 10 * k, budget=budget,
                         score_backend=backend)
    ids, _ = server.query_many(q_idx, q_val)      # warm-up + answers
    server.reset_stats()
    for _ in range(reps):
        ids, _ = server.query_many(q_idx, q_val)
    recalls = [recall_at_k(ids[b], truth[b]) for b in range(len(q_idx))]
    mrrs = [reciprocal_rank(ids[b], truth[b][0]) for b in range(len(q_idx))]
    lat = server.latency_percentiles()
    return {"recall_at_k": float(np.mean(recalls)),
            "mrr": float(np.mean(mrrs)),
            "p50_ms": lat["p50"], "p99_ms": lat["p99"]}


_POINT_DEFAULTS = {"m": 64, "sketch_kind": "full", "cell_dtype": "bf16",
                   "kprime": None, "budget": None}


def frontier(doc_idx, doc_val, q_idx, q_val, n: int,
             points: Sequence[dict], *, k: int = 10, h: int = 1,
             index_buckets: Optional[int] = None, seed: int = 0,
             backend: Optional[str] = None, reps: int = 2,
             bounds_params: Optional[dict] = None) -> list[dict]:
    """Sweep lever configurations -> (memory, latency, recall) points.

    ``points``: dicts with any of ``m / sketch_kind / cell_dtype / kprime /
    budget`` (missing keys take ``_POINT_DEFAULTS``).  The exact oracle is
    computed once — it does not depend on the levers.  Each output point
    carries the resolved configuration, the quality/latency metrics, and the
    index memory split (``sketch_bytes`` / ``index_bytes`` — sketch plus
    bit-packed inverted index; the raw VecStore is rerank storage, not index
    memory, per the paper's §6.1.2 accounting).

    ``bounds_params``: optional kwargs for
    :func:`repro.eval.bounds.check_upper_bounds` (value distribution etc.);
    when given, every point also carries its empirical-vs-theory verdict
    under ``"bounds"``.
    """
    truth = exact_topk_ids(doc_idx, doc_val, q_idx, q_val, n, k)
    max_nnz = np.asarray(doc_idx).shape[1]
    out = []
    for raw in points:
        unknown = set(raw) - set(_POINT_DEFAULTS)
        if unknown:
            raise ValueError(f"unknown lever(s) {sorted(unknown)}; "
                             f"expected {sorted(_POINT_DEFAULTS)}")
        pt = {**_POINT_DEFAULTS, **raw}
        spec = lever_spec(n, len(doc_idx), max_nnz, m=pt["m"], h=h,
                          sketch_kind=pt["sketch_kind"],
                          cell_dtype=pt["cell_dtype"],
                          index_buckets=index_buckets, seed=seed)
        index = build_index(spec, doc_idx, doc_val)
        kprime = pt["kprime"] or min(10 * k, spec.capacity)
        metrics = evaluate_index(index, q_idx, q_val, truth, k=k,
                                 kprime=kprime, budget=pt["budget"],
                                 backend=backend, reps=reps)
        mem = index.memory_bytes()
        point = {**pt, "kprime": kprime, "k": k,
                 **metrics,
                 "sketch_bytes": mem["sketch"],
                 "index_bytes": mem["index_total"]}
        if bounds_params is not None:
            from repro.eval import bounds
            point["bounds"] = bounds.check_upper_bounds(index,
                                                        **bounds_params)
        out.append(point)
    return out
