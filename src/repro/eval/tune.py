"""Auto-tuner: pick a lever configuration for a memory budget + recall floor.

The paper's levers (§5–§6) form a small, well-behaved configuration space —
sketch half-size ``m``, ``sketch_kind`` full/lite, quantized cell dtype,
rerank budget ``k'`` and the anytime query cutoff.  Rather than asking the
operator to reason about Eq. (18) directly, :func:`tune` grid-searches the
levers on a *sample* of the corpus, measures each point with the
:mod:`repro.eval.recall` harness, and returns a ready
:class:`~repro.core.engine.EngineSpec` (plus the serving-side ``kprime`` /
``budget``) that fits the memory budget at the *target* corpus size while
holding the recall floor on the sample.

Memory is predicted analytically (:func:`spec_index_bytes` — exact, it
mirrors ``SinnamonIndex.memory_bytes``'s index accounting), so the sample
only has to be large enough for the *recall* estimate to transfer; leave a
few points of margin on ``recall_floor`` when sampling aggressively.

``repro.launch.serve --auto-tune`` exposes this end to end.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Optional, Sequence

import jax.numpy as jnp

import numpy as np

from repro.core import engine as eng
from repro.core import sketch
from repro.eval import recall as _recall


def spec_index_bytes(spec: eng.EngineSpec) -> int:
    """Predicted index bytes (sketch + bit-packed inverted index).

    Matches ``SinnamonIndex.memory_bytes()['index_total']`` without
    allocating: sketch = (m or 2m) rows × capacity cells, inverted index =
    one bit per (coordinate row, slot).  Raw VecStore bytes are rerank
    storage, not index memory (paper §6.1.2 accounting).
    """
    rows = spec.m if spec.upper_only else 2 * spec.m
    cell = jnp.dtype(sketch.resolve_cell_dtype(spec.dtype)).itemsize
    bit_rows = spec.index_buckets or spec.n
    return rows * spec.capacity * cell + bit_rows * (spec.capacity // 32) * 4


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """Outcome of a :func:`tune` search.

    ``spec`` is sized for the *target* corpus; ``point`` is the winning
    sample measurement; ``frontier`` is every evaluated point (each carries
    ``predicted_index_bytes`` at target scale and ``feasible``).  When no
    point satisfies both constraints, ``feasible`` is False and
    ``spec/point`` describe the highest-recall point within the memory
    budget (or the overall highest-recall point if none fit).
    """

    spec: eng.EngineSpec
    kprime: int
    budget: Optional[int]
    point: dict
    frontier: list
    feasible: bool


def tune(doc_idx, doc_val, q_idx, q_val, n: int, *,
         memory_budget_bytes: float, recall_floor: float, k: int = 10,
         target_docs: Optional[int] = None,
         sample_docs: int = 2048, sample_queries: int = 32,
         ms: Sequence[int] = (16, 32, 64, 96),
         sketch_kinds: Sequence[str] = ("full", "lite"),
         cell_dtypes: Sequence[str] = ("bf16",),
         kprimes: Sequence[Optional[int]] = (None,),
         budgets: Sequence[Optional[int]] = (None,),
         h: int = 1, index_buckets: Optional[int] = None, seed: int = 0,
         backend: Optional[str] = None) -> TuneResult:
    """Grid-search the levers; return a spec meeting both constraints.

    Selection among feasible points (predicted index bytes at
    ``target_docs`` ≤ budget AND sample recall@k ≥ floor): lowest measured
    p50 latency, ties broken toward smaller memory.  ``kprimes`` /
    ``budgets`` entries of None mean the harness defaults (10·k rerank, no
    query cutoff).
    """
    doc_idx = np.asarray(doc_idx)
    doc_val = np.asarray(doc_val)
    target_docs = target_docs or len(doc_idx)
    n_sample = min(sample_docs, len(doc_idx))
    nq = min(sample_queries, len(q_idx))
    sdoc_i, sdoc_v = doc_idx[:n_sample], doc_val[:n_sample]
    sq_i, sq_v = np.asarray(q_idx)[:nq], np.asarray(q_val)[:nq]

    points = [dict(m=m, sketch_kind=kind, cell_dtype=dt, kprime=kp,
                   budget=b)
              for m, kind, dt, kp, b in itertools.product(
                  ms, sketch_kinds, cell_dtypes, kprimes, budgets)]
    measured = _recall.frontier(sdoc_i, sdoc_v, sq_i, sq_v, n, points, k=k,
                                h=h, index_buckets=index_buckets, seed=seed,
                                backend=backend)

    target_cap = _recall.pad_capacity(target_docs)
    for pt in measured:
        spec = _target_spec(pt, n, target_cap, doc_idx.shape[1], h,
                            index_buckets, seed)
        pt["predicted_index_bytes"] = spec_index_bytes(spec)
        pt["feasible"] = (pt["predicted_index_bytes"] <= memory_budget_bytes
                          and pt["recall_at_k"] >= recall_floor)

    feasible = [pt for pt in measured if pt["feasible"]]
    if feasible:
        best = min(feasible,
                   key=lambda pt: (pt["p50_ms"], pt["predicted_index_bytes"]))
        ok = True
    else:
        in_budget = [pt for pt in measured
                     if pt["predicted_index_bytes"] <= memory_budget_bytes]
        pool = in_budget or measured
        best = max(pool, key=lambda pt: pt["recall_at_k"])
        ok = False
    spec = _target_spec(best, n, target_cap, doc_idx.shape[1], h,
                        index_buckets, seed)
    return TuneResult(spec=spec, kprime=int(best["kprime"]),
                      budget=best["budget"], point=best, frontier=measured,
                      feasible=ok)


def _target_spec(pt: dict, n: int, capacity: int, max_nnz: int, h: int,
                 index_buckets: Optional[int], seed: int) -> eng.EngineSpec:
    return eng.EngineSpec(
        n=n, m=pt["m"], capacity=capacity, max_nnz=max_nnz, h=h,
        positive_only=False, index_buckets=index_buckets,
        sketch_kind=pt["sketch_kind"], dtype=pt["cell_dtype"],
        value_dtype="float32", seed=seed)
