"""Quality-and-tuning harness for the streaming Sinnamon engine (paper §5–§6).

The paper's headline contribution is a set of *levers* that trade memory,
latency and accuracy against each other: sketch size ``m``, the rerank
budget ``k'``, the anytime query cutoff, the §3.3 upper-bound-only "lite"
sketch, and quantized sketch cells.  This package makes every lever
measurable against the repo's own exact oracles:

* :mod:`repro.eval.recall` — recall@k / MRR vs the exact LinScan/brute-force
  oracle, per-configuration latency, and the (memory, p99, recall) frontier
  sweep that `benchmarks/recall.py` emits as ``BENCH_recall.json``.
* :mod:`repro.eval.bounds` — measured per-coordinate sketch overestimates
  checked against the §5 theory in :mod:`repro.core.theory`, including the
  drift that §4.3 delete-then-recycle churn accumulates.
* :mod:`repro.eval.tune` — the auto-tuner: grid-search the levers on a
  corpus sample and return a ready :class:`repro.core.engine.EngineSpec`
  meeting a memory budget and recall floor (``repro.launch.serve
  --auto-tune`` wires it into the serving launcher).
"""

# The submodules are the API (`repro.eval.tune.tune(...)`); only names that
# cannot shadow a submodule are re-exported at package level.
from repro.eval import bounds, recall, tune  # noqa: F401
from repro.eval.recall import (  # noqa: F401
    build_index, evaluate_index, exact_topk_ids, frontier, lever_spec,
    recall_at_k, reciprocal_rank,
)
from repro.eval.bounds import (  # noqa: F401
    check_upper_bounds, churn_overestimate, per_coordinate_overestimate,
)
from repro.eval.tune import TuneResult, spec_index_bytes  # noqa: F401
