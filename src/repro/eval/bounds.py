"""Empirical-vs-theory: measured sketch overestimates against the §5 bounds.

The upper-bound sketch promises (Theorem 5.1) that the decoded value of any
active coordinate never undershoots the true value, and the §5 analysis
(:mod:`repro.core.theory`) predicts *how far* it overshoots: Eq. (13) gives
the CDF of the per-coordinate overestimation error Z̄ as a function of the
value distribution, the active mass Σp and the sketch geometry (m, h).

This module closes the loop on a *live index*: decode every active
coordinate of (a sample of) the stored documents, subtract the stored truth,
and compare the measured tail ``P[err > δ]`` against the theoretical tail —
the check `benchmarks/recall.py` runs on every swept frontier point and
``tests/test_eval_quality.py`` gates on.

Two deliberate wrinkles:

* **Quantized cells** (bf16/f8 sketch storage) sit up to one directed-
  rounding ulp above the real-valued sketch the theory models, so the
  empirical tail is measured at ``δ + margin`` with ``margin`` = one ulp at
  the largest stored cell magnitude (conservative; see
  :func:`quantization_margin`).
* **Churn drift** (§4.3 delete-then-recycle leaves merged residue in dirty
  columns) makes a live index *looser* than theory on purpose.  The clean
  check assumes a freshly built or compacted index;
  :func:`churn_overestimate` measures the drift trajectory explicitly —
  clean → churned → compacted — which is the evidence that compaction
  restores the theoretical regime.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch, theory
from repro.core import engine as eng


def per_coordinate_overestimate(index: eng.SinnamonIndex, *,
                                max_docs: int = 4096,
                                seed: int = 0) -> np.ndarray:
    """Measured ``decode(j) - x[j]`` over active (doc, coordinate) pairs.

    Decodes the upper bound of every active coordinate of up to ``max_docs``
    live documents straight from the index's sketch matrix (the same
    ``decode_vector`` path the §5 analysis models) and subtracts the stored
    value.  Non-negative everywhere on a clean index with float32 raw
    storage (Theorem 5.1); a narrower ``value_dtype`` can show ±1-ulp noise
    of the *store*, and dirty columns show genuine churn residue.
    """
    state, spec = index.state, index.spec
    active = np.flatnonzero(np.asarray(state.active))
    if active.size == 0:
        return np.zeros((0,), np.float32)
    if active.size > max_docs:
        gen = np.random.default_rng(seed)
        active = gen.choice(active, size=max_docs, replace=False)
    slots = jnp.asarray(np.sort(active).astype(np.int32))
    idx = state.store.indices[slots]                       # [S, P]
    val = state.store.values[slots].astype(jnp.float32)    # [S, P]
    u_cols = state.u[:, slots].T                           # [S, m]
    if state.l is None:
        decode = jax.vmap(lambda u, i: sketch.decode_vector(
            state.mappings, u, None, i)[0])
        ub = decode(u_cols, idx)
    else:
        l_cols = state.l[:, slots].T
        decode = jax.vmap(lambda u, l, i: sketch.decode_vector(
            state.mappings, u, l, i)[0])
        ub = decode(u_cols, l_cols, idx)
    err = np.asarray(ub - val)
    mask = np.asarray(idx) >= 0
    return err[mask].astype(np.float32)


def quantization_margin(index: eng.SinnamonIndex) -> float:
    """One directed-rounding ulp at the largest stored cell magnitude.

    The §5 theory models a real-valued sketch; quantized cells are rounded
    *up* (u) by at most one ulp, so measured errors can exceed the
    theoretical ones by up to ``eps(dtype) · max|cell|``.  Using the global
    max cell is conservative — it only makes the empirical tail smaller
    than an exact per-cell correction would.
    """
    dt = jnp.dtype(sketch.resolve_cell_dtype(index.spec.dtype))
    if dt == jnp.float32:
        return 0.0
    state = index.state
    top = float(jnp.max(jnp.abs(state.u.astype(jnp.float32))))
    if state.l is not None:
        top = max(top, float(jnp.max(jnp.abs(state.l.astype(jnp.float32)))))
    return float(jnp.finfo(dt).eps) * top


def check_upper_bounds(index: eng.SinnamonIndex, *, value_dist,
                       sum_p: Optional[float] = None,
                       deltas: Sequence[float] = (0.25, 0.5, 1.0),
                       slack: float = 0.05, max_docs: int = 4096,
                       seed: int = 0) -> dict:
    """Measured overestimate tails vs the Eq. (13) theoretical tails.

    value_dist: a ``(pdf, cdf, grid)`` triple from :mod:`repro.core.theory`
    (``gaussian_dist`` / ``lognormal_dist`` / ``uniform_dist`` — match the
    corpus's value law).  ``sum_p``: the active mass Σp (mean actives per
    document); estimated from the stored documents when None.  ``slack``
    absorbs Monte-Carlo noise — the *confidence* knob of the check: the
    verdict per δ is ``P̂[err > δ + margin] <= P_theory[err > δ] + slack``.

    Returns ``{"ok", "n_coords", "sum_p", "margin", "min_err", "checks"}``
    with one ``{"delta", "empirical", "bound", "ok"}`` row per δ.
    """
    errs = per_coordinate_overestimate(index, max_docs=max_docs, seed=seed)
    if errs.size == 0:
        raise ValueError("index holds no active documents to measure")
    if sum_p is None:
        state = index.state
        act = np.asarray(state.active)
        nnz = (np.asarray(state.store.indices) >= 0).sum(axis=1)
        sum_p = float(nnz[act].mean())
    pdf, cdf, grid = value_dist
    margin = quantization_margin(index)
    spec = index.spec
    checks = []
    for delta in deltas:
        emp = float((errs > delta + margin).mean())
        bound = float(1.0 - theory.error_cdf(float(delta), pdf, cdf, grid,
                                             sum_p, spec.m, spec.h))
        checks.append({"delta": float(delta), "empirical": emp,
                       "bound": bound, "ok": emp <= bound + slack})
    return {"ok": all(c["ok"] for c in checks),
            "n_coords": int(errs.size), "sum_p": float(sum_p),
            "margin": float(margin), "min_err": float(errs.min()),
            "checks": checks}


def churn_overestimate(spec: eng.EngineSpec, doc_idx, doc_val, *,
                       rounds: int = 2, frac: float = 0.25,
                       seed: int = 0, max_docs: int = 2048) -> dict:
    """The drift trajectory: clean -> churned -> compacted overestimates.

    Builds an index, then runs ``rounds`` of §4.3 churn (delete a random
    ``frac`` of the corpus, re-insert the same vectors — recycled slots get
    max/min-merged sketch columns), measuring the maximum per-coordinate
    overestimate and the engine's own ``slot_drift`` at each stage.
    ``compact()`` must return both to the clean regime (asserted by
    tests/test_eval_quality.py; reported as benchmark rows).
    """
    from repro.eval import recall as _recall

    index = _recall.build_index(spec, doc_idx, doc_val)
    gen = np.random.default_rng(seed)
    docs = len(doc_idx)

    def stage() -> dict:
        errs = per_coordinate_overestimate(index, max_docs=max_docs,
                                           seed=seed)
        return {"err_max": float(errs.max()),
                "err_mean": float(errs.mean()),
                "drift_max": float(index.slot_drift().max())}

    clean = stage()
    for _ in range(rounds):
        pick = gen.choice(docs, size=max(1, int(frac * docs)), replace=False)
        for d in pick:
            index.delete(int(d))
        index.insert_many([int(d) for d in pick],
                          np.asarray(doc_idx)[pick], np.asarray(doc_val)[pick])
    churned = stage()
    rebuilt = index.compact()
    compacted = stage()
    return {"clean": clean, "churned": churned, "compacted": compacted,
            "columns_rebuilt": int(rebuilt)}
