"""Distributed top-k merge.

The corpus axis of the retrieval engine is sharded over (pod, model); naive
``lax.top_k`` over a sharded axis makes GSPMD all-gather the *full* score
matrix (O(B·C) bytes).  The hierarchical merge below all-gathers only the
per-shard candidate tuples (O(B·shards·k') bytes — the paper's "monolithic
index, segment the lists" parallelism mapped onto SPMD):

    local top-k'  →  all-gather (value, global-id) pairs  →  global top-k.

Used inside shard_map bodies (see repro.serving.sharded) and directly by
tests on a 1-device mesh.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp


def local_candidates(scores: jax.Array, payload: jax.Array, k: int
                     ) -> Tuple[jax.Array, jax.Array]:
    """Per-shard top-k along the last axis; returns (values, payload)."""
    vals, pos = jax.lax.top_k(scores, k)
    return vals, jnp.take_along_axis(
        jnp.broadcast_to(payload, scores.shape), pos, axis=-1)


def merge_over_axes(vals: jax.Array, payload: jax.Array,
                    axes: Sequence[str], k: int):
    """All-gather candidate tuples over mesh ``axes`` and take the global top-k.

    Must run inside shard_map with ``axes`` as manual axes.  Output is
    replicated over ``axes``.
    """
    for ax in axes:
        vals = jax.lax.all_gather(vals, ax, axis=-1, tiled=True)
        payload = jax.lax.all_gather(payload, ax, axis=-1, tiled=True)
    top_vals, pos = jax.lax.top_k(vals, k)
    return top_vals, jnp.take_along_axis(payload, pos, axis=-1)


def topk_with_ids(scores: jax.Array, ids: jax.Array, k: int,
                  axes: Sequence[str] = ()):
    """Top-k of ``scores`` with payload ``ids``; distributed iff axes given."""
    vals, pay = local_candidates(scores, ids, min(k, scores.shape[-1]))
    if axes:
        vals, pay = merge_over_axes(vals, pay, axes, k)
    return vals, pay
