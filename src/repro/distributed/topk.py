"""Distributed top-k merge.

The corpus axis of the retrieval engine is sharded over (pod, model); naive
``lax.top_k`` over a sharded axis makes GSPMD all-gather the *full* score
matrix (O(B·C) bytes).  The hierarchical merge below all-gathers only the
per-shard candidate tuples (O(B·shards·k') bytes — the paper's "monolithic
index, segment the lists" parallelism mapped onto SPMD):

    local top-k'  →  all-gather (value, payload...) tuples  →  global top-k.

Payloads may be a single array or a tuple of arrays (e.g. external id AND a
packed (shard, slot) locator) — every payload rides the same top-k permutation
so one merge carries all of them.

Used inside shard_map bodies (see repro.serving.sharded) and directly by
tests on a 1-device mesh.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# (shard, slot) payload encoding
# ---------------------------------------------------------------------------
# Candidate tuples crossing shards carry a packed int32 locator so the host
# (or a later pipeline stage) can route follow-up work — delete, re-rank,
# cache fill — straight back to the owning shard without a lookup table.

SLOT_BITS = 24                      # up to 16M slots per shard
_SLOT_MASK = (1 << SLOT_BITS) - 1


def pack_shard_slot(shard, slot) -> jax.Array:
    """Encode (shard, local slot) into one int32: shard << SLOT_BITS | slot."""
    shard = jnp.asarray(shard, jnp.int32)
    slot = jnp.asarray(slot, jnp.int32)
    return (shard << SLOT_BITS) | (slot & _SLOT_MASK)


def unpack_shard_slot(packed) -> Tuple[jax.Array, jax.Array]:
    """Decode :func:`pack_shard_slot` back to (shard, local slot)."""
    packed = jnp.asarray(packed, jnp.int32)
    return packed >> SLOT_BITS, packed & _SLOT_MASK


def _as_tuple(payload):
    return (payload, True) if isinstance(payload, tuple) else ((payload,),
                                                               False)


def local_candidates(scores: jax.Array, payload, k: int):
    """Per-shard top-k along the last axis; returns (values, payload(s))."""
    vals, pos = jax.lax.top_k(scores, k)
    pays, is_tuple = _as_tuple(payload)
    out = tuple(jnp.take_along_axis(jnp.broadcast_to(p, scores.shape), pos,
                                    axis=-1) for p in pays)
    return vals, (out if is_tuple else out[0])


def merge_over_axes(vals: jax.Array, payload, axes: Sequence[str], k: int):
    """All-gather candidate tuples over mesh ``axes`` and take the global top-k.

    ``payload`` is one array or a tuple of arrays, all shaped like ``vals``.
    Must run inside shard_map with ``axes`` as manual axes.  Output is
    replicated over ``axes``.
    """
    pays, is_tuple = _as_tuple(payload)
    for ax in axes:
        vals = jax.lax.all_gather(vals, ax, axis=-1, tiled=True)
        pays = tuple(jax.lax.all_gather(p, ax, axis=-1, tiled=True)
                     for p in pays)
    top_vals, pos = jax.lax.top_k(vals, k)
    out = tuple(jnp.take_along_axis(p, pos, axis=-1) for p in pays)
    return top_vals, (out if is_tuple else out[0])


def topk_with_ids(scores: jax.Array, ids: jax.Array, k: int,
                  axes: Sequence[str] = ()):
    """Top-k of ``scores`` with payload ``ids``; distributed iff axes given."""
    vals, pay = local_candidates(scores, ids, min(k, scores.shape[-1]))
    if axes:
        vals, pay = merge_over_axes(vals, pay, axes, k)
    return vals, pay
