"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Models annotate every tensor dimension with a *logical* axis name; a rule
table maps each logical axis to an ordered list of candidate mesh-axis
tuples.  ``spec_for`` picks, per dimension, the first candidate whose mesh
axes (a) are all unused so far in this spec and (b) have a product that
divides the dimension — otherwise the dimension is replicated.  This single
mechanism is what lets every (architecture × shape × mesh) cell compile:
e.g. deepseek's 8 KV heads can't split over model=16, so the decode cache
falls through to its next rule (shard the KV *sequence* axis) automatically.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisCandidates = Sequence[Tuple[str, ...]]
Rules = Dict[str, AxisCandidates]

# Candidates are tried in order.  () entries are implicit — a miss replicates.
TRAIN_RULES: Rules = {
    # activations
    "batch":    [("pod", "data"), ("data",)],
    "seq":      [],
    # Megatron-style sequence parallelism for the residual stream: the layer
    # carry is seq-sharded over 'model'; attention/MLP constraints re-shard
    # to heads/mlp and GSPMD inserts the all-gather/reduce-scatter pairs.
    "act_seq":  [("model",)],
    "embed":    [],
    "heads":    [("model",)],
    "kv_heads": [("model",)],
    "kv_seq":   [("pod", "data", "model"), ("data", "model"), ("model",)],
    "mlp":      [("model",)],
    "vocab":    [("model",)],
    "expert":   [("model",)],
    "cap":      [],
    "group":    [("pod", "data"), ("data",)],
    # weights: fan-in dims get ZeRO/FSDP-style sharding over the data axes
    "fsdp":     [("data",), ("pod",)],
    # graph: node tensors are REPLICATED on the node axis (arbitrary-index
    # gathers from a node-sharded array force GSPMD replication anyway) and
    # sharded over 'model' on the channel axis; edges shard over the data
    # axes, with partial per-shard aggregation all-reduced into the node
    # accumulators.  See DESIGN.md §4 (GNN).
    "nodes":    [],
    "edges":    [("pod", "data"), ("data",)],
    "gnn_c":    [("model",)],
    "feat":     [],
    "coef":     [],
    # recsys
    "table_rows": [("pod", "model"), ("model",)],
    "fields":   [],
    "candidates": [("pod", "model"), ("model",)],
    # retrieval engine
    "slots":    [("pod", "model"), ("model",)],
    "slot_words": [("pod", "model"), ("model",)],
    "sketch_rows": [],
    "dim":      [],
}

# Serving differs only in how the (smaller) batch is placed.
SERVE_RULES: Rules = dict(TRAIN_RULES)


def _axes_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def spec_for(mesh: Mesh, shape: Sequence[int],
             logical: Sequence[Optional[str]],
             rules: Optional[Rules] = None) -> P:
    """PartitionSpec for ``shape`` given per-dimension logical axis names."""
    rules = rules if rules is not None else TRAIN_RULES
    used: set = set()
    out = []
    for dim, name in zip(shape, logical):
        placed = None
        if name is not None:
            for cand in rules.get(name, []):
                cand = tuple(a for a in cand if a in mesh.axis_names)
                if not cand or any(a in used for a in cand):
                    continue
                if dim % _axes_size(mesh, cand) == 0 and dim > 0:
                    placed = cand if len(cand) > 1 else cand[0]
                    used.update(cand)
                    break
        out.append(placed)
    # trim trailing Nones (canonical form)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def sharding_for(mesh, shape, logical, rules=None) -> NamedSharding:
    return NamedSharding(mesh, spec_for(mesh, shape, logical, rules))


def constrain(x: jax.Array, mesh: Mesh, logical: Sequence[Optional[str]],
              rules: Optional[Rules] = None) -> jax.Array:
    """with_sharding_constraint by logical axis names (no-op on 1-dev mesh)."""
    if mesh.size == 1:
        return x
    return jax.lax.with_sharding_constraint(
        x, sharding_for(mesh, x.shape, logical, rules))


class L:
    """Logical-axes annotation for one tensor (an opaque pytree *leaf*)."""

    __slots__ = ("axes",)

    def __init__(self, *axes: Optional[str]):
        self.axes = tuple(axes)

    def __repr__(self):
        return f"L{self.axes}"


def tree_sharding(mesh: Mesh, abstract_tree, logical_tree, rules=None):
    """Map (pytree of ShapeDtypeStructs, matching pytree of L(...)) → shardings."""
    return jax.tree.map(
        lambda ab, lg: sharding_for(mesh, ab.shape, lg.axes, rules),
        abstract_tree, logical_tree,
        is_leaf=lambda x: isinstance(x, L))
