"""Mesh construction helpers.

All functions — never module-level constants — so importing this module never
touches jax device state (required by the dry-run protocol).
"""

from __future__ import annotations

import math
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    """Build a mesh from the first prod(shape) available devices."""
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise ValueError(f"need {n} devices, have {len(devices)} "
                         "(dry-run scripts must set XLA_FLAGS "
                         "--xla_force_host_platform_device_count first)")
    arr = np.asarray(devices[:n]).reshape(tuple(shape))
    return Mesh(arr, tuple(axes))


def single_device_mesh(axes: Sequence[str] = ("data", "model")) -> Mesh:
    """1x1 mesh for CPU tests — same code path, no sharding."""
    return make_mesh((1,) * len(axes), axes)


def corpus_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes the retrieval corpus (document slots) is sharded over."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "model"))


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes the query/train batch is sharded over."""
    return tuple(a for a in mesh.axis_names if a == "data")


def n_shards(mesh: Mesh, axes: Sequence[str]) -> int:
    return math.prod(mesh.shape[a] for a in axes)


def linear_index(mesh: Mesh, axes: Sequence[str]):
    """Linearised shard index over ``axes``, traced inside a shard_map body.

    Major-to-minor in the order given, matching how a PartitionSpec with
    ``axes`` as one tuple entry lays contiguous blocks over the mesh — so
    shard ``i`` of an array sharded P((axes,)) owns block ``i``.
    """
    i = 0
    for ax in axes:
        i = i * mesh.shape[ax] + jax.lax.axis_index(ax)
    return jnp.int32(i)


def named(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))
