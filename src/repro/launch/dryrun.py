import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape) cell, lower + compile the step
function on the production mesh — 16×16 = 256 chips single-pod and
2×16×16 = 512 chips multi-pod — and record memory_analysis(),
cost_analysis() and the collective-byte census parsed from the optimized
HLO.  Failures here (sharding mismatch, OOM at compile, unsupported
collective) are bugs in the system.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-67b \
        --shape train_4k [--multi-pod] [--out results.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import numpy as np       # noqa: E402

from repro.configs import registry            # noqa: E402
from repro.launch import cells                # noqa: E402
from repro.launch.mesh import make_production_mesh   # noqa: E402

# TPU v5e-class hardware constants (per chip) for the roofline terms.
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*(?:\([^)]*\)|(\w+)\[[^\]]*\])?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|"
                       r"pred|c64|c128|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in optimized HLO.

    Bytes are per-participant (the HLO is the per-device SPMD module), i.e.
    directly comparable to per-chip link bandwidth.
    """
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0, "count": 0}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)$", s)
        if not m:
            continue
        rest = m.group(1)
        cm = re.match(
            r"^(?:\(|tuple\()?\s*(?:(?:f64|f32|f16|bf16|s64|u64|s32|u32|s16|"
            r"u16|s8|u8|pred|c64|c128|f8e4m3fn|f8e5m2)\[[0-9,]*\][{}\w,/#\s]*"
            r",?\s*)+\)?\s*(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(-start)?\(", rest)
        if not cm:
            continue
        op = cm.group(1)
        nbytes = 0
        head = rest.split(cm.group(1))[0]
        for dt, dims in _SHAPE_RE.findall(head):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[op] += nbytes
        out["count"] += 1
    out["total"] = sum(out[k] for k in
                       ("all-gather", "all-reduce", "reduce-scatter",
                        "all-to-all", "collective-permute"))
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             rules=None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.time()
    bundle = cells.build(arch, shape_name, mesh, rules)
    with mesh:
        jitted = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                         donate_argnums=bundle.donate_argnums)
        lowered = jitted.lower(*bundle.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())

    flops = float(cost.get("flops", 0.0))
    bytes_hbm = float(cost.get("bytes accessed", 0.0))
    res = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "ok": True,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        # memory_analysis is per-device for SPMD modules
        "bytes_per_device": int(getattr(mem, "temp_size_in_bytes", 0)
                                + getattr(mem, "argument_size_in_bytes", 0)
                                + getattr(mem, "output_size_in_bytes", 0)
                                - getattr(mem, "alias_size_in_bytes", 0)),
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        "arg_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        # cost_analysis of the SPMD module is per-device
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_hbm,
        "collective_bytes_per_device": coll["total"],
        "collectives": coll,
        # roofline terms (seconds)
        "t_compute": flops / PEAK_FLOPS,
        "t_memory": bytes_hbm / HBM_BW,
        "t_collective": coll["total"] / ICI_BW,
        "meta": bundle.meta,
    }
    terms = {"compute": res["t_compute"], "memory": res["t_memory"],
             "collective": res["t_collective"]}
    res["bottleneck"] = max(terms, key=terms.get)
    mf = bundle.meta.get("model_flops")
    if mf:
        res["model_flops"] = mf
        res["useful_flops_frac"] = (mf / (flops * n_chips)
                                    if flops else None)
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--include-extra", action="store_true",
                    help="also run the sinnamon-engine cells")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.all:
        todo = list(registry.all_cells(include_extra=args.include_extra))
    else:
        todo = [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for arch, shape in todo:
        for mp in meshes:
            tag = f"{arch}/{shape}/{'2x16x16' if mp else '16x16'}"
            try:
                res = run_cell(arch, shape, multi_pod=mp)
                print(f"[OK]   {tag}: bottleneck={res['bottleneck']} "
                      f"mem/dev={res['bytes_per_device']/2**30:.2f}GiB "
                      f"t=({res['t_compute']:.3e},{res['t_memory']:.3e},"
                      f"{res['t_collective']:.3e})s "
                      f"compile={res['compile_s']:.0f}s", flush=True)
            except Exception as e:                     # noqa: BLE001
                res = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16", "ok": False,
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
            results.append(res)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    n_ok = sum(1 for r in results if r.get("ok"))
    print(f"{n_ok}/{len(results)} cells OK")
    if n_ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
