"""Serving launcher for the retrieval engine: build (or recover) an index,
then serve batched queries with the anytime budget.

    PYTHONPATH=src python -m repro.launch.serve --docs 10000 --queries 64 \
        [--budget 16] [--kprime 800] [--index-buckets 2048] [--shards 4] \
        [--sketch-kind full|lite] [--value-dtype f32|bf16|f8] \
        [--auto-tune --tune-memory-mb 8 --recall-floor 0.9] \
        [--score-backend pallas|grouped|reference] \
        [--wal runs/wal --snapshot-dir runs/snap --snapshot-every 5000 \
         --compact-threshold 0.5]

``--shards N`` (N > 1) serves through the mesh-sharded streaming index on a
host-local mesh (N forced host devices, corpus sharded over 'model'), using
the batched `query_many` path; the default is the single-device index.

``--sketch-kind lite`` serves the §3.3 upper-bound-only half sketch and
``--value-dtype`` picks the quantized sketch-cell storage — the paper's
memory/accuracy levers (see docs/levers.md and ``repro.eval``).
``--auto-tune`` ignores ``--m/--sketch-kind/--value-dtype`` and instead
grid-searches those levers on a corpus sample (``repro.eval.tune``) for the
cheapest configuration that fits ``--tune-memory-mb`` of index memory at
``--docs`` scale while holding ``--recall-floor`` on the sample.

``--wal DIR`` makes the index durable: every insert/delete is logged to the
write-ahead log before it is applied, and on startup the launcher *recovers*
(latest snapshot from ``--snapshot-dir`` + WAL tail replay) instead of
re-indexing — so a second run with the same dirs skips the build entirely.
``--snapshot-every N`` snapshots after every N logged ops;
``--compact-threshold X`` rebuilds recycled sketch columns whenever the max
per-slot overestimate exceeds X (see repro.persist).

Observability (see docs/observability.md):

* ``--metrics-port P`` serves the process-global metrics registry over
  HTTP: ``/metrics`` (Prometheus text), ``/metrics.json`` (structured
  snapshot), ``/healthz`` (liveness), ``/readyz`` (readiness: 503 until
  the index is built/recovered), plus the ``/debug/*`` surfaces below.
* ``--event-log FILE`` appends one JSON line per query / maintenance op
  (trace spans attached on sampled queries); ``--event-log-max-bytes B``
  rotates the file at B bytes keeping ``--event-log-keep`` segments.
* ``--trace-every N`` runs every N-th query batch on the staged path,
  populating per-stage latency histograms (default 32 when metrics or the
  event log are on, else off; 0 disables).
* ``--recorder-capacity N`` sizes the tail-sampled flight recorder ring
  (``/debug/requests``, ``/debug/trace/<id>``, ``/debug/batches``);
  ``--record-sample R`` head-samples fast OK requests at rate R (errors,
  rejections, deadline misses, and the slowest decile are always kept).
* ``--slo-latency-ms`` / ``--slo-target`` / ``--slo-availability`` declare
  the serving SLOs; a background monitor publishes ``repro_slo_*``
  burn-rate gauges over fast/slow windows (``--slo-fast-window-s`` /
  ``--slo-slow-window-s``), serves ``/debug/slo``, and WARNs to the event
  log on sustained burn.
* ``--profile-dir DIR`` captures a ``jax.profiler`` trace of the query
  loop for kernel-level inspection, and mounts ``/debug/profile?seconds=N``
  for on-demand traces while serving.
* ``--hold-seconds S`` keeps the process (and the metrics endpoint) alive
  after the query loop — for scrape-based smoke tests and demos.

Serving front door (see docs/serving.md):

* ``--serve-port P`` boots the async HTTP/JSON front door
  (``repro.serving.frontend``) on this port after the build/recovery —
  ``POST /v1/query`` plus the standard ``/metrics`` family on the same
  port — and holds for ``--hold-seconds``.
* ``--max-batch B`` / ``--batch-window-ms W`` — dynamic batching: coalesce
  queries for up to W ms or until B are waiting, then issue ONE fused
  ``query_many`` dispatch.
* ``--queue-depth D`` — bounded admission queue; requests beyond D are
  rejected with 429 + Retry-After (explicit backpressure).
* ``--deadline-ms T`` — default per-request deadline; queries whose budget
  elapses while queued are dropped and counted, not served late.

Robustness (see docs/robustness.md):

* ``--failpoints SPEC`` arms the deterministic fault-injection registry
  (``site=mode[:arg][:prob]``, comma-separated — e.g.
  ``wal.fsync=error:0.02,device.dispatch=stall:250ms``) for chaos drills;
  ``--failpoint-seed`` fixes the injection schedule.  Equivalent to the
  ``REPRO_FAILPOINTS`` / ``REPRO_FAILPOINT_SEED`` environment variables.
* ``--degrade`` enables the front door's graceful-degradation ladder:
  driven by SLO fast-burn and queue depth, L1 shrinks the rerank budget,
  L2 serves sketch-only upper-bound scores (``degraded: true`` in the
  response), L3 sheds the lowest-priority tenants with 429.  Thresholds
  via ``--degrade-enter-burn`` / ``--degrade-exit-burn`` /
  ``--degrade-enter-queue-frac`` / ``--degrade-exit-queue-frac`` /
  ``--degrade-dwell-ticks`` (hysteresis).
* ``--watchdog-timeout-s S`` fails in-flight front-door queries with 504
  when one fused dispatch is stuck on the device longer than S seconds.

Index construction goes through the ``repro.api`` facade: the flags here
are argparse spellings of :class:`repro.api.IndexConfig` (and the ``--wal``
family of :class:`repro.api.DurabilityConfig`), and the launcher calls
``open_index`` exactly like library code should.
"""

from __future__ import annotations

import argparse
import os


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=10_000)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--kprime", type=int, default=800)
    ap.add_argument("--budget", type=int, default=None)
    ap.add_argument("--m", type=int, default=60)
    ap.add_argument("--h", type=int, default=1)
    ap.add_argument("--index-buckets", type=int, default=None)
    ap.add_argument("--sketch-kind", default="full",
                    choices=["full", "lite"],
                    help="lite = upper-bound-only half sketch (§3.3): "
                         "halves sketch memory; on signed collections "
                         "recall degrades (measure with repro.eval)")
    ap.add_argument("--value-dtype", default="bf16",
                    choices=["f32", "bf16", "f8"],
                    help="sketch cell storage dtype (quantized cells are "
                         "directed-rounded and dequantized in-kernel)")
    ap.add_argument("--auto-tune", action="store_true",
                    help="pick m/sketch-kind/value-dtype with the "
                         "repro.eval.tune grid search instead of the flags")
    ap.add_argument("--tune-memory-mb", type=float, default=8.0, metavar="MB",
                    help="auto-tune: index memory budget (sketch + inverted "
                         "index) at --docs scale")
    ap.add_argument("--recall-floor", type=float, default=0.9, metavar="R",
                    help="auto-tune: minimum recall@k on the tuning sample")
    ap.add_argument("--score-backend", default=None,
                    choices=["reference", "grouped", "pallas"],
                    help="scoring backend for the query hot path "
                         "(default: REPRO_SCORE_BACKEND env or 'pallas', "
                         "the fused tiled-top-k kernel)")
    ap.add_argument("--shards", type=int, default=1,
                    help=">1: sharded streaming index on a host-local mesh")
    ap.add_argument("--device-budget-mb", type=float, default=None,
                    metavar="MB",
                    help="per-device byte budget for raw vector rows; "
                         "enables the hot/cold tiered store (sketches stay "
                         "resident, rows page between a device chunk cache "
                         "and host RAM — docs/tiering.md); results are "
                         "bit-identical to the resident index")
    ap.add_argument("--tier-chunk-slots", type=int, default=256, metavar="S",
                    help="tiered store paging granularity in slots per chunk")
    ap.add_argument("--query-batch", type=int, default=16)
    ap.add_argument("--dataset", default="splade_like")
    ap.add_argument("--wal", default=None, metavar="DIR",
                    help="write-ahead-log dir; enables the durable index")
    ap.add_argument("--snapshot-dir", default=None, metavar="DIR",
                    help="snapshot dir (recovery base + periodic snapshots)")
    ap.add_argument("--snapshot-every", type=int, default=None, metavar="N",
                    help="snapshot after every N logged ops")
    ap.add_argument("--compact-threshold", type=float, default=None,
                    metavar="X", help="compact when max sketch drift > X")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="P",
                    help="serve /metrics (Prometheus text) + /metrics.json "
                         "+ /healthz on this port (0 = OS-assigned)")
    ap.add_argument("--event-log", default=None, metavar="FILE",
                    help="append one JSON line per query/maintenance op")
    ap.add_argument("--event-log-max-bytes", type=int, default=None,
                    metavar="B", help="rotate the event log at B bytes "
                                      "(default: never)")
    ap.add_argument("--event-log-keep", type=int, default=3, metavar="N",
                    help="rotated event-log segments to keep")
    ap.add_argument("--recorder-capacity", type=int, default=512,
                    metavar="N", help="flight-recorder ring size "
                                      "(0 disables the recorder)")
    ap.add_argument("--record-sample", type=float, default=0.05, metavar="R",
                    help="head-sampling rate for fast OK requests "
                         "(failures and the slow tail are always kept)")
    ap.add_argument("--slo-latency-ms", type=float, default=100.0,
                    metavar="MS", help="latency SLO bound")
    ap.add_argument("--slo-target", type=float, default=0.99, metavar="F",
                    help="fraction of requests that must meet the latency "
                         "bound")
    ap.add_argument("--slo-availability", type=float, default=0.999,
                    metavar="F", help="fraction of requests that must not "
                                      "be rejected/expired/errored")
    ap.add_argument("--slo-fast-window-s", type=float, default=300.0,
                    metavar="S", help="fast burn-rate window")
    ap.add_argument("--slo-slow-window-s", type=float, default=3600.0,
                    metavar="S", help="slow burn-rate window")
    ap.add_argument("--trace-every", type=int, default=None, metavar="N",
                    help="run every N-th query batch on the staged path "
                         "(per-stage histograms); default 32 when metrics "
                         "or the event log are enabled, 0 = off")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of the query loop")
    ap.add_argument("--hold-seconds", type=float, default=0.0, metavar="S",
                    help="keep the process (and metrics endpoint) alive "
                         "this long after the query loop")
    ap.add_argument("--serve-port", type=int, default=None, metavar="P",
                    help="boot the HTTP/JSON front door (POST /v1/query + "
                         "/metrics family) on this port (0 = OS-assigned) "
                         "and hold for --hold-seconds")
    ap.add_argument("--max-batch", type=int, default=16, metavar="B",
                    help="front door: max queries coalesced into one fused "
                         "dispatch")
    ap.add_argument("--batch-window-ms", type=float, default=2.0,
                    metavar="W", help="front door: max coalesce wait after "
                                      "the first queued query")
    ap.add_argument("--queue-depth", type=int, default=128, metavar="D",
                    help="front door: bounded admission queue; beyond this "
                         "requests get 429 + Retry-After")
    ap.add_argument("--deadline-ms", type=float, default=1000.0, metavar="T",
                    help="front door: default per-request deadline; "
                         "requests expiring in-queue are dropped + counted")
    ap.add_argument("--failpoints", default=None, metavar="SPEC",
                    help="arm fault-injection failpoints: comma-separated "
                         "site=mode[:arg][:prob] (docs/robustness.md); "
                         "equivalent to REPRO_FAILPOINTS")
    ap.add_argument("--failpoint-seed", type=int, default=0, metavar="N",
                    help="seed for the failpoint injection schedule")
    ap.add_argument("--degrade", action="store_true",
                    help="front door: enable the graceful-degradation "
                         "ladder (L1 shrink rerank, L2 sketch-only, "
                         "L3 shed lowest-priority tenants)")
    ap.add_argument("--degrade-enter-burn", type=float, default=4.0,
                    metavar="X", help="ladder: escalate when SLO fast-burn "
                                      ">= X")
    ap.add_argument("--degrade-exit-burn", type=float, default=1.0,
                    metavar="X", help="ladder: calm requires fast-burn <= X")
    ap.add_argument("--degrade-enter-queue-frac", type=float, default=0.75,
                    metavar="F", help="ladder: escalate when queue fill "
                                      "fraction >= F")
    ap.add_argument("--degrade-exit-queue-frac", type=float, default=0.25,
                    metavar="F", help="ladder: calm requires queue fill "
                                      "fraction <= F")
    ap.add_argument("--degrade-dwell-ticks", type=int, default=4,
                    metavar="N", help="ladder: consecutive calm ticks "
                                      "before de-escalating one level")
    ap.add_argument("--watchdog-timeout-s", type=float, default=None,
                    metavar="S", help="front door: fail in-flight queries "
                                      "with 504 when a fused dispatch is "
                                      "stuck longer than S seconds")
    args = ap.parse_args(argv)
    if args.trace_every is None:
        args.trace_every = 32 if (args.metrics_port is not None
                                  or args.event_log) else 0
    if args.wal is None and (args.snapshot_dir is not None
                             or args.snapshot_every is not None
                             or args.compact_threshold is not None):
        ap.error("--snapshot-dir/--snapshot-every/--compact-threshold "
                 "require --wal (durability is WAL-based)")
    if args.snapshot_every is not None and args.snapshot_dir is None:
        ap.error("--snapshot-every requires --snapshot-dir "
                 "(periodic snapshots need somewhere to go)")
    if (args.device_budget_mb is not None and args.wal is not None
            and args.shards > 1):
        ap.error("--device-budget-mb with both --wal and --shards > 1 is "
                 "not supported yet; drop one of the three")
    if args.auto_tune and args.wal is not None:
        ap.error("--auto-tune is incompatible with --wal: durable runs pin "
                 "their spec to the WAL dir; tune first, then launch with "
                 "the chosen flags")
    return args


def _check_launch_params(args) -> None:
    """Pin the corpus/spec flags of a durable run to its WAL directory."""
    import json
    import sys

    params = {"dataset": args.dataset, "docs": args.docs, "m": args.m,
              "h": args.h, "index_buckets": args.index_buckets,
              "sketch_kind": args.sketch_kind,
              "value_dtype": args.value_dtype,
              "shards": args.shards}
    os.makedirs(args.wal, exist_ok=True)
    pfile = os.path.join(args.wal, "launch_params.json")
    if os.path.exists(pfile):
        with open(pfile) as f:
            prev = json.load(f)
        changed = {k: (prev.get(k), v) for k, v in params.items()
                   if prev.get(k) != v and k != "shards"}
        if changed:
            sys.exit(f"refusing to recover from {args.wal}: "
                     f"{', '.join(f'--{k} was {a!r}, now {b!r}' for k, (a, b) in changed.items())} "
                     f"— the synthetic corpus/spec would no longer match the "
                     f"indexed vectors; rerun with the original flags or "
                     f"fresh --wal/--snapshot-dir directories")
        if prev != params:       # only the (elastic) shard count changed
            with open(pfile, "w") as f:
                json.dump(params, f)
    else:
        with open(pfile, "w") as f:
            json.dump(params, f)


def main():
    args = parse_args()
    if args.shards > 1:
        # Must happen before jax initialises its backends; append so any
        # user-provided XLA_FLAGS survive.
        flag = f"--xla_force_host_platform_device_count={args.shards}"
        prev = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in prev:
            os.environ["XLA_FLAGS"] = f"{prev} {flag}".strip()

    import numpy as np

    from repro.api import DurabilityConfig, IndexConfig, open_index
    from repro.core.linscan import brute_force_topk
    from repro.data import synth
    from repro.obs import (
        EventLog,
        FlightRecorder,
        MetricsServer,
        ReadyState,
        SLOMonitor,
        SLOSpec,
        set_event_log,
        set_recorder,
    )
    from repro.obs.instrument import install_recorder_gauges
    from repro.serving.serve import QueryServer

    if args.failpoints:
        from repro.fault import FailpointRegistry, set_failpoints
        set_failpoints(FailpointRegistry(seed=args.failpoint_seed)
                       .configure(args.failpoints))
        print(f"failpoints armed: {args.failpoints} "
              f"(seed={args.failpoint_seed})")

    obs_on = args.metrics_port is not None or args.serve_port is not None
    if args.event_log:
        set_event_log(EventLog(args.event_log,
                               max_bytes=args.event_log_max_bytes,
                               keep=args.event_log_keep))
        print(f"event log: {args.event_log}"
              + (f" (rotate at {args.event_log_max_bytes} B, "
                 f"keep {args.event_log_keep})"
                 if args.event_log_max_bytes else ""))
    recorder = slo_monitor = None
    ready = ReadyState()
    ready.mark("engine", False, "index build/recovery in progress")
    if obs_on and args.recorder_capacity > 0:
        recorder = FlightRecorder(capacity=args.recorder_capacity,
                                  sample_rate=args.record_sample)
        set_recorder(recorder)
        install_recorder_gauges(recorder)
    if obs_on:
        slo_monitor = SLOMonitor(
            SLOSpec(latency_ms=args.slo_latency_ms,
                    latency_target=args.slo_target,
                    availability_target=args.slo_availability),
            fast_window_s=args.slo_fast_window_s,
            slow_window_s=args.slo_slow_window_s)
    metrics_server = None
    if args.metrics_port is not None:
        metrics_server = MetricsServer(
            port=args.metrics_port, ready=ready, recorder=recorder,
            slo=slo_monitor, profile_dir=args.profile_dir).start()
        print(f"metrics: {metrics_server.url}/metrics "
              f"(json: /metrics.json, liveness: /healthz, "
              f"readiness: /readyz, debug: /debug/requests /debug/slo)")

    ds = synth.DATASETS[args.dataset]
    idx, val = synth.make_corpus(0, ds, args.docs, pad=256)
    qi, qv = synth.make_queries(1, ds, args.queries, pad=96)
    cap = ((args.docs + 31) // 32) * 32
    sketch_kind, cell_dtype = args.sketch_kind, args.value_dtype
    if args.auto_tune:
        from repro.eval import tune as tunelib
        result = tunelib.tune(
            idx, val, qi, qv, ds.n,
            memory_budget_bytes=args.tune_memory_mb * 2 ** 20,
            recall_floor=args.recall_floor, k=args.k,
            target_docs=args.docs, sample_docs=min(args.docs, 2048),
            sample_queries=min(args.queries, 32),
            ms=tuple(sorted({32, args.m, 96})),
            cell_dtypes=("bf16", "f8"),
            kprimes=(args.kprime,), budgets=(args.budget,),
            h=args.h, index_buckets=args.index_buckets)
        pt = result.point
        sketch_kind, cell_dtype, args.m = (pt["sketch_kind"],
                                           pt["cell_dtype"], pt["m"])
        print(f"auto-tune: m={pt['m']} sketch_kind={sketch_kind} "
              f"value_dtype={cell_dtype} -> predicted index "
              f"{pt['predicted_index_bytes'] / 2**20:.2f} MiB @ {args.docs} "
              f"docs, sample recall@{args.k}={pt['recall_at_k']:.3f} "
              f"({'meets constraints' if result.feasible else 'NO feasible point — best-recall fallback'})")
    if args.wal:
        # Recovery serves the PREVIOUS run's vectors, while the corpus and
        # the recall ground truth are regenerated from the flags — and
        # synth.make_corpus is not prefix-stable across --docs.  Refuse to
        # mix durable state with a differently-drawn corpus (or a spec the
        # snapshot would silently override).
        _check_launch_params(args)
    durability = None
    if args.wal:
        durability = DurabilityConfig(
            wal_dir=args.wal, snapshot_dir=args.snapshot_dir,
            snapshot_every=args.snapshot_every,
            compact_threshold=args.compact_threshold)
    config = IndexConfig(
        n=ds.n, capacity=cap, m=args.m, h=args.h, max_nnz=256,
        positive_only=ds.nonneg, index_buckets=args.index_buckets,
        sketch_kind=sketch_kind, cell_dtype=cell_dtype,
        backend=args.score_backend, shards=args.shards,
        durability=durability,
        device_budget_mb=args.device_budget_mb,
        tier_chunk_slots=args.tier_chunk_slots)
    index = open_index(config)
    recovered = index.size
    if recovered:
        print(f"recovered {recovered} docs from snapshot + WAL tail")
    todo = [d for d in range(args.docs)
            if args.wal is None or d not in index]
    for lo in range(0, len(todo), 2048):
        chunk = todo[lo:lo + 2048]
        index.insert_many(chunk, idx[chunk], val[chunk])
    n_shards = args.shards if args.shards > 1 else 1
    print(f"indexed {index.size} docs over {n_shards} shard(s)")
    if args.wal and args.snapshot_dir:
        index.snapshot()
        print(f"snapshot written to {args.snapshot_dir}")

    server = QueryServer(index, k=args.k, kprime=args.kprime,
                         budget=args.budget,
                         score_backend=args.score_backend,
                         trace_every=args.trace_every)
    ready.mark("engine", True)      # built/recovered: ready to serve
    if slo_monitor is not None:
        slo_monitor.start()
    profiling = False
    if args.profile_dir:
        import jax
        try:
            jax.profiler.start_trace(args.profile_dir)
            profiling = True
        except Exception as e:                          # noqa: BLE001
            print(f"profiler unavailable ({e!r}); continuing without")
    recalls = []
    for lo in range(0, args.queries, args.query_batch):
        hi = min(lo + args.query_batch, args.queries)
        ids, _ = server.query_many(qi[lo:hi], qv[lo:hi])
        for b in range(lo, hi):
            ids0, _ = brute_force_topk(idx, val, qi[b], qv[b], ds.n, args.k)
            recalls.append(
                len(set(ids[b - lo].tolist()) & set(ids0.tolist())) / args.k)
    if profiling:
        import jax
        jax.profiler.stop_trace()
        print(f"profiler trace written to {args.profile_dir}")
    lat = server.latency_percentiles()
    print(f"recall@{args.k}={np.mean(recalls):.3f}  "
          f"p50={lat['p50']:.1f}ms p90={lat['p90']:.1f}ms "
          f"p99={lat['p99']:.1f}ms", flush=True)
    frontend = front_door = None
    if args.serve_port is not None:
        from repro.fault import DegradeConfig
        from repro.serving.frontend import FrontendServer, ServingFrontend
        degrade_cfg = DegradeConfig(
            enabled=args.degrade,
            enter_burn=args.degrade_enter_burn,
            exit_burn=args.degrade_exit_burn,
            enter_queue_frac=args.degrade_enter_queue_frac,
            exit_queue_frac=args.degrade_exit_queue_frac,
            dwell_ticks=args.degrade_dwell_ticks) if args.degrade else None
        frontend = ServingFrontend(
            server, max_batch=args.max_batch,
            batch_window_ms=args.batch_window_ms,
            queue_depth=args.queue_depth,
            default_deadline_ms=args.deadline_ms,
            slo=slo_monitor, degrade=degrade_cfg,
            watchdog_timeout_s=args.watchdog_timeout_s)
        front_door = FrontendServer(
            frontend, port=args.serve_port, slo=slo_monitor,
            profile_dir=args.profile_dir)
        front_door.ready.add_check("engine",
                                   lambda: ready()[1]["engine"]["ok"])
        front_door.start()
        print(f"front door: POST {front_door.url}/v1/query "
              f"(max_batch={args.max_batch}, "
              f"window={args.batch_window_ms:g}ms, "
              f"queue_depth={args.queue_depth}, "
              f"deadline={args.deadline_ms:g}ms); "
              f"metrics + /debug also on {front_door.url}", flush=True)
    if args.hold_seconds > 0:
        import time
        print(f"holding for {args.hold_seconds:.0f}s "
              f"(front door and metrics stay up); Ctrl-C to exit",
              flush=True)
        try:
            time.sleep(args.hold_seconds)
        except KeyboardInterrupt:
            pass
    if front_door is not None:
        front_door.stop()
    if frontend is not None:
        frontend.close()
    if slo_monitor is not None:
        slo_monitor.stop()
    set_recorder(None)
    log = set_event_log(None)
    if log is not None:
        log.close()
    if metrics_server is not None:
        metrics_server.stop()


if __name__ == "__main__":
    main()
