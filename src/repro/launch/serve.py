"""Serving launcher for the retrieval engine: build (or restore) an index,
then serve batched queries with the anytime budget.

    PYTHONPATH=src python -m repro.launch.serve --docs 10000 --queries 64 \
        [--budget 16] [--kprime 800] [--index-buckets 2048]
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core.engine import EngineSpec, SinnamonIndex
from repro.core.linscan import brute_force_topk
from repro.data import synth
from repro.serving.serve import QueryServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=10_000)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--kprime", type=int, default=800)
    ap.add_argument("--budget", type=int, default=None)
    ap.add_argument("--m", type=int, default=60)
    ap.add_argument("--h", type=int, default=1)
    ap.add_argument("--index-buckets", type=int, default=None)
    ap.add_argument("--dataset", default="splade_like",
                    choices=list(synth.DATASETS))
    args = ap.parse_args()

    ds = synth.DATASETS[args.dataset]
    idx, val = synth.make_corpus(0, ds, args.docs, pad=256)
    qi, qv = synth.make_queries(1, ds, args.queries, pad=96)
    spec = EngineSpec(n=ds.n, m=args.m, h=args.h,
                      capacity=((args.docs + 31) // 32) * 32, max_nnz=256,
                      positive_only=ds.nonneg,
                      index_buckets=args.index_buckets)
    index = SinnamonIndex(spec)
    for lo in range(0, args.docs, 2048):
        hi = min(lo + 2048, args.docs)
        index.insert_many(list(range(lo, hi)), idx[lo:hi], val[lo:hi])
    print(f"indexed {index.size} docs; bytes: {index.memory_bytes()}")

    server = QueryServer(index, k=args.k, kprime=args.kprime,
                         budget=args.budget)
    recalls = []
    for b in range(args.queries):
        ids, _ = server.query(qi[b], qv[b])
        ids0, _ = brute_force_topk(idx, val, qi[b], qv[b], ds.n, args.k)
        recalls.append(len(set(ids.tolist()) & set(ids0.tolist())) / args.k)
    lat = server.latency_percentiles()
    print(f"recall@{args.k}={np.mean(recalls):.3f}  "
          f"p50={lat['p50']:.1f}ms p90={lat['p90']:.1f}ms "
          f"p99={lat['p99']:.1f}ms")


if __name__ == "__main__":
    main()
