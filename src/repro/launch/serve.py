"""Serving launcher for the retrieval engine: build (or recover) an index,
then serve batched queries with the anytime budget.

    PYTHONPATH=src python -m repro.launch.serve --docs 10000 --queries 64 \
        [--budget 16] [--kprime 800] [--index-buckets 2048] [--shards 4] \
        [--score-backend pallas|grouped|reference] \
        [--wal runs/wal --snapshot-dir runs/snap --snapshot-every 5000 \
         --compact-threshold 0.5]

``--shards N`` (N > 1) serves through the mesh-sharded streaming index on a
host-local mesh (N forced host devices, corpus sharded over 'model'), using
the batched `query_many` path; the default is the single-device index.

``--wal DIR`` makes the index durable: every insert/delete is logged to the
write-ahead log before it is applied, and on startup the launcher *recovers*
(latest snapshot from ``--snapshot-dir`` + WAL tail replay) instead of
re-indexing — so a second run with the same dirs skips the build entirely.
``--snapshot-every N`` snapshots after every N logged ops;
``--compact-threshold X`` rebuilds recycled sketch columns whenever the max
per-slot overestimate exceeds X (see repro.persist).
"""

from __future__ import annotations

import argparse
import os


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=10_000)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--kprime", type=int, default=800)
    ap.add_argument("--budget", type=int, default=None)
    ap.add_argument("--m", type=int, default=60)
    ap.add_argument("--h", type=int, default=1)
    ap.add_argument("--index-buckets", type=int, default=None)
    ap.add_argument("--score-backend", default=None,
                    choices=["reference", "grouped", "pallas"],
                    help="scoring backend for the query hot path "
                         "(default: REPRO_SCORE_BACKEND env or 'pallas', "
                         "the fused tiled-top-k kernel)")
    ap.add_argument("--shards", type=int, default=1,
                    help=">1: sharded streaming index on a host-local mesh")
    ap.add_argument("--query-batch", type=int, default=16)
    ap.add_argument("--dataset", default="splade_like")
    ap.add_argument("--wal", default=None, metavar="DIR",
                    help="write-ahead-log dir; enables the durable index")
    ap.add_argument("--snapshot-dir", default=None, metavar="DIR",
                    help="snapshot dir (recovery base + periodic snapshots)")
    ap.add_argument("--snapshot-every", type=int, default=None, metavar="N",
                    help="snapshot after every N logged ops")
    ap.add_argument("--compact-threshold", type=float, default=None,
                    metavar="X", help="compact when max sketch drift > X")
    args = ap.parse_args(argv)
    if args.wal is None and (args.snapshot_dir is not None
                             or args.snapshot_every is not None
                             or args.compact_threshold is not None):
        ap.error("--snapshot-dir/--snapshot-every/--compact-threshold "
                 "require --wal (durability is WAL-based)")
    if args.snapshot_every is not None and args.snapshot_dir is None:
        ap.error("--snapshot-every requires --snapshot-dir "
                 "(periodic snapshots need somewhere to go)")
    return args


def _check_launch_params(args) -> None:
    """Pin the corpus/spec flags of a durable run to its WAL directory."""
    import json
    import sys

    params = {"dataset": args.dataset, "docs": args.docs, "m": args.m,
              "h": args.h, "index_buckets": args.index_buckets,
              "shards": args.shards}
    os.makedirs(args.wal, exist_ok=True)
    pfile = os.path.join(args.wal, "launch_params.json")
    if os.path.exists(pfile):
        with open(pfile) as f:
            prev = json.load(f)
        changed = {k: (prev.get(k), v) for k, v in params.items()
                   if prev.get(k) != v and k != "shards"}
        if changed:
            sys.exit(f"refusing to recover from {args.wal}: "
                     f"{', '.join(f'--{k} was {a!r}, now {b!r}' for k, (a, b) in changed.items())} "
                     f"— the synthetic corpus/spec would no longer match the "
                     f"indexed vectors; rerun with the original flags or "
                     f"fresh --wal/--snapshot-dir directories")
        if prev != params:       # only the (elastic) shard count changed
            with open(pfile, "w") as f:
                json.dump(params, f)
    else:
        with open(pfile, "w") as f:
            json.dump(params, f)


def main():
    args = parse_args()
    if args.shards > 1:
        # Must happen before jax initialises its backends; append so any
        # user-provided XLA_FLAGS survive.
        flag = f"--xla_force_host_platform_device_count={args.shards}"
        prev = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in prev:
            os.environ["XLA_FLAGS"] = f"{prev} {flag}".strip()

    import numpy as np

    from repro.core.engine import EngineSpec, SinnamonIndex
    from repro.core.linscan import brute_force_topk
    from repro.data import synth
    from repro.distributed import mesh as meshlib
    from repro.serving.serve import QueryServer
    from repro.serving.sharded import ShardedSinnamonIndex

    ds = synth.DATASETS[args.dataset]
    idx, val = synth.make_corpus(0, ds, args.docs, pad=256)
    qi, qv = synth.make_queries(1, ds, args.queries, pad=96)
    cap = ((args.docs + 31) // 32) * 32
    durable = dict(wal_dir=args.wal, snapshot_dir=args.snapshot_dir,
                   snapshot_every=args.snapshot_every,
                   compact_threshold=args.compact_threshold)
    if args.wal:
        # Recovery serves the PREVIOUS run's vectors, while the corpus and
        # the recall ground truth are regenerated from the flags — and
        # synth.make_corpus is not prefix-stable across --docs.  Refuse to
        # mix durable state with a differently-drawn corpus (or a spec the
        # snapshot would silently override).
        _check_launch_params(args)
    if args.shards > 1:
        cap_local = ((cap // args.shards + 31) // 32) * 32
        spec = EngineSpec(n=ds.n, m=args.m, h=args.h, capacity=cap_local,
                          max_nnz=256, positive_only=ds.nonneg,
                          index_buckets=args.index_buckets)
        mesh = meshlib.make_mesh((1, args.shards), ("data", "model"))
        if args.wal:
            from repro.persist import DurableShardedSinnamonIndex
            index = DurableShardedSinnamonIndex.open(spec, mesh, **durable)
        else:
            index = ShardedSinnamonIndex(spec, mesh)
    else:
        spec = EngineSpec(n=ds.n, m=args.m, h=args.h, capacity=cap,
                          max_nnz=256, positive_only=ds.nonneg,
                          index_buckets=args.index_buckets)
        if args.wal:
            from repro.persist import DurableSinnamonIndex
            index = DurableSinnamonIndex.open(spec, **durable)
        else:
            index = SinnamonIndex(spec)
    recovered = index.size
    if recovered:
        print(f"recovered {recovered} docs from snapshot + WAL tail")
    todo = [d for d in range(args.docs)
            if args.wal is None or d not in index]
    for lo in range(0, len(todo), 2048):
        chunk = todo[lo:lo + 2048]
        index.insert_many(chunk, idx[chunk], val[chunk])
    n_shards = args.shards if args.shards > 1 else 1
    print(f"indexed {index.size} docs over {n_shards} shard(s)")
    if args.wal and args.snapshot_dir:
        index.snapshot()
        print(f"snapshot written to {args.snapshot_dir}")

    server = QueryServer(index, k=args.k, kprime=args.kprime,
                         budget=args.budget,
                         score_backend=args.score_backend)
    recalls = []
    for lo in range(0, args.queries, args.query_batch):
        hi = min(lo + args.query_batch, args.queries)
        ids, _ = server.query_many(qi[lo:hi], qv[lo:hi])
        for b in range(lo, hi):
            ids0, _ = brute_force_topk(idx, val, qi[b], qv[b], ds.n, args.k)
            recalls.append(
                len(set(ids[b - lo].tolist()) & set(ids0.tolist())) / args.k)
    lat = server.latency_percentiles()
    print(f"recall@{args.k}={np.mean(recalls):.3f}  "
          f"p50={lat['p50']:.1f}ms p90={lat['p90']:.1f}ms "
          f"p99={lat['p99']:.1f}ms")


if __name__ == "__main__":
    main()
