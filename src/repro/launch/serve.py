"""Serving launcher for the retrieval engine: build (or restore) an index,
then serve batched queries with the anytime budget.

    PYTHONPATH=src python -m repro.launch.serve --docs 10000 --queries 64 \
        [--budget 16] [--kprime 800] [--index-buckets 2048] [--shards 4]

``--shards N`` (N > 1) serves through the mesh-sharded streaming index on a
host-local mesh (N forced host devices, corpus sharded over 'model'), using
the batched `query_many` path; the default is the single-device index.
"""

from __future__ import annotations

import argparse
import os


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=10_000)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--kprime", type=int, default=800)
    ap.add_argument("--budget", type=int, default=None)
    ap.add_argument("--m", type=int, default=60)
    ap.add_argument("--h", type=int, default=1)
    ap.add_argument("--index-buckets", type=int, default=None)
    ap.add_argument("--shards", type=int, default=1,
                    help=">1: sharded streaming index on a host-local mesh")
    ap.add_argument("--query-batch", type=int, default=16)
    ap.add_argument("--dataset", default="splade_like")
    return ap.parse_args(argv)


def main():
    args = parse_args()
    if args.shards > 1:
        # Must happen before jax initialises its backends; append so any
        # user-provided XLA_FLAGS survive.
        flag = f"--xla_force_host_platform_device_count={args.shards}"
        prev = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in prev:
            os.environ["XLA_FLAGS"] = f"{prev} {flag}".strip()

    import numpy as np

    from repro.core.engine import EngineSpec, SinnamonIndex
    from repro.core.linscan import brute_force_topk
    from repro.data import synth
    from repro.distributed import mesh as meshlib
    from repro.serving.serve import QueryServer
    from repro.serving.sharded import ShardedSinnamonIndex

    ds = synth.DATASETS[args.dataset]
    idx, val = synth.make_corpus(0, ds, args.docs, pad=256)
    qi, qv = synth.make_queries(1, ds, args.queries, pad=96)
    cap = ((args.docs + 31) // 32) * 32
    if args.shards > 1:
        cap_local = ((cap // args.shards + 31) // 32) * 32
        spec = EngineSpec(n=ds.n, m=args.m, h=args.h, capacity=cap_local,
                          max_nnz=256, positive_only=ds.nonneg,
                          index_buckets=args.index_buckets)
        mesh = meshlib.make_mesh((1, args.shards), ("data", "model"))
        index = ShardedSinnamonIndex(spec, mesh)
    else:
        spec = EngineSpec(n=ds.n, m=args.m, h=args.h, capacity=cap,
                          max_nnz=256, positive_only=ds.nonneg,
                          index_buckets=args.index_buckets)
        index = SinnamonIndex(spec)
    for lo in range(0, args.docs, 2048):
        hi = min(lo + 2048, args.docs)
        index.insert_many(list(range(lo, hi)), idx[lo:hi], val[lo:hi])
    n_shards = args.shards if args.shards > 1 else 1
    print(f"indexed {index.size} docs over {n_shards} shard(s)")

    server = QueryServer(index, k=args.k, kprime=args.kprime,
                         budget=args.budget)
    recalls = []
    for lo in range(0, args.queries, args.query_batch):
        hi = min(lo + args.query_batch, args.queries)
        ids, _ = server.query_many(qi[lo:hi], qv[lo:hi])
        for b in range(lo, hi):
            ids0, _ = brute_force_topk(idx, val, qi[b], qv[b], ds.n, args.k)
            recalls.append(
                len(set(ids[b - lo].tolist()) & set(ids0.tolist())) / args.k)
    lat = server.latency_percentiles()
    print(f"recall@{args.k}={np.mean(recalls):.3f}  "
          f"p50={lat['p50']:.1f}ms p90={lat['p90']:.1f}ms "
          f"p99={lat['p99']:.1f}ms")


if __name__ == "__main__":
    main()
