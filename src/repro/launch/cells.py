"""Cell builders for the multi-pod dry-run: for every (architecture × input
shape) this produces the step function to lower, ShapeDtypeStruct stand-ins
for all inputs (no allocation), and logical-axis-derived in_shardings.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.core import engine as eng
from repro.distributed import mesh as meshlib
from repro.distributed import rules as R
from repro.distributed.rules import L
from repro.models import gnn, recsys, transformer as tr
from repro.optim import adamw
from repro.serving import sharded
from repro.storage import vecstore
from repro.train import loop


class CellBundle(NamedTuple):
    fn: Any                 # callable to jit
    args: Tuple             # abstract (ShapeDtypeStruct) inputs
    in_shardings: Tuple
    donate_argnums: Tuple[int, ...]
    meta: dict              # MODEL_FLOPS etc. for the roofline report


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _opt_abstract(params_abs):
    return jax.eval_shape(adamw.init, params_abs)


def _opt_axes(params_axes):
    return adamw.OptState(m=params_axes, v=params_axes, step=L())


OPT_CFG = adamw.AdamWConfig()


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_flops(cfg, shape) -> dict:
    tokens = shape["batch"] * (shape["seq"] if shape["kind"] != "lm_decode"
                               else 1)
    n_active = cfg.active_param_count()
    mult = 6 if shape["kind"] == "lm_train" else 2
    return {"model_flops": mult * n_active * tokens,
            "params": cfg.param_count(), "active_params": n_active,
            "tokens": tokens}


def build_lm(mod, shape, mesh, rules=None) -> CellBundle:
    cfg = mod.full_config()
    kind = shape["kind"]
    B, S = shape["batch"], shape["seq"]
    meta = _lm_flops(cfg, shape)
    meta["arch_kind"] = kind

    if kind == "lm_train":
        params_abs = tr.abstract_params(cfg)            # fp32 master weights
        ax = tr.logical_axes(cfg)
        state_abs = loop.TrainState(params_abs, _opt_abstract(params_abs), None)
        state_ax = loop.TrainState(ax, _opt_axes(ax), None)
        state_sh = R.tree_sharding(mesh, state_abs, state_ax, rules)
        bsh = R.sharding_for(mesh, (B, S), ("batch", "seq"), rules)
        batch_abs = (_sds((B, S), jnp.int32), _sds((B, S), jnp.int32))

        def loss_fn(params, batch):
            loss, metrics = tr.lm_loss(params, batch[0], batch[1], cfg, mesh,
                                       rules)
            return loss, metrics

        step = loop.make_train_step(loss_fn, OPT_CFG)
        return CellBundle(step, (state_abs, batch_abs),
                          (state_sh, (bsh, bsh)), (0,), meta)

    params_abs = tr.abstract_params(cfg, dtype=jnp.bfloat16)   # serving
    ax = tr.logical_axes(cfg)
    psh = R.tree_sharding(mesh, params_abs, ax, rules)

    if kind == "lm_prefill":
        tokens = _sds((B, S), jnp.int32)
        tsh = R.sharding_for(mesh, (B, S), ("batch", "seq"), rules)
        fn = lambda p, t: tr.prefill(p, t, cfg, mesh, rules)
        return CellBundle(fn, (params_abs, tokens), (psh, tsh), (), meta)

    # decode: one new token against a KV cache of S entries
    cache_abs = tr.abstract_cache(cfg, B, S)
    cache_sh = R.tree_sharding(mesh, cache_abs, tr.cache_logical_axes(), rules)
    tokens = _sds((B, 1), jnp.int32)
    tsh = R.sharding_for(mesh, (B, 1), ("batch", None), rules)
    pos = _sds((), jnp.int32)
    pos_sh = NamedSharding(mesh, P())
    fn = lambda p, c, t, pos: tr.decode_step(p, c, t, pos, cfg, mesh, rules)
    return CellBundle(fn, (params_abs, cache_abs, tokens, pos),
                      (psh, cache_sh, tsh, pos_sh), (1,), meta)


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def build_gnn(mod, shape, mesh, rules=None) -> CellBundle:
    cfg = mod.full_config(shape)
    pn, pe = shape["pad_nodes"], shape["pad_edges"]
    n_graphs = shape.get("batch_graphs", 1)
    labels = (_sds((pn,), jnp.int32) if shape["task"] == "node_class"
              else _sds((n_graphs,), jnp.float32))
    g_abs = gnn.GraphBatch(
        node_feat=_sds((pn, shape["d_feat"]), jnp.float32),
        edge_src=_sds((pe,), jnp.int32), edge_dst=_sds((pe,), jnp.int32),
        edge_vec=_sds((pe, 3), jnp.float32),
        labels=labels, forces=_sds((pn, 3), jnp.float32),
        graph_id=_sds((pn,), jnp.int32), n_graphs=None)
    gax = gnn.graph_logical_axes()._replace(
        labels=L("nodes") if shape["task"] == "node_class" else L(None))
    # n_graphs is static (None in the traced pytree; re-attached in loss_fn).
    g_sh = R.tree_sharding(mesh, g_abs, gax, rules)

    from repro.models import gnn_sharded
    params_abs = gnn.abstract_params(cfg)
    psh = gnn_sharded.param_shardings(cfg, mesh)
    state_abs = loop.TrainState(params_abs, _opt_abstract(params_abs), None)
    state_sh = loop.TrainState(psh, adamw.OptState(
        m=psh, v=psh, step=NamedSharding(mesh, P())), None)
    # edges over the data axes, node tensors replicated (DESIGN.md §4 GNN)
    edge_spec = P(tuple(a for a in mesh.axis_names if a in ("pod", "data")))
    g_sh = gnn.GraphBatch(
        node_feat=NamedSharding(mesh, P()),
        edge_src=NamedSharding(mesh, edge_spec),
        edge_dst=NamedSharding(mesh, edge_spec),
        edge_vec=NamedSharding(mesh, P(edge_spec[0], None)),
        labels=NamedSharding(mesh, P()), forces=NamedSharding(mesh, P()),
        graph_id=NamedSharding(mesh, P()), n_graphs=None)

    def loss_fn(params, batch):
        batch = batch._replace(n_graphs=n_graphs)
        if mesh.size > 1:
            return gnn_sharded.loss_fn_sharded(params, batch, cfg, mesh)
        return gnn.loss_fn(params, batch, cfg, mesh, rules)

    step = loop.make_train_step(loss_fn, OPT_CFG)
    # eSCN per-edge cost: rotate (2×Σ(2l+1)²·C) + SO(2) conv matmuls
    lm = cfg.l_max
    rot = 2 * sum((2 * l + 1) ** 2 for l in range(lm + 1)) * cfg.c
    conv = ((lm + 1) * cfg.c) ** 2 + 2 * sum(
        ((lm + 1 - m) * cfg.c) ** 2 * 2 for m in range(1, cfg.m_max + 1))
    meta = {"arch_kind": "gnn_train",
            "model_flops": 6 * shape["n_edges"] * (rot + conv) * cfg.n_layers,
            "params": None, "tokens": shape["n_edges"]}
    return CellBundle(step, (state_abs, g_abs), (state_sh, g_sh), (0,), meta)


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------

def _recsys_batch_abs(cfg, B):
    return recsys.RecsysBatch(
        dense=_sds((B, cfg.n_dense), jnp.float32),
        sparse=_sds((B, cfg.n_sparse, cfg.multi_hot), jnp.int32),
        hist=_sds((B, cfg.seq_len), jnp.int32),
        target=_sds((B,), jnp.int32),
        labels=_sds((B,), jnp.float32))


def _recsys_flops(cfg, B) -> int:
    D = cfg.embed_dim
    if cfg.model == "dlrm":
        dims_b = (cfg.n_dense,) + cfg.bot_mlp
        dims_t = (cfg.bot_mlp[-1] + (cfg.n_sparse + 1) * cfg.n_sparse // 2,
                  ) + cfg.top_mlp
        mlp = sum(a * b for a, b in zip(dims_b[:-1], dims_b[1:])) + \
            sum(a * b for a, b in zip(dims_t[:-1], dims_t[1:]))
        inter = (cfg.n_sparse + 1) ** 2 * D
        return 2 * B * (mlp + inter)
    if cfg.model == "din":
        att = cfg.seq_len * (4 * D * cfg.attn_mlp[0]
                             + cfg.attn_mlp[0] * cfg.attn_mlp[1])
        m = 2 * D * cfg.mlp[0] + cfg.mlp[0] * cfg.mlp[1]
        return 2 * B * (att + m)
    if cfg.model == "sasrec":
        S = cfg.seq_len
        return 2 * B * cfg.n_blocks * (4 * S * D * D + 2 * S * S * D)
    S = cfg.seq_len
    return 2 * B * cfg.capsule_iters * (2 * S * cfg.n_interests * D + D * D)


def build_recsys(mod, shape, mesh, rules=None) -> CellBundle:
    cfg = mod.full_config()
    kind = shape["kind"]
    B = shape["batch"]
    batch_abs = _recsys_batch_abs(cfg, B)
    b_sh = R.tree_sharding(mesh, batch_abs, recsys.batch_logical_axes(), rules)
    meta = {"arch_kind": kind, "model_flops": _recsys_flops(cfg, B),
            "tokens": B}

    if kind == "recsys_train":
        params_abs = recsys.abstract_params(cfg)
        ax = recsys.logical_axes(cfg)
        state_abs = loop.TrainState(params_abs, _opt_abstract(params_abs),
                                    None)
        state_ax = loop.TrainState(ax, _opt_axes(ax), None)
        state_sh = R.tree_sharding(mesh, state_abs, state_ax, rules)
        meta["model_flops"] *= 3

        def loss_fn(params, batch):
            return recsys.loss(params, batch, cfg, mesh, rules), {}

        step = loop.make_train_step(loss_fn, OPT_CFG)
        return CellBundle(step, (state_abs, batch_abs), (state_sh, b_sh),
                          (0,), meta)

    params_abs = recsys.abstract_params(cfg)
    psh = R.tree_sharding(mesh, params_abs, recsys.logical_axes(cfg), rules)
    if kind == "recsys_serve":
        fn = lambda p, b: recsys.score(p, b, cfg, mesh, rules)
        return CellBundle(fn, (params_abs, batch_abs), (psh, b_sh), (), meta)

    # retrieval_cand: batched-dot MIPS against the full candidate set
    k = shape["k"]
    meta["model_flops"] = 2 * B * shape["n_candidates"] * cfg.embed_dim

    def fn(p, b):
        s = recsys.retrieval_scores(p, b, cfg, mesh, rules)
        return jax.lax.top_k(s, k)

    return CellBundle(fn, (params_abs, batch_abs), (psh, b_sh), (), meta)


# ---------------------------------------------------------------------------
# Retrieval-engine cells (the paper's own workload)
# ---------------------------------------------------------------------------

def build_retrieval(mod, shape, mesh, rules=None) -> CellBundle:
    corpus_ax = meshlib.corpus_axes(mesh)
    n_shards = meshlib.n_shards(mesh, corpus_ax)
    spec = mod.full_config(shape, n_shards)
    C_total = spec.capacity * n_shards
    W = C_total // 32
    state_abs = eng.SinnamonState(
        mappings=_sds((spec.h, spec.n), jnp.int32),
        u=_sds((spec.m, C_total), jnp.bfloat16),
        l=_sds((spec.m, C_total), jnp.bfloat16),
        bits=_sds((spec.index_buckets or spec.n, W), jnp.uint32),
        store=vecstore.VecStore(
            indices=_sds((C_total, spec.max_nnz), jnp.int32),
            values=_sds((C_total, spec.max_nnz), jnp.bfloat16)),
        active=_sds((C_total,), jnp.bool_),
        ids=_sds((C_total, 2), jnp.uint32),
        dirty=_sds((C_total,), jnp.bool_))
    state_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                            sharded.state_pspecs(mesh, False),
                            is_leaf=lambda x: isinstance(x, P))
    B, Lq = shape["batch"], shape["psi_q"]
    q_abs = (_sds((B, Lq), jnp.int32), _sds((B, Lq), jnp.float32))
    qsh = NamedSharding(mesh, P("data"))
    step = sharded.make_search_step(
        mesh, spec, k=shape["k"], kprime_local=shape["kprime_local"])
    # scoring reads ψ_q rows of U and the bitmask per query coordinate
    flops = B * Lq * (spec.h * 2 + 2) * C_total
    meta = {"arch_kind": "retrieval_serve", "model_flops": flops,
            "tokens": B}
    return CellBundle(step, (state_abs,) + (q_abs[0], q_abs[1]),
                      (state_sh, qsh, qsh), (), meta)


# ---------------------------------------------------------------------------

def build(arch: str, shape_name: str, mesh, rules=None) -> CellBundle:
    mod = registry.get(arch)
    shape = mod.SHAPES[shape_name]
    fam = mod.FAMILY
    if fam == "lm":
        return build_lm(mod, shape, mesh, rules)
    if fam == "gnn":
        return build_gnn(mod, shape, mesh, rules)
    if fam == "recsys":
        return build_recsys(mod, shape, mesh, rules)
    if fam == "retrieval":
        return build_retrieval(mod, shape, mesh, rules)
    raise ValueError(fam)
