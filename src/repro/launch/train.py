"""Training launcher with checkpoint auto-resume (fault tolerance).

Runs REDUCED (smoke) configs end-to-end on whatever devices exist — the FULL
configs are exercised structurally via dryrun.py.  On a real cluster the same
driver runs under `jax.distributed.initialize()` with the production mesh.

    PYTHONPATH=src python -m repro.launch.train --arch moonshot-v1-16b-a3b \
        --steps 50 [--ckpt-dir /tmp/ck] [--resume] [--microbatches 2]
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs import registry
from repro.data import graph as graphdata
from repro.data import loaders
from repro.models import gnn, recsys, transformer as tr
from repro.optim import adamw
from repro.train import loop


def build(arch: str, microbatches: int):
    mod = registry.get(arch)
    cfg = mod.smoke_config()
    if mod.FAMILY == "lm":
        params = tr.init_params(jax.random.PRNGKey(0), cfg)

        def loss_fn(p, b):
            return tr.lm_loss(p, b[0], b[1], cfg)

        def batch_at(step):
            t, l = loaders.lm_batch(0, step, 4 * microbatches, 64, cfg.vocab)
            return (jnp.asarray(t), jnp.asarray(l))
    elif mod.FAMILY == "recsys":
        params = recsys.init_params(jax.random.PRNGKey(0), cfg)

        def loss_fn(p, b):
            return recsys.loss(p, b, cfg), {}

        def batch_at(step):
            return jax.tree.map(jnp.asarray,
                                loaders.recsys_batch(0, step,
                                                     8 * microbatches, cfg))
    elif mod.FAMILY == "gnn":
        params = gnn.init_params(jax.random.PRNGKey(0), cfg)
        g = graphdata.random_geometric_graph(0, 64, 256, cfg.f_in, cfg.n_out)
        g = jax.tree.map(lambda x: jnp.asarray(x)
                         if not isinstance(x, int) else x, g)

        def loss_fn(p, b):
            return gnn.loss_fn(p, b, cfg)

        def batch_at(step):
            return g
        microbatches = 1
    else:
        raise ValueError(f"{arch}: use repro.launch.serve for retrieval")
    return params, loss_fn, batch_at, microbatches


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    params, loss_fn, batch_at, mb = build(args.arch, args.microbatches)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=10,
                                decay_steps=args.steps)
    step_fn = jax.jit(loop.make_train_step(loss_fn, opt_cfg,
                                           microbatches=mb))
    state = loop.init_state(params)
    start = 0
    if args.resume and args.ckpt_dir and ckpt.latest_step(args.ckpt_dir):
        state, start, _ = ckpt.restore(args.ckpt_dir, state)
        print(f"resumed from step {start}")

    for step in range(start, args.steps):
        state, metrics = step_fn(state, batch_at(step))
        if (step + 1) % 10 == 0 or step == start:
            print(f"[{args.arch}] step {step+1:4d} "
                  f"loss={float(metrics['loss']):.4f} "
                  f"|g|={float(metrics['grad_norm']):.3f}")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, step + 1, state)


if __name__ == "__main__":
    main()
