"""Fault injection and resilience primitives.

* :mod:`repro.fault.failpoints` — deterministic seeded failpoints
  threaded through the real WAL / snapshot / compaction / serving
  error paths (armed via API or ``REPRO_FAILPOINTS``).
* :mod:`repro.fault.retry` — retry with exponential backoff + jitter
  under a deadline budget, and a closed/open/half-open circuit breaker.
* :mod:`repro.fault.degrade` — the serving degradation ladder
  (rerank-shrink → sketch-only → tenant shedding) with hysteresis.

See docs/robustness.md for the failpoint catalog and semantics.
"""

from repro.fault.degrade import DegradationController, DegradeConfig
from repro.fault.failpoints import (
    FailpointRegistry,
    InjectedError,
    InjectedFault,
    get_failpoints,
    injected,
    set_failpoints,
)
from repro.fault.retry import (
    CircuitBreaker,
    CircuitOpen,
    RetryPolicy,
    call_with_retry,
    fsync_transient,
    transient_oserror,
)

__all__ = [
    "CircuitBreaker",
    "CircuitOpen",
    "DegradationController",
    "DegradeConfig",
    "FailpointRegistry",
    "InjectedError",
    "InjectedFault",
    "RetryPolicy",
    "call_with_retry",
    "fsync_transient",
    "get_failpoints",
    "injected",
    "set_failpoints",
    "transient_oserror",
]
