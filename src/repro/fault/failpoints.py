"""Deterministic, seeded failpoint registry.

A *failpoint* is a named injection site compiled into a real error path:
the WAL append/fsync, the snapshot writer, the compactor's optimistic
swap, the VecStore restore read, the serving device dispatch.  Arming a
site makes the production code fail (or stall) exactly where a real
disk/device would, through exactly the handling the real fault would
take — no monkeypatching, no test-only forks of the logic.

Sites ship disabled and cost one dict lookup per pass-through (measured
by ``benchmarks/obs_overhead.py``'s ≤5% gate, which runs with failpoints
compiled in).  Arm them via the API::

    from repro.fault import failpoints
    with failpoints.injected("wal.fsync=error:0.02", seed=7):
        ...

or via the environment (read once, at first use)::

    REPRO_FAILPOINTS="wal.fsync=error:0.02,device.dispatch=stall:250ms"
    REPRO_FAILPOINT_SEED=7

Spec grammar (comma-separated ``site=mode[:arg][:prob]``):

* ``error[:prob]`` / ``eio[:prob]`` — raise :class:`InjectedError`
  (an ``OSError`` with ``errno=EIO``) with probability ``prob``
  (default 1.0);
* ``enospc[:prob]`` — same with ``errno=ENOSPC`` (disk full: callers
  must NOT retry this one);
* ``torn[:frac][:prob]`` — the site writes only ``frac`` (default 0.5)
  of its bytes, then raises ``InjectedError(EIO)`` — a torn write;
* ``stall:<ms>ms[:prob]`` — sleep ``ms`` milliseconds, then continue
  (a slow/stuck device or disk).

Every fire increments ``repro_fault_injected_total{site,mode}`` and the
per-site hit counter (``hits()``), so a chaos schedule can assert its
faults actually landed.  Probability rolls come from one seeded
``random.Random`` — the same seed replays the same fault schedule.

The failpoint catalog (which sites exist and what they model) lives in
docs/robustness.md.
"""

from __future__ import annotations

import errno
import os
import re
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass
from random import Random
from typing import Dict, Optional

from repro.obs import metrics as obs_metrics

__all__ = [
    "Action",
    "FailpointRegistry",
    "InjectedError",
    "InjectedFault",
    "fire",
    "get_failpoints",
    "injected",
    "set_failpoints",
]

MODES = ("error", "eio", "enospc", "torn", "stall")

_STALL_RE = re.compile(r"^(\d+(?:\.\d+)?)ms$")


class InjectedFault(Exception):
    """Marker base so tests/chaos can tell injected faults from real ones."""


class InjectedError(InjectedFault, OSError):
    """An injected ``OSError`` — callers' real ``except OSError`` paths
    (WAL unwind, snapshot abort, compactor error counting) handle it
    exactly as they would the disk fault it models."""


@dataclass(frozen=True)
class Action:
    """What an armed site decided for this pass (returned by :func:`fire`
    for modes the site must interpret itself, e.g. ``torn``)."""

    site: str
    mode: str
    arg: float      # torn: fraction of bytes written; stall: milliseconds


@dataclass
class _Armed:
    mode: str
    arg: float
    prob: float
    count: Optional[int]     # remaining fires; None = unlimited


def _parse_one(site: str, rest: str) -> _Armed:
    parts = rest.split(":")
    mode = parts[0]
    if mode not in MODES:
        raise ValueError(f"failpoint {site!r}: unknown mode {mode!r} "
                         f"(expected one of {'/'.join(MODES)})")
    arg, prob = 0.0, 1.0
    tail = parts[1:]
    if mode == "stall":
        if not tail:
            raise ValueError(f"failpoint {site!r}: stall needs a duration, "
                             f"e.g. stall:250ms")
        m = _STALL_RE.match(tail[0])
        if not m:
            raise ValueError(f"failpoint {site!r}: bad stall duration "
                             f"{tail[0]!r} (expected e.g. 250ms)")
        arg, tail = float(m.group(1)), tail[1:]
    elif mode == "torn":
        arg = 0.5
        if tail and tail[0]:
            arg, tail = float(tail[0]), tail[1:]
            if not (0.0 <= arg < 1.0):
                raise ValueError(f"failpoint {site!r}: torn fraction must "
                                 f"be in [0, 1), got {arg}")
    if tail:
        prob = float(tail[0])
        if not (0.0 < prob <= 1.0):
            raise ValueError(f"failpoint {site!r}: probability must be in "
                             f"(0, 1], got {prob}")
        tail = tail[1:]
    if tail:
        raise ValueError(f"failpoint {site!r}: trailing spec parts {tail}")
    return _Armed(mode=mode, arg=arg, prob=prob, count=None)


class FailpointRegistry:
    """Armed failpoints + the seeded dice that decide each pass.

    Thread-safe: the WAL writer, the dispatcher and the compactor all
    pass through the same registry.  ``sleep`` is injectable so tests
    can fake stalls without wall-clock cost.
    """

    def __init__(self, seed: Optional[int] = None, registry=None,
                 sleep=time.sleep):
        self._sites: Dict[str, _Armed] = {}
        self._hits: Dict[str, int] = {}
        self._rng = Random(seed)
        self._lock = threading.Lock()
        self._registry = registry
        self._sleep = sleep

    # -- arming --------------------------------------------------------------
    def configure(self, spec: str) -> "FailpointRegistry":
        """Arm sites from the env-style spec string (see module docs)."""
        for part in (spec or "").split(","):
            part = part.strip()
            if not part:
                continue
            if "=" not in part:
                raise ValueError(f"bad failpoint spec {part!r} "
                                 f"(expected site=mode[:arg][:prob])")
            site, rest = part.split("=", 1)
            with self._lock:
                self._sites[site.strip()] = _parse_one(site.strip(), rest)
        return self

    def set(self, site: str, mode: str, *, arg: float = 0.0,
            prob: float = 1.0, count: Optional[int] = None) -> None:
        """Arm one site programmatically.  ``count`` limits how many times
        it fires before auto-disarming (handy for fire-exactly-once)."""
        if mode not in MODES:
            raise ValueError(f"unknown failpoint mode {mode!r}")
        with self._lock:
            self._sites[site] = _Armed(mode=mode, arg=float(arg),
                                       prob=float(prob), count=count)

    def clear(self, site: Optional[str] = None) -> None:
        with self._lock:
            if site is None:
                self._sites.clear()
            else:
                self._sites.pop(site, None)

    @property
    def active(self) -> bool:
        return bool(self._sites)

    def sites(self) -> Dict[str, str]:
        """{site: "mode:arg:prob"} of currently armed sites (for logs)."""
        with self._lock:
            return {s: f"{a.mode}:{a.arg:g}:{a.prob:g}"
                    for s, a in self._sites.items()}

    def hits(self, site: str) -> int:
        """How many times ``site`` actually fired (post-probability)."""
        return self._hits.get(site, 0)

    # -- firing --------------------------------------------------------------
    def check(self, site: str) -> Optional[Action]:
        """Roll the dice for ``site``; count + return the Action if it
        fires.  Does NOT raise or sleep — see :meth:`fire`."""
        if not self._sites:
            return None
        with self._lock:
            armed = self._sites.get(site)
            if armed is None:
                return None
            if armed.prob < 1.0 and self._rng.random() >= armed.prob:
                return None
            if armed.count is not None:
                armed.count -= 1
                if armed.count <= 0:
                    del self._sites[site]
            self._hits[site] = self._hits.get(site, 0) + 1
        reg = self._registry if self._registry is not None \
            else obs_metrics.get_registry()
        reg.counter("repro_fault_injected_total",
                    "Failpoint fires by site and mode.",
                    labels={"site": site, "mode": armed.mode}).inc()
        return Action(site=site, mode=armed.mode, arg=armed.arg)

    def fire(self, site: str) -> Optional[Action]:
        """The call-site entry point: roll, then act.

        * error / eio  -> raises ``InjectedError(EIO)``
        * enospc       -> raises ``InjectedError(ENOSPC)``
        * stall        -> sleeps ``arg`` ms, returns the Action
        * torn         -> returns the Action (the site tears its own write)
        * not armed / dice miss -> returns None
        """
        act = self.check(site)
        if act is None:
            return None
        if act.mode in ("error", "eio"):
            raise InjectedError(errno.EIO, f"injected {act.mode} at {site}")
        if act.mode == "enospc":
            raise InjectedError(errno.ENOSPC, f"injected enospc at {site}")
        if act.mode == "stall":
            self._sleep(act.arg / 1e3)
        return act


# ---------------------------------------------------------------------------
# Process-global registry (env-armed, overridable in tests)
# ---------------------------------------------------------------------------

_GLOBAL: Optional[FailpointRegistry] = None
_GLOBAL_LOCK = threading.Lock()


def get_failpoints() -> FailpointRegistry:
    """The process-global registry, created (and armed from
    ``REPRO_FAILPOINTS`` / ``REPRO_FAILPOINT_SEED``) on first use."""
    global _GLOBAL
    if _GLOBAL is None:
        with _GLOBAL_LOCK:
            if _GLOBAL is None:
                seed = os.environ.get("REPRO_FAILPOINT_SEED")
                reg = FailpointRegistry(
                    seed=int(seed) if seed is not None else None)
                reg.configure(os.environ.get("REPRO_FAILPOINTS", ""))
                _GLOBAL = reg
    return _GLOBAL


def set_failpoints(reg: Optional[FailpointRegistry]
                   ) -> Optional[FailpointRegistry]:
    """Swap the process-global registry (None = back to env-lazy).
    Returns the previous one."""
    global _GLOBAL
    with _GLOBAL_LOCK:
        prev, _GLOBAL = _GLOBAL, reg
    return prev


def fire(site: str) -> Optional[Action]:
    """Module-level :meth:`FailpointRegistry.fire` against the global
    registry.  The disabled-site fast path is one attribute read and one
    empty-dict check — cheap enough for per-dispatch serving code."""
    reg = _GLOBAL
    if reg is None:
        reg = get_failpoints()
    if not reg._sites:
        return None
    return reg.fire(site)


@contextmanager
def injected(spec: str, seed: int = 0, registry=None):
    """Scoped injection for tests: arm ``spec`` on a fresh seeded registry,
    make it the global one, restore the previous on exit."""
    reg = FailpointRegistry(seed=seed, registry=registry).configure(spec)
    prev = set_failpoints(reg)
    try:
        yield reg
    finally:
        set_failpoints(prev)
