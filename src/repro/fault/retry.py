"""Retry-with-backoff and circuit breaking — the two recovery primitives.

:func:`call_with_retry` retries a callable with exponential backoff and
full jitter, **under a deadline budget**: the total time spent (work +
sleeps) never exceeds ``policy.deadline_s``, and a sleep that would blow
the budget is clamped or skipped.  Retries are observable via
``repro_fault_retries_total{op}``.

:class:`CircuitBreaker` is the classic closed → open → half-open state
machine: after ``failure_threshold`` consecutive failures the circuit
opens and ``allow()`` returns False (callers fast-fail) until
``reset_timeout_s`` elapses; then exactly one probe is let through
(half-open) and its outcome closes or re-opens the circuit.  A probe
whose outcome is never reported (the holder got wedged, or the probed
request was dropped before reaching the dependency) is reclaimed after
``probe_timeout_s`` so a lost probe cannot fast-fail everyone forever.
State is exported as ``repro_fault_breaker_state{name}`` (0=closed,
1=open, 2=half-open) and each trip counts in
``repro_fault_breaker_open_total{name}``.

Adopters in this repo: ``WalWriter`` retries interrupted fsyncs
(:func:`fsync_transient`: EINTR/EAGAIN only — an fsync EIO is fatal,
see the fsyncgate note there); ``BackgroundCompactor`` circuit-breaks
instead of hot-looping on persistent errors; ``ServingFrontend``
fast-fails submits while its dispatch breaker is open and consumes the
half-open probe at *dispatch* time, so an admission-rejected or
queue-expired request can never strand it.  Semantics are documented
in docs/robustness.md.
"""

from __future__ import annotations

import dataclasses
import errno
import threading
import time
from typing import Callable, Optional, Tuple

from repro.obs import metrics as obs_metrics

__all__ = [
    "CircuitBreaker",
    "CircuitOpen",
    "RetryPolicy",
    "call_with_retry",
    "fsync_transient",
    "transient_oserror",
]

#: errnos worth retrying: interruptions and (possibly) transient I/O.
#: ENOSPC is deliberately absent — a full disk does not heal on retry.
_TRANSIENT_ERRNOS = (errno.EINTR, errno.EAGAIN, errno.EIO)

#: errnos safe to retry at a durability barrier: pure interruptions only.
_FSYNC_TRANSIENT_ERRNOS = (errno.EINTR, errno.EAGAIN)


def transient_oserror(exc: BaseException) -> bool:
    """Default ``should_retry`` for filesystem ops: retry EINTR/EAGAIN/EIO,
    never ENOSPC or non-OSErrors."""
    return isinstance(exc, OSError) and exc.errno in _TRANSIENT_ERRNOS


def fsync_transient(exc: BaseException) -> bool:
    """``should_retry`` for fsync call sites: EINTR/EAGAIN only.

    EIO is deliberately NOT retried here (fsyncgate): on Linux a failed
    fsync clears the kernel error state and marks the dirty pages clean,
    so a retried fsync can report success without the bytes ever reaching
    the disk.  A durability barrier that fails with EIO must be treated
    as fatal for the write it was meant to persist.
    """
    return isinstance(exc, OSError) and exc.errno in _FSYNC_TRANSIENT_ERRNOS


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Backoff shape and budget for :func:`call_with_retry`.

    ``attempts`` counts total calls (1 = no retries).  Delay before
    retry ``i`` (1-based) is drawn uniformly from
    ``[base * mult^(i-1) * (1-jitter), base * mult^(i-1)]``, capped at
    ``max_delay_s``, and further clamped so the whole operation stays
    inside ``deadline_s`` (None = no budget).
    """

    attempts: int = 3
    base_delay_s: float = 0.01
    max_delay_s: float = 1.0
    multiplier: float = 2.0
    jitter: float = 0.5
    deadline_s: Optional[float] = None

    def delay(self, attempt: int, rand: Callable[[], float]) -> float:
        """Backoff before retry number ``attempt`` (1-based)."""
        d = min(self.base_delay_s * self.multiplier ** (attempt - 1),
                self.max_delay_s)
        return d * (1.0 - self.jitter * rand())


def call_with_retry(fn: Callable[[], object], *,
                    policy: RetryPolicy = RetryPolicy(),
                    should_retry: Callable[[BaseException], bool] =
                    transient_oserror,
                    op: str = "op",
                    clock: Callable[[], float] = time.monotonic,
                    sleep: Callable[[float], None] = time.sleep,
                    rand: Callable[[], float] = None,
                    registry=None) -> object:
    """Call ``fn`` with retries per ``policy``; return its result.

    Re-raises the last exception when attempts or the deadline budget
    run out, or immediately when ``should_retry`` says the failure is
    not transient.  Each retry (not the first attempt) increments
    ``repro_fault_retries_total{op}``.
    """
    if rand is None:
        import random
        rand = random.random
    start = clock()
    attempt = 0
    while True:
        attempt += 1
        try:
            return fn()
        except BaseException as e:  # noqa: BLE001 - filtered by should_retry
            if attempt >= policy.attempts or not should_retry(e):
                raise
            remaining = (None if policy.deadline_s is None
                         else policy.deadline_s - (clock() - start))
            if remaining is not None and remaining <= 0:
                raise
            d = policy.delay(attempt, rand)
            if remaining is not None:
                d = min(d, remaining)
            reg = registry if registry is not None \
                else obs_metrics.get_registry()
            reg.counter("repro_fault_retries_total",
                        "Retries taken by operation.",
                        labels={"op": op}).inc()
            if d > 0:
                sleep(d)


class CircuitOpen(RuntimeError):
    """Raised (by callers that choose to) when a breaker is open."""

    def __init__(self, name: str, remaining_s: float):
        super().__init__(f"circuit {name!r} open for {remaining_s:.2f}s more")
        self.name = name
        self.remaining_s = remaining_s


_STATE_CODE = {"closed": 0.0, "open": 1.0, "half_open": 2.0}


class CircuitBreaker:
    """Consecutive-failure circuit breaker (closed/open/half-open).

    Thread-safe.  Usage::

        if not breaker.allow():
            fast_fail(breaker.remaining_s())
        try:
            do_work(); breaker.record_success()
        except Exception:
            breaker.record_failure(); raise
    """

    def __init__(self, *, failure_threshold: int = 5,
                 reset_timeout_s: float = 30.0,
                 probe_timeout_s: Optional[float] = None,
                 name: str = "breaker",
                 clock: Callable[[], float] = time.monotonic,
                 registry=None):
        self.failure_threshold = int(failure_threshold)
        self.reset_timeout_s = float(reset_timeout_s)
        # How long a half-open probe may stay unreported before its token
        # is reclaimed (a holder that dies without calling record_* must
        # not wedge the breaker).  Defaults to the reset timeout.
        self.probe_timeout_s = (self.reset_timeout_s if probe_timeout_s
                                is None else float(probe_timeout_s))
        self.name = name
        self._clock = clock
        self._registry = registry
        self._lock = threading.Lock()
        self._failures = 0
        self._state = "closed"
        self._opened_at = 0.0
        self._probing = False
        self._probe_started = 0.0
        self._publish()

    def _reg(self):
        return self._registry if self._registry is not None \
            else obs_metrics.get_registry()

    def _publish(self):
        self._reg().gauge(
            "repro_fault_breaker_state",
            "Breaker state: 0=closed, 1=open, 2=half-open.",
            labels={"name": self.name}).set(_STATE_CODE[self._state])

    @property
    def state(self) -> str:
        with self._lock:
            self._maybe_half_open()
            return self._state

    def _maybe_half_open(self):
        if self._state == "open" and \
                self._clock() - self._opened_at >= self.reset_timeout_s:
            self._state = "half_open"
            self._probing = False
            self._publish()
        # Reclaim a stale probe: if the holder never reported an outcome
        # (wedged, crashed, or the probed request was dropped upstream),
        # the next caller gets a fresh probe instead of everyone
        # fast-failing forever.
        if self._state == "half_open" and self._probing and \
                self._clock() - self._probe_started >= self.probe_timeout_s:
            self._probing = False

    def allow(self) -> bool:
        """True if a call may proceed.  While half-open, exactly one
        caller gets True (the probe); others keep fast-failing."""
        with self._lock:
            self._maybe_half_open()
            if self._state == "closed":
                return True
            if self._state == "half_open" and not self._probing:
                self._probing = True
                self._probe_started = self._clock()
                return True
            return False

    def remaining_s(self) -> float:
        """Seconds until the next probe is allowed (0 when not open)."""
        with self._lock:
            if self._state != "open":
                return 0.0
            return max(0.0,
                       self.reset_timeout_s - (self._clock()
                                               - self._opened_at))

    def record_success(self) -> None:
        with self._lock:
            self._failures = 0
            self._probing = False
            if self._state != "closed":
                self._state = "closed"
                self._publish()

    def record_failure(self) -> None:
        with self._lock:
            self._failures += 1
            self._probing = False
            tripped = (self._state == "half_open"
                       or (self._state == "closed"
                           and self._failures >= self.failure_threshold))
            if tripped:
                self._state = "open"
                self._opened_at = self._clock()
                self._publish()
        if tripped:
            self._reg().counter(
                "repro_fault_breaker_open_total",
                "Times a circuit breaker tripped open.",
                labels={"name": self.name}).inc()

    def snapshot(self) -> Tuple[str, int]:
        """(state, consecutive_failures) — for health surfaces."""
        with self._lock:
            self._maybe_half_open()
            return self._state, self._failures
