"""Degradation ladder controller — brownout instead of blackout.

Sinnamon's accuracy levers are exactly the knobs an overloaded server
wants to turn: shrinking the rerank candidate pool (k') trades recall
for latency, and skipping the exact rerank entirely — answering from
the sketch upper-bounds alone — is the cheapest answer the index can
produce (the §3.3 "lite" regime).  The ladder maps overload pressure
onto those levers:

* **L0** — healthy, full fidelity.
* **L1** — shrink the rerank budget (k'/4): cheaper exact scoring.
* **L2** — sketch-only answers, ``degraded=true`` stamped on results.
* **L3** — additionally shed lowest-priority tenants with 429.

:class:`DegradationController` is a pure, clock-free state machine the
frontend housekeeping thread ticks with two pressure signals: the
``SLOMonitor`` fast-window burn rate and the queue fullness fraction.
Escalation is immediate (one level per tick while either signal is hot);
de-escalation requires ``dwell_ticks`` consecutive calm ticks
(hysteresis) so the ladder doesn't flap around the threshold.

Level is exported as ``repro_frontend_degraded_level`` and every
transition counts in
``repro_frontend_degraded_transitions_total{direction}``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.obs import metrics as obs_metrics

__all__ = ["DegradeConfig", "DegradationController"]


@dataclasses.dataclass(frozen=True)
class DegradeConfig:
    """Thresholds + hysteresis for the ladder.

    Defaults: escalate when the fast window burns error budget at ≥4×
    the sustainable rate or the queue is ≥75% full; recover one level
    after ``dwell_ticks`` consecutive ticks with burn ≤1× and queue
    ≤25%.  In-between readings hold the current level (and reset the
    recovery dwell) — that asymmetry is the hysteresis.
    """

    enabled: bool = True
    max_level: int = 3
    enter_burn: float = 4.0
    exit_burn: float = 1.0
    enter_queue_frac: float = 0.75
    exit_queue_frac: float = 0.25
    dwell_ticks: int = 4


class DegradationController:
    """Tick-driven ladder state.  Not thread-safe by itself — ticked from
    one housekeeping thread; ``level`` reads are a single int load."""

    def __init__(self, config: Optional[DegradeConfig] = None,
                 registry=None):
        self.config = config or DegradeConfig()
        self._registry = registry
        self.level = 0
        self._calm_ticks = 0
        self._gauge().set(0.0)

    def _reg(self):
        return self._registry if self._registry is not None \
            else obs_metrics.get_registry()

    def _gauge(self):
        return self._reg().gauge(
            "repro_frontend_degraded_level",
            "Current degradation ladder level (0=healthy .. 3=shedding).")

    def _transition(self, new_level: int, direction: str) -> None:
        self.level = new_level
        self._gauge().set(float(new_level))
        self._reg().counter(
            "repro_frontend_degraded_transitions_total",
            "Ladder level changes by direction.",
            labels={"direction": direction}).inc()

    def tick(self, *, burn: float, queue_frac: float) -> int:
        """Advance one tick with fresh pressure readings; return level."""
        cfg = self.config
        if not cfg.enabled:
            return self.level
        hot = burn >= cfg.enter_burn or queue_frac >= cfg.enter_queue_frac
        calm = burn <= cfg.exit_burn and queue_frac <= cfg.exit_queue_frac
        if hot:
            self._calm_ticks = 0
            if self.level < cfg.max_level:
                self._transition(self.level + 1, "up")
        elif calm and self.level > 0:
            self._calm_ticks += 1
            if self._calm_ticks >= cfg.dwell_ticks:
                self._calm_ticks = 0
                self._transition(self.level - 1, "down")
        else:
            self._calm_ticks = 0
        return self.level
