"""Graph data substrate: synthetic geometric graphs matched to the assigned
GNN shape cells, batched small molecules, and a real fanout neighbor sampler
(minibatch_lg requires one).

All graphs are self-loop-free: eSCN edge frames are undefined for zero-length
edge vectors (standard geometric-GNN convention).
"""

from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np

from repro.models.gnn import GraphBatch


def _pad_to(x: np.ndarray, n: int, fill) -> np.ndarray:
    out = np.full((n,) + x.shape[1:], fill, x.dtype)
    out[: len(x)] = x
    return out


def random_geometric_graph(seed: int, n_nodes: int, n_edges: int,
                           d_feat: int, n_classes: int,
                           pad_nodes: int = 0, pad_edges: int = 0
                           ) -> GraphBatch:
    """Random positions in a box; random non-self edges; class labels."""
    gen = np.random.Generator(np.random.Philox(key=seed))
    pos = gen.normal(0, 1, (n_nodes, 3)).astype(np.float32)
    src = gen.integers(0, n_nodes, n_edges)
    dst = (src + gen.integers(1, n_nodes, n_edges)) % n_nodes   # no self loops
    vec = (pos[src] - pos[dst]).astype(np.float32)
    feat = gen.normal(0, 1, (n_nodes, d_feat)).astype(np.float32)
    labels = gen.integers(0, n_classes, n_nodes).astype(np.int32)
    pn = max(pad_nodes, n_nodes)
    pe = max(pad_edges, n_edges)
    return GraphBatch(
        node_feat=_pad_to(feat, pn, 0.0),
        edge_src=_pad_to(src.astype(np.int32), pe, -1),
        edge_dst=_pad_to(dst.astype(np.int32), pe, -1),
        edge_vec=_pad_to(vec, pe, 1.0),
        labels=_pad_to(labels, pn, -1),
        forces=np.zeros((pn, 3), np.float32),
        graph_id=np.zeros(pn, np.int32),
        n_graphs=1,
    )


def molecule_batch(seed: int, batch: int, nodes_per: int, edges_per: int,
                   d_feat: int = 16) -> GraphBatch:
    """Disjoint union of ``batch`` small molecules with energy/force targets."""
    gen = np.random.Generator(np.random.Philox(key=seed))
    N = batch * nodes_per
    E = batch * edges_per
    pos = gen.normal(0, 1, (N, 3)).astype(np.float32)
    src = np.zeros(E, np.int64)
    dst = np.zeros(E, np.int64)
    for b in range(batch):
        lo = b * nodes_per
        s = gen.integers(0, nodes_per, edges_per)
        d = (s + gen.integers(1, nodes_per, edges_per)) % nodes_per
        src[b * edges_per:(b + 1) * edges_per] = lo + s
        dst[b * edges_per:(b + 1) * edges_per] = lo + d
    vec = (pos[src] - pos[dst]).astype(np.float32)
    feat = gen.normal(0, 1, (N, d_feat)).astype(np.float32)
    energy = gen.normal(0, 1, batch).astype(np.float32)
    forces = gen.normal(0, 0.1, (N, 3)).astype(np.float32)
    graph_id = np.repeat(np.arange(batch, dtype=np.int32), nodes_per)
    return GraphBatch(
        node_feat=feat,
        edge_src=src.astype(np.int32), edge_dst=dst.astype(np.int32),
        edge_vec=vec, labels=energy, forces=forces,
        graph_id=graph_id, n_graphs=batch,
    )


class NeighborSampler:
    """Uniform fanout sampling from a CSR adjacency (GraphSAGE-style).

    ``sample(seeds, fanouts)`` returns a padded GraphBatch over the union of
    sampled nodes with edges pointing child → parent (messages flow toward
    the seed nodes), exactly the minibatch_lg training regime.
    """

    def __init__(self, seed: int, n_nodes: int, edges: np.ndarray,
                 feats: np.ndarray, labels: np.ndarray,
                 positions: np.ndarray | None = None):
        self.gen = np.random.Generator(np.random.Philox(key=seed))
        self.n = n_nodes
        src, dst = edges
        order = np.argsort(dst, kind="stable")
        self._nbr = src[order]
        self._off = np.zeros(n_nodes + 1, np.int64)
        np.add.at(self._off, dst + 1, 1)
        self._off = np.cumsum(self._off)
        self.feats = feats
        self.labels = labels
        self.pos = (positions if positions is not None
                    else self.gen.normal(0, 1, (n_nodes, 3)).astype(np.float32))

    def _sample_neighbors(self, nodes: np.ndarray, fanout: int):
        src_out, dst_out = [], []
        for v in nodes:
            lo, hi = self._off[v], self._off[v + 1]
            if hi == lo:
                continue
            picks = self._nbr[self.gen.integers(lo, hi, fanout)]
            picks = picks[picks != v]
            src_out.append(picks)
            dst_out.append(np.full(len(picks), v, np.int64))
        if not src_out:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        return np.concatenate(src_out), np.concatenate(dst_out)

    def sample(self, seeds: np.ndarray, fanouts: Sequence[int],
               pad_nodes: int, pad_edges: int) -> GraphBatch:
        frontier = np.asarray(seeds, np.int64)
        all_src, all_dst = [], []
        seen = set(frontier.tolist())
        for f in fanouts:
            s, d = self._sample_neighbors(frontier, f)
            all_src.append(s)
            all_dst.append(d)
            new = sorted(set(s.tolist()) - seen)
            seen.update(new)
            frontier = np.asarray(new, np.int64)
            if frontier.size == 0:
                break
        src = np.concatenate(all_src) if all_src else np.zeros(0, np.int64)
        dst = np.concatenate(all_dst) if all_dst else np.zeros(0, np.int64)
        nodes = np.asarray(sorted(seen), np.int64)
        remap = {int(v): i for i, v in enumerate(nodes)}
        ls = np.array([remap[int(v)] for v in src], np.int64) if src.size else src
        ld = np.array([remap[int(v)] for v in dst], np.int64) if dst.size else dst
        vec = (self.pos[src] - self.pos[dst]).astype(np.float32) \
            if src.size else np.zeros((0, 3), np.float32)
        labels = np.full(len(nodes), -1, np.int32)
        seed_local = [remap[int(v)] for v in seeds if int(v) in remap]
        labels[seed_local] = self.labels[np.asarray(seeds)[
            [i for i, v in enumerate(seeds) if int(v) in remap]]]
        ls = ls[:pad_edges]; ld = ld[:pad_edges]; vec = vec[:pad_edges]
        return GraphBatch(
            node_feat=_pad_to(self.feats[nodes].astype(np.float32), pad_nodes, 0.0),
            edge_src=_pad_to(ls.astype(np.int32), pad_edges, -1),
            edge_dst=_pad_to(ld.astype(np.int32), pad_edges, -1),
            edge_vec=_pad_to(vec, pad_edges, 1.0),
            labels=_pad_to(labels, pad_nodes, -1),
            forces=np.zeros((pad_nodes, 3), np.float32),
            graph_id=np.zeros(pad_nodes, np.int32),
            n_graphs=1,
        )
