"""Synthetic batch generators for the LM and recsys training/serving paths.
Deterministic in (seed, step) — a restart resumes the exact data stream
(fault-tolerance: the data pipeline is stateless given the step counter).
"""

from __future__ import annotations

import numpy as np

from repro.models.recsys import RecsysBatch, RecsysConfig


def lm_batch(seed: int, step: int, batch: int, seq: int, vocab: int):
    gen = np.random.Generator(np.random.Philox(key=(seed << 20) ^ step))
    # Zipfian tokens — realistic softmax/embedding access pattern.
    ranks = gen.zipf(1.3, size=(batch, seq + 1))
    toks = np.minimum(ranks - 1, vocab - 1).astype(np.int32)
    return toks[:, :-1], toks[:, 1:]


def recsys_batch(seed: int, step: int, batch: int, cfg: RecsysConfig
                 ) -> RecsysBatch:
    gen = np.random.Generator(np.random.Philox(key=(seed << 20) ^ step))
    dense = gen.normal(0, 1, (batch, cfg.n_dense)).astype(np.float32)
    sparse = gen.integers(0, cfg.vocab_per_field,
                          (batch, cfg.n_sparse, cfg.multi_hot)).astype(np.int32)
    drop = gen.random((batch, cfg.n_sparse, cfg.multi_hot)) < 0.2
    sparse = np.where(drop, -1, sparse)
    hist = gen.integers(0, cfg.n_items, (batch, cfg.seq_len)).astype(np.int32)
    lengths = gen.integers(1, cfg.seq_len + 1, batch)
    mask = np.arange(cfg.seq_len)[None, :] >= lengths[:, None]
    hist = np.where(mask, -1, hist)
    target = gen.integers(0, cfg.n_items, batch).astype(np.int32)
    labels = gen.integers(0, 2, batch).astype(np.float32)
    return RecsysBatch(dense=dense, sparse=sparse, hist=hist,
                       target=target, labels=labels)
