"""Synthetic sparse-vector datasets (paper §6.1.1 and §6.5).

The paper evaluates on MS MARCO encoded by BM25 / SPLADE / Efficient-SPLADE /
uniCOIL, plus fully synthetic real-valued collections G_100 / G_200.  Offline
we reproduce the *statistical shape* of each collection (Table 3 + Figure 6):

  * value distribution of non-zero entries (uniform / gaussian / zeta / lognormal)
  * activation law: which coordinates are active (uniform Bernoulli for the
    synthetic sets; Zipf-tilted for the text-like sets, matching Fig. 6(b))
  * ψ_d / ψ_q : mean non-zeros per document / query (Table 3)

Everything is deterministic in the seed and generated in NumPy (host data
pipeline), streamed in padded (idx, val) batches.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SparseDatasetSpec:
    name: str
    n: int                  # dimensionality
    psi_doc: int            # mean active coords per document
    psi_query: int          # mean active coords per query
    value_dist: str = "gaussian"   # gaussian | uniform | zeta | lognormal
    value_param: float = 1.0       # σ for gaussian, s for zeta
    nonneg: bool = False           # non-negative collection (Sinnamon+ territory)
    activation: str = "uniform"    # uniform | zipf  (Fig. 6(b) tail shape)
    zipf_a: float = 1.3


# Paper's synthetic real-valued datasets (§6.5, Table 4).
G100 = SparseDatasetSpec("G100", n=10_000, psi_doc=100, psi_query=100,
                         value_dist="gaussian", value_param=1.0)
G200 = SparseDatasetSpec("G200", n=32_000, psi_doc=200, psi_query=200,
                         value_dist="gaussian", value_param=1.0)

# Text-like emulations (Table 3 statistics; vocabulary 30k as in SPLADE).
SPLADE_LIKE = SparseDatasetSpec("splade_like", n=30_000, psi_doc=119,
                                psi_query=43, value_dist="lognormal",
                                value_param=0.6, nonneg=True,
                                activation="zipf")
ESPLADE_LIKE = SparseDatasetSpec("esplade_like", n=30_000, psi_doc=181,
                                 psi_query=6, value_dist="lognormal",
                                 value_param=0.6, nonneg=True,
                                 activation="zipf")
BM25_LIKE = SparseDatasetSpec("bm25_like", n=30_000, psi_doc=39, psi_query=6,
                              value_dist="lognormal", value_param=0.4,
                              nonneg=True, activation="zipf")
UNICOIL_LIKE = SparseDatasetSpec("unicoil_like", n=30_000, psi_doc=68,
                                 psi_query=6, value_dist="lognormal",
                                 value_param=0.5, nonneg=True,
                                 activation="zipf")

DATASETS = {d.name: d for d in
            (G100, G200, SPLADE_LIKE, ESPLADE_LIKE, BM25_LIKE, UNICOIL_LIKE)}


def _coord_weights(spec: SparseDatasetSpec) -> np.ndarray:
    if spec.activation == "uniform":
        return np.full(spec.n, 1.0 / spec.n)
    ranks = np.arange(1, spec.n + 1, dtype=np.float64)
    w = ranks ** (-spec.zipf_a)
    return w / w.sum()


def _draw_values(gen: np.random.Generator, size: int,
                 spec: SparseDatasetSpec) -> np.ndarray:
    if spec.value_dist == "gaussian":
        v = gen.normal(0.0, spec.value_param, size)
    elif spec.value_dist == "uniform":
        v = gen.uniform(-1.0, 1.0, size)
    elif spec.value_dist == "zeta":
        levels = np.linspace(-1.0, 1.0, 1024)
        pmf = np.arange(1, 1025, dtype=np.float64) ** (-spec.value_param)
        pmf /= pmf.sum()
        v = gen.choice(levels, size=size, p=pmf)
    elif spec.value_dist == "lognormal":
        v = gen.lognormal(mean=0.0, sigma=spec.value_param, size=size)
    else:
        raise ValueError(spec.value_dist)
    if spec.nonneg:
        v = np.abs(v)
    # active coordinates are almost-surely non-zero (paper §5 footnote 3)
    v = np.where(v == 0.0, 1e-6, v)
    return v.astype(np.float32)


def sample_sparse_batch(
    seed: int, spec: SparseDatasetSpec, batch: int, psi: int, pad: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """Draw ``batch`` sparse vectors with ψ ~ Poisson(psi) active coordinates.

    Returns padded (idx int32[batch, pad], val f32[batch, pad]); pad idx = -1.
    """
    gen = np.random.Generator(np.random.Philox(key=seed))
    weights = _coord_weights(spec)
    idx = np.full((batch, pad), -1, np.int32)
    val = np.zeros((batch, pad), np.float32)
    counts = np.clip(gen.poisson(psi, batch), 1, pad)
    for b in range(batch):
        c = int(counts[b])
        if spec.activation == "uniform":
            coords = gen.choice(spec.n, size=c, replace=False)
        else:
            coords = np.unique(gen.choice(spec.n, size=2 * c, p=weights))
            gen.shuffle(coords)
            coords = coords[:c]
            c = len(coords)
        idx[b, :c] = np.sort(coords)
        val[b, :c] = _draw_values(gen, c, spec)
    return idx, val


def make_corpus(seed: int, spec: SparseDatasetSpec, n_docs: int,
                pad: int | None = None):
    pad = pad or int(2.5 * spec.psi_doc)
    return sample_sparse_batch(seed, spec, n_docs, spec.psi_doc, pad)


def make_queries(seed: int, spec: SparseDatasetSpec, n_queries: int,
                 pad: int | None = None):
    pad = pad or int(2.5 * spec.psi_query)
    return sample_sparse_batch(seed ^ 0x5EED, spec, n_queries,
                               spec.psi_query, pad)


class StreamingFeed:
    """Infinite shuffled stream of (id, idx, val) insert events plus deletes.

    Models the paper's §6.4 protocol: sequential inserts of a shuffled corpus,
    optionally interleaved with random deletions of live documents.
    """

    def __init__(self, seed: int, spec: SparseDatasetSpec, pad: int,
                 delete_ratio: float = 0.0):
        self.gen = np.random.Generator(np.random.Philox(key=seed))
        self.spec = spec
        self.pad = pad
        self.delete_ratio = delete_ratio
        self._next_id = 0
        self._live: list[int] = []

    def events(self, count: int) -> Iterator[tuple]:
        for _ in range(count):
            if (self._live and self.delete_ratio > 0
                    and self.gen.random() < self.delete_ratio):
                pos = self.gen.integers(len(self._live))
                doc = self._live.pop(int(pos))
                yield ("delete", doc, None, None)
            else:
                idx, val = sample_sparse_batch(
                    int(self.gen.integers(2 ** 31)), self.spec, 1,
                    self.spec.psi_doc, self.pad)
                doc = self._next_id
                self._next_id += 1
                self._live.append(doc)
                yield ("insert", doc, idx[0], val[0])
