"""Tail-sampled flight recorder: a bounded in-memory ring of completed
request traces.

Retention is decided at request COMPLETION (tail sampling), when the
outcome and latency are known:

* every non-``ok`` request (error / deadline-exceeded / rejected) is
  retained — the requests an operator actually needs to explain;
* the slowest decile of recent OK requests is retained (the threshold is
  a running p90 over a sliding window of OK latencies);
* the rest are head-sampled at a configurable rate so the ring always
  holds a background of normal traffic to compare against.

Retained records spill to the JSONL event log (``request_trace`` events)
so they survive the ring; the ring itself backs the live debug surfaces
(``/debug/requests``, ``/debug/trace/<id>``, ``/debug/batches`` — see
`repro.obs.server`).

A process-global recorder (installed by ``launch/serve.py``) mirrors the
event-log pattern: `get_recorder()` / `set_recorder()`; when none is
installed, recording is a cheap no-op at the call sites.
"""

from __future__ import annotations

import itertools
import os
import threading
from collections import deque
from typing import Optional

from repro.obs import events as _events
from repro.obs import metrics as _metrics
from repro.obs.trace import TraceContext

__all__ = ["FlightRecorder", "get_recorder", "set_recorder"]

_batch_counter = itertools.count(1)
_batch_lock = threading.Lock()


def new_batch_id() -> str:
    """Process-unique id for one coalesced dispatch."""
    with _batch_lock:
        n = next(_batch_counter)
    return f"b-{os.getpid():x}-{n:x}"


class FlightRecorder:
    """Bounded ring of finished request traces with tail-sampled retention.

    ``capacity`` bounds the request ring, ``batch_capacity`` the ring of
    coalesced-batch records.  ``sample_rate`` is the head-sampling fraction
    for fast OK requests (0 disables; 1.0 keeps everything).
    ``tail_fraction`` is the slowest fraction of OK traffic always kept
    (0.1 = slowest decile); the threshold is recomputed every 32 records
    over the last ``tail_window`` OK latencies and stays ``inf`` (no tail
    retention) until ``min_tail_samples`` latencies have been seen.

    ``spill=True`` emits every retained record as a ``request_trace``
    event to ``event_log`` (or the process-global log).
    """

    def __init__(self, capacity: int = 512, *, batch_capacity: int = 256,
                 sample_rate: float = 0.05, tail_fraction: float = 0.1,
                 tail_window: int = 512, min_tail_samples: int = 32,
                 registry=None, event_log=None, spill: bool = True):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if not (0.0 <= sample_rate <= 1.0):
            raise ValueError(f"sample_rate must be in [0, 1], "
                             f"got {sample_rate}")
        if not (0.0 < tail_fraction < 1.0):
            raise ValueError(f"tail_fraction must be in (0, 1), "
                             f"got {tail_fraction}")
        self.capacity = int(capacity)
        self.sample_rate = float(sample_rate)
        self.tail_fraction = float(tail_fraction)
        self.min_tail_samples = int(min_tail_samples)
        self.spill = bool(spill)
        self._every = round(1.0 / sample_rate) if sample_rate > 0 else 0
        self._registry = registry
        self._event_log = event_log
        self._lock = threading.Lock()
        self._ring: deque = deque()            # request record dicts
        self._by_id: dict = {}                 # trace_id -> record
        self._batches: deque = deque(maxlen=int(batch_capacity))
        self._batch_by_id: dict = {}
        self._ok_lat: deque = deque(maxlen=int(tail_window))
        self._tail_threshold_ms = float("inf")
        self._seen_ok = 0
        self.seen = 0

    # -- wiring --------------------------------------------------------------
    def _reg(self):
        return self._registry if self._registry is not None \
            else _metrics.get_registry()

    def _log(self):
        return self._event_log if self._event_log is not None \
            else _events.get_event_log()

    # -- recording -----------------------------------------------------------
    def record(self, ctx) -> Optional[str]:
        """Decide retention for a finished context (or record dict).

        Returns the retention reason (``"outcome"`` / ``"tail"`` /
        ``"sampled"``) when the record was kept, else ``None``.  The
        returned truthiness is what links *exemplars* to the ring: callers
        attach the trace_id as a histogram exemplar only when it resolves.
        """
        rec = ctx.to_dict() if isinstance(ctx, TraceContext) else dict(ctx)
        outcome = rec.get("outcome")
        total_ms = rec.get("total_ms")
        with self._lock:
            self.seen += 1
            reason = None
            if outcome != "ok":
                reason = "outcome"
            else:
                if total_ms is not None:
                    self._seen_ok += 1
                    self._ok_lat.append(float(total_ms))
                    if (self._seen_ok % 32 == 0
                            and len(self._ok_lat) >= self.min_tail_samples):
                        lat = sorted(self._ok_lat)
                        i = int(len(lat) * (1.0 - self.tail_fraction))
                        self._tail_threshold_ms = lat[min(i, len(lat) - 1)]
                    if float(total_ms) >= self._tail_threshold_ms:
                        reason = "tail"
                if (reason is None and self._every
                        and self.seen % self._every == 0):
                    reason = "sampled"
            if reason is None:
                self._reg().counter(
                    "repro_recorder_dropped_total",
                    "Completed requests not retained by the recorder.").inc()
                return None
            rec["retained"] = reason
            self._ring.append(rec)
            self._by_id[rec["trace_id"]] = rec
            while len(self._ring) > self.capacity:
                old = self._ring.popleft()
                # only unmap if a newer record didn't reuse the id
                if self._by_id.get(old["trace_id"]) is old:
                    del self._by_id[old["trace_id"]]
        self._reg().counter(
            "repro_recorder_retained_total",
            "Request traces retained in the flight-recorder ring, by "
            "retention reason (outcome / tail / sampled).",
            labels={"reason": reason}).inc()
        if self.spill:
            log = self._log()
            if log is not None:
                level = "INFO" if outcome == "ok" else "WARN"
                log.emit("request_trace", level=level, **rec)
        return reason

    def record_batch(self, rec: dict) -> None:
        """Retain one coalesced-dispatch record (always kept; the batch
        ring is small and batches are ~max_batch× rarer than requests)."""
        rec = dict(rec)
        with self._lock:
            if len(self._batches) == self._batches.maxlen:
                old = self._batches[0]
                if self._batch_by_id.get(old.get("batch_id")) is old:
                    self._batch_by_id.pop(old.get("batch_id"), None)
            self._batches.append(rec)
            bid = rec.get("batch_id")
            if bid:
                self._batch_by_id[bid] = rec

    # -- reading -------------------------------------------------------------
    @property
    def tail_threshold_ms(self) -> float:
        return self._tail_threshold_ms

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    def get(self, trace_id: str) -> Optional[dict]:
        with self._lock:
            return self._by_id.get(trace_id)

    def get_batch(self, batch_id: str) -> Optional[dict]:
        with self._lock:
            return self._batch_by_id.get(batch_id)

    def recent(self, *, outcome: Optional[str] = None,
               tenant: Optional[str] = None,
               min_ms: Optional[float] = None,
               limit: int = 50) -> list:
        """Newest-first retained records, optionally filtered by outcome
        (prefix match, so ``rejected`` matches both rejection flavours),
        tenant, and minimum total latency."""
        out = []
        with self._lock:
            records = list(self._ring)
        for rec in reversed(records):
            if outcome is not None and \
                    not str(rec.get("outcome", "")).startswith(outcome):
                continue
            if tenant is not None and rec.get("tenant") != tenant:
                continue
            if min_ms is not None and \
                    (rec.get("total_ms") or 0.0) < float(min_ms):
                continue
            out.append(rec)
            if len(out) >= limit:
                break
        return out

    def recent_batches(self, limit: int = 50) -> list:
        with self._lock:
            records = list(self._batches)
        return list(reversed(records))[:limit]

    def stats(self) -> dict:
        with self._lock:
            return {
                "seen": self.seen,
                "ring_size": len(self._ring),
                "capacity": self.capacity,
                "batches": len(self._batches),
                "tail_threshold_ms":
                    None if self._tail_threshold_ms == float("inf")
                    else round(self._tail_threshold_ms, 4),
                "sample_rate": self.sample_rate,
                "tail_fraction": self.tail_fraction,
            }


_global_recorder: Optional[FlightRecorder] = None
_global_lock = threading.Lock()


def get_recorder() -> Optional[FlightRecorder]:
    return _global_recorder


def set_recorder(recorder: Optional[FlightRecorder]) -> \
        Optional[FlightRecorder]:
    """Install the process-global flight recorder; returns the previous."""
    global _global_recorder
    with _global_lock:
        old = _global_recorder
        _global_recorder = recorder
    return old
