"""Structured JSONL event log — one line per query / maintenance op.

The log is append-only newline-delimited JSON so it can be tailed,
`jq`-filtered, or bulk-loaded without a parser.  A process-global default
log (set by `launch/serve.py --event-log`) receives events from every
subsystem via the module-level `emit()`; when no log is installed,
`emit()` is a cheap no-op.

Size-based rotation (`max_bytes` + keep-N segments) bounds disk use under
sustained traffic: when appending a line would push the active file past
``max_bytes`` the file rotates to ``<path>.1`` (existing segments shift to
``.2`` … ``.keep``, the oldest is dropped) and a fresh file is opened.
`read_events` reads a log back tolerating a torn final line — the shape a
crash mid-append leaves behind.
"""

from __future__ import annotations

import json
import os
import threading
import time

__all__ = ["EventLog", "emit", "get_event_log", "read_events",
           "set_event_log"]


class EventLog:
    """Thread-safe append-only JSONL writer with size-based rotation.

    ``max_bytes=None`` (default) never rotates — the pre-rotation
    behaviour.  With ``max_bytes`` set, an append that would exceed it
    first rotates the active file; ``keep`` bounds how many rotated
    segments survive (``<path>.1`` newest … ``<path>.keep`` oldest).
    A single event larger than ``max_bytes`` still lands whole in a fresh
    segment — events are never split across files.
    """

    def __init__(self, path: str, max_bytes: int | None = None,
                 keep: int = 3):
        if max_bytes is not None and max_bytes < 1:
            raise ValueError(f"max_bytes must be >= 1, got {max_bytes}")
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        self.path = str(path)
        self.max_bytes = max_bytes
        self.keep = int(keep)
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._f = open(self.path, "a", encoding="utf-8")
        self._size = self._f.tell()
        self._lock = threading.Lock()
        self.written = 0
        self.rotations = 0

    def _rotate_locked(self) -> None:
        self._f.close()
        oldest = f"{self.path}.{self.keep}"
        if os.path.exists(oldest):
            os.remove(oldest)
        for i in range(self.keep - 1, 0, -1):
            src = f"{self.path}.{i}"
            if os.path.exists(src):
                os.replace(src, f"{self.path}.{i + 1}")
        os.replace(self.path, f"{self.path}.1")
        self._f = open(self.path, "a", encoding="utf-8")
        self._size = 0
        self.rotations += 1

    def emit(self, event: str, level: str = "INFO", **fields) -> None:
        rec = {"ts": round(time.time(), 6), "level": level, "event": event}
        for k, v in fields.items():
            if v is not None:
                rec[k] = v
        line = json.dumps(rec, default=str, separators=(",", ":")) + "\n"
        with self._lock:
            if self._f.closed:
                return
            nbytes = len(line.encode("utf-8"))
            if (self.max_bytes is not None and self._size > 0
                    and self._size + nbytes > self.max_bytes):
                self._rotate_locked()
            self._f.write(line)
            self._f.flush()
            self._size += nbytes
            self.written += 1

    def segments(self) -> list:
        """Existing log files, oldest first (rotated then active)."""
        out = [f"{self.path}.{i}" for i in range(self.keep, 0, -1)
               if os.path.exists(f"{self.path}.{i}")]
        if os.path.exists(self.path):
            out.append(self.path)
        return out

    def close(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        self.close()
        return False


def read_events(path: str, include_rotated: bool = False) -> list:
    """Parse a JSONL event log back into dicts, oldest first.

    A torn FINAL line (crash mid-append: no trailing newline, truncated
    JSON) is silently dropped — that is the valid on-disk shape after a
    crash.  A malformed line anywhere else raises ``ValueError``: interior
    corruption is a real problem and must not be skipped quietly.

    ``include_rotated`` also reads ``<path>.N`` segments (oldest first)
    written by the size-based rotation.
    """
    paths = []
    if include_rotated:
        i = 1
        found = []
        while os.path.exists(f"{path}.{i}"):
            found.append(f"{path}.{i}")
            i += 1
        paths.extend(reversed(found))        # .N is oldest
    paths.append(path)
    out = []
    for p in paths:
        if not os.path.exists(p):
            continue
        with open(p, encoding="utf-8", errors="replace") as f:
            raw = f.read()
        lines = raw.split("\n")
        last_complete = len(lines) - 1 if raw.endswith("\n") else \
            len(lines) - 2   # unterminated tail at lines[-1] (if any)
        for i, line in enumerate(lines):
            if not line.strip():
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                if p == paths[-1] and i > last_complete:
                    break                    # torn tail — tolerated
                raise ValueError(
                    f"{p}:{i + 1}: malformed interior event line: "
                    f"{line[:120]!r}") from None
    return out


_global_log: EventLog | None = None
_global_lock = threading.Lock()


def get_event_log() -> EventLog | None:
    return _global_log


def set_event_log(log: EventLog | None) -> EventLog | None:
    """Install the process-global event log; returns the previous one."""
    global _global_log
    with _global_lock:
        old = _global_log
        _global_log = log
    return old


def emit(event: str, level: str = "INFO", **fields) -> None:
    """Emit to the process-global log if one is installed; else no-op."""
    log = _global_log
    if log is not None:
        log.emit(event, level=level, **fields)
