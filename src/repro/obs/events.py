"""Structured JSONL event log — one line per query / maintenance op.

The log is append-only newline-delimited JSON so it can be tailed,
`jq`-filtered, or bulk-loaded without a parser.  A process-global default
log (set by `launch/serve.py --event-log`) receives events from every
subsystem via the module-level `emit()`; when no log is installed,
`emit()` is a cheap no-op.
"""

from __future__ import annotations

import json
import os
import threading
import time

__all__ = ["EventLog", "emit", "get_event_log", "set_event_log"]


class EventLog:
    """Thread-safe append-only JSONL writer."""

    def __init__(self, path: str):
        self.path = str(path)
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._f = open(self.path, "a", encoding="utf-8")
        self._lock = threading.Lock()
        self.written = 0

    def emit(self, event: str, level: str = "INFO", **fields) -> None:
        rec = {"ts": round(time.time(), 6), "level": level, "event": event}
        for k, v in fields.items():
            if v is not None:
                rec[k] = v
        line = json.dumps(rec, default=str, separators=(",", ":"))
        with self._lock:
            if self._f.closed:
                return
            self._f.write(line + "\n")
            self._f.flush()
            self.written += 1

    def close(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        self.close()
        return False


_global_log: EventLog | None = None
_global_lock = threading.Lock()


def get_event_log() -> EventLog | None:
    return _global_log


def set_event_log(log: EventLog | None) -> EventLog | None:
    """Install the process-global event log; returns the previous one."""
    global _global_log
    with _global_lock:
        old = _global_log
        _global_log = log
    return old


def emit(event: str, level: str = "INFO", **fields) -> None:
    """Emit to the process-global log if one is installed; else no-op."""
    log = _global_log
    if log is not None:
        log.emit(event, level=level, **fields)
