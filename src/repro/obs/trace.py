"""Span tracer and propagated per-request trace context.

Two levels of tracing live here:

* `Trace` — a flat list of named spans recorded with a context manager;
  the serving layer opens one per sampled query and calls
  `jax.block_until_ready` inside each span so device work is attributed to
  the stage that launched it (see `QueryServer._search_staged`).
* `TraceContext` — the *propagated* per-request context (ISSUE 8): created
  at the front door (`ServingFrontend.submit`) or at `QueryServer.query*`,
  threaded through quota check → admission queue → batch assembly → device
  dispatch → response, accumulating per-stage wall-clock timestamps and
  annotations (which coalesced batch the request rode in, its outcome).
  Finished contexts go to the flight recorder (`repro.obs.recorder`) so a
  ``QueryResult.trace_id`` resolves to a full stage breakdown at
  ``/debug/trace/<id>``.
"""

from __future__ import annotations

import itertools
import os
import threading
import time
from typing import Optional

__all__ = ["Span", "Trace", "TraceContext", "new_trace_id"]

_trace_counter = itertools.count(1)
_trace_lock = threading.Lock()


def new_trace_id() -> str:
    """Process-unique, monotonically increasing query trace id."""
    with _trace_lock:
        n = next(_trace_counter)
    return f"q-{os.getpid():x}-{n:x}"


class Span:
    __slots__ = ("name", "ms")

    def __init__(self, name: str, ms: float):
        self.name = name
        self.ms = ms

    def __repr__(self) -> str:
        return f"Span({self.name!r}, {self.ms:.3f}ms)"


class _SpanCtx:
    __slots__ = ("_trace", "_name", "_t0")

    def __init__(self, trace: "Trace", name: str):
        self._trace = trace
        self._name = name

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        self._trace.spans.append(
            Span(self._name, (time.perf_counter() - self._t0) * 1e3)
        )
        return False


class Trace:
    """Named collection of timed spans for one operation."""

    __slots__ = ("name", "spans")

    def __init__(self, name: str = "query"):
        self.name = name
        self.spans: list[Span] = []

    def span(self, name: str) -> _SpanCtx:
        """Context manager timing one stage; appends a `Span` on exit."""
        return _SpanCtx(self, name)

    def total_ms(self) -> float:
        return sum(s.ms for s in self.spans)

    def stage_ms(self) -> dict:
        return {s.name: s.ms for s in self.spans}

    def as_dict(self) -> dict:
        return {
            "name": self.name,
            "spans": [{"stage": s.name, "ms": round(s.ms, 4)} for s in self.spans],
        }


class _CtxSpan:
    __slots__ = ("_ctx", "_name", "_t0")

    def __init__(self, ctx: "TraceContext", name: str):
        self._ctx = ctx
        self._name = name

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        self._ctx.add_stage(
            self._name, (time.perf_counter() - self._t0) * 1e3,
            start_ms=(self._t0 - self._ctx._t0) * 1e3)
        return False


class TraceContext:
    """One request's propagated trace: id, stage timings, annotations.

    Stages are ``(name, start_ms, dur_ms)`` with ``start_ms`` relative to
    context creation (``None`` for sub-spans imported from a staged
    `Trace`, which only carry durations).  A context is built up by exactly
    one thread at a time (submit thread, then the dispatcher) — the
    hand-off happens through the admission queue, so no locking is needed.

    The context is deliberately cheap to create and finish (a couple of
    ``perf_counter`` calls and list appends): every request gets one, and
    the *retention* decision is the flight recorder's, made at completion
    — tail sampling, not head sampling.
    """

    __slots__ = ("trace_id", "tenant", "ts", "_t0", "stages",
                 "annotations", "outcome", "error", "total_ms")

    def __init__(self, tenant: str = "default",
                 trace_id: Optional[str] = None):
        self.trace_id = trace_id if trace_id is not None else new_trace_id()
        self.tenant = tenant
        self.ts = time.time()                # wall-clock anchor (unix)
        self._t0 = time.perf_counter()       # monotonic anchor
        self.stages: list = []               # [name, start_ms|None, dur_ms]
        self.annotations: dict = {}
        self.outcome: Optional[str] = None
        self.error: Optional[str] = None
        self.total_ms: Optional[float] = None

    # -- recording -----------------------------------------------------------
    def stage(self, name: str) -> _CtxSpan:
        """Context manager timing one stage of this request."""
        return _CtxSpan(self, name)

    def add_stage(self, name: str, dur_ms: float,
                  start_ms: Optional[float] = None) -> None:
        """Record a stage timed externally (e.g. with the frontend's
        injectable clock); ``start_ms`` is relative to context creation."""
        self.stages.append((name, start_ms, float(dur_ms)))

    def elapsed_ms(self) -> float:
        return (time.perf_counter() - self._t0) * 1e3

    def annotate(self, **fields) -> None:
        """Attach key/value annotations (batch id, width bucket, ...)."""
        self.annotations.update(fields)

    def add_trace(self, trace: Trace, prefix: str = "") -> None:
        """Import a staged `Trace`'s spans as sub-stages (duration only)."""
        for s in trace.spans:
            self.stages.append((prefix + s.name, None, s.ms))

    def finish(self, outcome: str, total_ms: Optional[float] = None,
               error: Optional[str] = None) -> "TraceContext":
        """Seal the context: outcome + total latency.  ``total_ms`` defaults
        to the context's own elapsed wall clock."""
        self.outcome = outcome
        self.error = error
        self.total_ms = self.elapsed_ms() if total_ms is None \
            else float(total_ms)
        return self

    # -- reading -------------------------------------------------------------
    def stage_ms(self) -> dict:
        """{stage: dur_ms}; repeated stage names accumulate."""
        out: dict = {}
        for name, _start, dur in self.stages:
            out[name] = out.get(name, 0.0) + dur
        return out

    def to_dict(self) -> dict:
        d = {
            "trace_id": self.trace_id,
            "tenant": self.tenant,
            "ts": round(self.ts, 6),
            "outcome": self.outcome,
            "total_ms": None if self.total_ms is None
            else round(self.total_ms, 4),
            "stages": [
                {"stage": name,
                 **({} if start is None
                    else {"start_ms": round(start, 4)}),
                 "ms": round(dur, 4)}
                for name, start, dur in self.stages
            ],
        }
        if self.error is not None:
            d["error"] = self.error
        if self.annotations:
            d.update(self.annotations)
        return d

    def __repr__(self) -> str:
        return (f"TraceContext({self.trace_id!r}, tenant={self.tenant!r}, "
                f"outcome={self.outcome!r}, stages={len(self.stages)})")
