"""Lightweight span tracer for staged query timing.

A `Trace` is a flat list of named spans recorded with a context manager;
the serving layer opens one per sampled query and calls
`jax.block_until_ready` inside each span so device work is attributed to
the stage that launched it (see `QueryServer._search_staged`).
"""

from __future__ import annotations

import time

__all__ = ["Span", "Trace"]


class Span:
    __slots__ = ("name", "ms")

    def __init__(self, name: str, ms: float):
        self.name = name
        self.ms = ms

    def __repr__(self) -> str:
        return f"Span({self.name!r}, {self.ms:.3f}ms)"


class _SpanCtx:
    __slots__ = ("_trace", "_name", "_t0")

    def __init__(self, trace: "Trace", name: str):
        self._trace = trace
        self._name = name

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        self._trace.spans.append(
            Span(self._name, (time.perf_counter() - self._t0) * 1e3)
        )
        return False


class Trace:
    """Named collection of timed spans for one operation."""

    __slots__ = ("name", "spans")

    def __init__(self, name: str = "query"):
        self.name = name
        self.spans: list[Span] = []

    def span(self, name: str) -> _SpanCtx:
        """Context manager timing one stage; appends a `Span` on exit."""
        return _SpanCtx(self, name)

    def total_ms(self) -> float:
        return sum(s.ms for s in self.spans)

    def stage_ms(self) -> dict:
        return {s.name: s.ms for s in self.spans}

    def as_dict(self) -> dict:
        return {
            "name": self.name,
            "spans": [{"stage": s.name, "ms": round(s.ms, 4)} for s in self.spans],
        }
