"""Process-local metrics: counters, gauges, and fixed-exponential-bucket
histograms behind a registry with bounded label cardinality.

Design constraints (ISSUE 6):

- stdlib only — the registry must be importable from every layer (WAL,
  kernels wrappers, benchmarks) without dragging in JAX or numpy;
- thread-safe — the WAL writer, `BackgroundCompactor`, and the metrics
  HTTP server all touch it from their own threads;
- mergeable snapshots — two registries (e.g. per-process shards) with the
  same bucket layout can be summed sample-for-sample;
- bounded label cardinality — a typo'd dynamic label (doc id, slot
  number) raises `LabelCardinalityError` instead of silently growing an
  unbounded family;
- one shared percentile implementation — `Histogram.percentile` backs
  both `QueryServer.latency_percentiles()` and the benchmark gates.

Histograms use a fixed exponential layout ``bound[i] = start * factor**i``
so percentile estimates carry at most one bucket (``factor``) of relative
error, tightened at the tails by clamping to the exact tracked min/max.
"""

from __future__ import annotations

import json
import math
import re
import threading
from bisect import bisect_left

__all__ = [
    "Buckets",
    "Counter",
    "Gauge",
    "Histogram",
    "LabelCardinalityError",
    "MetricsRegistry",
    "NULL_REGISTRY",
    "DEFAULT_LATENCY_BUCKETS",
    "DEFAULT_COUNT_BUCKETS",
    "get_registry",
    "set_registry",
    "merge_snapshots",
    "parse_exposition",
]

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_NAME_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


class LabelCardinalityError(RuntimeError):
    """A metric family exceeded the registry's label-set budget."""


class Buckets:
    """Fixed exponential histogram layout: ``bound[i] = start * factor**i``."""

    __slots__ = ("start", "factor", "count", "bounds")

    def __init__(self, start: float, factor: float, count: int):
        if not (start > 0.0 and factor > 1.0 and count >= 1):
            raise ValueError("need start > 0, factor > 1, count >= 1")
        self.start = float(start)
        self.factor = float(factor)
        self.count = int(count)
        self.bounds = tuple(self.start * self.factor**i for i in range(self.count))

    def index(self, value: float) -> int:
        """Bucket index for `value`; `count` means the +Inf overflow bucket."""
        return bisect_left(self.bounds, value)

    def spec(self) -> dict:
        return {"start": self.start, "factor": self.factor, "count": self.count}

    def __eq__(self, other) -> bool:
        return (
            isinstance(other, Buckets)
            and (self.start, self.factor, self.count) == (other.start, other.factor, other.count)
        )

    def __hash__(self) -> int:
        return hash((self.start, self.factor, self.count))


# 1 µs .. ~14.7 s in milliseconds at ±~9% resolution (factor 2**0.25).
DEFAULT_LATENCY_BUCKETS = Buckets(1e-3, 2**0.25, 96)
# 1 .. 2**31 for batch sizes / byte counts per op.
DEFAULT_COUNT_BUCKETS = Buckets(1.0, 2.0, 32)


class Counter:
    """Monotonic counter."""

    __slots__ = ("_value", "_lock")

    def __init__(self):
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = 0.0

    def snapshot(self) -> dict:
        return {"value": self._value}


class Gauge:
    """Instantaneous value."""

    __slots__ = ("_value", "_lock")

    def __init__(self):
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.inc(-amount)

    @property
    def value(self) -> float:
        return self._value

    def reset(self) -> None:
        self.set(0.0)

    def snapshot(self) -> dict:
        return {"value": self._value}


class Histogram:
    """Exponential-bucket histogram with exact sum/count/min/max sidecars.

    Buckets optionally carry an *exemplar* — the id of one concrete sample
    (a flight-recorder trace id) that landed in the bucket, so a latency
    bucket in a dashboard links to a full request trace at
    ``/debug/trace/<id>``.  One exemplar per bucket, latest wins.
    """

    __slots__ = ("buckets", "_counts", "_count", "_sum", "_min", "_max",
                 "_exemplars", "_lock")

    def __init__(self, buckets: Buckets | None = None):
        self.buckets = buckets if buckets is not None else DEFAULT_LATENCY_BUCKETS
        self._counts = [0] * (self.buckets.count + 1)
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._exemplars = None  # {bucket_index: (id, value)}, lazily allocated
        self._lock = threading.Lock()

    def observe(self, value: float, n: int = 1, exemplar: str | None = None) -> None:
        """Record `value`; `n > 1` records it as n identical samples (used
        for per-query latency derived from one timed batch).  `exemplar`
        attaches a trace id to the bucket the value lands in."""
        value = float(value)
        i = self.buckets.index(value)
        with self._lock:
            self._counts[i] += n
            self._count += n
            self._sum += value * n
            if value < self._min:
                self._min = value
            if value > self._max:
                self._max = value
            if exemplar is not None:
                if self._exemplars is None:
                    self._exemplars = {}
                self._exemplars[i] = (str(exemplar), value)

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def percentile(self, p: float) -> float:
        """Estimate the p-th percentile (0..100) from the bucket counts.

        The estimate is the geometric midpoint of the bucket holding the
        target rank, clamped to the exact tracked [min, max] — relative
        error is at most one bucket width (`buckets.factor`).
        """
        with self._lock:
            count = self._count
            counts = list(self._counts)
            lo_clamp, hi_clamp = self._min, self._max
        if count == 0:
            return 0.0
        target = max(1, math.ceil((p / 100.0) * count))
        target = min(target, count)
        seen = 0
        bounds = self.buckets.bounds
        for i, c in enumerate(counts):
            seen += c
            if seen >= target:
                lo = bounds[i - 1] if i > 0 else bounds[0] / self.buckets.factor
                hi = bounds[i] if i < len(bounds) else hi_clamp
                est = math.sqrt(lo * hi) if hi > 0 and lo > 0 else (lo + hi) / 2.0
                return min(max(est, lo_clamp), hi_clamp)
        return hi_clamp

    def reset(self) -> None:
        with self._lock:
            self._counts = [0] * (self.buckets.count + 1)
            self._count = 0
            self._sum = 0.0
            self._min = math.inf
            self._max = -math.inf
            self._exemplars = None

    @property
    def bucket_counts(self) -> list:
        with self._lock:
            return list(self._counts)

    def snapshot(self) -> dict:
        with self._lock:
            out = {
                "buckets": self.buckets.spec(),
                "counts": list(self._counts),
                "count": self._count,
                "sum": self._sum,
                "min": None if self._count == 0 else self._min,
                "max": None if self._count == 0 else self._max,
            }
            if self._exemplars:
                out["exemplars"] = {
                    str(i): {"trace_id": tid, "value": val}
                    for i, (tid, val) in self._exemplars.items()
                }
            return out


class _Family:
    __slots__ = ("kind", "help", "buckets", "children")

    def __init__(self, kind: str, help_text: str, buckets: Buckets | None):
        self.kind = kind
        self.help = help_text
        self.buckets = buckets
        self.children = {}  # label tuple -> metric


def _label_key(labels: dict | None) -> tuple:
    if not labels:
        return ()
    items = []
    for k, v in labels.items():
        k = str(k)
        if not _LABEL_NAME_RE.match(k):
            raise ValueError(f"invalid label name: {k!r}")
        items.append((k, str(v)))
    return tuple(sorted(items))


class MetricsRegistry:
    """Named metric families plus pull-time gauge collectors.

    `max_label_sets` bounds the number of distinct label combinations per
    family — exceeding it raises `LabelCardinalityError` so accidental
    per-document labels fail loudly instead of leaking memory.
    """

    def __init__(self, max_label_sets: int = 64):
        self.max_label_sets = int(max_label_sets)
        self._families: dict[str, _Family] = {}
        self._collectors = []
        self.collector_errors = 0
        self._lock = threading.Lock()

    # -- metric accessors (create on first use, return existing after) ------

    def counter(self, name: str, help_text: str = "", labels: dict | None = None) -> Counter:
        return self._child(name, "counter", help_text, labels)

    def gauge(self, name: str, help_text: str = "", labels: dict | None = None) -> Gauge:
        return self._child(name, "gauge", help_text, labels)

    def histogram(
        self,
        name: str,
        help_text: str = "",
        labels: dict | None = None,
        buckets: Buckets | None = None,
    ) -> Histogram:
        return self._child(name, "histogram", help_text, labels, buckets)

    def _child(self, name, kind, help_text, labels, buckets=None):
        key = _label_key(labels)
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                if not _NAME_RE.match(name):
                    raise ValueError(f"invalid metric name: {name!r}")
                fam = _Family(kind, help_text, buckets)
                self._families[name] = fam
            else:
                if fam.kind != kind:
                    raise ValueError(f"{name} is a {fam.kind}, requested {kind}")
                if kind == "histogram" and buckets is not None and fam.buckets is not None:
                    if buckets != fam.buckets:
                        raise ValueError(f"{name}: conflicting bucket layouts")
            child = fam.children.get(key)
            if child is None:
                if len(fam.children) >= self.max_label_sets:
                    raise LabelCardinalityError(
                        f"{name}: more than {self.max_label_sets} label sets"
                    )
                if kind == "counter":
                    child = Counter()
                elif kind == "gauge":
                    child = Gauge()
                else:
                    child = Histogram(fam.buckets)
                fam.children[key] = child
            return child

    # -- pull-time collectors -----------------------------------------------

    def add_collector(self, fn) -> None:
        """Register `fn()` to run before every snapshot/exposition.  A
        collector that returns False (e.g. its weakref target died) is
        removed; one that raises is kept and counted in
        `collector_errors`."""
        with self._lock:
            self._collectors.append(fn)

    def collect(self) -> None:
        with self._lock:
            collectors = list(self._collectors)
        dead = []
        for fn in collectors:
            try:
                if fn() is False:
                    dead.append(fn)
            except Exception:
                self.collector_errors += 1
        if dead:
            with self._lock:
                for fn in dead:
                    if fn in self._collectors:
                        self._collectors.remove(fn)

    # -- export --------------------------------------------------------------

    def snapshot(self) -> dict:
        """Structured dump: {name: {type, help, series: [{labels, ...}]}}."""
        self.collect()
        out = {}
        with self._lock:
            families = list(self._families.items())
        for name, fam in families:
            series = []
            for key, child in list(fam.children.items()):
                entry = {"labels": dict(key)}
                entry.update(child.snapshot())
                series.append(entry)
            out[name] = {"type": fam.kind, "help": fam.help, "series": series}
        return out

    def to_json(self) -> str:
        return json.dumps(self.snapshot(), sort_keys=True)

    def exposition(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        lines = []
        for name, fam in self.snapshot().items():
            if fam["help"]:
                lines.append(f"# HELP {name} {_escape_help(fam['help'])}")
            lines.append(f"# TYPE {name} {fam['type']}")
            for s in fam["series"]:
                labels = s["labels"]
                if fam["type"] in ("counter", "gauge"):
                    lines.append(f"{name}{_fmt_labels(labels)} {_fmt_value(s['value'])}")
                else:
                    spec = s["buckets"]
                    bounds = [spec["start"] * spec["factor"] ** i for i in range(spec["count"])]
                    exemplars = s.get("exemplars", {})
                    cum = 0
                    for i, (b, c) in enumerate(zip(bounds, s["counts"])):
                        cum += c
                        le = {**labels, "le": format(b, ".10g")}
                        line = f"{name}_bucket{_fmt_labels(le)} {cum}"
                        ex = exemplars.get(str(i))
                        if ex is not None:  # OpenMetrics exemplar suffix
                            line += (f' # {{trace_id="'
                                     f'{_escape_label_value(ex["trace_id"])}'
                                     f'"}} {_fmt_value(ex["value"])}')
                        lines.append(line)
                    cum += s["counts"][-1]
                    le = {**labels, "le": "+Inf"}
                    lines.append(f"{name}_bucket{_fmt_labels(le)} {cum}")
                    lines.append(f"{name}_sum{_fmt_labels(labels)} {_fmt_value(s['sum'])}")
                    lines.append(f"{name}_count{_fmt_labels(labels)} {s['count']}")
        return "\n".join(lines) + ("\n" if lines else "")


def _escape_help(text: str) -> str:
    return text.replace("\\", "\\\\").replace("\n", "\\n")


def _escape_label_value(value: str) -> str:
    return value.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape_label_value(v)}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    if v != v:
        return "NaN"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


# -- the process-global registry ---------------------------------------------


class _NullMetric:
    """Absorbs every metric call; `percentile` is 0 and `count` stays 0."""

    __slots__ = ()

    def inc(self, amount: float = 1.0) -> None:
        pass

    def dec(self, amount: float = 1.0) -> None:
        pass

    def set(self, value: float) -> None:
        pass

    def observe(self, value: float, n: int = 1, exemplar: str | None = None) -> None:
        pass

    def reset(self) -> None:
        pass

    def percentile(self, p: float) -> float:
        return 0.0

    value = 0.0
    count = 0
    sum = 0.0

    def snapshot(self) -> dict:
        return {}


_NULL_METRIC = _NullMetric()


class NullRegistry:
    """Registry stand-in that records nothing — inject to turn metrics off."""

    max_label_sets = 0
    collector_errors = 0

    def counter(self, name, help_text="", labels=None):
        return _NULL_METRIC

    def gauge(self, name, help_text="", labels=None):
        return _NULL_METRIC

    def histogram(self, name, help_text="", labels=None, buckets=None):
        return _NULL_METRIC

    def add_collector(self, fn) -> None:
        pass

    def collect(self) -> None:
        pass

    def snapshot(self) -> dict:
        return {}

    def to_json(self) -> str:
        return "{}"

    def exposition(self) -> str:
        return ""


NULL_REGISTRY = NullRegistry()

_global_registry = MetricsRegistry()
_global_lock = threading.Lock()


def get_registry() -> MetricsRegistry:
    """The process-global registry every subsystem reports into by default."""
    return _global_registry


def set_registry(registry) -> MetricsRegistry:
    """Swap the process-global registry (tests; metrics-off benchmarking).
    Returns the previous registry so callers can restore it."""
    global _global_registry
    with _global_lock:
        old = _global_registry
        _global_registry = registry
    return old


# -- snapshot merging ---------------------------------------------------------


def merge_snapshots(a: dict, b: dict) -> dict:
    """Merge two `MetricsRegistry.snapshot()` dicts sample-for-sample.

    Counters and gauges sum; histograms require identical bucket layouts
    (ValueError otherwise) and sum counts/sums, min/max-ing the sidecars.
    """
    out = {}
    for name in sorted(set(a) | set(b)):
        fa, fb = a.get(name), b.get(name)
        if fa is None or fb is None:
            src = fa if fb is None else fb
            out[name] = json.loads(json.dumps(src))
            continue
        if fa["type"] != fb["type"]:
            raise ValueError(f"{name}: type mismatch {fa['type']} vs {fb['type']}")
        merged = {"type": fa["type"], "help": fa["help"] or fb["help"], "series": []}
        by_labels = {}
        for src in (fa, fb):
            for s in src["series"]:
                key = tuple(sorted(s["labels"].items()))
                prev = by_labels.get(key)
                if prev is None:
                    by_labels[key] = json.loads(json.dumps(s))
                elif fa["type"] == "histogram":
                    if prev["buckets"] != s["buckets"]:
                        raise ValueError(f"{name}: bucket layout mismatch")
                    prev["counts"] = [x + y for x, y in zip(prev["counts"], s["counts"])]
                    prev["count"] += s["count"]
                    prev["sum"] += s["sum"]
                    for fld, pick in (("min", min), ("max", max)):
                        vals = [v for v in (prev[fld], s[fld]) if v is not None]
                        prev[fld] = pick(vals) if vals else None
                    if "exemplars" in s:  # per-bucket: later source wins
                        prev["exemplars"] = {**prev.get("exemplars", {}),
                                             **s["exemplars"]}
                else:
                    prev["value"] += s["value"]
        merged["series"] = [by_labels[k] for k in sorted(by_labels)]
        out[name] = merged
    return out


# -- exposition parsing (CI validator) ----------------------------------------

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r"\s+(?P<value>[^\s#]+)"
    r"(?P<exemplar>\s+#\s+\{[^}]*\}\s+[^\s]+)?\s*$"
)
_LABEL_PAIR_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def parse_exposition(text: str) -> dict:
    """Parse Prometheus text exposition into {(name, label_tuple): value}.

    OpenMetrics exemplar suffixes (``# {trace_id="..."} 1.23``) on bucket
    lines are validated and stripped.  Raises ValueError on any malformed
    line — used by CI to validate the live `/metrics` endpoint actually
    speaks the format.
    """
    samples = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        match = _SAMPLE_RE.match(line)
        if not match:
            raise ValueError(f"line {lineno}: malformed sample {line!r}")
        labels = ()
        body = match.group("labels")
        if body:
            pairs = _LABEL_PAIR_RE.findall(body)
            rebuilt = ",".join(f'{k}="{v}"' for k, v in pairs)
            if rebuilt != body:
                raise ValueError(f"line {lineno}: malformed labels {body!r}")
            labels = tuple((k, v) for k, v in pairs)
        raw = match.group("value")
        try:
            value = float(raw.replace("+Inf", "inf").replace("-Inf", "-inf"))
        except ValueError as exc:
            raise ValueError(f"line {lineno}: bad value {raw!r}") from exc
        samples[(match.group("name"), labels)] = value
    return samples
