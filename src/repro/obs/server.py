"""Stdlib HTTP observability server: metrics exposition, health/readiness
probes, and request-level debug surfaces.

Endpoints (all GET; every server in the system mounts the same map via
`build_endpoints`, so `/metrics` on the metrics port and on the serving
front door behave identically):

- ``/metrics`` — Prometheus text exposition format 0.0.4 (with
  OpenMetrics exemplar suffixes on bucket lines that carry one);
- ``/metrics.json`` — the structured registry snapshot as JSON;
- ``/healthz`` — pure liveness (``ok`` while the process serves HTTP);
- ``/readyz`` — readiness: 200 when every registered check passes, 503
  with a JSON reason breakdown when not (see `ReadyState`);
- ``/debug/requests`` — recent flight-recorder ring, filterable by
  ``?outcome=&tenant=&min_ms=&limit=``;
- ``/debug/trace/<id>`` — one retained request trace, full stage
  breakdown;
- ``/debug/batches`` — recent coalesced-dispatch records;
- ``/debug/slo`` — the SLO monitor's live burn-rate report;
- ``/debug/profile?seconds=N`` — capture an on-demand ``jax.profiler``
  trace into the configured profile dir.

Endpoint protocol: ``fn(rest, query) -> (status, body_bytes, ctype)``
where ``rest`` is the path remainder after a prefix-mounted key (empty
for exact keys) and ``query`` is the parsed query string.  `dispatch`
routes a raw request path through an endpoint map (exact match first,
then longest registered ``.../`` prefix).

Bound to loopback by default; pass ``port=0`` to let the OS pick (the
chosen port is published on ``server.port`` after `start()`).
"""

from __future__ import annotations

import json
import os
import threading
import time
import urllib.parse
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.obs import metrics as _metrics

__all__ = [
    "MetricsServer",
    "ReadyState",
    "build_endpoints",
    "debug_endpoints",
    "dispatch",
    "registry_endpoints",
]


class ReadyState:
    """Named readiness conditions aggregated into one ``/readyz`` answer.

    Two kinds of condition:

    * `mark(name, ok, reason)` — a latched flag the owner flips (e.g. the
      launcher marks ``engine`` ready once recovery/replay completes);
    * `add_check(name, fn)` — evaluated live on every probe; ``fn`` returns
      ``(ok, reason)`` (a bare bool is accepted).  A check that raises
      reports not-ready with the exception as the reason.

    Calling the state returns ``(ready, {name: {"ok": bool, "reason":
    str}})``.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._flags: dict = {}       # name -> (ok, reason)
        self._checks: dict = {}      # name -> fn

    def mark(self, name: str, ok: bool = True, reason: str = "") -> None:
        with self._lock:
            self._flags[str(name)] = (bool(ok), str(reason))

    def add_check(self, name: str, fn) -> None:
        with self._lock:
            self._checks[str(name)] = fn

    def __call__(self):
        with self._lock:
            flags = dict(self._flags)
            checks = dict(self._checks)
        detail = {}
        for name, (ok, reason) in flags.items():
            detail[name] = {"ok": ok, "reason": reason}
        for name, fn in checks.items():
            try:
                res = fn()
            except Exception as e:                     # noqa: BLE001
                res = (False, f"check raised: {e!r}")
            ok, reason = res if isinstance(res, tuple) else (bool(res), "")
            detail[name] = {"ok": bool(ok), "reason": str(reason)}
        ready = all(d["ok"] for d in detail.values())
        return ready, detail


def _json_body(status: int, doc) -> tuple:
    return status, json.dumps(doc).encode("utf-8"), "application/json"


def registry_endpoints(registry, ready=None) -> dict:
    """The standard observability GET endpoints as an endpoint map.

    ``ready`` is an optional callable (e.g. a `ReadyState`) returning
    ``(bool, detail)``; without one, ``/readyz`` reports ready with no
    checks — liveness stays on ``/healthz``, which never consults state.
    """
    def metrics(rest, query):
        return (200, registry.exposition().encode("utf-8"),
                "text/plain; version=0.0.4; charset=utf-8")

    def metrics_json(rest, query):
        return 200, registry.to_json().encode("utf-8"), "application/json"

    def healthz(rest, query):
        # pure liveness: if this handler runs, the process is alive.
        return 200, b"ok\n", "text/plain; charset=utf-8"

    def readyz(rest, query):
        if ready is None:
            return _json_body(200, {"ready": True, "checks": {}})
        ok, detail = ready()
        return _json_body(200 if ok else 503,
                          {"ready": ok, "checks": detail})

    return {"/metrics": metrics, "/": metrics,
            "/metrics.json": metrics_json,
            "/healthz": healthz, "/readyz": readyz}


def _requests_endpoint(recorder):
    def debug_requests(rest, query):
        try:
            limit = int(query.get("limit", 50))
            min_ms = query.get("min_ms")
            records = recorder.recent(
                outcome=query.get("outcome") or None,
                tenant=query.get("tenant") or None,
                min_ms=float(min_ms) if min_ms else None,
                limit=max(1, min(limit, 1000)))
        except ValueError as e:
            return _json_body(400, {"error": "bad_request",
                                    "detail": str(e)})
        return _json_body(200, {"requests": records,
                                "count": len(records),
                                "recorder": recorder.stats()})
    return debug_requests


def _trace_endpoint(recorder):
    def debug_trace(rest, query):
        trace_id = rest.strip("/")
        if not trace_id:
            return _json_body(400, {"error": "bad_request",
                                    "detail": "missing trace id"})
        rec = recorder.get(trace_id) or recorder.get_batch(trace_id)
        if rec is None:
            return _json_body(404, {
                "error": "not_found", "trace_id": trace_id,
                "detail": "not retained (dropped by sampling, evicted "
                          "from the ring, or never recorded)"})
        return _json_body(200, rec)
    return debug_trace


def _batches_endpoint(recorder):
    def debug_batches(rest, query):
        limit = max(1, min(int(query.get("limit", 50)), 1000))
        records = recorder.recent_batches(limit=limit)
        return _json_body(200, {"batches": records,
                                "count": len(records)})
    return debug_batches


def _slo_endpoint(slo):
    def debug_slo(rest, query):
        return _json_body(200, slo.report())
    return debug_slo


def _profile_endpoint(profile_dir):
    lock = threading.Lock()

    def debug_profile(rest, query):
        try:
            seconds = min(max(float(query.get("seconds", 1.0)), 0.05), 60.0)
        except ValueError:
            return _json_body(400, {"error": "bad_request",
                                    "detail": "seconds must be a number"})
        if not lock.acquire(blocking=False):
            return _json_body(409, {"error": "profile_in_progress"})
        try:
            import jax
            out = os.path.join(profile_dir,
                               f"ondemand-{int(time.time())}")
            jax.profiler.start_trace(out)
            try:
                time.sleep(seconds)
            finally:
                jax.profiler.stop_trace()
        except Exception as e:                         # noqa: BLE001
            return _json_body(503, {"error": "profiler_unavailable",
                                    "detail": repr(e)})
        finally:
            lock.release()
        return _json_body(200, {"profile_dir": out,
                                "seconds": seconds})
    return debug_profile


def debug_endpoints(recorder=None, slo=None, profile_dir=None) -> dict:
    """The ``/debug/*`` surfaces for whichever components exist."""
    endpoints = {}
    if recorder is not None:
        endpoints["/debug/requests"] = _requests_endpoint(recorder)
        endpoints["/debug/trace/"] = _trace_endpoint(recorder)
        endpoints["/debug/batches"] = _batches_endpoint(recorder)
    if slo is not None:
        endpoints["/debug/slo"] = _slo_endpoint(slo)
    if profile_dir is not None:
        endpoints["/debug/profile"] = _profile_endpoint(profile_dir)
    return endpoints


def build_endpoints(registry, *, ready=None, recorder=None, slo=None,
                    profile_dir=None) -> dict:
    """Registry + debug endpoints in one map (what every server mounts)."""
    endpoints = registry_endpoints(registry, ready=ready)
    endpoints.update(debug_endpoints(recorder=recorder, slo=slo,
                                     profile_dir=profile_dir))
    return endpoints


def dispatch(endpoints: dict, raw_path: str):
    """Route one GET.  Returns ``(status, body, ctype)`` or None for 404.

    Exact path match wins; otherwise the longest registered key ending in
    ``/`` that prefixes the path handles it with ``rest`` set to the
    remainder (that is how ``/debug/trace/<id>`` works).
    """
    parsed = urllib.parse.urlsplit(raw_path)
    path = parsed.path
    query = {k: v[-1] for k, v in
             urllib.parse.parse_qs(parsed.query).items()}
    fn = endpoints.get(path)
    rest = ""
    if fn is None:
        for key in sorted(endpoints, key=len, reverse=True):
            if key.endswith("/") and len(key) > 1 and path.startswith(key):
                fn = endpoints[key]
                rest = path[len(key):]
                break
    if fn is None:
        return None
    try:
        return fn(rest, query)
    except Exception as e:                             # noqa: BLE001
        return _json_body(500, {"error": "internal", "detail": repr(e)})


class MetricsServer:
    """Daemon-thread HTTP server for the observability endpoint map.

    ``ready``/``recorder``/``slo``/``profile_dir`` mount the matching
    surfaces next to ``/metrics`` (see module docstring); all are
    optional — the default server exposes metrics + health only, exactly
    the pre-ISSUE-8 behaviour.
    """

    def __init__(self, registry=None, host: str = "127.0.0.1", port: int = 0,
                 *, ready=None, recorder=None, slo=None, profile_dir=None):
        self.registry = registry if registry is not None else _metrics.get_registry()
        self.host = host
        self.port = int(port)
        self.ready = ready
        self.recorder = recorder
        self.slo = slo
        self.profile_dir = profile_dir
        self._httpd = None
        self._thread = None

    def start(self) -> "MetricsServer":
        endpoints = build_endpoints(
            self.registry, ready=self.ready, recorder=self.recorder,
            slo=self.slo, profile_dir=self.profile_dir)

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 - http.server API
                routed = dispatch(endpoints, self.path)
                if routed is None:
                    self.send_error(404)
                    return
                status, body, ctype = routed
                self.send_response(status)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, fmt, *args):
                pass  # scrapes shouldn't spam the serving process's stderr

        self._httpd = ThreadingHTTPServer((self.host, self.port), Handler)
        self._httpd.daemon_threads = True
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="metrics-http", daemon=True
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._httpd = None
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def __enter__(self):
        if self._httpd is None:
            self.start()
        return self

    def __exit__(self, exc_type, exc, tb):
        self.stop()
        return False
