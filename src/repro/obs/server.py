"""Stdlib HTTP exposition server for a `MetricsRegistry`.

Serves three endpoints from a daemon thread:

- `/metrics` — Prometheus text exposition format 0.0.4;
- `/metrics.json` — the structured registry snapshot as JSON;
- `/healthz` — liveness probe (`ok`).

Bound to loopback by default; pass ``port=0`` to let the OS pick (the
chosen port is published on ``server.port`` after `start()`).
"""

from __future__ import annotations

import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.obs import metrics as _metrics

__all__ = ["MetricsServer", "registry_endpoints"]


def registry_endpoints(registry) -> dict:
    """The standard observability GET endpoints as ``{path: () -> (body,
    content_type)}`` thunks.

    `MetricsServer` serves exactly these; other HTTP front doors (e.g. the
    serving frontend in ``repro.serving.frontend``) mount the same map so
    every server in the system exposes ``/metrics`` identically.
    """
    def metrics():
        return (registry.exposition().encode("utf-8"),
                "text/plain; version=0.0.4; charset=utf-8")

    def metrics_json():
        return registry.to_json().encode("utf-8"), "application/json"

    def healthz():
        return b"ok\n", "text/plain; charset=utf-8"

    return {"/metrics": metrics, "/": metrics,
            "/metrics.json": metrics_json, "/healthz": healthz}


class MetricsServer:
    def __init__(self, registry=None, host: str = "127.0.0.1", port: int = 0):
        self.registry = registry if registry is not None else _metrics.get_registry()
        self.host = host
        self.port = int(port)
        self._httpd = None
        self._thread = None

    def start(self) -> "MetricsServer":
        endpoints = registry_endpoints(self.registry)

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 - http.server API
                endpoint = endpoints.get(self.path)
                if endpoint is None:
                    self.send_error(404)
                    return
                body, ctype = endpoint()
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, fmt, *args):
                pass  # scrapes shouldn't spam the serving process's stderr

        self._httpd = ThreadingHTTPServer((self.host, self.port), Handler)
        self._httpd.daemon_threads = True
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="metrics-http", daemon=True
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._httpd = None
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def __enter__(self):
        if self._httpd is None:
            self.start()
        return self

    def __exit__(self, exc_type, exc, tb):
        self.stop()
        return False
