"""Pull-time gauge collectors wiring live index health into a registry.

`install_engine_gauges(index)` registers a weakref-backed collector that,
on every scrape/snapshot, publishes live-slot counts, free-list depth,
per-shard skew, measured + analytic index bytes, dirty-column count, and
(for durable indexes) WAL/snapshot freshness.  The collector holds only a
weak reference — when the index is garbage collected it returns False and
the registry prunes it, so short-lived test indexes never pin memory or
leak label sets.
"""

from __future__ import annotations

import time
import weakref

from repro.obs import metrics as _metrics

__all__ = ["install_engine_gauges", "install_recorder_gauges"]


def install_engine_gauges(index, registry=None, name: str = "index"):
    """Attach health gauges for `index` (SinnamonIndex, ShardedSinnamonIndex,
    or a durable subclass) to `registry` (default: process-global).  The
    `name` label keeps multiple indexes in one registry distinct."""
    registry = registry if registry is not None else _metrics.get_registry()
    ref = weakref.ref(index)
    labels = {"index": str(name)}

    def _collect():
        ix = ref()
        if ix is None:
            return False
        _publish(registry, ix, labels)
        return True

    registry.add_collector(_collect)
    return _collect


def install_recorder_gauges(recorder, registry=None):
    """Attach pull-time ring-occupancy gauges for a `FlightRecorder`.

    Same weakref-collector pattern as the engine gauges: nothing runs on
    the request path, the scrape reads `recorder.stats()`."""
    registry = registry if registry is not None else _metrics.get_registry()
    ref = weakref.ref(recorder)

    def _collect():
        rec = ref()
        if rec is None:
            return False
        stats = rec.stats()
        registry.gauge(
            "repro_recorder_ring_size",
            "Request traces currently retained in the flight-recorder "
            "ring.").set(stats["ring_size"])
        registry.gauge(
            "repro_recorder_ring_capacity",
            "Flight-recorder ring capacity.").set(stats["capacity"])
        registry.gauge(
            "repro_recorder_tail_threshold_ms",
            "Current tail-retention latency threshold (-1 until enough OK "
            "samples).").set(
            -1.0 if stats["tail_threshold_ms"] is None
            else stats["tail_threshold_ms"])
        return True

    registry.add_collector(_collect)
    return _collect


def _publish(registry, ix, labels):
    import numpy as np

    def gauge(metric, help_text="", **extra):
        return registry.gauge(metric, help_text, labels={**labels, **extra})

    spec = ix.spec
    n_shards = getattr(ix, "n_shards", 1)
    capacity = spec.capacity * n_shards
    gauge("repro_engine_live_docs", "Documents currently live in the index.").set(ix.size)
    gauge("repro_engine_capacity_slots", "Total slot capacity across shards.").set(capacity)

    free = getattr(ix, "_free", None)
    if free is not None:
        if free and isinstance(free[0], list):  # sharded: one free list per shard
            depths = [len(f) for f in free]
            gauge("repro_engine_free_slots", "Free (recyclable) slots.").set(sum(depths))
            live = [spec.capacity - d for d in depths]
            for s, n_live in enumerate(live):
                gauge("repro_engine_shard_live_slots",
                      "Live slots on one shard.", shard=str(s)).set(n_live)
            gauge("repro_engine_shard_skew_slots",
                  "max-min live slots across shards (routing imbalance).",
                  ).set(max(live) - min(live) if live else 0)
        else:
            gauge("repro_engine_free_slots", "Free (recyclable) slots.").set(len(free))

    # Tiered indexes: one TieredVecStore (single-device `.tiered`) or one
    # per corpus shard (sharded `.tiers`); the placeholder state.store is
    # zero-row, so `storage` below reports the device chunk cache instead.
    tiers = ([ix.tiered] if hasattr(ix, "tiered")
             else list(getattr(ix, "tiers", ())))
    if tiers:
        gauge("repro_tier_resident_bytes",
              "Device bytes of raw rows resident in the tier chunk caches.",
              ).set(sum(t.device_bytes() for t in tiers))
        gauge("repro_tier_resident_chunks",
              "Chunks currently resident across all tier caches.",
              ).set(sum(t.resident_chunks() for t in tiers))
        gauge("repro_tier_host_bytes",
              "Host-RAM bytes of the cold raw-row backing store.",
              ).set(sum(t.host_bytes() for t in tiers))

    state = getattr(ix, "state", None)
    if state is not None:
        mem = {
            "sketch": state.u.size * state.u.dtype.itemsize
                      + (0 if state.l is None else state.l.size * state.l.dtype.itemsize),
            "inverted_index": state.bits.size * state.bits.dtype.itemsize,
            "storage": (sum(t.device_bytes() for t in tiers) if tiers else
                        state.store.indices.size * state.store.indices.dtype.itemsize
                        + state.store.values.size * state.store.values.dtype.itemsize),
        }
        for component, nbytes in mem.items():
            gauge("repro_engine_bytes", "Measured device bytes by component.",
                  component=component).set(nbytes)
        gauge("repro_engine_dirty_columns",
              "Sketch columns invalidated by delete-recycle (paper §4.3).",
              ).set(int(np.asarray(state.dirty).sum()))

    try:  # analytic §6.1.2 accounting, comparable across capacity changes
        from repro.eval.tune import spec_index_bytes
        gauge("repro_engine_spec_index_bytes",
              "Analytic sketch+inverted-index bytes from the spec.",
              ).set(spec_index_bytes(spec) * n_shards)
    except ImportError:
        pass

    last_lsn = getattr(ix, "_last_lsn", None)
    if last_lsn is not None:
        gauge("repro_wal_last_lsn", "Highest LSN durably applied.").set(last_lsn)
    snap_ts = getattr(ix, "_last_snapshot_ts", None)
    if snap_ts:
        gauge("repro_snapshot_age_s",
              "Seconds since the last completed snapshot.").set(time.time() - snap_ts)
