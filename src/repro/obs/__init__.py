"""Dependency-free observability: metrics registry, span tracer, JSONL
event log, and a stdlib HTTP exposition server.

Everything in this package is importable without JAX so the hot paths can
instrument themselves unconditionally; the cost of a disabled registry
(`NULL_REGISTRY`) is a no-op method call.  See `docs/observability.md`
for the metric catalog.
"""

from repro.obs.events import EventLog, emit, get_event_log, set_event_log
from repro.obs.metrics import (
    NULL_REGISTRY,
    Buckets,
    Counter,
    Gauge,
    Histogram,
    LabelCardinalityError,
    MetricsRegistry,
    get_registry,
    merge_snapshots,
    parse_exposition,
    set_registry,
)
from repro.obs.server import MetricsServer
from repro.obs.trace import Span, Trace

__all__ = [
    "Buckets",
    "Counter",
    "EventLog",
    "Gauge",
    "Histogram",
    "LabelCardinalityError",
    "MetricsRegistry",
    "MetricsServer",
    "NULL_REGISTRY",
    "Span",
    "Trace",
    "emit",
    "get_event_log",
    "get_registry",
    "merge_snapshots",
    "parse_exposition",
    "set_event_log",
    "set_registry",
]
