"""Dependency-free observability: metrics registry, span tracer, request
trace contexts, tail-sampled flight recorder, SLO monitor, JSONL event
log, and a stdlib HTTP exposition/debug server.

Everything in this package is importable without JAX so the hot paths can
instrument themselves unconditionally; the cost of a disabled registry
(`NULL_REGISTRY`) is a no-op method call.  See `docs/observability.md`
for the metric catalog and trace-context model.
"""

from repro.obs.events import (
    EventLog,
    emit,
    get_event_log,
    read_events,
    set_event_log,
)
from repro.obs.metrics import (
    NULL_REGISTRY,
    Buckets,
    Counter,
    Gauge,
    Histogram,
    LabelCardinalityError,
    MetricsRegistry,
    get_registry,
    merge_snapshots,
    parse_exposition,
    set_registry,
)
from repro.obs.recorder import (
    FlightRecorder,
    get_recorder,
    new_batch_id,
    set_recorder,
)
from repro.obs.server import MetricsServer, ReadyState
from repro.obs.slo import SLOMonitor, SLOSpec
from repro.obs.trace import Span, Trace, TraceContext, new_trace_id

__all__ = [
    "Buckets",
    "Counter",
    "EventLog",
    "FlightRecorder",
    "Gauge",
    "Histogram",
    "LabelCardinalityError",
    "MetricsRegistry",
    "MetricsServer",
    "NULL_REGISTRY",
    "ReadyState",
    "SLOMonitor",
    "SLOSpec",
    "Span",
    "Trace",
    "TraceContext",
    "emit",
    "get_event_log",
    "get_recorder",
    "get_registry",
    "merge_snapshots",
    "new_batch_id",
    "new_trace_id",
    "parse_exposition",
    "read_events",
    "set_event_log",
    "set_recorder",
    "set_registry",
]
