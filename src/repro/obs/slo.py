"""SLO monitor: declared latency/availability objectives with multi-window
burn-rate computation from the existing registry histograms.

An `SLOSpec` declares the objectives (launcher flags spell them); an
`SLOMonitor` periodically snapshots the cumulative good/total counts the
registry already tracks and derives, per objective and per window
(fast 5 m / slow 1 h by default):

* **compliance** — fraction of requests that met the objective over the
  window;
* **burn rate** — ``(1 - compliance) / (1 - target)``: how many times
  faster than budget the error budget is being spent (1.0 = exactly on
  budget; >1 = burning).

Both surface as ``repro_slo_*`` gauges, as the ``/debug/slo`` endpoint
(`report()`), and as ``slo_burn`` WARN events when the fast window burns
hot while the slow window confirms it is sustained (the classic
multi-window alert shape: the fast window catches the spike, the slow
window suppresses blips).

Counts come from histograms/counters that already exist, so the monitor
adds zero cost to the request path:

* latency: good = samples ≤ the objective bound, read from the cumulative
  bucket counts of ``repro_frontend_latency_ms`` (preferred) or
  ``repro_query_latency_ms`` (when no front door is running).  The bound
  snaps UP to the nearest bucket boundary (≤ one bucket width, ±~9% with
  the default layout) — documented, deterministic, and free.
* availability: good = ``outcome="ok"`` from
  ``repro_frontend_requests_total``; without a front door every counted
  query was served, so availability reads 1.0.
"""

from __future__ import annotations

import bisect
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Optional

from repro.obs import events as _events
from repro.obs import metrics as _metrics

__all__ = ["SLOMonitor", "SLOSpec"]

#: (family, good-outcome predicate input) preference order for latency.
_LATENCY_FAMILIES = ("repro_frontend_latency_ms", "repro_query_latency_ms")
_REQUESTS_FAMILY = "repro_frontend_requests_total"
_QUERIES_FAMILY = "repro_queries_total"


@dataclass(frozen=True)
class SLOSpec:
    """Declared serving objectives.

    ``latency_target`` of requests must complete within ``latency_ms``;
    ``availability_target`` of requests must not be rejected / expired /
    errored.  Targets are fractions in (0, 1).
    """

    latency_ms: float = 100.0
    latency_target: float = 0.99
    availability_target: float = 0.999

    def __post_init__(self):
        if self.latency_ms <= 0:
            raise ValueError(f"latency_ms must be > 0, got {self.latency_ms}")
        for name in ("latency_target", "availability_target"):
            v = getattr(self, name)
            if not (0.0 < v < 1.0):
                raise ValueError(f"{name} must be in (0, 1), got {v}")


def _family_counts_latency(snapshot: dict, bound_ms: float):
    """(good, total, effective_bound) from the first latency family with
    samples; good = cumulative count at the first bucket bound >= bound_ms
    (all series of the family summed — tenants/backends together)."""
    for family in _LATENCY_FAMILIES:
        fam = snapshot.get(family)
        if not fam or fam.get("type") != "histogram":
            continue
        good = total = 0
        eff = bound_ms
        for s in fam["series"]:
            spec = s["buckets"]
            bounds = [spec["start"] * spec["factor"] ** i
                      for i in range(spec["count"])]
            i = bisect.bisect_left(bounds, bound_ms)
            if i >= len(bounds):          # objective beyond the layout
                good += s["count"]
                eff = float("inf")
            else:
                good += sum(s["counts"][:i + 1])
                eff = bounds[i]
            total += s["count"]
        if total:
            return good, total, eff
    return 0, 0, bound_ms


def _family_counts_availability(snapshot: dict):
    """(good, total) request outcomes; falls back to the query counter
    (every counted query was served) when no front door reports."""
    fam = snapshot.get(_REQUESTS_FAMILY)
    if fam and fam["series"]:
        good = total = 0
        for s in fam["series"]:
            n = s["value"]
            total += n
            if s["labels"].get("outcome") == "ok":
                good += n
        return good, total
    fam = snapshot.get(_QUERIES_FAMILY)
    if fam and fam["series"]:
        n = sum(s["value"] for s in fam["series"])
        return n, n
    return 0, 0


class _Window:
    __slots__ = ("name", "seconds")

    def __init__(self, name: str, seconds: float):
        self.name = name
        self.seconds = float(seconds)


class SLOMonitor:
    """Multi-window burn-rate monitor over a metrics registry.

    ``tick()`` takes one sample (timestamp + cumulative good/total per
    objective) and publishes gauges; ``start(interval_s)`` runs it on a
    daemon thread.  ``report()`` is the ``/debug/slo`` payload.

    ``burn_warn`` (default 10) emits one ``slo_burn`` WARN event per
    breach episode when the fast-window burn exceeds it AND the
    slow-window burn exceeds 1 (sustained, not a blip).
    """

    def __init__(self, spec: SLOSpec, registry=None, *,
                 fast_window_s: float = 300.0,
                 slow_window_s: float = 3600.0,
                 burn_warn: float = 10.0,
                 event_log=None, clock=time.time):
        if fast_window_s <= 0 or slow_window_s < fast_window_s:
            raise ValueError("need 0 < fast_window_s <= slow_window_s")
        self.spec = spec
        self.registry = registry if registry is not None \
            else _metrics.get_registry()
        self.windows = (_Window("fast", fast_window_s),
                        _Window("slow", slow_window_s))
        self.burn_warn = float(burn_warn)
        self._event_log = event_log
        self._clock = clock
        self._samples: deque = deque()   # (t, {slo: (good, total)})
        self._lock = threading.Lock()
        self._burning = False            # edge-triggered WARN
        self._thread = None
        self._stop = threading.Event()
        self.ticks = 0
        self.last_stats: Optional[dict] = None   # most recent tick() output

    # -- sampling ------------------------------------------------------------
    def _read(self):
        snap = self.registry.snapshot()
        lat_good, lat_total, eff = _family_counts_latency(
            snap, self.spec.latency_ms)
        av_good, av_total = _family_counts_availability(snap)
        return {"latency": (lat_good, lat_total),
                "availability": (av_good, av_total)}, eff

    def tick(self, now: Optional[float] = None) -> dict:
        """Take one sample, publish gauges, emit WARN on sustained burn.
        Returns the per-objective window stats (the `report()` core)."""
        now = self._clock() if now is None else float(now)
        counts, eff_bound = self._read()
        horizon = self.windows[-1].seconds * 1.25
        with self._lock:
            self._samples.append((now, counts))
            while self._samples and now - self._samples[0][0] > horizon \
                    and len(self._samples) > 1:
                self._samples.popleft()
            samples = list(self._samples)
            self.ticks += 1

        targets = {"latency": self.spec.latency_target,
                   "availability": self.spec.availability_target}
        out: dict = {}
        for slo, target in targets.items():
            budget = 1.0 - target
            out[slo] = {"target": target, "windows": {}}
            for win in self.windows:
                base = self._window_base(samples, now, win.seconds)
                good = counts[slo][0] - base[slo][0]
                total = counts[slo][1] - base[slo][1]
                compliance = 1.0 if total <= 0 else good / total
                burn = (1.0 - compliance) / budget
                out[slo]["windows"][win.name] = {
                    "window_s": win.seconds,
                    "good": good, "total": total,
                    "compliance": round(compliance, 6),
                    "burn_rate": round(burn, 4),
                }
                self.registry.gauge(
                    "repro_slo_burn_rate",
                    "Error-budget burn rate over the window "
                    "(1.0 = spending exactly the budget).",
                    labels={"slo": slo, "window": win.name}).set(burn)
                self.registry.gauge(
                    "repro_slo_compliance_ratio",
                    "Fraction of requests meeting the objective over the "
                    "window.",
                    labels={"slo": slo, "window": win.name}).set(compliance)
            self.registry.gauge(
                "repro_slo_objective_ratio",
                "Declared SLO target fraction.",
                labels={"slo": slo}).set(target)
        self.registry.gauge(
            "repro_slo_latency_bound_ms",
            "Latency objective after snapping up to the nearest histogram "
            "bucket boundary.").set(
            -1.0 if eff_bound == float("inf") else eff_bound)
        out["latency"]["bound_ms"] = \
            None if eff_bound == float("inf") else round(eff_bound, 6)
        self._maybe_warn(out)
        self.last_stats = out
        return out

    def fast_burn(self) -> float:
        """Worst fast-window burn rate across objectives at the last tick
        (0.0 before any tick).  This is the degradation ladder's pressure
        signal — a cheap read, no fresh scrape."""
        stats = self.last_stats
        if not stats:
            return 0.0
        return max(stats[slo]["windows"]["fast"]["burn_rate"]
                   for slo in ("latency", "availability"))

    @staticmethod
    def _window_base(samples, now, window_s):
        """Earliest sample inside the window (the subtraction base); falls
        back to the oldest sample when the ring is younger than the
        window."""
        base = samples[0][1]
        for t, counts in samples:
            if now - t <= window_s:
                base = counts
                break
        return base

    def _maybe_warn(self, out: dict) -> None:
        hot = any(
            o["windows"]["fast"]["burn_rate"] > self.burn_warn
            and o["windows"]["slow"]["burn_rate"] > 1.0
            for o in (out["latency"], out["availability"]))
        if hot and not self._burning:
            log = self._event_log if self._event_log is not None \
                else _events.get_event_log()
            if log is not None:
                log.emit(
                    "slo_burn", level="WARN",
                    burn_warn=self.burn_warn,
                    latency=out["latency"]["windows"],
                    availability=out["availability"]["windows"])
        self._burning = hot

    # -- surfaces ------------------------------------------------------------
    def report(self) -> dict:
        """The ``/debug/slo`` payload: objectives + live window stats."""
        stats = self.tick()
        return {
            "objectives": {
                "latency_ms": self.spec.latency_ms,
                "latency_target": self.spec.latency_target,
                "availability_target": self.spec.availability_target,
            },
            "windows": {w.name: w.seconds for w in self.windows},
            "burn_warn": self.burn_warn,
            "ticks": self.ticks,
            "slos": stats,
        }

    # -- background loop -----------------------------------------------------
    def start(self, interval_s: float = 5.0) -> "SLOMonitor":
        """Tick on a daemon thread every ``interval_s`` until `stop()`."""
        if self._thread is not None:
            return self
        self._stop.clear()

        def loop():
            while not self._stop.wait(interval_s):
                try:
                    self.tick()
                except Exception:               # noqa: BLE001
                    pass    # a failed scrape must never kill the monitor

        self._thread = threading.Thread(target=loop, name="slo-monitor",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=5)
        self._thread = None
