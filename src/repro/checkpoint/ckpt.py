"""Checkpoint/restart with elastic resharding.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json (+ .tmp staging, atomic
rename — a preempted save never corrupts the latest checkpoint).  Arrays are
stored *unsharded* (gathered) with their full global shapes, so a restore can
re-shard onto **any** mesh — that is the elastic-scaling path: train on
(2,16,16), restart on (16,16), or grow the retrieval corpus shards.

For true multi-host deployments each host would write its own addressable
shards; the manifest format (named leaves + shapes + dtypes) is already
host-count-agnostic, and `restore(..., shardings=...)` does the placement.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

from repro.fault import failpoints as _fp

# numpy's npz cannot represent ml_dtypes (bfloat16, fp8): store such arrays
# as raw uint views and record the true dtype in the manifest.
_EXOTIC = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
}


def _fsync(path: str) -> None:
    """Durably persist a file's contents or a directory's entries.

    Callers that delete their redundancy once a checkpoint exists (the
    retrieval WAL is pruned against snapshots) need the publish itself to
    survive a power cut, not just a process crash.
    """
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _flatten(tree) -> dict:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.name in _EXOTIC:
            arr = arr.view(_EXOTIC[arr.dtype.name][1])
        out[key] = arr
    return out


def save(ckpt_dir: str, step: int, tree, keep: int = 3,
         extra: Optional[dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    adopt_strays(ckpt_dir)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = _flatten(tree)
    # Failpoint sites model a save dying at each distinct hazard: while
    # writing array bytes, while making them durable, and at the publish
    # rename.  All three strand only .tmp/.old debris that the next
    # save/adopt_strays clears — never the published step.
    _fp.fire("snapshot.write")
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    true_dtypes = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        true_dtypes[key] = str(jax.numpy.asarray(leaf).dtype) \
            if hasattr(leaf, "dtype") else "float32"
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(v.shape),
                       "dtype": true_dtypes.get(k, str(v.dtype))}
                   for k, v in arrays.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    _fp.fire("snapshot.fsync")
    for name in ("arrays.npz", "manifest.json"):
        _fsync(os.path.join(tmp, name))
    _fsync(tmp)
    _fp.fire("snapshot.rename")
    if os.path.exists(final):
        # Never delete the published step before its replacement is in
        # place: rename it aside, publish, then drop the old copy — so the
        # window in which no valid copy exists shrinks from a full rmtree
        # to the instant between two renames.
        old = final + ".old"
        if os.path.exists(old):
            shutil.rmtree(old)
        os.rename(final, old)
        os.rename(tmp, final)                   # atomic publish
        shutil.rmtree(old)
    else:
        os.rename(tmp, final)                   # atomic publish
    _fsync(ckpt_dir)      # persist the rename: the publish must survive a
    _gc(ckpt_dir, keep)   # power cut, not just a process crash
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"),
                      ignore_errors=True)


def adopt_strays(ckpt_dir: str) -> None:
    """Recover from a save() that crashed between its two swap renames.

    Such a crash strands the previously published (complete, valid) copy at
    ``step_<N>.old`` with ``step_<N>`` gone: promote it back so the step
    stays recoverable.  With ``step_<N>`` present the ``.old`` copy is
    superseded leftovers and is removed.  Only the directory's writer (a
    fresh save, or recovery before any reads) may call this — a reader
    doing it would race a concurrent save's swap.
    """
    if not os.path.isdir(ckpt_dir):
        return
    for name in os.listdir(ckpt_dir):
        if (name.startswith("step_") and name.endswith(".old")
                and name[5:-4].isdigit()):
            stray = os.path.join(ckpt_dir, name)
            final = os.path.join(ckpt_dir, name[:-4])
            try:
                if os.path.exists(final):
                    shutil.rmtree(stray)
                else:
                    os.rename(stray, final)
            except OSError:
                pass                           # read-only fs etc.


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        # digits-only filter also skips in-flight .tmp / .old dirs
        if name.startswith("step_") and name[5:].isdigit():
            out.append(int(name[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def read_manifest(ckpt_dir: str, step: Optional[int] = None):
    """Peek at a checkpoint's manifest without materialising arrays.

    Lets a caller that stores its reconstruction recipe in ``extra`` (e.g.
    the retrieval-index snapshots: engine spec, id↔slot maps, WAL position)
    build the restore template *before* calling :func:`restore`.
    Returns (manifest dict, step).
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(d, "manifest.json")) as f:
        return json.load(f), step


def restore(ckpt_dir: str, tree_template, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``tree_template``.

    shardings: optional matching pytree of NamedSharding — arrays are placed
    (and thereby re-sharded) onto the current mesh; None = host arrays.
    Returns (tree, step, extra).
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_template)
    leaves = []
    shard_flat = (None if shardings is None
                  else jax.tree_util.tree_flatten(shardings)[0])
    for i, (path, leaf) in enumerate(flat):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = data[key]
        true_dt = manifest["leaves"].get(key, {}).get("dtype", "")
        if true_dt in _EXOTIC:
            arr = arr.view(_EXOTIC[true_dt][0])
        want = tuple(leaf.shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"{key}: checkpoint {arr.shape} != model {want}")
        if shard_flat is not None:
            leaves.append(jax.device_put(arr, shard_flat[i]))
        else:
            leaves.append(arr)
    return (jax.tree_util.tree_unflatten(treedef, leaves), step,
            manifest.get("extra", {}))
