"""Generic training loop substrate: train-step builder (grad + clip + AdamW),
microbatch gradient accumulation (overlaps the previous microbatch's
reduction with compute under XLA latency hiding), and optional cross-pod
int8 error-feedback gradient compression.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.optim import adamw, compress


class TrainState(NamedTuple):
    params: Any
    opt: adamw.OptState
    ef_residual: Any = None    # error-feedback state (grad compression)


def init_state(params, use_compression: bool = False) -> TrainState:
    res = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
           if use_compression else None)
    return TrainState(params=params, opt=adamw.init(params), ef_residual=res)


def make_train_step(
    loss_fn: Callable,                 # (params, batch) -> (loss, metrics)
    opt_cfg: adamw.AdamWConfig,
    *,
    microbatches: int = 1,
    compress_axis: Optional[str] = None,   # e.g. 'pod' inside shard_map
):
    """Build ``train_step(state, batch) -> (state, metrics)``."""

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def accumulate(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads
        split = jax.tree.map(
            lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                + x.shape[1:]), batch)

        def mb(carry, b):
            acc, loss_acc = carry
            (loss, _), grads = grad_fn(params, b)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                               acc, grads)
            return (acc, loss_acc + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (grads, loss), _ = jax.lax.scan(mb, (zeros, 0.0), split)
        grads = jax.tree.map(lambda g: g / microbatches, grads)
        return loss / microbatches, {}, grads

    def train_step(state: TrainState, batch):
        loss, metrics, grads = accumulate(state.params, batch)
        residual = state.ef_residual
        if compress_axis is not None and residual is not None:
            grads, residual = compress.compressed_psum(
                grads, residual, compress_axis)
        params, opt, opt_metrics = adamw.update(
            grads, state.opt, state.params, opt_cfg)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return TrainState(params, opt, residual), metrics

    return train_step
