"""Numerics for the paper's error analysis (Section 5).

Implements, for arbitrary value distributions given as (pdf, cdf) callables:

  * Theorem 5.2 / Eq. (6)  — probability the upper-bound sketch overestimates
  * Corollary 5.3 / Eq. (12) — Gaussian closed form for that probability
  * Theorem 5.4 / Eq. (13) — CDF of the overestimation error Z̄
  * Lemma 5.5 / Eq. (16)  — expected overestimation error
  * Corollary 5.6 / Eq. (17) — Gaussian closed-form error CDF
  * Lemma 5.7 / Eq. (18)  — sketch-size sizing rule m(δ, ε, h)
  * Theorem 5.8 / Eq. (19) — the standardised inner-product error Z
    (construction of the statistic; normality is validated empirically in
    benchmarks/fig5_z_normality.py)

All integrals are trapezoid quadrature on numpy grids; these functions are the
oracles that tests and benchmarks compare Monte-Carlo measurements against
(paper Tables 1–2, Figures 4–5, 7).
"""

from __future__ import annotations

import math
from typing import Callable, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Distributions (paper Table 1 rows)
# ---------------------------------------------------------------------------

def uniform_dist(lo: float = -1.0, hi: float = 1.0):
    pdf = lambda a: np.where((a >= lo) & (a <= hi), 1.0 / (hi - lo), 0.0)
    cdf = lambda a: np.clip((a - lo) / (hi - lo), 0.0, 1.0)
    grid = np.linspace(lo, hi, 4001)
    return pdf, cdf, grid


def gaussian_dist(mu: float = 0.0, sigma: float = 1.0):
    pdf = lambda a: np.exp(-0.5 * ((a - mu) / sigma) ** 2) / (
        sigma * math.sqrt(2 * math.pi))
    cdf = lambda a: 0.5 * (1 + _erf((a - mu) / (sigma * math.sqrt(2))))
    grid = np.linspace(mu - 8 * sigma, mu + 8 * sigma, 4001)
    return pdf, cdf, grid


def lognormal_dist(sigma: float = 1.0, mu: float = 0.0):
    """Log-normal non-negative values — the text-like collections' value law
    (paper Table 3 / Fig. 6(a); what ``repro.data.synth``'s *_like datasets
    draw).  Lets the generic Eq. (6)/(13) quadratures bound the sketch
    overestimate on SPLADE/BM25-shaped corpora, not just the Table 1 rows.
    """
    s2 = sigma * math.sqrt(2)

    def pdf(a):
        a = np.asarray(a, np.float64)
        safe = np.maximum(a, 1e-300)
        return np.where(a > 0,
                        np.exp(-0.5 * ((np.log(safe) - mu) / sigma) ** 2)
                        / (safe * sigma * math.sqrt(2 * math.pi)), 0.0)

    def cdf(a):
        a = np.asarray(a, np.float64)
        safe = np.maximum(a, 1e-300)
        return np.where(a > 0, 0.5 * (1 + _erf((np.log(safe) - mu) / s2)),
                        0.0)

    grid = np.linspace(0.0, math.exp(mu + 8 * sigma), 8001)
    return pdf, cdf, grid


def zeta_dist(s: float, support_lo: float = -1.0, support_hi: float = 1.0,
              levels: int = 2 ** 10):
    """Paper Table 1: Zeta(s) over [-1, 1] quantised into 2^10 discrete values.

    Probability mass ∝ rank^{-s} assigned to levels spanning the interval,
    largest mass on the smallest |value| ranks — returned as a discrete
    (values, pmf) pair wrapped into pdf/cdf callables via step functions.
    """
    ranks = np.arange(1, levels + 1, dtype=np.float64)
    pmf = ranks ** (-s)
    pmf /= pmf.sum()
    values = np.linspace(support_lo, support_hi, levels)
    order = np.argsort(values)
    v_sorted = values[order]
    p_sorted = pmf[order]
    cum = np.cumsum(p_sorted)

    def cdf(a):
        a = np.asarray(a, np.float64)
        pos = np.searchsorted(v_sorted, a, side="right")
        return np.where(pos == 0, 0.0, cum[np.clip(pos - 1, 0, levels - 1)])

    # "pdf" as discrete pmf lookup on the grid (used only via the grid below).
    def pdf(a):
        a = np.asarray(a, np.float64)
        pos = np.clip(np.searchsorted(v_sorted, a), 0, levels - 1)
        spacing = v_sorted[1] - v_sorted[0]
        return p_sorted[pos] / spacing

    return pdf, cdf, v_sorted


def _erf(x):
    return np.vectorize(math.erf)(x)


# ---------------------------------------------------------------------------
# Theorem 5.2 — probability of overestimation
# ---------------------------------------------------------------------------

def prob_overestimate(pdf: Callable, cdf: Callable, grid: np.ndarray,
                      sum_p: float, m: int, h: int) -> float:
    """Eq. (6): P[X̄_i > X_i] ≈ ∫ [1 - e^{-(h/m)(1-Φ(α)) Σp}]^h φ(α) dα."""
    a = grid
    inner = (1.0 - np.exp(-(h / m) * (1.0 - cdf(a)) * sum_p)) ** h
    return float(np.trapezoid(inner * pdf(a), a))


def prob_overestimate_gaussian_closed(m: int, h: int, n: int, p: float) -> float:
    """Eq. (12): closed form for standard-Gaussian values."""
    beta = (n - 1) * p / m
    total = 1.0
    for k in range(1, h + 1):
        total += (math.comb(h, k) * (-1.0) ** k
                  * (1.0 / (k * h * beta))
                  * (1.0 - math.exp(-k * h * beta)))
    return total


# ---------------------------------------------------------------------------
# Theorem 5.4 / Lemma 5.5 — error CDF and expectation
# ---------------------------------------------------------------------------

def error_cdf(delta, pdf, cdf, grid, sum_p: float, m: int, h: int):
    """Eq. (13): P[Z̄ ≤ δ | active] ≈ 1 - ∫ [1 - e^{-(h/m)(1-Φ(α+δ))Σp}]^h φ dα."""
    delta = np.atleast_1d(np.asarray(delta, np.float64))
    a = grid[None, :]
    d = delta[:, None]
    inner = (1.0 - np.exp(-(h / m) * (1.0 - cdf(a + d)) * sum_p)) ** h
    out = 1.0 - np.trapezoid(inner * pdf(a), grid, axis=-1)
    return out if out.size > 1 else float(out[0])


def expected_error(pdf, cdf, grid, sum_p: float, m: int, h: int,
                   delta_max: float = None, n_delta: int = 600) -> float:
    """Eq. (16): E[Z̄ | active] = ∫_0^∞ P[Z̄ ≥ δ] dδ (truncated quadrature)."""
    if delta_max is None:
        delta_max = float(grid[-1] - grid[0])
    deltas = np.linspace(0.0, delta_max, n_delta)
    tail = 1.0 - np.asarray(error_cdf(deltas, pdf, cdf, grid, sum_p, m, h))
    return float(np.trapezoid(tail, deltas))


def error_cdf_gaussian_closed(delta, sigma: float, m: int, h: int,
                              n: int, p: float):
    """Eq. (17): closed-form CDF for Gaussian(0, σ) values.

    Φ' is the CDF of a zero-mean Gaussian with std σ√2 (difference of two
    coordinate values).
    """
    delta = np.asarray(delta, np.float64)
    phi2 = 0.5 * (1 + _erf(delta / (sigma * math.sqrt(2) * math.sqrt(2))))
    return 1.0 - (1.0 - np.exp(-(h * (n - 1) * p / m) * (1.0 - phi2))) ** h


def required_m(delta: float, eps: float, h: int, n: int, p: float,
               sigma: float) -> float:
    """Lemma 5.7 / Eq. (18): sketch size m for P[Z̄ > δ] < ε."""
    phi2 = 0.5 * (1 + math.erf(delta / (sigma * 2.0)))
    return -h * (n - 1) * p * (1.0 - phi2) / math.log(1.0 - eps ** (1.0 / h))


# ---------------------------------------------------------------------------
# Theorem 5.8 — the standardised inner-product error statistic Z
# ---------------------------------------------------------------------------

def z_statistic(ip_err: np.ndarray, q_vals: np.ndarray, p_active: float,
                mu_active: float, var_uncond: float) -> np.ndarray:
    """Eq. (19) with homogeneous coordinate statistics.

    ip_err: observed ⟨q, x̃ - x⟩ per query-document pair.
    q_vals: [ψ_q] the query's non-zero entries.
    mu_active: E[Z_i | active] (from :func:`expected_error`).
    var_uncond: Var[Z_i] of the unconditional error (mixture of 0 w.p. 1-p
    and the active error w.p. p) — see :func:`unconditional_moments`.
    """
    shift = p_active * mu_active * float(np.sum(q_vals))
    scale = math.sqrt(var_uncond * float(np.sum(q_vals ** 2))) + 1e-30
    return (ip_err - shift) / scale


def unconditional_moments(p_active: float, mu_active: float,
                          var_active: float) -> Tuple[float, float]:
    """§5.2 closing remark: E[Z̄]=pμ, Var(Z̄)=pσ² + p(1-p)μ²."""
    mean = p_active * mu_active
    var = p_active * var_active + p_active * (1 - p_active) * mu_active ** 2
    return mean, var
