"""WAND baseline (Broder et al. 2003), modified for real-valued vectors
(paper §6.1.4).

Document-at-a-time traversal with per-list score upper bounds and pivot-based
skipping.  Generalisation to real values: for list j the partial-score upper
bound is ``max(q[j]·max_val_j, q[j]·min_val_j)`` — exact for non-negative data
and still a valid bound for signed data.

This is intentionally host-side NumPy/Python: pointer-chasing DAAT traversal
has no TPU-idiomatic equivalent (irregular, data-dependent skipping), which is
itself one of the paper's findings (§6.3: WAND loses to regular scans once the
Zipfian/short-query assumptions break).  Recorded in DESIGN.md §6.  It exists
to reproduce the paper's comparison tables, not as a production path.
"""

from __future__ import annotations

import heapq
from typing import Tuple

import numpy as np


class WandIndex:
    def __init__(self, n: int):
        self.n = n
        self._lists: dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        self._doc_idx: dict[int, np.ndarray] = {}
        self._doc_val: dict[int, np.ndarray] = {}

    def build(self, ids, idx_batch, val_batch) -> None:
        per_coord: dict[int, list] = {}
        for d, idx, val in zip(ids, idx_batch, val_batch):
            idx = np.asarray(idx); val = np.asarray(val, np.float32)
            keep = idx >= 0
            idx, val = idx[keep], val[keep]
            self._doc_idx[int(d)] = idx
            self._doc_val[int(d)] = val
            for j, v in zip(idx, val):
                per_coord.setdefault(int(j), []).append((int(d), float(v)))
        for j, postings in per_coord.items():
            postings.sort()
            docs = np.array([p[0] for p in postings], np.int64)
            vals = np.array([p[1] for p in postings], np.float32)
            self._lists[j] = (docs, vals)

    def exact_score(self, doc: int, q_idx, q_val) -> float:
        qd = dict(zip(np.asarray(q_idx).tolist(),
                      np.asarray(q_val, np.float32).tolist()))
        i, v = self._doc_idx[doc], self._doc_val[doc]
        return float(sum(qd.get(int(j), 0.0) * float(x) for j, x in zip(i, v)))

    def search(self, q_idx, q_val, k: int):
        """Classic WAND with a growing heap threshold θ."""
        q_idx = np.asarray(q_idx); q_val = np.asarray(q_val, np.float32)
        keep = (q_idx >= 0) & (q_val != 0)
        q_idx, q_val = q_idx[keep], q_val[keep]

        cursors = []   # per query term: [list_docs, list_vals, pos, ub, qv]
        for j, qv in zip(q_idx, q_val):
            if int(j) not in self._lists:
                continue
            docs, vals = self._lists[int(j)]
            ub = max(qv * float(vals.max()), qv * float(vals.min()))
            cursors.append([docs, vals, 0, ub, float(qv)])
        heap: list[Tuple[float, int]] = []   # (score, doc) min-heap
        theta = -np.inf

        def current_doc(c):
            return c[0][c[2]] if c[2] < len(c[0]) else np.iinfo(np.int64).max

        while True:
            cursors = [c for c in cursors if c[2] < len(c[0])]
            if not cursors:
                break
            cursors.sort(key=current_doc)
            # Real-valued generalisation: a document in any SUBSET of the
            # prefix lists is bounded by Σ max(UB_i, 0) — clamping keeps the
            # pruning sound when per-list bounds can be negative.
            acc, pivot = 0.0, -1
            for i, c in enumerate(cursors):
                acc += max(c[3], 0.0)
                if acc > theta or len(heap) < k:
                    pivot = i
                    break
            if pivot < 0:
                break
            pivot_doc = int(current_doc(cursors[pivot]))
            if int(current_doc(cursors[0])) == pivot_doc:
                # fully evaluate pivot_doc
                s = 0.0
                for c in cursors:
                    if int(current_doc(c)) == pivot_doc:
                        s += c[4] * float(c[1][c[2]])
                        c[2] += 1
                if len(heap) < k:
                    heapq.heappush(heap, (s, pivot_doc))
                elif s > heap[0][0]:
                    heapq.heapreplace(heap, (s, pivot_doc))
                if len(heap) == k:
                    theta = heap[0][0]
            else:
                # skip all cursors before the pivot up to pivot_doc
                for c in cursors[:pivot]:
                    c[2] += int(np.searchsorted(c[0][c[2]:], pivot_doc))
        out = sorted(heap, key=lambda t: -t[0])
        ids = np.array([d for _, d in out], np.int64)
        scores = np.array([s for s, _ in out], np.float32)
        return ids, scores

    def memory_bytes(self) -> int:
        return int(sum(d.nbytes + v.nbytes for d, v in self._lists.values()))
