"""Bit-packed streaming inverted index (paper §4.1's id-only inverted index).

The paper keeps, per coordinate ``j``, a Roaring bitmap of document ids whose
``j``-th coordinate is active.  The TPU-native equivalent is a fixed-capacity
**bit matrix** ``B ∈ uint32[n, C/32]`` over document *slots*:

    bit(j, s) = 1  ⇔  coordinate j is active in the vector stored at slot s.

Same set semantics, but fixed-shape (jittable / shardable), O(1) insert and
delete (bit set/clear — the paper's headline deletion cost), and rows unpack
lane-wise inside the scoring kernel.  Capacity is a config knob; growth is a
host-side reallocation (`repro.core.engine.SinnamonIndex.grow`).

Bit order: slot ``s`` lives at word ``s // 32``, bit ``s % 32`` (LSB-first).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

WORD = 32


def n_words(capacity: int) -> int:
    if capacity % WORD != 0:
        raise ValueError(f"capacity {capacity} must be a multiple of {WORD}")
    return capacity // WORD


def empty(n: int, capacity: int) -> Array:
    return jnp.zeros((n, n_words(capacity)), dtype=jnp.uint32)


def set_doc(bits: Array, idx: Array, slot, on: bool) -> Array:
    """Set (on=True) or clear the membership bits of one document.

    idx: int32[P] active coordinates (or hashed bucket rows), padded with -1.
    Padded entries are routed OUT OF BOUNDS and dropped by the scatter —
    routing them to row 0 would race with a genuine row-0 update (scatter
    duplicate-index write order is undefined).  Duplicate *valid* rows
    (bucket collisions within one doc) all write the identical value (same
    slot ⇒ same word and mask), so they cannot conflict.
    """
    valid = idx >= 0
    oob = jnp.int32(bits.shape[0])
    safe = jnp.where(valid, idx, oob)
    word = slot // WORD
    mask = (jnp.uint32(1) << jnp.uint32(slot % WORD))
    rows = bits[jnp.where(valid, idx, 0), word]              # [P]
    if on:
        new = rows | mask
    else:
        new = rows & ~mask
    return bits.at[safe, word].set(new, mode="drop")


def test_bit(bits: Array, j, slot) -> Array:
    word = slot // WORD
    return (bits[j, word] >> jnp.uint32(slot % WORD)) & jnp.uint32(1)


def unpack_row(row: Array) -> Array:
    """uint32[..., W] -> bool[..., W*32] membership mask (LSB-first)."""
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bitsets = (row[..., :, None] >> shifts) & jnp.uint32(1)  # [..., W, 32]
    return bitsets.reshape(*row.shape[:-1], row.shape[-1] * WORD).astype(jnp.bool_)


def row_mask(bits: Array, j) -> Array:
    """Membership mask of coordinate j over all slots: bool[C]."""
    return unpack_row(bits[j])


def popcounts(bits: Array) -> Array:
    """Postings-list length per coordinate: int32[n] (index statistics)."""
    return jax.lax.population_count(bits).sum(axis=-1).astype(jnp.int32)
