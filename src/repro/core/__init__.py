"""Core contribution of the paper: Sinnamon sketch + bit-packed streaming
inverted index + approximate/exact SMIPS engines (Sinnamon, LinScan, WAND)
and the paper's error theory (Section 5) as numerics."""

from repro.core.sketch import SketchSpec, make_mappings, encode, encode_batch
from repro.core import bitindex
from repro.core.engine import EngineSpec, SinnamonState, SinnamonIndex
