"""The Sinnamon sketch (paper §4.1, Algorithm 5).

A sparse vector ``x ∈ R^n`` with active set ``nz(x)`` is compressed into an
upper-bound sketch ``u ∈ R^m`` and a lower-bound sketch ``l ∈ R^m`` using ``h``
independent random mappings ``π_o : [n] → [m]``:

    u[k] = max { x[j] : j ∈ nz(x), ∃o π_o(j) = k }
    l[k] = min { x[j] : j ∈ nz(x), ∃o π_o(j) = k }

Decoding the value of an *active* coordinate ``j`` probes the same ``h`` cells
regardless of the vector (Counting-Bloom-style):

    x̄[j] = min_{o} u[π_o(j)]      (least upper bound;   used when q[j] > 0)
    x̲[j] = max_{o} l[π_o(j)]      (greatest lower bound; used when q[j] < 0)

so that the partial score ``q[j]·decode(j)`` always upper-bounds ``q[j]·x[j]``
(Theorem 5.1).

TPU adaptation notes
--------------------
* Mappings are materialised as an ``int32[h, n]`` table (deterministic Philox),
  so that both encode and decode are dense gathers — no hashing in the kernel.
* Sketches are stored in bfloat16 (as in the paper) but with **directed
  rounding**: values are rounded *up* to the next representable bf16 in ``u``
  and *down* in ``l``.  Plain round-to-nearest bf16 (the paper's choice) can
  round an upper bound below the true value and silently void Theorem 5.1;
  directed rounding restores the guarantee at zero extra cost.
* Cells that receive no value are filled with 0 rather than ±inf.  They are
  never decoded for a *valid* (doc, coordinate) pair — the membership index
  guarantees at least the coordinate's own value landed in all h probed cells —
  but a finite fill keeps masked dense arithmetic NaN-free.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SketchSpec:
    """Static configuration of a Sinnamon sketch."""

    n: int                      # ambient dimensionality of the sparse space
    m: int                      # rows in each of U and L (sketch size = 2m)
    h: int = 1                  # number of independent random mappings
    positive_only: bool = False  # Sinnamon+ (paper §4.1): drop L entirely
    dtype: str = "bfloat16"     # storage dtype of sketch cells

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def sketch_rows(self) -> int:
        return self.m if self.positive_only else 2 * self.m


def make_mappings(seed: int, n: int, m: int, h: int) -> np.ndarray:
    """h independent uniform random mappings [n] -> [m] as an int32[h, n] table.

    Deterministic in ``seed`` (Philox counter-based bit generator), so an index
    checkpoint only needs to store the seed, not the table.
    """
    gen = np.random.Generator(np.random.Philox(key=seed))
    return gen.integers(0, m, size=(h, n), dtype=np.int32)


# ---------------------------------------------------------------------------
# Directed bfloat16 rounding (upper bounds round toward +inf, lower toward -inf)
# ---------------------------------------------------------------------------

def _bf16_next_toward_inf(b: Array, positive: bool) -> Array:
    """Next representable bf16 strictly toward +inf (positive=True) or -inf."""
    bits = jax.lax.bitcast_convert_type(b, jnp.uint16)
    is_nonneg = ~jnp.signbit(b)
    if positive:
        # toward +inf: magnitude grows for x>=0, shrinks for x<0.
        nxt = jnp.where(is_nonneg, bits + 1, bits - 1)
        # -0.0 (0x8000) - 1 would be garbage; map any zero to smallest +subnormal
        nxt = jnp.where(b == 0, jnp.uint16(0x0001), nxt)
    else:
        nxt = jnp.where(is_nonneg, bits - 1, bits + 1)
        nxt = jnp.where(b == 0, jnp.uint16(0x8001), nxt)
    return jax.lax.bitcast_convert_type(nxt, jnp.bfloat16)


def quantize_directed(x: Array, dtype, toward_pos_inf: bool) -> Array:
    """Cast f32 -> dtype rounding toward +inf (u) or -inf (l)."""
    x = x.astype(jnp.float32)
    if jnp.dtype(dtype) == jnp.float32:
        return x
    if jnp.dtype(dtype) != jnp.dtype(jnp.bfloat16):
        raise ValueError(f"unsupported sketch dtype {dtype}")
    b = x.astype(jnp.bfloat16)
    bf = b.astype(jnp.float32)
    if toward_pos_inf:
        need = bf < x
    else:
        need = bf > x
    out = jnp.where(need, _bf16_next_toward_inf(b, toward_pos_inf), b)
    # XLA CPU flushes bf16 subnormals to zero, which can void the bound for
    # |x| below the smallest normal bf16 — fall back to ±smallest-normal.
    tiny = jnp.bfloat16(1.1754944e-38)
    of = out.astype(jnp.float32)
    if toward_pos_inf:
        out = jnp.where(of < x, tiny, out)
    else:
        out = jnp.where(of > x, -tiny, out)
    return out


# ---------------------------------------------------------------------------
# Encode (Algorithm 5) / decode (Algorithm 6 inner step)
# ---------------------------------------------------------------------------

def encode(
    mappings: Array,            # int32[h, n]
    m: int,
    idx: Array,                 # int32[P], padded with -1
    val: Array,                 # f32[P]
    dtype="bfloat16",
    positive_only: bool = False,
) -> Tuple[Array, Optional[Array]]:
    """Sketch one sparse vector -> (u[m], l[m]) (l is None for Sinnamon+)."""
    h = mappings.shape[0]
    valid = idx >= 0
    safe = jnp.where(valid, idx, 0)
    targets = mappings[:, safe].reshape(-1)                # [h*P]
    vals = jnp.broadcast_to(val.astype(jnp.float32), (h,) + val.shape).reshape(-1)
    ok = jnp.broadcast_to(valid, (h,) + valid.shape).reshape(-1)

    u = jax.ops.segment_max(
        jnp.where(ok, vals, -jnp.inf), targets, num_segments=m,
        indices_are_sorted=False, unique_indices=False)
    u = jnp.where(jnp.isneginf(u), 0.0, u)
    u = quantize_directed(u, dtype, toward_pos_inf=True)
    if positive_only:
        return u, None
    l = jax.ops.segment_min(
        jnp.where(ok, vals, jnp.inf), targets, num_segments=m,
        indices_are_sorted=False, unique_indices=False)
    l = jnp.where(jnp.isposinf(l), 0.0, l)
    l = quantize_directed(l, dtype, toward_pos_inf=False)
    return u, l


def encode_batch(mappings, m, idx, val, dtype="bfloat16", positive_only=False):
    """vmap of :func:`encode` over a leading batch axis of (idx, val)."""
    fn = lambda i, v: encode(mappings, m, i, v, dtype, positive_only)
    return jax.vmap(fn)(idx, val)


def decode_coord(
    mappings: Array,    # int32[h, n]
    u: Array,           # [m, ...]  (sketch matrix; trailing axes = doc slots)
    l: Optional[Array],
    j: Array,           # scalar int32 coordinate
):
    """Least-upper / greatest-lower bounds of coordinate j for every column.

    Returns (ub[...], lb[...]).  For Sinnamon+ (l=None) lb is zeros — valid
    because Sinnamon+ is only used for non-negative collections.
    """
    rows = mappings[:, j]                                   # [h]
    ucells = u[rows].astype(jnp.float32)                    # [h, ...]
    ub = jnp.min(ucells, axis=0)
    if l is None:
        lb = jnp.zeros_like(ub)
    else:
        lcells = l[rows].astype(jnp.float32)
        lb = jnp.max(lcells, axis=0)
    return ub, lb


def decode_vector(mappings, u, l, idx):
    """Reconstruct per-coordinate (ub, lb) for a single sketched vector.

    u, l: [m] sketches of one vector.  idx: int32[P] active coordinates
    (padded with -1).  Used by the §5 error analysis and its tests.
    """
    safe = jnp.where(idx >= 0, idx, 0)
    rows = mappings[:, safe]                                # [h, P]
    ub = jnp.min(u[rows].astype(jnp.float32), axis=0)
    if l is None:
        lb = jnp.zeros_like(ub)
    else:
        lb = jnp.max(l[rows].astype(jnp.float32), axis=0)
    return ub, lb
