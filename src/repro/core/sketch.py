"""The Sinnamon sketch (paper §4.1, Algorithm 5).

A sparse vector ``x ∈ R^n`` with active set ``nz(x)`` is compressed into an
upper-bound sketch ``u ∈ R^m`` and a lower-bound sketch ``l ∈ R^m`` using ``h``
independent random mappings ``π_o : [n] → [m]``:

    u[k] = max { x[j] : j ∈ nz(x), ∃o π_o(j) = k }
    l[k] = min { x[j] : j ∈ nz(x), ∃o π_o(j) = k }

Decoding the value of an *active* coordinate ``j`` probes the same ``h`` cells
regardless of the vector (Counting-Bloom-style):

    x̄[j] = min_{o} u[π_o(j)]      (least upper bound;   used when q[j] > 0)
    x̲[j] = max_{o} l[π_o(j)]      (greatest lower bound; used when q[j] < 0)

so that the partial score ``q[j]·decode(j)`` always upper-bounds ``q[j]·x[j]``
(Theorem 5.1).

TPU adaptation notes
--------------------
* Mappings are materialised as an ``int32[h, n]`` table (deterministic Philox),
  so that both encode and decode are dense gathers — no hashing in the kernel.
* Sketch cells are **quantized storage** (paper §6.1.2's memory lever): the
  supported cell dtypes are ``f32 | bf16 | f8`` (see :func:`resolve_cell_dtype`
  for the aliases; f8 is ``float8_e4m3fn``) and every narrow dtype uses
  **directed rounding** — values are rounded *up* to the next representable
  cell value in ``u`` and *down* in ``l``.  Plain round-to-nearest (the
  paper's choice for bf16) can round an upper bound below the true value and
  silently void Theorem 5.1; directed rounding restores the guarantee at zero
  extra cost.  Quantized cells are decoded (cast back to f32) inside the
  scoring tile loop, so the HBM-resident sketch stays at the narrow width.
* f8 cells saturate at ±448 (e4m3fn has no inf): beyond that magnitude the
  directed bound is voided.  Real sparse-retrieval values sit orders of
  magnitude below the cliff; ``repro.eval.bounds`` measures the residual
  quantization overestimate empirically.
* Cells that receive no value are filled with 0 rather than ±inf.  They are
  never decoded for a *valid* (doc, coordinate) pair — the membership index
  guarantees at least the coordinate's own value landed in all h probed cells —
  but a finite fill keeps masked dense arithmetic NaN-free.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SketchSpec:
    """Static configuration of a Sinnamon sketch.

    ``positive_only`` means "no lower sketch is stored": true Sinnamon+
    collections (paper §4.1, non-negative values make L redundant) and the
    §3.3 *lite* variant (L dropped deliberately to halve sketch memory; the
    engine sets this flag from ``EngineSpec.upper_only``).
    """

    n: int                      # ambient dimensionality of the sparse space
    m: int                      # rows in each of U and L (sketch size = 2m)
    h: int = 1                  # number of independent random mappings
    positive_only: bool = False  # drop L entirely (Sinnamon+ / lite)
    dtype: str = "bfloat16"     # storage dtype of sketch cells (see aliases)

    @property
    def jdtype(self):
        return jnp.dtype(resolve_cell_dtype(self.dtype))

    @property
    def sketch_rows(self) -> int:
        return self.m if self.positive_only else 2 * self.m


# ---------------------------------------------------------------------------
# Quantized sketch cells (the memory lever): f32 | bf16 | f8
# ---------------------------------------------------------------------------

_CELL_ALIASES = {
    "f32": "float32", "float32": "float32",
    "bf16": "bfloat16", "bfloat16": "bfloat16",
    "f8": "float8_e4m3fn", "float8_e4m3fn": "float8_e4m3fn",
}

#: Lever names accepted by CLIs (``--value-dtype``) and the auto-tuner.
CELL_DTYPES = ("f32", "bf16", "f8")

# Narrow formats that support directed rounding: dtype -> bit-pattern dtype.
_BITS_OF = {
    jnp.dtype(jnp.bfloat16): jnp.uint16,
    jnp.dtype("float8_e4m3fn"): jnp.uint8,
}


def resolve_cell_dtype(name) -> str:
    """Canonical sketch-cell dtype name from a lever alias.

    Accepts ``f32 | bf16 | f8`` (the CLI/tuner lever names) or the canonical
    numpy names (``float32 | bfloat16 | float8_e4m3fn``).  NOTE: the aliases
    are checked *before* numpy's dtype parser on purpose — to numpy, ``"f8"``
    means float64, which is exactly the wrong 56 bits.
    """
    key = str(name)
    if key not in _CELL_ALIASES:
        try:
            key = np.dtype(name).name
        except TypeError:
            pass
    if key not in _CELL_ALIASES:
        raise ValueError(f"unknown sketch cell dtype {name!r}; expected one "
                         f"of {CELL_DTYPES} (or a canonical name: "
                         f"{sorted(set(_CELL_ALIASES.values()))})")
    return _CELL_ALIASES[key]


def make_mappings(seed: int, n: int, m: int, h: int) -> np.ndarray:
    """h independent uniform random mappings [n] -> [m] as an int32[h, n] table.

    Deterministic in ``seed`` (Philox counter-based bit generator), so an index
    checkpoint only needs to store the seed, not the table.
    """
    gen = np.random.Generator(np.random.Philox(key=seed))
    return gen.integers(0, m, size=(h, n), dtype=np.int32)


# ---------------------------------------------------------------------------
# Directed rounding (upper bounds round toward +inf, lower toward -inf) for
# every narrow cell dtype in _BITS_OF.
# ---------------------------------------------------------------------------

def _next_toward_inf(b: Array, positive: bool) -> Array:
    """Next representable cell value strictly toward +inf or -inf.

    Works on the bit pattern of any IEEE-ish sign/exponent/mantissa format
    (bf16, f8): incrementing the magnitude bits steps one ulp away from zero,
    decrementing steps toward it.  jnp.signbit has no f8 lowering, so the
    sign comes from the top bit directly.
    """
    bits_dtype = _BITS_OF[b.dtype]
    nbits = jnp.dtype(bits_dtype).itemsize * 8
    bits = jax.lax.bitcast_convert_type(b, bits_dtype)
    one = jnp.asarray(1, bits_dtype)
    sign_mask = jnp.asarray(1 << (nbits - 1), bits_dtype)
    is_nonneg = (bits & sign_mask) == 0
    if positive:
        # toward +inf: magnitude grows for x>=0, shrinks for x<0.
        nxt = jnp.where(is_nonneg, bits + one, bits - one)
        # -0.0 (sign_mask) - 1 would be garbage; map any zero to the
        # smallest positive subnormal (bit pattern 0...01).
        nxt = jnp.where(b == 0, one, nxt)
    else:
        nxt = jnp.where(is_nonneg, bits - one, bits + one)
        nxt = jnp.where(b == 0, sign_mask | one, nxt)
    return jax.lax.bitcast_convert_type(nxt, b.dtype)


def quantize_directed(x: Array, dtype, toward_pos_inf: bool) -> Array:
    """Cast f32 -> cell dtype rounding toward +inf (u) or -inf (l).

    Values beyond the format's largest finite magnitude saturate there
    (e4m3fn has no inf to round to), which voids the directed bound only
    for |x| > finfo(dtype).max — far outside real retrieval value ranges,
    and measurable via repro.eval.bounds.
    """
    x = x.astype(jnp.float32)
    dt = jnp.dtype(resolve_cell_dtype(dtype))
    if dt == jnp.float32:
        return x
    fin = jnp.finfo(dt)
    xc = jnp.clip(x, float(-fin.max), float(fin.max))
    b = xc.astype(dt)
    bf = b.astype(jnp.float32)
    if toward_pos_inf:
        need = bf < xc
    else:
        need = bf > xc
    out = jnp.where(need, _next_toward_inf(b, toward_pos_inf), b)
    # XLA CPU flushes narrow-format subnormals to zero, which can void the
    # bound for |x| below the smallest normal — fall back to ±smallest-normal.
    tiny = jnp.asarray(float(fin.tiny), dt)
    of = out.astype(jnp.float32)
    if toward_pos_inf:
        out = jnp.where(of < xc, tiny, out)
    else:
        out = jnp.where(of > xc, -tiny, out)
    return out


# ---------------------------------------------------------------------------
# Encode (Algorithm 5) / decode (Algorithm 6 inner step)
# ---------------------------------------------------------------------------

def encode(
    mappings: Array,            # int32[h, n]
    m: int,
    idx: Array,                 # int32[P], padded with -1
    val: Array,                 # f32[P]
    dtype="bfloat16",
    positive_only: bool = False,
) -> Tuple[Array, Optional[Array]]:
    """Sketch one sparse vector -> (u[m], l[m]) (l is None for Sinnamon+)."""
    h = mappings.shape[0]
    valid = idx >= 0
    safe = jnp.where(valid, idx, 0)
    targets = mappings[:, safe].reshape(-1)                # [h*P]
    vals = jnp.broadcast_to(val.astype(jnp.float32), (h,) + val.shape).reshape(-1)
    ok = jnp.broadcast_to(valid, (h,) + valid.shape).reshape(-1)

    u = jax.ops.segment_max(
        jnp.where(ok, vals, -jnp.inf), targets, num_segments=m,
        indices_are_sorted=False, unique_indices=False)
    u = jnp.where(jnp.isneginf(u), 0.0, u)
    u = quantize_directed(u, dtype, toward_pos_inf=True)
    if positive_only:
        return u, None
    l = jax.ops.segment_min(
        jnp.where(ok, vals, jnp.inf), targets, num_segments=m,
        indices_are_sorted=False, unique_indices=False)
    l = jnp.where(jnp.isposinf(l), 0.0, l)
    l = quantize_directed(l, dtype, toward_pos_inf=False)
    return u, l


def encode_batch(mappings, m, idx, val, dtype="bfloat16", positive_only=False):
    """vmap of :func:`encode` over a leading batch axis of (idx, val)."""
    fn = lambda i, v: encode(mappings, m, i, v, dtype, positive_only)
    return jax.vmap(fn)(idx, val)


def decode_coord(
    mappings: Array,    # int32[h, n]
    u: Array,           # [m, ...]  (sketch matrix; trailing axes = doc slots)
    l: Optional[Array],
    j: Array,           # scalar int32 coordinate
):
    """Least-upper / greatest-lower bounds of coordinate j for every column.

    Returns (ub[...], lb[...]).  For Sinnamon+ (l=None) lb is zeros — valid
    because Sinnamon+ is only used for non-negative collections.
    """
    rows = mappings[:, j]                                   # [h]
    ucells = u[rows].astype(jnp.float32)                    # [h, ...]
    ub = jnp.min(ucells, axis=0)
    if l is None:
        lb = jnp.zeros_like(ub)
    else:
        lcells = l[rows].astype(jnp.float32)
        lb = jnp.max(lcells, axis=0)
    return ub, lb


def decode_vector(mappings, u, l, idx):
    """Reconstruct per-coordinate (ub, lb) for a single sketched vector.

    u, l: [m] sketches of one vector.  idx: int32[P] active coordinates
    (padded with -1).  Used by the §5 error analysis and its tests.
    """
    safe = jnp.where(idx >= 0, idx, 0)
    rows = mappings[:, safe]                                # [h, P]
    ub = jnp.min(u[rows].astype(jnp.float32), axis=0)
    if l is None:
        lb = jnp.zeros_like(ub)
    else:
        lb = jnp.max(l[rows].astype(jnp.float32), axis=0)
    return ub, lb
