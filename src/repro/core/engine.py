"""Sinnamon: the approximate streaming SMIPS engine (paper §4).

Functional JAX core (everything jit-able, shardable) + a thin host wrapper
that owns slot allocation / id mapping / capacity growth.

State layout (one shard):
    mappings : int32[h, n]        random coordinate mappings (π_o)
    u, l     : bf16[m, C]         sketch matrix  X̃ = [U; L]   (l=None → Sinnamon+)
    bits     : uint32[n, C/32]    id-only inverted index (bit-packed)
    store    : VecStore[C, P]     raw vectors (exact rerank source)
    active   : bool[C]            slot occupancy
    ids      : int64[C]           external document ids per slot

Retrieval = Algorithm 6 (budgeted, coordinate-at-a-time upper-bound scoring)
          + Algorithm 7 (top-k' candidates → exact rerank → top-k).
Deletion  = bit-clear + slot recycling (paper §4.3): the sketch column is left
            *dirty* and the next insert MERGES into it (max into u, min into l)
            instead of rebuilding it.  That keeps deletion O(ψ) and preserves
            the Theorem 5.1 upper-bound property — the merged column bounds the
            union of the stale and the new document — but the bound gets
            *looser* under sustained churn.  ``dirty`` tracks which columns
            carry stale residue; :func:`compact_state` rebuilds them exactly
            from the raw vectors in the VecStore (see repro.persist.compact).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitindex, sketch
from repro.storage import vecstore

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """Static engine configuration (hashable; safe as a jit static arg)."""

    n: int                       # ambient dimensionality
    m: int                       # sketch half-size (2m total rows, paper's "2m")
    capacity: int                # document slots C (multiple of 32)
    max_nnz: int                 # padded CSR width P (max ψ_d)
    h: int = 1
    positive_only: bool = False  # Sinnamon+
    # Approximate inverted index (paper §4.1.2 future work, built here):
    # coordinates hash into `index_buckets` bitmap rows; each list becomes a
    # SUPERSET of the exact one, which preserves the Theorem 5.1 upper-bound
    # (a false positive only ever ADDS a non-negative overestimate) while
    # shrinking the index by n/index_buckets. None = exact bitmap.
    index_buckets: "int | None" = None
    dtype: str = "bfloat16"      # sketch storage dtype
    value_dtype: str = "bfloat16"  # raw-value storage dtype (paper uses bf16)
    seed: int = 0

    def __post_init__(self):
        if self.capacity % 32 != 0:
            raise ValueError("capacity must be a multiple of 32")

    @property
    def sketch_spec(self) -> sketch.SketchSpec:
        return sketch.SketchSpec(self.n, self.m, self.h, self.positive_only,
                                 self.dtype)


def coord_rows(spec: EngineSpec, idx: Array) -> Array:
    """Map coordinate ids to bitmap rows (identity, or hashed buckets)."""
    if spec.index_buckets is None:
        return idx
    u = idx.astype(jnp.uint32) * jnp.uint32(2654435761)
    return jnp.where(idx >= 0,
                     (u % jnp.uint32(spec.index_buckets)).astype(jnp.int32),
                     idx)


class SinnamonState(NamedTuple):
    mappings: Array
    u: Array
    l: Optional[Array]
    bits: Array
    store: vecstore.VecStore
    active: Array
    ids: Array
    dirty: Array     # bool[C]: sketch column carries stale (deleted-doc) residue


# ---------------------------------------------------------------------------
# Functional core
# ---------------------------------------------------------------------------

def init(spec: EngineSpec) -> SinnamonState:
    mappings = jnp.asarray(sketch.make_mappings(spec.seed, spec.n, spec.m, spec.h))
    u = jnp.zeros((spec.m, spec.capacity), dtype=spec.sketch_spec.jdtype)
    l = None if spec.positive_only else jnp.zeros_like(u)
    return SinnamonState(
        mappings=mappings,
        u=u,
        l=l,
        bits=bitindex.empty(spec.index_buckets or spec.n, spec.capacity),
        store=vecstore.empty(spec.capacity, spec.max_nnz,
                             dtype=jnp.dtype(spec.value_dtype)),
        active=jnp.zeros((spec.capacity,), jnp.bool_),
        ids=jnp.full((spec.capacity,), -1, jnp.int32),
        dirty=jnp.zeros((spec.capacity,), jnp.bool_),
    )


def insert(state: SinnamonState, spec: EngineSpec, slot, ext_id,
           idx: Array, val: Array) -> SinnamonState:
    """Algorithm 5: index one document at ``slot``.

    A clean slot gets the document's exact sketch column.  A *dirty* slot
    (recycled after a §4.3 deletion) is MERGED into — max for u, min for l —
    so the column still upper/lower-bounds every value it ever saw.  The bound
    stays valid but loose; the slot stays dirty until compaction rebuilds it.
    """
    u_col, l_col = sketch.encode(state.mappings, spec.m, idx, val,
                                 dtype=spec.dtype,
                                 positive_only=spec.positive_only)
    was_dirty = state.dirty[slot]
    u_col = u_col.astype(state.u.dtype)
    u_col = jnp.where(was_dirty, jnp.maximum(state.u[:, slot], u_col), u_col)
    u = state.u.at[:, slot].set(u_col)
    if state.l is None:
        l = None
    else:
        l_col = l_col.astype(state.l.dtype)
        l_col = jnp.where(was_dirty, jnp.minimum(state.l[:, slot], l_col),
                          l_col)
        l = state.l.at[:, slot].set(l_col)
    bits = bitindex.set_doc(state.bits, coord_rows(spec, idx), slot,
                            on=True)
    store = vecstore.write(state.store, slot, idx, val)
    return state._replace(
        u=u, l=l, bits=bits, store=store,
        active=state.active.at[slot].set(True),
        ids=state.ids.at[slot].set(ext_id),
    )


def insert_batch(state: SinnamonState, spec: EngineSpec, slots: Array,
                 ext_ids: Array, idx: Array, val: Array) -> SinnamonState:
    """Sequential-semantics batch insert (scan; one jit dispatch per batch)."""

    def body(st, args):
        slot, eid, i, v = args
        return insert(st, spec, slot, eid, i, v), None

    state, _ = jax.lax.scan(body, state, (slots, ext_ids, idx, val))
    return state


def insert_batch_masked(state: SinnamonState, spec: EngineSpec, slots: Array,
                        ext_ids: Array, idx: Array, val: Array,
                        mask: Array) -> SinnamonState:
    """:func:`insert_batch` where ``mask=False`` entries are exact no-ops.

    This is the shard_map-body form: each shard receives a host-routed,
    padded slice of the update batch and applies only its own entries, so a
    sharded insert needs no collectives (see repro.serving.sharded).
    """

    def body(st, args):
        slot, eid, i, v, ok = args
        st = jax.lax.cond(ok, lambda s: insert(s, spec, slot, eid, i, v),
                          lambda s: s, st)
        return st, None

    state, _ = jax.lax.scan(body, state, (slots, ext_ids, idx, val, mask))
    return state


def delete_batch_masked(state: SinnamonState, spec: EngineSpec, slots: Array,
                        mask: Array) -> SinnamonState:
    """Masked batch delete (scan); the shard_map-body twin of delete."""

    def body(st, args):
        slot, ok = args
        st = jax.lax.cond(ok, lambda s: delete(s, spec, slot),
                          lambda s: s, st)
        return st, None

    state, _ = jax.lax.scan(body, state, (slots, mask))
    return state


def delete(state: SinnamonState, spec: EngineSpec, slot) -> SinnamonState:
    """Paper §4.3: clear inverted-index bits; leave the sketch column stale.

    The stale column is marked dirty so the next insert merges rather than
    overwrites, and compaction knows which columns to rebuild.
    """
    idx = state.store.indices[slot]
    bits = bitindex.set_doc(state.bits, coord_rows(spec, idx), slot,
                            on=False)
    store = vecstore.erase(state.store, slot)
    return state._replace(
        bits=bits, store=store,
        active=state.active.at[slot].set(False),
        ids=state.ids.at[slot].set(-1),
        dirty=state.dirty.at[slot].set(True),
    )


def grow_state(state: SinnamonState, spec: EngineSpec,
               new_spec: EngineSpec) -> SinnamonState:
    """Pad every per-slot axis from spec.capacity to new_spec.capacity.

    Pure function of the arrays (slot numbering is preserved), so it works
    both as the host-side reallocation of :class:`SinnamonIndex` and as a
    shard-local shard_map body where each shard grows its own slot range.
    """
    c = spec.capacity
    st = init(new_spec)
    return SinnamonState(
        mappings=state.mappings,
        u=st.u.at[:, :c].set(state.u),
        l=None if state.l is None else st.l.at[:, :c].set(state.l),
        bits=st.bits.at[:, : c // 32].set(state.bits),
        store=vecstore.VecStore(
            indices=st.store.indices.at[:c].set(state.store.indices),
            values=st.store.values.at[:c].set(state.store.values)),
        active=st.active.at[:c].set(state.active),
        ids=st.ids.at[:c].set(state.ids),
        dirty=st.dirty.at[:c].set(state.dirty),
    )


# ---------------------------------------------------------------------------
# Sketch compaction (repro.persist.compact drives these; pure so they work as
# shard_map bodies too)
# ---------------------------------------------------------------------------

def fresh_sketch(state: SinnamonState, spec: EngineSpec
                 ) -> Tuple[Array, Optional[Array]]:
    """Exact sketch matrix re-encoded from the raw vectors in the VecStore.

    Returns (u[m, C], l[m, C]).  Erased slots encode to all-zero columns.
    This is the Theorem 5.1-tight reference: no recycled-slot residue.
    """
    u, l = sketch.encode_batch(
        state.mappings, spec.m, state.store.indices,
        state.store.values.astype(jnp.float32),
        dtype=spec.dtype, positive_only=spec.positive_only)
    return u.T, None if l is None else l.T


def compact_state(state: SinnamonState, spec: EngineSpec) -> SinnamonState:
    """Rebuild every dirty sketch column exactly from the VecStore.

    Dirty+active columns become the document's fresh sketch; dirty+inactive
    (deleted, not yet recycled) columns become zero.  Clean columns are left
    untouched bit-for-bit.  Pure function of the arrays — usable directly or
    as a shard-local shard_map body (see repro.serving.sharded).
    """
    u_f, l_f = fresh_sketch(state, spec)
    d = state.dirty[None, :]
    u = jnp.where(d, u_f.astype(state.u.dtype), state.u)
    l = None if state.l is None else jnp.where(
        d, l_f.astype(state.l.dtype), state.l)
    return state._replace(u=u, l=l, dirty=jnp.zeros_like(state.dirty))


def slot_drift(state: SinnamonState, spec: EngineSpec) -> Array:
    """Per-slot sketch overestimate vs. a fresh sketch.  f32[C].

    For each active slot: the max over sketch cells of how far the stored
    upper bound sits ABOVE the tight one (plus, symmetrically, how far the
    stored lower bound sits below).  0 for clean slots (up to storage-dtype
    effects when value_dtype != float32) and for inactive slots.
    """
    u_f, l_f = fresh_sketch(state, spec)
    over = jnp.max(jnp.clip(state.u.astype(jnp.float32)
                            - u_f.astype(jnp.float32), 0.0, None), axis=0)
    if state.l is not None:
        over_l = jnp.max(jnp.clip(l_f.astype(jnp.float32)
                                  - state.l.astype(jnp.float32), 0.0, None),
                         axis=0)
        over = jnp.maximum(over, over_l)
    return jnp.where(state.active, over, 0.0)


def _sorted_query(q_idx: Array, q_val: Array) -> Tuple[Array, Array]:
    """Order query coordinates by |q[j]| descending, padding (idx<0) last."""
    key = jnp.where(q_idx >= 0, jnp.abs(q_val.astype(jnp.float32)), -1.0)
    order = jnp.argsort(-key)
    return q_idx[order], q_val[order]


def score(state: SinnamonState, spec: EngineSpec, q_idx: Array, q_val: Array,
          budget: Optional[int] = None) -> Array:
    """Algorithm 6: upper-bound scores for every slot.  f32[C].

    ``budget`` is the anytime lever: only the ``budget`` largest-|q[j]|
    coordinates are scored (deterministic adaptation of the paper's wall-clock
    budget T; see DESIGN.md §6).  None = all coordinates (T = ∞).
    """
    q_idx, q_val = _sorted_query(q_idx, q_val)
    steps = q_idx.shape[0] if budget is None else min(budget, q_idx.shape[0])
    rows = coord_rows(spec, q_idx)          # bitmap rows in SORTED order

    def body(t, scores):
        j = q_idx[t]
        v = q_val[t].astype(jnp.float32)
        safe_j = jnp.maximum(j, 0)
        ub, lb = sketch.decode_coord(state.mappings, state.u, state.l, safe_j)
        contrib = jnp.where(v > 0, v * ub, v * lb)
        memb = bitindex.row_mask(state.bits, jnp.maximum(rows[t], 0))
        return scores + jnp.where(memb & (j >= 0), contrib, 0.0)

    scores = jnp.zeros((spec.capacity,), jnp.float32)
    return jax.lax.fori_loop(0, steps, body, scores)


def score_grouped(state: SinnamonState, spec: EngineSpec, q_idx: Array,
                  q_val: Array, budget: Optional[int] = None) -> Array:
    """Beyond-paper scoring schedule (EXPERIMENTS.md §Perf): process all
    budgeted coordinates in ONE fused pass instead of a coordinate-at-a-time
    loop.  Same math as :func:`score`; the sketch/bitmap rows are gathered as
    a single [L, ·] batch and reduced with one einsum-style sum, which lets
    XLA keep the candidate tile resident instead of re-walking scores[C] per
    coordinate (psi_q x fewer accumulator read-modify-writes).
    """
    q_idx, q_val = _sorted_query(q_idx, q_val)
    L = q_idx.shape[0] if budget is None else min(budget, q_idx.shape[0])
    j = q_idx[:L]
    v = q_val[:L].astype(jnp.float32)
    safe = jnp.where(j >= 0, j, 0)
    rows = state.mappings[:, safe]                           # [h, L]
    ub = jnp.min(state.u[rows].astype(jnp.float32), axis=0)  # [L, C]
    if state.l is None:
        lb = jnp.zeros_like(ub)
    else:
        lb = jnp.max(state.l[rows].astype(jnp.float32), axis=0)
    contrib = jnp.where(v[:, None] > 0, v[:, None] * ub, v[:, None] * lb)
    bit_rows = jnp.maximum(coord_rows(spec, j), 0)
    memb = bitindex.unpack_row(state.bits[bit_rows])         # [L, C]
    contrib = jnp.where(memb & (j >= 0)[:, None], contrib, 0.0)
    return jnp.sum(contrib, axis=0)


def score_batch(state, spec, q_idx, q_val, budget=None, grouped=False
                ) -> Array:
    """[B, C] upper-bound scores for a batch of queries."""
    fn = score_grouped if grouped else score
    return jax.vmap(lambda i, v: fn(state, spec, i, v, budget))(q_idx, q_val)


def search(state: SinnamonState, spec: EngineSpec, q_idx: Array, q_val: Array,
           k: int, kprime: int, budget: Optional[int] = None,
           filter_mask: Optional[Array] = None,
           score_fn=None):
    """Algorithms 6+7: scoring → top-k' → exact rerank → top-k.

    filter_mask: optional bool[C] for constrained search (paper §4.2.4, Eq. 3).
    score_fn: override the scoring backend (e.g. the Pallas kernel wrapper).
    Returns (ids int64[k], exact_scores f32[k], slots int32[k]).
    """
    sfn = score_fn if score_fn is not None else score
    s = sfn(state, spec, q_idx, q_val, budget)
    ok = state.active if filter_mask is None else (state.active & filter_mask)
    s = jnp.where(ok, s, -jnp.inf)
    cand_scores, cand_slots = jax.lax.top_k(s, kprime)

    q_dense = vecstore.densify_query(spec.n, q_idx, q_val)
    exact = vecstore.exact_scores(state.store, cand_slots, q_dense)
    exact = jnp.where(jnp.isneginf(cand_scores), -jnp.inf, exact)
    top_scores, pos = jax.lax.top_k(exact, k)
    slots = cand_slots[pos]
    return state.ids[slots], top_scores, slots


def search_batch(state, spec, q_idx, q_val, k, kprime, budget=None,
                 filter_mask=None, score_fn=None):
    fn = lambda i, v: search(state, spec, i, v, k, kprime, budget,
                             filter_mask, score_fn)
    return jax.vmap(fn)(q_idx, q_val)


# ---------------------------------------------------------------------------
# Host wrapper: slot allocation, id mapping, growth
# ---------------------------------------------------------------------------

class SinnamonIndex:
    """Streaming host-facing index.  All heavy math stays jitted/functional."""

    def __init__(self, spec: EngineSpec):
        self.spec = spec
        self.state = init(spec)
        self._free = list(range(spec.capacity - 1, -1, -1))  # pop() -> slot 0 first
        self._id2slot: dict[int, int] = {}
        self._insert = jax.jit(insert, static_argnums=(1,))
        self._insert_batch = jax.jit(insert_batch, static_argnums=(1,))
        self._delete = jax.jit(delete, static_argnums=(1,))
        self._search = jax.jit(
            search, static_argnums=(1, 4, 5, 6),
            static_argnames=("score_fn",))
        self._search_many = jax.jit(
            search_batch, static_argnums=(1, 4, 5, 6),
            static_argnames=("score_fn",))
        self._compact = jax.jit(compact_state, static_argnums=(1,))
        self._slot_drift = jax.jit(slot_drift, static_argnums=(1,))

    # -- streaming updates ---------------------------------------------------
    def insert(self, ext_id: int, idx, val) -> None:
        if ext_id in self._id2slot:
            self.delete(ext_id)
        if not self._free:
            self.grow(self.spec.capacity * 2)
        slot = self._free.pop()
        idx, val = pad_sparse(idx, val, self.spec.max_nnz)
        self.state = self._insert(self.state, self.spec, slot, ext_id, idx, val)
        self._id2slot[ext_id] = slot

    def insert_many(self, ext_ids, idx_batch, val_batch) -> None:
        ext_ids = [int(e) for e in ext_ids]
        if len(set(ext_ids)) != len(ext_ids):
            # Sequential overwrite semantics (same as the sharded index):
            # only the LAST occurrence of a duplicated id survives.
            last = {e: pos for pos, e in enumerate(ext_ids)}
            keep = sorted(last.values())
            ext_ids = [ext_ids[p] for p in keep]
            idx_batch = np.asarray(idx_batch)[keep]
            val_batch = np.asarray(val_batch)[keep]
        for e in ext_ids:
            if e in self._id2slot:      # overwrite: drop the stale copy
                self.delete(e)
        bn = len(ext_ids)
        while len(self._free) < bn:
            self.grow(self.spec.capacity * 2)
        slots = np.array([self._free.pop() for _ in range(bn)], np.int32)
        self.state = self._insert_batch(
            self.state, self.spec, jnp.asarray(slots),
            jnp.asarray(np.asarray(ext_ids, np.int32)),
            jnp.asarray(idx_batch), jnp.asarray(val_batch))
        for eid, slot in zip(ext_ids, slots):
            self._id2slot[int(eid)] = int(slot)

    def delete(self, ext_id: int) -> None:
        slot = self._id2slot.pop(ext_id)
        self.state = self._delete(self.state, self.spec, slot)
        self._free.append(slot)

    # -- retrieval -------------------------------------------------------------
    def search(self, q_idx, q_val, k: int, kprime: Optional[int] = None,
               budget: Optional[int] = None, filter_mask=None, score_fn=None):
        kprime = kprime if kprime is not None else max(5 * k, k)
        kprime = min(kprime, self.spec.capacity)
        k = min(k, kprime)
        ids, scores, _ = self._search(
            self.state, self.spec, jnp.asarray(q_idx), jnp.asarray(q_val),
            k, kprime, budget, filter_mask, score_fn=score_fn)
        return np.asarray(ids), np.asarray(scores)

    def search_many(self, q_idx, q_val, k: int, kprime: Optional[int] = None,
                    budget: Optional[int] = None, filter_mask=None,
                    score_fn=None):
        """Batched search: q_idx/q_val are [B, Lq]; one jit dispatch total."""
        kprime = kprime if kprime is not None else max(5 * k, k)
        kprime = min(kprime, self.spec.capacity)
        k = min(k, kprime)
        ids, scores, _ = self._search_many(
            self.state, self.spec, jnp.asarray(q_idx), jnp.asarray(q_val),
            k, kprime, budget, filter_mask, score_fn=score_fn)
        return np.asarray(ids), np.asarray(scores)

    # -- capacity management ----------------------------------------------------
    def grow(self, new_capacity: int) -> None:
        """Reallocate to a larger capacity, preserving slot numbering."""
        spec = self.spec
        if new_capacity <= spec.capacity or new_capacity % 32 != 0:
            raise ValueError("new capacity must be a larger multiple of 32")
        new_spec = dataclasses.replace(spec, capacity=new_capacity)
        self.state = grow_state(self.state, spec, new_spec)
        self.spec = new_spec
        self._free = (list(range(new_capacity - 1, spec.capacity - 1, -1))
                      + self._free)

    # -- maintenance -----------------------------------------------------------
    def compact(self) -> int:
        """Rebuild all dirty sketch columns from the VecStore.

        Restores the Theorem 5.1 upper-bound tightness lost to §4.3
        delete-then-recycle churn.  Returns the number of columns rebuilt.
        """
        n_dirty = int(jnp.sum(self.state.dirty))
        if n_dirty:
            self.state = self._compact(self.state, self.spec)
        return n_dirty

    def slot_drift(self) -> np.ndarray:
        """Per-slot sketch overestimate vs. a fresh sketch (f32[C])."""
        return np.asarray(self._slot_drift(self.state, self.spec))

    @property
    def size(self) -> int:
        return len(self._id2slot)

    def __contains__(self, ext_id) -> bool:
        """True iff ``ext_id`` is currently live in the index."""
        return int(ext_id) in self._id2slot

    def doc_ids(self) -> list:
        """Sorted external ids of every live document."""
        return sorted(self._id2slot)

    def memory_bytes(self) -> dict:
        """Index-size accounting (paper §6.1.2): sketch vs inverted index vs raw."""
        st = self.state
        out = {
            "sketch": st.u.size * st.u.dtype.itemsize
                      + (0 if st.l is None else st.l.size * st.l.dtype.itemsize),
            "inverted_index": st.bits.size * st.bits.dtype.itemsize,
            "storage": st.store.indices.size * st.store.indices.dtype.itemsize
                       + st.store.values.size * st.store.values.dtype.itemsize,
        }
        out["index_total"] = out["sketch"] + out["inverted_index"]
        return out


def pad_sparse(idx, val, width: int):
    """Pad/truncate a sparse (idx, val) pair to fixed width (pad idx = -1)."""
    idx = np.asarray(idx, np.int32)[:width]
    val = np.asarray(val, np.float32)[:width]
    out_i = np.full((width,), -1, np.int32)
    out_v = np.zeros((width,), np.float32)
    out_i[: idx.size] = idx
    out_v[: val.size] = val
    return jnp.asarray(out_i), jnp.asarray(out_v)
