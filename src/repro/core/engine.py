"""Sinnamon: the approximate streaming SMIPS engine (paper §4).

Functional JAX core (everything jit-able, shardable) + a thin host wrapper
that owns slot allocation / id mapping / capacity growth.

State layout (one shard):
    mappings : int32[h, n]        random coordinate mappings (π_o)
    u, l     : bf16[m, C]         sketch matrix  X̃ = [U; L]   (l=None → Sinnamon+)
    bits     : uint32[n, C/32]    id-only inverted index (bit-packed)
    store    : VecStore[C, P]     raw vectors (exact rerank source)
    active   : bool[C]            slot occupancy
    ids      : uint32[C, 2]       external int64 document ids per slot, packed
                                  as (low, high) 32-bit words — jax runs with
                                  x64 disabled, so a packed pair is how the
                                  full 64-bit id range survives on device
                                  (pack_ids64 / unpack_ids64 convert at the
                                  host boundary; -1 = empty slot)

Retrieval = Algorithm 6 (budgeted, coordinate-at-a-time upper-bound scoring)
          + Algorithm 7 (top-k' candidates → exact rerank → top-k).
Deletion  = bit-clear + slot recycling (paper §4.3): the sketch column is left
            *dirty* and the next insert MERGES into it (max into u, min into l)
            instead of rebuilding it.  That keeps deletion O(ψ) and preserves
            the Theorem 5.1 upper-bound property — the merged column bounds the
            union of the stale and the new document — but the bound gets
            *looser* under sustained churn.  ``dirty`` tracks which columns
            carry stale residue; :func:`compact_state` rebuilds them exactly
            from the raw vectors in the VecStore (see repro.persist.compact).
"""

from __future__ import annotations

import dataclasses
import time
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitindex, sketch
from repro.obs import metrics as obs_metrics
from repro.storage import vecstore

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """Static engine configuration (hashable; safe as a jit static arg).

    The *accuracy levers* (paper §5–§6, measured by ``repro.eval``):

    * ``m`` — sketch half-size; more rows = tighter Theorem 5.1 bounds.
    * ``sketch_kind`` — ``"full"`` stores both U and L; ``"lite"`` (§3.3)
      stores only the upper-bound sketch, halving sketch memory.  On
      non-negative collections lite loses nothing (L is redundant there —
      same as ``positive_only``); on signed collections negative query
      coordinates contribute 0 instead of ``q[j]·lb``, so the score is no
      longer a strict upper bound and recall degrades gracefully instead.
    * ``dtype`` — sketch cell storage: ``f32 | bf16 | f8`` (directed-rounded
      quantization, decoded in the scoring tile loop; see repro.core.sketch).
    """

    n: int                       # ambient dimensionality
    m: int                       # sketch half-size (2m total rows, paper's "2m")
    capacity: int                # document slots C (multiple of 32)
    max_nnz: int                 # padded CSR width P (max ψ_d)
    h: int = 1
    positive_only: bool = False  # Sinnamon+
    # Approximate inverted index (paper §4.1.2 future work, built here):
    # coordinates hash into `index_buckets` bitmap rows; each list becomes a
    # SUPERSET of the exact one, which preserves the Theorem 5.1 upper-bound
    # (a false positive only ever ADDS a non-negative overestimate) while
    # shrinking the index by n/index_buckets. None = exact bitmap.
    index_buckets: "int | None" = None
    sketch_kind: str = "full"    # full | lite (§3.3 upper-bound-only sketch)
    # NB two distinct storage dtypes: `dtype` is the SKETCH CELL width (the
    # quantization lever; launcher flag --value-dtype, eval name
    # "cell_dtype"), while `value_dtype` is the RAW VecStore width that the
    # exact rerank reads — the launcher flag does NOT set value_dtype.
    dtype: str = "bfloat16"      # sketch cell storage dtype (f32|bf16|f8)
    value_dtype: str = "bfloat16"  # raw-value storage dtype (paper uses bf16)
    seed: int = 0

    def __post_init__(self):
        if self.capacity % 32 != 0:
            raise ValueError("capacity must be a multiple of 32")
        if self.sketch_kind not in ("full", "lite"):
            raise ValueError(f"sketch_kind must be 'full' or 'lite', "
                             f"got {self.sketch_kind!r}")
        # Canonicalize lever aliases ("f8" -> "float8_e4m3fn") up front so
        # jit caches and snapshot recipes key on one spelling.
        object.__setattr__(self, "dtype",
                           sketch.resolve_cell_dtype(self.dtype))

    @property
    def upper_only(self) -> bool:
        """True when no lower sketch is stored (Sinnamon+ or lite)."""
        return self.positive_only or self.sketch_kind == "lite"

    @property
    def sketch_spec(self) -> sketch.SketchSpec:
        return sketch.SketchSpec(self.n, self.m, self.h, self.upper_only,
                                 self.dtype)


def coord_rows(spec: EngineSpec, idx: Array) -> Array:
    """Map coordinate ids to bitmap rows (identity, or hashed buckets)."""
    if spec.index_buckets is None:
        return idx
    u = idx.astype(jnp.uint32) * jnp.uint32(2654435761)
    return jnp.where(idx >= 0,
                     (u % jnp.uint32(spec.index_buckets)).astype(jnp.int32),
                     idx)


class SinnamonState(NamedTuple):
    mappings: Array
    u: Array
    l: Optional[Array]
    bits: Array
    store: vecstore.VecStore
    active: Array
    ids: Array       # uint32[C, 2]: packed int64 external ids (lo, hi words)
    dirty: Array     # bool[C]: sketch column carries stale (deleted-doc) residue


# -- 64-bit external ids on a 32-bit device -----------------------------------
# jax_enable_x64 is off (flipping it would re-type every float in the repo),
# so external ids — int64 on the host API — live on device as (lo, hi)
# uint32 pairs.  Packing is lossless over the full int64 range; -1 (empty
# slot) packs to (0xFFFFFFFF, 0xFFFFFFFF).

def pack_ids64(ids) -> np.ndarray:
    """int64[...] -> uint32[..., 2] (lo, hi) words."""
    u = np.asarray(ids, np.int64).view(np.uint64)
    return np.stack([u & np.uint64(0xFFFFFFFF), u >> np.uint64(32)],
                    axis=-1).astype(np.uint32)


def unpack_ids64(packed) -> np.ndarray:
    """uint32[..., 2] (lo, hi) words -> int64[...]."""
    p = np.asarray(packed, np.uint32).astype(np.uint64)
    return (p[..., 0] | (p[..., 1] << np.uint64(32))).view(np.int64)


_EMPTY_ID = np.uint32(0xFFFFFFFF)    # both words of a packed -1


# ---------------------------------------------------------------------------
# Functional core
# ---------------------------------------------------------------------------

def init(spec: EngineSpec, *, store_rows: Optional[int] = None) -> SinnamonState:
    """Fresh state.  ``store_rows=0`` allocates a zero-row VecStore
    placeholder — the tiered index keeps raw rows in a host-side
    TieredVecStore and every batched mutation's ``mode="drop"`` scatter is an
    exact no-op on the empty placeholder, so the functional core needs no
    tiering branches."""
    mappings = jnp.asarray(sketch.make_mappings(spec.seed, spec.n, spec.m, spec.h))
    u = jnp.zeros((spec.m, spec.capacity), dtype=spec.sketch_spec.jdtype)
    l = None if spec.upper_only else jnp.zeros_like(u)
    return SinnamonState(
        mappings=mappings,
        u=u,
        l=l,
        bits=bitindex.empty(spec.index_buckets or spec.n, spec.capacity),
        store=vecstore.empty(spec.capacity if store_rows is None
                             else store_rows, spec.max_nnz,
                             dtype=jnp.dtype(spec.value_dtype)),
        active=jnp.zeros((spec.capacity,), jnp.bool_),
        ids=jnp.full((spec.capacity, 2), _EMPTY_ID, jnp.uint32),
        dirty=jnp.zeros((spec.capacity,), jnp.bool_),
    )


def insert(state: SinnamonState, spec: EngineSpec, slot, ext_id,
           idx: Array, val: Array) -> SinnamonState:
    """Algorithm 5: index one document at ``slot``.

    ``ext_id`` is the packed uint32[2] form of the external int64 id
    (see :func:`pack_ids64`).

    A clean slot gets the document's exact sketch column.  A *dirty* slot
    (recycled after a §4.3 deletion) is MERGED into — max for u, min for l —
    so the column still upper/lower-bounds every value it ever saw.  The bound
    stays valid but loose; the slot stays dirty until compaction rebuilds it.
    """
    u_col, l_col = sketch.encode(state.mappings, spec.m, idx, val,
                                 dtype=spec.dtype,
                                 positive_only=spec.upper_only)
    was_dirty = state.dirty[slot]
    u_col = u_col.astype(state.u.dtype)
    u_col = jnp.where(was_dirty, jnp.maximum(state.u[:, slot], u_col), u_col)
    u = state.u.at[:, slot].set(u_col)
    if state.l is None:
        l = None
    else:
        l_col = l_col.astype(state.l.dtype)
        l_col = jnp.where(was_dirty, jnp.minimum(state.l[:, slot], l_col),
                          l_col)
        l = state.l.at[:, slot].set(l_col)
    bits = bitindex.set_doc(state.bits, coord_rows(spec, idx), slot,
                            on=True)
    store = vecstore.write(state.store, slot, idx, val)
    return state._replace(
        u=u, l=l, bits=bits, store=store,
        active=state.active.at[slot].set(True),
        ids=state.ids.at[slot].set(ext_id),
    )


# -- vectorized batch mutations ----------------------------------------------
# The host allocator guarantees every batch touches UNIQUE slots (free-list
# pops for inserts; deduped id->slot lookups for deletes), which makes whole
# batches expressible as single-dispatch scatters instead of a lax.scan of
# per-document whole-state updates:
#
# * sketch columns: one encode_batch + one dirty-aware merged column scatter;
# * membership bits: one scatter-ADD (insert) / scatter-SUBTRACT (delete) of
#   per-coordinate word masks.  Distinct slots in one batch touch distinct
#   bits even when they share a word, and within one document duplicate
#   bitmap rows (index_buckets collisions) are routed out-of-bounds after the
#   first occurrence, so every (row, word, bit) is touched at most once and
#   add == bitwise-OR / subtract == bit-clear.  This leans on the engine
#   invariant that a free slot's bit column is all-zero (delete clears
#   exactly the rows its stored document set) — the same invariant the
#   sequential path needs for its OR to mean "insert".
# * VecStore / active / ids: one batched row scatter each.
#
# Masked-off entries are routed out-of-bounds and dropped, so the masked
# variants stay exact no-ops per entry (the shard_map-body contract).  The
# lax.scan forms survive as *_scan reference oracles (tests assert tree
# equality between the two on randomized streams).


def _dedup_first(rows: Array) -> Array:
    """bool[..., P]: True at the FIRST occurrence of each row within a doc."""
    eq = rows[..., :, None] == rows[..., None, :]          # [..., P, P]
    earlier = jnp.tril(jnp.ones((rows.shape[-1],) * 2, jnp.bool_), -1)
    return ~jnp.any(eq & earlier, axis=-1)


def _bit_scatter_operands(state, spec, slots, idx, mask):
    """(rows, words, bitmasks) for one batched membership-bit scatter.

    Invalid coordinates, duplicate in-document rows and masked-off documents
    are routed to the out-of-bounds row (dropped by the scatter).
    """
    rows = coord_rows(spec, idx)                           # [B, P]
    keep = (idx >= 0) & mask[:, None] & _dedup_first(rows)
    oob = jnp.int32(state.bits.shape[0])
    safe_rows = jnp.where(keep, rows, oob)
    words = jnp.broadcast_to((slots // bitindex.WORD)[:, None], rows.shape)
    bitm = (jnp.uint32(1) << (slots % bitindex.WORD).astype(jnp.uint32))
    bitm = jnp.broadcast_to(bitm[:, None], rows.shape)
    return safe_rows, words, bitm


def insert_batch_masked(state: SinnamonState, spec: EngineSpec, slots: Array,
                        ext_ids: Array, idx: Array, val: Array,
                        mask: Array) -> SinnamonState:
    """Vectorized batch insert; ``mask=False`` entries are exact no-ops.

    One device dispatch for the whole batch (see the module comment above for
    the uniqueness/invariant preconditions).  ``ext_ids``: packed uint32[B, 2]
    external ids.  This is also the shard_map-body form: each shard receives
    a host-routed, padded slice of the update batch and applies only its own
    entries, so a sharded insert needs no collectives
    (see repro.serving.sharded).
    """
    u_cols, l_cols = sketch.encode_batch(state.mappings, spec.m, idx, val,
                                         dtype=spec.dtype,
                                         positive_only=spec.upper_only)
    cap = state.active.shape[0]
    safe_slots = jnp.where(mask, slots, cap)               # OOB -> dropped

    was_dirty = state.dirty[slots]                         # [B]
    u_new = u_cols.T.astype(state.u.dtype)                 # [m, B]
    u_new = jnp.where(was_dirty[None, :],
                      jnp.maximum(state.u[:, slots], u_new), u_new)
    u = state.u.at[:, safe_slots].set(u_new, mode="drop")
    if state.l is None:
        l = None
    else:
        l_new = l_cols.T.astype(state.l.dtype)
        l_new = jnp.where(was_dirty[None, :],
                          jnp.minimum(state.l[:, slots], l_new), l_new)
        l = state.l.at[:, safe_slots].set(l_new, mode="drop")

    rows, words, bitm = _bit_scatter_operands(state, spec, slots, idx, mask)
    bits = state.bits.at[rows, words].add(bitm, mode="drop")

    store = vecstore.VecStore(
        indices=state.store.indices.at[safe_slots].set(idx, mode="drop"),
        values=state.store.values.at[safe_slots].set(
            val.astype(state.store.values.dtype), mode="drop"))
    return state._replace(
        u=u, l=l, bits=bits, store=store,
        active=state.active.at[safe_slots].set(True, mode="drop"),
        ids=state.ids.at[safe_slots].set(ext_ids, mode="drop"),
    )


def insert_batch(state: SinnamonState, spec: EngineSpec, slots: Array,
                 ext_ids: Array, idx: Array, val: Array) -> SinnamonState:
    """Vectorized batch insert over unique slots (one jit dispatch)."""
    return insert_batch_masked(state, spec, slots, ext_ids, idx, val,
                               jnp.ones(slots.shape, jnp.bool_))


def delete_batch_masked(state: SinnamonState, spec: EngineSpec, slots: Array,
                        mask: Array) -> SinnamonState:
    """Vectorized masked batch delete; the shard_map-body twin of delete.

    Reads the deleted documents' coordinate rows from the resident VecStore;
    the tiered index supplies them from its host backing instead via
    :func:`delete_batch_rows`.
    """
    return delete_batch_rows(state, spec, slots, state.store.indices[slots],
                             mask)


def delete_batch_rows(state: SinnamonState, spec: EngineSpec, slots: Array,
                      idx: Array, mask: Array) -> SinnamonState:
    """Masked batch delete with the coordinate rows ``idx`` [B, P] passed in.

    Bit-clearing is a scatter-SUBTRACT of the same per-coordinate word masks
    the insert scatter added: each targeted bit is guaranteed set (the slot's
    stored document set exactly these rows), so subtraction borrows nothing.
    """
    rows, words, bitm = _bit_scatter_operands(state, spec, slots, idx, mask)
    bits = state.bits.at[rows, words].add(jnp.uint32(0) - bitm, mode="drop")

    cap = state.active.shape[0]
    safe_slots = jnp.where(mask, slots, cap)
    store = vecstore.VecStore(
        indices=state.store.indices.at[safe_slots].set(-1, mode="drop"),
        values=state.store.values.at[safe_slots].set(0, mode="drop"))
    return state._replace(
        bits=bits, store=store,
        active=state.active.at[safe_slots].set(False, mode="drop"),
        ids=state.ids.at[safe_slots].set(jnp.uint32(0xFFFFFFFF), mode="drop"),
        dirty=state.dirty.at[safe_slots].set(True, mode="drop"),
    )


# -- sequential reference oracles (tests only) --------------------------------

def insert_batch_scan(state: SinnamonState, spec: EngineSpec, slots: Array,
                      ext_ids: Array, idx: Array, val: Array) -> SinnamonState:
    """Sequential-semantics batch insert (scan); the vectorized oracle."""

    def body(st, args):
        slot, eid, i, v = args
        return insert(st, spec, slot, eid, i, v), None

    state, _ = jax.lax.scan(body, state, (slots, ext_ids, idx, val))
    return state


def insert_batch_masked_scan(state: SinnamonState, spec: EngineSpec,
                             slots: Array, ext_ids: Array, idx: Array,
                             val: Array, mask: Array) -> SinnamonState:
    """Scan twin of :func:`insert_batch_masked` (reference oracle)."""

    def body(st, args):
        slot, eid, i, v, ok = args
        st = jax.lax.cond(ok, lambda s: insert(s, spec, slot, eid, i, v),
                          lambda s: s, st)
        return st, None

    state, _ = jax.lax.scan(body, state, (slots, ext_ids, idx, val, mask))
    return state


def delete_batch_masked_scan(state: SinnamonState, spec: EngineSpec,
                             slots: Array, mask: Array) -> SinnamonState:
    """Scan twin of :func:`delete_batch_masked` (reference oracle)."""

    def body(st, args):
        slot, ok = args
        st = jax.lax.cond(ok, lambda s: delete(s, spec, slot),
                          lambda s: s, st)
        return st, None

    state, _ = jax.lax.scan(body, state, (slots, mask))
    return state


def delete(state: SinnamonState, spec: EngineSpec, slot) -> SinnamonState:
    """Paper §4.3: clear inverted-index bits; leave the sketch column stale.

    The stale column is marked dirty so the next insert merges rather than
    overwrites, and compaction knows which columns to rebuild.
    """
    idx = state.store.indices[slot]
    bits = bitindex.set_doc(state.bits, coord_rows(spec, idx), slot,
                            on=False)
    store = vecstore.erase(state.store, slot)
    return state._replace(
        bits=bits, store=store,
        active=state.active.at[slot].set(False),
        ids=state.ids.at[slot].set(jnp.uint32(0xFFFFFFFF)),
        dirty=state.dirty.at[slot].set(True),
    )


def grow_state(state: SinnamonState, spec: EngineSpec,
               new_spec: EngineSpec) -> SinnamonState:
    """Pad every per-slot axis from spec.capacity to new_spec.capacity.

    Pure function of the arrays (slot numbering is preserved), so it works
    both as the host-side reallocation of :class:`SinnamonIndex` and as a
    shard-local shard_map body where each shard grows its own slot range.
    """
    c = spec.capacity
    placeholder = state.store.indices.shape[0] == 0    # tiered: stays empty
    st = init(new_spec, store_rows=0 if placeholder else None)
    return SinnamonState(
        mappings=state.mappings,
        u=st.u.at[:, :c].set(state.u),
        l=None if state.l is None else st.l.at[:, :c].set(state.l),
        bits=st.bits.at[:, : c // 32].set(state.bits),
        store=st.store if placeholder else vecstore.VecStore(
            indices=st.store.indices.at[:c].set(state.store.indices),
            values=st.store.values.at[:c].set(state.store.values)),
        active=st.active.at[:c].set(state.active),
        ids=st.ids.at[:c].set(state.ids),
        dirty=st.dirty.at[:c].set(state.dirty),
    )


# ---------------------------------------------------------------------------
# Sketch compaction (repro.persist.compact drives these; pure so they work as
# shard_map bodies too)
# ---------------------------------------------------------------------------

def fresh_sketch(state: SinnamonState, spec: EngineSpec
                 ) -> Tuple[Array, Optional[Array]]:
    """Exact sketch matrix re-encoded from the raw vectors in the VecStore.

    Returns (u[m, C], l[m, C]).  Erased slots encode to all-zero columns.
    This is the Theorem 5.1-tight reference: no recycled-slot residue.
    """
    u, l = sketch.encode_batch(
        state.mappings, spec.m, state.store.indices,
        state.store.values.astype(jnp.float32),
        dtype=spec.dtype, positive_only=spec.upper_only)
    return u.T, None if l is None else l.T


def compact_state(state: SinnamonState, spec: EngineSpec) -> SinnamonState:
    """Rebuild every dirty sketch column exactly from the VecStore.

    Dirty+active columns become the document's fresh sketch; dirty+inactive
    (deleted, not yet recycled) columns become zero.  Clean columns are left
    untouched bit-for-bit.  Pure function of the arrays — usable directly or
    as a shard-local shard_map body (see repro.serving.sharded).
    """
    u_f, l_f = fresh_sketch(state, spec)
    d = state.dirty[None, :]
    u = jnp.where(d, u_f.astype(state.u.dtype), state.u)
    l = None if state.l is None else jnp.where(
        d, l_f.astype(state.l.dtype), state.l)
    return state._replace(u=u, l=l, dirty=jnp.zeros_like(state.dirty))


def slot_drift(state: SinnamonState, spec: EngineSpec) -> Array:
    """Per-slot sketch overestimate vs. a fresh sketch.  f32[C].

    For each active slot: the max over sketch cells of how far the stored
    upper bound sits ABOVE the tight one (plus, symmetrically, how far the
    stored lower bound sits below).  0 for clean slots (up to storage-dtype
    effects when value_dtype != float32) and for inactive slots.
    """
    u_f, l_f = fresh_sketch(state, spec)
    over = jnp.max(jnp.clip(state.u.astype(jnp.float32)
                            - u_f.astype(jnp.float32), 0.0, None), axis=0)
    if state.l is not None:
        over_l = jnp.max(jnp.clip(l_f.astype(jnp.float32)
                                  - state.l.astype(jnp.float32), 0.0, None),
                         axis=0)
        over = jnp.maximum(over, over_l)
    return jnp.where(state.active, over, 0.0)


def compact_slots_rows(state: SinnamonState, spec: EngineSpec, slots: Array,
                       idx_rows: Array, val_rows: Array,
                       mask: Array) -> SinnamonState:
    """Rebuild the sketch columns of ``slots`` from their raw rows.

    The rows-based twin of :func:`compact_state` for stores whose raw rows
    live off-device (TieredVecStore): the host reads the dirty slots' rows
    from the backing store and passes them in; masked-off entries are exact
    no-ops.  Encoding matches :func:`fresh_sketch` cell-for-cell (erased
    rows encode to zero columns), so compacting the dirty set this way is
    bit-identical to :func:`compact_state`.
    """
    u_cols, l_cols = sketch.encode_batch(
        state.mappings, spec.m, idx_rows, val_rows.astype(jnp.float32),
        dtype=spec.dtype, positive_only=spec.upper_only)
    cap = state.active.shape[0]
    safe = jnp.where(mask, slots, cap)                     # OOB -> dropped
    u = state.u.at[:, safe].set(u_cols.T.astype(state.u.dtype), mode="drop")
    l = None if state.l is None else state.l.at[:, safe].set(
        l_cols.T.astype(state.l.dtype), mode="drop")
    dirty = state.dirty.at[safe].set(False, mode="drop")
    return state._replace(u=u, l=l, dirty=dirty)


def slot_drift_rows(state: SinnamonState, spec: EngineSpec, slots: Array,
                    idx_rows: Array, val_rows: Array) -> Array:
    """Sketch overestimate of ``slots`` given their raw rows.  f32[len(slots)].

    Same per-slot math as :func:`slot_drift`, fed from host-read rows instead
    of the resident VecStore (the tiered index only evaluates dirty slots —
    clean slots report 0 by definition there).
    """
    u_cols, l_cols = sketch.encode_batch(
        state.mappings, spec.m, idx_rows, val_rows.astype(jnp.float32),
        dtype=spec.dtype, positive_only=spec.upper_only)
    over = jnp.max(jnp.clip(state.u[:, slots].astype(jnp.float32)
                            - u_cols.T.astype(jnp.float32), 0.0, None), axis=0)
    if state.l is not None:
        over_l = jnp.max(jnp.clip(l_cols.T.astype(jnp.float32)
                                  - state.l[:, slots].astype(jnp.float32),
                                  0.0, None), axis=0)
        over = jnp.maximum(over, over_l)
    return jnp.where(state.active[slots], over, 0.0)


def _sorted_query(q_idx: Array, q_val: Array) -> Tuple[Array, Array]:
    """Order query coordinates by |q[j]| descending, padding (idx<0) last."""
    key = jnp.where(q_idx >= 0, jnp.abs(q_val.astype(jnp.float32)), -1.0)
    order = jnp.argsort(-key)
    return q_idx[order], q_val[order]


def score(state: SinnamonState, spec: EngineSpec, q_idx: Array, q_val: Array,
          budget: Optional[int] = None) -> Array:
    """Algorithm 6: upper-bound scores for every slot.  f32[C].

    ``budget`` is the anytime lever: only the ``budget`` largest-|q[j]|
    coordinates are scored (deterministic adaptation of the paper's wall-clock
    budget T; see DESIGN.md §6).  None = all coordinates (T = ∞).
    """
    q_idx, q_val = _sorted_query(q_idx, q_val)
    steps = q_idx.shape[0] if budget is None else min(budget, q_idx.shape[0])
    rows = coord_rows(spec, q_idx)          # bitmap rows in SORTED order

    def body(t, scores):
        j = q_idx[t]
        v = q_val[t].astype(jnp.float32)
        safe_j = jnp.maximum(j, 0)
        ub, lb = sketch.decode_coord(state.mappings, state.u, state.l, safe_j)
        contrib = jnp.where(v > 0, v * ub, v * lb)
        memb = bitindex.row_mask(state.bits, jnp.maximum(rows[t], 0))
        return scores + jnp.where(memb & (j >= 0), contrib, 0.0)

    scores = jnp.zeros((spec.capacity,), jnp.float32)
    return jax.lax.fori_loop(0, steps, body, scores)


def score_grouped(state: SinnamonState, spec: EngineSpec, q_idx: Array,
                  q_val: Array, budget: Optional[int] = None) -> Array:
    """Beyond-paper scoring schedule (EXPERIMENTS.md §Perf): process all
    budgeted coordinates in ONE fused pass instead of a coordinate-at-a-time
    loop.  Same math as :func:`score`; the sketch/bitmap rows are gathered as
    a single [L, ·] batch and reduced with one einsum-style sum, which lets
    XLA keep the candidate tile resident instead of re-walking scores[C] per
    coordinate (psi_q x fewer accumulator read-modify-writes).
    """
    q_idx, q_val = _sorted_query(q_idx, q_val)
    L = q_idx.shape[0] if budget is None else min(budget, q_idx.shape[0])
    j = q_idx[:L]
    v = q_val[:L].astype(jnp.float32)
    safe = jnp.where(j >= 0, j, 0)
    rows = state.mappings[:, safe]                           # [h, L]
    ub = jnp.min(state.u[rows].astype(jnp.float32), axis=0)  # [L, C]
    if state.l is None:
        lb = jnp.zeros_like(ub)
    else:
        lb = jnp.max(state.l[rows].astype(jnp.float32), axis=0)
    contrib = jnp.where(v[:, None] > 0, v[:, None] * ub, v[:, None] * lb)
    bit_rows = jnp.maximum(coord_rows(spec, j), 0)
    memb = bitindex.unpack_row(state.bits[bit_rows])         # [L, C]
    contrib = jnp.where(memb & (j >= 0)[:, None], contrib, 0.0)
    return jnp.sum(contrib, axis=0)


def score_batch(state, spec, q_idx, q_val, budget=None, grouped=False
                ) -> Array:
    """[B, C] upper-bound scores for a batch of queries."""
    fn = score_grouped if grouped else score
    return jax.vmap(lambda i, v: fn(state, spec, i, v, budget))(q_idx, q_val)


def topk_candidates(state: SinnamonState, spec: EngineSpec, q_idx: Array,
                    q_val: Array, kprime: int, budget: Optional[int] = None,
                    filter_mask: Optional[Array] = None, score_fn=None,
                    backend: Optional[str] = None):
    """Batched candidate generation: the Algorithm 6 front half of search.

    q_idx/q_val: [B, Lq].  Returns (upper_bounds f32[B, kprime],
    slots int32[B, kprime]) ordered by (upper bound desc, slot asc) — every
    backend produces this order bit-identically, which is what lets the
    fused Pallas path be the drop-in production default.

    backend: ``reference | grouped | pallas`` (None -> the process default,
    see repro.kernels.ops.resolve_backend).  ``score_fn`` overrides the
    backend with a custom per-query dense scorer (legacy hook).
    """
    from repro.kernels import ops as _ops   # deferred: kernels import engine

    ok = state.active if filter_mask is None else (state.active & filter_mask)
    backend = _ops.resolve_backend(backend)
    if score_fn is None and backend == "pallas":
        return _ops.sinnamon_topk_batch(state, spec, q_idx, q_val, kprime,
                                        budget=budget, ok=ok)
    fn = score_fn if score_fn is not None else (
        score_grouped if backend == "grouped" else score)
    s = jax.vmap(lambda i, v: fn(state, spec, i, v, budget))(q_idx, q_val)
    s = jnp.where(ok[None, :], s, -jnp.inf)
    vals, slots = jax.lax.top_k(s, kprime)
    return vals, slots.astype(jnp.int32)


def search(state: SinnamonState, spec: EngineSpec, q_idx: Array, q_val: Array,
           k: int, kprime: int, budget: Optional[int] = None,
           filter_mask: Optional[Array] = None,
           score_fn=None, backend: Optional[str] = None):
    """Algorithms 6+7: candidate generation → sparse exact rerank → top-k.

    filter_mask: optional bool[C] for constrained search (paper §4.2.4, Eq. 3).
    score_fn: override the scoring backend with a custom dense scorer.
    backend: ``reference | grouped | pallas`` candidate backend (see
    :func:`topk_candidates`).  The rerank gathers only the k' candidate CSR
    rows (no dense R^n query), identical across backends.
    Returns (packed ids uint32[k, 2], exact_scores f32[k], slots int32[k]).
    """
    cand_scores, cand_slots = topk_candidates(
        state, spec, q_idx[None], q_val[None], kprime, budget, filter_mask,
        score_fn=score_fn, backend=backend)
    cand_scores, cand_slots = cand_scores[0], cand_slots[0]
    exact = vecstore.exact_scores_sparse(state.store, cand_slots, q_idx, q_val)
    exact = jnp.where(jnp.isneginf(cand_scores), -jnp.inf, exact)
    top_scores, pos = jax.lax.top_k(exact, k)
    slots = cand_slots[pos]
    return state.ids[slots], top_scores, slots


def rerank_topk(state, cand_scores, cand_slots, q_idx, q_val, k):
    """Algorithm 7 back half: sparse exact rerank of [B, k'] candidates.

    Gathers only the candidate CSR rows (no dense R^n query), masks slots
    whose upper bound was gated to -inf, and returns the exact top-k:
    (packed ids uint32[B, k, 2], scores f32[B, k], slots int32[B, k]).
    Shared by :func:`search_batch` and the staged serving path so both
    rerank bit-identically.
    """
    exact = jax.vmap(
        lambda s_, i, v: vecstore.exact_scores_sparse(state.store, s_, i, v)
    )(cand_slots, q_idx, q_val)
    exact = jnp.where(jnp.isneginf(cand_scores), -jnp.inf, exact)
    top_scores, pos = jax.lax.top_k(exact, k)
    slots = jnp.take_along_axis(cand_slots, pos, axis=-1)
    return state.ids[slots], top_scores, slots


def rerank_topk_rows(state, cand_scores, cand_slots, rows_idx, rows_val,
                     q_idx, q_val, k):
    """:func:`rerank_topk` with the candidate CSR rows passed in directly.

    The tiered path: ``TieredVecStore.gather_rows`` supplies
    ``rows_idx``/``rows_val`` as flat ``[B*k', P]`` (or ``[B, k', P]``)
    arrays and the exact scores go through the same
    ``vecstore.exact_scores_rows`` primitive the resident rerank uses, so
    both paths produce bit-identical (ids, scores, slots).
    """
    B, kp = cand_slots.shape
    Pw = rows_idx.shape[-1]
    ri = rows_idx.reshape(B, kp, Pw)
    rv = rows_val.reshape(B, kp, Pw)
    exact = jax.vmap(vecstore.exact_scores_rows)(ri, rv, q_idx, q_val)
    exact = jnp.where(jnp.isneginf(cand_scores), -jnp.inf, exact)
    top_scores, pos = jax.lax.top_k(exact, k)
    slots = jnp.take_along_axis(cand_slots, pos, axis=-1)
    return state.ids[slots], top_scores, slots


def rerank_single_rows(state, cand_scores, cand_slots, rows_idx, rows_val,
                       q_idx, q_val, k):
    """:func:`search`'s single-query rerank tail with the rows passed in.

    The unbatched rerank sums in a different (shape-dependent) order than
    the vmapped one, so the tiered single-query path must mirror
    :func:`search` exactly — not go through the batched rerank — to stay
    bit-identical to the resident ``SinnamonIndex.search``.
    """
    exact = vecstore.exact_scores_rows(rows_idx, rows_val, q_idx, q_val)
    exact = jnp.where(jnp.isneginf(cand_scores), -jnp.inf, exact)
    top_scores, pos = jax.lax.top_k(exact, k)
    slots = cand_slots[pos]
    return state.ids[slots], top_scores, slots


def search_batch(state, spec, q_idx, q_val, k, kprime, budget=None,
                 filter_mask=None, score_fn=None,
                 backend: Optional[str] = None):
    """Batched search [B, Lq] -> ([B, k] ids/scores/slots), ONE dispatch.

    Candidate generation is batch-native (the fused kernel's grid covers the
    whole batch); only the k'-row sparse rerank is vmapped.
    """
    cand_scores, cand_slots = topk_candidates(
        state, spec, q_idx, q_val, kprime, budget, filter_mask,
        score_fn=score_fn, backend=backend)
    return rerank_topk(state, cand_scores, cand_slots, q_idx, q_val, k)


def search_batch_sketch(state, spec, q_idx, q_val, k, budget=None,
                        backend: Optional[str] = None):
    """Sketch-only batched search: Algorithm 6 with NO exact rerank.

    Answers straight from the top-k sketch upper bounds — the cheapest
    answer the index can produce (the paper's lite regime taken to its
    limit: scores are Theorem 5.1 upper bounds, not inner products, and
    ranking quality is whatever the sketch alone provides).  This is the
    serving brownout lever: under overload the front door trades rerank
    cost for availability and stamps results ``degraded``.
    Returns (packed ids uint32[B, k, 2], upper_bounds f32[B, k],
    slots int32[B, k]).
    """
    ub, slots = topk_candidates(state, spec, q_idx, q_val, k, budget,
                                None, backend=backend)
    return state.ids[slots], ub, slots


# ---------------------------------------------------------------------------
# Host wrapper: slot allocation, id mapping, growth
# ---------------------------------------------------------------------------

class _WritePathMetrics:
    """Write-path metric handles, lazily bound and revalidated against the
    current process-global registry (so `obs.metrics.set_registry` in tests
    takes effect on indexes created earlier).  Shared by `SinnamonIndex`
    and `ShardedSinnamonIndex`."""

    __slots__ = ("_registry", "_ops", "_docs", "_batch")
    _OPS = ("insert", "insert_many", "delete", "delete_many", "grow", "compact")

    def __init__(self):
        self._registry = None

    def _bind(self):
        reg = obs_metrics.get_registry()
        if reg is not self._registry:
            self._ops = {
                op: (reg.counter("repro_engine_ops_total",
                                 "Engine mutations applied.", labels={"op": op}),
                     reg.histogram(
                         "repro_engine_update_ms",
                         "Host wall time of one mutation, scatter dispatch "
                         "included (async device work not synced).",
                         labels={"op": op}))
                for op in self._OPS}
            self._docs = {
                d: reg.counter("repro_engine_docs_total",
                               "Documents written/removed.", labels={"op": d})
                for d in ("insert", "delete")}
            self._batch = reg.histogram(
                "repro_engine_update_batch_docs",
                "Documents per mutation call.",
                buckets=obs_metrics.DEFAULT_COUNT_BUCKETS)
            self._registry = reg

    def record(self, op: str, t0_s: float, ndocs: int = 0) -> None:
        self._bind()
        count, hist = self._ops[op]
        count.inc()
        hist.observe((time.perf_counter() - t0_s) * 1e3)
        if ndocs:
            self._batch.observe(ndocs)
            self._docs["delete" if op.startswith("delete") else "insert"].inc(ndocs)


class SinnamonIndex:
    """Streaming host-facing index (paper §4's full system, single device).

    Owns the host-side bookkeeping — slot free list, external-id ↔ slot map,
    capacity growth — while every heavy operation stays a jitted pure
    function of :class:`SinnamonState`.  Mutations: :meth:`insert` /
    :meth:`insert_many` (Algorithm 5 sketching + bit-index update),
    :meth:`delete` (§4.3 bit-clear with slot recycling).  Retrieval:
    :meth:`search` / :meth:`search_many` (Algorithm 6 budgeted upper-bound
    candidates + Algorithm 7 exact rerank, through the pluggable scoring
    backend).  Maintenance: :meth:`compact` / :meth:`slot_drift` for churn
    residue, :meth:`memory_bytes` for the §6.1.2 accounting that the
    ``repro.eval`` harness and auto-tuner report.
    """

    def __init__(self, spec: EngineSpec):
        self.spec = spec
        self.default_backend: Optional[str] = None  # repro.api facade sets this
        self.state = self._init_state()
        self._free = list(range(spec.capacity - 1, -1, -1))  # pop() -> slot 0 first
        self._id2slot: dict[int, int] = {}
        self._insert = jax.jit(insert, static_argnums=(1,))
        self._insert_batch = jax.jit(insert_batch, static_argnums=(1,))
        self._delete = jax.jit(delete, static_argnums=(1,))
        self._search = jax.jit(
            search, static_argnums=(1, 4, 5, 6),
            static_argnames=("score_fn", "backend"))
        self._search_many = jax.jit(
            search_batch, static_argnums=(1, 4, 5, 6),
            static_argnames=("score_fn", "backend"))
        self._search_many_sketch = jax.jit(
            search_batch_sketch, static_argnums=(1, 4, 5),
            static_argnames=("backend",))
        self._compact = jax.jit(compact_state, static_argnums=(1,))
        self._slot_drift = jax.jit(slot_drift, static_argnums=(1,))
        self._obs = _WritePathMetrics()

    def _init_state(self) -> SinnamonState:
        """Fresh device state; the tiered subclass swaps in a placeholder
        store here."""
        return init(self.spec)

    # -- streaming updates ---------------------------------------------------
    def insert(self, ext_id: int, idx, val) -> None:
        t0 = time.perf_counter()
        ext_id = int(ext_id)
        if ext_id in self._id2slot:
            self.delete(ext_id)
        if not self._free:
            self.grow(self.spec.capacity * 2)
        slot = self._free.pop()
        idx, val = pad_sparse(idx, val, self.spec.max_nnz)
        self.state = self._insert(self.state, self.spec, slot,
                                  jnp.asarray(pack_ids64(ext_id)), idx, val)
        self._id2slot[ext_id] = slot
        self._obs.record("insert", t0, 1)

    def insert_many(self, ext_ids, idx_batch, val_batch) -> None:
        t0 = time.perf_counter()
        ext_ids = [int(e) for e in ext_ids]
        if len(set(ext_ids)) != len(ext_ids):
            # Sequential overwrite semantics (same as the sharded index):
            # only the LAST occurrence of a duplicated id survives.
            last = {e: pos for pos, e in enumerate(ext_ids)}
            keep = sorted(last.values())
            ext_ids = [ext_ids[p] for p in keep]
            idx_batch = np.asarray(idx_batch)[keep]
            val_batch = np.asarray(val_batch)[keep]
        for e in ext_ids:
            if e in self._id2slot:      # overwrite: drop the stale copy
                self.delete(e)
        bn = len(ext_ids)
        while len(self._free) < bn:
            self.grow(self.spec.capacity * 2)
        slots = np.array([self._free.pop() for _ in range(bn)], np.int32)
        self.state = self._insert_batch(
            self.state, self.spec, jnp.asarray(slots),
            jnp.asarray(pack_ids64(ext_ids)),
            jnp.asarray(idx_batch), jnp.asarray(val_batch))
        for eid, slot in zip(ext_ids, slots):
            self._id2slot[int(eid)] = int(slot)
        self._obs.record("insert_many", t0, bn)

    def delete(self, ext_id: int) -> None:
        t0 = time.perf_counter()
        slot = self._id2slot.pop(ext_id)
        self.state = self._delete(self.state, self.spec, slot)
        self._free.append(slot)
        self._obs.record("delete", t0, 1)

    # -- retrieval -------------------------------------------------------------
    def search(self, q_idx, q_val, k: int, kprime: Optional[int] = None,
               budget: Optional[int] = None, filter_mask=None, score_fn=None,
               backend: Optional[str] = None):
        kprime = kprime if kprime is not None else max(5 * k, k)
        kprime = min(kprime, self.spec.capacity)
        k = min(k, kprime)
        ids, scores, _ = self._search(
            self.state, self.spec, jnp.asarray(q_idx), jnp.asarray(q_val),
            k, kprime, budget, filter_mask, score_fn=score_fn,
            backend=self._backend(backend))
        return unpack_ids64(np.asarray(ids)), np.asarray(scores)

    def search_many(self, q_idx, q_val, k: int, kprime: Optional[int] = None,
                    budget: Optional[int] = None, filter_mask=None,
                    score_fn=None, backend: Optional[str] = None):
        """Batched search: q_idx/q_val are [B, Lq]; one jit dispatch total."""
        kprime = kprime if kprime is not None else max(5 * k, k)
        kprime = min(kprime, self.spec.capacity)
        k = min(k, kprime)
        ids, scores, _ = self._search_many(
            self.state, self.spec, jnp.asarray(q_idx), jnp.asarray(q_val),
            k, kprime, budget, filter_mask, score_fn=score_fn,
            backend=self._backend(backend))
        return unpack_ids64(np.asarray(ids)), np.asarray(scores)

    def search_many_sketch(self, q_idx, q_val, k: int,
                           budget: Optional[int] = None,
                           backend: Optional[str] = None):
        """Batched sketch-only search (no exact rerank): the degraded
        serving path.  Scores are sketch UPPER BOUNDS, not inner products
        — see :func:`search_batch_sketch`."""
        k = min(k, self.spec.capacity)
        ids, ub, _ = self._search_many_sketch(
            self.state, self.spec, jnp.asarray(q_idx), jnp.asarray(q_val),
            k, budget, backend=self._backend(backend))
        return unpack_ids64(np.asarray(ids)), np.asarray(ub)

    def _backend(self, backend) -> str:
        """Resolve the backend OUTSIDE jit so the default binds at call
        time (not at trace time) and jit caches key on the concrete choice.
        Per-call choice > the index default (``repro.api`` sets it from
        ``IndexConfig.backend``) > the process env default."""
        from repro.kernels import ops as _ops
        if backend is None:
            backend = self.default_backend
        return _ops.resolve_backend(backend)

    # -- capacity management ----------------------------------------------------
    def grow(self, new_capacity: int) -> None:
        """Reallocate to a larger capacity, preserving slot numbering."""
        t0 = time.perf_counter()
        spec = self.spec
        if new_capacity <= spec.capacity or new_capacity % 32 != 0:
            raise ValueError("new capacity must be a larger multiple of 32")
        new_spec = dataclasses.replace(spec, capacity=new_capacity)
        self.state = grow_state(self.state, spec, new_spec)
        self.spec = new_spec
        self._free = (list(range(new_capacity - 1, spec.capacity - 1, -1))
                      + self._free)
        self._obs.record("grow", t0)

    # -- maintenance -----------------------------------------------------------
    def compact(self) -> int:
        """Rebuild all dirty sketch columns from the VecStore.

        Restores the Theorem 5.1 upper-bound tightness lost to §4.3
        delete-then-recycle churn.  Returns the number of columns rebuilt.
        """
        t0 = time.perf_counter()
        n_dirty = int(jnp.sum(self.state.dirty))
        if n_dirty:
            self.state = self._compact(self.state, self.spec)
        self._obs.record("compact", t0)
        return n_dirty

    def slot_drift(self) -> np.ndarray:
        """Per-slot sketch overestimate vs. a fresh sketch (f32[C])."""
        return np.asarray(self._slot_drift(self.state, self.spec))

    @property
    def size(self) -> int:
        return len(self._id2slot)

    def __contains__(self, ext_id) -> bool:
        """True iff ``ext_id`` is currently live in the index."""
        return int(ext_id) in self._id2slot

    def doc_ids(self) -> list:
        """Sorted external ids of every live document."""
        return sorted(self._id2slot)

    def memory_bytes(self) -> dict:
        """Index-size accounting (paper §6.1.2): sketch vs inverted index vs raw."""
        st = self.state
        out = {
            "sketch": st.u.size * st.u.dtype.itemsize
                      + (0 if st.l is None else st.l.size * st.l.dtype.itemsize),
            "inverted_index": st.bits.size * st.bits.dtype.itemsize,
            "storage": st.store.indices.size * st.store.indices.dtype.itemsize
                       + st.store.values.size * st.store.values.dtype.itemsize,
        }
        out["index_total"] = out["sketch"] + out["inverted_index"]
        return out


class TieredSinnamonIndex(SinnamonIndex):
    """SinnamonIndex whose raw VecStore is hot/cold tiered.

    The sketch (and bit index, active, ids, dirty) stays fully
    device-resident; ``state.store`` is a zero-row placeholder and the raw
    CSR rows live in a :class:`repro.storage.tiered.TieredVecStore` — host
    RAM backing behind a bounded device-side chunk cache — so the corpus can
    outgrow the device budget.  Search runs as two dispatches: sketch-only
    candidate generation, then a host sync of the ``[B, k']`` candidate
    slots drives chunk promotion (candidate-driven prefetch) before the
    rows-based exact rerank.  Every rerank flows through the same
    ``exact_scores_rows`` primitive as the resident baseline, so results
    are bit-identical (tests/test_tiered_store.py enforces this, churn and
    all).  Maintenance (compact / slot_drift) reads dirty rows from the
    host backing in fixed-size blocks; ``slot_drift`` reports 0 for clean
    slots (the resident path also reports value-dtype quantization noise
    there — tiering only ever evaluates the dirty set).
    """

    _MAINT_BLOCK = 256           # dirty-slot rows per maintenance dispatch

    def __init__(self, spec: EngineSpec, *, tier_chunk_slots: int = 256,
                 device_budget_bytes: Optional[int] = None,
                 cache_chunks: Optional[int] = None):
        from repro.storage import tiered as tiered_mod
        self.tiered = tiered_mod.TieredVecStore(
            spec.capacity, spec.max_nnz, value_dtype=spec.value_dtype,
            chunk_slots=tier_chunk_slots,
            device_budget_bytes=device_budget_bytes,
            cache_chunks=cache_chunks)
        super().__init__(spec)
        self._cand = jax.jit(topk_candidates, static_argnums=(1, 4, 5),
                             static_argnames=("score_fn", "backend"))
        self._rerank_rows = jax.jit(rerank_topk_rows, static_argnums=(7,))
        self._rerank1 = jax.jit(rerank_single_rows, static_argnums=(7,))
        self._delete_rows = jax.jit(delete_batch_rows, static_argnums=(1,))
        self._compact_rows = jax.jit(compact_slots_rows, static_argnums=(1,))
        self._drift_rows = jax.jit(slot_drift_rows, static_argnums=(1,))

    def _init_state(self) -> SinnamonState:
        return init(self.spec, store_rows=0)

    def _placeholder_store(self) -> vecstore.VecStore:
        return vecstore.empty(0, self.spec.max_nnz,
                              dtype=jnp.dtype(self.spec.value_dtype))

    # -- streaming updates ---------------------------------------------------
    def insert(self, ext_id: int, idx, val) -> None:
        i, v = pad_sparse(idx, val, self.spec.max_nnz)
        self.insert_many([ext_id], np.asarray(i)[None], np.asarray(v)[None])

    def insert_many(self, ext_ids, idx_batch, val_batch) -> None:
        t0 = time.perf_counter()
        ext_ids = [int(e) for e in ext_ids]
        if len(set(ext_ids)) != len(ext_ids):
            # Sequential overwrite semantics (same as the resident index):
            # only the LAST occurrence of a duplicated id survives.
            last = {e: pos for pos, e in enumerate(ext_ids)}
            keep = sorted(last.values())
            ext_ids = [ext_ids[p] for p in keep]
            idx_batch = np.asarray(idx_batch)[keep]
            val_batch = np.asarray(val_batch)[keep]
        for e in ext_ids:
            if e in self._id2slot:      # overwrite: drop the stale copy
                self.delete(e)
        bn = len(ext_ids)
        while len(self._free) < bn:
            self.grow(self.spec.capacity * 2)
        slots = np.array([self._free.pop() for _ in range(bn)], np.int32)
        idx_np = _pad_rows(np.asarray(idx_batch, np.int32),
                           self.spec.max_nnz, -1)
        val_np = _pad_rows(np.asarray(val_batch, np.float32),
                           self.spec.max_nnz, 0)
        # Host backing first (write-through), chunks pinned until the
        # device-side sketch/bit update for this in-flight batch is issued.
        chunks = self.tiered.write_rows(slots, idx_np, val_np, pin=True)
        try:
            self.state = self._insert_batch(
                self.state, self.spec, jnp.asarray(slots),
                jnp.asarray(pack_ids64(ext_ids)),
                jnp.asarray(idx_np), jnp.asarray(val_np))
        finally:
            self.tiered.unpin(chunks)
        for eid, slot in zip(ext_ids, slots):
            self._id2slot[int(eid)] = int(slot)
        self._obs.record("insert_many", t0, bn)

    def delete(self, ext_id: int) -> None:
        t0 = time.perf_counter()
        slot = self._id2slot.pop(int(ext_id))
        row = self.tiered.read_indices(np.array([slot]))
        self.state = self._delete_rows(
            self.state, self.spec, jnp.asarray(np.array([slot], np.int32)),
            jnp.asarray(row), jnp.ones((1,), jnp.bool_))
        self.tiered.erase_rows(np.array([slot]))
        self._free.append(slot)
        self._obs.record("delete", t0, 1)

    # -- retrieval -----------------------------------------------------------
    def search(self, q_idx, q_val, k: int, kprime: Optional[int] = None,
               budget: Optional[int] = None, filter_mask=None, score_fn=None,
               backend: Optional[str] = None):
        kprime = kprime if kprime is not None else max(5 * k, k)
        kprime = min(kprime, self.spec.capacity)
        k = min(k, kprime)
        qi, qv = jnp.asarray(q_idx), jnp.asarray(q_val)
        ub, slots = self._cand(self.state, self.spec, qi[None], qv[None],
                               kprime, budget, filter_mask, score_fn=score_fn,
                               backend=self._backend(backend))
        ub, slots = ub[0], slots[0]
        ridx, rval = self.tiered.gather_rows(np.asarray(slots))
        ids, scores, _ = self._rerank1(self.state, ub, slots, ridx, rval,
                                       qi, qv, k)
        return unpack_ids64(np.asarray(ids)), np.asarray(scores)

    def search_many(self, q_idx, q_val, k: int, kprime: Optional[int] = None,
                    budget: Optional[int] = None, filter_mask=None,
                    score_fn=None, backend: Optional[str] = None):
        """Two dispatches: sketch-scan candidates, then rows-based rerank
        fed by the chunk cache (the ``[B, k']`` slot sync between them is
        what drives promotion)."""
        kprime = kprime if kprime is not None else max(5 * k, k)
        kprime = min(kprime, self.spec.capacity)
        k = min(k, kprime)
        qi, qv = jnp.asarray(q_idx), jnp.asarray(q_val)
        ub, slots = self._cand(self.state, self.spec, qi, qv, kprime, budget,
                               filter_mask, score_fn=score_fn,
                               backend=self._backend(backend))
        ridx, rval = self.tiered.gather_rows(np.asarray(slots).reshape(-1))
        ids, scores, _ = self._rerank_rows(self.state, ub, slots, ridx, rval,
                                           qi, qv, k)
        return unpack_ids64(np.asarray(ids)), np.asarray(scores)

    # -- capacity / maintenance ----------------------------------------------
    def grow(self, new_capacity: int) -> None:
        super().grow(new_capacity)          # grow_state keeps the placeholder
        self.tiered.grow(new_capacity)

    def _maint_blocks(self):
        """Yield (slots[B], mask[B], n_real) fixed-size blocks of dirty slots."""
        dirty = np.flatnonzero(np.asarray(self.state.dirty))
        B = self._MAINT_BLOCK
        for i in range(0, dirty.size, B):
            blk = dirty[i:i + B]
            slots = np.zeros((B,), np.int32)
            mask = np.zeros((B,), bool)
            slots[:blk.size] = blk
            mask[:blk.size] = True
            yield slots, mask, blk.size

    def compact(self) -> int:
        t0 = time.perf_counter()
        total = 0
        for slots, mask, n in self._maint_blocks():
            ridx, rval = self.tiered.read_rows(slots)
            self.state = self._compact_rows(
                self.state, self.spec, jnp.asarray(slots), jnp.asarray(ridx),
                jnp.asarray(rval), jnp.asarray(mask))
            total += n
        self._obs.record("compact", t0)
        return total

    def slot_drift(self) -> np.ndarray:
        out = np.zeros((self.spec.capacity,), np.float32)
        for slots, mask, n in self._maint_blocks():
            ridx, rval = self.tiered.read_rows(slots)
            d = np.asarray(self._drift_rows(self.state, self.spec,
                                            jnp.asarray(slots),
                                            jnp.asarray(ridx),
                                            jnp.asarray(rval)))
            out[slots[:n]] = d[:n]
        return out

    def memory_bytes(self) -> dict:
        out = super().memory_bytes()
        out["storage"] = self.tiered.device_bytes()       # device-resident
        out["storage_host"] = self.tiered.host_bytes()    # cold backing
        return out

    # -- persistence hooks (repro.persist.snapshot) --------------------------
    def logical_state(self) -> SinnamonState:
        """The state with the FULL raw store materialized (host arrays) —
        what snapshots serialize, so tiered and resident snapshots are one
        interchangeable format."""
        idx, val = self.tiered.to_arrays()
        return self.state._replace(
            store=vecstore.VecStore(indices=idx, values=val))

    def adopt_logical_state(self, state: SinnamonState) -> None:
        """Install a restored logical state: raw rows go to the host
        backing (tiering state resets to access-free defaults), everything
        else to device with the placeholder store."""
        self.tiered.load_rows(np.asarray(state.store.indices),
                              np.asarray(state.store.values))
        self.state = jax.tree.map(
            jnp.asarray, state._replace(store=self._placeholder_store()))


def _pad_rows(arr: np.ndarray, width: int, fill) -> np.ndarray:
    """Pad [B, L] update rows to the fixed CSR width [B, width]."""
    if arr.shape[1] > width:
        raise ValueError(f"document nnz {arr.shape[1]} > max_nnz {width}")
    if arr.shape[1] == width:
        return arr
    out = np.full((arr.shape[0], width), fill, arr.dtype)
    out[:, :arr.shape[1]] = arr
    return out


def pad_sparse(idx, val, width: int):
    """Pad/truncate a sparse (idx, val) pair to fixed width (pad idx = -1)."""
    idx = np.asarray(idx, np.int32)[:width]
    val = np.asarray(val, np.float32)[:width]
    out_i = np.full((width,), -1, np.int32)
    out_v = np.zeros((width,), np.float32)
    out_i[: idx.size] = idx
    out_v[: val.size] = val
    return jnp.asarray(out_i), jnp.asarray(out_v)
