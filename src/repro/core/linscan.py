"""LinScan: the paper's exact SMIPS baseline (§3, Algorithms 1–4).

Two implementations, both exact:

1. ``LinScanIndex`` — the *faithful* coordinate-at-a-time traversal over an
   inverted index of (slot, value) postings, including the anytime variant
   (Algorithm 4: process coordinates in descending |q[j]| order under a
   postings budget, then rerank k' candidates exactly).  Postings traversal is
   inherently ragged, so this lives in vectorised NumPy on the host — it is
   the ground-truth oracle and the CPU comparison point of the paper.

2. The TPU-native exact scan is `repro.storage.vecstore.exact_scores_all`
   (document-ordered padded-CSR gather — same exact scores, regular memory
   access; see DESIGN.md §2) and is what the distributed serving path uses
   when exact retrieval is requested.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


class LinScanIndex:
    """Exact inverted index over a *static snapshot* plus a streaming tail.

    Streaming inserts/deletes are accumulated in a small uncompacted tail and
    merged into the CSR arrays on demand (``compact()``), mirroring how the
    paper's dynamic arrays amortise reallocation.
    """

    def __init__(self, n: int):
        self.n = n
        # CSR over coordinates: postings sorted by coordinate.
        self._coord_offsets = np.zeros(n + 1, np.int64)
        self._post_slot = np.zeros(0, np.int32)
        self._post_val = np.zeros(0, np.float32)
        # doc-major copies for exact rerank / deletion.
        self._doc_idx: dict[int, np.ndarray] = {}
        self._doc_val: dict[int, np.ndarray] = {}
        self._tail: list[Tuple[int, np.ndarray, np.ndarray]] = []
        self._deleted: set[int] = set()

    # -- updates (Algorithm 1 + §3.1 full deletion) -------------------------
    def insert(self, doc_id: int, idx, val) -> None:
        idx = np.asarray(idx, np.int32)
        val = np.asarray(val, np.float32)
        self._doc_idx[doc_id] = idx
        self._doc_val[doc_id] = val
        self._tail.append((doc_id, idx, val))
        self._deleted.discard(doc_id)

    def insert_many(self, ids, idx_batch, val_batch) -> None:
        for d, i, v in zip(ids, idx_batch, val_batch):
            valid = np.asarray(i) >= 0
            self.insert(int(d), np.asarray(i)[valid], np.asarray(v)[valid])
        self.compact()

    def delete(self, doc_id: int) -> None:
        """Full deletion: postings are physically removed at next compaction."""
        self._deleted.add(doc_id)
        self._doc_idx.pop(doc_id, None)
        self._doc_val.pop(doc_id, None)

    def compact(self) -> None:
        # Rebuild from the doc-major truth (simplest correct full-deletion).
        all_c, all_s, all_v = [], [], []
        for d, i in self._doc_idx.items():
            all_c.append(i)
            all_s.append(np.full(i.size, d, np.int32))
            all_v.append(self._doc_val[d])
        if all_c:
            c = np.concatenate(all_c)
            s = np.concatenate(all_s)
            v = np.concatenate(all_v)
            order = np.argsort(c, kind="stable")
            c, s, v = c[order], s[order], v[order]
        else:
            c = np.zeros(0, np.int32); s = np.zeros(0, np.int32)
            v = np.zeros(0, np.float32)
        self._coord_offsets = np.zeros(self.n + 1, np.int64)
        np.add.at(self._coord_offsets, c + 1, 1)
        self._coord_offsets = np.cumsum(self._coord_offsets)
        self._post_slot, self._post_val = s, v
        self._tail = []

    # -- retrieval (Algorithms 2–4) ------------------------------------------
    def scores(self, q_idx, q_val,
               posting_budget: Optional[int] = None) -> np.ndarray:
        """Coordinate-at-a-time accumulation; budget = anytime Algorithm 4."""
        if self._tail:
            self.compact()
        max_doc = (max(self._doc_idx) + 1) if self._doc_idx else 1
        scores = np.zeros(max_doc, np.float32)
        q_idx = np.asarray(q_idx, np.int64)
        q_val = np.asarray(q_val, np.float32)
        keep = q_idx >= 0
        q_idx, q_val = q_idx[keep], q_val[keep]
        order = np.argsort(-np.abs(q_val), kind="stable")   # Alg. 4 line 2
        spent = 0
        for t in order:
            j, v = q_idx[t], q_val[t]
            lo, hi = self._coord_offsets[j], self._coord_offsets[j + 1]
            if posting_budget is not None:
                hi = min(hi, lo + max(0, posting_budget - spent))
                spent += hi - lo
            if hi > lo:
                np.add.at(scores, self._post_slot[lo:hi],
                          v * self._post_val[lo:hi])
            if posting_budget is not None and spent >= posting_budget:
                break
        return scores

    def exact_score(self, doc_id: int, q_dense: np.ndarray) -> float:
        i = self._doc_idx[doc_id]
        return float(np.dot(q_dense[i], self._doc_val[doc_id]))

    def search(self, q_idx, q_val, k: int,
               kprime: Optional[int] = None,
               posting_budget: Optional[int] = None):
        """Exact top-k (budget=None) or anytime Algorithm 4 (budget set)."""
        s = self.scores(q_idx, q_val, posting_budget)
        if posting_budget is None:
            top = _find_largest(s, k)
            return top, s[top]
        kprime = kprime or 5 * k
        cands = _find_largest(s, min(kprime, s.size))
        q_dense = np.zeros(self.n, np.float32)
        qi = np.asarray(q_idx); qv = np.asarray(q_val, np.float32)
        q_dense[qi[qi >= 0]] = qv[qi >= 0]
        exact = np.array([
            self.exact_score(int(d), q_dense) if int(d) in self._doc_idx
            else -np.inf for d in cands])
        top = _find_largest(exact, min(k, exact.size))
        return cands[top], exact[top]

    def memory_bytes(self) -> int:
        return int(self._post_slot.nbytes + self._post_val.nbytes
                   + self._coord_offsets.nbytes)


def _find_largest(scores: np.ndarray, k: int) -> np.ndarray:
    """Algorithm 3 (FindLargest) — argpartition in place of the binary heap."""
    k = min(k, scores.size)
    part = np.argpartition(-scores, k - 1)[:k]
    return part[np.argsort(-scores[part], kind="stable")]


def brute_force_topk(doc_idx, doc_val, q_idx, q_val, n: int, k: int):
    """Dense brute force (test oracle): returns (ids, scores)."""
    q = np.zeros(n, np.float32)
    qi = np.asarray(q_idx); qv = np.asarray(q_val, np.float32)
    q[qi[qi >= 0]] = qv[qi >= 0]
    scores = np.zeros(len(doc_idx), np.float32)
    for d, (i, v) in enumerate(zip(doc_idx, doc_val)):
        i = np.asarray(i); v = np.asarray(v, np.float32)
        keep = i >= 0
        scores[d] = np.dot(q[i[keep]], v[keep])
    top = _find_largest(scores, k)
    return top, scores[top]
