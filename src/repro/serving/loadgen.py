"""Closed-loop load generator for the serving front door.

Drives a frontend (in-process `ServingFrontend` or the HTTP endpoint) with
``clients`` closed-loop workers that collectively pace to an offered QPS,
and reduces each run to one `LoadPoint`: achieved/goodput throughput,
latency percentiles (p50/p99/p999), and outcome counts.  ``benchmarks/
serving.py`` sweeps offered load through this to produce
``BENCH_serving.json``.

Pacing: a shared arrival schedule at ``offered_qps`` (deterministic,
evenly spaced) is consumed by the workers; each worker sleeps until its
next arrival slot, issues the query, and blocks for the answer (closed
loop).  When the system can't keep up the workers fall behind schedule and
achieved < offered — exactly the saturation signal the sweep is after.

Goodput counts only requests that returned OK *within* the deadline;
rejections (backpressure/quota) and expiries are tallied separately so a
sweep row distinguishes "fast because it sheds" from "fast and correct".
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

import numpy as np

__all__ = ["LoadPoint", "frontend_client", "run_point"]


@dataclass
class LoadPoint:
    """One offered-load operating point, reduced to serving stats."""

    offered_qps: float
    duration_s: float
    clients: int
    ok: int = 0
    rejected: int = 0
    expired: int = 0
    errors: int = 0
    latencies_ms: List[float] = field(default_factory=list)

    @property
    def issued(self) -> int:
        return self.ok + self.rejected + self.expired + self.errors

    @property
    def achieved_qps(self) -> float:
        return self.issued / self.duration_s if self.duration_s > 0 else 0.0

    @property
    def goodput_qps(self) -> float:
        return self.ok / self.duration_s if self.duration_s > 0 else 0.0

    def percentile_ms(self, q: float) -> float:
        """Latency percentile over OK requests; NaN when nothing succeeded."""
        if not self.latencies_ms:
            return float("nan")
        return float(np.percentile(np.asarray(self.latencies_ms), q))

    @property
    def p50_ms(self) -> float:
        return self.percentile_ms(50)

    @property
    def p99_ms(self) -> float:
        return self.percentile_ms(99)

    @property
    def p999_ms(self) -> float:
        return self.percentile_ms(99.9)

    def to_row(self) -> dict:
        return {
            "offered_qps": round(self.offered_qps, 3),
            "achieved_qps": round(self.achieved_qps, 3),
            "goodput_qps": round(self.goodput_qps, 3),
            "p50_ms": round(self.p50_ms, 4),
            "p99_ms": round(self.p99_ms, 4),
            "p999_ms": round(self.p999_ms, 4),
            "ok": self.ok,
            "rejected": self.rejected,
            "expired": self.expired,
            "errors": self.errors,
        }


def run_point(
    client_fn: Callable[[np.ndarray, np.ndarray], str],
    queries: Sequence,
    offered_qps: float,
    *,
    clients: int = 8,
    duration_s: float = 2.0,
    clock=time.monotonic,
    sleep=time.sleep,
) -> LoadPoint:
    """Drive one offered-load point and return its `LoadPoint`.

    ``client_fn(q_idx, q_val)`` issues one query and returns its outcome:
    ``"ok"``, ``"rejected"``, ``"expired"``, or ``"error"`` (anything it
    raises also counts as ``"error"``).  ``queries`` is a sequence of
    ``(q_idx, q_val)`` pairs cycled through by arrival index, so every run
    at the same offered load replays the same work.
    """
    if offered_qps <= 0:
        raise ValueError(f"offered_qps must be > 0, got {offered_qps}")
    point = LoadPoint(offered_qps=float(offered_qps),
                      duration_s=float(duration_s), clients=int(clients))
    period = 1.0 / offered_qps
    n_arrivals = max(1, int(round(offered_qps * duration_s)))
    next_slot = [0]
    lock = threading.Lock()
    start = clock()

    def worker():
        while True:
            with lock:
                slot = next_slot[0]
                if slot >= n_arrivals:
                    return
                next_slot[0] = slot + 1
            at = start + slot * period
            delay = at - clock()
            if delay > 0:
                sleep(delay)
            q_idx, q_val = queries[slot % len(queries)]
            t0 = clock()
            try:
                outcome = client_fn(q_idx, q_val)
            except Exception:                            # noqa: BLE001
                outcome = "error"
            dt_ms = (clock() - t0) * 1e3
            with lock:
                if outcome == "ok":
                    point.ok += 1
                    point.latencies_ms.append(dt_ms)
                elif outcome == "rejected":
                    point.rejected += 1
                elif outcome == "expired":
                    point.expired += 1
                else:
                    point.errors += 1

    threads = [threading.Thread(target=worker, name=f"loadgen-{i}",
                                daemon=True)
               for i in range(max(1, int(clients)))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    # Measure over the actual wall time so achieved_qps is honest when the
    # system falls behind the arrival schedule.
    point.duration_s = max(clock() - start, 1e-9)
    return point


def frontend_client(frontend, *, tenant: str = "default",
                    deadline_ms: Optional[float] = None,
                    k: Optional[int] = None) -> Callable:
    """Adapt a `ServingFrontend` to the ``client_fn`` protocol."""
    from repro.serving.frontend import DeadlineExceeded, Rejected

    def call(q_idx, q_val) -> str:
        try:
            frontend.query(q_idx, q_val, tenant=tenant,
                           deadline_ms=deadline_ms, k=k)
            return "ok"
        except Rejected:
            return "rejected"
        except DeadlineExceeded:
            return "expired"

    return call
