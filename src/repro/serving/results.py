"""Typed query results for the serving surface.

The two-level serving API (documented in docs/serving.md):

* **Level 1 — functional**: ``repro.core.engine.search`` /
  ``search_batch`` are pure jittable functions of ``(state, spec)``;
  they return device arrays and exist for composition (shard_map bodies,
  staged tracing, custom pipelines).
* **Level 2 — host serving**: ``QueryServer.query`` / ``query_many`` (and
  the async front door, ``repro.serving.frontend``) own host concerns —
  metrics, tracing, padding — and return a :class:`QueryResult`.

``QueryResult`` is frozen (the arrays it carries are the response; mutate
copies, not the result) and remains unpackable as the legacy
``(ids, scores)`` tuple so existing call sites keep working during the
migration to the typed surface.

``trace_id`` generation lives in ``repro.obs.trace`` (re-exported here for
compatibility) so every serving layer draws from ONE id namespace: a
result's trace id resolves against the flight recorder at
``/debug/trace/<id>`` regardless of which layer created it.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.obs.trace import new_trace_id

__all__ = ["QueryResult", "new_trace_id"]


@dataclasses.dataclass(frozen=True, eq=False)
class QueryResult:
    """One query (or query batch) answer from the serving surface.

    ``ids``/``scores`` are ``[k]`` for :meth:`QueryServer.query` and
    ``[B, k]`` for :meth:`QueryServer.query_many` / coalesced front-door
    batches.  ``backend`` is the resolved scoring backend that produced the
    candidates (``reference | grouped | pallas | custom``); ``trace_id``
    correlates the response with metric samples and event-log entries.

    Tuple-compat shim: iterating/indexing yields ``(ids, scores)`` so legacy
    ``ids, scores = server.query(...)`` call sites keep working.
    """

    ids: np.ndarray
    scores: np.ndarray
    k: int
    backend: str
    trace_id: str
    #: True when the answer was produced under the serving degradation
    #: ladder (shrunken rerank budget or sketch-only scoring) — scores may
    #: be upper bounds rather than exact inner products.
    degraded: bool = False

    # -- legacy (ids, scores) tuple compatibility ---------------------------
    def __iter__(self):
        return iter((self.ids, self.scores))

    def __getitem__(self, i):
        return (self.ids, self.scores)[i]

    def __len__(self) -> int:
        return 2

    # -- batch helpers -------------------------------------------------------
    @property
    def batch_size(self) -> Optional[int]:
        """B for a batched result, None for a single-query result."""
        return self.ids.shape[0] if self.ids.ndim == 2 else None

    def row(self, i: int, k: Optional[int] = None,
            trace_id: Optional[str] = None) -> "QueryResult":
        """Per-request slice of a batched result (optionally trimmed to a
        smaller ``k``); the front door uses this to split a coalesced batch
        back into individual responses."""
        if self.ids.ndim != 2:
            raise ValueError("row() is only defined on batched results")
        kk = self.k if k is None else min(int(k), self.k)
        return QueryResult(ids=self.ids[i, :kk], scores=self.scores[i, :kk],
                           k=kk, backend=self.backend,
                           trace_id=trace_id or self.trace_id,
                           degraded=self.degraded)
