"""Sharded Sinnamon serving: the paper's engine as an SPMD program.

Corpus slots are sharded over the (pod, model) mesh axes, the query batch over
data.  Scoring and the exact rerank are fully shard-local; only (k'-sized)
candidate tuples cross shards (see repro.distributed.topk).

This module now covers the full *streaming* lifecycle at sharded scale:

* ``make_search_step``  — batched SPMD search (the original serve step),
  returning external ids plus packed (shard, slot) locators.
* ``make_insert_step`` / ``make_delete_step`` — collective-free shard-local
  updates: the host routes each document to its owning shard (hash of the
  external id), pads the per-shard update batches to one rectangle, and every
  shard applies only its masked slice.
* ``make_grow_step``    — shard-local capacity growth (each shard pads its own
  slot range; the re-laid-out global state falls out of the out_specs).
* ``ShardedSinnamonIndex`` — the host wrapper that owns routing, per-shard
  slot free lists, and the id → (shard, slot) map, mirroring the
  single-device ``SinnamonIndex`` API (insert/delete/search/grow).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import engine as eng
from repro.distributed import mesh as meshlib
from repro.distributed import topk
from repro.storage import vecstore


def _corpus_spec(mesh: Mesh):
    corpus = meshlib.corpus_axes(mesh)
    return corpus if len(corpus) > 1 else (corpus[0] if corpus else None)


def state_pspecs(mesh: Mesh, positive_only: bool = False) -> eng.SinnamonState:
    """PartitionSpecs for every SinnamonState leaf (corpus over pod+model).

    ``positive_only`` here means "the state has no ``l`` leaf" — pass
    ``spec.upper_only``, which also covers the §3.3 lite sketch variant.
    """
    c = _corpus_spec(mesh)
    return eng.SinnamonState(
        mappings=P(),                      # replicated
        u=P(None, c),
        l=None if positive_only else P(None, c),
        bits=P(None, c),
        store=vecstore.VecStore(indices=P(c), values=P(c)),
        active=P(c),
        ids=P(c, None),                    # uint32[C, 2] packed int64 ids
        dirty=P(c),
    )


def state_shardings(mesh: Mesh, positive_only: bool = False):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        state_pspecs(mesh, positive_only),
                        is_leaf=lambda x: isinstance(x, P))


def make_search_step(mesh: Mesh, local_spec: eng.EngineSpec, *,
                     k: int, kprime_local: int,
                     budget: Optional[int] = None,
                     score_fn=None, backend: Optional[str] = None):
    """Build the jittable SPMD search step.

    local_spec.capacity is the *per-shard* slot count.  Returns
    ``step(state, q_idx[B, Lq], q_val[B, Lq])
        -> (scores[B, k], ids[B, k, 2], locators[B, k])``
    with the batch sharded over 'data' and outputs replicated over corpus
    axes.  ``ids`` are packed uint32 (lo, hi) words of the external int64 id
    (decode with engine.unpack_ids64); ``locators`` packs (shard, local slot)
    per hit (see topk.pack_shard_slot) so follow-up work routes straight back
    to the owning shard.

    ``backend`` selects the shard-local candidate backend (reference |
    grouped | pallas — the fused kernel runs per shard; only candidate
    tuples cross shards through the existing hierarchical merge).  The exact
    rerank gathers only the k' candidate CSR rows per shard — no [B, n]
    dense query block on any path.
    """
    from repro.kernels import ops as _ops

    corpus = meshlib.corpus_axes(mesh)
    qspec = P("data") if "data" in mesh.axis_names else P()
    backend = _ops.resolve_backend(backend) if score_fn is None else None

    def local_search(state: eng.SinnamonState, q_idx, q_val):
        kl = min(kprime_local, local_spec.capacity)
        if score_fn is not None:
            # Custom scorers keep the original BATCHED sharded contract:
            # score_fn(state, spec, q_idx[b, Lq], q_val[b, Lq], budget)
            # -> [b, C].
            scores = score_fn(state, local_spec, q_idx, q_val, budget)
            scores = jnp.where(state.active[None, :], scores, -jnp.inf)
            ub, slots = jax.lax.top_k(scores, kl)            # [b, kl]
        else:
            ub, slots = eng.topk_candidates(state, local_spec, q_idx, q_val,
                                            kl, budget,
                                            backend=backend)  # [b, kl]
        exact = jax.vmap(
            lambda s, i, v: vecstore.exact_scores_sparse(state.store, s, i, v)
        )(slots, q_idx, q_val)                               # [b, kl]
        exact = jnp.where(jnp.isneginf(ub), -jnp.inf, exact)
        gids = state.ids[slots]                              # [b, kl, 2]
        shard = meshlib.linear_index(mesh, corpus)
        loc = topk.pack_shard_slot(shard, slots)
        payload = (gids[..., 0], gids[..., 1], loc)
        if corpus:
            vals, (lo, hi, loc) = topk.merge_over_axes(
                exact, payload, corpus, k)
            return vals, jnp.stack([lo, hi], axis=-1), loc
        vals, pos = jax.lax.top_k(exact, k)
        take = lambda p: jnp.take_along_axis(p, pos, axis=-1)
        return (vals, jnp.stack([take(payload[0]), take(payload[1])],
                                axis=-1), take(loc))

    sharded = shard_map(
        local_search, mesh=mesh,
        in_specs=(state_pspecs(mesh, local_spec.upper_only), qspec, qspec),
        out_specs=(qspec, qspec, qspec),
        check_rep=False,
    )
    return jax.jit(sharded)


# ---------------------------------------------------------------------------
# Collective-free SPMD updates
# ---------------------------------------------------------------------------
# Update batches arrive as [S, B, ...] rectangles whose leading axis is
# sharded over the corpus axes: shard s sees only its own [1, B, ...] slice,
# applies the mask-valid entries against its local slots, and no bytes ever
# cross shards.  The host (ShardedSinnamonIndex) is responsible for routing —
# entry (s, b) must actually belong to shard s.

def make_insert_step(mesh: Mesh, local_spec: eng.EngineSpec):
    """``step(state, slots[S,B], ids[S,B,2], idx[S,B,P], val[S,B,P],
    mask[S,B])`` → state, with every array's leading axis sharded over the
    corpus axes (``ids`` are packed uint32 lo/hi words, engine.pack_ids64)."""
    c = _corpus_spec(mesh)
    sspec = state_pspecs(mesh, local_spec.upper_only)
    uspec = P(c)

    def local_insert(state, slots, eids, idx, val, mask):
        return eng.insert_batch_masked(state, local_spec, slots[0], eids[0],
                                       idx[0], val[0], mask[0])

    sharded = shard_map(
        local_insert, mesh=mesh,
        in_specs=(sspec, uspec, uspec, uspec, uspec, uspec),
        out_specs=sspec, check_rep=False)
    return jax.jit(sharded)


def make_delete_step(mesh: Mesh, local_spec: eng.EngineSpec):
    """``step(state, slots[S,B], mask[S,B])`` → state (shard-local deletes)."""
    c = _corpus_spec(mesh)
    sspec = state_pspecs(mesh, local_spec.upper_only)
    uspec = P(c)

    def local_delete(state, slots, mask):
        return eng.delete_batch_masked(state, local_spec, slots[0], mask[0])

    sharded = shard_map(
        local_delete, mesh=mesh,
        in_specs=(sspec, uspec, uspec),
        out_specs=sspec, check_rep=False)
    return jax.jit(sharded)


def make_grow_step(mesh: Mesh, local_spec: eng.EngineSpec,
                   new_local_capacity: int):
    """``step(state)`` → state with every shard grown to new_local_capacity.

    Each shard pads its own slot range (pure shard-local grow_state); the
    out_specs re-assemble the blocks into the grown global layout, so slot
    numbering *within a shard* is preserved and no collective is emitted.
    """
    new_spec = dataclasses.replace(local_spec, capacity=new_local_capacity)
    sspec_in = state_pspecs(mesh, local_spec.upper_only)

    def local_grow(state):
        return eng.grow_state(state, local_spec, new_spec)

    sharded = shard_map(local_grow, mesh=mesh, in_specs=(sspec_in,),
                        out_specs=sspec_in, check_rep=False)
    return jax.jit(sharded), new_spec


def make_compact_step(mesh: Mesh, local_spec: eng.EngineSpec):
    """``step(state)`` → state with every shard's dirty sketch columns rebuilt
    from its local VecStore slice (shard-local; no collectives)."""
    sspec = state_pspecs(mesh, local_spec.upper_only)

    def local_compact(state):
        return eng.compact_state(state, local_spec)

    sharded = shard_map(local_compact, mesh=mesh, in_specs=(sspec,),
                        out_specs=sspec, check_rep=False)
    return jax.jit(sharded)


def make_drift_step(mesh: Mesh, local_spec: eng.EngineSpec):
    """``step(state)`` → f32[C_global] per-slot sketch overestimate."""
    c = _corpus_spec(mesh)
    sspec = state_pspecs(mesh, local_spec.upper_only)

    def local_drift(state):
        return eng.slot_drift(state, local_spec)

    sharded = shard_map(local_drift, mesh=mesh, in_specs=(sspec,),
                        out_specs=P(c), check_rep=False)
    return jax.jit(sharded)


def shard_state(state: eng.SinnamonState, mesh: Mesh):
    """Place a host-built (global) state onto the mesh."""
    return jax.device_put(state, state_shardings(mesh, state.l is None))


# ---------------------------------------------------------------------------
# Host wrapper
# ---------------------------------------------------------------------------

class ShardedSinnamonIndex:
    """Streaming host-facing index over a mesh-sharded SinnamonState.

    ``spec.capacity`` is the PER-SHARD slot count; global capacity is
    ``spec.capacity * n_shards``.  Documents are routed to an owning shard by
    a multiplicative hash of the external id, so insert, delete and search
    all agree on placement without any shared table beyond the host's
    id → (shard, slot) dict.  All device work is jitted shard_map programs;
    queries go through the hierarchical top-k merge, so only (k'·shards)
    candidate tuples ever cross shards.
    """

    def __init__(self, spec: eng.EngineSpec, mesh: Mesh, *,
                 update_block: int = 32):
        self.mesh = mesh
        self.spec = spec                       # per-shard spec
        self.default_backend: Optional[str] = None  # repro.api facade sets this
        self.corpus = meshlib.corpus_axes(mesh)
        self.n_shards = meshlib.n_shards(mesh, self.corpus)
        self.update_block = update_block
        global_spec = dataclasses.replace(
            spec, capacity=spec.capacity * self.n_shards)
        self.state = shard_state(eng.init(global_spec), mesh)
        self._free = [list(range(spec.capacity - 1, -1, -1))
                      for _ in range(self.n_shards)]
        self._id2slot: dict[int, tuple[int, int]] = {}
        self._steps: dict = {}
        self._obs = eng._WritePathMetrics()

    # -- routing ------------------------------------------------------------
    def route(self, ext_id: int) -> int:
        """Owning shard of an external id (Knuth multiplicative hash)."""
        return ((int(ext_id) * 2654435761) & 0xFFFFFFFF) % self.n_shards

    def _step(self, key, build):
        if key not in self._steps:
            self._steps[key] = build()
        return self._steps[key]

    # -- streaming updates --------------------------------------------------
    def insert(self, ext_id: int, idx, val) -> None:
        idx = np.asarray(idx, np.int32)
        val = np.asarray(val, np.float32)
        self.insert_many([ext_id], idx[None], val[None])

    def insert_many(self, ext_ids, idx_batch, val_batch) -> None:
        t0 = time.perf_counter()
        ext_ids = [int(e) for e in ext_ids]
        if len(set(ext_ids)) != len(ext_ids):
            # Sequential overwrite semantics: only the LAST occurrence of a
            # duplicated id survives; earlier ones never touch the index.
            last = {e: pos for pos, e in enumerate(ext_ids)}
            keep = sorted(last.values())
            ext_ids = [ext_ids[p] for p in keep]
            idx_batch = np.asarray(idx_batch)[keep]
            val_batch = np.asarray(val_batch)[keep]
        stale = [e for e in ext_ids if e in self._id2slot]
        if stale:
            self.delete_many(stale)
        idx_batch = self._pad(np.asarray(idx_batch, np.int32), -1)
        val_batch = self._pad(np.asarray(val_batch, np.float32), 0)

        per_shard = [[] for _ in range(self.n_shards)]
        for pos, e in enumerate(ext_ids):
            per_shard[self.route(e)].append(pos)
        while any(len(self._free[s]) < len(per_shard[s])
                  for s in range(self.n_shards)):
            self.grow()

        step = self._step("insert", lambda: make_insert_step(self.mesh,
                                                             self.spec))
        S, B, Pw = self.n_shards, self.update_block, self.spec.max_nnz
        packed = eng.pack_ids64(np.asarray(ext_ids, np.int64))
        offsets = [0] * S
        while any(offsets[s] < len(per_shard[s]) for s in range(S)):
            slots = np.zeros((S, B), np.int32)
            eids = np.full((S, B, 2), 0xFFFFFFFF, np.uint32)
            idxs = np.full((S, B, Pw), -1, np.int32)
            vals = np.zeros((S, B, Pw), np.float32)
            mask = np.zeros((S, B), bool)
            for s in range(S):
                take = per_shard[s][offsets[s]:offsets[s] + B]
                offsets[s] += len(take)
                for b, pos in enumerate(take):
                    slot = self._free[s].pop()
                    slots[s, b] = slot
                    eids[s, b] = packed[pos]
                    idxs[s, b] = idx_batch[pos]
                    vals[s, b] = val_batch[pos]
                    mask[s, b] = True
                    self._id2slot[ext_ids[pos]] = (s, slot)
            self.state = step(self.state, jnp.asarray(slots),
                              jnp.asarray(eids), jnp.asarray(idxs),
                              jnp.asarray(vals), jnp.asarray(mask))
        self._obs.record("insert_many", t0, len(ext_ids))

    def delete(self, ext_id: int) -> None:
        self.delete_many([ext_id])

    def delete_many(self, ext_ids) -> None:
        t0 = time.perf_counter()
        # dedup: a repeated id is one deletion, not a KeyError mid-mutation
        ext_ids = list(dict.fromkeys(int(e) for e in ext_ids))
        missing = [e for e in ext_ids if e not in self._id2slot]
        if missing:     # fail atomically, before any bookkeeping mutates
            raise KeyError(f"unknown document ids: {missing[:5]}")
        per_shard = [[] for _ in range(self.n_shards)]
        for e in ext_ids:
            s, slot = self._id2slot.pop(e)
            per_shard[s].append(slot)
        step = self._step("delete", lambda: make_delete_step(self.mesh,
                                                             self.spec))
        S, B = self.n_shards, self.update_block
        offsets = [0] * S
        while any(offsets[s] < len(per_shard[s]) for s in range(S)):
            slots = np.zeros((S, B), np.int32)
            mask = np.zeros((S, B), bool)
            for s in range(S):
                take = per_shard[s][offsets[s]:offsets[s] + B]
                offsets[s] += len(take)
                slots[s, :len(take)] = take
                mask[s, :len(take)] = True
            self.state = step(self.state, jnp.asarray(slots),
                              jnp.asarray(mask))
        for s in range(S):
            self._free[s].extend(reversed(per_shard[s]))
        self._obs.record("delete_many", t0, len(ext_ids))

    # -- retrieval ----------------------------------------------------------
    def search(self, q_idx, q_val, k: int, kprime: Optional[int] = None,
               budget: Optional[int] = None, score_fn=None,
               backend: Optional[str] = None):
        q_idx = np.asarray(q_idx, np.int32)
        q_val = np.asarray(q_val, np.float32)
        ids, scores = self.search_many(q_idx[None], q_val[None], k,
                                       kprime=kprime, budget=budget,
                                       score_fn=score_fn, backend=backend)
        return ids[0], scores[0]

    def search_many(self, q_idx, q_val, k: int,
                    kprime: Optional[int] = None,
                    budget: Optional[int] = None, score_fn=None,
                    backend: Optional[str] = None,
                    return_locators: bool = False, trace=None):
        """Batched search over [B, Lq] queries (one SPMD dispatch).

        ``kprime`` is the per-shard candidate count k'.  ``backend`` picks
        the shard-local scoring backend (None -> process default).  With
        ``return_locators`` the packed (shard, slot) payload of every hit is
        also returned (decode with topk.unpack_shard_slot).  ``trace`` is an
        optional `repro.obs.Trace`: the SPMD dispatch (synced) is recorded
        as one ``spmd_search`` span — shard-local stages run inside a single
        shard_map program and cannot honestly be split further.
        """
        from repro.kernels import ops as _ops

        kprime = kprime if kprime is not None else max(5 * k, k)
        kl = min(kprime, self.spec.capacity)
        k = min(k, kl * self.n_shards)
        if backend is None:
            backend = self.default_backend
        backend = _ops.resolve_backend(backend) if score_fn is None else None
        key = ("search", k, kl, budget, score_fn, backend)
        step = self._step(key, lambda: make_search_step(
            self.mesh, self.spec, k=k, kprime_local=kl, budget=budget,
            score_fn=score_fn, backend=backend))
        if trace is not None:
            with trace.span("spmd_search"):
                scores, ids, loc = step(self.state, jnp.asarray(q_idx),
                                        jnp.asarray(q_val))
                jax.block_until_ready(scores)
        else:
            scores, ids, loc = step(self.state, jnp.asarray(q_idx),
                                    jnp.asarray(q_val))
        ids = eng.unpack_ids64(np.asarray(ids))
        if return_locators:
            return ids, np.asarray(scores), np.asarray(loc)
        return ids, np.asarray(scores)

    # -- capacity management ------------------------------------------------
    def grow(self, new_local_capacity: Optional[int] = None) -> None:
        """Double (or set) every shard's local capacity, shard-locally."""
        t0 = time.perf_counter()
        old_c = self.spec.capacity
        new_c = new_local_capacity or old_c * 2
        if new_c <= old_c or new_c % 32 != 0:
            raise ValueError("new capacity must be a larger multiple of 32")
        step, new_spec = make_grow_step(self.mesh, self.spec, new_c)
        self.state = step(self.state)
        self.spec = new_spec
        self._steps.clear()        # cached steps close over the old capacity
        for s in range(self.n_shards):
            self._free[s] = (list(range(new_c - 1, old_c - 1, -1))
                             + self._free[s])
        self._obs.record("grow", t0)

    # -- maintenance ---------------------------------------------------------
    def compact(self) -> int:
        """Rebuild every shard's dirty sketch columns (shard-local step).

        Returns the number of columns rebuilt across all shards.
        """
        t0 = time.perf_counter()
        n_dirty = int(np.asarray(jnp.sum(self.state.dirty)))
        if n_dirty:
            step = self._step("compact", lambda: make_compact_step(
                self.mesh, self.spec))
            self.state = step(self.state)
        self._obs.record("compact", t0)
        return n_dirty

    def slot_drift(self) -> np.ndarray:
        """Per-slot sketch overestimate vs. a fresh sketch (f32[C_global])."""
        step = self._step("drift", lambda: make_drift_step(self.mesh,
                                                           self.spec))
        return np.asarray(step(self.state))

    # -- misc ----------------------------------------------------------------
    @property
    def size(self) -> int:
        return len(self._id2slot)

    def __contains__(self, ext_id) -> bool:
        """True iff ``ext_id`` is currently live in the index."""
        return int(ext_id) in self._id2slot

    def doc_ids(self) -> list:
        """Sorted external ids of every live document."""
        return sorted(self._id2slot)

    def _pad(self, arr: np.ndarray, fill) -> np.ndarray:
        w = self.spec.max_nnz
        if arr.shape[1] > w:
            raise ValueError(f"document nnz {arr.shape[1]} > max_nnz {w}")
        if arr.shape[1] == w:
            return arr
        out = np.full((arr.shape[0], w), fill, arr.dtype)
        out[:, :arr.shape[1]] = arr
        return out
