"""Sharded Sinnamon serving: the paper's engine as an SPMD program.

Corpus slots are sharded over the (pod, model) mesh axes, the query batch over
data.  Scoring and the exact rerank are fully shard-local; only (k'-sized)
candidate tuples cross shards (see repro.distributed.topk).  This is the
``serve_step`` that the multi-pod dry-run lowers for the paper's own workload
and that `repro.launch.serve` drives.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import engine as eng
from repro.distributed import mesh as meshlib
from repro.distributed import topk
from repro.storage import vecstore


def state_pspecs(mesh: Mesh, positive_only: bool = False) -> eng.SinnamonState:
    """PartitionSpecs for every SinnamonState leaf (corpus over pod+model)."""
    corpus = meshlib.corpus_axes(mesh)
    c = corpus if len(corpus) > 1 else (corpus[0] if corpus else None)
    return eng.SinnamonState(
        mappings=P(),                      # replicated
        u=P(None, c),
        l=None if positive_only else P(None, c),
        bits=P(None, c),
        store=vecstore.VecStore(indices=P(c), values=P(c)),
        active=P(c),
        ids=P(c),
    )


def state_shardings(mesh: Mesh, positive_only: bool = False):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        state_pspecs(mesh, positive_only),
                        is_leaf=lambda x: isinstance(x, P))


def make_search_step(mesh: Mesh, local_spec: eng.EngineSpec, *,
                     k: int, kprime_local: int,
                     budget: Optional[int] = None,
                     score_fn=None):
    """Build the jittable SPMD search step.

    local_spec.capacity is the *per-shard* slot count.  Returns
    ``step(state, q_idx[B, Lq], q_val[B, Lq]) -> (scores[B, k], ids[B, k])``
    with the batch sharded over 'data' and outputs replicated over corpus axes.
    """
    corpus = meshlib.corpus_axes(mesh)
    qspec = P("data") if "data" in mesh.axis_names else P()

    def local_search(state: eng.SinnamonState, q_idx, q_val):
        scores = eng.score_batch(state, local_spec, q_idx, q_val, budget) \
            if score_fn is None else score_fn(state, local_spec, q_idx, q_val,
                                              budget)
        scores = jnp.where(state.active[None, :], scores, -jnp.inf)
        kl = min(kprime_local, local_spec.capacity)
        ub, slots = jax.lax.top_k(scores, kl)                  # [b, kl]

        dens = functools.partial(vecstore.densify_query, local_spec.n)
        q_dense = jax.vmap(dens)(q_idx, q_val)                 # [b, n]
        exact = jax.vmap(lambda s, qd: vecstore.exact_scores(state.store, s, qd)
                         )(slots, q_dense)                     # [b, kl]
        exact = jnp.where(jnp.isneginf(ub), -jnp.inf, exact)
        gids = state.ids[slots]
        if corpus:
            return topk.merge_over_axes(exact, gids, corpus, k)
        vals, pos = jax.lax.top_k(exact, k)
        return vals, jnp.take_along_axis(gids, pos, axis=-1)

    sharded = shard_map(
        local_search, mesh=mesh,
        in_specs=(state_pspecs(mesh, local_spec.positive_only), qspec, qspec),
        out_specs=(qspec, qspec),
        check_rep=False,
    )
    return jax.jit(sharded)


def shard_state(state: eng.SinnamonState, mesh: Mesh):
    """Place a host-built (global) state onto the mesh."""
    return jax.device_put(state, state_shardings(mesh, state.l is None))
