"""Sharded Sinnamon serving: the paper's engine as an SPMD program.

Corpus slots are sharded over the (pod, model) mesh axes, the query batch over
data.  Scoring and the exact rerank are fully shard-local; only (k'-sized)
candidate tuples cross shards (see repro.distributed.topk).

This module now covers the full *streaming* lifecycle at sharded scale:

* ``make_search_step``  — batched SPMD search (the original serve step),
  returning external ids plus packed (shard, slot) locators.
* ``make_insert_step`` / ``make_delete_step`` — collective-free shard-local
  updates: the host routes each document to its owning shard (hash of the
  external id), pads the per-shard update batches to one rectangle, and every
  shard applies only its masked slice.
* ``make_grow_step``    — shard-local capacity growth (each shard pads its own
  slot range; the re-laid-out global state falls out of the out_specs).
* ``ShardedSinnamonIndex`` — the host wrapper that owns routing, per-shard
  slot free lists, and the id → (shard, slot) map, mirroring the
  single-device ``SinnamonIndex`` API (insert/delete/search/grow).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import engine as eng
from repro.distributed import mesh as meshlib
from repro.distributed import topk
from repro.storage import vecstore


def _corpus_spec(mesh: Mesh):
    corpus = meshlib.corpus_axes(mesh)
    return corpus if len(corpus) > 1 else (corpus[0] if corpus else None)


def state_pspecs(mesh: Mesh, positive_only: bool = False) -> eng.SinnamonState:
    """PartitionSpecs for every SinnamonState leaf (corpus over pod+model).

    ``positive_only`` here means "the state has no ``l`` leaf" — pass
    ``spec.upper_only``, which also covers the §3.3 lite sketch variant.
    """
    c = _corpus_spec(mesh)
    return eng.SinnamonState(
        mappings=P(),                      # replicated
        u=P(None, c),
        l=None if positive_only else P(None, c),
        bits=P(None, c),
        store=vecstore.VecStore(indices=P(c), values=P(c)),
        active=P(c),
        ids=P(c, None),                    # uint32[C, 2] packed int64 ids
        dirty=P(c),
    )


def state_shardings(mesh: Mesh, positive_only: bool = False):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        state_pspecs(mesh, positive_only),
                        is_leaf=lambda x: isinstance(x, P))


def _merge_local_exact(mesh: Mesh, corpus, state: eng.SinnamonState,
                       exact, slots, k: int):
    """Shared tail of every sharded search: package shard-local exact scores
    into (gid lo/hi, locator) payloads and run the hierarchical top-k merge.
    Factored out so the tiered rows-based rerank step merges bit-identically
    with the resident fused step."""
    gids = state.ids[slots]                              # [b, kl, 2]
    shard = meshlib.linear_index(mesh, corpus)
    loc = topk.pack_shard_slot(shard, slots)
    payload = (gids[..., 0], gids[..., 1], loc)
    if corpus:
        vals, (lo, hi, loc) = topk.merge_over_axes(exact, payload, corpus, k)
        return vals, jnp.stack([lo, hi], axis=-1), loc
    vals, pos = jax.lax.top_k(exact, k)
    take = lambda p: jnp.take_along_axis(p, pos, axis=-1)
    return (vals, jnp.stack([take(payload[0]), take(payload[1])],
                            axis=-1), take(loc))


def make_search_step(mesh: Mesh, local_spec: eng.EngineSpec, *,
                     k: int, kprime_local: int,
                     budget: Optional[int] = None,
                     score_fn=None, backend: Optional[str] = None):
    """Build the jittable SPMD search step.

    local_spec.capacity is the *per-shard* slot count.  Returns
    ``step(state, q_idx[B, Lq], q_val[B, Lq])
        -> (scores[B, k], ids[B, k, 2], locators[B, k])``
    with the batch sharded over 'data' and outputs replicated over corpus
    axes.  ``ids`` are packed uint32 (lo, hi) words of the external int64 id
    (decode with engine.unpack_ids64); ``locators`` packs (shard, local slot)
    per hit (see topk.pack_shard_slot) so follow-up work routes straight back
    to the owning shard.

    ``backend`` selects the shard-local candidate backend (reference |
    grouped | pallas — the fused kernel runs per shard; only candidate
    tuples cross shards through the existing hierarchical merge).  The exact
    rerank gathers only the k' candidate CSR rows per shard — no [B, n]
    dense query block on any path.
    """
    from repro.kernels import ops as _ops

    corpus = meshlib.corpus_axes(mesh)
    qspec = P("data") if "data" in mesh.axis_names else P()
    backend = _ops.resolve_backend(backend) if score_fn is None else None

    def local_search(state: eng.SinnamonState, q_idx, q_val):
        kl = min(kprime_local, local_spec.capacity)
        if score_fn is not None:
            # Custom scorers keep the original BATCHED sharded contract:
            # score_fn(state, spec, q_idx[b, Lq], q_val[b, Lq], budget)
            # -> [b, C].
            scores = score_fn(state, local_spec, q_idx, q_val, budget)
            scores = jnp.where(state.active[None, :], scores, -jnp.inf)
            ub, slots = jax.lax.top_k(scores, kl)            # [b, kl]
        else:
            ub, slots = eng.topk_candidates(state, local_spec, q_idx, q_val,
                                            kl, budget,
                                            backend=backend)  # [b, kl]
        exact = jax.vmap(
            lambda s, i, v: vecstore.exact_scores_sparse(state.store, s, i, v)
        )(slots, q_idx, q_val)                               # [b, kl]
        exact = jnp.where(jnp.isneginf(ub), -jnp.inf, exact)
        return _merge_local_exact(mesh, corpus, state, exact, slots, k)

    sharded = shard_map(
        local_search, mesh=mesh,
        in_specs=(state_pspecs(mesh, local_spec.upper_only), qspec, qspec),
        out_specs=(qspec, qspec, qspec),
        check_rep=False,
    )
    return jax.jit(sharded)


# ---------------------------------------------------------------------------
# Collective-free SPMD updates
# ---------------------------------------------------------------------------
# Update batches arrive as [S, B, ...] rectangles whose leading axis is
# sharded over the corpus axes: shard s sees only its own [1, B, ...] slice,
# applies the mask-valid entries against its local slots, and no bytes ever
# cross shards.  The host (ShardedSinnamonIndex) is responsible for routing —
# entry (s, b) must actually belong to shard s.

def make_insert_step(mesh: Mesh, local_spec: eng.EngineSpec):
    """``step(state, slots[S,B], ids[S,B,2], idx[S,B,P], val[S,B,P],
    mask[S,B])`` → state, with every array's leading axis sharded over the
    corpus axes (``ids`` are packed uint32 lo/hi words, engine.pack_ids64)."""
    c = _corpus_spec(mesh)
    sspec = state_pspecs(mesh, local_spec.upper_only)
    uspec = P(c)

    def local_insert(state, slots, eids, idx, val, mask):
        return eng.insert_batch_masked(state, local_spec, slots[0], eids[0],
                                       idx[0], val[0], mask[0])

    sharded = shard_map(
        local_insert, mesh=mesh,
        in_specs=(sspec, uspec, uspec, uspec, uspec, uspec),
        out_specs=sspec, check_rep=False)
    return jax.jit(sharded)


def make_delete_step(mesh: Mesh, local_spec: eng.EngineSpec):
    """``step(state, slots[S,B], mask[S,B])`` → state (shard-local deletes)."""
    c = _corpus_spec(mesh)
    sspec = state_pspecs(mesh, local_spec.upper_only)
    uspec = P(c)

    def local_delete(state, slots, mask):
        return eng.delete_batch_masked(state, local_spec, slots[0], mask[0])

    sharded = shard_map(
        local_delete, mesh=mesh,
        in_specs=(sspec, uspec, uspec),
        out_specs=sspec, check_rep=False)
    return jax.jit(sharded)


def make_grow_step(mesh: Mesh, local_spec: eng.EngineSpec,
                   new_local_capacity: int):
    """``step(state)`` → state with every shard grown to new_local_capacity.

    Each shard pads its own slot range (pure shard-local grow_state); the
    out_specs re-assemble the blocks into the grown global layout, so slot
    numbering *within a shard* is preserved and no collective is emitted.
    """
    new_spec = dataclasses.replace(local_spec, capacity=new_local_capacity)
    sspec_in = state_pspecs(mesh, local_spec.upper_only)

    def local_grow(state):
        return eng.grow_state(state, local_spec, new_spec)

    sharded = shard_map(local_grow, mesh=mesh, in_specs=(sspec_in,),
                        out_specs=sspec_in, check_rep=False)
    return jax.jit(sharded), new_spec


def make_compact_step(mesh: Mesh, local_spec: eng.EngineSpec):
    """``step(state)`` → state with every shard's dirty sketch columns rebuilt
    from its local VecStore slice (shard-local; no collectives)."""
    sspec = state_pspecs(mesh, local_spec.upper_only)

    def local_compact(state):
        return eng.compact_state(state, local_spec)

    sharded = shard_map(local_compact, mesh=mesh, in_specs=(sspec,),
                        out_specs=sspec, check_rep=False)
    return jax.jit(sharded)


def make_drift_step(mesh: Mesh, local_spec: eng.EngineSpec):
    """``step(state)`` → f32[C_global] per-slot sketch overestimate."""
    c = _corpus_spec(mesh)
    sspec = state_pspecs(mesh, local_spec.upper_only)

    def local_drift(state):
        return eng.slot_drift(state, local_spec)

    sharded = shard_map(local_drift, mesh=mesh, in_specs=(sspec,),
                        out_specs=P(c), check_rep=False)
    return jax.jit(sharded)


def shard_state(state: eng.SinnamonState, mesh: Mesh):
    """Place a host-built (global) state onto the mesh."""
    return jax.device_put(state, state_shardings(mesh, state.l is None))


# ---------------------------------------------------------------------------
# Tiered-store SPMD steps (split search + rows-based mutation/maintenance)
# ---------------------------------------------------------------------------
# The tiered sharded index keeps raw CSR rows in per-shard host-backed
# TieredVecStores; ``state.store`` is a zero-row placeholder, so every step
# that used to read it gets a rows-based twin whose row inputs arrive as
# [S, ...] rectangles (leading axis sharded over the corpus axes).  Search
# splits in two: a candidates step (sketch-only), a host-side per-shard
# chunk-cache gather, then a rerank step that reuses _merge_local_exact so
# the merge is bit-identical to make_search_step.

def _block_spec(mesh: Mesh):
    """PartitionSpec for [S, B, ...] blocks: S over corpus, B over data."""
    c = _corpus_spec(mesh)
    bax = meshlib.batch_axes(mesh)
    return P(c, bax[0]) if bax else P(c)


def make_candidates_step(mesh: Mesh, local_spec: eng.EngineSpec, *,
                         kprime_local: int, budget: Optional[int] = None,
                         backend: Optional[str] = None):
    """``step(state, q_idx[B, Lq], q_val[B, Lq])
    -> (ub f32[S, B, kl], slots int32[S, B, kl])`` — the sketch-only front
    half of a tiered sharded search (leading axis sharded over corpus)."""
    from repro.kernels import ops as _ops

    qspec = P("data") if "data" in mesh.axis_names else P()
    bspec = _block_spec(mesh)
    backend = _ops.resolve_backend(backend)

    def local_cand(state, q_idx, q_val):
        kl = min(kprime_local, local_spec.capacity)
        ub, slots = eng.topk_candidates(state, local_spec, q_idx, q_val, kl,
                                        budget, backend=backend)
        return ub[None], slots[None]

    sharded = shard_map(
        local_cand, mesh=mesh,
        in_specs=(state_pspecs(mesh, local_spec.upper_only), qspec, qspec),
        out_specs=(bspec, bspec), check_rep=False)
    return jax.jit(sharded)


def make_rerank_rows_step(mesh: Mesh, local_spec: eng.EngineSpec, *, k: int):
    """``step(state, ub[S, B, kl], slots[S, B, kl], ridx[S, B, kl, P],
    rval[S, B, kl, P], q_idx, q_val) -> (scores[B, k], ids[B, k, 2],
    locators[B, k])`` — the rows-fed exact rerank + hierarchical merge."""
    corpus = meshlib.corpus_axes(mesh)
    qspec = P("data") if "data" in mesh.axis_names else P()
    bspec = _block_spec(mesh)
    sspec = state_pspecs(mesh, local_spec.upper_only)

    def local_rerank(state, ub, slots, ridx, rval, q_idx, q_val):
        ub, slots = ub[0], slots[0]                      # [b, kl]
        exact = jax.vmap(vecstore.exact_scores_rows)(ridx[0], rval[0],
                                                     q_idx, q_val)
        exact = jnp.where(jnp.isneginf(ub), -jnp.inf, exact)
        return _merge_local_exact(mesh, corpus, state, exact, slots, k)

    sharded = shard_map(
        local_rerank, mesh=mesh,
        in_specs=(sspec, bspec, bspec, bspec, bspec, qspec, qspec),
        out_specs=(qspec, qspec, qspec), check_rep=False)
    return jax.jit(sharded)


def make_delete_rows_step(mesh: Mesh, local_spec: eng.EngineSpec):
    """``step(state, slots[S,B], idx[S,B,P], mask[S,B])`` → state — the
    delete step with the bit-clear coordinate rows supplied by the host."""
    c = _corpus_spec(mesh)
    sspec = state_pspecs(mesh, local_spec.upper_only)
    uspec = P(c)

    def local_delete(state, slots, idx, mask):
        return eng.delete_batch_rows(state, local_spec, slots[0], idx[0],
                                     mask[0])

    sharded = shard_map(
        local_delete, mesh=mesh,
        in_specs=(sspec, uspec, uspec, uspec),
        out_specs=sspec, check_rep=False)
    return jax.jit(sharded)


def make_compact_rows_step(mesh: Mesh, local_spec: eng.EngineSpec):
    """``step(state, slots[S,B], idx[S,B,P], val[S,B,P], mask[S,B])`` →
    state with the masked slots' sketch columns rebuilt from the rows."""
    c = _corpus_spec(mesh)
    sspec = state_pspecs(mesh, local_spec.upper_only)
    uspec = P(c)

    def local_compact(state, slots, idx, val, mask):
        return eng.compact_slots_rows(state, local_spec, slots[0], idx[0],
                                      val[0], mask[0])

    sharded = shard_map(
        local_compact, mesh=mesh,
        in_specs=(sspec, uspec, uspec, uspec, uspec),
        out_specs=sspec, check_rep=False)
    return jax.jit(sharded)


def make_drift_rows_step(mesh: Mesh, local_spec: eng.EngineSpec):
    """``step(state, slots[S,B], idx[S,B,P], val[S,B,P])`` → f32[S, B]."""
    c = _corpus_spec(mesh)
    sspec = state_pspecs(mesh, local_spec.upper_only)
    uspec = P(c)

    def local_drift(state, slots, idx, val):
        return eng.slot_drift_rows(state, local_spec, slots[0], idx[0],
                                   val[0])[None]

    sharded = shard_map(
        local_drift, mesh=mesh,
        in_specs=(sspec, uspec, uspec, uspec),
        out_specs=P(c), check_rep=False)
    return jax.jit(sharded)


def _corpus_shard_devices(mesh: Mesh) -> list:
    """One owning device per corpus shard (first device when replicated)."""
    S = meshlib.n_shards(mesh, meshlib.corpus_axes(mesh))
    sh = NamedSharding(mesh, P(_corpus_spec(mesh)))
    out = [None] * S
    for dev, idx in sh.devices_indices_map((S,)).items():
        start = idx[0].start or 0
        if out[start] is None:
            out[start] = dev
    return out


# ---------------------------------------------------------------------------
# Host wrapper
# ---------------------------------------------------------------------------

class ShardedSinnamonIndex:
    """Streaming host-facing index over a mesh-sharded SinnamonState.

    ``spec.capacity`` is the PER-SHARD slot count; global capacity is
    ``spec.capacity * n_shards``.  Documents are routed to an owning shard by
    a multiplicative hash of the external id, so insert, delete and search
    all agree on placement without any shared table beyond the host's
    id → (shard, slot) dict.  All device work is jitted shard_map programs;
    queries go through the hierarchical top-k merge, so only (k'·shards)
    candidate tuples ever cross shards.
    """

    def __init__(self, spec: eng.EngineSpec, mesh: Mesh, *,
                 update_block: int = 32):
        self.mesh = mesh
        self.spec = spec                       # per-shard spec
        self.default_backend: Optional[str] = None  # repro.api facade sets this
        self.corpus = meshlib.corpus_axes(mesh)
        self.n_shards = meshlib.n_shards(mesh, self.corpus)
        self.update_block = update_block
        global_spec = dataclasses.replace(
            spec, capacity=spec.capacity * self.n_shards)
        self.state = shard_state(self._init_state(global_spec), mesh)
        self._free = [list(range(spec.capacity - 1, -1, -1))
                      for _ in range(self.n_shards)]
        self._id2slot: dict[int, tuple[int, int]] = {}
        self._steps: dict = {}
        self._obs = eng._WritePathMetrics()

    def _init_state(self, global_spec: eng.EngineSpec) -> eng.SinnamonState:
        """Fresh host-built global state; the tiered subclass swaps in a
        zero-row placeholder store here."""
        return eng.init(global_spec)

    # -- routing ------------------------------------------------------------
    def route(self, ext_id: int) -> int:
        """Owning shard of an external id (Knuth multiplicative hash)."""
        return ((int(ext_id) * 2654435761) & 0xFFFFFFFF) % self.n_shards

    def _step(self, key, build):
        if key not in self._steps:
            self._steps[key] = build()
        return self._steps[key]

    # -- streaming updates --------------------------------------------------
    def insert(self, ext_id: int, idx, val) -> None:
        idx = np.asarray(idx, np.int32)
        val = np.asarray(val, np.float32)
        self.insert_many([ext_id], idx[None], val[None])

    def insert_many(self, ext_ids, idx_batch, val_batch) -> None:
        t0 = time.perf_counter()
        ext_ids = [int(e) for e in ext_ids]
        if len(set(ext_ids)) != len(ext_ids):
            # Sequential overwrite semantics: only the LAST occurrence of a
            # duplicated id survives; earlier ones never touch the index.
            last = {e: pos for pos, e in enumerate(ext_ids)}
            keep = sorted(last.values())
            ext_ids = [ext_ids[p] for p in keep]
            idx_batch = np.asarray(idx_batch)[keep]
            val_batch = np.asarray(val_batch)[keep]
        stale = [e for e in ext_ids if e in self._id2slot]
        if stale:
            self.delete_many(stale)
        idx_batch = self._pad(np.asarray(idx_batch, np.int32), -1)
        val_batch = self._pad(np.asarray(val_batch, np.float32), 0)

        per_shard = [[] for _ in range(self.n_shards)]
        for pos, e in enumerate(ext_ids):
            per_shard[self.route(e)].append(pos)
        while any(len(self._free[s]) < len(per_shard[s])
                  for s in range(self.n_shards)):
            self.grow()

        S, B, Pw = self.n_shards, self.update_block, self.spec.max_nnz
        packed = eng.pack_ids64(np.asarray(ext_ids, np.int64))
        offsets = [0] * S
        while any(offsets[s] < len(per_shard[s]) for s in range(S)):
            slots = np.zeros((S, B), np.int32)
            eids = np.full((S, B, 2), 0xFFFFFFFF, np.uint32)
            idxs = np.full((S, B, Pw), -1, np.int32)
            vals = np.zeros((S, B, Pw), np.float32)
            mask = np.zeros((S, B), bool)
            for s in range(S):
                take = per_shard[s][offsets[s]:offsets[s] + B]
                offsets[s] += len(take)
                for b, pos in enumerate(take):
                    slot = self._free[s].pop()
                    slots[s, b] = slot
                    eids[s, b] = packed[pos]
                    idxs[s, b] = idx_batch[pos]
                    vals[s, b] = val_batch[pos]
                    mask[s, b] = True
                    self._id2slot[ext_ids[pos]] = (s, slot)
            self._apply_insert_block(slots, eids, idxs, vals, mask)
        self._obs.record("insert_many", t0, len(ext_ids))

    def _apply_insert_block(self, slots, eids, idxs, vals, mask) -> None:
        step = self._step("insert", lambda: make_insert_step(self.mesh,
                                                             self.spec))
        self.state = step(self.state, jnp.asarray(slots),
                          jnp.asarray(eids), jnp.asarray(idxs),
                          jnp.asarray(vals), jnp.asarray(mask))

    def delete(self, ext_id: int) -> None:
        self.delete_many([ext_id])

    def delete_many(self, ext_ids) -> None:
        t0 = time.perf_counter()
        # dedup: a repeated id is one deletion, not a KeyError mid-mutation
        ext_ids = list(dict.fromkeys(int(e) for e in ext_ids))
        missing = [e for e in ext_ids if e not in self._id2slot]
        if missing:     # fail atomically, before any bookkeeping mutates
            raise KeyError(f"unknown document ids: {missing[:5]}")
        per_shard = [[] for _ in range(self.n_shards)]
        for e in ext_ids:
            s, slot = self._id2slot.pop(e)
            per_shard[s].append(slot)
        S, B = self.n_shards, self.update_block
        offsets = [0] * S
        while any(offsets[s] < len(per_shard[s]) for s in range(S)):
            slots = np.zeros((S, B), np.int32)
            mask = np.zeros((S, B), bool)
            for s in range(S):
                take = per_shard[s][offsets[s]:offsets[s] + B]
                offsets[s] += len(take)
                slots[s, :len(take)] = take
                mask[s, :len(take)] = True
            self._apply_delete_block(slots, mask)
        for s in range(S):
            self._free[s].extend(reversed(per_shard[s]))
        self._obs.record("delete_many", t0, len(ext_ids))

    def _apply_delete_block(self, slots, mask) -> None:
        step = self._step("delete", lambda: make_delete_step(self.mesh,
                                                             self.spec))
        self.state = step(self.state, jnp.asarray(slots), jnp.asarray(mask))

    # -- retrieval ----------------------------------------------------------
    def search(self, q_idx, q_val, k: int, kprime: Optional[int] = None,
               budget: Optional[int] = None, score_fn=None,
               backend: Optional[str] = None):
        q_idx = np.asarray(q_idx, np.int32)
        q_val = np.asarray(q_val, np.float32)
        ids, scores = self.search_many(q_idx[None], q_val[None], k,
                                       kprime=kprime, budget=budget,
                                       score_fn=score_fn, backend=backend)
        return ids[0], scores[0]

    def search_many(self, q_idx, q_val, k: int,
                    kprime: Optional[int] = None,
                    budget: Optional[int] = None, score_fn=None,
                    backend: Optional[str] = None,
                    return_locators: bool = False, trace=None):
        """Batched search over [B, Lq] queries (one SPMD dispatch).

        ``kprime`` is the per-shard candidate count k'.  ``backend`` picks
        the shard-local scoring backend (None -> process default).  With
        ``return_locators`` the packed (shard, slot) payload of every hit is
        also returned (decode with topk.unpack_shard_slot).  ``trace`` is an
        optional `repro.obs.Trace`: the SPMD dispatch (synced) is recorded
        as one ``spmd_search`` span — shard-local stages run inside a single
        shard_map program and cannot honestly be split further.
        """
        from repro.kernels import ops as _ops

        kprime = kprime if kprime is not None else max(5 * k, k)
        kl = min(kprime, self.spec.capacity)
        k = min(k, kl * self.n_shards)
        if backend is None:
            backend = self.default_backend
        backend = _ops.resolve_backend(backend) if score_fn is None else None
        key = ("search", k, kl, budget, score_fn, backend)
        step = self._step(key, lambda: make_search_step(
            self.mesh, self.spec, k=k, kprime_local=kl, budget=budget,
            score_fn=score_fn, backend=backend))
        if trace is not None:
            with trace.span("spmd_search"):
                scores, ids, loc = step(self.state, jnp.asarray(q_idx),
                                        jnp.asarray(q_val))
                jax.block_until_ready(scores)
        else:
            scores, ids, loc = step(self.state, jnp.asarray(q_idx),
                                    jnp.asarray(q_val))
        ids = eng.unpack_ids64(np.asarray(ids))
        if return_locators:
            return ids, np.asarray(scores), np.asarray(loc)
        return ids, np.asarray(scores)

    # -- capacity management ------------------------------------------------
    def grow(self, new_local_capacity: Optional[int] = None) -> None:
        """Double (or set) every shard's local capacity, shard-locally."""
        t0 = time.perf_counter()
        old_c = self.spec.capacity
        new_c = new_local_capacity or old_c * 2
        if new_c <= old_c or new_c % 32 != 0:
            raise ValueError("new capacity must be a larger multiple of 32")
        step, new_spec = make_grow_step(self.mesh, self.spec, new_c)
        self.state = step(self.state)
        self.spec = new_spec
        self._steps.clear()        # cached steps close over the old capacity
        for s in range(self.n_shards):
            self._free[s] = (list(range(new_c - 1, old_c - 1, -1))
                             + self._free[s])
        self._obs.record("grow", t0)

    # -- maintenance ---------------------------------------------------------
    def compact(self) -> int:
        """Rebuild every shard's dirty sketch columns (shard-local step).

        Returns the number of columns rebuilt across all shards.
        """
        t0 = time.perf_counter()
        n_dirty = int(np.asarray(jnp.sum(self.state.dirty)))
        if n_dirty:
            step = self._step("compact", lambda: make_compact_step(
                self.mesh, self.spec))
            self.state = step(self.state)
        self._obs.record("compact", t0)
        return n_dirty

    def slot_drift(self) -> np.ndarray:
        """Per-slot sketch overestimate vs. a fresh sketch (f32[C_global])."""
        step = self._step("drift", lambda: make_drift_step(self.mesh,
                                                           self.spec))
        return np.asarray(step(self.state))

    # -- misc ----------------------------------------------------------------
    @property
    def size(self) -> int:
        return len(self._id2slot)

    def __contains__(self, ext_id) -> bool:
        """True iff ``ext_id`` is currently live in the index."""
        return int(ext_id) in self._id2slot

    def doc_ids(self) -> list:
        """Sorted external ids of every live document."""
        return sorted(self._id2slot)

    def _pad(self, arr: np.ndarray, fill) -> np.ndarray:
        w = self.spec.max_nnz
        if arr.shape[1] > w:
            raise ValueError(f"document nnz {arr.shape[1]} > max_nnz {w}")
        if arr.shape[1] == w:
            return arr
        out = np.full((arr.shape[0], w), fill, arr.dtype)
        out[:, :arr.shape[1]] = arr
        return out


class TieredShardedSinnamonIndex(ShardedSinnamonIndex):
    """ShardedSinnamonIndex with per-shard hot/cold tiered raw stores.

    ``state.store`` is a zero-row placeholder; each corpus shard owns a
    :class:`repro.storage.tiered.TieredVecStore` committed to that shard's
    device (``device_budget_bytes`` is PER SHARD).  Search runs as two SPMD
    dispatches — sketch-only candidates, then (after a host sync of the
    ``[S, B, k']`` candidate slots drives per-shard chunk promotion) a
    rows-fed rerank step that reuses the exact same hierarchical merge as
    the resident step, so results are bit-identical to
    :class:`ShardedSinnamonIndex`.  ``score_fn`` (the legacy custom-scorer
    hook) is not supported here.
    """

    def __init__(self, spec: eng.EngineSpec, mesh: Mesh, *,
                 update_block: int = 32, tier_chunk_slots: int = 256,
                 device_budget_bytes: Optional[int] = None,
                 cache_chunks: Optional[int] = None):
        from repro.storage.tiered import TieredVecStore
        super().__init__(spec, mesh, update_block=update_block)
        devices = _corpus_shard_devices(mesh)
        self.tiers = [
            TieredVecStore(spec.capacity, spec.max_nnz,
                           value_dtype=spec.value_dtype,
                           chunk_slots=tier_chunk_slots,
                           device_budget_bytes=device_budget_bytes,
                           cache_chunks=cache_chunks,
                           device=devices[s])
            for s in range(self.n_shards)]

    def _init_state(self, global_spec: eng.EngineSpec) -> eng.SinnamonState:
        return eng.init(global_spec, store_rows=0)

    # -- streaming updates ---------------------------------------------------
    def _apply_insert_block(self, slots, eids, idxs, vals, mask) -> None:
        pinned = []
        for s in range(self.n_shards):
            m = mask[s]
            if m.any():
                pinned.append((s, self.tiers[s].write_rows(
                    slots[s][m], idxs[s][m], vals[s][m], pin=True)))
        try:
            super()._apply_insert_block(slots, eids, idxs, vals, mask)
        finally:
            for s, chunks in pinned:
                self.tiers[s].unpin(chunks)

    def _apply_delete_block(self, slots, mask) -> None:
        S, B = slots.shape
        idxs = np.full((S, B, self.spec.max_nnz), -1, np.int32)
        for s in range(S):
            m = mask[s]
            if m.any():
                idxs[s, m] = self.tiers[s].read_indices(slots[s][m])
        step = self._step("delete_rows", lambda: make_delete_rows_step(
            self.mesh, self.spec))
        self.state = step(self.state, jnp.asarray(slots), jnp.asarray(idxs),
                          jnp.asarray(mask))
        for s in range(S):
            if mask[s].any():
                self.tiers[s].erase_rows(slots[s][mask[s]])

    # -- retrieval -----------------------------------------------------------
    def search_many(self, q_idx, q_val, k: int,
                    kprime: Optional[int] = None,
                    budget: Optional[int] = None, score_fn=None,
                    backend: Optional[str] = None,
                    return_locators: bool = False, trace=None):
        """Two SPMD dispatches with a candidate-driven per-shard prefetch in
        between; with ``trace`` the stages are recorded as separate
        ``spmd_candidates`` / ``prefetch`` / ``spmd_rerank`` spans."""
        from repro.kernels import ops as _ops

        if score_fn is not None:
            raise NotImplementedError(
                "score_fn is not supported on the tiered sharded index")
        kprime = kprime if kprime is not None else max(5 * k, k)
        kl = min(kprime, self.spec.capacity)
        k = min(k, kl * self.n_shards)
        if backend is None:
            backend = self.default_backend
        backend = _ops.resolve_backend(backend)
        cstep = self._step(("tiered_cand", kl, budget, backend),
                           lambda: make_candidates_step(
                               self.mesh, self.spec, kprime_local=kl,
                               budget=budget, backend=backend))
        rstep = self._step(("tiered_rerank", k, kl),
                           lambda: make_rerank_rows_step(self.mesh, self.spec,
                                                         k=k))
        qi, qv = jnp.asarray(q_idx), jnp.asarray(q_val)
        if trace is None:
            ub, slots = cstep(self.state, qi, qv)
            ridx, rval = self._gather_global(np.asarray(slots))
            scores, ids, loc = rstep(self.state, ub, slots, ridx, rval,
                                     qi, qv)
        else:
            with trace.span("spmd_candidates"):
                ub, slots = cstep(self.state, qi, qv)
                slots_np = np.asarray(slots)             # sync
            with trace.span("prefetch"):
                ridx, rval = self._gather_global(slots_np)
                jax.block_until_ready((ridx, rval))
            with trace.span("spmd_rerank"):
                scores, ids, loc = rstep(self.state, ub, slots, ridx, rval,
                                         qi, qv)
                jax.block_until_ready(scores)
        ids = eng.unpack_ids64(np.asarray(ids))
        if return_locators:
            return ids, np.asarray(scores), np.asarray(loc)
        return ids, np.asarray(scores)

    def _gather_global(self, slots_np: np.ndarray):
        """Per-shard chunk-cache gathers assembled into global [S, B, kl, P]
        arrays sharded over the corpus axes.  Fast path: each shard's rows
        are already on its own device, so the global array is assembled
        without host round-trips; falls back to a host stack + device_put
        when the batch is data-sharded."""
        S, B, kl = slots_np.shape
        Pw = self.spec.max_nnz
        pieces = [self.tiers[s].gather_rows(slots_np[s].reshape(-1))
                  for s in range(S)]
        sh = NamedSharding(self.mesh, _block_spec(self.mesh))
        shape = (S, B, kl, Pw)
        try:
            if any(self.mesh.shape[a] != 1
                   for a in meshlib.batch_axes(self.mesh)):
                raise ValueError("data-sharded batch needs the host path")
            ridx = jax.make_array_from_single_device_arrays(
                shape, sh, [p[0].reshape(1, B, kl, Pw) for p in pieces])
            rval = jax.make_array_from_single_device_arrays(
                shape, sh, [p[1].reshape(1, B, kl, Pw) for p in pieces])
        except Exception:                                  # noqa: BLE001
            ridx = jax.device_put(
                np.stack([np.asarray(p[0]).reshape(B, kl, Pw)
                          for p in pieces]), sh)
            rval = jax.device_put(
                np.stack([np.asarray(p[1]).reshape(B, kl, Pw)
                          for p in pieces]), sh)
        return ridx, rval

    # -- capacity / maintenance ----------------------------------------------
    def grow(self, new_local_capacity: Optional[int] = None) -> None:
        super().grow(new_local_capacity)
        for t in self.tiers:
            t.grow(self.spec.capacity)

    def _maint_blocks(self):
        """Yield (slots[S,B], idx[S,B,P], val[S,B,P], mask[S,B]) blocks of
        dirty slots with their host-read rows, shard-local numbering."""
        dirty = np.asarray(self.state.dirty)
        cap = self.spec.capacity
        per_shard = [np.flatnonzero(dirty[s * cap:(s + 1) * cap])
                     for s in range(self.n_shards)]
        S, B, Pw = self.n_shards, max(self.update_block, 32), self.spec.max_nnz
        vdt = self.tiers[0].value_dtype
        offsets = [0] * S
        while any(offsets[s] < per_shard[s].size for s in range(S)):
            slots = np.zeros((S, B), np.int32)
            mask = np.zeros((S, B), bool)
            idxs = np.full((S, B, Pw), -1, np.int32)
            vals = np.zeros((S, B, Pw), vdt)
            for s in range(S):
                take = per_shard[s][offsets[s]:offsets[s] + B]
                offsets[s] += take.size
                if take.size:
                    slots[s, :take.size] = take
                    mask[s, :take.size] = True
                    ri, rv = self.tiers[s].read_rows(take)
                    idxs[s, :take.size] = ri
                    vals[s, :take.size] = rv
            yield slots, idxs, vals, mask

    def compact(self) -> int:
        t0 = time.perf_counter()
        total = 0
        step = None
        for slots, idxs, vals, mask in self._maint_blocks():
            if step is None:
                step = self._step("tiered_compact",
                                  lambda: make_compact_rows_step(self.mesh,
                                                                 self.spec))
            self.state = step(self.state, jnp.asarray(slots),
                              jnp.asarray(idxs), jnp.asarray(vals),
                              jnp.asarray(mask))
            total += int(mask.sum())
        self._obs.record("compact", t0)
        return total

    def slot_drift(self) -> np.ndarray:
        out = np.zeros((self.spec.capacity * self.n_shards,), np.float32)
        cap = self.spec.capacity
        step = None
        for slots, idxs, vals, mask in self._maint_blocks():
            if step is None:
                step = self._step("tiered_drift",
                                  lambda: make_drift_rows_step(self.mesh,
                                                               self.spec))
            d = np.asarray(step(self.state, jnp.asarray(slots),
                                jnp.asarray(idxs), jnp.asarray(vals)))
            for s in range(self.n_shards):
                out[s * cap + slots[s][mask[s]]] = d[s][mask[s]]
        return out

    # -- persistence hooks ----------------------------------------------------
    def logical_state(self) -> eng.SinnamonState:
        """Global state with the full raw store spliced back in, so tiered
        snapshots are byte-interchangeable with resident ones."""
        cap, Pw = self.spec.capacity, self.spec.max_nnz
        idx = np.full((cap * self.n_shards, Pw), -1, np.int32)
        val = np.zeros((cap * self.n_shards, Pw), self.tiers[0].value_dtype)
        for s, t in enumerate(self.tiers):
            hi, hv = t.to_arrays()
            idx[s * cap:(s + 1) * cap] = hi
            val[s * cap:(s + 1) * cap] = hv
        return self.state._replace(store=vecstore.VecStore(
            indices=idx, values=val))

    def adopt_logical_state(self, state: eng.SinnamonState) -> None:
        """Restore from a full-store global state: raw rows land in the
        per-shard host backings (tiering heat resets to access-free
        defaults), the device state keeps the zero-row placeholder."""
        cap = self.spec.capacity
        idx = np.asarray(state.store.indices)
        val = np.asarray(state.store.values)
        for s, t in enumerate(self.tiers):
            t.load_rows(idx[s * cap:(s + 1) * cap],
                        val[s * cap:(s + 1) * cap])
        ph = vecstore.empty(0, self.spec.max_nnz,
                            dtype=jnp.dtype(self.spec.value_dtype))
        self.state = shard_state(
            jax.tree.map(jnp.asarray, state._replace(store=ph)), self.mesh)
        self._steps.clear()
