"""Host-side serving drivers for the retrieval engine.

``QueryServer`` — batched query serving over a (possibly sharded) Sinnamon
index with the paper's anytime budget as the latency lever.  ``query`` /
``query_many`` return a typed :class:`repro.serving.results.QueryResult`
(ids, scores, k, backend, trace id) — the level-2 host surface over the
level-1 functional ``engine.search`` / ``search_batch`` (see
docs/serving.md).  Every query reports into a metrics registry
(`repro.obs`): latency/batch histograms per scoring backend, plus — on
sampled queries (``trace_every``) — a per-stage span breakdown
(admission → sketch scan → top-k merge → rerank) recorded by running the
same math as separate synced dispatches.  Concurrent-client admission,
dynamic batching and quotas live one level up, in
``repro.serving.frontend``; under overload the front door asks for
degraded answers (``query_many(..., degrade=N)``: shrunken rerank budget,
then sketch-only scoring — see docs/robustness.md).
"""

from __future__ import annotations

import time
from functools import partial
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as eng
from repro.core.engine import SinnamonIndex
from repro.fault import failpoints as _fp
from repro.obs import events as obs_events
from repro.obs import metrics as obs_metrics
from repro.obs import recorder as obs_recorder
from repro.obs.instrument import install_engine_gauges
from repro.obs.trace import Trace, TraceContext
from repro.serving.results import QueryResult
from repro.serving.sharded import ShardedSinnamonIndex

#: Stage names of the staged (traced) single-device query path, in order.
QUERY_STAGES = ("admission", "sketch_scan", "topk_merge", "rerank")

#: Stage names of the staged path over a tiered index: the candidate/rerank
#: split is real (two dispatches with a host slot sync between them), and
#: the device/prefetch stage — chunk-cache promotion of the candidates'
#: cold chunks — gets its own span.
TIERED_QUERY_STAGES = ("admission", "sketch_scan", "prefetch", "rerank")


# -- staged query pieces ------------------------------------------------------
# The production path is ONE fused jit program (engine.search_batch); these
# are the same stages as separate jitted dispatches, synced between spans so
# a sampled query can attribute wall time per stage (the SINDI-style
# breakdown).  Results are bit-identical to the fused path: identical
# operand prep, identical kernels, identical rerank.

@partial(jax.jit, static_argnums=(1, 4, 5))
def _tile_candidates(state, spec, q_idx, q_val, kprime, budget):
    from repro.kernels import ops as _ops
    return _ops.sinnamon_tile_topk(state, spec, q_idx, q_val, kprime,
                                   budget=budget, ok=state.active)


@partial(jax.jit, static_argnums=(2,))
def _merge_candidates(vals, slots, kprime):
    from repro.kernels import sinnamon_score as _sinn
    return _sinn.merge_tile_topk(vals, slots, kprime)


@partial(jax.jit, static_argnums=(1, 4, 5))
def _gated_scores(state, spec, q_idx, q_val, budget, backend):
    s = eng.score_batch(state, spec, q_idx, q_val, budget,
                        grouped=(backend == "grouped"))
    return jnp.where(state.active[None, :], s, -jnp.inf)


@partial(jax.jit, static_argnums=(1,))
def _dense_topk(scores, kprime):
    vals, slots = jax.lax.top_k(scores, kprime)
    return vals, slots.astype(jnp.int32)


@partial(jax.jit, static_argnums=(1,))
def _rerank(state, k, cand_scores, cand_slots, q_idx, q_val):
    return eng.rerank_topk(state, cand_scores, cand_slots, q_idx, q_val, k)


class QueryServer:
    """Serves one index — single-device or mesh-sharded; both expose the same
    ``search`` / ``search_many`` surface, so the server is layout-agnostic.

    ``score_backend`` picks the index's scoring backend per server
    (``reference | grouped | pallas``; None -> process default, see
    repro.kernels.ops.resolve_backend).

    Telemetry: every query records into ``registry`` (default: the
    process-global `repro.obs.metrics.get_registry()`; inject
    ``NULL_REGISTRY`` to turn metrics off).  With ``trace_every=N > 0``
    every N-th ``query_many`` batch runs the staged path and publishes
    per-stage histograms (``repro_query_stage_ms``) plus a ``query`` event
    with spans attached to the active event log.  Engine health gauges for
    ``index`` are installed on construction (weakref — dropping the server
    and index detaches them).

    Durable indexes (repro.persist.durable) serve through the same surface,
    and the server keeps answering during snapshots and background
    compaction: searches read the immutable state ref without taking the
    index's op lock, so maintenance never blocks the query path.
    """

    def __init__(self, index: Union[SinnamonIndex, ShardedSinnamonIndex],
                 k: int = 10, kprime: int = 1000,
                 budget: Optional[int] = None, score_fn=None,
                 score_backend: Optional[str] = None,
                 registry=None, event_log=None, trace_every: int = 0,
                 index_name: str = "index", recorder=None):
        self.index = index
        self.k, self.kprime, self.budget = k, kprime, budget
        self.score_fn = score_fn
        self.score_backend = score_backend
        self.registry = (obs_metrics.get_registry() if registry is None
                         else registry)
        self.event_log = event_log
        self.recorder = recorder
        self.trace_every = int(trace_every)
        self.stats = {"queries": 0}
        self.last_latency_ms = 0.0       # most recent per-query latency
        self.last_trace: Optional[Trace] = None
        self._since_trace = 0
        self._handles: dict = {}
        install_engine_gauges(index, self.registry, name=index_name)

    # -- metric handles (cached per label set) -------------------------------
    def _backend_label(self) -> str:
        if self.score_fn is not None:
            return "custom"
        from repro.kernels import ops as _ops
        backend = self.score_backend
        if backend is None:     # index default (repro.api) > process default
            backend = getattr(self.index, "default_backend", None)
        return _ops.resolve_backend(backend)

    def _hist(self, name: str, help_text: str, labels=None, buckets=None):
        key = (name, tuple(sorted((labels or {}).items())))
        h = self._handles.get(key)
        if h is None:
            h = self.registry.histogram(name, help_text, labels=labels,
                                        buckets=buckets)
            self._handles[key] = h
        return h

    def _latency_hist(self, backend: str):
        return self._hist("repro_query_latency_ms",
                          "Per-query serving latency.",
                          labels={"backend": backend})

    def _recorder(self):
        return self.recorder if self.recorder is not None \
            else obs_recorder.get_recorder()

    def _fail(self, ctx: TraceContext, owns: bool, e: BaseException) -> None:
        """Seal + record an errored context this server owns."""
        if not owns:
            return      # the front door owns the context's lifecycle
        ctx.finish("error", error=repr(e))
        rec = self._recorder()
        if rec is not None:
            rec.record(ctx)

    # -- serving -------------------------------------------------------------
    def query(self, q_idx, q_val, ctx: Optional[TraceContext] = None) \
            -> QueryResult:
        """Serve one query.  Returns a :class:`repro.serving.QueryResult`
        (``[k]`` ids/scores; unpackable as the legacy ``(ids, scores)``).

        ``ctx`` is an optional propagated :class:`TraceContext`; without
        one the server opens (and records) its own, so the result's
        ``trace_id`` resolves at ``/debug/trace/<id>`` whenever a flight
        recorder is installed."""
        backend = self._backend_label()
        owns = ctx is None
        if owns:
            ctx = TraceContext()
        try:
            with ctx.stage("device"):
                t0 = time.perf_counter()
                _fp.fire("device.dispatch")
                ids, scores = self.index.search(
                    q_idx, q_val, k=self.k, kprime=self.kprime,
                    budget=self.budget, score_fn=self.score_fn,
                    backend=self.score_backend)
                dt_ms = (time.perf_counter() - t0) * 1e3
        except Exception as e:
            self._fail(ctx, owns, e)
            raise
        self._record(1, dt_ms, backend, ctx=ctx, owns=owns)
        return QueryResult(ids=ids, scores=scores, k=len(ids),
                           backend=backend, trace_id=ctx.trace_id)

    def query_many(self, q_idx, q_val,
                   ctx: Optional[TraceContext] = None,
                   degrade: int = 0) -> QueryResult:
        """Batched serving path: [B, Lq] queries in ONE device dispatch.

        Amortizes dispatch + (on a sharded index) the candidate merge across
        the batch; per-query latency is recorded as batch time / B, so the
        percentile accounting stays comparable with :meth:`query`.  Returns
        one batched :class:`QueryResult` (``[B, k]``; ``.row(i)`` slices out
        a per-request result).

        With a caller-provided ``ctx`` (the front door's batch context) the
        server only annotates it — the caller seals and records it; without
        one the server owns the context end to end.

        ``degrade`` (the front door's ladder level): 1 shrinks the rerank
        candidate pool to k'/4; ≥2 answers sketch-only when the index
        supports it (scores become upper bounds).  Any degraded answer is
        stamped ``degraded=True`` and annotated on the trace.  Each level
        maps to one fixed jit specialization, so the ladder never causes
        per-request recompiles.
        """
        bn = len(q_idx)
        backend = self._backend_label()
        owns = ctx is None
        if owns:
            ctx = TraceContext()
        trace = None
        if self.trace_every > 0 and self.score_fn is None and degrade == 0:
            self._since_trace += 1
            if self._since_trace >= self.trace_every:
                self._since_trace = 0
                trace = Trace()
        sketch_only = (degrade >= 2 and self.score_fn is None
                       and hasattr(self.index, "search_many_sketch"))
        try:
            with ctx.stage("device"):
                t0 = time.perf_counter()
                _fp.fire("device.dispatch")
                if trace is not None:
                    ids, scores = self._search_staged(q_idx, q_val, trace)
                elif sketch_only:
                    ids, scores = self.index.search_many_sketch(
                        q_idx, q_val, k=self.k, budget=self.budget,
                        backend=self.score_backend)
                else:
                    kprime = self.kprime
                    if degrade >= 1:
                        if kprime is None:
                            kprime = max(5 * self.k, self.k)
                        kprime = max(self.k, kprime // 4)
                    # Rerank-bearing paths only: a stalled/broken rerank
                    # is exactly what sketch-only degradation sidesteps.
                    _fp.fire("device.rerank")
                    ids, scores = self.index.search_many(
                        q_idx, q_val, k=self.k, kprime=kprime,
                        budget=self.budget, score_fn=self.score_fn,
                        backend=self.score_backend)
                dt_ms = (time.perf_counter() - t0) * 1e3
        except Exception as e:
            self._fail(ctx, owns, e)
            raise
        if degrade > 0:
            ctx.annotate(degraded=True, degrade_level=int(degrade),
                         sketch_only=sketch_only)
        self._record(bn, dt_ms, backend, trace, ctx=ctx, owns=owns)
        return QueryResult(ids=ids, scores=scores, k=ids.shape[-1],
                           backend=backend, trace_id=ctx.trace_id,
                           degraded=degrade > 0)

    def _record(self, bn: int, dt_ms: float, backend: str,
                trace: Optional[Trace] = None,
                ctx: Optional[TraceContext] = None,
                owns: bool = False) -> None:
        per_query = dt_ms / bn
        self.stats["queries"] += bn
        self.last_latency_ms = per_query
        retained = None
        if ctx is not None:
            ctx.annotate(backend=backend, batch=bn)
            if trace is not None:
                ctx.add_trace(trace, prefix="device/")
            if owns:
                ctx.finish("ok", total_ms=dt_ms)
                rec = self._recorder()
                if rec is not None:
                    retained = rec.record(ctx)
        # exemplar only when the id actually resolves in the recorder ring
        self._latency_hist(backend).observe(
            per_query, n=bn,
            exemplar=ctx.trace_id if (ctx is not None and retained) else None)
        self._hist("repro_query_batch_docs", "Queries per serving batch.",
                   buckets=obs_metrics.DEFAULT_COUNT_BUCKETS).observe(bn)
        self.registry.counter("repro_queries_total", "Queries served.",
                              labels={"backend": backend}).inc(bn)
        if trace is not None:
            self.last_trace = trace
            self.registry.counter("repro_query_traces_total",
                                  "Sampled queries run on the staged "
                                  "(per-stage timed) path.").inc()
            for span in trace.spans:
                self._hist("repro_query_stage_ms",
                           "Wall time per query-path stage (sampled "
                           "staged dispatches, device-synced per span).",
                           labels={"stage": span.name,
                                   "backend": backend}).observe(span.ms)
        log = self.event_log if self.event_log is not None \
            else obs_events.get_event_log()
        if log is not None:
            log.emit("query", batch=bn, ms=round(dt_ms, 4), backend=backend,
                     trace_id=ctx.trace_id if ctx is not None else None,
                     spans=trace.as_dict()["spans"] if trace else None)

    # -- staged (traced) path ------------------------------------------------
    def _search_staged(self, q_idx, q_val, trace: Trace):
        if isinstance(self.index, eng.TieredSinnamonIndex):
            return self._staged_tiered(q_idx, q_val, trace)
        if isinstance(self.index, SinnamonIndex):
            return self._staged_single(q_idx, q_val, trace)
        return self._staged_generic(q_idx, q_val, trace)

    def _staged_single(self, q_idx, q_val, trace: Trace):
        index = self.index
        with trace.span("admission"):
            spec = index.spec
            state = index.state
            backend = self._backend_label()
            kprime = self.kprime if self.kprime is not None \
                else max(5 * self.k, self.k)
            kprime = min(kprime, spec.capacity)
            k = min(self.k, kprime)
            q_idx = jnp.asarray(q_idx)
            q_val = jnp.asarray(q_val)
        if backend == "pallas":
            with trace.span("sketch_scan"):
                tile_vals, tile_slots = _tile_candidates(
                    state, spec, q_idx, q_val, kprime, self.budget)
                jax.block_until_ready(tile_vals)
            with trace.span("topk_merge"):
                cand_scores, cand_slots = _merge_candidates(
                    tile_vals, tile_slots, kprime)
                jax.block_until_ready(cand_scores)
        else:
            with trace.span("sketch_scan"):
                scores = _gated_scores(state, spec, q_idx, q_val,
                                       self.budget, backend)
                jax.block_until_ready(scores)
            with trace.span("topk_merge"):
                cand_scores, cand_slots = _dense_topk(scores, kprime)
                jax.block_until_ready(cand_scores)
        with trace.span("rerank"):
            ids, top_scores, _ = _rerank(state, k, cand_scores, cand_slots,
                                         q_idx, q_val)
            out_ids = eng.unpack_ids64(np.asarray(ids))
            out_scores = np.asarray(top_scores)
        return out_ids, out_scores

    def _staged_tiered(self, q_idx, q_val, trace: Trace):
        """Tiered single-device index (see TIERED_QUERY_STAGES): reuses the
        index's own jitted candidate/rerank programs, so staged results are
        bit-identical to ``index.search_many``."""
        index = self.index
        with trace.span("admission"):
            spec = index.spec
            state = index.state
            kprime = self.kprime if self.kprime is not None \
                else max(5 * self.k, self.k)
            kprime = min(kprime, spec.capacity)
            k = min(self.k, kprime)
            q_idx = jnp.asarray(q_idx)
            q_val = jnp.asarray(q_val)
        with trace.span("sketch_scan"):
            ub, slots = index._cand(state, spec, q_idx, q_val, kprime,
                                    self.budget, None, score_fn=None,
                                    backend=index._backend(self.score_backend))
            slots_np = np.asarray(slots)             # host sync
        with trace.span("prefetch"):
            ridx, rval = index.tiered.gather_rows(slots_np.reshape(-1))
            jax.block_until_ready((ridx, rval))
        with trace.span("rerank"):
            ids, scores, _ = index._rerank_rows(state, ub, slots, ridx, rval,
                                                q_idx, q_val, k)
            out_ids = eng.unpack_ids64(np.asarray(ids))
            out_scores = np.asarray(scores)
        return out_ids, out_scores

    def _staged_generic(self, q_idx, q_val, trace: Trace):
        """Sharded (or unknown) index: shard-local stages live inside one
        shard_map program, so the finest honest split is admission vs the
        SPMD search dispatch."""
        with trace.span("admission"):
            q_idx = np.asarray(q_idx)
            q_val = np.asarray(q_val)
        if isinstance(self.index, ShardedSinnamonIndex):
            # the index records the (synced) spmd_search span itself
            ids, scores = self.index.search_many(
                q_idx, q_val, k=self.k, kprime=self.kprime,
                budget=self.budget, backend=self.score_backend, trace=trace)
        else:
            with trace.span("spmd_search"):
                ids, scores = self.index.search_many(
                    q_idx, q_val, k=self.k, kprime=self.kprime,
                    budget=self.budget, backend=self.score_backend)
        return ids, scores

    # -- stats ---------------------------------------------------------------
    def latency_percentiles(self):
        """Compat shim over the registry latency histogram (the one shared
        percentile implementation — `obs.metrics.Histogram.percentile`)."""
        h = self._latency_hist(self._backend_label())
        if h.count == 0:
            return {}
        return {f"p{p}": h.percentile(p) for p in (50, 90, 99)}

    def reset_stats(self) -> None:
        """Zero the query counter and this server's latency/stage samples
        (shared-registry histograms for the current backend label)."""
        backend = self._backend_label()
        self.stats["queries"] = 0
        self.last_trace = None
        self._latency_hist(backend).reset()
        for stage in QUERY_STAGES + TIERED_QUERY_STAGES + ("spmd_search",):
            self._hist("repro_query_stage_ms", "",
                       labels={"stage": stage, "backend": backend}).reset()
