"""Host-side serving drivers for the retrieval engine.

* ``QueryServer`` — batched query serving over a (possibly sharded) Sinnamon
  index with the paper's anytime budget as the latency lever.
* ``HedgedServer`` — straggler mitigation: the same query is issued to R
  replica indexes and the first completed answer wins.  On real clusters the
  replicas are distinct hosts; here they are distinct index objects and the
  "race" is simulated by a per-replica latency model, which is exactly what
  the tail-latency analysis needs (the compute results are identical —
  hedging is a scheduling property, validated as such in tests/test_ft.py).
"""

from __future__ import annotations

import time
from typing import Optional, Sequence, Union

import numpy as np

from repro.core.engine import SinnamonIndex
from repro.serving.sharded import ShardedSinnamonIndex


class LatencyRing:
    """Fixed-size ring buffer of latency samples.

    Under sustained traffic an unbounded list grows without limit; the ring
    keeps the most recent ``maxlen`` samples in a preallocated f32 buffer
    while exposing the same surface the old list did (append / extend /
    clear / len / np.asarray), so percentile accounting is unchanged — it
    just windows to recent traffic.
    """

    def __init__(self, maxlen: int = 8192):
        self.maxlen = int(maxlen)
        self._buf = np.zeros(self.maxlen, np.float32)
        self._pos = 0          # next write index
        self._count = 0        # total samples ever recorded

    def append(self, value: float) -> None:
        self._buf[self._pos] = value
        self._pos = (self._pos + 1) % self.maxlen
        self._count += 1

    def extend(self, values) -> None:
        for v in values:
            self.append(v)

    def clear(self) -> None:
        self._pos = 0
        self._count = 0

    def __len__(self) -> int:
        return min(self._count, self.maxlen)

    def __getitem__(self, i):
        """Index into the oldest-first window (list-compatible access)."""
        return np.asarray(self)[i]

    def __array__(self, dtype=None, copy=None):
        n = len(self)
        if self._count <= self.maxlen:
            out = self._buf[:n]
        else:                  # oldest-first view of the wrapped window
            out = np.concatenate([self._buf[self._pos:], self._buf[:self._pos]])
        out = np.array(out) if copy is None or copy else out
        return out.astype(dtype) if dtype is not None else out


class QueryServer:
    """Serves one index — single-device or mesh-sharded; both expose the same
    ``search`` / ``search_many`` surface, so the server is layout-agnostic.

    ``score_backend`` picks the index's scoring backend per server
    (``reference | grouped | pallas``; None -> process default, see
    repro.kernels.ops.resolve_backend).

    Durable indexes (repro.persist.durable) serve through the same surface,
    and the server keeps answering during snapshots and background
    compaction: searches read the immutable state ref without taking the
    index's op lock, so maintenance never blocks the query path.
    """

    def __init__(self, index: Union[SinnamonIndex, ShardedSinnamonIndex],
                 k: int = 10, kprime: int = 1000,
                 budget: Optional[int] = None, score_fn=None,
                 score_backend: Optional[str] = None,
                 latency_window: int = 8192):
        self.index = index
        self.k, self.kprime, self.budget = k, kprime, budget
        self.score_fn = score_fn
        self.score_backend = score_backend
        self.stats = {"queries": 0, "latency_ms": LatencyRing(latency_window)}

    def query(self, q_idx, q_val):
        t0 = time.perf_counter()
        ids, scores = self.index.search(
            q_idx, q_val, k=self.k, kprime=self.kprime, budget=self.budget,
            score_fn=self.score_fn, backend=self.score_backend)
        self.stats["queries"] += 1
        self.stats["latency_ms"].append((time.perf_counter() - t0) * 1e3)
        return ids, scores

    def query_many(self, q_idx, q_val):
        """Batched serving path: [B, Lq] queries in ONE device dispatch.

        Amortizes dispatch + (on a sharded index) the candidate merge across
        the batch; per-query latency is recorded as batch time / B, so the
        percentile accounting stays comparable with :meth:`query`.
        """
        bn = len(q_idx)
        t0 = time.perf_counter()
        ids, scores = self.index.search_many(
            q_idx, q_val, k=self.k, kprime=self.kprime, budget=self.budget,
            score_fn=self.score_fn, backend=self.score_backend)
        dt_ms = (time.perf_counter() - t0) * 1e3
        self.stats["queries"] += bn
        self.stats["latency_ms"].extend([dt_ms / bn] * bn)
        return ids, scores

    def latency_percentiles(self):
        lat = np.asarray(self.stats["latency_ms"])
        if lat.size == 0:
            return {}
        return {f"p{p}": float(np.percentile(lat, p)) for p in (50, 90, 99)}


class HedgedServer:
    """Issue each query to all replicas; take the first simulated finisher."""

    def __init__(self, replicas: Sequence[QueryServer], seed: int = 0,
                 straggler_prob: float = 0.1, straggler_mult: float = 10.0):
        self.replicas = list(replicas)
        self.gen = np.random.Generator(np.random.Philox(key=seed))
        self.straggler_prob = straggler_prob
        self.straggler_mult = straggler_mult
        self.effective_latency_ms: list = []

    def query(self, q_idx, q_val):
        finish = []
        answers = []
        for rep in self.replicas:
            ids, scores = rep.query(q_idx, q_val)
            base = rep.stats["latency_ms"][-1]
            if self.gen.random() < self.straggler_prob:
                base *= self.straggler_mult
            finish.append(base)
            answers.append((ids, scores))
        win = int(np.argmin(finish))
        self.effective_latency_ms.append(min(finish))
        return answers[win]
