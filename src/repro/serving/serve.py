"""Host-side serving drivers for the retrieval engine.

* ``QueryServer`` — batched query serving over a (possibly sharded) Sinnamon
  index with the paper's anytime budget as the latency lever.
* ``HedgedServer`` — straggler mitigation: the same query is issued to R
  replica indexes and the first completed answer wins.  On real clusters the
  replicas are distinct hosts; here they are distinct index objects and the
  "race" is simulated by a per-replica latency model, which is exactly what
  the tail-latency analysis needs (the compute results are identical —
  hedging is a scheduling property, validated as such in tests/test_ft.py).
"""

from __future__ import annotations

import time
from typing import Optional, Sequence, Union

import numpy as np

from repro.core.engine import SinnamonIndex
from repro.serving.sharded import ShardedSinnamonIndex


class QueryServer:
    """Serves one index — single-device or mesh-sharded; both expose the same
    ``search`` / ``search_many`` surface, so the server is layout-agnostic.

    Durable indexes (repro.persist.durable) serve through the same surface,
    and the server keeps answering during snapshots and background
    compaction: searches read the immutable state ref without taking the
    index's op lock, so maintenance never blocks the query path.
    """

    def __init__(self, index: Union[SinnamonIndex, ShardedSinnamonIndex],
                 k: int = 10, kprime: int = 1000,
                 budget: Optional[int] = None, score_fn=None):
        self.index = index
        self.k, self.kprime, self.budget = k, kprime, budget
        self.score_fn = score_fn
        self.stats = {"queries": 0, "latency_ms": []}

    def query(self, q_idx, q_val):
        t0 = time.perf_counter()
        ids, scores = self.index.search(
            q_idx, q_val, k=self.k, kprime=self.kprime, budget=self.budget,
            score_fn=self.score_fn)
        self.stats["queries"] += 1
        self.stats["latency_ms"].append((time.perf_counter() - t0) * 1e3)
        return ids, scores

    def query_many(self, q_idx, q_val):
        """Batched serving path: [B, Lq] queries in ONE device dispatch.

        Amortizes dispatch + (on a sharded index) the candidate merge across
        the batch; per-query latency is recorded as batch time / B, so the
        percentile accounting stays comparable with :meth:`query`.
        """
        bn = len(q_idx)
        t0 = time.perf_counter()
        ids, scores = self.index.search_many(
            q_idx, q_val, k=self.k, kprime=self.kprime, budget=self.budget,
            score_fn=self.score_fn)
        dt_ms = (time.perf_counter() - t0) * 1e3
        self.stats["queries"] += bn
        self.stats["latency_ms"].extend([dt_ms / bn] * bn)
        return ids, scores

    def latency_percentiles(self):
        lat = np.asarray(self.stats["latency_ms"])
        if lat.size == 0:
            return {}
        return {f"p{p}": float(np.percentile(lat, p)) for p in (50, 90, 99)}


class HedgedServer:
    """Issue each query to all replicas; take the first simulated finisher."""

    def __init__(self, replicas: Sequence[QueryServer], seed: int = 0,
                 straggler_prob: float = 0.1, straggler_mult: float = 10.0):
        self.replicas = list(replicas)
        self.gen = np.random.Generator(np.random.Philox(key=seed))
        self.straggler_prob = straggler_prob
        self.straggler_mult = straggler_mult
        self.effective_latency_ms: list = []

    def query(self, q_idx, q_val):
        finish = []
        answers = []
        for rep in self.replicas:
            ids, scores = rep.query(q_idx, q_val)
            base = rep.stats["latency_ms"][-1]
            if self.gen.random() < self.straggler_prob:
                base *= self.straggler_mult
            finish.append(base)
            answers.append((ids, scores))
        win = int(np.argmin(finish))
        self.effective_latency_ms.append(min(finish))
        return answers[win]
