"""Async serving front door: admission, deadline-aware dynamic batching,
per-tenant quotas, and a stdlib HTTP/JSON endpoint.

This is the layer that models *concurrent clients* over the fused batched
query path — the GPUExecutor shape: a bounded admission queue decouples
request intake from device execution, and one dispatcher thread drains it
into single fused ``query_many`` dispatches.

Pipeline (see docs/serving.md for the full diagram and SLO guidance)::

    client threads / HTTP handlers
        │  submit(q, tenant, deadline)
        ▼
    [admission]  per-tenant token bucket ──✗──► Rejected(throttled,
        │                                        retry_after)
        ▼
    [queue]  bounded depth ──✗──► Rejected(queue_full, retry_after)
        │                         (explicit backpressure, never silent
        ▼                          blocking)
    [dispatcher thread]  coalesce: wait ≤ batch_window_ms OR until
        │                max_batch queued, whichever first
        │   drop + count queries whose deadline elapsed while queued
        ▼
    QueryServer.query_many  — ONE fused dispatch for the whole batch
        │
        ▼
    per-request ``QueryResult`` futures (bit-identical to per-query
    ``query()`` answers — batching is a scheduling optimization, never a
    semantic one; asserted in tests/test_frontend.py)

Shape discipline: every dispatch is padded to exactly
``(max_batch, query_pad·j)`` so the jit cache holds one program per width
bucket instead of one per (B, Lq) combination.  Padding rows/coordinates
contribute exact zeros, which is why coalesced answers stay bit-identical.

Resilience (docs/robustness.md):

* the dispatcher is **supervised** — a crash restarts it (bounded times)
  instead of silently wedging every future;
* a **poisoned batch** is retried one query at a time, so only the
  malformed query's future fails and healthy riders still get answers;
* a **circuit breaker** over device dispatch fast-fails submits (429
  "unavailable") while the device is persistently broken; the half-open
  probe token is consumed by the dispatcher at dispatch time (never at
  admission), so a throttled/queue-full/expired request cannot strand it;
* a **stuck-device watchdog** fails in-flight futures with
  :class:`DeviceStuck` (HTTP 504) instead of hanging clients forever;
* a **degradation ladder** driven by SLO fast-burn and queue depth
  brownouts instead of blacking out: L1 shrinks the rerank budget, L2
  serves sketch-only answers stamped ``degraded``, L3 sheds
  lowest-priority tenants with 429 — with hysteresis auto-recovery.

All queue/batch/latency/drop behaviour reports into the ``repro.obs``
registry (metric catalog: docs/observability.md, "Serving front door").
"""

from __future__ import annotations

import inspect
import json
import math
import threading
import time
from collections import deque
from concurrent.futures import Future, InvalidStateError
from dataclasses import dataclass, field
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, Optional

import numpy as np

from repro.fault.degrade import DegradationController, DegradeConfig
from repro.fault.retry import CircuitBreaker
from repro.obs import metrics as obs_metrics
from repro.obs import recorder as obs_recorder
from repro.obs import server as obs_server
from repro.obs.recorder import new_batch_id
from repro.obs.trace import TraceContext
from repro.serving.results import QueryResult

__all__ = [
    "DeadlineExceeded",
    "DeviceStuck",
    "FrontendServer",
    "Rejected",
    "ServingFrontend",
    "TenantQuota",
]


class Rejected(RuntimeError):
    """Admission failure — the request never entered the queue.

    ``reason`` is ``"queue_full"`` (backpressure: the bounded admission
    queue is at depth) or ``"throttled"`` (the tenant's token bucket is
    empty).  ``retry_after_ms`` is the server's estimate of when capacity
    will exist; the HTTP front door surfaces it as a ``Retry-After`` header
    on a 429.
    """

    def __init__(self, reason: str, retry_after_ms: float, tenant: str,
                 trace_id: Optional[str] = None):
        super().__init__(f"rejected ({reason}, tenant={tenant!r}): "
                         f"retry after {retry_after_ms:.1f} ms")
        self.reason = reason
        self.retry_after_ms = float(retry_after_ms)
        self.tenant = tenant
        self.trace_id = trace_id     # resolves at /debug/trace/<id>


class DeadlineExceeded(RuntimeError):
    """The request's deadline elapsed while it sat in the queue.

    The query was admitted but never dispatched: spending device time on an
    answer nobody is still waiting for only steals capacity from requests
    that can still meet their deadline, so the dispatcher drops and counts
    it instead.
    """

    def __init__(self, queued_ms: float, deadline_ms: float,
                 trace_id: Optional[str] = None):
        super().__init__(f"deadline of {deadline_ms:.1f} ms elapsed after "
                         f"{queued_ms:.1f} ms in queue")
        self.queued_ms = queued_ms
        self.deadline_ms = deadline_ms
        self.trace_id = trace_id     # resolves at /debug/trace/<id>


class DeviceStuck(DeadlineExceeded):
    """The stuck-device watchdog failed this in-flight request.

    The dispatch it rode did not return within ``watchdog_timeout_s`` —
    a stalled device, not a busy queue.  Subclasses
    :class:`DeadlineExceeded` so every 504 path handles it unchanged;
    ``queued_ms``/``deadline_ms`` carry (time stuck, watchdog timeout).
    """


@dataclass(frozen=True)
class TenantQuota:
    """Token-bucket quota: sustained ``rate_qps`` with ``burst`` headroom.

    ``priority`` orders tenants for L3 load shedding: when the degradation
    ladder reaches its top level, tenants in the strictly-lowest priority
    class are shed with 429 (higher number = more important; sheds only
    when more than one distinct class exists)."""

    rate_qps: float
    burst: float = 0.0      # 0 -> defaults to max(rate_qps, 1)
    priority: int = 0

    def resolved_burst(self) -> float:
        return self.burst if self.burst > 0 else max(self.rate_qps, 1.0)


class _TokenBucket:
    def __init__(self, quota: TenantQuota, now: float):
        self.rate = float(quota.rate_qps)
        self.burst = float(quota.resolved_burst())
        self.tokens = self.burst
        self.t = now
        self.lock = threading.Lock()

    def try_take(self, now: float) -> float:
        """0.0 when a token was taken, else seconds until one exists."""
        with self.lock:
            self.tokens = min(self.burst,
                              self.tokens + (now - self.t) * self.rate)
            self.t = now
            if self.tokens >= 1.0:
                self.tokens -= 1.0
                return 0.0
            return (1.0 - self.tokens) / self.rate if self.rate > 0 \
                else math.inf


@dataclass
class _Pending:
    q_idx: np.ndarray
    q_val: np.ndarray
    k: Optional[int]
    tenant: str
    deadline_ms: float
    deadline: float              # clock timestamp
    enqueued: float              # clock timestamp
    ctx: TraceContext            # propagated request trace (ISSUE 8)
    future: Future = field(default_factory=Future)


def _pad_batch(items, width: int, rows: int):
    """Pad sparse queries to one ``[rows, width]`` rectangle.

    Shorter queries pad with (idx=-1, val=0) — scoring treats idx<0 as
    absent and the contribution is an exact 0.0, so padding never changes a
    real row's answer.  Rows beyond ``len(items)`` are all-padding dummy
    queries whose results are discarded.
    """
    qi = np.full((rows, width), -1, np.int32)
    qv = np.zeros((rows, width), np.float32)
    for b, p in enumerate(items):
        L = p.q_idx.shape[0]
        qi[b, :L] = p.q_idx
        qv[b, :L] = p.q_val
    return qi, qv


class ServingFrontend:
    """Deadline-aware dynamically batching front end over a `QueryServer`.

    The only thing this class asks of ``server`` is ``query_many`` returning
    a batched :class:`QueryResult` and a ``k`` attribute, so tests can stub
    the device side, and any index layout the ``QueryServer`` handles
    (single, sharded, durable) serves through it unchanged.

    Admission (caller thread, never blocks on the device):

    1. per-tenant token bucket (``quotas`` / ``default_quota``; None =
       unthrottled) — failure raises :class:`Rejected` ("throttled");
    2. bounded queue (``queue_depth``) — failure raises :class:`Rejected`
       ("queue_full") with a retry-after derived from the queue's current
       drain rate.

    Dispatch (single daemon thread): collect for ``batch_window_ms`` after
    the first waiting request OR until ``max_batch`` requests are queued,
    whichever comes first; drop queued requests whose deadline has already
    elapsed (their futures fail with :class:`DeadlineExceeded`); pad to the
    fixed ``(max_batch, width_bucket)`` rectangle; one fused
    ``query_many``; split the batched result into per-request futures.

    ``submit`` returns a ``concurrent.futures.Future[QueryResult]``;
    :meth:`query` is the blocking convenience wrapper.
    """

    def __init__(self, server, *, max_batch: int = 16,
                 batch_window_ms: float = 2.0, queue_depth: int = 128,
                 default_deadline_ms: float = 1000.0,
                 quotas: Optional[Dict[str, TenantQuota]] = None,
                 default_quota: Optional[TenantQuota] = None,
                 query_pad: int = 32, registry=None,
                 clock=time.monotonic, recorder=None,
                 slo=None, degrade: Optional[DegradeConfig] = None,
                 breaker: Optional[CircuitBreaker] = None,
                 watchdog_timeout_s: Optional[float] = None,
                 max_dispatcher_restarts: int = 3,
                 degrade_tick_s: float = 0.25):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if queue_depth < 1:
            raise ValueError(f"queue_depth must be >= 1, got {queue_depth}")
        self.server = server
        self.max_batch = int(max_batch)
        self.batch_window_s = float(batch_window_ms) / 1e3
        self.queue_depth = int(queue_depth)
        self.default_deadline_ms = float(default_deadline_ms)
        self.query_pad = int(query_pad)
        self.quotas = dict(quotas or {})
        self.default_quota = default_quota
        self.registry = (obs_metrics.get_registry() if registry is None
                         else registry)
        self.recorder = recorder     # None -> process-global at record time
        self._clock = clock
        self._queue: deque[_Pending] = deque()
        self._cv = threading.Condition()
        self._buckets: Dict[str, _TokenBucket] = {}
        self._buckets_lock = threading.Lock()
        self._closed = False
        self._ewma_service_s = 0.0           # drain-rate estimate for 429s
        # -- resilience state -------------------------------------------------
        self.slo = slo               # SLOMonitor: the ladder's burn signal
        # No config -> ladder off: overload answers stay pure backpressure
        # unless the operator opts into brownouts.
        self.degrade = DegradationController(
            degrade if degrade is not None else DegradeConfig(enabled=False),
            registry=self.registry)
        self.breaker = breaker if breaker is not None else CircuitBreaker(
            failure_threshold=5, reset_timeout_s=5.0, name="frontend",
            clock=clock, registry=self.registry)
        self.watchdog_timeout_s = watchdog_timeout_s
        self.max_dispatcher_restarts = int(max_dispatcher_restarts)
        self.dispatcher_restarts = 0
        self._dispatcher_dead = False
        self._degrade_tick_s = float(degrade_tick_s)
        self._inflight = None        # (t0, live) while a dispatch is on-device
        self._inflight_lock = threading.Lock()   # dispatcher/watchdog CAS
        self._live_batch = None      # batch the dispatch loop is holding
        self._supports_degrade = self._probe_degrade(server)
        self._metrics_init()
        self._dispatcher = threading.Thread(target=self._dispatch_supervised,
                                            name="frontend-dispatch",
                                            daemon=True)
        self._dispatcher.start()
        self._hk_stop = threading.Event()
        self._housekeeper = threading.Thread(target=self._housekeeping,
                                             name="frontend-housekeeping",
                                             daemon=True)
        self._housekeeper.start()

    @staticmethod
    def _probe_degrade(server) -> bool:
        """Does ``server.query_many`` accept the ``degrade`` kwarg?  Probed
        once so stub servers in tests (and older QueryServers) keep working
        without it."""
        try:
            return "degrade" in inspect.signature(
                server.query_many).parameters
        except (TypeError, ValueError):
            return False

    # -- metrics -------------------------------------------------------------
    def _metrics_init(self):
        reg = self.registry
        self._m_depth = reg.gauge(
            "repro_frontend_queue_depth",
            "Requests currently waiting in the admission queue.")
        self._m_batch = reg.histogram(
            "repro_frontend_batch_size",
            "Live queries per coalesced dispatch.",
            buckets=obs_metrics.DEFAULT_COUNT_BUCKETS)
        self._m_wait = reg.histogram(
            "repro_frontend_coalesce_wait_ms",
            "Oldest-request wait from enqueue to dispatch.")
        self._m_dispatch = reg.counter(
            "repro_frontend_dispatches_total",
            "Coalesced device dispatches issued.")
        self._m_expired = reg.counter(
            "repro_frontend_expired_total",
            "Queries dropped because their deadline elapsed while queued.")

    def _m_outcome(self, tenant: str, outcome: str):
        return self.registry.counter(
            "repro_frontend_requests_total",
            "Front-door requests by tenant and outcome.",
            labels={"tenant": tenant, "outcome": outcome})

    def _m_reject(self, reason: str):
        return self.registry.counter(
            "repro_frontend_rejected_total",
            "Admission rejections (explicit backpressure) by reason.",
            labels={"reason": reason})

    def _m_throttle(self, tenant: str):
        return self.registry.counter(
            "repro_frontend_throttled_total",
            "Token-bucket quota rejections per tenant.",
            labels={"tenant": tenant})

    def _m_latency(self, tenant: str):
        return self.registry.histogram(
            "repro_frontend_latency_ms",
            "End-to-end front-door latency (admission to response).",
            labels={"tenant": tenant})

    def _m_shed(self, tenant: str):
        return self.registry.counter(
            "repro_frontend_shed_total",
            "Requests shed at ladder L3 (lowest-priority tenants, 429).",
            labels={"tenant": tenant})

    def _m_degraded_queries(self, level: int):
        return self.registry.counter(
            "repro_frontend_degraded_queries_total",
            "Requests answered while the degradation ladder was engaged.",
            labels={"level": str(level)})

    # -- tracing -------------------------------------------------------------
    def _recorder(self):
        return self.recorder if self.recorder is not None \
            else obs_recorder.get_recorder()

    def _seal(self, ctx: TraceContext, outcome: str, total_ms: float,
              error: Optional[str] = None):
        """Finish a request context and hand it to the flight recorder.
        Returns the retention reason (truthy when the id resolves)."""
        ctx.finish(outcome, total_ms=total_ms, error=error)
        rec = self._recorder()
        return rec.record(ctx) if rec is not None else None

    # -- admission -----------------------------------------------------------
    def submit(self, q_idx, q_val, *, tenant: str = "default",
               deadline_ms: Optional[float] = None,
               k: Optional[int] = None) -> Future:
        """Admit one query; returns a ``Future[QueryResult]``.

        Raises :class:`Rejected` synchronously when admission fails (quota
        or queue depth); the future fails with :class:`DeadlineExceeded`
        when the deadline elapses in-queue, or with the device error if the
        dispatch itself fails.
        """
        if self._closed:
            raise RuntimeError("frontend is closed")
        now = self._clock()
        ctx = TraceContext(tenant=tenant)
        deadline_ms = (self.default_deadline_ms if deadline_ms is None
                       else float(deadline_ms))
        if self._dispatcher_dead or self.breaker.state == "open":
            # Fast-fail while the device side is known-broken (breaker
            # open, or the supervised dispatcher exhausted its restarts):
            # a 429 with a honest retry hint beats queueing into a void.
            # Deliberately a state CHECK, not allow(): the half-open probe
            # token is consumed by the dispatcher at dispatch time, so a
            # request that is throttled, queue-full, or expires in queue
            # can never strand the probe and wedge the breaker.
            retry_ms = (self.breaker.remaining_s() * 1e3
                        if not self._dispatcher_dead
                        else self.default_deadline_ms)
            self._m_reject("unavailable").inc()
            self._m_outcome(tenant, "rejected_unavailable").inc()
            ctx.annotate(retry_after_ms=round(retry_ms, 3),
                         breaker=self.breaker.state,
                         dispatcher_dead=self._dispatcher_dead)
            self._seal(ctx, "rejected_unavailable",
                       (self._clock() - now) * 1e3)
            raise Rejected("unavailable", retry_ms, tenant,
                           trace_id=ctx.trace_id)
        if self.degrade.level >= 3 and self._sheddable(tenant):
            self._m_shed(tenant).inc()
            self._m_reject("shed").inc()
            self._m_outcome(tenant, "rejected_shed").inc()
            ctx.annotate(retry_after_ms=1000.0,
                         degrade_level=self.degrade.level)
            self._seal(ctx, "rejected_shed", (self._clock() - now) * 1e3)
            raise Rejected("shed", 1000.0, tenant, trace_id=ctx.trace_id)
        quota = self.quotas.get(tenant, self.default_quota)
        if quota is not None:
            with self._buckets_lock:
                bucket = self._buckets.get(tenant)
                if bucket is None:
                    bucket = self._buckets[tenant] = _TokenBucket(quota, now)
            wait_s = bucket.try_take(now)
            ctx.add_stage("quota", (self._clock() - now) * 1e3, start_ms=0.0)
            if wait_s > 0:
                self._m_throttle(tenant).inc()
                self._m_reject("throttled").inc()
                self._m_outcome(tenant, "rejected_throttled").inc()
                ctx.annotate(retry_after_ms=round(wait_s * 1e3, 3))
                self._seal(ctx, "rejected_throttled",
                           (self._clock() - now) * 1e3)
                raise Rejected("throttled", wait_s * 1e3, tenant,
                               trace_id=ctx.trace_id)
        else:
            ctx.add_stage("quota", (self._clock() - now) * 1e3, start_ms=0.0)
        p = _Pending(
            q_idx=np.asarray(q_idx, np.int32).reshape(-1),
            q_val=np.asarray(q_val, np.float32).reshape(-1),
            k=k, tenant=tenant, deadline_ms=deadline_ms,
            deadline=now + deadline_ms / 1e3, enqueued=now,
            ctx=ctx)
        if p.q_idx.shape != p.q_val.shape:
            raise ValueError(f"query idx/val length mismatch: "
                             f"{p.q_idx.shape[0]} vs {p.q_val.shape[0]}")
        with self._cv:
            if len(self._queue) >= self.queue_depth:
                # Explicit backpressure: hand the client a retry hint
                # instead of silently blocking its thread on our queue.
                per = self._ewma_service_s or self.batch_window_s or 1e-3
                retry_ms = per * (1 + len(self._queue) / self.max_batch) * 1e3
                self._m_reject("queue_full").inc()
                self._m_outcome(tenant, "rejected_queue_full").inc()
                ctx.annotate(retry_after_ms=round(retry_ms, 3),
                             queue_depth=len(self._queue))
                self._seal(ctx, "rejected_queue_full",
                           (self._clock() - now) * 1e3)
                raise Rejected("queue_full", retry_ms, tenant,
                               trace_id=ctx.trace_id)
            self._queue.append(p)
            self._m_depth.set(len(self._queue))
            self._cv.notify_all()
        return p.future

    def query(self, q_idx, q_val, *, tenant: str = "default",
              deadline_ms: Optional[float] = None,
              k: Optional[int] = None) -> QueryResult:
        """Blocking convenience wrapper over :meth:`submit`."""
        return self.submit(q_idx, q_val, tenant=tenant,
                           deadline_ms=deadline_ms, k=k).result()

    # -- dispatch ------------------------------------------------------------
    def _take_batch(self):
        """Wait for work, coalesce, and pop up to ``max_batch`` requests."""
        with self._cv:
            while not self._queue and not self._closed:
                self._cv.wait()
            if not self._queue:
                return []
            first = self._queue[0].enqueued
            while (len(self._queue) < self.max_batch and not self._closed):
                remaining = first + self.batch_window_s - self._clock()
                if remaining <= 0:
                    break
                self._cv.wait(timeout=remaining)
                if not self._queue:          # everything got drained/closed
                    return []
                first = self._queue[0].enqueued
            n = min(len(self._queue), self.max_batch)
            batch = [self._queue.popleft() for _ in range(n)]
            self._m_depth.set(len(self._queue))
            return batch

    def _sheddable(self, tenant: str) -> bool:
        """L3 sheds only the strictly-lowest priority class, and only when
        more than one class exists — uniform deployments never shed."""
        prios = {q.priority for q in self.quotas.values()}
        prios.add(self.default_quota.priority
                  if self.default_quota is not None else 0)
        if len(prios) <= 1:
            return False
        quota = self.quotas.get(tenant, self.default_quota)
        return (quota.priority if quota is not None else 0) == min(prios)

    @staticmethod
    def _try_fail(future: Future, exc: BaseException) -> bool:
        """Fail a future unless someone (watchdog vs dispatcher race) beat
        us to it.  True when this call actually set the exception."""
        try:
            future.set_exception(exc)
            return True
        except InvalidStateError:
            return False

    def _server_query(self, qi, qv, ctx, level: int):
        if self._supports_degrade and level > 0:
            return self.server.query_many(qi, qv, ctx=ctx, degrade=level)
        return self.server.query_many(qi, qv, ctx=ctx)

    def _dispatch_supervised(self):
        """Dispatcher crash supervisor: ``_dispatch_loop`` exiting cleanly
        (close) ends the thread; anything escaping it — only a bug in the
        loop itself can, batch failures are handled inside — restarts the
        loop up to ``max_dispatcher_restarts`` times before declaring the
        front door dead and failing everything still queued."""
        while True:
            try:
                self._dispatch_loop()
                return
            except BaseException as e:                   # noqa: BLE001
                # Whatever crashed the loop, the batch it was holding must
                # not leak: query() blocks on these futures with no timeout,
                # so an unfailed future is a client hung forever — exactly
                # the wedge this supervisor exists to prevent.
                batch, self._live_batch = self._live_batch, None
                for p in (batch or ()):
                    if p.future.done():
                        continue
                    self._m_outcome(p.tenant, "error").inc()
                    self._seal(p.ctx, "error",
                               (self._clock() - p.enqueued) * 1e3,
                               error=repr(e))
                    self._try_fail(p.future, e)
                if self._closed:
                    return
                self.dispatcher_restarts += 1
                self.registry.counter(
                    "repro_frontend_dispatcher_restarts_total",
                    "Supervised dispatcher crash-restarts.").inc()
                if self.dispatcher_restarts > self.max_dispatcher_restarts:
                    self._dispatcher_dead = True
                    with self._cv:
                        pending = list(self._queue)
                        self._queue.clear()
                        self._m_depth.set(0)
                    for p in pending:
                        self._m_outcome(p.tenant, "error").inc()
                        self._seal(p.ctx, "error",
                                   (self._clock() - p.enqueued) * 1e3,
                                   error=repr(e))
                        self._try_fail(p.future, e)
                    return

    def _dispatch_loop(self):
        while True:
            batch = self._take_batch()
            if not batch:
                if self._closed:
                    return
                continue
            self._live_batch = batch    # supervisor fails these on a crash
            now = self._clock()
            live = []
            for p in batch:
                queued_ms = (now - p.enqueued) * 1e3
                p.ctx.add_stage("queue", queued_ms)
                if p.deadline < now:
                    self._m_expired.inc()
                    self._m_outcome(p.tenant, "expired").inc()
                    self._seal(p.ctx, "expired", queued_ms,
                               error=f"deadline {p.deadline_ms:.1f} ms "
                                     f"elapsed in queue")
                    p.future.set_exception(DeadlineExceeded(
                        queued_ms, p.deadline_ms, trace_id=p.ctx.trace_id))
                else:
                    live.append(p)
            if not live:
                self._live_batch = None
                continue
            if not self.breaker.allow():
                # The breaker opened after these requests were admitted
                # (or the half-open probe dispatch is already in flight):
                # fast-fail instead of burning a known-broken device.  The
                # probe token is consumed HERE, by an actual dispatch whose
                # outcome is always recorded below — never by a request
                # that might be rejected or expire before reaching us.
                self._fail_unavailable(live)
                self._live_batch = None
                continue
            self._m_wait.observe(
                (now - min(p.enqueued for p in live)) * 1e3)
            self._m_batch.observe(len(live))
            self._m_dispatch.inc()
            bctx = TraceContext(tenant="batch", trace_id=new_batch_id())
            width = max(p.q_idx.shape[0] for p in live)
            width = max(self.query_pad,
                        -(-width // self.query_pad) * self.query_pad)
            level = self.degrade.level
            t0 = self._clock()
            try:
                qi, qv = _pad_batch(live, width, self.max_batch)
                bctx.add_stage("assembly", (self._clock() - t0) * 1e3,
                               start_ms=0.0)
                inflight = (self._clock(), live)
                with self._inflight_lock:
                    self._inflight = inflight
                try:
                    res = self._server_query(qi, qv, bctx, level)
                finally:
                    with self._inflight_lock:
                        # Identity compare: the watchdog clears exactly the
                        # tuple it tripped on, so a trip can never be
                        # mistaken for (or clobber) a different dispatch.
                        tripped = self._inflight is not inflight
                        self._inflight = None
            except Exception as e:                       # noqa: BLE001
                self._fail_batch(bctx, live, width, e, level)
                self._live_batch = None
                continue
            if not tripped:
                self.breaker.record_success()
            dt = self._clock() - t0
            a = 0.2        # smooth the drain-rate estimate for 429 hints
            self._ewma_service_s = (dt if self._ewma_service_s == 0
                                    else a * dt + (1 - a) * self._ewma_service_s)
            done = self._clock()
            pad_frac = 1.0 - (sum(p.q_idx.shape[0] for p in live)
                              / float(self.max_batch * width))
            if level > 0:
                self._m_degraded_queries(level).inc(len(live))
                bctx.annotate(degrade_level=level)
            for i, p in enumerate(live):
                if p.future.done():
                    continue        # watchdog already 504'd this rider
                out = res.row(i, k=p.k, trace_id=p.ctx.trace_id)
                self._m_outcome(p.tenant, "ok").inc()
                lat_ms = (done - p.enqueued) * 1e3
                # batch-level stages (assembly + synced device dispatch +
                # sampled device/* sub-spans) are wall time every rider
                # waited through, so each request inherits them whole.
                for name, _start, dur in bctx.stages:
                    p.ctx.add_stage(name, dur)
                p.ctx.add_stage("respond", (self._clock() - done) * 1e3)
                p.ctx.annotate(batch_id=bctx.trace_id, batch_size=len(live),
                               width_bucket=width,
                               padding_fraction=round(pad_frac, 4))
                if level > 0:
                    p.ctx.annotate(degraded=True, degrade_level=level)
                retained = self._seal(p.ctx, "ok", lat_ms)
                self._m_latency(p.tenant).observe(
                    lat_ms, exemplar=p.ctx.trace_id if retained else None)
                try:
                    p.future.set_result(out)
                except InvalidStateError:
                    pass            # lost the race to the watchdog
            bctx.finish("ok", total_ms=(self._clock() - t0) * 1e3)
            self._record_batch(bctx, live, width)
            self._live_batch = None

    def _fail_unavailable(self, live) -> None:
        """Fast-fail already-admitted requests while the breaker is open:
        the same 429 "unavailable" answer :meth:`submit` gives new traffic,
        minus the admission work."""
        retry_ms = (self.breaker.remaining_s() * 1e3
                    or self.default_deadline_ms)
        for p in live:
            if p.future.done():
                continue
            self._m_reject("unavailable").inc()
            self._m_outcome(p.tenant, "rejected_unavailable").inc()
            p.ctx.annotate(retry_after_ms=round(retry_ms, 3),
                           breaker=self.breaker.state)
            self._seal(p.ctx, "rejected_unavailable",
                       (self._clock() - p.enqueued) * 1e3)
            self._try_fail(p.future, Rejected(
                "unavailable", retry_ms, p.tenant, trace_id=p.ctx.trace_id))

    def _fail_batch(self, bctx: TraceContext, live, width: int,
                    e: BaseException, level: int) -> None:
        """A coalesced dispatch raised.  One malformed query must not fail
        its healthy riders: with >1 live query each one is retried as its
        own single-row dispatch (same padded shape, so no fresh jit
        compile), and only the queries that still fail get the exception.
        The breaker records a device failure only when nothing could be
        served singly (a poisoned query is not a broken device)."""
        err = repr(e)
        bctx.finish("error", error=err)
        recovered = 0
        for i, p in enumerate(live):
            if p.future.done():
                continue
            out = exc = None
            if len(live) > 1:
                sctx = TraceContext(tenant="batch", trace_id=new_batch_id())
                try:
                    qi, qv = _pad_batch([p], width, self.max_batch)
                    res = self._server_query(qi, qv, sctx, level)
                    sctx.finish("ok")
                    out = res.row(0, k=p.k, trace_id=p.ctx.trace_id)
                except Exception as se:                  # noqa: BLE001
                    sctx.finish("error", error=repr(se))
                    exc = se
            else:
                exc = e
            for name, _start, dur in bctx.stages:
                p.ctx.add_stage(name, dur)
            lat_ms = (self._clock() - p.enqueued) * 1e3
            if out is not None:
                recovered += 1
                self._m_outcome(p.tenant, "ok").inc()
                p.ctx.annotate(batch_id=bctx.trace_id, retried_single=True)
                retained = self._seal(p.ctx, "ok", lat_ms)
                self._m_latency(p.tenant).observe(
                    lat_ms, exemplar=p.ctx.trace_id if retained else None)
                try:
                    p.future.set_result(out)
                except InvalidStateError:
                    pass
            else:
                self._m_outcome(p.tenant, "error").inc()
                self._seal(p.ctx, "error", lat_ms, error=repr(exc))
                self._try_fail(p.future, exc)
        if recovered:
            self.breaker.record_success()
        else:
            self.breaker.record_failure()
        self._record_batch(bctx, live, width)

    # -- housekeeping: watchdog + degradation ladder -------------------------
    def _housekeeping(self):
        """Sidecar thread: the dispatcher blocks inside ``query_many``
        during a device stall, so the watchdog and the ladder tick must
        live on their own thread.  The body is exception-guarded: a bug in
        the SLO signal or a metrics call must not silently kill the
        watchdog and the ladder, so failures are counted and the loop
        keeps running."""
        last_tick = self._clock()
        while not self._hk_stop.wait(0.05):
            try:
                now = self._clock()
                if self.watchdog_timeout_s is not None:
                    inflight = self._inflight
                    if inflight is not None:
                        t0, _live = inflight
                        if now - t0 > self.watchdog_timeout_s:
                            self._trip_watchdog(inflight, (now - t0) * 1e3)
                if self.degrade.config.enabled \
                        and now - last_tick >= self._degrade_tick_s:
                    last_tick = now
                    burn = (self.slo.fast_burn() if self.slo is not None
                            else 0.0)
                    self.degrade.tick(
                        burn=burn,
                        queue_frac=len(self._queue) / self.queue_depth)
            except Exception:                            # noqa: BLE001
                self.registry.counter(
                    "repro_frontend_housekeeping_errors_total",
                    "Exceptions swallowed by the housekeeping loop "
                    "(watchdog + degradation ladder kept alive).").inc()

    def _trip_watchdog(self, inflight, stalled_ms: float) -> None:
        """Fail a stuck dispatch's futures with 504 instead of hanging the
        clients; the dispatcher thread is still blocked on the device and
        will skip every already-done future when (if) it returns.
        Compare-and-clear on the exact snapshot: if the stalled dispatch
        returned (and the dispatcher possibly started the next one)
        between the housekeeping check and this call, the trip is a no-op
        instead of 504'ing a healthy dispatch and mis-recording a breaker
        failure for one that completed."""
        _t0, live = inflight
        with self._inflight_lock:
            if self._inflight is not inflight:
                return              # the stalled dispatch already returned
            self._inflight = None   # fire at most once per dispatch
        self.registry.counter(
            "repro_frontend_watchdog_trips_total",
            "Stuck-device watchdog activations (in-flight futures 504'd)."
        ).inc()
        self.breaker.record_failure()
        timeout_ms = self.watchdog_timeout_s * 1e3
        for p in live:
            if p.future.done():
                continue
            exc = DeviceStuck(stalled_ms, timeout_ms,
                              trace_id=p.ctx.trace_id)
            if self._try_fail(p.future, exc):
                self._m_outcome(p.tenant, "stuck").inc()
                self._seal(p.ctx, "stuck",
                           (self._clock() - p.enqueued) * 1e3,
                           error=f"device stuck > {timeout_ms:.0f} ms")

    def _record_batch(self, bctx: TraceContext, live, width: int) -> None:
        """Retain one coalesced-dispatch record in the recorder's batch
        ring (`/debug/batches`, `/debug/trace/<batch_id>`)."""
        rec = self._recorder()
        if rec is None:
            return
        pad_frac = 1.0 - (sum(p.q_idx.shape[0] for p in live)
                          / float(self.max_batch * width))
        bctx.annotate(batch_id=bctx.trace_id, size=len(live),
                      width_bucket=width,
                      padding_fraction=round(pad_frac, 4),
                      trace_ids=[p.ctx.trace_id for p in live])
        rec.record_batch(bctx.to_dict())

    # -- lifecycle -----------------------------------------------------------
    def close(self, drain: bool = True) -> None:
        """Stop the dispatcher.  With ``drain`` (default) queued requests
        are served first; otherwise their futures fail with `Rejected`."""
        with self._cv:
            self._closed = True
            if not drain:
                now = self._clock()
                while self._queue:
                    p = self._queue.popleft()
                    self._m_outcome(p.tenant, "rejected_shutdown").inc()
                    p.ctx.add_stage("queue", (now - p.enqueued) * 1e3)
                    self._seal(p.ctx, "rejected_shutdown",
                               (now - p.enqueued) * 1e3)
                    p.future.set_exception(
                        Rejected("shutdown", 0.0, p.tenant,
                                 trace_id=p.ctx.trace_id))
                self._m_depth.set(0)
            self._cv.notify_all()
        self._hk_stop.set()
        self._dispatcher.join(timeout=30)
        self._housekeeper.join(timeout=5)

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        self.close()
        return False


# ---------------------------------------------------------------------------
# HTTP/JSON front door
# ---------------------------------------------------------------------------

class FrontendServer:
    """Stdlib HTTP/JSON front door over a :class:`ServingFrontend`.

    Endpoints:

    * ``POST /v1/query`` — body ``{"indices": [...], "values": [...]}`` plus
      optional ``"k"``, ``"tenant"``, ``"deadline_ms"``; responds 200 with
      ``{"ids", "scores", "k", "backend", "trace_id", "degraded"}``
      (``degraded`` true when the answer was served under the degradation
      ladder), 429 + ``Retry-After`` on admission rejection (reasons:
      throttled, queue_full, unavailable — breaker open, shed — ladder
      L3), 504 on in-queue deadline expiry or a watchdog-detected stuck
      device, 400 on malformed input.
    * the standard observability endpoints (``/metrics``,
      ``/metrics.json``, ``/healthz``, ``/readyz``) plus any ``/debug/*``
      surfaces, mounted from ``repro.obs.server`` — one port serves both
      queries and scrapes.  ``/readyz`` defaults to two live checks:
      the dispatcher thread is alive, and the admission queue is below 90%
      of its depth (saturated = not ready, so load balancers stop sending
      before clients start seeing 429s); pass ``ready=`` to extend or
      replace them.

    Handlers block in ``frontend.query`` (each connection gets a thread via
    ``ThreadingHTTPServer``), so concurrent clients coalesce into fused
    batches exactly like in-process callers.  Rejection (429) and deadline
    (504) bodies carry the request's ``trace_id``, which resolves at
    ``/debug/trace/<id>`` whenever a flight recorder is mounted.
    """

    def __init__(self, frontend: ServingFrontend, host: str = "127.0.0.1",
                 port: int = 0, registry=None, *, ready=None, recorder=None,
                 slo=None, profile_dir=None):
        self.frontend = frontend
        self.host = host
        self.port = int(port)
        self.registry = (frontend.registry if registry is None else registry)
        if ready is None:
            ready = obs_server.ReadyState()
            ready.add_check("dispatcher", self._check_dispatcher)
            ready.add_check("admission_queue", self._check_queue)
        self.ready = ready
        self.recorder = recorder
        self.slo = slo
        self.profile_dir = profile_dir
        self._httpd = None
        self._thread = None

    def _check_dispatcher(self):
        alive = self.frontend._dispatcher.is_alive()
        return alive, "" if alive else "dispatcher thread is not running"

    def _check_queue(self):
        depth = len(self.frontend._queue)
        limit = 0.9 * self.frontend.queue_depth
        ok = depth < limit
        return ok, "" if ok else (f"admission queue saturated: "
                                  f"{depth}/{self.frontend.queue_depth}")

    def start(self) -> "FrontendServer":
        frontend = self.frontend
        recorder = self.recorder if self.recorder is not None \
            else frontend._recorder()
        get_endpoints = obs_server.build_endpoints(
            self.registry, ready=self.ready, recorder=recorder,
            slo=self.slo, profile_dir=self.profile_dir)

        class Handler(BaseHTTPRequestHandler):
            def _reply(self, code: int, body: bytes, ctype: str,
                       headers=()):
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                for k, v in headers:
                    self.send_header(k, v)
                self.end_headers()
                self.wfile.write(body)

            def _reply_json(self, code: int, doc: dict, headers=()):
                self._reply(code, json.dumps(doc).encode("utf-8"),
                            "application/json", headers)

            def do_GET(self):  # noqa: N802 - http.server API
                routed = obs_server.dispatch(get_endpoints, self.path)
                if routed is None:
                    self.send_error(404)
                    return
                status, body, ctype = routed
                self._reply(status, body, ctype)

            def do_POST(self):  # noqa: N802 - http.server API
                if self.path != "/v1/query":
                    self.send_error(404)
                    return
                try:
                    length = int(self.headers.get("Content-Length", 0))
                    doc = json.loads(self.rfile.read(length))
                    q_idx = np.asarray(doc["indices"], np.int32)
                    q_val = np.asarray(doc["values"], np.float32)
                    if q_idx.ndim != 1 or q_idx.shape != q_val.shape:
                        raise ValueError("indices/values must be equal-"
                                         "length 1-d arrays")
                    tenant = str(doc.get("tenant", "default"))
                    deadline_ms = doc.get("deadline_ms")
                    k = doc.get("k")
                except (KeyError, TypeError, ValueError,
                        json.JSONDecodeError) as e:
                    self._reply_json(400, {"error": "bad_request",
                                           "detail": str(e)})
                    return
                try:
                    res = frontend.query(q_idx, q_val, tenant=tenant,
                                         deadline_ms=deadline_ms, k=k)
                except Rejected as e:
                    self._reply_json(
                        429, {"error": "rejected", "reason": e.reason,
                              "retry_after_ms": e.retry_after_ms,
                              "trace_id": e.trace_id},
                        headers=[("Retry-After",
                                  str(max(1, math.ceil(e.retry_after_ms
                                                       / 1e3))))])
                    return
                except DeadlineExceeded as e:
                    self._reply_json(504, {"error": "deadline_exceeded",
                                           "queued_ms": round(e.queued_ms, 3),
                                           "deadline_ms": e.deadline_ms,
                                           "trace_id": e.trace_id})
                    return
                self._reply_json(200, {
                    "ids": [int(i) for i in res.ids],
                    "scores": [float(s) for s in res.scores],
                    "k": res.k, "backend": res.backend,
                    "trace_id": res.trace_id,
                    "degraded": bool(getattr(res, "degraded", False))})

            def log_message(self, fmt, *args):
                pass    # request logging belongs to metrics, not stderr

        self._httpd = ThreadingHTTPServer((self.host, self.port), Handler)
        self._httpd.daemon_threads = True
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="frontend-http", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._httpd = None
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def __enter__(self):
        if self._httpd is None:
            self.start()
        return self

    def __exit__(self, exc_type, exc, tb):
        self.stop()
        return False
