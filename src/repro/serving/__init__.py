"""Host-side serving surface over the Sinnamon engine.

Two levels (docs/serving.md):

* `QueryServer.query` / `QueryServer.query_many` — synchronous, typed
  (`QueryResult`), instrumented single-index serving;
* `ServingFrontend` / `FrontendServer` — the async front door: bounded
  admission queue with explicit backpressure, per-tenant token-bucket
  quotas, and deadline-aware dynamic batching into fused ``query_many``
  dispatches, plus the stdlib HTTP/JSON endpoint.

`repro.serving.loadgen` drives offered-load sweeps against either level.
"""

from repro.serving import loadgen
from repro.serving.frontend import (
    DeadlineExceeded,
    DeviceStuck,
    FrontendServer,
    Rejected,
    ServingFrontend,
    TenantQuota,
)
from repro.serving.results import QueryResult, new_trace_id
from repro.serving.serve import QueryServer
from repro.serving.sharded import ShardedSinnamonIndex

__all__ = [
    "DeadlineExceeded",
    "DeviceStuck",
    "FrontendServer",
    "QueryResult",
    "QueryServer",
    "Rejected",
    "ServingFrontend",
    "ShardedSinnamonIndex",
    "TenantQuota",
    "loadgen",
    "new_trace_id",
]
