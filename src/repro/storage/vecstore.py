"""Vector storage (the "S" box of the paper's Figure 1).

The vector database keeps every active vector in raw (exactly recoverable)
form; the retrieval engine fetches candidates from it for the exact rerank of
Algorithm 7.  On TPU the natural representation is **padded CSR** over slots:

    indices : int32[C, P]   active coordinates, padded with -1
    values  : f32/bf16[C, P]

Fetching k' candidates is a row gather; exact inner products are a gather of
``q_dense[indices]`` plus a masked dot — dense, regular, MXU/VPU-friendly.
The same primitive scanned over *all* slots is the TPU-native exact LinScan
("document-ordered scan"; see DESIGN.md §2).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class VecStore(NamedTuple):
    indices: Array   # int32[C, P], pad = -1
    values: Array    # [C, P]

    @property
    def capacity(self) -> int:
        return self.indices.shape[0]

    @property
    def max_nnz(self) -> int:
        return self.indices.shape[1]


def empty(capacity: int, max_nnz: int, dtype=jnp.float32) -> VecStore:
    return VecStore(
        indices=jnp.full((capacity, max_nnz), -1, dtype=jnp.int32),
        values=jnp.zeros((capacity, max_nnz), dtype=dtype),
    )


def write(store: VecStore, slot, idx: Array, val: Array) -> VecStore:
    return VecStore(
        indices=store.indices.at[slot].set(idx),
        values=store.values.at[slot].set(val.astype(store.values.dtype)),
    )


def erase(store: VecStore, slot) -> VecStore:
    return VecStore(
        indices=store.indices.at[slot].set(-1),
        values=store.values.at[slot].set(0),
    )


def densify_query(n: int, q_idx: Array, q_val: Array) -> Array:
    """Scatter a padded sparse query into a dense R^n vector."""
    valid = q_idx >= 0
    safe = jnp.where(valid, q_idx, 0)
    contrib = jnp.where(valid, q_val.astype(jnp.float32), 0.0)
    return jnp.zeros((n,), jnp.float32).at[safe].add(contrib, mode="drop")


def combine_query(q_idx: Array, q_val: Array) -> tuple:
    """Sort query coordinates (pads routed last) and combine duplicates.

    Returns ``(qs, comb)``: sorted coordinate keys (pad = int32 max) and, at
    every position, the TOTAL value of its coordinate's duplicate run — the
    same sum densify_query's scatter-add produces.  The combine is a sorted
    segment-sum: O(ψ_q log ψ_q) for the sort plus one length-ψ_q scatter-add,
    replacing the old O(ψ_q²) pairwise-equality mask.
    """
    big = jnp.int32(jnp.iinfo(jnp.int32).max)
    key = jnp.where(q_idx >= 0, q_idx, big)
    order = jnp.argsort(key)
    qs = key[order]                                  # sorted coords, pads last
    qv = jnp.where(q_idx >= 0, q_val.astype(jnp.float32), 0.0)[order]
    if qs.shape[0] == 0:         # static shape: nothing to combine
        return qs, qv
    start = jnp.concatenate([jnp.ones((1,), jnp.bool_), qs[1:] != qs[:-1]])
    seg = jnp.cumsum(start) - 1                      # [L] run id per position
    sums = jnp.zeros_like(qv).at[seg].add(qv)        # segment totals
    return qs, sums[seg]                             # broadcast back to runs


def exact_scores_rows(idx: Array, val: Array, q_idx: Array,
                      q_val: Array) -> Array:
    """Exact ⟨q, x⟩ for pre-gathered CSR rows (idx int32[K, P], val [K, P]).

    The row-level Algorithm 7 rerank primitive: both the resident path
    (:func:`exact_scores_sparse`) and the tiered path
    (``TieredVecStore.gather_rows`` → rerank) delegate here, so tiering is
    bit-identical to the resident baseline by construction.  f32[K].
    """
    val = val.astype(jnp.float32)
    qs, comb = combine_query(q_idx, q_val)
    pos = jnp.clip(jnp.searchsorted(qs, idx), 0, qs.shape[0] - 1)
    hit = (jnp.take(qs, pos) == idx) & (idx >= 0)
    qd = jnp.where(hit, jnp.take(comb, pos), 0.0)    # [K, P]
    return jnp.sum(qd * val, axis=-1)


def exact_scores_sparse(store: VecStore, slots: Array, q_idx: Array,
                        q_val: Array) -> Array:
    """Exact ⟨q, x_s⟩ for the given slots WITHOUT densifying the query.

    The Algorithm 7 rerank used by every scoring backend: gathers only the
    k' candidate CSR rows and matches their coordinates against the sorted
    sparse query via searchsorted — O(k'·P·log ψ_q) and no R^n scatter, so a
    batched rerank never allocates a ``[B, n]`` dense query block.
    Duplicate query coordinates are pre-combined by addition (the same
    result densify_query's scatter-add produces).  f32[len(slots)].
    """
    return exact_scores_rows(store.indices[slots], store.values[slots],
                             q_idx, q_val)


def exact_scores(store: VecStore, slots: Array, q_dense: Array) -> Array:
    """Exact ⟨q, x_s⟩ for the given slots (Algorithm 7 rerank). f32[len(slots)]."""
    idx = store.indices[slots]                       # [K, P]
    val = store.values[slots].astype(jnp.float32)    # [K, P]
    valid = idx >= 0
    qv = q_dense[jnp.where(valid, idx, 0)]           # [K, P]
    return jnp.sum(jnp.where(valid, qv * val, 0.0), axis=-1)


def exact_scores_all(store: VecStore, q_dense: Array) -> Array:
    """Exact scores for every slot — the TPU-native exact LinScan. f32[C]."""
    valid = store.indices >= 0
    qv = q_dense[jnp.where(valid, store.indices, 0)]
    return jnp.sum(jnp.where(valid, qv * store.values.astype(jnp.float32), 0.0),
                   axis=-1)
