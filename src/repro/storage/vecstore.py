"""Vector storage (the "S" box of the paper's Figure 1).

The vector database keeps every active vector in raw (exactly recoverable)
form; the retrieval engine fetches candidates from it for the exact rerank of
Algorithm 7.  On TPU the natural representation is **padded CSR** over slots:

    indices : int32[C, P]   active coordinates, padded with -1
    values  : f32/bf16[C, P]

Fetching k' candidates is a row gather; exact inner products are a gather of
``q_dense[indices]`` plus a masked dot — dense, regular, MXU/VPU-friendly.
The same primitive scanned over *all* slots is the TPU-native exact LinScan
("document-ordered scan"; see DESIGN.md §2).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class VecStore(NamedTuple):
    indices: Array   # int32[C, P], pad = -1
    values: Array    # [C, P]

    @property
    def capacity(self) -> int:
        return self.indices.shape[0]

    @property
    def max_nnz(self) -> int:
        return self.indices.shape[1]


def empty(capacity: int, max_nnz: int, dtype=jnp.float32) -> VecStore:
    return VecStore(
        indices=jnp.full((capacity, max_nnz), -1, dtype=jnp.int32),
        values=jnp.zeros((capacity, max_nnz), dtype=dtype),
    )


def write(store: VecStore, slot, idx: Array, val: Array) -> VecStore:
    return VecStore(
        indices=store.indices.at[slot].set(idx),
        values=store.values.at[slot].set(val.astype(store.values.dtype)),
    )


def erase(store: VecStore, slot) -> VecStore:
    return VecStore(
        indices=store.indices.at[slot].set(-1),
        values=store.values.at[slot].set(0),
    )


def densify_query(n: int, q_idx: Array, q_val: Array) -> Array:
    """Scatter a padded sparse query into a dense R^n vector."""
    valid = q_idx >= 0
    safe = jnp.where(valid, q_idx, 0)
    contrib = jnp.where(valid, q_val.astype(jnp.float32), 0.0)
    return jnp.zeros((n,), jnp.float32).at[safe].add(contrib, mode="drop")


def exact_scores(store: VecStore, slots: Array, q_dense: Array) -> Array:
    """Exact ⟨q, x_s⟩ for the given slots (Algorithm 7 rerank). f32[len(slots)]."""
    idx = store.indices[slots]                       # [K, P]
    val = store.values[slots].astype(jnp.float32)    # [K, P]
    valid = idx >= 0
    qv = q_dense[jnp.where(valid, idx, 0)]           # [K, P]
    return jnp.sum(jnp.where(valid, qv * val, 0.0), axis=-1)


def exact_scores_all(store: VecStore, q_dense: Array) -> Array:
    """Exact scores for every slot — the TPU-native exact LinScan. f32[C]."""
    valid = store.indices >= 0
    qv = q_dense[jnp.where(valid, store.indices, 0)]
    return jnp.sum(jnp.where(valid, qv * store.values.astype(jnp.float32), 0.0),
                   axis=-1)
