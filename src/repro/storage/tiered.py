"""Frequency-aware hot/cold tiering for the raw VecStore (ROADMAP item 2).

Sinnamon's sketch is the small, always-resident part (~22–25 bytes/vector in
the paper); the raw padded-CSR rows that only the Algorithm 7 exact rerank
reads dominate memory.  :class:`TieredVecStore` lets that raw store outgrow
device memory:

* the **host backing store** (numpy, authoritative, write-through) holds every
  row, partitioned into fixed-size *chunks* of ``chunk_slots`` consecutive
  slots;
* a **bounded device-side chunk cache** holds at most ``cache_chunks`` chunks
  as one ``[L, chunk_slots, P]`` array pair, sized from ``device_budget_bytes``;
* **LFU-with-aging** eviction: per-chunk access counters, halved every
  ``aging_every`` accesses so long-cold chunks lose their historical score
  (the CacheEmbedding ``freq_aware_embedding`` policy);
* **candidate-driven prefetch**: after the sketch scan returns ``[B, k']``
  candidate slots, :meth:`prefetch`/:meth:`gather_rows` promote the unique
  chunks before the rerank gathers rows;
* a **pinned set** protects chunks touched by in-flight inserts from eviction.

Writes are write-through (host first, then the resident device copy), so a
demotion is a pure map drop — nothing is ever flushed, and crash recovery
(repro.persist) sees exactly one logical store.  Promotions fire the
``vecstore.read`` failpoint *before* any cache-map mutation, so an injected
read fault can never leave a poisoned (mapped-but-unfilled) cache line.

Bit-identity contract: :meth:`gather_rows` returns exactly the rows the
resident ``VecStore`` holds, and the rerank consumes them through the same
``exact_scores_rows`` primitive — so tiered search results are bit-identical
to the fully-resident baseline (enforced by tests/test_tiered_store.py).
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.fault import failpoints as _fp
from repro.obs import metrics as obs_metrics


def chunk_bytes(chunk_slots: int, max_nnz: int, value_dtype) -> int:
    """Device bytes one resident chunk occupies (int32 indices + values)."""
    return chunk_slots * max_nnz * (4 + jnp.dtype(value_dtype).itemsize)


class _TierMetrics:
    """Process-global tier counters, lazily (re)bound to the current metrics
    registry — the same pattern as engine._WritePathMetrics, so
    ``obs.metrics.set_registry`` in tests takes effect on existing stores."""

    __slots__ = ("_registry", "hits", "misses", "promotions", "evictions",
                 "prefetched", "fallbacks")

    def __init__(self):
        self._registry = None

    def bind(self) -> "_TierMetrics":
        reg = obs_metrics.get_registry()
        if reg is not self._registry:
            self.hits = reg.counter(
                "repro_tier_hits_total",
                "Chunk-cache hits (unique chunks already device-resident).")
            self.misses = reg.counter(
                "repro_tier_misses_total",
                "Chunk-cache misses (chunk cold at access time).")
            self.promotions = reg.counter(
                "repro_tier_promotions_total",
                "Cold chunks copied host -> device cache.")
            self.evictions = reg.counter(
                "repro_tier_evictions_total",
                "Resident chunks demoted (LFU-with-aging victim drop).")
            self.prefetched = reg.counter(
                "repro_tier_prefetch_total",
                "Chunks promoted by candidate-driven prefetch.")
            self.fallbacks = reg.counter(
                "repro_tier_fallback_total",
                "Row gathers served straight from host backing "
                "(every cache line pinned).")
            self._registry = reg
        return self


@jax.jit
def _gather_rows_dev(ci, cv, lines, offs):
    return ci[lines, offs], cv[lines, offs]


@jax.jit
def _set_chunks_dev(ci, cv, lines, hidx, hval):
    return ci.at[lines].set(hidx), cv.at[lines].set(hval)


@jax.jit
def _set_rows_dev(ci, cv, lines, offs, idx, val):
    return (ci.at[lines, offs].set(idx),
            cv.at[lines, offs].set(val.astype(cv.dtype)))


class TieredVecStore:
    """Chunked host-RAM CSR row store behind a bounded device chunk cache.

    ``capacity``/``max_nnz`` mirror the resident ``VecStore[C, P]`` geometry.
    Exactly one of ``device_budget_bytes`` / ``cache_chunks`` sizes the cache
    (``cache_chunks`` wins when both are given); the budget is rounded down
    to whole chunks with a floor of one line.  ``device`` commits the cache
    (and every gather output) to a specific device — the per-shard caches of
    the sharded index use this.  All methods are thread-safe.
    """

    def __init__(self, capacity: int, max_nnz: int, *,
                 value_dtype="bfloat16", chunk_slots: int = 256,
                 device_budget_bytes: Optional[int] = None,
                 cache_chunks: Optional[int] = None,
                 device=None, aging_every: int = 4096):
        if chunk_slots < 1:
            raise ValueError("chunk_slots must be >= 1")
        self.max_nnz = max_nnz
        self.chunk_slots = chunk_slots
        self._vdtype = jnp.dtype(value_dtype)
        self._device = device
        self.aging_every = aging_every
        if cache_chunks is None:
            if device_budget_bytes is None:
                raise ValueError("size the cache with device_budget_bytes "
                                 "or cache_chunks")
            cache_chunks = max(1, int(device_budget_bytes)
                               // chunk_bytes(chunk_slots, max_nnz,
                                              self._vdtype))
        self.cache_chunks = int(cache_chunks)

        self.capacity = 0
        self._h_idx = np.zeros((0, max_nnz), np.int32)
        self._h_val = np.zeros((0, max_nnz), self._vdtype)
        self._freq = np.zeros((0,), np.float64)
        self._line_by_chunk = np.zeros((0,), np.int32)
        self._resize_backing(capacity)

        L, S, P = self.cache_chunks, chunk_slots, max_nnz
        self._c_idx = self._put(np.full((L, S, P), -1, np.int32))
        self._c_val = self._put(np.zeros((L, S, P), self._vdtype))
        self._chunk_by_line = np.full((L,), -1, np.int64)
        self._free_lines = list(range(L - 1, -1, -1))
        self._pinned: set[int] = set()
        self._accesses = 0
        self._lock = threading.RLock()
        self._m = _TierMetrics()
        # instance-local counters for stats()/benchmarks (the registry
        # counters aggregate across stores)
        self._hits = self._misses = self._promotions = 0
        self._evictions = self._prefetched = self._fallbacks = 0

    # -- geometry -------------------------------------------------------------
    @property
    def num_chunks(self) -> int:
        return self._h_idx.shape[0] // self.chunk_slots

    @property
    def value_dtype(self):
        return self._vdtype

    def device_bytes(self) -> int:
        return (self._c_idx.size * self._c_idx.dtype.itemsize
                + self._c_val.size * self._c_val.dtype.itemsize)

    def host_bytes(self) -> int:
        return self._h_idx.nbytes + self._h_val.nbytes

    def resident_chunks(self) -> int:
        return self.cache_chunks - len(self._free_lines)

    def _resize_backing(self, new_capacity: int) -> None:
        S = self.chunk_slots
        padded = -(-new_capacity // S) * S       # whole chunks
        grow = padded - self._h_idx.shape[0]
        if grow < 0:
            raise ValueError("TieredVecStore cannot shrink")
        if grow:
            self._h_idx = np.concatenate(
                [self._h_idx, np.full((grow, self.max_nnz), -1, np.int32)])
            self._h_val = np.concatenate(
                [self._h_val, np.zeros((grow, self.max_nnz), self._vdtype)])
            nc = padded // S
            self._freq = np.concatenate(
                [self._freq, np.zeros((nc - self._freq.size,), np.float64)])
            self._line_by_chunk = np.concatenate(
                [self._line_by_chunk,
                 np.full((nc - self._line_by_chunk.size,), -1, np.int32)])
        self.capacity = new_capacity

    def _put(self, arr):
        return (jax.device_put(arr, self._device) if self._device is not None
                else jnp.asarray(arr))

    # -- LFU with aging -------------------------------------------------------
    def _touch(self, chunks: np.ndarray) -> None:
        self._freq[chunks] += 1.0
        self._accesses += len(chunks)
        if self._accesses >= self.aging_every:
            self._freq *= 0.5                    # age: historical heat decays
            self._accesses = 0

    def _pick_victim(self) -> Optional[int]:
        """Least-frequently-used resident unpinned chunk (ties: lowest id)."""
        best, best_key = None, None
        for line in range(self.cache_chunks):
            c = int(self._chunk_by_line[line])
            if c < 0 or c in self._pinned:
                continue
            key = (self._freq[c], c)
            if best_key is None or key < best_key:
                best, best_key = c, key
        return best

    def _evict(self, chunk: int) -> None:
        line = int(self._line_by_chunk[chunk])
        self._line_by_chunk[chunk] = -1
        self._chunk_by_line[line] = -1
        self._free_lines.append(line)
        self._evictions += 1
        self._m.bind().evictions.inc()

    def _ensure_resident(self, chunks, count=None) -> bool:
        """Promote every chunk in ``chunks`` (host -> device cache).

        Returns False (promoting nothing further) if the cache is fully
        pinned before all chunks fit — the caller falls back to a direct
        host gather.  The ``vecstore.read`` failpoint fires before any
        cache-map mutation for the new chunks, so a failed promotion never
        leaves a chunk marked resident ("no cache poisoning").
        """
        need = [int(c) for c in chunks if self._line_by_chunk[c] < 0]
        if not need:
            return True
        evictable = sum(1 for line in range(self.cache_chunks)
                        if self._chunk_by_line[line] >= 0
                        and int(self._chunk_by_line[line]) not in self._pinned)
        if len(need) > len(self._free_lines) + evictable:
            return False    # can't fit: don't churn the cache for nothing
        lines = []
        for c in need:
            if not self._free_lines:
                victim = self._pick_victim()
                if victim is None:               # everything pinned
                    self._free_lines.extend(reversed(lines))
                    return False
                self._evict(victim)
            lines.append(self._free_lines.pop())
        try:
            _fp.fire("vecstore.read")            # injected cold-read faults
            S = self.chunk_slots
            view_i = self._h_idx.reshape(self.num_chunks, S, self.max_nnz)
            view_v = self._h_val.reshape(self.num_chunks, S, self.max_nnz)
            self._c_idx, self._c_val = _set_chunks_dev(
                self._c_idx, self._c_val, self._put(np.asarray(lines, np.int32)),
                self._put(view_i[need]), self._put(view_v[need]))
        except BaseException:
            self._free_lines.extend(reversed(lines))   # lines stay unmapped
            raise
        for c, line in zip(need, lines):         # commit only after the copy
            self._line_by_chunk[c] = line
            self._chunk_by_line[line] = c
        self._promotions += len(need)
        self._m.bind().promotions.inc(len(need))
        if count is not None:
            count.inc(len(need))
        return True

    # -- pinning --------------------------------------------------------------
    def _chunks_of(self, slots: np.ndarray) -> np.ndarray:
        return np.unique(np.asarray(slots, np.int64) // self.chunk_slots)

    def pin(self, chunks) -> None:
        with self._lock:
            self._pinned.update(int(c) for c in chunks)

    def unpin(self, chunks) -> None:
        with self._lock:
            for c in chunks:
                self._pinned.discard(int(c))

    @contextmanager
    def pinning(self, slots):
        """Pin the chunks covering ``slots`` for the duration of the block."""
        chunks = self._chunks_of(slots)
        added = [int(c) for c in chunks if int(c) not in self._pinned]
        self.pin(added)
        try:
            yield
        finally:
            self.unpin(added)

    # -- reads ----------------------------------------------------------------
    def gather_rows(self, slots) -> Tuple[jax.Array, jax.Array]:
        """Device rows for ``slots`` (flat int array) — the rerank feed.

        Promotes the unique cold chunks first (LFU eviction as needed); when
        the cache is fully pinned the rows are served straight from the host
        backing instead (prefetch-miss fallback) so a query never blocks on
        an unevictable cache.  Returns (int32[K, P], value_dtype[K, P]).
        """
        with self._lock:
            slots = np.asarray(slots, np.int64).reshape(-1)
            chunks = self._chunks_of(slots)
            self._touch(chunks)
            m = self._m.bind()
            hits = int(np.sum(self._line_by_chunk[chunks] >= 0))
            self._hits += hits
            self._misses += len(chunks) - hits
            m.hits.inc(hits)
            m.misses.inc(len(chunks) - hits)
            if self._ensure_resident(chunks):
                lines = self._line_by_chunk[slots // self.chunk_slots]
                offs = slots % self.chunk_slots
                return _gather_rows_dev(
                    self._c_idx, self._c_val,
                    self._put(lines.astype(np.int32)),
                    self._put(offs.astype(np.int32)))
            self._fallbacks += 1
            m.fallbacks.inc()
            return (self._put(self._h_idx[slots]),
                    self._put(self._h_val[slots]))

    def prefetch(self, slots) -> int:
        """Promote the chunks covering candidate ``slots`` (best effort).

        Returns the number of chunks promoted.  Used by the staged serving
        path so the ``prefetch`` trace stage accounts the host->device copy
        separately from the rerank itself.
        """
        with self._lock:
            chunks = self._chunks_of(slots)
            self._touch(chunks)
            before = self._promotions
            self._ensure_resident(chunks, count=self._m.bind().prefetched)
            n = self._promotions - before
            self._prefetched += n
            return n

    def read_indices(self, slots) -> np.ndarray:
        """Host read of index rows (no promotion) — the delete bit-clear feed."""
        with self._lock:
            return self._h_idx[np.asarray(slots, np.int64)].copy()

    def read_rows(self, slots) -> Tuple[np.ndarray, np.ndarray]:
        """Host read of full rows (no promotion) — compaction/drift feed."""
        with self._lock:
            slots = np.asarray(slots, np.int64)
            return self._h_idx[slots].copy(), self._h_val[slots].copy()

    # -- writes (write-through) ----------------------------------------------
    def write_rows(self, slots, idx_rows, val_rows, *, pin: bool = False):
        """Write CSR rows: host backing first, then any resident device copy.

        With ``pin=True`` the touched chunks are left pinned (caller unpins
        once the in-flight insert's device work is dispatched); the pinned
        chunk ids are returned either way.
        """
        with self._lock:
            slots = np.asarray(slots, np.int64).reshape(-1)
            idx_rows = np.asarray(idx_rows, np.int32).reshape(
                slots.size, self.max_nnz)
            val_rows = np.asarray(val_rows).astype(self._vdtype).reshape(
                slots.size, self.max_nnz)
            self._h_idx[slots] = idx_rows
            self._h_val[slots] = val_rows
            chunks = self._chunks_of(slots)
            self._touch(chunks)
            if pin:
                self.pin(chunks)
            lines = self._line_by_chunk[slots // self.chunk_slots]
            res = lines >= 0
            if res.any():
                self._c_idx, self._c_val = _set_rows_dev(
                    self._c_idx, self._c_val,
                    self._put(lines[res].astype(np.int32)),
                    self._put((slots[res] % self.chunk_slots).astype(np.int32)),
                    self._put(idx_rows[res]), self._put(val_rows[res]))
            return chunks

    def erase_rows(self, slots) -> None:
        slots = np.asarray(slots, np.int64).reshape(-1)
        self.write_rows(
            slots, np.full((slots.size, self.max_nnz), -1, np.int32),
            np.zeros((slots.size, self.max_nnz), self._vdtype))

    # -- bulk / lifecycle -----------------------------------------------------
    def to_arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        """The full logical store as host arrays [capacity, P] (snapshots)."""
        with self._lock:
            return (self._h_idx[:self.capacity].copy(),
                    self._h_val[:self.capacity].copy())

    def load_rows(self, indices: np.ndarray, values: np.ndarray) -> None:
        """Replace the whole backing store (snapshot restore).

        Tiering state resets to access-free defaults: empty cache, zero
        frequencies, nothing pinned — recovery never trusts pre-crash heat.
        """
        with self._lock:
            indices = np.asarray(indices, np.int32)
            self.capacity = 0
            self._h_idx = np.zeros((0, self.max_nnz), np.int32)
            self._h_val = np.zeros((0, self.max_nnz), self._vdtype)
            self._freq = np.zeros((0,), np.float64)
            self._line_by_chunk = np.zeros((0,), np.int32)
            self._resize_backing(indices.shape[0])
            self._h_idx[:indices.shape[0]] = indices
            self._h_val[:indices.shape[0]] = np.asarray(values).astype(
                self._vdtype)
            L = self.cache_chunks
            self._chunk_by_line = np.full((L,), -1, np.int64)
            self._free_lines = list(range(L - 1, -1, -1))
            self._pinned.clear()
            self._accesses = 0

    def grow(self, new_capacity: int) -> None:
        """Extend the host backing (cache geometry is unchanged)."""
        with self._lock:
            self._resize_backing(new_capacity)

    # -- reporting ------------------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            total = self._hits + self._misses
            return {
                "hits": self._hits, "misses": self._misses,
                "promotions": self._promotions, "evictions": self._evictions,
                "prefetched": self._prefetched, "fallbacks": self._fallbacks,
                "hit_rate": (self._hits / total) if total else 0.0,
                "resident_chunks": self.resident_chunks(),
                "cache_chunks": self.cache_chunks,
                "num_chunks": self.num_chunks,
                "resident_bytes": self.device_bytes(),
                "host_bytes": self.host_bytes(),
            }
