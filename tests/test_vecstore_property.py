"""Property-based VecStore scoring tests (optional `hypothesis` dev dep).

The invariant under test is the rerank oracle equivalence behind the whole
tier-transparency story: for ANY padded CSR rows and ANY sparse query —
duplicate query coordinates, pads in arbitrary positions, all-pad rows,
negative values — the sparse searchsorted rerank
(:func:`exact_scores_sparse`, which both the resident and the tiered path
delegate to through :func:`exact_scores_rows`) must equal the dense-scatter
oracle ``exact_scores(store, slots, densify_query(...))`` EXACTLY.

Values are drawn as multiples of 1/8 so every partial sum is exact in
float32 — equality failures mean a real combine/matching bug, never
summation-order ULP noise.
"""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="optional dev dep; property tests skip without it")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.storage import vecstore  # noqa: E402

N = 64          # coordinate space — small so duplicates/collisions are common


def _eighths(rng, shape):
    """Exactly-representable values (multiples of 1/8 in [-4, 4])."""
    return (rng.integers(-32, 33, shape) / 8.0).astype(np.float32)


def _store(rng, rows, max_nnz, all_pad_row=False):
    """Padded CSR rows: unique coords per row, pads anywhere (not just
    trailing), optionally one fully padded row."""
    idx = np.full((rows, max_nnz), -1, np.int32)
    val = np.zeros((rows, max_nnz), np.float32)
    for r in range(rows):
        if all_pad_row and r == 0:
            continue
        nnz = int(rng.integers(0, max_nnz + 1))
        pos = rng.choice(max_nnz, nnz, replace=False)   # pads interleave
        idx[r, pos] = rng.choice(N, nnz, replace=False)
        val[r, pos] = _eighths(rng, nnz)
    return vecstore.VecStore(indices=jnp.asarray(idx),
                             values=jnp.asarray(val))


def _query(rng, length, dup_frac):
    """Sparse query with pads anywhere and a controllable duplicate rate."""
    q_idx = np.full(length, -1, np.int32)
    q_val = np.zeros(length, np.float32)
    nnz = int(rng.integers(0, length + 1))
    pos = rng.choice(length, nnz, replace=False)
    coords = rng.choice(N, nnz, replace=True if dup_frac else False)
    if dup_frac and nnz > 1:                        # force real duplicates
        ndup = max(1, int(nnz * dup_frac))
        coords[:ndup] = coords[-1]
    q_idx[pos] = coords
    q_val[pos] = _eighths(rng, nnz)
    return jnp.asarray(q_idx), jnp.asarray(q_val)


@given(seed=st.integers(0, 10_000),
       rows=st.integers(1, 8), max_nnz=st.integers(1, 12),
       qlen=st.integers(1, 12),
       dup_frac=st.sampled_from([0.0, 0.3, 0.9]),
       all_pad_row=st.booleans())
@settings(max_examples=60, deadline=None)
def test_sparse_rerank_equals_dense_oracle(seed, rows, max_nnz, qlen,
                                           dup_frac, all_pad_row):
    rng = np.random.default_rng(seed)
    store = _store(rng, rows, max_nnz, all_pad_row=all_pad_row)
    q_idx, q_val = _query(rng, qlen, dup_frac)
    slots = jnp.asarray(rng.permutation(rows))      # every row, shuffled

    got = vecstore.exact_scores_sparse(store, slots, q_idx, q_val)
    want = vecstore.exact_scores(store, slots,
                                 vecstore.densify_query(N, q_idx, q_val))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    if all_pad_row:
        empty_pos = int(np.where(np.asarray(slots) == 0)[0][0])
        assert float(np.asarray(got)[empty_pos]) == 0.0


@given(seed=st.integers(0, 10_000), qlen=st.integers(1, 16))
@settings(max_examples=40, deadline=None)
def test_combine_query_matches_dense_totals(seed, qlen):
    """combine_query's per-coordinate totals are exactly densify_query's
    scatter-add sums, and pads sort last with zero contribution."""
    rng = np.random.default_rng(seed)
    q_idx, q_val = _query(rng, qlen, dup_frac=0.5)
    qs, comb = vecstore.combine_query(q_idx, q_val)
    qs, comb = np.asarray(qs), np.asarray(comb)
    dense = np.asarray(vecstore.densify_query(N, q_idx, q_val))

    big = np.iinfo(np.int32).max
    assert np.all(np.diff(qs.astype(np.int64)) >= 0), "keys must be sorted"
    for k, c in zip(qs, comb):
        if k == big:
            continue
        assert c == dense[k], (k, c, dense[k])


@given(seed=st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_negative_values_and_empty_query(seed):
    """All-negative corpora score exactly; a fully padded query scores
    everything 0 through both paths."""
    rng = np.random.default_rng(seed)
    store = _store(rng, 4, 8)
    store = store._replace(values=-jnp.abs(store.values))
    q_idx = jnp.full((6,), -1, jnp.int32)
    q_val = jnp.zeros((6,), jnp.float32)
    slots = jnp.arange(4)
    got = vecstore.exact_scores_sparse(store, slots, q_idx, q_val)
    assert np.all(np.asarray(got) == 0.0)

    qi, qv = _query(rng, 8, dup_frac=0.0)
    qv = -jnp.abs(qv)
    got = vecstore.exact_scores_sparse(store, slots, qi, qv)
    want = vecstore.exact_scores(store, slots,
                                 vecstore.densify_query(N, qi, qv))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
