"""Monte-Carlo validation of the §5 theory (paper Tables 1–2, Fig. 4/5/7)."""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sketch, theory


def _simulate_errors(gen, n, m, h, p, trials, dist="gaussian", sigma=1.0):
    """Empirical overestimation errors of the upper-bound sketch."""
    mp = jnp.asarray(sketch.make_mappings(7, n, m, h))
    errs, probs = [], []
    for t in range(trials):
        active = gen.random(n) < p
        k = active.sum()
        if k == 0:
            continue
        idx = np.where(active)[0].astype(np.int32)
        if dist == "gaussian":
            vals = gen.normal(0, sigma, k).astype(np.float32)
        else:
            vals = gen.uniform(-1, 1, k).astype(np.float32)
        pad = np.full(n, -1, np.int32)
        pv = np.zeros(n, np.float32)
        pad[:k] = idx
        pv[:k] = vals
        u, l = sketch.encode(mp, m, jnp.asarray(pad), jnp.asarray(pv),
                             dtype="float32")
        ub, _ = sketch.decode_vector(mp, u, l, jnp.asarray(pad))
        e = np.asarray(ub)[:k] - vals
        errs.append(e)
        probs.append((e > 1e-7).mean())
    return np.concatenate(errs), float(np.mean(probs))


def test_theorem_5_2_probability_gaussian():
    """Empirical P[overestimate] matches Eq. (6)/(12) within MC error."""
    gen = np.random.default_rng(0)
    n, psi = 600, 120
    p = psi / n
    for m in (60, 120):
        _, emp = _simulate_errors(gen, n, m, 1, p, trials=60)
        pred = theory.prob_overestimate_gaussian_closed(m, 1, n, p)
        assert abs(emp - pred) < 0.06, (m, emp, pred)


def test_theorem_5_4_error_cdf():
    """Empirical error CDF matches Eq. (13) (paper Fig. 7a)."""
    gen = np.random.default_rng(1)
    n, psi, m = 600, 120, 120
    p = psi / n
    errs, _ = _simulate_errors(gen, n, m, 1, p, trials=60)
    pdf, cdf, grid = theory.gaussian_dist(0, 1.0)
    for delta in (0.25, 0.5, 1.0, 2.0):
        emp = (errs <= delta).mean()
        pred = theory.error_cdf(delta, pdf, cdf, grid, psi, m, 1)
        assert abs(emp - pred) < 0.06, (delta, emp, pred)


def test_lemma_5_5_expected_error():
    gen = np.random.default_rng(2)
    n, psi, m = 600, 120, 120
    errs, _ = _simulate_errors(gen, n, m, 1, psi / n, trials=60)
    pdf, cdf, grid = theory.gaussian_dist(0, 1.0)
    pred = theory.expected_error(pdf, cdf, grid, psi, m, 1)
    assert abs(errs.mean() - pred) < 0.06, (errs.mean(), pred)


def test_corollary_5_6_closed_form_matches_general():
    """Cor. 5.6 replaces 1-Φ(α+δ) with the pair-difference tail 1-Φ'(δ) —
    itself an approximation (paper Appendix B), so agreement is coarse."""
    pdf, cdf, grid = theory.gaussian_dist(0, 0.5)
    n, p, m, h = 600, 0.2, 60, 2
    for delta in (0.1, 0.4, 1.0):
        general = theory.error_cdf(delta, pdf, cdf, grid, (n - 1) * p, m, h)
        closed = theory.error_cdf_gaussian_closed(delta, 0.5, m, h, n, p)
        assert abs(general - closed) < 0.15, (delta, general, closed)


def test_lemma_5_7_sizing_rule():
    """m from Eq. (18) actually achieves P[err > δ] < ε (Monte-Carlo)."""
    gen = np.random.default_rng(3)
    n, p, sigma, delta, eps, h = 600, 0.2, 1.0, 1.0, 0.2, 1
    m = int(math.ceil(theory.required_m(delta, eps, h, n, p, sigma)))
    errs, _ = _simulate_errors(gen, n, m, h, p, trials=40)
    assert (errs > delta).mean() < eps + 0.05


def test_table1_paper_values():
    """Reproduce the uniform row of paper Table 1 to 2 decimals."""
    pdf, cdf, grid = theory.uniform_dist(-1, 1)
    got = [round(theory.prob_overestimate(pdf, cdf, grid, 120.0, m, h), 2)
           for m in (60, 120, 240) for h in (1, 2, 3)]
    want = [0.57, 0.63, 0.69, 0.37, 0.38, 0.43, 0.21, 0.17, 0.17]
    assert np.allclose(got, want, atol=0.015), got


def test_theorem_5_8_z_normality():
    """The standardised inner-product error Z is ~N(0,1) (paper Fig. 5)."""
    gen = np.random.default_rng(4)
    n, psi_d, m, psi_q = 600, 120, 60, 16
    p = psi_d / n
    pdf, cdf, grid = theory.gaussian_dist(0, 1.0)
    mu = theory.expected_error(pdf, cdf, grid, psi_d, m, 1)
    # variance of the active error via the CDF
    deltas = np.linspace(0, 8, 400)
    tail = 1.0 - np.asarray(theory.error_cdf(deltas, pdf, cdf, grid,
                                             psi_d, m, 1))
    e2 = float(np.trapezoid(2 * deltas * tail, deltas))
    var_active = e2 - mu ** 2
    _, var_u = theory.unconditional_moments(p, mu, var_active)

    zs = []
    errs, _ = _simulate_errors(gen, n, m, 1, p, trials=200)
    gen2 = np.random.default_rng(5)
    for _ in range(400):
        qv = gen2.normal(0, 1, psi_q)
        # per-coordinate unconditional error sample (0 w.p. 1-p)
        ei = np.where(gen2.random(psi_q) < p,
                      gen2.choice(errs, psi_q), 0.0)
        ip_err = np.sum(np.abs(qv) * ei)   # sign-aligned: always upper bound
        zs.append(theory.z_statistic(np.array([ip_err]), np.abs(qv), p, mu,
                                     var_u)[0])
    zs = np.asarray(zs)
    assert abs(zs.mean()) < 0.25, zs.mean()
    assert abs(zs.std() - 1.0) < 0.3, zs.std()
