"""ISSUE 10 tier-transparency contracts: tiering must be invisible.

* **Churn equivalence** — a randomized insert/overwrite/delete/compact/
  grow/query stream driven in lockstep through a `TieredSinnamonIndex`
  with an adversarially tiny device cache (1–2 chunks) and the resident
  `SinnamonIndex` baseline returns bit-identical ids AND scores, for both
  `search` and `search_many`, on every scoring backend.
* **Store mechanics** — eviction of a just-written chunk round-trips the
  rows byte-identically (write-through means demotion is a drop, never a
  copy-back); a fully pinned cache falls back to a direct host gather with
  identical rows; LFU victim selection is deterministic.
* **Sharded parity** — `TieredShardedSinnamonIndex` on a single-device
  mesh matches `ShardedSinnamonIndex` bit-for-bit under churn, including
  drift/compaction parity of the sketch state itself.
* **Durable round-trip** — crash + recovery of `DurableTieredSinnamonIndex`
  reproduces search results and the full logical state byte-for-byte, and
  the same WAL+snapshot restores into a *resident* durable index (one
  interchange format).

The scaled-up latency/hit-rate twin runs in ``benchmarks/tiering.py``.
"""

import numpy as np
import pytest

import repro.core.engine as eng
from repro.storage.tiered import TieredVecStore

BACKENDS = ("reference", "grouped", "pallas")
N, MAX_NNZ, DOC_NNZ = 512, 16, 12


def _spec(capacity=96, m=24):
    return eng.EngineSpec(capacity=capacity, n=N, m=m, max_nnz=MAX_NNZ,
                          h=2, seed=7, value_dtype="float32")


def _docs(rng, B, nnz=DOC_NNZ):
    """Padded [B, MAX_NNZ] rows — resident insert_many requires full pad."""
    idx = np.full((B, MAX_NNZ), -1, np.int32)
    val = np.zeros((B, MAX_NNZ), np.float32)
    idx[:, :nnz] = np.stack([rng.choice(N, nnz, replace=False)
                             for _ in range(B)])
    val[:, :nnz] = rng.standard_normal((B, nnz)).astype(np.float32)
    return idx, val


def _assert_bitwise(a, b, msg):
    ia, sa = np.asarray(a[0]), np.asarray(a[1])
    ib, sb = np.asarray(b[0]), np.asarray(b[1])
    np.testing.assert_array_equal(ia, ib, err_msg=f"{msg}: ids")
    np.testing.assert_array_equal(sa, sb, err_msg=f"{msg}: scores")


# -- churn equivalence --------------------------------------------------------

@pytest.mark.parametrize("cache_chunks,seed", [(1, 0), (2, 1), (2, 2)])
def test_churn_equivalence_all_backends(cache_chunks, seed):
    """Tiered == resident (ids AND scores) under churn with a cache so
    small every multi-chunk candidate set must promote, evict, or fall
    back — the adversarial regime for cache-coherence bugs."""
    rng = np.random.default_rng(seed)
    spec = _spec()
    resident = eng.SinnamonIndex(spec)
    tiered = eng.TieredSinnamonIndex(spec, tier_chunk_slots=8,
                                     cache_chunks=cache_chunks)

    live, next_id = set(), 0
    for step in range(60):
        op = rng.random()
        if op < 0.45 or len(live) < 10:
            B = int(rng.integers(1, 6))
            ids = []
            for _ in range(B):
                if live and rng.random() < 0.3:     # overwrite in place
                    ids.append(int(rng.choice(sorted(live))))
                else:
                    ids.append(next_id)
                    next_id += 1
            di, dv = _docs(rng, B)
            resident.insert_many(ids, di, dv)
            tiered.insert_many(ids, di, dv)
            live.update(ids)
        elif op < 0.62 and len(live) > 5:
            doc = int(rng.choice(sorted(live)))
            resident.delete(doc)
            tiered.delete(doc)
            live.discard(doc)
        elif op < 0.72:
            assert resident.compact() == tiered.compact()
        else:
            B = int(rng.integers(1, 4))
            qi, qv = _docs(rng, B)
            for backend in BACKENDS:
                _assert_bitwise(
                    resident.search_many(qi, qv, k=5, backend=backend),
                    tiered.search_many(qi, qv, k=5, backend=backend),
                    f"step {step} search_many backend={backend}")
            _assert_bitwise(resident.search(qi[0], qv[0], k=5),
                            tiered.search(qi[0], qv[0], k=5),
                            f"step {step} search")
    st = tiered.tiered.stats()
    # a 1-chunk cache can't hold a multi-chunk candidate set: every gather
    # is a host-gather fallback; with 2 chunks promotions happen for real
    assert st["promotions"] + st["fallbacks"] > 0, \
        "cold path never exercised"
    assert st["resident_chunks"] <= cache_chunks


def test_grow_keeps_equivalence():
    """Capacity growth mid-stream resizes the host backing; results stay
    bit-identical before and after."""
    rng = np.random.default_rng(3)
    spec = _spec(capacity=32)
    resident = eng.SinnamonIndex(spec)
    tiered = eng.TieredSinnamonIndex(spec, tier_chunk_slots=8,
                                     cache_chunks=2)
    di, dv = _docs(rng, 30)
    resident.insert_many(list(range(30)), di, dv)
    tiered.insert_many(list(range(30)), di, dv)
    resident.grow(96)
    tiered.grow(96)
    assert tiered.tiered.capacity >= 96
    di2, dv2 = _docs(rng, 50)
    resident.insert_many(list(range(30, 80)), di2, dv2)
    tiered.insert_many(list(range(30, 80)), di2, dv2)
    qi, qv = _docs(rng, 4)
    _assert_bitwise(resident.search_many(qi, qv, k=7),
                    tiered.search_many(qi, qv, k=7), "post-grow")


def test_drift_and_compaction_parity():
    """Sketch maintenance reads rows through the tier: per-slot drift and
    post-compaction sketch state must match the resident index exactly."""
    rng = np.random.default_rng(4)
    spec = _spec()
    resident = eng.SinnamonIndex(spec)
    tiered = eng.TieredSinnamonIndex(spec, tier_chunk_slots=8,
                                     cache_chunks=1)
    di, dv = _docs(rng, 60)
    resident.insert_many(list(range(60)), di, dv)
    tiered.insert_many(list(range(60)), di, dv)
    for doc in range(0, 30, 3):                     # churn up some drift
        resident.delete(doc)
        tiered.delete(doc)
    di2, dv2 = _docs(rng, 10)
    resident.insert_many(list(range(100, 110)), di2, dv2)
    tiered.insert_many(list(range(100, 110)), di2, dv2)

    dirty = np.asarray(resident.state.dirty)
    np.testing.assert_array_equal(resident.slot_drift()[dirty],
                                  tiered.slot_drift()[dirty])
    assert resident.compact() == tiered.compact()
    for name in ("u", "bits", "active", "dirty"):
        np.testing.assert_array_equal(
            np.asarray(getattr(resident.state, name)),
            np.asarray(getattr(tiered.state, name)), err_msg=name)


# -- store mechanics ----------------------------------------------------------

def test_evict_just_written_chunk_roundtrips():
    """Write rows, force their chunk out of the cache, read them back cold:
    write-through means the host copy was authoritative all along."""
    rng = np.random.default_rng(5)
    store = TieredVecStore(64, MAX_NNZ, value_dtype="float32", chunk_slots=8, cache_chunks=1)
    store.gather_rows(np.arange(8))                 # chunk 0 resident
    di, dv = _docs(rng, 8)
    store.write_rows(np.arange(8), di, dv)          # patches the device line
    before = store.stats()["evictions"]
    store.gather_rows(np.arange(48, 56))            # promote chunk 6 → evict 0
    assert store.stats()["evictions"] > before
    ri, rv = store.gather_rows(np.arange(8))        # cold re-promotion
    np.testing.assert_array_equal(np.asarray(ri), di)
    np.testing.assert_array_equal(np.asarray(rv, np.float32), dv)


def test_fully_pinned_cache_falls_back_to_host_gather():
    """When pins block every line, gather_rows must serve from host RAM
    (correctness never depends on residency) and count a fallback."""
    rng = np.random.default_rng(6)
    store = TieredVecStore(64, MAX_NNZ, value_dtype="float32", chunk_slots=8, cache_chunks=2)
    di, dv = _docs(rng, 64)
    store.load_rows(di, dv)
    store.gather_rows(np.arange(0, 16))             # chunks 0,1 resident
    with store.pinning(np.arange(0, 16)):
        before = store.stats()
        ri, rv = store.gather_rows(np.arange(24, 40))   # needs chunks 3,4
        after = store.stats()
        assert after["fallbacks"] == before["fallbacks"] + 1
        assert after["resident_chunks"] == 2        # nothing evicted
    np.testing.assert_array_equal(np.asarray(ri), di[24:40])
    np.testing.assert_array_equal(np.asarray(rv, np.float32), dv[24:40])
    # after unpin the same gather promotes normally
    store.gather_rows(np.arange(24, 32))
    assert store.stats()["promotions"] > before["promotions"]


def test_prefetch_warms_then_hits():
    rng = np.random.default_rng(7)
    store = TieredVecStore(64, MAX_NNZ, value_dtype="float32", chunk_slots=8, cache_chunks=4)
    di, dv = _docs(rng, 64)
    store.load_rows(di, dv)
    assert store.prefetch(np.arange(0, 24)) == 3    # chunks 0..2 promoted
    before = store.stats()
    store.gather_rows(np.arange(0, 24))
    after = store.stats()
    assert after["misses"] == before["misses"]      # all hits, no promotion
    assert after["promotions"] == before["promotions"]


def test_lfu_evicts_the_cold_chunk():
    """The hot chunk survives eviction pressure; the low-frequency one is
    the deterministic victim when a third chunk needs its line."""
    rng = np.random.default_rng(8)
    store = TieredVecStore(64, MAX_NNZ, value_dtype="float32", chunk_slots=8, cache_chunks=2)
    di, dv = _docs(rng, 64)
    store.load_rows(di, dv)
    for _ in range(5):
        store.gather_rows(np.arange(0, 8))          # chunk 0 hot
    store.gather_rows(np.arange(8, 16))             # chunk 1: one access
    store.gather_rows(np.arange(16, 24))            # chunk 2 evicts chunk 1
    p = store.stats()["promotions"]
    store.gather_rows(np.arange(0, 8))              # hot chunk: still a hit
    assert store.stats()["promotions"] == p
    store.gather_rows(np.arange(8, 16))             # chunk 1: cold again
    assert store.stats()["promotions"] == p + 1


# -- sharded parity -----------------------------------------------------------

def test_sharded_tiered_matches_sharded_resident():
    from repro.distributed import mesh as meshlib
    from repro.serving.sharded import (ShardedSinnamonIndex,
                                       TieredShardedSinnamonIndex)

    rng = np.random.default_rng(9)
    spec = _spec(capacity=64)
    mesh = meshlib.single_device_mesh()
    base = ShardedSinnamonIndex(spec, mesh, update_block=8)
    tier = TieredShardedSinnamonIndex(spec, mesh, update_block=8,
                                      tier_chunk_slots=8, cache_chunks=2)

    live, next_id = set(), 0
    for step in range(40):
        op = rng.random()
        if op < 0.45 or len(live) < 10:
            B = int(rng.integers(1, 6))
            ids = []
            for _ in range(B):
                if live and rng.random() < 0.3:
                    ids.append(int(rng.choice(sorted(live))))
                else:
                    ids.append(next_id)
                    next_id += 1
            di, dv = _docs(rng, B)
            base.insert_many(ids, di, dv)
            tier.insert_many(ids, di, dv)
            live.update(ids)
        elif op < 0.6 and len(live) > 5:
            n = int(rng.integers(1, 4))
            dels = [int(d) for d in rng.choice(sorted(live), n,
                                               replace=False)]
            base.delete_many(dels)
            tier.delete_many(dels)
            live.difference_update(dels)
        elif op < 0.7:
            assert base.compact() == tier.compact()
        else:
            B = int(rng.integers(1, 4))
            qi, qv = _docs(rng, B)
            _assert_bitwise(base.search_many(qi, qv, k=5),
                            tier.search_many(qi, qv, k=5),
                            f"step {step} sharded search_many")
            _assert_bitwise(base.search(qi[0], qv[0], k=5),
                            tier.search(qi[0], qv[0], k=5),
                            f"step {step} sharded search")

    dirty = np.asarray(base.state.dirty)
    np.testing.assert_array_equal(base.slot_drift()[dirty],
                                  tier.slot_drift()[dirty])
    base.compact()
    tier.compact()
    for name in ("u", "bits"):
        np.testing.assert_array_equal(np.asarray(getattr(base.state, name)),
                                      np.asarray(getattr(tier.state, name)),
                                      err_msg=name)
    st = tier.tiers[0].stats()
    assert st["promotions"] + st["fallbacks"] > 0


def test_sharded_tiered_matches_single_tiered():
    """Shard transparency and tier transparency compose."""
    from repro.distributed import mesh as meshlib
    from repro.serving.sharded import TieredShardedSinnamonIndex

    rng = np.random.default_rng(10)
    spec = _spec(capacity=64)
    single = eng.TieredSinnamonIndex(spec, tier_chunk_slots=8,
                                     cache_chunks=2)
    sharded = TieredShardedSinnamonIndex(spec, meshlib.single_device_mesh(),
                                         update_block=8, tier_chunk_slots=8,
                                         cache_chunks=2)
    di, dv = _docs(rng, 50)
    single.insert_many(list(range(50)), di, dv)
    sharded.insert_many(list(range(50)), di, dv)
    qi, qv = _docs(rng, 3)
    _assert_bitwise(single.search_many(qi, qv, k=5),
                    sharded.search_many(qi, qv, k=5), "sharded==single")


# -- durable round-trip -------------------------------------------------------

def _drive(ix, rng, steps=30):
    live, nid = [], 0
    for _ in range(steps):
        op = rng.random()
        if op < 0.55 or len(live) < 8:
            B = int(rng.integers(1, 4))
            ids = list(range(nid, nid + B))
            nid += B
            di, dv = _docs(rng, B)
            ix.insert_many(ids, di, dv)
            live += ids
        elif op < 0.72 and len(live) > 4:
            ix.delete(live.pop(int(rng.integers(len(live)))))
        elif op < 0.82:
            ix.compact()
    return live


def test_durable_tiered_crash_recovery_and_cross_restore(tmp_path):
    from repro.persist.durable import (DurableSinnamonIndex,
                                       DurableTieredSinnamonIndex)

    spec = _spec(capacity=64)
    wd, sd = str(tmp_path / "wal"), str(tmp_path / "snap")
    kw = dict(wal_dir=wd, snapshot_dir=sd, tier_chunk_slots=8,
              cache_chunks=2, fsync=False)

    t = DurableTieredSinnamonIndex.open(spec, **kw)
    rng = np.random.default_rng(11)
    _drive(t, rng)
    t.snapshot()
    _drive(t, rng)                                  # WAL tail past snapshot
    qi, qv = _docs(rng, 6)
    ids0, sc0 = t.search_many(qi, qv, k=5)
    st0 = t.logical_state()
    del t                                           # crash (no clean close)

    r = DurableTieredSinnamonIndex.open(spec, **kw)
    _assert_bitwise((ids0, sc0), r.search_many(qi, qv, k=5), "recovery")
    st1 = r.logical_state()
    for name in ("u", "bits", "active", "ids", "dirty"):
        np.testing.assert_array_equal(np.asarray(getattr(st0, name)),
                                      np.asarray(getattr(st1, name)),
                                      err_msg=name)
    np.testing.assert_array_equal(np.asarray(st0.store.indices),
                                  np.asarray(st1.store.indices))
    np.testing.assert_array_equal(np.asarray(st0.store.values, np.float32),
                                  np.asarray(st1.store.values, np.float32))

    # the same WAL+snapshot restores into a RESIDENT durable index
    r2 = DurableSinnamonIndex.open(spec, wal_dir=wd, snapshot_dir=sd,
                                   fsync=False)
    _assert_bitwise((ids0, sc0), r2.search_many(qi, qv, k=5),
                    "cross-restore into resident")

    # optimistic async compaction still works on the tiered wrapper
    r.try_compact_async()
    _assert_bitwise((ids0, sc0), r.search_many(qi, qv, k=5), "post-compact")
