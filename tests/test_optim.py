"""Optimizer substrate: AdamW math vs a NumPy oracle; schedules; gradient
compression convergence parity."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw, compress


def test_adamw_matches_numpy_reference():
    cfg = adamw.AdamWConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8,
                            weight_decay=0.01, clip_norm=0.0,
                            warmup_steps=0, decay_steps=10**9,
                            min_lr_ratio=1.0)
    gen = np.random.default_rng(0)
    p0 = gen.normal(0, 1, (4, 3)).astype(np.float32)
    params = {"w": jnp.asarray(p0)}
    opt = adamw.init(params)

    m = np.zeros_like(p0)
    v = np.zeros_like(p0)
    p_ref = p0.copy()
    for t in range(1, 6):
        g = gen.normal(0, 1, p0.shape).astype(np.float32)
        params, opt, _ = adamw.update({"w": jnp.asarray(g)}, opt, params, cfg)
        m = 0.9 * m + 0.1 * g
        v = 0.99 * v + 0.01 * g * g
        mh = m / (1 - 0.9 ** t)
        vh = v / (1 - 0.99 ** t)
        p_ref = p_ref - 1e-2 * (mh / (np.sqrt(vh) + 1e-8) + 0.01 * p_ref)
    np.testing.assert_allclose(np.asarray(params["w"]), p_ref, rtol=1e-5,
                               atol=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 3.0}
    clipped, gn = adamw.clip_by_global_norm(g, 1.0)
    assert float(gn) == np.sqrt(90.0).astype(np.float32)
    np.testing.assert_allclose(float(adamw.global_norm(clipped)), 1.0,
                               rtol=1e-5)


def test_schedule_shape():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, decay_steps=100,
                            min_lr_ratio=0.1)
    lrs = [float(adamw.schedule(cfg, jnp.int32(s))) for s in
           (0, 5, 10, 55, 100, 200)]
    assert lrs[1] < lrs[2]          # warmup rising
    assert lrs[2] == 1.0            # peak
    assert lrs[3] < lrs[2]          # decaying
    assert abs(lrs[4] - 0.1) < 1e-6  # floor
    assert abs(lrs[5] - 0.1) < 1e-6


def test_quantize_roundtrip_error_bounded():
    gen = np.random.default_rng(1)
    x = jnp.asarray(gen.normal(0, 3, (64,)).astype(np.float32))
    q, s = compress.quantize_int8(x)
    err = np.abs(compress.dequantize(q, s) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_accumulates():
    """EF compression: averaged compressed grads converge to true mean."""
    gen = np.random.default_rng(2)
    g_true = gen.normal(0, 1, (32,)).astype(np.float32)
    residual = {"w": jnp.zeros((32,), jnp.float32)}
    total = np.zeros(32, np.float64)
    n = 50
    for _ in range(n):
        q, s, residual_new = compress.ef_compress_tree(
            {"w": jnp.asarray(g_true)}, residual)
        residual = residual_new
        total += np.asarray(compress.dequantize(q["w"], s["w"]))
    # with error feedback, the *sum* of dequantized grads tracks the sum of
    # true grads to within one quantisation step
    drift = np.abs(total / n - g_true).max()
    assert drift < 0.01, drift


def test_compressed_psum_shard_map():
    """compressed_psum inside shard_map == exact mean within int8 error."""
    import os
    import subprocess, sys, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, "src")
        import functools
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.distributed import mesh as meshlib
        from repro.optim import compress
        mesh = meshlib.make_mesh((4,), ("pod",))
        g = jnp.asarray(np.random.default_rng(0).normal(
            0, 1, (4, 64)).astype(np.float32))
        res = jnp.zeros((4, 64), jnp.float32)
        def f(g, r):
            out, r2 = compress.compressed_psum({"w": g[0]}, {"w": r[0]},
                                               "pod")
            return out["w"][None], r2["w"][None]
        fn = shard_map(f, mesh=mesh, in_specs=(P("pod"), P("pod")),
                       out_specs=(P("pod"), P("pod")))
        out, _ = fn(g, res)
        want = np.asarray(g).mean(0)
        got = np.asarray(out)[0]
        err = np.abs(got - want).max()
        print("OK" if err < 0.05 else f"BAD {err}")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=".", timeout=300)
    assert "OK" in out.stdout, out.stdout + out.stderr
