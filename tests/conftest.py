"""Test config. NOTE: no XLA_FLAGS device-count forcing here — smoke tests
and benches must see 1 device (dry-run scripts set their own flags)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)
