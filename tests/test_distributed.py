"""Distributed runtime: rules, top-k merge, and a subprocess SPMD search
(the subprocess forces 8 host devices so the main test process keeps 1)."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import mesh as meshlib
from repro.distributed import rules as R
from repro.distributed import topk


def test_rules_divisibility_fallback():
    mesh = meshlib.single_device_mesh(("data", "model"))
    # single-device mesh: everything divisible, axes named
    spec = R.spec_for(mesh, (64, 128), ("batch", "mlp"))
    assert spec == jax.sharding.PartitionSpec("data", "model")


def test_rules_fallback_chain():
    # fake mesh shape checks without devices: use spec_for math directly on a
    # 1-device mesh named like production (sizes 1 always divide) — then on a
    # synthetic Mesh-like object for the 16x16 case.
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    spec = R.spec_for(FakeMesh(), (8, 32768, 128),
                      ("kv_heads", "kv_seq", None))
    # kv_heads=8 not divisible by 16 -> kv_seq takes (data, model)
    assert spec == jax.sharding.PartitionSpec(None, ("data", "model"))

    spec = R.spec_for(FakeMesh(), (128, 16, 32768, 128),
                      ("batch", "kv_heads", "kv_seq", None))
    assert spec == jax.sharding.PartitionSpec("data", "model")


def test_topk_merge_single_device():
    scores = jnp.asarray(np.random.default_rng(0).normal(0, 1, (4, 100)))
    ids = jnp.arange(100)[None, :].repeat(4, 0)
    vals, pay = topk.topk_with_ids(scores, ids, 10)
    ref = np.sort(np.asarray(scores), axis=-1)[:, ::-1][:, :10]
    np.testing.assert_allclose(np.asarray(vals), ref, rtol=1e-6)
    assert np.all(np.take_along_axis(np.asarray(scores), np.asarray(pay),
                                     axis=-1) == np.asarray(vals))


SUBPROC = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    sys.path.insert(0, "src")
    import dataclasses
    import numpy as np, jax, jax.numpy as jnp
    from repro.core.engine import EngineSpec, SinnamonIndex
    from repro.core.linscan import brute_force_topk
    from repro.data import synth
    from repro.distributed import mesh as meshlib
    from repro.serving import sharded

    ds = synth.SparseDatasetSpec("t", n=300, psi_doc=18, psi_query=9)
    idx, val = synth.make_corpus(0, ds, 384, pad=36)
    qi, qv = synth.make_queries(1, ds, 4, pad=18)
    spec = EngineSpec(n=300, m=16, capacity=384, max_nnz=36, h=1,
                      value_dtype="float32")
    index = SinnamonIndex(spec)
    index.insert_many(list(range(384)), idx, val)
    mesh = meshlib.make_mesh((2, 4), ("data", "model"))
    local = dataclasses.replace(spec, capacity=96)
    step = sharded.make_search_step(mesh, local, k=10, kprime_local=40)
    state = sharded.shard_state(index.state, mesh)
    scores, ids, loc = step(state, jnp.asarray(qi), jnp.asarray(qv))
    from repro.core import engine as eng
    ids = eng.unpack_ids64(np.asarray(ids))      # packed uint32 lo/hi words
    ok = True
    for b in range(4):
        ids0, sc0 = brute_force_topk(idx, val, qi[b], qv[b], 300, 10)
        rec = len(set(ids[b].tolist()) & set(ids0.tolist())) / 10
        ok &= rec >= 0.9
    # (shard, slot) locators must resolve back to the returned external ids:
    # global slot = shard * C_local + local slot under the contiguous layout.
    from repro.distributed import topk as topklib
    sh_ids, sl = topklib.unpack_shard_slot(jnp.asarray(loc))
    gslot = np.asarray(sh_ids) * 96 + np.asarray(sl)
    slot_ids = eng.unpack_ids64(np.asarray(index.state.ids))[gslot]
    ok &= bool(np.all(slot_ids == ids))
    print("RECALL_OK" if ok else "RECALL_BAD")
""")


@pytest.mark.distributed
def test_sharded_search_subprocess():
    out = subprocess.run([sys.executable, "-c", SUBPROC],
                         capture_output=True, text=True, cwd=".",
                         timeout=420)
    assert "RECALL_OK" in out.stdout, out.stdout + out.stderr


def test_corpus_axes():
    mesh = meshlib.single_device_mesh(("pod", "data", "model"))
    assert meshlib.corpus_axes(mesh) == ("pod", "model")
    assert meshlib.batch_axes(mesh) == ("data",)
