"""ISSUE 2 tentpole: the sharded streaming index must reproduce the
single-device ``SinnamonIndex`` exactly on the same document stream.

All tests here run on a 1x1 ("data", "model") mesh — the same shard_map
code path as production, no multi-device runtime needed — and assert
*elementwise* equality of returned ids and exact rerank scores.  The
multi-shard equivalence run lives in the `distributed`-marked subprocess
test at the bottom (forced host devices, like tests/test_distributed.py).
"""

import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.engine import EngineSpec, SinnamonIndex
from repro.data import synth
from repro.distributed import mesh as meshlib
from repro.distributed import topk
from repro.serving.serve import QueryServer
from repro.serving.sharded import ShardedSinnamonIndex

DS = synth.SparseDatasetSpec("t", n=400, psi_doc=20, psi_query=10,
                             value_dist="gaussian")
N_DOCS = 160


def _spec(capacity):
    return EngineSpec(n=DS.n, m=16, capacity=capacity, max_nnz=48, h=2,
                      seed=3, value_dtype="float32")


@pytest.fixture(scope="module")
def pair():
    """(sharded on 1x1 mesh, single-device) indexes fed the same stream."""
    idx, val = synth.make_corpus(0, DS, N_DOCS, pad=48)
    mesh = meshlib.single_device_mesh(("data", "model"))
    sharded = ShardedSinnamonIndex(_spec(192), mesh)
    single = SinnamonIndex(_spec(192))
    for lo in range(0, N_DOCS, 64):
        hi = min(lo + 64, N_DOCS)
        ids = list(range(lo, hi))
        sharded.insert_many(ids, idx[lo:hi], val[lo:hi])
        single.insert_many(ids, idx[lo:hi], val[lo:hi])
    return sharded, single, idx, val


def _assert_same_results(sharded, single, seed, k=10, kprime=60, nq=6):
    qi, qv = synth.make_queries(seed, DS, nq, pad=24)
    for b in range(nq):
        ids_s, sc_s = sharded.search(qi[b], qv[b], k=k, kprime=kprime)
        ids_0, sc_0 = single.search(qi[b], qv[b], k=k, kprime=kprime)
        np.testing.assert_array_equal(ids_s, ids_0)
        np.testing.assert_array_equal(sc_s, sc_0)


def test_insert_matches_single_device(pair):
    sharded, single, _, _ = pair
    assert sharded.size == single.size == N_DOCS
    _assert_same_results(sharded, single, seed=1)


def test_locators_resolve_to_owner_shard(pair):
    sharded, _, _, _ = pair
    qi, qv = synth.make_queries(2, DS, 2, pad=24)
    ids, _, loc = sharded.search_many(qi, qv, k=10, kprime=60,
                                      return_locators=True)
    sh, sl = topk.unpack_shard_slot(loc)
    for b in range(2):
        for e, s, slot in zip(ids[b], np.asarray(sh)[b], np.asarray(sl)[b]):
            assert sharded.route(int(e)) == int(s)
            assert sharded._id2slot[int(e)] == (int(s), int(slot))


def test_delete_and_slot_recycling_round_trip():
    idx, val = synth.make_corpus(4, DS, 96, pad=48)
    mesh = meshlib.single_device_mesh(("data", "model"))
    sharded = ShardedSinnamonIndex(_spec(96), mesh)
    single = SinnamonIndex(_spec(96))
    ids = list(range(96))
    sharded.insert_many(ids, idx, val)
    single.insert_many(ids, idx, val)

    qi, qv = synth.make_queries(5, DS, 1, pad=24)
    top, _ = single.search(qi[0], qv[0], k=5, kprime=40)
    victims = [int(d) for d in top[:3]]
    for v in victims:
        sharded.delete(v)
        single.delete(v)
    _assert_same_results(sharded, single, seed=6)
    ids_after, _ = sharded.search(qi[0], qv[0], k=5, kprime=40)
    assert not set(victims) & set(ids_after.tolist())

    # slot recycling: re-inserting reuses freed slots on the owning shard
    free_before = sum(len(f) for f in sharded._free)
    extra_i, extra_v = synth.make_corpus(7, DS, 3, pad=48)
    new_ids = [1000, 1001, 1002]
    sharded.insert_many(new_ids, extra_i, extra_v)
    single.insert_many(new_ids, extra_i, extra_v)
    assert sum(len(f) for f in sharded._free) == free_before - 3
    assert sharded.size == single.size == 96
    _assert_same_results(sharded, single, seed=8)


def test_update_overwrites_in_place():
    idx, val = synth.make_corpus(9, DS, 2, pad=48)
    mesh = meshlib.single_device_mesh(("data", "model"))
    sharded = ShardedSinnamonIndex(_spec(64), mesh)
    single = SinnamonIndex(_spec(64))
    sharded.insert_many([0, 1], idx, val)
    single.insert_many([0, 1], idx, val)
    sharded.insert(0, idx[1][idx[1] >= 0], val[1][idx[1] >= 0])
    single.insert(0, idx[1][idx[1] >= 0], val[1][idx[1] >= 0])
    assert sharded.size == single.size == 2
    _assert_same_results(sharded, single, seed=10, k=2, kprime=8, nq=2)


def test_grow_preserves_content_and_matches():
    idx, val = synth.make_corpus(11, DS, 64, pad=48)
    mesh = meshlib.single_device_mesh(("data", "model"))
    sharded = ShardedSinnamonIndex(_spec(64), mesh)
    single = SinnamonIndex(_spec(64))
    sharded.insert_many(list(range(64)), idx, val)
    single.insert_many(list(range(64)), idx, val)
    qi, qv = synth.make_queries(12, DS, 1, pad=24)
    before, _ = sharded.search(qi[0], qv[0], k=10, kprime=40)
    sharded.grow(128)
    single.grow(128)
    after, _ = sharded.search(qi[0], qv[0], k=10, kprime=40)
    np.testing.assert_array_equal(before, after)
    assert sharded.spec.capacity == 128
    _assert_same_results(sharded, single, seed=13)


def test_duplicate_ids_in_one_batch_keep_last():
    idx, val = synth.make_corpus(16, DS, 2, pad=48)
    mesh = meshlib.single_device_mesh(("data", "model"))
    sharded = ShardedSinnamonIndex(_spec(32), mesh)
    sharded.insert_many([7, 7], idx, val)       # only the last survives
    assert sharded.size == 1
    sharded.delete(7)
    assert sharded.size == 0
    assert sum(len(f) for f in sharded._free) == 32   # no leaked slot


def test_delete_many_unknown_id_is_atomic():
    idx, val = synth.make_corpus(17, DS, 2, pad=48)
    mesh = meshlib.single_device_mesh(("data", "model"))
    sharded = ShardedSinnamonIndex(_spec(32), mesh)
    sharded.insert_many([0, 1], idx, val)
    with pytest.raises(KeyError):
        sharded.delete_many([0, 999])
    assert sharded.size == 2                     # nothing was popped
    sharded.delete_many([0, 1])                  # still fully deletable
    assert sharded.size == 0


def test_auto_grow_on_overflow():
    idx, val = synth.make_corpus(14, DS, 80, pad=48)
    mesh = meshlib.single_device_mesh(("data", "model"))
    sharded = ShardedSinnamonIndex(_spec(32), mesh)
    sharded.insert_many(list(range(80)), idx, val)   # forces two doublings
    assert sharded.size == 80
    assert sharded.spec.capacity >= 80


def test_query_server_batched_path(pair):
    sharded, single, _, _ = pair
    qi, qv = synth.make_queries(15, DS, 8, pad=24)
    srv_s = QueryServer(sharded, k=10, kprime=60)
    srv_0 = QueryServer(single, k=10, kprime=60)
    ids_s, sc_s = srv_s.query_many(qi, qv)
    ids_0, sc_0 = srv_0.query_many(qi, qv)
    np.testing.assert_array_equal(ids_s, ids_0)
    np.testing.assert_array_equal(sc_s, sc_0)
    assert srv_s.stats["queries"] == 8
    assert set(srv_s.latency_percentiles()) == {"p50", "p90", "p99"}


MULTI = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    sys.path.insert(0, "src")
    import numpy as np
    from repro.core.engine import EngineSpec, SinnamonIndex
    from repro.data import synth
    from repro.distributed import mesh as meshlib
    from repro.serving.sharded import ShardedSinnamonIndex

    ds = synth.SparseDatasetSpec("t", n=400, psi_doc=20, psi_query=10)
    idx, val = synth.make_corpus(0, ds, 200, pad=48)
    qi, qv = synth.make_queries(1, ds, 6, pad=24)
    spec = EngineSpec(n=400, m=16, capacity=96, max_nnz=48, h=2,
                      value_dtype="float32")
    mesh = meshlib.make_mesh((1, 4), ("data", "model"))
    sharded = ShardedSinnamonIndex(spec, mesh)
    single = SinnamonIndex(
        EngineSpec(n=400, m=16, capacity=384, max_nnz=48, h=2,
                   value_dtype="float32"))
    sharded.insert_many(list(range(200)), idx, val)
    single.insert_many(list(range(200)), idx, val)
    ok = True
    for b in range(6):
        i_s, s_s = sharded.search(qi[b], qv[b], k=10, kprime=96)
        i_0, s_0 = single.search(qi[b], qv[b], k=10, kprime=384)
        ok &= set(i_s.tolist()) == set(i_0.tolist())
        ok &= bool(np.allclose(np.sort(s_s), np.sort(s_0), atol=1e-5))
    victims = [int(d) for d in i_0[:3]]
    sharded.delete_many(victims)
    for v in victims:
        single.delete(v)
    for b in range(6):
        i_s, _ = sharded.search(qi[b], qv[b], k=10, kprime=96)
        i_0, _ = single.search(qi[b], qv[b], k=10, kprime=384)
        ok &= set(i_s.tolist()) == set(i_0.tolist())
    print("STREAM_OK" if ok else "STREAM_BAD")
""")


@pytest.mark.distributed
def test_multi_shard_stream_subprocess():
    out = subprocess.run([sys.executable, "-c", MULTI], capture_output=True,
                         text=True, cwd=".", timeout=600)
    assert "STREAM_OK" in out.stdout, out.stdout + out.stderr[-3000:]
