"""ISSUE 4 tentpole contracts.

* The fused Pallas backend (in-kernel tiled top-k + log-tree merge) returns
  BIT-IDENTICAL ids and exact scores to the reference backend across dirty /
  recycled slots, filter masks, anytime budgets, positive-only mode, bucket
  hashing and non-tile-aligned capacities.
* The vectorized single-dispatch batch mutations reproduce the sequential
  lax.scan oracles leaf-for-leaf.
* External ids are int64 end-to-end: values >= 2**31 survive the engine, the
  sharded locator path and a snapshot round-trip without wrapping.
* QueryServer latency stats are fixed-size registry histograms.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as eng
from repro.core.engine import EngineSpec, SinnamonIndex
from repro.data import synth
from repro.distributed import mesh as meshlib
from repro.kernels import ops, ref, sinnamon_score
from repro.obs import metrics as obs_metrics
from repro.serving.serve import QueryServer
from repro.serving.sharded import ShardedSinnamonIndex

DS = synth.SparseDatasetSpec("t", n=500, psi_doc=24, psi_query=12,
                             value_dist="gaussian")

SPECS = {
    "plain": dict(m=16, h=2),
    "buckets": dict(m=16, h=1, index_buckets=96),
    "fp32": dict(m=24, h=1, dtype="float32"),
}


def _spec(capacity, **kw):
    return EngineSpec(n=DS.n, capacity=capacity, max_nnz=48,
                      value_dtype="float32", seed=3, **kw)


def _churned_index(spec_kw, n_docs=140, capacity=192, seed=0):
    """Index with real streaming history: inserts, deletes, recycled (dirty)
    slots via re-insert — the state shape the §4.3 paths produce."""
    idx, val = synth.make_corpus(seed, DS, n_docs + 20, pad=48)
    index = SinnamonIndex(_spec(capacity, **spec_kw))
    index.insert_many(list(range(n_docs)), idx[:n_docs], val[:n_docs])
    for d in range(0, n_docs, 7):                   # delete ~1/7th
        index.delete(d)
    extra = list(range(n_docs, n_docs + 20))        # recycle into dirty slots
    index.insert_many(extra, idx[n_docs:], val[n_docs:])
    return index


@pytest.mark.parametrize("spec_kw", list(SPECS.values()),
                         ids=list(SPECS.keys()))
@pytest.mark.parametrize("budget", [None, 5])
def test_pallas_bit_identical_to_reference(spec_kw, budget):
    index = _churned_index(spec_kw)
    qi, qv = synth.make_queries(1, DS, 6, pad=24)
    mask = np.ones(index.spec.capacity, bool)
    mask[::3] = False
    for filt in (None, jnp.asarray(mask)):
        r_ids, r_sc = index.search_many(qi, qv, k=10, kprime=60,
                                        budget=budget, filter_mask=filt,
                                        backend="reference")
        p_ids, p_sc = index.search_many(qi, qv, k=10, kprime=60,
                                        budget=budget, filter_mask=filt,
                                        backend="pallas")
        np.testing.assert_array_equal(r_ids, p_ids)
        np.testing.assert_array_equal(r_sc, p_sc)
        g_ids, g_sc = index.search_many(qi, qv, k=10, kprime=60,
                                        budget=budget, filter_mask=filt,
                                        backend="grouped")
        np.testing.assert_array_equal(r_ids, g_ids)
        np.testing.assert_allclose(r_sc, g_sc, rtol=1e-5, atol=1e-6)


def test_pallas_bit_identical_positive_only():
    ds = dataclasses.replace(DS, nonneg=True, value_dist="lognormal",
                             value_param=0.5)
    idx, val = synth.make_corpus(11, ds, 128, pad=48)
    spec = EngineSpec(n=ds.n, m=16, capacity=128, max_nnz=48, h=1,
                      positive_only=True, value_dtype="float32")
    index = SinnamonIndex(spec)
    index.insert_many(list(range(128)), idx, val)
    qi, qv = synth.make_queries(12, ds, 6, pad=24)
    r_ids, r_sc = index.search_many(qi, qv, k=10, kprime=60,
                                    backend="reference")
    p_ids, p_sc = index.search_many(qi, qv, k=10, kprime=60,
                                    backend="pallas")
    np.testing.assert_array_equal(r_ids, p_ids)
    np.testing.assert_array_equal(r_sc, p_sc)


def test_pallas_identical_at_odd_capacity_after_grow():
    """grow() to a non-tile-aligned capacity: the wrappers pad the slot axis
    and gate the padding to -inf, so every backend still agrees exactly —
    including k' = full capacity where the -inf tail is part of the result."""
    index = _churned_index(SPECS["plain"], n_docs=100, capacity=128)
    index.grow(224)                                 # not a tile multiple
    qi, qv = synth.make_queries(3, DS, 4, pad=24)
    for kprime in (60, 224):
        r_ids, r_sc = index.search_many(qi, qv, k=12, kprime=kprime,
                                        backend="reference")
        p_ids, p_sc = index.search_many(qi, qv, k=12, kprime=kprime,
                                        backend="pallas")
        np.testing.assert_array_equal(r_ids, p_ids)
        np.testing.assert_array_equal(r_sc, p_sc)


def test_kernel_wrappers_pad_and_slice_odd_capacity():
    """Direct wrapper calls at an odd (post-grow) capacity with an explicit
    tile size that does NOT divide C: both the dense and the fused wrapper
    must pad-and-slice rather than raise."""
    index = _churned_index(SPECS["plain"], n_docs=100, capacity=128)
    index.grow(160)
    qi, qv = synth.make_queries(4, DS, 3, pad=24)
    qvp, rows, qbits = ops.prepare_query_operands(
        index.state, jnp.asarray(qi), jnp.asarray(qv), spec=index.spec)
    dense = ops.sinnamon_score_batch(index.state, qvp, rows, qbits,
                                     tile_c=128)
    assert dense.shape == (3, 160)
    want = eng.score_batch(index.state, index.spec, jnp.asarray(qi),
                           jnp.asarray(qv))
    np.testing.assert_allclose(np.asarray(dense), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    vals, slots = ops.sinnamon_topk_batch(index.state, index.spec,
                                          jnp.asarray(qi), jnp.asarray(qv),
                                          40, ok=index.state.active,
                                          tile_c=128)
    s = jnp.where(index.state.active[None], want, -jnp.inf)
    rv = np.sort(np.asarray(s))[:, ::-1][:, :40]
    np.testing.assert_allclose(np.asarray(vals), rv, rtol=1e-5, atol=1e-5)
    assert int(np.asarray(slots).max()) < 160       # padding never leaks
    # interpret-mode kernel and XLA twin agree through the full wrapper
    kv, ks = ops.sinnamon_topk_batch(index.state, index.spec,
                                     jnp.asarray(qi), jnp.asarray(qv),
                                     40, ok=index.state.active, tile_c=128,
                                     use_kernel=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(slots))
    np.testing.assert_array_equal(np.asarray(kv), np.asarray(vals))


def test_fused_topk_kernel_matches_dense_oracle(rng):
    """Kernel-level contract: interpret-mode kernel == XLA twin == gated
    dense oracle + lax.top_k, bit for bit (odd tile counts, kprime > tile_c,
    one-sided and positive-only decode)."""
    for (B, L, h, m, C, tile, kprime) in [(2, 5, 2, 8, 384, 128, 40),
                                          (3, 7, 1, 16, 512, 128, 200),
                                          (1, 4, 3, 8, 256, 256, 10),
                                          (5, 6, 2, 8, 640, 128, 300)]:
        W = C // 32
        qv = rng.normal(0, 1, (B, L)).astype(np.float32)
        qv[:, -1] = 0.0
        rows = rng.integers(0, m, (B, L, h)).astype(np.int32)
        qbits = rng.integers(0, 2**32, (B, L, W), dtype=np.uint32)
        u = rng.normal(0, 1, (m, C)).astype(np.float32)
        ll = (rng.normal(0, 1, (m, C)) - 1).astype(np.float32)
        gate = np.where(rng.random((1, C)) < 0.8, 0.0,
                        -np.inf).astype(np.float32)
        pos = jnp.asarray(qv) > 0
        for l in (jnp.asarray(ll), None):
            rv, rs = ref.sinnamon_topk_ref(
                jnp.asarray(qv), jnp.asarray(rows), jnp.asarray(qbits),
                jnp.asarray(gate), jnp.asarray(u), l, kprime)
            if l is not None:
                skm = jnp.concatenate([jnp.asarray(u), l], axis=0)
                prow = jnp.where(pos[..., None], jnp.asarray(rows),
                                 jnp.asarray(rows) + m)
                one_sided = True
            else:
                skm, prow, one_sided = jnp.asarray(u), jnp.asarray(rows), False
            operands = (jnp.asarray(qv), pos, prow, jnp.asarray(qbits),
                        jnp.asarray(gate), skm)
            kv, ks = sinnamon_score.sinnamon_score_topk(
                *operands, kp=min(kprime, tile), tile_c=tile,
                one_sided=one_sided, interpret=True)
            gv, gs = sinnamon_score.merge_tile_topk(kv, ks, kprime)
            np.testing.assert_array_equal(np.asarray(gs), np.asarray(rs))
            np.testing.assert_array_equal(np.asarray(gv), np.asarray(rv))
            tv, ts = sinnamon_score.fused_topk_xla(
                *operands, kp=min(kprime, tile), tile_c=tile,
                one_sided=one_sided, query_block=2)
            tv, ts = sinnamon_score.merge_tile_topk(tv, ts, kprime)
            np.testing.assert_array_equal(np.asarray(ts), np.asarray(rs))
            np.testing.assert_array_equal(np.asarray(tv), np.asarray(rv))


# ---------------------------------------------------------------------------
# Vectorized batch mutations == sequential scan oracles
# ---------------------------------------------------------------------------

def _tree_equal(a, b):
    for name, x, y in zip(eng.SinnamonState._fields, a, b):
        if name == "store":
            np.testing.assert_array_equal(np.asarray(x.indices),
                                          np.asarray(y.indices))
            np.testing.assert_array_equal(np.asarray(x.values),
                                          np.asarray(y.values))
        elif x is not None:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=name)


@pytest.mark.parametrize("spec_kw", list(SPECS.values()),
                         ids=list(SPECS.keys()))
def test_vectorized_batches_match_scan_oracle(spec_kw):
    rng = np.random.default_rng(7)
    idx, val = synth.make_corpus(5, DS, 80, pad=48)
    index = _churned_index(spec_kw, n_docs=96, capacity=160, seed=4)
    spec = index.spec

    free = [index._free[-(i + 1)] for i in range(16)]  # unique free slots
    slots = jnp.asarray(np.asarray(free, np.int32))
    eids = jnp.asarray(eng.pack_ids64(
        rng.integers(0, 2**62, 16).astype(np.int64)))
    i16, v16 = jnp.asarray(idx[:16]), jnp.asarray(val[:16])

    _tree_equal(
        eng.insert_batch(index.state, spec, slots, eids, i16, v16),
        eng.insert_batch_scan(index.state, spec, slots, eids, i16, v16))

    mask = jnp.asarray(rng.random(16) < 0.6)
    _tree_equal(
        eng.insert_batch_masked(index.state, spec, slots, eids, i16, v16,
                                mask),
        eng.insert_batch_masked_scan(index.state, spec, slots, eids, i16,
                                     v16, mask))

    # delete a mix of occupied slots (unique, as delete_many guarantees)
    live = [index._id2slot[d] for d in list(index._id2slot)[:16]]
    dslots = jnp.asarray(np.asarray(live, np.int32))
    dmask = jnp.asarray(rng.random(16) < 0.7)
    _tree_equal(
        eng.delete_batch_masked(index.state, spec, dslots, dmask),
        eng.delete_batch_masked_scan(index.state, spec, dslots, dmask))


# ---------------------------------------------------------------------------
# int64 external ids end-to-end
# ---------------------------------------------------------------------------

BIG_IDS = [2**31 + 5, 2**40 + 7, 2**62 + 123, 3]


def test_ids_int64_roundtrip_single():
    idx, val = synth.make_corpus(8, DS, 8, pad=48)
    index = SinnamonIndex(_spec(32, m=16, h=2))
    index.insert_many(BIG_IDS, idx[:4], val[:4])
    assert sorted(index.doc_ids()) == sorted(BIG_IDS)
    qi, qv = synth.make_queries(9, DS, 1, pad=24)
    ids, _ = index.search(qi[0], qv[0], k=4, kprime=8)
    assert ids.dtype == np.int64
    assert set(ids.tolist()) == set(BIG_IDS)        # no int32 wrap
    # device state carries the full 64-bit value (packed words round-trip)
    packed = np.asarray(index.state.ids)
    slot = index._id2slot[2**40 + 7]
    assert int(eng.unpack_ids64(packed)[slot]) == 2**40 + 7
    index.delete(2**40 + 7)
    assert 2**40 + 7 not in index
    ids2, _ = index.search(qi[0], qv[0], k=3, kprime=8)
    assert 2**40 + 7 not in ids2.tolist()


def test_ids_int64_sharded_and_locators():
    from repro.distributed import topk
    idx, val = synth.make_corpus(10, DS, 8, pad=48)
    mesh = meshlib.single_device_mesh(("data", "model"))
    index = ShardedSinnamonIndex(_spec(64, m=16, h=2), mesh)
    index.insert_many(BIG_IDS, idx[:4], val[:4])
    qi, qv = synth.make_queries(11, DS, 2, pad=24)
    ids, _, loc = index.search_many(qi, qv, k=4, kprime=16,
                                    return_locators=True)
    assert ids.dtype == np.int64
    assert set(ids[0].tolist()) == set(BIG_IDS)
    sh, sl = topk.unpack_shard_slot(loc)
    for e, s, slot in zip(ids[0], np.asarray(sh)[0], np.asarray(sl)[0]):
        assert index.route(int(e)) == int(s)
        assert index._id2slot[int(e)] == (int(s), int(slot))


def test_ids_int64_snapshot_roundtrip(tmp_path):
    from repro.persist import snapshot as snaplib
    idx, val = synth.make_corpus(12, DS, 8, pad=48)
    index = SinnamonIndex(_spec(32, m=16, h=2))
    index.insert_many(BIG_IDS, idx[:4], val[:4])
    snaplib.save(str(tmp_path), index, wal_lsn=3)
    restored, lsn = snaplib.load_single(str(tmp_path))
    assert lsn == 3
    assert sorted(restored.doc_ids()) == sorted(BIG_IDS)
    np.testing.assert_array_equal(np.asarray(restored.state.ids),
                                  np.asarray(index.state.ids))
    qi, qv = synth.make_queries(13, DS, 1, pad=24)
    a, _ = index.search(qi[0], qv[0], k=4, kprime=8)
    b, _ = restored.search(qi[0], qv[0], k=4, kprime=8)
    np.testing.assert_array_equal(a, b)


def test_pack_unpack_ids64_lossless():
    vals = np.asarray([0, -1, 1, 2**31 - 1, 2**31, 2**32 + 9, -2**63,
                       2**63 - 1], np.int64)
    np.testing.assert_array_equal(eng.unpack_ids64(eng.pack_ids64(vals)),
                                  vals)


# ---------------------------------------------------------------------------
# QueryServer latency accounting (fixed-size registry histograms)
# ---------------------------------------------------------------------------

def test_latency_histogram_is_bounded():
    h = obs_metrics.Histogram(obs_metrics.Buckets(1.0, 2.0, 4))
    for v in range(1000):
        h.observe(float(v))
    assert h.count == 1000
    # storage is the fixed bucket array, independent of sample volume
    assert len(h.bucket_counts) == 4 + 1
    h.reset()
    assert h.count == 0
    h.observe(5.0)
    assert h.count == 1 and h.snapshot()["min"] == 5.0


def test_query_server_stats_stay_bounded():
    idx, val = synth.make_corpus(14, DS, 64, pad=48)
    index = SinnamonIndex(_spec(64, m=16, h=2))
    index.insert_many(list(range(64)), idx, val)
    reg = obs_metrics.MetricsRegistry()
    srv = QueryServer(index, k=5, kprime=16, registry=reg)
    qi, qv = synth.make_queries(15, DS, 8, pad=24)
    for _ in range(5):
        srv.query_many(qi, qv)
    assert srv.stats["queries"] == 40
    hist = srv._latency_hist(srv._backend_label())
    assert hist.count == 40                 # one sample per query...
    # ...but storage stays the fixed bucket array, not a per-sample list
    assert len(hist.bucket_counts) == obs_metrics.DEFAULT_LATENCY_BUCKETS.count + 1
    pcts = srv.latency_percentiles()
    assert set(pcts) == {"p50", "p90", "p99"}
    assert all(v >= 0 for v in pcts.values())
    srv.reset_stats()
    assert srv.stats["queries"] == 0
    assert srv.latency_percentiles() == {}
