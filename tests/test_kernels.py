"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracle across
shape and dtype sweeps (the mandated CPU validation path)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import csr_score, embed_bag, ops, ref, sinnamon_score


def _mk_sinnamon_operands(rng, B, L, h, m, C, W, dtype):
    qv = rng.normal(0, 1, (B, L)).astype(np.float32)
    qv[:, -1] = 0.0                                     # padded coordinate
    rows = rng.integers(0, m, (B, L, h)).astype(np.int32)
    qbits = rng.integers(0, 2**32, (B, L, W), dtype=np.uint32)
    u = rng.normal(0, 1, (m, C)).astype(dtype)
    l = (rng.normal(0, 1, (m, C)) - 1).astype(dtype)
    return (jnp.asarray(qv), jnp.asarray(rows), jnp.asarray(qbits),
            jnp.asarray(u), jnp.asarray(l))


@pytest.mark.parametrize("dtype", [np.float32, "bfloat16"])
@pytest.mark.parametrize("B,L,h,m,C", [
    (1, 4, 1, 8, 128),
    (2, 7, 2, 16, 256),
    (3, 5, 3, 8, 384),
])
def test_sinnamon_score_sweep(rng, dtype, B, L, h, m, C):
    dtype = jnp.dtype(dtype)
    tile = 128
    qv, rows, qbits, u, l = _mk_sinnamon_operands(
        rng, B, L, h, m, C, C // 32,
        np.float32 if dtype == jnp.float32 else jnp.bfloat16)
    got = sinnamon_score.sinnamon_score(qv, rows, qbits, u, l,
                                        tile_c=tile, interpret=True)
    want = ref.sinnamon_score_ref(qv, rows, qbits, u, l)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_sinnamon_score_positive_only(rng):
    qv, rows, qbits, u, _ = _mk_sinnamon_operands(
        rng, 2, 6, 2, 8, 256, 8, np.float32)
    got = sinnamon_score.sinnamon_score(qv, rows, qbits, u, None,
                                        tile_c=128, interpret=True)
    want = ref.sinnamon_score_ref(qv, rows, qbits, u, None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


@pytest.mark.parametrize("dtype", [np.float32, "bfloat16"])
@pytest.mark.parametrize("C,P,n,tile", [(128, 8, 200, 64), (512, 17, 1000, 256)])
def test_csr_score_sweep(rng, dtype, C, P, n, tile):
    idx = rng.integers(-1, n, (C, P)).astype(np.int32)
    val = rng.normal(0, 1, (C, P)).astype(jnp.dtype(dtype))
    qd = rng.normal(0, 1, n).astype(np.float32)
    got = csr_score.csr_score(jnp.asarray(qd), jnp.asarray(idx),
                              jnp.asarray(val), tile_c=tile, interpret=True)
    want = ref.csr_score_ref(jnp.asarray(qd), jnp.asarray(idx),
                             jnp.asarray(val))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2 if dtype != np.float32 else 1e-5,
                               atol=1e-4)


@pytest.mark.parametrize("V,D,B,F", [(50, 16, 8, 5), (200, 32, 4, 9),
                                     (30, 128, 16, 1)])
def test_embed_bag_sweep(rng, V, D, B, F):
    table = rng.normal(0, 1, (V, D)).astype(np.float32)
    idx = rng.integers(-1, V, (B, F)).astype(np.int32)
    w = rng.normal(0, 1, (B, F)).astype(np.float32)
    got = embed_bag.embed_bag(jnp.asarray(table), jnp.asarray(idx),
                              jnp.asarray(np.where(idx >= 0, w, 0.0)),
                              interpret=True)
    want = ref.embed_bag_ref(jnp.asarray(table), jnp.asarray(idx),
                             jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


def test_embed_bag_mean_mode(rng):
    table = rng.normal(0, 1, (40, 8)).astype(np.float32)
    idx = rng.integers(-1, 40, (6, 4)).astype(np.int32)
    got = ops.embed_bag(jnp.asarray(table), jnp.asarray(idx), mode="mean",
                        interpret=True)
    valid = idx >= 0
    rows = np.where(valid[..., None], table[np.where(valid, idx, 0)], 0)
    want = rows.sum(1) / np.maximum(valid.sum(1, keepdims=True), 1)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-6)


def test_ops_end_to_end_matches_engine(rng):
    """Kernel-backed scoring == reference engine scoring on a live index."""
    from repro.core import engine as eng
    from repro.data import synth

    ds = synth.SparseDatasetSpec("t", n=300, psi_doc=20, psi_query=10)
    idx, val = synth.make_corpus(0, ds, 150, pad=40)
    qi, qv = synth.make_queries(1, ds, 4, pad=20)
    spec = eng.EngineSpec(n=300, m=16, capacity=160, max_nnz=40, h=2)
    index = eng.SinnamonIndex(spec)
    index.insert_many(list(range(150)), idx, val)
    qvp, rows, qbits = ops.prepare_query_operands(
        index.state, jnp.asarray(qi), jnp.asarray(qv))
    kout = ops.sinnamon_score_batch(index.state, qvp, rows, qbits, tile_c=128)
    eout = eng.score_batch(index.state, spec, jnp.asarray(qi), jnp.asarray(qv))
    np.testing.assert_allclose(np.asarray(kout), np.asarray(eout), rtol=1e-5,
                               atol=1e-5)
