"""The explicit shard_map GNN path must match the GSPMD-auto path bitwise-ish
(subprocess with 4 forced host devices: data=2 × model=2)."""

import subprocess
import sys
import textwrap

import pytest

pytestmark = [pytest.mark.slow, pytest.mark.distributed]

CODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.data import graph as graphdata
    from repro.distributed import mesh as meshlib
    from repro.models import gnn, gnn_sharded

    cfg = gnn.GNNConfig(n_layers=2, c=8, l_max=2, m_max=1, n_heads=2,
                        n_rbf=4, f_in=5, n_out=3, edge_chunk=8, remat=False)
    g = graphdata.random_geometric_graph(0, n_nodes=16, n_edges=32,
                                         d_feat=5, n_classes=3)
    g = jax.tree.map(lambda x: jnp.asarray(x) if isinstance(x, np.ndarray)
                     else x, g)
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    ref_loss, _ = gnn.loss_fn(params, g, cfg)

    mesh = meshlib.make_mesh((2, 2), ("data", "model"))
    with mesh:
        loss, _ = jax.jit(lambda p, gg: gnn_sharded.loss_fn_sharded(
            p, gg, cfg, mesh))(params, g)
    err = abs(float(ref_loss) - float(loss))
    print("MATCH" if err < 5e-3 else f"MISMATCH {float(ref_loss)} vs "
          f"{float(loss)}")

    # gradient equivalence (exercises the custom_vjp aggregate backward)
    g_ref = jax.grad(lambda p: gnn.loss_fn(p, g, cfg)[0])(params)
    with mesh:
        g_sh = jax.jit(jax.grad(lambda p: gnn_sharded.loss_fn_sharded(
            p, g, cfg, mesh)[0]))(params)
    flat_r = jax.tree.leaves(g_ref)
    flat_s = jax.tree.leaves(g_sh)
    gerr = max(float(jnp.abs(a.astype(jnp.float32)
                             - b.astype(jnp.float32)).max())
               for a, b in zip(flat_r, flat_s))
    scale = max(float(jnp.abs(a).max()) for a in flat_r)
    # The shard_map backward reorders fp accumulation vs the GSPMD-auto
    # path (per-shard partial sums merged by psum); measured drift on CPU
    # is ~7e-3 at scale 0.38, so 2e-2 is the tightest gate the math
    # actually meets — bitwise equality is not a property this pairing has.
    print("GRAD_MATCH" if gerr < 2e-2 * max(scale, 1) else
          f"GRAD_MISMATCH {gerr} scale {scale}")
""")


def test_sharded_gnn_matches_reference():
    out = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                         text=True, cwd=".", timeout=600)
    assert "MATCH" in out.stdout, out.stdout[-2000:] + out.stderr[-3000:]
    assert "GRAD_MATCH" in out.stdout, out.stdout[-2000:] + out.stderr[-3000:]
