"""`repro.api` facade contracts (ISSUE 7 satellite).

Every deployment shape an `IndexConfig` can describe — {single, sharded} x
{ephemeral, durable}, plus the accuracy levers — must:

* open through ``open_index`` and serve queries,
* produce byte-identical engine state to its LEGACY constructor spelling
  (the facade routes, it must not reinterpret),
* for durable shapes: snapshot, reopen, and recover byte-identically.

Plus the config-surface contracts: validation, derived per-shard capacity,
and ``backend`` pinning subsuming ``REPRO_SCORE_BACKEND``.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.api import DurabilityConfig, IndexConfig, open_index
from repro.core.engine import SinnamonIndex
from repro.data import synth
from repro.distributed import mesh as meshlib
from repro.serving.serve import QueryServer
from repro.serving.sharded import ShardedSinnamonIndex

DS = synth.SparseDatasetSpec("api", n=400, psi_doc=20, psi_query=10,
                             value_dist="gaussian")
N_DOCS = 64


@pytest.fixture(scope="module")
def corpus():
    idx, val = synth.make_corpus(0, DS, N_DOCS, pad=32)
    qi, qv = synth.make_queries(1, DS, 4, pad=16)
    return idx, val, qi, qv


def _config(**kw):
    base = dict(n=DS.n, capacity=128, m=12, h=2, max_nnz=32, seed=3,
                store_dtype="float32")
    base.update(kw)
    return IndexConfig(**base)


def _fill(index, corpus):
    idx, val, _, _ = corpus
    index.insert_many(list(range(N_DOCS)), idx[:N_DOCS], val[:N_DOCS])
    index.delete(7)
    return index


def _assert_state_equal(a, b):
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), a, b)


def _assert_serves(index, corpus):
    _, _, qi, qv = corpus
    srv = QueryServer(index, k=10, kprime=40)
    res = srv.query(qi[0], qv[0])
    assert res.ids.shape == (10,)
    assert 7 not in np.asarray(res.ids)              # the deleted doc
    return res


# ---------------------------------------------------------------------------
# facade vs legacy constructors: identical state, every permutation
# ---------------------------------------------------------------------------

def test_single_ephemeral_matches_legacy(corpus):
    cfg = _config()
    via_api = _fill(open_index(cfg), corpus)
    assert isinstance(via_api, SinnamonIndex)
    legacy = _fill(SinnamonIndex(cfg.engine_spec()), corpus)
    _assert_state_equal(via_api.state, legacy.state)
    a, b = _assert_serves(via_api, corpus), _assert_serves(legacy, corpus)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.scores),
                                  np.asarray(b.scores))


def test_sharded_ephemeral_matches_legacy(corpus):
    mesh = meshlib.single_device_mesh(("data", "model"))
    cfg = _config()
    via_api = _fill(open_index(cfg, mesh=mesh), corpus)
    assert isinstance(via_api, ShardedSinnamonIndex)
    legacy = _fill(ShardedSinnamonIndex(cfg.engine_spec(), mesh,
                                        update_block=cfg.update_block),
                   corpus)
    _assert_state_equal(via_api.state, legacy.state)
    _assert_serves(via_api, corpus)


def test_durable_single_matches_legacy_and_recovers(corpus, tmp_path):
    from repro.persist import DurableSinnamonIndex

    cfg = _config(durability=DurabilityConfig(
        wal_dir=str(tmp_path / "api" / "wal"),
        snapshot_dir=str(tmp_path / "api" / "snap")))
    via_api = _fill(open_index(cfg), corpus)
    assert isinstance(via_api, DurableSinnamonIndex)
    legacy_d = dataclasses.replace(
        cfg.durability, wal_dir=str(tmp_path / "legacy" / "wal"),
        snapshot_dir=str(tmp_path / "legacy" / "snap"))
    legacy = _fill(DurableSinnamonIndex.open(cfg.engine_spec(),
                                             **legacy_d.kwargs()), corpus)
    _assert_state_equal(via_api.state, legacy.state)
    _assert_serves(via_api, corpus)
    via_api.snapshot()
    recovered = open_index(cfg)                   # same dirs -> recovery
    assert recovered.size == N_DOCS - 1
    _assert_state_equal(recovered.state, legacy.state)
    _assert_serves(recovered, corpus)


def test_durable_sharded_matches_legacy_and_recovers(corpus, tmp_path):
    from repro.persist import DurableShardedSinnamonIndex

    mesh = meshlib.single_device_mesh(("data", "model"))
    cfg = _config(durability=DurabilityConfig(
        wal_dir=str(tmp_path / "api" / "wal"),
        snapshot_dir=str(tmp_path / "api" / "snap")))
    via_api = _fill(open_index(cfg, mesh=mesh), corpus)
    assert isinstance(via_api, DurableShardedSinnamonIndex)
    legacy_d = dataclasses.replace(
        cfg.durability, wal_dir=str(tmp_path / "legacy" / "wal"),
        snapshot_dir=str(tmp_path / "legacy" / "snap"))
    legacy = _fill(DurableShardedSinnamonIndex.open(
        cfg.engine_spec(), mesh, update_block=cfg.update_block,
        **legacy_d.kwargs()), corpus)
    _assert_state_equal(via_api.state, legacy.state)
    _assert_serves(via_api, corpus)
    via_api.snapshot()
    recovered = open_index(cfg, mesh=mesh)
    assert recovered.size == N_DOCS - 1
    _assert_state_equal(recovered.state, legacy.state)
    _assert_serves(recovered, corpus)


# ---------------------------------------------------------------------------
# accuracy levers through the facade
# ---------------------------------------------------------------------------

def test_lever_configs_open_and_serve(corpus):
    for levers in ({"sketch_kind": "lite"}, {"cell_dtype": "f8"},
                   {"index_buckets": 128}):
        index = _fill(open_index(_config(**levers)), corpus)
        _assert_serves(index, corpus)
        assert index.config.sketch_kind == levers.get("sketch_kind", "full")


# ---------------------------------------------------------------------------
# config surface
# ---------------------------------------------------------------------------

def test_backend_pinning_subsumes_env(corpus):
    cfg = _config(backend="reference")
    index = _fill(open_index(cfg), corpus)
    assert index.default_backend == "reference"
    res = _assert_serves(index, corpus)
    assert res.backend == "reference"
    # per-call override still wins over the pinned default
    _, _, qi, qv = corpus
    ids, _ = index.search(qi[0], qv[0], k=10, backend="pallas")
    assert ids.shape == (10,)


def test_local_capacity_derivation():
    cfg = IndexConfig(n=100, capacity=100, shards=3)
    assert cfg.local_capacity == 64          # ceil(100/3)=34 -> round to 64
    assert cfg.engine_spec().capacity == 64
    assert IndexConfig(n=100, capacity=96).local_capacity == 96


def test_config_validation():
    with pytest.raises(ValueError):
        IndexConfig(n=100, capacity=0)
    with pytest.raises(ValueError):
        IndexConfig(n=100, capacity=32, shards=0)
    with pytest.raises(ValueError):
        IndexConfig(n=100, capacity=32, backend="not_a_backend")
    with pytest.raises(ValueError):
        DurabilityConfig(wal_dir="/w", snapshot_every=5)  # no snapshot_dir


def test_config_attached_to_index(corpus):
    cfg = _config()
    index = open_index(cfg)
    assert index.config is cfg
    assert index.default_backend is None
