"""Property-based GNN equivariance test (optional `hypothesis` dev dep);
separate module so a missing dep degrades to a skip, not a collection error."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="optional dev dep; property tests skip without it")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models import gnn  # noqa: E402

from test_gnn import _graph, _rand_rot  # noqa: E402

pytestmark = pytest.mark.slow


@given(seed=st.integers(0, 1000))
@settings(max_examples=5, deadline=None)
def test_equivariance_property(seed):
    """Hypothesis: equivariance holds for random graphs/rotations/params."""
    gen = np.random.default_rng(seed)
    cfg = gnn.GNNConfig(n_layers=1, c=8, l_max=2, m_max=1, n_heads=2,
                        n_rbf=4, f_in=3, n_out=2, edge_chunk=64)
    params = gnn.init_params(jax.random.PRNGKey(seed), cfg)
    g = _graph(gen, N=8, E=20, f_in=3)
    Rm = _rand_rot(gen)
    g_rot = g._replace(edge_vec=jnp.asarray(np.asarray(g.edge_vec) @ Rm.T))
    f1 = gnn.forward(params, g, cfg)
    f2 = gnn.forward(params, g_rot, cfg)
    scale = max(float(jnp.abs(f1).max()), 1.0)
    assert float(jnp.abs(f1[:, 0, :] - f2[:, 0, :]).max()) < 2e-3 * scale
