"""Churn drift regression: §4.3 delete-then-recycle leaves sketch columns
carrying stale residue (merge-on-recycle), so upper bounds grow loose; the
compaction pass must restore them to EXACTLY a freshly built index's sketch,
on both the single-device and the 1-device-mesh sharded index."""

import jax.numpy as jnp
import numpy as np

from repro.core import engine as eng
from repro.core.engine import EngineSpec, SinnamonIndex
from repro.data import synth
from repro.distributed import mesh as meshlib
from repro.persist import compact
from repro.serving.sharded import ShardedSinnamonIndex

# psi_query is deliberately dense: comparisons against a freshly BUILT index
# place documents on different slots, so zero-score ties in the rerank tail
# would be broken by layout, not by content — dense queries keep the top-k
# strictly positive and distinct.
DS = synth.SparseDatasetSpec("t", n=300, psi_doc=16, psi_query=24,
                             value_dist="gaussian")


def _spec(capacity=64):
    return EngineSpec(n=DS.n, m=12, capacity=capacity, max_nnz=32, h=2,
                      seed=3, value_dtype="float32")


def _churn(index, idx, val, waves=3):
    """Insert 64 docs, then repeatedly delete + re-insert over the same
    slots.  Returns the per-wave max drift and the final live (ids→row) map.
    """
    index.insert_many(list(range(64)), idx[:64], val[:64])
    next_id, row = 64, {e: e for e in range(64)}
    drifts = []
    for w in range(waves):
        victims = sorted(row)[w * 7 % 31::5][:8]
        for v in victims:
            index.delete(v)
            row.pop(v)
        rows = [64 + (next_id + j) % 32 for j in range(len(victims))]
        new_ids = list(range(next_id, next_id + len(victims)))
        index.insert_many(new_ids, idx[rows], val[rows])
        for e, r in zip(new_ids, rows):
            row[e] = r
        next_id += len(victims)
        drifts.append(float(index.slot_drift().max()))
    return drifts, row


def _fresh_like(row, idx, val, capacity=64):
    fresh = SinnamonIndex(_spec(capacity))
    ids = sorted(row)
    fresh.insert_many(ids, idx[[row[e] for e in ids]],
                      val[[row[e] for e in ids]])
    return fresh


def test_churn_accumulates_drift_and_compaction_removes_it():
    idx, val = synth.make_corpus(0, DS, 96, pad=32)
    index = SinnamonIndex(_spec())
    drifts, row = _churn(index, idx, val)

    # drift is real, positive, and survives across waves
    assert drifts[0] > 0
    assert max(drifts) == max(index.slot_drift().max(), max(drifts))
    m = compact.drift_metrics(index)
    assert m["max_overestimate"] > 0 and m["dirty_active"] > 0

    dirty_before = int(np.asarray(index.state.dirty).sum())
    n = index.compact()
    assert n == dirty_before > 0
    assert not np.asarray(index.state.dirty).any()
    after = compact.drift_metrics(index)
    assert after["max_overestimate"] == 0.0
    assert after["dirty_total"] == 0

    # post-compaction sketch == a freshly built index's, per live document
    fresh = _fresh_like(row, idx, val)
    qi, qv = synth.make_queries(1, DS, 4, pad=32)
    for q in range(4):
        s_c = np.asarray(eng.score(index.state, index.spec,
                                   jnp.asarray(qi[q]), jnp.asarray(qv[q])))
        s_f = np.asarray(eng.score(fresh.state, fresh.spec,
                                   jnp.asarray(qi[q]), jnp.asarray(qv[q])))
        for e in row:
            assert s_c[index._id2slot[e]] == s_f[fresh._id2slot[e]], e
        # and the search results (ids + exact rerank scores) agree.
        # kprime=capacity: the two indexes lay documents out on different
        # slots, so sub-capacity candidate cuts tie-break the (many) zero
        # upper bounds by slot order — a layout artifact, not drift.
        ids_c, sc_c = index.search(qi[q], qv[q], k=10, kprime=64)
        ids_f, sc_f = fresh.search(qi[q], qv[q], k=10, kprime=64)
        np.testing.assert_array_equal(ids_c, ids_f)
        np.testing.assert_array_equal(sc_c, sc_f)


def test_upper_bound_stays_valid_under_churn():
    """Theorem 5.1 must hold for the DIRTY sketch too (loose, never wrong)."""
    idx, val = synth.make_corpus(2, DS, 96, pad=32)
    index = SinnamonIndex(_spec())
    _churn(index, idx, val)
    qi, qv = synth.make_queries(3, DS, 6, pad=32)
    from repro.storage import vecstore
    for q in range(6):
        s = np.asarray(eng.score(index.state, index.spec,
                                 jnp.asarray(qi[q]), jnp.asarray(qv[q])))
        qd = vecstore.densify_query(DS.n, jnp.asarray(qi[q]),
                                    jnp.asarray(qv[q]))
        exact = np.asarray(vecstore.exact_scores_all(index.state.store, qd))
        active = np.asarray(index.state.active)
        assert (s[active] - exact[active]).min() >= -1e-4


def test_sharded_churn_compaction_matches_single_device():
    idx, val = synth.make_corpus(4, DS, 96, pad=32)
    mesh = meshlib.single_device_mesh(("data", "model"))
    sharded = ShardedSinnamonIndex(_spec(), mesh)
    single = SinnamonIndex(_spec())
    for index in (sharded, single):
        index.insert_many(list(range(64)), idx[:64], val[:64])
        for v in (3, 11, 25, 40):
            index.delete(v)
        index.insert_many([100, 101, 102, 103], idx[64:68], val[64:68])

    # both accumulate identical drift ...
    np.testing.assert_allclose(sharded.slot_drift(), single.slot_drift(),
                               atol=1e-6)
    assert sharded.slot_drift().max() > 0
    # ... and compaction brings them to the same exact state
    assert sharded.compact() == single.compact() > 0
    assert not np.asarray(sharded.state.dirty).any()
    qi, qv = synth.make_queries(5, DS, 4, pad=32)
    for q in range(4):
        ids_s, sc_s = sharded.search(qi[q], qv[q], k=10, kprime=40)
        ids_0, sc_0 = single.search(qi[q], qv[q], k=10, kprime=40)
        np.testing.assert_array_equal(ids_s, ids_0)
        np.testing.assert_array_equal(sc_s, sc_0)
    assert sharded.slot_drift().max() == 0.0
