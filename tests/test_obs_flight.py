"""ISSUE 8 contracts: trace context, flight recorder, SLO monitor, and the
health/readiness + debug HTTP surfaces.

* `TraceContext` accumulates per-stage timings and annotations and seals
  into a flat record dict.
* `FlightRecorder` tail-samples at completion: non-ok outcomes always
  retained, slowest decile retained once warm, the rest head-sampled; the
  ring is bounded and retained records spill to the event log.
* `EventLog` rotates by size without ever splitting a line; `read_events`
  tolerates a torn FINAL line (crash shape) but raises on interior
  corruption.
* `SLOMonitor` computes multi-window burn rates from registry counts with
  an injected clock, and emits one edge-triggered `slo_burn` WARN per
  episode.
* Histogram exemplars survive exposition, parsing, and snapshot merge.
* `/healthz` is pure liveness; `/readyz` aggregates latched flags + live
  checks into 200/503 with per-check reasons; `/debug/*` dispatches by
  prefix and validates query params.
* `/metrics` stays parseable under concurrent scrapes during write churn.
"""

import json
import threading
import urllib.error
import urllib.request

import pytest

from repro.obs import (Buckets, EventLog, FlightRecorder, MetricsRegistry,
                       MetricsServer, ReadyState, Trace, TraceContext,
                       merge_snapshots, parse_exposition, read_events)
from repro.obs.server import build_endpoints, dispatch
from repro.obs.slo import SLOMonitor, SLOSpec


def _rec(outcome="ok", total_ms=1.0, trace_id=None, tenant="default",
         **extra):
    d = {"trace_id": trace_id or f"q-t-{id(extra) % 100000:x}",
         "tenant": tenant, "outcome": outcome, "total_ms": total_ms,
         "stages": []}
    d.update(extra)
    return d


# ---------------------------------------------------------------------------
# TraceContext
# ---------------------------------------------------------------------------

def test_trace_context_stages_and_seal():
    ctx = TraceContext(tenant="t0")
    assert ctx.trace_id.startswith("q-")
    ctx.add_stage("quota", 0.5, start_ms=0.0)
    with ctx.stage("work"):
        pass
    ctx.add_stage("work", 2.0)            # repeated names accumulate
    ctx.annotate(batch_id="b-1", width_bucket=32)
    tr = Trace("staged")
    with tr.span("scan"):
        pass
    ctx.add_trace(tr, prefix="device/")
    ctx.finish("ok", total_ms=7.25)
    d = ctx.to_dict()
    assert d["outcome"] == "ok" and d["total_ms"] == 7.25
    assert d["batch_id"] == "b-1" and d["width_bucket"] == 32
    names = [s["stage"] for s in d["stages"]]
    assert names == ["quota", "work", "work", "device/scan"]
    assert d["stages"][0]["start_ms"] == 0.0
    assert "start_ms" not in d["stages"][3]     # imported spans: dur only
    assert ctx.stage_ms()["work"] >= 2.0
    # finish() without total_ms uses the context's own wall clock
    ctx2 = TraceContext().finish("error", error="boom")
    assert ctx2.total_ms >= 0.0
    assert ctx2.to_dict()["error"] == "boom"


# ---------------------------------------------------------------------------
# FlightRecorder retention
# ---------------------------------------------------------------------------

def test_recorder_keeps_every_non_ok_outcome():
    rec = FlightRecorder(capacity=16, sample_rate=0.0, spill=False,
                         registry=MetricsRegistry())
    for i, outcome in enumerate(["error", "expired", "rejected_throttled",
                                 "rejected_queue_full"]):
        assert rec.record(_rec(outcome, trace_id=f"q-{i}")) == "outcome"
    assert len(rec) == 4
    assert rec.get("q-2")["outcome"] == "rejected_throttled"
    assert rec.recent(outcome="rejected") and all(
        r["outcome"].startswith("rejected")
        for r in rec.recent(outcome="rejected"))


def test_recorder_tail_retains_slowest_decile():
    reg = MetricsRegistry()
    rec = FlightRecorder(capacity=256, sample_rate=0.0, spill=False,
                         min_tail_samples=32, registry=reg)
    # 32 fast OK requests warm the p90 threshold (recomputed at the 32nd,
    # which must itself sit below the fresh threshold to stay dropped)
    for i in range(31):
        assert rec.record(_rec("ok", total_ms=1.0, trace_id=f"q-w{i}")) \
            is None
    assert rec.record(_rec("ok", total_ms=0.5, trace_id="q-w31")) is None
    assert rec.tail_threshold_ms == pytest.approx(1.0)
    assert rec.record(_rec("ok", total_ms=50.0, trace_id="q-slow")) == "tail"
    assert rec.record(_rec("ok", total_ms=0.5, trace_id="q-fast")) is None
    assert rec.get("q-slow")["retained"] == "tail"
    assert rec.get("q-fast") is None
    snap = json.loads(reg.to_json())
    retained = {s["labels"]["reason"]: s["value"]
                for s in snap["repro_recorder_retained_total"]["series"]}
    assert retained == {"tail": 1}
    assert snap["repro_recorder_dropped_total"]["series"][0]["value"] == 33


def test_recorder_head_sampling_and_ring_eviction():
    rec = FlightRecorder(capacity=4, sample_rate=1.0, spill=False,
                         registry=MetricsRegistry())
    for i in range(6):
        assert rec.record(_rec("ok", trace_id=f"q-{i}")) == "sampled"
    assert len(rec) == 4
    assert rec.get("q-0") is None and rec.get("q-1") is None  # evicted
    assert rec.get("q-5") is not None
    assert rec.stats()["seen"] == 6 and rec.stats()["ring_size"] == 4
    # sample_rate=0.25 keeps every 4th
    quarter = FlightRecorder(capacity=64, sample_rate=0.25, spill=False,
                             registry=MetricsRegistry())
    kept = sum(1 for i in range(40)
               if quarter.record(_rec("ok", total_ms=None,
                                      trace_id=f"q-{i}")))
    assert kept == 10


def test_recorder_spills_retained_records_to_event_log(tmp_path):
    path = str(tmp_path / "events.jsonl")
    with EventLog(path) as log:
        rec = FlightRecorder(capacity=8, sample_rate=0.0, event_log=log,
                             spill=True, registry=MetricsRegistry())
        rec.record(_rec("error", trace_id="q-err"))
        rec.record(_rec("ok", trace_id="q-ok"))       # dropped, no spill
    events = read_events(path)
    assert [e["event"] for e in events] == ["request_trace"]
    assert events[0]["trace_id"] == "q-err"
    assert events[0]["level"] == "WARN"


def test_recorder_batches_and_filters():
    rec = FlightRecorder(capacity=32, sample_rate=1.0, spill=False,
                         registry=MetricsRegistry())
    rec.record(_rec("ok", total_ms=3.0, trace_id="q-a", tenant="t0"))
    rec.record(_rec("ok", total_ms=9.0, trace_id="q-b", tenant="t1"))
    rec.record_batch({"batch_id": "b-1", "trace_ids": ["q-a", "q-b"],
                      "size": 2})
    assert rec.get_batch("b-1")["size"] == 2
    assert rec.recent_batches() == [{"batch_id": "b-1",
                                     "trace_ids": ["q-a", "q-b"], "size": 2}]
    assert [r["trace_id"] for r in rec.recent(tenant="t1")] == ["q-b"]
    assert [r["trace_id"] for r in rec.recent(min_ms=5.0)] == ["q-b"]
    assert [r["trace_id"] for r in rec.recent(limit=1)] == ["q-b"]  # newest


# ---------------------------------------------------------------------------
# EventLog rotation + torn-line tolerance
# ---------------------------------------------------------------------------

def test_event_log_rotates_by_size_without_splitting_lines(tmp_path):
    path = str(tmp_path / "ev.jsonl")
    with EventLog(path, max_bytes=256, keep=2) as log:
        for i in range(40):
            log.emit("tick", i=i)
        assert log.rotations >= 2
        segs = log.segments()
    assert segs[-1] == path and f"{path}.1" in segs
    # every surviving file parses whole — no torn interior lines
    for seg in segs:
        with open(seg) as f:
            for line in f:
                json.loads(line)
    events = read_events(path, include_rotated=True)
    ids = [e["i"] for e in events]
    assert ids == sorted(ids) and ids[-1] == 39    # oldest-first, contiguous
    assert len(ids) <= 40                          # keep=2 dropped the oldest


def test_read_events_tolerates_torn_tail_rejects_interior(tmp_path):
    path = str(tmp_path / "torn.jsonl")
    with EventLog(path) as log:
        log.emit("a")
        log.emit("b")
    with open(path, "a") as f:
        f.write('{"ts": 1, "level": "INFO", "eve')   # crash mid-append
    events = read_events(path)
    assert [e["event"] for e in events] == ["a", "b"]
    bad = str(tmp_path / "corrupt.jsonl")
    with open(bad, "w") as f:
        f.write('{"ts": 1, "level": "INFO", "event": "a"}\n')
        f.write("NOT JSON\n")                        # interior corruption
        f.write('{"ts": 2, "level": "INFO", "event": "b"}\n')
    with pytest.raises(ValueError, match="malformed interior"):
        read_events(bad)


# ---------------------------------------------------------------------------
# SLO monitor
# ---------------------------------------------------------------------------

def _count(reg, outcome, n):
    reg.counter("repro_frontend_requests_total", "outcomes",
                labels={"tenant": "t", "outcome": outcome}).inc(n)


def test_slo_burn_rates_multi_window(tmp_path):
    reg = MetricsRegistry()
    log = EventLog(str(tmp_path / "slo.jsonl"))
    t = [0.0]
    mon = SLOMonitor(SLOSpec(latency_ms=100.0, availability_target=0.999),
                     reg, fast_window_s=60.0, slow_window_s=600.0,
                     burn_warn=2.0, event_log=log, clock=lambda: t[0])
    mon.tick()                                   # baseline sample at t=0
    _count(reg, "ok", 90)
    _count(reg, "error", 10)                     # 90% availability
    t[0] = 10.0
    out = mon.tick()
    fast = out["availability"]["windows"]["fast"]
    assert fast["good"] == 90 and fast["total"] == 100
    assert fast["compliance"] == pytest.approx(0.9)
    assert fast["burn_rate"] == pytest.approx(0.1 / 0.001, rel=1e-3)
    # both windows burning -> exactly ONE edge-triggered WARN
    t[0] = 20.0
    mon.tick()
    warns = [e for e in read_events(log.path) if e["event"] == "slo_burn"]
    assert len(warns) == 1 and warns[0]["level"] == "WARN"
    # far beyond the slow window the bad episode ages out -> re-armed
    t[0] = 2000.0
    mon.tick()
    assert not mon._burning
    _count(reg, "error", 50)
    t[0] = 2010.0
    mon.tick()
    warns = [e for e in read_events(log.path) if e["event"] == "slo_burn"]
    assert len(warns) == 2                       # second episode, second WARN
    snap = json.loads(reg.to_json())
    burn = {tuple(sorted(s["labels"].items())): s["value"]
            for s in snap["repro_slo_burn_rate"]["series"]}
    assert len(burn) == 4                        # 2 objectives x 2 windows
    log.close()


def test_slo_latency_objective_reads_histogram_and_report_schema():
    reg = MetricsRegistry()
    t = [0.0]
    mon = SLOMonitor(SLOSpec(latency_ms=100.0, latency_target=0.99), reg,
                     clock=lambda: t[0])
    mon.tick()                                   # baseline before traffic
    h = reg.histogram("repro_frontend_latency_ms", "lat",
                      labels={"tenant": "t"})
    for _ in range(98):
        h.observe(1.0)
    h.observe(500.0)
    h.observe(900.0)                             # 98/100 under 100ms
    t[0] = 10.0
    rep = mon.report()
    assert rep["objectives"]["latency_ms"] == 100.0
    assert set(rep["windows"]) == {"fast", "slow"}
    lat = rep["slos"]["latency"]
    assert lat["bound_ms"] >= 100.0              # snapped UP to a bucket edge
    fast = lat["windows"]["fast"]
    assert fast["total"] == 100 and fast["good"] >= 98
    assert fast["burn_rate"] <= 2.001
    for key in ("burn_rate", "compliance", "good", "total", "window_s"):
        assert key in fast


# ---------------------------------------------------------------------------
# exemplars
# ---------------------------------------------------------------------------

def test_exemplars_survive_exposition_parse_and_merge():
    reg = MetricsRegistry()
    h = reg.histogram("repro_exemplar_test_ms", "h",
                      buckets=Buckets(1.0, 2.0, 8))
    h.observe(3.2, exemplar="q-abc-1")
    h.observe(3.3)                               # same bucket, no exemplar
    text = reg.exposition()
    line = next(ln for ln in text.splitlines() if "# {" in ln)
    assert 'trace_id="q-abc-1"' in line and line.rstrip().endswith("3.2")
    parse_exposition(text)                       # suffix validates + strips
    snap = json.loads(reg.to_json())
    series = snap["repro_exemplar_test_ms"]["series"][0]
    (ex,) = series["exemplars"].values()
    assert ex == {"trace_id": "q-abc-1", "value": 3.2}
    # merge: exemplars union, later source wins per bucket
    reg2 = MetricsRegistry()
    h2 = reg2.histogram("repro_exemplar_test_ms", "h",
                        buckets=Buckets(1.0, 2.0, 8))
    h2.observe(3.4, exemplar="q-abc-2")
    merged = merge_snapshots(reg.snapshot(), reg2.snapshot())
    series = merged["repro_exemplar_test_ms"]["series"][0]
    assert series["count"] == 3
    (ex,) = series["exemplars"].values()
    assert ex["trace_id"] == "q-abc-2"


# ---------------------------------------------------------------------------
# readiness + debug endpoint dispatch
# ---------------------------------------------------------------------------

def test_ready_state_flags_and_live_checks():
    ready = ReadyState()
    ready.mark("engine", False, "recovering")
    ok, detail = ready()
    assert not ok and detail["engine"] == {"ok": False,
                                           "reason": "recovering"}
    ready.mark("engine", True)
    depth = [0]
    ready.add_check("queue", lambda: (depth[0] < 10, f"depth={depth[0]}"))
    assert ready()[0]
    depth[0] = 50
    ok, detail = ready()
    assert not ok and detail["queue"]["reason"] == "depth=50"
    ready.add_check("boom", lambda: 1 / 0)       # raising check = not ready
    ok, detail = ready()
    assert not ok and "check raised" in detail["boom"]["reason"]


def test_debug_endpoint_dispatch_and_param_validation():
    reg = MetricsRegistry()
    rec = FlightRecorder(capacity=8, sample_rate=0.0, spill=False,
                         registry=reg)
    rec.record(_rec("error", trace_id="q-x", tenant="t9"))
    rec.record_batch({"batch_id": "b-x", "size": 1})
    eps = build_endpoints(reg, recorder=rec)
    status, body, _ = dispatch(eps, "/debug/trace/q-x")
    assert status == 200 and json.loads(body)["outcome"] == "error"
    status, body, _ = dispatch(eps, "/debug/trace/b-x")   # batch ids resolve
    assert status == 200 and json.loads(body)["size"] == 1
    status, body, _ = dispatch(eps, "/debug/trace/q-nope")
    assert status == 404 and json.loads(body)["error"] == "not_found"
    status, body, _ = dispatch(eps, "/debug/trace/")
    assert status == 400
    status, body, _ = dispatch(eps, "/debug/requests?tenant=t9&limit=5")
    doc = json.loads(body)
    assert status == 200 and doc["count"] == 1
    assert doc["recorder"]["seen"] == 1    # batches don't count as requests
    status, body, _ = dispatch(eps, "/debug/requests?limit=abc")
    assert status == 400 and json.loads(body)["error"] == "bad_request"
    assert dispatch(eps, "/debug/nothing") is None        # unrouted -> 404
    status, _, _ = dispatch(eps, "/healthz")
    assert status == 200


def test_metrics_server_healthz_vs_readyz():
    reg = MetricsRegistry()
    ready = ReadyState()
    ready.mark("engine", False, "index build/recovery in progress")
    with MetricsServer(reg, port=0, ready=ready) as srv:
        assert urllib.request.urlopen(
            srv.url + "/healthz", timeout=10).read() == b"ok\n"
        with pytest.raises(urllib.error.HTTPError) as exc:
            urllib.request.urlopen(srv.url + "/readyz", timeout=10)
        assert exc.value.code == 503
        doc = json.loads(exc.value.read())
        assert doc["ready"] is False
        assert doc["checks"]["engine"]["reason"].startswith("index build")
        ready.mark("engine", True)
        doc = json.loads(urllib.request.urlopen(
            srv.url + "/readyz", timeout=10).read())
        assert doc["ready"] is True


def test_concurrent_scrapes_during_write_churn():
    reg = MetricsRegistry()
    stop = threading.Event()

    def churn(i):
        h = reg.histogram("repro_churn_test_ms", "h")
        c = reg.counter("repro_churn_test_total", "c",
                        labels={"writer": str(i)})
        v = 0.1
        while not stop.is_set():
            h.observe(v, exemplar=f"q-{i}")
            c.inc()
            v = v * 1.1 if v < 1e3 else 0.1

    writers = [threading.Thread(target=churn, args=(i,), daemon=True)
               for i in range(4)]
    for w in writers:
        w.start()
    try:
        with MetricsServer(reg, port=0) as srv:
            def scrape(out):
                for _ in range(5):
                    text = urllib.request.urlopen(
                        srv.url + "/metrics", timeout=10).read().decode()
                    out.append(parse_exposition(text))

            results = [[] for _ in range(4)]
            scrapers = [threading.Thread(target=scrape, args=(r,))
                        for r in results]
            for s in scrapers:
                s.start()
            for s in scrapers:
                s.join(timeout=30)
                assert not s.is_alive()
    finally:
        stop.set()
        for w in writers:
            w.join(timeout=5)
    for r in results:
        assert len(r) == 5                       # every scrape parsed clean
        for flat in r:
            names = {n for n, _l in flat}
            assert "repro_churn_test_ms_count" in names
